//! Quickstart: encode a data stream with ZAC-DEST through the v2
//! `Session` API, compare the energy against the exact BD-Coder
//! baseline, and inspect the approximation.
//!
//! Run: `cargo run --release --example quickstart`

use zac_dest::encoding::CodecSpec;
use zac_dest::session::{Session, Trace, TrafficClass};
use zac_dest::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // An image-like byte stream (slowly varying values — the data
    // similarity ZAC-DEST exploits).
    let mut r = Rng::new(1);
    let mut v = 128i32;
    let bytes: Vec<u8> = (0..256 * 1024)
        .map(|_| {
            v = (v + (r.below(9) as i32 - 4)).clamp(0, 255);
            v as u8
        })
        .collect();
    let trace = Trace::from_bytes(bytes);

    // Exact baseline: the paper's modified BD-Coder. The codec comes
    // from the open registry ("BDE" is its Table I name), and the
    // stream is marked error-resilient — the default TrafficClass is
    // Critical, which never approximates.
    let bde = Session::builder()
        .codec(CodecSpec::named("BDE"))
        .traffic(TrafficClass::Approximate)
        .build()?
        .run(&trace)?;
    assert_eq!(bde.bytes, trace.bytes(), "exact schemes are lossless");

    // ZAC-DEST at an 80% similarity limit: approximate, much cheaper.
    let spec = CodecSpec::zac(80);
    let zac = Session::builder()
        .codec(spec.clone())
        .traffic(TrafficClass::Approximate)
        .build()?
        .run(&trace)?;

    println!(
        "stream: {} bytes ({} cache lines)\n",
        trace.byte_len(),
        trace.line_count()
    );
    println!(
        "BDE  (exact)  : termination 1s {:>9}  switching {:>9}",
        bde.counts.termination_ones, bde.counts.switching_transitions
    );
    println!(
        "ZAC-DEST L80  : termination 1s {:>9}  switching {:>9}",
        zac.counts.termination_ones, zac.counts.switching_transitions
    );
    println!(
        "savings vs BDE: termination {:.1}%  switching {:.1}%",
        zac.counts.termination_savings_vs(&bde.counts),
        zac.counts.switching_savings_vs(&bde.counts)
    );

    // The reconstruction is approximate, but bounded by the similarity
    // envelope: every 64-bit *chip word* differs by < 13 bits (80% of
    // 64). Note the envelope is per chip word — the channel interleaves
    // bytes across chips, so we must compare in chip-word space.
    let thr = spec.zac_knobs().expect("zac spec").dissimilar_threshold();
    let recon_words = zac_dest::trace::bytes_to_chip_words(&zac.bytes);
    let max_diff = trace
        .lines()
        .iter()
        .zip(&recon_words)
        .flat_map(|(a, b)| a.iter().zip(b).map(|(x, y)| (x ^ y).count_ones()))
        .max()
        .unwrap();
    println!("\nmax per-word approximation: {max_diff} bits (envelope: < {thr})");
    assert!(max_diff < thr);

    // Per-outcome breakdown (cf. paper Fig. 22).
    println!("\nencoding outcomes:");
    for o in zac_dest::encoding::Outcome::all() {
        println!("  {:<10} {:>6.1}%", o.label(), 100.0 * zac.stats.fraction(o));
    }

    // Fault injection: the same run over voltage-scaled approximate
    // DRAM (EDEN-style 1.05 V bin — the CLI equivalent is
    // `zac-dest encode --faults voltage:1050`). Energy is identical by
    // construction (injection happens after the transfer was paid
    // for); only the quality axis moves, and critical traffic would
    // bypass injection entirely.
    let faulty = Session::builder()
        .codec(spec.clone())
        .traffic(TrafficClass::Approximate)
        .faults(zac_dest::faults::FaultSpec::voltage(1050))
        .build()?
        .run(&trace)?;
    assert_eq!(faulty.counts, zac.counts, "energy is fault-invariant");
    println!("\nunder 1.05 V approximate DRAM:");
    println!("  {}", faulty.quality_delta());

    // Correcting codecs: at a deep voltage bin (1.0 V, BER 1e-3) a bare
    // exact scheme surfaces every injected flip, while the SECDED(72,64)
    // wrapper repairs single flips per word before the base decoder
    // runs — quality recovered for one extra sideband line of
    // termination energy. CLI: `zac-dest encode --scheme ECC+BDE
    // --faults voltage:1000`.
    let deep = zac_dest::faults::FaultSpec::voltage(1000);
    let bare = Session::builder()
        .codec(CodecSpec::named("BDE"))
        .traffic(TrafficClass::Approximate)
        .faults(deep)
        .build()?
        .run(&trace)?;
    let ecc = Session::builder()
        .codec(CodecSpec::named("ECC+BDE"))
        .traffic(TrafficClass::Approximate)
        .faults(deep)
        .build()?
        .run(&trace)?;
    println!("\ncorrecting codecs at the 1.0 V bin:");
    println!("  BDE     : {}", bare.quality_delta());
    println!("  ECC+BDE : {}", ecc.quality_delta());
    assert!(ecc.faults.corrected_bits > 0, "the wrapper never repaired a bit");
    assert!(
        ecc.faults.residual_error_bits < bare.faults.residual_error_bits,
        "correction failed to recover quality"
    );

    // Address steering: on a multi-channel system the placement policy
    // decides which channel's DataTable sees which lines. Round-robin
    // (the default) scatters neighboring lines across channels;
    // `steer` keeps whole pages — and similar value regions — on one
    // channel, so each channel's table history is maximally similar and
    // the hit rate (and with it the skip-transfer savings) rises. The
    // CLI equivalent is `zac-dest encode --channels 4 --address steer`.
    use zac_dest::system::AddressSpec;
    let at = |address: AddressSpec| -> anyhow::Result<zac_dest::session::RunReport> {
        Session::builder()
            .codec(spec.clone())
            .channels(4)
            .address(address)
            .traffic(TrafficClass::Approximate)
            .build()?
            .run(&trace)
    };
    let rr = at(AddressSpec::round_robin())?;
    let steer = at(AddressSpec::steer())?;
    println!("\naddress steering at 4 channels:");
    println!(
        "  round_robin: table hit rate {:>5.1}%  termination 1s {:>9}",
        100.0 * rr.stats.table_hit_rate(),
        rr.counts.termination_ones
    );
    println!(
        "  steer      : table hit rate {:>5.1}%  termination 1s {:>9}  (load imbalance {:.2}x)",
        100.0 * steer.stats.table_hit_rate(),
        steer.counts.termination_ones,
        steer.load_imbalance()
    );
    // (The hit-rate advantage is pinned by rust/tests/address.rs on the
    // canonical synthetic trace; this demo just shows the comparison.)

    // Telemetry: the same run with the metrics registry on — per-stage
    // drive-loop timings, mailbox backpressure and per-chunk service
    // latency, at zero cost when off (no clock reads on the hot path).
    // CLI: `zac-dest encode --channels 2 --metrics-out metrics.json`,
    // or `ZAC_METRICS=1` on any run.
    let timed = Session::builder()
        .codec(spec.clone())
        .channels(2)
        .traffic(TrafficClass::Approximate)
        .telemetry(true)
        .build()?
        .run(&trace)?;
    let snap = timed.telemetry.expect("telemetry was requested");
    println!("\n{}", snap.render_table());

    // Record & replay: persist the trace as a framed `.zactrace` file
    // and stream it back through the mmap-backed reader — the replayed
    // run is bit-identical to the live one, without the stream resident
    // in RAM. CLI: `zac-dest record run.zactrace --bytes 262144` then
    // `zac-dest replay run.zactrace --scheme ZAC-DEST` and
    // `zac-dest trace-info run.zactrace`.
    let path = std::env::temp_dir().join("zac_quickstart.zactrace");
    trace.record(&path, true)?;
    let file = zac_dest::trace::wire::TraceFile::open(&path)?;
    let replayed = Session::builder()
        .codec(spec.clone())
        .traffic(TrafficClass::Approximate)
        .build()?
        .replay(&file)?;
    assert_eq!(replayed.bytes, zac.bytes, "replay must be bit-identical");
    assert_eq!(replayed.counts, zac.counts, "replay must cost the same");
    let info = file.inspect();
    println!(
        "\nrecorded {} bytes in {} frames ({:.1}% zero lines), replayed bit-identically",
        file.byte_len(),
        file.frame_count(),
        100.0 * info.zero_fraction()
    );
    std::fs::remove_file(&path)?;

    // Parallel + resumable sweeps: the scenario grid fans across a
    // work-stealing pool (workers=1 is pinned bit-identical, so
    // parallelism is a pure wall-clock knob), and every result row
    // carries a content fingerprint so an interrupted sweep resumes
    // without re-running finished cells. CLI:
    // `zac-dest sweep --workers 4` then `zac-dest sweep --resume`.
    use zac_dest::system::{run_sweep, run_sweep_resume, SweepSpec};
    let sweep_spec = SweepSpec {
        name: "quickstart".into(),
        bytes: 64 * 1024,
        workers: 2,
        ..SweepSpec::default()
    };
    let sweep_trace = Trace::from_bytes(trace.bytes()[..64 * 1024].to_vec());
    let first = run_sweep(&sweep_spec, &sweep_trace)?;
    println!(
        "\nsweep: {} cells on {} workers in {:.2}s",
        first.cells_run, first.workers, first.wall_s
    );
    let resumed = run_sweep_resume(&sweep_spec, &sweep_trace, Some(&first))?;
    assert_eq!(resumed.cells_run, 0, "a completed sweep resumes for free");
    println!(
        "resume: {} cells re-run, {} carried over",
        resumed.cells_run, resumed.cells_skipped
    );

    // Open-loop load generation: replay the trace into the sharded
    // array at fixed offered rates (the closed-loop sweep can never see
    // queueing — it pushes only as fast as the shards drain). CLI:
    // `zac-dest sweep --open-loop 5e4,2e5`.
    use zac_dest::system::{run_loadgen, LoadGenSpec};
    let lg = LoadGenSpec::from_sweep(&sweep_spec, vec![1e5, 1e9])?;
    let curve = run_loadgen(&lg, &Trace::from_bytes(trace.bytes()[..16 * 1024].to_vec()))?;
    println!("\n{}", curve.render_table());
    Ok(())
}
