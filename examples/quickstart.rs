//! Quickstart: encode a data stream with ZAC-DEST, compare the energy
//! against the exact BD-Coder baseline, and inspect the approximation.
//!
//! Run: `cargo run --release --example quickstart`

use zac_dest::coordinator::simulate_bytes;
use zac_dest::encoding::{Scheme, ZacConfig};
use zac_dest::util::rng::Rng;

fn main() {
    // An image-like byte stream (slowly varying values — the data
    // similarity ZAC-DEST exploits).
    let mut r = Rng::new(1);
    let mut v = 128i32;
    let bytes: Vec<u8> = (0..256 * 1024)
        .map(|_| {
            v = (v + (r.below(9) as i32 - 4)).clamp(0, 255);
            v as u8
        })
        .collect();

    // Exact baseline: the paper's modified BD-Coder.
    let bde = simulate_bytes(&ZacConfig::scheme(Scheme::Bde), &bytes, true);
    assert_eq!(bde.bytes, bytes, "exact schemes are lossless");

    // ZAC-DEST at an 80% similarity limit: approximate, much cheaper.
    let cfg = ZacConfig::zac(80);
    let zac = simulate_bytes(&cfg, &bytes, true);

    println!("stream: {} bytes ({} cache lines)\n", bytes.len(), bytes.len() / 64);
    println!(
        "BDE  (exact)  : termination 1s {:>9}  switching {:>9}",
        bde.counts.termination_ones, bde.counts.switching_transitions
    );
    println!(
        "ZAC-DEST L80  : termination 1s {:>9}  switching {:>9}",
        zac.counts.termination_ones, zac.counts.switching_transitions
    );
    println!(
        "savings vs BDE: termination {:.1}%  switching {:.1}%",
        zac.counts.termination_savings_vs(&bde.counts),
        zac.counts.switching_savings_vs(&bde.counts)
    );

    // The reconstruction is approximate, but bounded by the similarity
    // envelope: every 64-bit *chip word* differs by < 13 bits (80% of
    // 64). Note the envelope is per chip word — the channel interleaves
    // bytes across chips, so we must compare in chip-word space.
    let thr = cfg.dissimilar_threshold();
    let orig_words = zac_dest::trace::bytes_to_chip_words(&bytes);
    let recon_words = zac_dest::trace::bytes_to_chip_words(&zac.bytes);
    let max_diff = orig_words
        .iter()
        .zip(&recon_words)
        .flat_map(|(a, b)| a.iter().zip(b).map(|(x, y)| (x ^ y).count_ones()))
        .max()
        .unwrap();
    println!("\nmax per-word approximation: {max_diff} bits (envelope: < {thr})");
    assert!(max_diff < thr);

    // Per-outcome breakdown (cf. paper Fig. 22).
    println!("\nencoding outcomes:");
    for o in zac_dest::encoding::Outcome::all() {
        println!("  {:<10} {:>6.1}%", o.label(), 100.0 * zac.stats.fraction(o));
    }
}
