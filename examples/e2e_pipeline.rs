//! END-TO-END DRIVER: the full system on a real (synthetic) workload,
//! proving all three layers compose:
//!
//!   datasets → cache-line traces → MULTI-CHANNEL system layer (sharded
//!   channel array, one service-loop worker per channel, bounded chunk
//!   mailboxes = backpressure; `ZAC_CHANNELS` picks the shard count) →
//!   channel energy model → receiver-side reconstruction → PJRT
//!   workloads (L2 JAX graphs with L1 Pallas kernels inside) → quality
//!   metrics,
//!
//! for the paper's headline comparison: ZAC-DEST vs BD-Coder on all
//! five workloads, plus a short training run on reconstructed data with
//! the loss curve logged. Results are recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example e2e_pipeline`

use zac_dest::encoding::CodecSpec;
use zac_dest::runtime::Runtime;
use zac_dest::session::{Session, Trace, TrafficClass};
use zac_dest::system::{channels_from_env, AddressSpec};
use zac_dest::util::table::{f, pct, TextTable};
use zac_dest::workloads::{cnn, Kind, Suite, SuiteBudget};

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let seed = 42;
    let budget = match std::env::var("ZAC_BUDGET").as_deref() {
        Ok("full") => SuiteBudget::full(),
        _ => SuiteBudget::quick(),
    };

    // ---- Phase 1: build + train everything on clean data (L2/L1 via PJRT).
    eprintln!("[e2e] loading PJRT runtime + training workloads (clean data) ...");
    let rt = Runtime::load(Runtime::default_dir())?;
    let suite = Suite::build(rt, seed, budget)?;
    eprintln!(
        "[e2e] suite ready in {:.1}s (resnet clean acc {:.3}, svm {:.3}, eigen {:.3})",
        t0.elapsed().as_secs_f64(),
        suite.resnet_clean_acc,
        suite.svm_clean_acc,
        suite.eigen_clean_acc
    );

    // ---- Phase 2: stream the test-image trace through the sharded
    // channel array (round-robin address interleaving, one service-loop
    // worker per channel behind a bounded chunk mailbox) — all behind
    // one `Session` run.
    let spec = CodecSpec::zac(80);
    let mut bytes = Vec::new();
    for img in &suite.test_images {
        bytes.extend_from_slice(&img.data);
    }
    let trace = Trace::from_bytes(bytes);
    let channels = match channels_from_env()? {
        Some(list) => {
            if list.len() > 1 {
                eprintln!(
                    "[e2e] ZAC_CHANNELS lists {list:?}; this example streams one array, using {}",
                    list[0]
                );
            }
            list[0]
        }
        None => 2,
    };
    // ZAC_ADDRESS picks the placement policy (round_robin | steer |
    // capacity:<w0>/<w1>/...); steering routes similar pages to one
    // channel so its DataTable history stays relevant.
    let address = match std::env::var("ZAC_ADDRESS") {
        Ok(v) => AddressSpec::parse(&v)?,
        Err(_) => AddressSpec::round_robin(),
    };
    let session = Session::builder()
        .codec(spec.clone())
        .channels(channels)
        .address(address.clone())
        .traffic(TrafficClass::Approximate)
        .capacity_lines(64)
        .build()?;
    let ts = std::time::Instant::now();
    let streamed = session.run(&trace)?;
    eprintln!(
        "[e2e] streamed {} cache lines across {} channel(s) (address {}) in {:.1} ms \
         ({:.1} MB/s, table hit rate {:.1}%)",
        trace.line_count(),
        channels,
        address.label(),
        ts.elapsed().as_secs_f64() * 1e3,
        trace.byte_len() as f64 / ts.elapsed().as_secs_f64() / 1e6,
        100.0 * streamed.stats.table_hit_rate(),
    );
    println!("\n{}", streamed.render());

    // ---- Phase 3: the headline table — ZAC-DEST L80 vs BDE across all
    // five workloads: energy savings + output quality.
    println!("\n=== ZAC-DEST (L80) vs BD-Coder: energy & quality, all workloads ===\n");
    let mut t = TextTable::new(&[
        "workload",
        "term savings",
        "switch savings",
        "quality",
        "orig metric",
        "approx metric",
        "unencoded",
    ]);
    let mut mean_term = 0.0;
    let mut mean_sw = 0.0;
    let mut mean_q = 0.0;
    for kind in Kind::all() {
        let r = suite.eval(&spec, kind)?;
        // BDE baseline on the same trace for the savings columns.
        let kind_bytes: Vec<u8> = match kind {
            Kind::ImageNet | Kind::ResNet => trace.bytes().to_vec(),
            Kind::Quant => suite.kodak.iter().flat_map(|i| i.data.clone()).collect(),
            Kind::Eigen => suite.faces_test.iter().flat_map(|i| i.data.clone()).collect(),
            Kind::Svm => suite.fmnist_test.iter().flat_map(|i| i.data.clone()).collect(),
        };
        let base = Session::builder()
            .codec(CodecSpec::named("BDE"))
            .traffic(TrafficClass::Approximate)
            .build()?
            .run(&Trace::from_bytes(kind_bytes))?;
        let term = r.run.counts.termination_savings_vs(&base.counts);
        let sw = r.run.counts.switching_savings_vs(&base.counts);
        mean_term += term / 5.0;
        mean_sw += sw / 5.0;
        mean_q += r.quality / 5.0;
        t.row(vec![
            kind.label().into(),
            pct(term),
            pct(sw),
            f(r.quality, 3),
            f(r.original_metric, 3),
            f(r.approx_metric, 3),
            pct(100.0 * r.run.stats.unencoded_fraction()),
        ]);
    }
    t.row(vec![
        "MEAN".into(),
        pct(mean_term),
        pct(mean_sw),
        f(mean_q, 3),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    println!("{}", t.render());

    // ---- Phase 4: short training run ON RECONSTRUCTED data, logging
    // the loss curve (the paper's train-with-ZAC-DEST result).
    eprintln!("[e2e] training on ZAC-DEST-reconstructed images, logging loss ...");
    let (recon_train, _) = suite.reconstruct_images(&spec, &suite.train_images)?;
    let steps = suite.budget.train_steps;
    let (params, losses) = cnn::train(&suite.rt, &recon_train, steps, suite.budget.lr, seed ^ 0xE2E)?;
    println!("loss curve (train on reconstructed, {} steps):", losses.len());
    for (i, chunk) in losses.chunks(8.max(losses.len() / 8)).enumerate() {
        let mean = chunk.iter().sum::<f32>() / chunk.len() as f32;
        println!("  steps {:>3}..{:>3}  mean loss {:.4}", i * chunk.len(), i * chunk.len() + chunk.len(), mean);
    }
    let (recon_test, _) = suite.reconstruct_images(&spec, &suite.test_images)?;
    let acc = cnn::accuracy(&suite.rt, &params, &recon_test)?;
    println!(
        "\ntrained-on-reconstructed accuracy on reconstructed test: {:.3} \
         (clean-trained on clean: {:.3})",
        acc, suite.resnet_clean_acc
    );
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "training on reconstructed data must reduce the loss"
    );

    eprintln!("\n[e2e] total wall time {:.1}s — all layers composed OK", t0.elapsed().as_secs_f64());
    Ok(())
}
