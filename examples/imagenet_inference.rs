//! ImageNet-workload inference under approximation: train the CNN zoo
//! on clean data, then serve inference over images reconstructed from
//! ZAC-DEST channel traffic at each similarity limit (paper Fig. 11/13).
//!
//! Run: `make artifacts && cargo run --release --example imagenet_inference`

use zac_dest::encoding::CodecSpec;
use zac_dest::runtime::Runtime;
use zac_dest::workloads::{Kind, Suite, SuiteBudget};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(Runtime::default_dir())?;
    eprintln!("training the CNN zoo on clean data ...");
    let suite = Suite::build(rt, 42, SuiteBudget::quick())?;
    println!(
        "zoo of {} models, clean top-1: {:?}",
        suite.zoo.len(),
        suite
            .zoo_clean_acc
            .iter()
            .map(|a| format!("{a:.3}"))
            .collect::<Vec<_>>()
    );
    println!("\nlimit  quality  approx-top1  term-1s  ohe-skip%");
    for limit in [90u32, 80, 75, 70] {
        let r = suite.eval(&CodecSpec::zac(limit), Kind::ImageNet)?;
        println!(
            "L{limit:<4}  {:>6.3}  {:>10.3}  {:>8}  {:>7.1}",
            r.quality,
            r.approx_metric,
            r.run.counts.termination_ones,
            100.0 * r.run.stats.fraction(zac_dest::encoding::Outcome::OheSkip)
        );
    }
    Ok(())
}
