//! Energy sweep: every (similarity limit × truncation × tolerance) knob
//! combination over all five workload traces, as CSV on stdout — the
//! data behind the paper's Fig. 14/15/16.
//!
//! Run: `cargo run --release --example energy_sweep > sweep.csv`

use zac_dest::coordinator::simulate_bytes;
use zac_dest::encoding::{Scheme, ZacConfig};
use zac_dest::figures::FigureCtx;
use zac_dest::workloads::{Kind, SuiteBudget};

fn main() {
    let ctx = FigureCtx::new(42, SuiteBudget::quick());
    println!("workload,limit,trunc_bits,tol_bits,term_savings_vs_bde,switch_savings_vs_bde,ohe_frac,unencoded_frac");
    for kind in Kind::all() {
        let bytes = ctx.workload_trace(kind);
        let base = simulate_bytes(&ZacConfig::scheme(Scheme::Bde), &bytes, true);
        for limit in [90u32, 80, 75, 70] {
            for trunc in [0u32, 1, 2] {
                for tol in [0u32, 1, 2] {
                    let cfg = ZacConfig::zac_full(limit, trunc, tol);
                    let out = simulate_bytes(&cfg, &bytes, true);
                    println!(
                        "{},{},{},{},{:.2},{:.2},{:.4},{:.4}",
                        kind.label(),
                        limit,
                        trunc * 8,
                        tol * 8,
                        out.counts.termination_savings_vs(&base.counts),
                        out.counts.switching_savings_vs(&base.counts),
                        out.stats.fraction(zac_dest::encoding::Outcome::OheSkip),
                        out.stats.unencoded_fraction(),
                    );
                }
            }
        }
    }
}
