//! Energy sweep: every (similarity limit × truncation × tolerance) knob
//! combination over all five workload traces, as CSV on stdout — the
//! data behind the paper's Fig. 14/15/16, driven by the declarative
//! scenario engine (`system::SweepSpec` + `run_sweep`) instead of
//! hand-rolled config loops.
//!
//! `ZAC_CHANNELS` shards each run across that many 8-chip channels
//! (default 1, the paper's single-channel setup); `ZAC_SWEEP_WORKERS`
//! fans the grid cells across a work-stealing pool (default 1 —
//! sequential, bit-identical figures either way).
//!
//! Run: `cargo run --release --example energy_sweep > sweep.csv`

use zac_dest::encoding::Outcome;
use zac_dest::figures::FigureCtx;
use zac_dest::session::Trace;
use zac_dest::system::{channels_from_env, run_sweep, sweep_workers_from_env, SweepSpec};
use zac_dest::workloads::{Kind, SuiteBudget};

fn main() -> anyhow::Result<()> {
    let ctx = FigureCtx::new(42, SuiteBudget::quick());
    let channels = channels_from_env()?.unwrap_or_else(|| vec![1]);
    println!(
        "workload,channels,address,limit,trunc_bits,tol_bits,term_savings_vs_bde,switch_savings_vs_bde,ohe_frac,unencoded_frac"
    );
    let workers = sweep_workers_from_env()?.unwrap_or(1);
    for kind in Kind::all() {
        let trace = Trace::from_bytes(ctx.workload_trace(kind));
        let spec = SweepSpec {
            name: format!("energy_sweep_{}", kind.label()),
            channels: channels.clone(),
            schemes: vec!["OHE".into()],
            limits: vec![90, 80, 75, 70],
            truncations: vec![0, 1, 2],
            tolerances: vec![0, 1, 2],
            baseline: "BDE".into(),
            workers,
            ..SweepSpec::default()
        };
        let report = run_sweep(&spec, &trace)?;
        for r in &report.scenarios {
            println!(
                "{},{},{},{},{},{},{:.2},{:.2},{:.4},{:.4}",
                kind.label(),
                r.channels,
                r.address,
                r.limit,
                r.truncation_bits * 8,
                r.tolerance_bits * 8,
                r.term_savings_pct,
                r.switch_savings_pct,
                r.fraction(Outcome::OheSkip),
                r.fraction(Outcome::Raw),
            );
        }
    }
    Ok(())
}
