//! The paper's headline training result (Fig. 18): training the model
//! *on reconstructed images* recovers most of the quality lost to
//! aggressive approximation, so ZAC-DEST can save energy during both
//! training and inference.
//!
//! Run: `make artifacts && cargo run --release --example train_with_zacdest`

use zac_dest::encoding::CodecSpec;
use zac_dest::runtime::Runtime;
use zac_dest::workloads::{Kind, Suite, SuiteBudget};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(Runtime::default_dir())?;
    eprintln!("training the clean-baseline ResNet ...");
    let suite = Suite::build(rt, 42, SuiteBudget::quick())?;
    println!("clean test accuracy: {:.3}\n", suite.resnet_clean_acc);
    println!("config      trained-on-clean  trained-on-recon  improvement");
    for (limit, trunc) in [(80u32, 0u32), (70, 0), (70, 2)] {
        let spec = CodecSpec::zac_full(limit, trunc, 0);
        let base = suite.eval(&spec, Kind::ResNet)?;
        eprintln!("retraining on reconstructed images (L{limit} T{}) ...", trunc * 8);
        let retrained = suite.resnet_trained_on_recon(&spec)?;
        let imp = if base.quality > 0.0 {
            retrained.quality / base.quality
        } else {
            f64::INFINITY
        };
        println!(
            "L{limit} T{:<3}   {:>16.3}  {:>16.3}  {:>10.2}x",
            trunc * 8,
            base.quality,
            retrained.quality,
            imp
        );
    }
    Ok(())
}
