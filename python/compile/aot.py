"""AOT compiler: lower every L2 graph to HLO *text* + a manifest.

HLO text — NOT ``lowered.compile()`` / ``.serialize()`` — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is lowered with ``return_tuple=True`` so the rust side
always unwraps a tuple, and ``artifacts/manifest.json`` records the exact
positional argument shapes/dtypes plus output shapes so the rust runtime
can type-check literals before execution.

Usage:  python -m compile.aot --out ../artifacts [--only name[,name...]]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _cnn_param_specs():
    return [spec(s) for _, s in M.CNN_PARAM_SHAPES]


# name -> (fn, [arg specs], [arg names])
ARTIFACTS = {
    "cnn_infer": (
        M.cnn_infer,
        [spec((M.BATCH, M.IMG, M.IMG, 3))] + _cnn_param_specs(),
        ["images"] + [n for n, _ in M.CNN_PARAM_SHAPES],
    ),
    "cnn_train_step": (
        M.cnn_train_step,
        [spec((M.BATCH, M.IMG, M.IMG, 3)), spec((M.BATCH,), I32), spec((1,))]
        + _cnn_param_specs(),
        ["images", "labels", "lr"] + [n for n, _ in M.CNN_PARAM_SHAPES],
    ),
    "kmeans_step": (
        M.kmeans_step,
        [spec((M.KMEANS_N, M.KMEANS_D)), spec((M.KMEANS_K, M.KMEANS_D))],
        ["x", "c"],
    ),
    "kmeans_assign": (
        M.kmeans_assign_model,
        [spec((M.KMEANS_N, M.KMEANS_D)), spec((M.KMEANS_K, M.KMEANS_D))],
        ["x", "c"],
    ),
    "pca_cov": (
        M.pca_cov,
        [spec((M.FACE_N, M.FACE_D))],
        ["x"],
    ),
    "pca_power_iter": (
        M.pca_power_iter,
        [spec((M.FACE_D, M.FACE_D)), spec((M.FACE_D, M.PCA_K))],
        ["cov", "v"],
    ),
    "pca_project": (
        M.pca_project,
        [spec((M.FACE_N, M.FACE_D)), spec((M.FACE_D,)), spec((M.FACE_D, M.PCA_K))],
        ["x", "mean", "v"],
    ),
    "svm_train_step": (
        M.svm_train_step,
        [spec((M.SVM_D, M.SVM_C)), spec((M.SVM_B, M.SVM_D)), spec((M.SVM_B,), I32), spec((1,))],
        ["w", "x", "y", "lr"],
    ),
    "svm_infer": (
        M.svm_infer,
        [spec((M.SVM_D, M.SVM_C)), spec((M.SVM_B, M.SVM_D))],
        ["w", "x"],
    ),
    "trace_stats": (
        M.trace_stats,
        [spec((M.TRACE_N, 2), I32)],
        ["words"],
    ),
    "trace_screen": (
        M.trace_screen,
        [spec((M.TRACE_N, 2), I32), spec((M.TABLE_T, 2), I32)],
        ["words", "table"],
    ),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(dt).name]


def build(out_dir: str, only: set[str] | None = None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"artifacts": {}}
    for name, (fn, specs, arg_names) in ARTIFACTS.items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *specs)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "args": [
                {"name": an, "shape": list(s.shape), "dtype": _dtype_name(s.dtype)}
                for an, s in zip(arg_names, specs)
            ],
            "outputs": [
                {"shape": list(o.shape), "dtype": _dtype_name(o.dtype)} for o in outs
            ],
        }
        print(f"  {name}: {len(text)} chars -> {path}")
    mpath = os.path.join(out_dir, "manifest.json")
    # Merge with a pre-existing manifest when --only rebuilt a subset.
    if only and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        old["artifacts"].update(manifest["artifacts"])
        manifest = old
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"  manifest -> {mpath}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", default=None, help="comma-separated artifact subset")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    build(args.out, only)


if __name__ == "__main__":
    main()
