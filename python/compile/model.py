"""Layer-2 JAX compute graphs for the five ZAC-DEST workloads.

Every graph is a pure function over fixed shapes, lowered once by
``aot.py`` to HLO text and executed from the rust coordinator via PJRT.
Anything matmul-shaped routes through the Layer-1 Pallas kernels
(``kernels.matmul`` / ``kernels.conv2d`` / ...), so the kernels lower into
the same HLO module as the surrounding model.

Workload → graph map (see DESIGN.md §2):
  ImageNet / ResNet   → ``cnn_infer`` / ``cnn_train_step`` (residual CNN)
  Quant (K-Means)     → ``kmeans_step`` / ``kmeans_assign_model``
  Eigen (PCA faces)   → ``pca_cov`` / ``pca_power_iter`` / ``pca_project``
  SVM (sparse FMNIST) → ``svm_train_step`` / ``svm_infer``
  trace analytics     → ``trace_stats`` / ``trace_screen``
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import conv2d, kmeans_assign, matmul, popcount64, similarity_screen

# ---------------------------------------------------------------------------
# Residual CNN (ImageNet-zoo analogue + ResNet analogue)
#
# 32x32x3 u8 images (normalized to [0,1] on the rust side):
#   conv1 3->16 3x3 relu, maxpool2          -> 16x16x16
#   res  block: relu(conv 16->16 3x3 + id)  -> 16x16x16, maxpool2 -> 8x8x16
#   dense 1024 -> NUM_CLASSES
# ---------------------------------------------------------------------------

NUM_CLASSES = 10
IMG = 32
BATCH = 32
FEAT = (IMG // 4) * (IMG // 4) * 16  # 1024

CNN_PARAM_SHAPES = [
    ("w1", (3, 3, 3, 16)),
    ("b1", (16,)),
    ("w2", (3, 3, 16, 16)),
    ("b2", (16,)),
    ("w3", (FEAT, NUM_CLASSES)),
    ("b3", (NUM_CLASSES,)),
]


def _maxpool2(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_forward(images, w1, b1, w2, b2, w3, b3):
    """images: (B, 32, 32, 3) f32 in [0,1] -> logits (B, NUM_CLASSES)."""
    x = conv2d(images, w1) + b1
    x = jax.nn.relu(x)
    x = _maxpool2(x)  # (B, 16, 16, 16)
    # Residual block — the "ResNet" structural ingredient the paper's
    # CIFAR experiments rely on.
    r = conv2d(x, w2) + b2
    x = jax.nn.relu(x + r)
    x = _maxpool2(x)  # (B, 8, 8, 16)
    x = x.reshape(x.shape[0], -1)  # (B, FEAT)
    return matmul(x, w3) + b3


def cnn_infer(images, w1, b1, w2, b2, w3, b3):
    logits = cnn_forward(images, w1, b1, w2, b2, w3, b3)
    return (logits,)


def _cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.mean(nll)


def cnn_train_step(images, labels, lr, w1, b1, w2, b2, w3, b3):
    """One SGD step. labels: (B,) i32, lr: (1,) f32.

    Returns the updated parameters followed by the scalar loss (shaped
    (1,) so the rust side never deals with rank-0 literals).
    """
    params = (w1, b1, w2, b2, w3, b3)

    def loss_fn(ps):
        return _cross_entropy(cnn_forward(images, *ps), labels)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new = tuple(p - lr[0] * g for p, g in zip(params, grads))
    return new + (loss[None],)


# ---------------------------------------------------------------------------
# Quant: K-Means colour quantization
# ---------------------------------------------------------------------------

KMEANS_N = 4096  # pixels per step (one sampled block of an image)
KMEANS_K = 64
KMEANS_D = 3


def kmeans_step(x, c):
    """One Lloyd iteration. x: (N, 3) f32, c: (K, 3) f32.

    Returns (new_centroids (K,3), counts (K,) f32, assign (N,) i32).
    Empty clusters keep their previous centroid.
    """
    assign = kmeans_assign(x, c)
    onehot = jax.nn.one_hot(assign, c.shape[0], dtype=jnp.float32)  # (N, K)
    counts = jnp.sum(onehot, axis=0)  # (K,)
    sums = matmul(onehot.T, x)  # (K, 3)
    new_c = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None], c)
    return new_c, counts, assign


def kmeans_assign_model(x, c):
    return (kmeans_assign(x, c),)


# ---------------------------------------------------------------------------
# Eigen: PCA face matching
# ---------------------------------------------------------------------------

FACE_D = 24 * 24
FACE_N = 128
PCA_K = 16


def pca_cov(x):
    """Mean-center and form the covariance. x: (N, D) f32 -> (cov (D,D), mean (D,))."""
    mean = jnp.mean(x, axis=0)
    xc = x - mean
    cov = matmul(xc.T, xc) / jnp.float32(x.shape[0])
    return cov, mean


def _gram_schmidt(v):
    """Column-wise modified Gram-Schmidt (no LAPACK custom-calls — the
    PJRT-CPU 0.5.1 client cannot execute jax's lapack custom_call)."""
    d, k = v.shape

    def body(i, vv):
        col = vv[:, i]

        def inner(j, c):
            prev = vv[:, j]
            # Only subtract projections for j < i.
            proj = jnp.where(j < i, jnp.dot(prev, c), 0.0)
            return c - proj * prev

        col = jax.lax.fori_loop(0, i, inner, col)
        col = col / jnp.maximum(jnp.linalg.norm(col), 1e-8)
        return vv.at[:, i].set(col)

    return jax.lax.fori_loop(0, k, body, v)


def pca_power_iter(cov, v):
    """One blocked power-iteration step with re-orthonormalization.

    cov: (D, D) f32, v: (D, K) f32 -> (v' (D, K),)
    """
    v = matmul(cov, v)
    return (_gram_schmidt(v),)


def pca_project(x, mean, v):
    """Project faces into eigenspace. x: (N, D), mean: (D,), v: (D, K)."""
    return (matmul(x - mean, v),)


# ---------------------------------------------------------------------------
# SVM: multi-class linear SVM on sparse u8 images (FMNIST analogue)
# ---------------------------------------------------------------------------

SVM_D = 28 * 28
SVM_C = 10
SVM_B = 64


def svm_train_step(w, x, y, lr):
    """One subgradient step of multiclass (Crammer-Singer) hinge loss.

    w: (D, C) f32, x: (B, D) f32, y: (B,) i32, lr: (1,) f32
    -> (w' (D, C), loss (1,))
    """

    def loss_fn(wm):
        scores = matmul(x, wm)  # (B, C)
        correct = jnp.take_along_axis(scores, y[:, None], axis=1)  # (B, 1)
        margins = jnp.maximum(0.0, scores - correct + 1.0)
        # The correct class contributes margin exactly 1; subtract it.
        loss = jnp.mean(jnp.sum(margins, axis=1) - 1.0)
        return loss + 1e-4 * jnp.sum(wm * wm)

    loss, g = jax.value_and_grad(loss_fn)(w)
    return w - lr[0] * g, loss[None]


def svm_infer(w, x):
    scores = matmul(x, w)
    return (jnp.argmax(scores, axis=1).astype(jnp.int32),)


# ---------------------------------------------------------------------------
# Trace analytics: bulk hamming / CAM screen over packed channel words
# ---------------------------------------------------------------------------

TRACE_N = 8192
TABLE_T = 64


def trace_stats(words):
    """words: (N, 2) i32 -> (per-word hamming (N,), total (1,))."""
    h = popcount64(words)
    return h, jnp.sum(h)[None]


def trace_screen(words, table):
    """Batched CAM search. words: (N, 2) i32, table: (T, 2) i32 ->
    ((N, 2) i32 [min_dist, idx],)."""
    return (similarity_screen(words, table),)
