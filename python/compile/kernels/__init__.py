"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO).

All kernels run under ``interpret=True`` — the CPU PJRT client cannot
execute Mosaic custom-calls, so interpret mode is the correctness path and
real-TPU performance is estimated analytically (see DESIGN.md §Perf).
"""

from .matmul import matmul  # noqa: F401
from .conv2d import conv2d  # noqa: F401
from .kmeans import kmeans_assign  # noqa: F401
from .popcount import popcount64, similarity_screen  # noqa: F401
