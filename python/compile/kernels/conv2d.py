"""SAME-padding stride-1 conv2d as im2col + the Pallas matmul kernel.

Hardware adaptation: on GPU this conv would be a warp-tiled implicit-GEMM;
on TPU the idiomatic shape is explicit im2col (patch extraction is a pure
data-movement op XLA fuses into the surrounding layout changes) feeding the
128x128 MXU through the tiled Pallas matmul. The patch extraction is plain
differentiable jnp, so autodiff flows through it and into
``matmul``'s custom VJP.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .matmul import matmul


def _im2col(x: jax.Array, kh: int, kw: int) -> jax.Array:
    """(N, H, W, C) -> (N*H*W, KH*KW*C) patch matrix, SAME padding."""
    n, h, w, c = x.shape
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    cols = []
    for di in range(kh):
        for dj in range(kw):
            cols.append(xp[:, di : di + h, dj : dj + w, :])
    # (N, H, W, KH*KW*C) with the same (di, dj, c) ordering as a HWIO
    # weight reshape, so patches @ w.reshape(-1, Cout) is exactly the conv.
    patches = jnp.concatenate(cols, axis=-1)
    return patches.reshape(n * h * w, kh * kw * c)


def conv2d(x: jax.Array, w: jax.Array) -> jax.Array:
    """NHWC conv, SAME padding, stride 1, via the Pallas matmul.

    x: (N, H, W, Cin), w: (KH, KW, Cin, Cout) -> (N, H, W, Cout)
    """
    n, h, wd, _ = x.shape
    kh, kw, _, cout = w.shape
    patches = _im2col(x, kh, kw)
    out = matmul(patches, w.reshape(-1, cout))
    return out.reshape(n, h, wd, cout)
