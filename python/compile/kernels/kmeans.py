"""Pallas K-Means assignment kernel (the Quant workload hot-spot).

The grid tiles the point set; each step stages a (bm, D) block of points
plus the full (K, D) centroid table into VMEM (K=64, D=3 for colour
quantization — the centroid table is tiny and stays resident), computes
the (bm, K) squared-distance tile via the ||x||² - 2x·c + ||c||² expansion
(one MXU matmul + VPU rank-1 updates), and reduces with an argmin along
the centroid axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(x_ref, c_ref, o_ref):
    x = x_ref[...]  # (bm, D)
    c = c_ref[...]  # (K, D)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)[None, :]
    d = x2 - 2.0 * jnp.dot(x, c.T, preferred_element_type=jnp.float32) + c2
    o_ref[...] = jnp.argmin(d, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bm",))
def kmeans_assign(x: jax.Array, c: jax.Array, bm: int = 4096) -> jax.Array:
    """Nearest-centroid assignment. x: (N, D) f32, c: (K, D) f32 -> (N,) i32."""
    n, d = x.shape
    k, d2 = c.shape
    assert d == d2
    bm = min(bm, n)
    pad = (-n) % bm
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    out = pl.pallas_call(
        _assign_kernel,
        grid=((n + pad) // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), jnp.int32),
        interpret=True,
    )(xp, c)
    return out[:n]
