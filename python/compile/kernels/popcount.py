"""Pallas popcount / CAM-similarity-screen kernels.

These are the *trace analytics* hot-spots: bulk hamming-weight of packed
64-bit channel words (termination-energy estimation) and the batched
BD-Coder CAM search (min hamming distance + argmin index against a table).
64-bit words are carried as (N, 2) int32 (lo, hi) because PJRT-CPU
literals and the TPU VPU are 32-bit-lane friendly; all bit math runs in
uint32 with the classic SWAR popcount (shift-mask-multiply), which maps
onto VPU lane ops — no per-lane scalar loops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _popcnt_u32(v):
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (v * jnp.uint32(0x01010101)) >> 24


def _popcount_kernel(w_ref, o_ref):
    v = w_ref[...].astype(jnp.uint32)  # (bm, 2)
    p = _popcnt_u32(v).astype(jnp.int32)
    o_ref[...] = jnp.sum(p, axis=1)


@functools.partial(jax.jit, static_argnames=("bm",))
def popcount64(words: jax.Array, bm: int = 8192) -> jax.Array:
    """Per-word hamming weight. words: (N, 2) i32 -> (N,) i32."""
    n = words.shape[0]
    bm = min(bm, n)
    pad = (-n) % bm
    wp = jnp.pad(words, ((0, pad), (0, 0))) if pad else words
    out = pl.pallas_call(
        _popcount_kernel,
        grid=((n + pad) // bm,),
        in_specs=[pl.BlockSpec((bm, 2), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), jnp.int32),
        interpret=True,
    )(wp)
    return out[:n]


def _screen_kernel(w_ref, t_ref, o_ref):
    x = w_ref[...].astype(jnp.uint32)[:, None, :]  # (bm, 1, 2)
    t = t_ref[...].astype(jnp.uint32)[None, :, :]  # (1, T, 2)
    p = _popcnt_u32(jnp.bitwise_xor(x, t)).astype(jnp.int32)
    d = jnp.sum(p, axis=2)  # (bm, T)
    o_ref[...] = jnp.stack(
        [jnp.min(d, axis=1), jnp.argmin(d, axis=1).astype(jnp.int32)], axis=1
    )


@functools.partial(jax.jit, static_argnames=("bm",))
def similarity_screen(words: jax.Array, table: jax.Array, bm: int = 2048) -> jax.Array:
    """Batched CAM search: for each word the (min hamming distance, index)
    against every table entry. Ties resolve to the lowest index.

    words: (N, 2) i32, table: (T, 2) i32 -> (N, 2) i32 [min_dist, idx]
    """
    n = words.shape[0]
    t = table.shape[0]
    bm = min(bm, n)
    pad = (-n) % bm
    wp = jnp.pad(words, ((0, pad), (0, 0))) if pad else words
    out = pl.pallas_call(
        _screen_kernel,
        grid=((n + pad) // bm,),
        in_specs=[
            pl.BlockSpec((bm, 2), lambda i: (i, 0)),
            pl.BlockSpec((t, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, 2), jnp.int32),
        interpret=True,
    )(wp, table)
    return out[:n]
