"""Tiled Pallas matmul — the MXU-shaped compute hot-spot of every L2 model.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks (M/bm,
N/bn) output tiles; BlockSpec stages an (bm, K) x-stripe and a (K, bn)
y-stripe HBM→VMEM per step and the body is a single f32-accumulating
``jnp.dot`` that the TPU backend maps onto the 128x128 MXU systolic array.
Block sizes default to 128 so a tile pair + accumulator fits comfortably
in the ~16 MiB VMEM budget for every K used by the models in this repo
(worst case K=2048: (128*2048 + 2048*128 + 128*128)*4 B ≈ 4.3 MiB).

Autodiff: ``pallas_call`` has no automatic VJP, so ``matmul`` carries a
``jax.custom_vjp`` whose backward pass is two more Pallas matmuls (dx =
g @ y^T, dy = x^T @ g) — the training-step artifacts differentiate
straight through the kernel.

Runs with ``interpret=True`` (CPU PJRT cannot execute Mosaic calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    # One (bm, bn) output tile: full-K contraction, f32 accumulation on
    # the MXU. K is block-resident (see module docstring for the VMEM
    # budget argument).
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# Default block sizes. On a real TPU these would be 128 (one MXU tile,
# VMEM-resident — see the module docstring); under interpret=True each
# grid step costs ~0.6 ms of interpreter overhead on CPU, so the default
# M-block is large to keep the grid small (measured 216x on the conv1
# matmul: 151 ms at bm=128 -> 0.7 ms at full-M blocks; EXPERIMENTS.md
# §Perf). The BlockSpec structure is identical either way.
BM_DEFAULT = 4096
BN_DEFAULT = 128


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def _matmul_raw(
    x: jax.Array, y: jax.Array, bm: int = BM_DEFAULT, bn: int = BN_DEFAULT
) -> jax.Array:
    """Forward tiled matmul. Pads M/N up to the block grid, slices back."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {y.shape}"
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    yp = jnp.pad(y, ((0, 0), (0, np_ - n))) if np_ != n else y
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """``x @ y`` through the Pallas kernel, differentiable.

    x: (M, K), y: (K, N) -> (M, N); f32 in, f32 accumulate.
    """
    return _matmul_raw(x, y)


def _matmul_fwd(x, y):
    return _matmul_raw(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    # dx = g @ y^T, dy = x^T @ g — both via the same Pallas kernel so the
    # backward pass exercises identical MXU tiles.
    dx = _matmul_raw(g, y.T)
    dy = _matmul_raw(x.T, g)
    return dx, dy


matmul.defvjp(_matmul_fwd, _matmul_bwd)
