"""Pure-jnp correctness oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain ``jax.numpy`` / ``jax.lax`` ops only. The pytest suite
(``python/tests/``) sweeps shapes and dtypes with hypothesis and asserts
``assert_allclose(kernel(...), ref(...))`` — this is the core correctness
signal for Layer 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Reference matmul with f32 accumulation, matching kernels.matmul."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def conv2d_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Reference NHWC conv with SAME padding, stride 1.

    x: (N, H, W, Cin), w: (KH, KW, Cin, Cout) -> (N, H, W, Cout)
    """
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def kmeans_assign_ref(x: jax.Array, c: jax.Array) -> jax.Array:
    """Reference K-Means assignment: nearest centroid index per row.

    x: (N, D), c: (K, D) -> (N,) int32
    """
    # Squared euclidean distance via the expansion ||x||^2 - 2 x.c + ||c||^2.
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (N, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]  # (1, K)
    d = x2 - 2.0 * (x @ c.T) + c2  # (N, K)
    return jnp.argmin(d, axis=1).astype(jnp.int32)


def popcount_ref(words: jax.Array) -> jax.Array:
    """Reference per-word hamming weight for packed 64-bit words.

    words: (N, 2) int32 — low/high halves of a 64-bit word -> (N,) int32.
    """
    v = words.astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    v = (v * jnp.uint32(0x01010101)) >> 24
    return jnp.sum(v.astype(jnp.int32), axis=1)


def similarity_screen_ref(words: jax.Array, table: jax.Array) -> jax.Array:
    """Reference most-similar-entry screen.

    For each packed 64-bit word, the minimum hamming distance to any table
    entry and the index achieving it (the BD-Coder CAM search, batched).
    Ties resolve to the lowest index, matching the rust data table.

    words: (N, 2) int32, table: (T, 2) int32 -> (N, 2) int32 [min_dist, idx]
    """
    x = words.astype(jnp.uint32)[:, None, :]  # (N, 1, 2)
    t = table.astype(jnp.uint32)[None, :, :]  # (1, T, 2)
    v = jnp.bitwise_xor(x, t)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    v = (v * jnp.uint32(0x01010101)) >> 24
    d = jnp.sum(v.astype(jnp.int32), axis=2)  # (N, T)
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    mind = jnp.min(d, axis=1).astype(jnp.int32)
    return jnp.stack([mind, idx], axis=1)
