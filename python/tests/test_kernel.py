"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and value ranges; assert_allclose against ref.py
is the core correctness signal for Layer 1 (kernels run interpret=True).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    conv2d,
    kmeans_assign,
    matmul,
    popcount64,
    similarity_screen,
)
from compile.kernels import ref

SET = dict(max_examples=20, deadline=None)


def f32(rng, *shape):
    return jnp.array(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------- matmul


@settings(**SET)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 96),
    n=st.integers(1, 150),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = f32(rng, m, k), f32(rng, k, n)
    np.testing.assert_allclose(
        matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4
    )


@settings(**SET)
@given(
    m=st.integers(2, 64),
    k=st.integers(2, 48),
    n=st.integers(2, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_vjp_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = f32(rng, m, k), f32(rng, k, n)
    gx, gy = jax.grad(lambda a, b: jnp.sum(jnp.sin(matmul(a, b))), argnums=(0, 1))(x, y)
    rx, ry = jax.grad(lambda a, b: jnp.sum(jnp.sin(a @ b)), argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx, rx, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(gy, ry, rtol=1e-3, atol=1e-4)


def test_matmul_block_boundary_shapes():
    # Exactly at / just off the 128 tile boundary.
    rng = np.random.default_rng(0)
    for m, k, n in [(128, 128, 128), (129, 128, 127), (127, 64, 129), (1, 1, 1)]:
        x, y = f32(rng, m, k), f32(rng, k, n)
        np.testing.assert_allclose(
            matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4
        )


def test_matmul_identity():
    rng = np.random.default_rng(1)
    x = f32(rng, 33, 33)
    np.testing.assert_allclose(matmul(x, jnp.eye(33)), x, rtol=1e-6, atol=1e-6)


def test_matmul_zeros():
    z = jnp.zeros((17, 5), jnp.float32)
    y = jnp.ones((5, 9), jnp.float32)
    assert float(jnp.max(jnp.abs(matmul(z, y)))) == 0.0


# ---------------------------------------------------------------- conv2d


@settings(**SET)
@given(
    n=st.integers(1, 4),
    hw=st.integers(3, 16),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    kk=st.sampled_from([1, 3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(n, hw, cin, cout, kk, seed):
    rng = np.random.default_rng(seed)
    x = f32(rng, n, hw, hw, cin)
    w = f32(rng, kk, kk, cin, cout)
    np.testing.assert_allclose(
        conv2d(x, w), ref.conv2d_ref(x, w), rtol=1e-3, atol=1e-4
    )


def test_conv2d_grad_flows():
    rng = np.random.default_rng(2)
    x = f32(rng, 2, 8, 8, 3)
    w = f32(rng, 3, 3, 3, 4)
    g = jax.grad(lambda ww: jnp.sum(conv2d(x, ww) ** 2))(w)
    gr = jax.grad(lambda ww: jnp.sum(ref.conv2d_ref(x, ww) ** 2))(w)
    np.testing.assert_allclose(g, gr, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------- kmeans


@settings(**SET)
@given(
    n=st.integers(1, 600),
    k=st.integers(1, 64),
    d=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_kmeans_assign_matches_ref(n, k, d, seed):
    rng = np.random.default_rng(seed)
    x, c = f32(rng, n, d), f32(rng, k, d)
    np.testing.assert_array_equal(kmeans_assign(x, c), ref.kmeans_assign_ref(x, c))


def test_kmeans_assign_exact_hits():
    # Points equal to centroids must map to themselves.
    c = jnp.array(np.random.default_rng(3).normal(size=(16, 3)).astype(np.float32))
    assign = kmeans_assign(c, c)
    np.testing.assert_array_equal(np.asarray(assign), np.arange(16))


# ---------------------------------------------------------------- popcount


@settings(**SET)
@given(n=st.integers(1, 3000), seed=st.integers(0, 2**31 - 1))
def test_popcount_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    w = jnp.array(rng.integers(-(2**31), 2**31, size=(n, 2)).astype(np.int32))
    np.testing.assert_array_equal(popcount64(w), ref.popcount_ref(w))


def test_popcount_known_values():
    w = jnp.array([[0, 0], [-1, -1], [1, 0], [0, 1]], jnp.int32)
    np.testing.assert_array_equal(np.asarray(popcount64(w)), [0, 64, 1, 1])


@settings(**SET)
@given(
    n=st.integers(1, 512),
    t=st.sampled_from([1, 8, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_similarity_screen_matches_ref(n, t, seed):
    rng = np.random.default_rng(seed)
    w = jnp.array(rng.integers(-(2**31), 2**31, size=(n, 2)).astype(np.int32))
    tab = jnp.array(rng.integers(-(2**31), 2**31, size=(t, 2)).astype(np.int32))
    np.testing.assert_array_equal(
        similarity_screen(w, tab), ref.similarity_screen_ref(w, tab)
    )


def test_similarity_screen_exact_match_is_zero():
    rng = np.random.default_rng(4)
    tab = jnp.array(rng.integers(-(2**31), 2**31, size=(64, 2)).astype(np.int32))
    out = np.asarray(similarity_screen(tab, tab))
    np.testing.assert_array_equal(out[:, 0], np.zeros(64))
    np.testing.assert_array_equal(out[:, 1], np.arange(64))
