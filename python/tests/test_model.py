"""L2 semantic tests: shapes, learning behaviour, numerical sanity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(42)


def _init_cnn(rng):
    ps = []
    for name, shape in M.CNN_PARAM_SHAPES:
        if name.startswith("w"):
            fan_in = int(np.prod(shape[:-1]))
            ps.append(
                jnp.array(
                    (rng.normal(size=shape) * np.sqrt(2.0 / fan_in)).astype(np.float32)
                )
            )
        else:
            ps.append(jnp.zeros(shape, jnp.float32))
    return ps


def test_cnn_infer_shape(rng):
    ps = _init_cnn(rng)
    imgs = jnp.array(rng.random((M.BATCH, M.IMG, M.IMG, 3)).astype(np.float32))
    (logits,) = M.cnn_infer(imgs, *ps)
    assert logits.shape == (M.BATCH, M.NUM_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_cnn_train_step_reduces_loss(rng):
    ps = _init_cnn(rng)
    imgs = jnp.array(rng.random((M.BATCH, M.IMG, M.IMG, 3)).astype(np.float32))
    labels = jnp.array(rng.integers(0, M.NUM_CLASSES, M.BATCH).astype(np.int32))
    lr = jnp.array([0.05], jnp.float32)
    losses = []
    for _ in range(8):
        *ps, loss = M.cnn_train_step(imgs, labels, lr, *ps)
        losses.append(float(loss[0]))
    # Overfitting a single fixed batch must reduce the loss.
    assert losses[-1] < losses[0], losses


def test_cnn_train_step_param_shapes(rng):
    ps = _init_cnn(rng)
    imgs = jnp.zeros((M.BATCH, M.IMG, M.IMG, 3), jnp.float32)
    labels = jnp.zeros((M.BATCH,), jnp.int32)
    out = M.cnn_train_step(imgs, labels, jnp.array([0.1], jnp.float32), *ps)
    assert len(out) == len(ps) + 1
    for p, o in zip(ps, out[:-1]):
        assert p.shape == o.shape and p.dtype == o.dtype


def test_kmeans_step_reduces_inertia(rng):
    x = jnp.array(rng.random((M.KMEANS_N, 3)).astype(np.float32))
    c = jnp.array(rng.random((M.KMEANS_K, 3)).astype(np.float32))

    def inertia(x, c):
        d = jnp.sum((x[:, None, :] - c[None, :, :]) ** 2, axis=2)
        return float(jnp.mean(jnp.min(d, axis=1)))

    i0 = inertia(x, c)
    for _ in range(3):
        c, counts, assign = M.kmeans_step(x, c)
    i1 = inertia(x, c)
    assert i1 < i0
    assert int(jnp.sum(counts)) == M.KMEANS_N
    assert assign.shape == (M.KMEANS_N,)


def test_kmeans_step_empty_cluster_keeps_centroid(rng):
    x = jnp.ones((M.KMEANS_N, 3), jnp.float32)
    c = jnp.array(rng.random((M.KMEANS_K, 3)).astype(np.float32))
    far = c.at[5].set(jnp.array([100.0, 100.0, 100.0]))
    c2, counts, _ = M.kmeans_step(x, far)
    assert float(counts[5]) == 0.0
    np.testing.assert_allclose(np.asarray(c2[5]), [100.0, 100.0, 100.0])


def test_pca_pipeline_orthonormal_and_projects(rng):
    x = jnp.array(rng.normal(size=(M.FACE_N, M.FACE_D)).astype(np.float32))
    cov, mean = M.pca_cov(x)
    assert cov.shape == (M.FACE_D, M.FACE_D)
    np.testing.assert_allclose(np.asarray(cov), np.asarray(cov).T, atol=1e-3)
    v = jnp.array(rng.normal(size=(M.FACE_D, M.PCA_K)).astype(np.float32))
    for _ in range(5):
        (v,) = M.pca_power_iter(cov, v)
    vtv = np.asarray(v.T @ v)
    np.testing.assert_allclose(vtv, np.eye(M.PCA_K), atol=1e-3)
    (proj,) = M.pca_project(x, mean, v)
    assert proj.shape == (M.FACE_N, M.PCA_K)


def test_pca_power_iter_finds_dominant_direction(rng):
    # Covariance with a planted dominant axis.
    d = M.FACE_D
    u = np.zeros(d, np.float32)
    u[7] = 1.0
    cov = jnp.array(10.0 * np.outer(u, u) + 0.01 * np.eye(d), jnp.float32)
    v = jnp.array(rng.normal(size=(d, M.PCA_K)).astype(np.float32))
    for _ in range(20):
        (v,) = M.pca_power_iter(cov, v)
    lead = np.abs(np.asarray(v[:, 0]))
    assert lead[7] > 0.99


def test_svm_learns_separable_data(rng):
    # Two well-separated class blobs embedded in SVM_D dims.
    w = jnp.zeros((M.SVM_D, M.SVM_C), jnp.float32)
    xs = rng.normal(size=(M.SVM_B, M.SVM_D)).astype(np.float32) * 0.1
    ys = rng.integers(0, 2, M.SVM_B).astype(np.int32)
    xs[:, 0] += np.where(ys == 0, -3.0, 3.0)
    x, y = jnp.array(xs), jnp.array(ys)
    lr = jnp.array([0.05], jnp.float32)
    for _ in range(30):
        w, loss = M.svm_train_step(w, x, y, lr)
    (pred,) = M.svm_infer(w, x)
    acc = float(jnp.mean((pred == y).astype(jnp.float32)))
    assert acc > 0.95, acc


def test_trace_stats_totals(rng):
    w = jnp.array(rng.integers(-(2**31), 2**31, (M.TRACE_N, 2)).astype(np.int32))
    h, total = M.trace_stats(w)
    assert h.shape == (M.TRACE_N,)
    assert int(total[0]) == int(np.sum(np.asarray(h)))


def test_trace_screen_self_table(rng):
    tab = jnp.array(rng.integers(-(2**31), 2**31, (M.TABLE_T, 2)).astype(np.int32))
    words = jnp.tile(tab, (M.TRACE_N // M.TABLE_T, 1))
    (out,) = M.trace_screen(words, tab)
    assert int(jnp.max(out[:, 0])) == 0
