"""AOT pipeline tests: lowering determinism + manifest consistency."""

import json
import os

import pytest

from compile import aot
from compile import model as M


def test_all_artifacts_lower(tmp_path):
    aot.build(str(tmp_path))
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert set(manifest["artifacts"]) == set(aot.ARTIFACTS)
    for name, meta in manifest["artifacts"].items():
        p = tmp_path / meta["file"]
        assert p.exists(), name
        text = p.read_text()
        assert text.startswith("HloModule"), name
        assert len(meta["args"]) == len(aot.ARTIFACTS[name][1])


def test_lowering_is_deterministic(tmp_path):
    a, b = tmp_path / "a", tmp_path / "b"
    aot.build(str(a), only={"trace_stats"})
    aot.build(str(b), only={"trace_stats"})
    ma = json.loads((a / "manifest.json").read_text())
    mb = json.loads((b / "manifest.json").read_text())
    assert (
        ma["artifacts"]["trace_stats"]["sha256"]
        == mb["artifacts"]["trace_stats"]["sha256"]
    )


def test_manifest_shapes_match_model_constants(tmp_path):
    aot.build(str(tmp_path), only={"cnn_train_step"})
    m = json.loads((tmp_path / "manifest.json").read_text())
    args = m["artifacts"]["cnn_train_step"]["args"]
    assert args[0]["shape"] == [M.BATCH, M.IMG, M.IMG, 3]
    assert args[1] == {"name": "labels", "shape": [M.BATCH], "dtype": "i32"}
    outs = m["artifacts"]["cnn_train_step"]["outputs"]
    assert outs[-1]["shape"] == [1]  # loss
    # params round-trip shapes
    for (name, shape), a in zip(M.CNN_PARAM_SHAPES, args[3:]):
        assert a["name"] == name and a["shape"] == list(shape)


def test_hlo_has_no_serialized_proto_path(tmp_path):
    # Guard: we must emit text, never the 64-bit-id serialized proto that
    # xla_extension 0.5.1 rejects.
    aot.build(str(tmp_path), only={"svm_infer"})
    text = (tmp_path / "svm_infer.hlo.txt").read_text()
    assert "HloModule" in text.splitlines()[0]


def test_only_subset_merges_manifest(tmp_path):
    aot.build(str(tmp_path), only={"svm_infer"})
    aot.build(str(tmp_path), only={"trace_stats"})
    m = json.loads((tmp_path / "manifest.json").read_text())
    assert {"svm_infer", "trace_stats"} <= set(m["artifacts"])
