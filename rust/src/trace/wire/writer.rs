//! The `.zactrace` encoder: a streaming frame writer, separate from the
//! decoder per the rzCOBS discipline. Frames append as the traffic
//! arrives; the header's totals (byte length, frame count) are patched
//! in place on [`TraceWriter::finish`], so an interrupted recording is
//! detectable (its header still says zero frames → the reader reports
//! a frame-count mismatch rather than trusting a half-written file).

use std::fs::File;
use std::io::{BufWriter, Seek, SeekFrom, Write};
use std::path::Path;

use crate::trace::{ChipWords, LINE_BYTES};

use super::{
    crc32, io, Header, Layout, WireError, DEFAULT_CHUNK_LINES, FRAME_HEADER_BYTES, VERSION,
};

/// Streaming `.zactrace` writer: create, append chunks, finish.
///
/// ```no_run
/// # use zac_dest::trace::wire::{Layout, TraceWriter};
/// # fn demo(lines: &[[u64; 8]], byte_len: usize) -> Result<(), zac_dest::trace::wire::WireError> {
/// let mut w = TraceWriter::create("run.zactrace", Layout::Raw, true)?;
/// w.write_lines(lines, true)?;
/// w.finish(byte_len)?;
/// # Ok(())
/// # }
/// ```
pub struct TraceWriter {
    file: BufWriter<File>,
    layout: Layout,
    stream_approx: bool,
    chunk_lines: u32,
    frames: u64,
    lines: u64,
}

impl TraceWriter {
    /// Create `path` (truncating any existing file) and write the
    /// provisional header. Frames default to [`DEFAULT_CHUNK_LINES`]
    /// lines — the engines' native batch size.
    pub fn create(
        path: impl AsRef<Path>,
        layout: Layout,
        approx: bool,
    ) -> Result<TraceWriter, WireError> {
        Self::create_with_chunk(path, layout, approx, DEFAULT_CHUNK_LINES)
    }

    /// [`create`](Self::create) with an explicit nominal frame size in
    /// lines (recorded in the header; [`write_lines`](Self::write_lines)
    /// splits at this size).
    pub fn create_with_chunk(
        path: impl AsRef<Path>,
        layout: Layout,
        approx: bool,
        chunk_lines: u32,
    ) -> Result<TraceWriter, WireError> {
        if chunk_lines == 0 {
            return Err(WireError::BadChunkLines);
        }
        let file = File::create(path).map_err(io("creating trace file"))?;
        let mut w = TraceWriter {
            file: BufWriter::new(file),
            layout,
            stream_approx: approx,
            chunk_lines,
            frames: 0,
            lines: 0,
        };
        let header = w.header(0);
        w.file
            .write_all(&header.to_bytes())
            .map_err(io("writing trace header"))?;
        Ok(w)
    }

    fn header(&self, byte_len: u64) -> Header {
        Header {
            version: VERSION,
            line_bytes: LINE_BYTES as u32,
            chunk_lines: self.chunk_lines,
            layout: self.layout,
            traffic_approx: self.stream_approx,
            byte_len,
            frame_count: self.frames,
        }
    }

    /// Lines written so far.
    pub fn lines_written(&self) -> u64 {
        self.lines
    }

    /// Frames written so far.
    pub fn frames_written(&self) -> u64 {
        self.frames
    }

    /// Append one frame. `approx` is the frame's traffic class,
    /// recorded per frame so mixed-criticality streams replay
    /// faithfully. An empty slice writes nothing (the format forbids
    /// zero-line frames).
    pub fn write_chunk(&mut self, lines: &[ChipWords], approx: bool) -> Result<(), WireError> {
        if lines.is_empty() {
            return Ok(());
        }
        let payload = lines_to_le_bytes(lines);
        let mut head = [0u8; FRAME_HEADER_BYTES];
        head[0..4].copy_from_slice(&(lines.len() as u32).to_le_bytes());
        head[4..8].copy_from_slice(&(approx as u32).to_le_bytes());
        head[8..12].copy_from_slice(&crc32(&payload).to_le_bytes());
        self.file
            .write_all(&head)
            .map_err(io("writing frame header"))?;
        self.file
            .write_all(&payload)
            .map_err(io("writing frame payload"))?;
        self.frames += 1;
        self.lines += lines.len() as u64;
        Ok(())
    }

    /// Append a whole line slice, split into nominal-size frames.
    pub fn write_lines(&mut self, lines: &[ChipWords], approx: bool) -> Result<(), WireError> {
        for chunk in lines.chunks(self.chunk_lines as usize) {
            self.write_chunk(chunk, approx)?;
        }
        Ok(())
    }

    /// Validate `byte_len` against the lines written, patch the header
    /// totals in place and flush. Returns the final header.
    pub fn finish(mut self, byte_len: usize) -> Result<Header, WireError> {
        let need = (byte_len as u64).div_ceil(LINE_BYTES as u64);
        if need != self.lines {
            return Err(WireError::LengthMismatch {
                lines: self.lines,
                byte_len: byte_len as u64,
            });
        }
        let header = self.header(byte_len as u64);
        self.file
            .seek(SeekFrom::Start(0))
            .map_err(io("patching trace header"))?;
        self.file
            .write_all(&header.to_bytes())
            .map_err(io("patching trace header"))?;
        self.file.flush().map_err(io("flushing trace file"))?;
        Ok(header)
    }
}

/// Record pre-split cache lines to `path` in one call — the convenience
/// wrapper `Trace::record` and the CLI `record` command use.
pub fn write_trace(
    path: impl AsRef<Path>,
    lines: &[ChipWords],
    byte_len: usize,
    layout: Layout,
    approx: bool,
) -> Result<Header, WireError> {
    let mut w = TraceWriter::create(path, layout, approx)?;
    w.write_lines(lines, approx)?;
    w.finish(byte_len)
}

/// One cache line's on-disk payload encoding: 8 chip words, each u64
/// little-endian, in chip order. On little-endian hosts this equals the
/// in-memory `[u64; 8]` representation — what makes the reader's
/// zero-copy reinterpretation possible. (Deliberately *not*
/// `chip_words_to_bytes`, which de-interleaves back to stream order.)
pub(super) fn lines_to_le_bytes(lines: &[ChipWords]) -> Vec<u8> {
    let mut out = Vec::with_capacity(lines.len() * LINE_BYTES);
    for line in lines {
        for w in line {
            out.extend_from_slice(&w.to_le_bytes());
        }
    }
    out
}

/// Inverse of [`lines_to_le_bytes`]: decode a frame payload into owned
/// lines (the big-endian / misaligned fallback and the materializer).
pub(super) fn le_bytes_to_lines(payload: &[u8]) -> Vec<ChipWords> {
    debug_assert_eq!(payload.len() % LINE_BYTES, 0);
    payload
        .chunks_exact(LINE_BYTES)
        .map(|line| {
            std::array::from_fn(|j| {
                let mut b = [0u8; 8];
                b.copy_from_slice(&line[j * 8..j * 8 + 8]);
                u64::from_le_bytes(b)
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_encoding_round_trips_and_matches_memory_layout() {
        let lines: Vec<ChipWords> = (0..5)
            .map(|l| std::array::from_fn(|j| (l * 8 + j) as u64 * 0x0101_0101))
            .collect();
        let bytes = lines_to_le_bytes(&lines);
        assert_eq!(bytes.len(), 5 * LINE_BYTES);
        assert_eq!(le_bytes_to_lines(&bytes), lines);
        #[cfg(target_endian = "little")]
        {
            // The on-disk encoding is the in-memory representation.
            let raw = unsafe {
                std::slice::from_raw_parts(lines.as_ptr() as *const u8, 5 * LINE_BYTES)
            };
            assert_eq!(bytes, raw);
        }
    }
}
