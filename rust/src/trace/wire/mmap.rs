//! Read-only whole-file mapping without a `libc`/`memmap2` dependency
//! (the offline build substrate vendors no crates): `mmap(2)` via a
//! direct `extern "C"` declaration on unix, and an 8-byte-aligned heap
//! read everywhere else — also the fallback for empty files, which
//! `mmap` rejects, and for any mapping failure.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// An active `mmap(2)` region, unmapped on drop.
#[cfg(unix)]
#[derive(Debug)]
pub struct MmapRegion {
    ptr: *const u8,
    len: usize,
}

// SAFETY: the region is read-only (PROT_READ, MAP_PRIVATE) and never
// aliased mutably; sharing the raw pointer across threads is sound.
#[cfg(unix)]
unsafe impl Send for MmapRegion {}
#[cfg(unix)]
unsafe impl Sync for MmapRegion {}

#[cfg(unix)]
impl Drop for MmapRegion {
    fn drop(&mut self) {
        // SAFETY: `ptr`/`len` came from a successful mmap and are
        // unmapped exactly once.
        unsafe { sys::munmap(self.ptr as *mut _, self.len) };
    }
}

/// A read-only view of a whole file. Page-cache backed where `mmap` is
/// available — multi-GiB traces stream without residing in RAM — and
/// always 8-byte aligned at the base, so `.zactrace` frame payloads
/// (whose offsets are ≡ 0 mod 16) can be reinterpreted as `[u64; 8]`
/// cache lines in place.
#[derive(Debug)]
pub enum MapBuf {
    /// `mmap`-backed pages (unix, non-empty files).
    #[cfg(unix)]
    Mapped(MmapRegion),
    /// Owned heap buffer, allocated as `u64`s so the base pointer is
    /// 8-byte aligned (non-unix hosts, empty files, or mmap failure).
    Heap { words: Vec<u64>, len: usize },
}

impl MapBuf {
    /// Map (or read) `len` bytes of an open file.
    pub fn open(file: &File, len: usize) -> std::io::Result<MapBuf> {
        #[cfg(unix)]
        if len > 0 {
            use std::os::unix::io::AsRawFd;
            // SAFETY: a fresh private read-only mapping of a file we
            // hold open; failure is checked below.
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            // MAP_FAILED is (void*)-1; fall back to the heap read on
            // any failure rather than surfacing platform errno quirks.
            if ptr as usize != usize::MAX && !ptr.is_null() {
                return Ok(MapBuf::Mapped(MmapRegion {
                    ptr: ptr as *const u8,
                    len,
                }));
            }
        }
        Self::read_heap(file, len)
    }

    fn read_heap(mut file: &File, len: usize) -> std::io::Result<MapBuf> {
        file.seek(SeekFrom::Start(0))?;
        let mut words = vec![0u64; len.div_ceil(8)];
        // SAFETY: viewing the u64 buffer as bytes — same allocation,
        // `len <= words.len() * 8`; the tail of the last word stays 0.
        let bytes = unsafe { std::slice::from_raw_parts_mut(words.as_mut_ptr() as *mut u8, len) };
        file.read_exact(bytes)?;
        Ok(MapBuf::Heap { words, len })
    }

    /// The mapped bytes.
    pub fn as_bytes(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            // SAFETY: the region stays mapped for `self`'s lifetime.
            MapBuf::Mapped(m) => unsafe { std::slice::from_raw_parts(m.ptr, m.len) },
            MapBuf::Heap { words, len } => {
                // SAFETY: same allocation viewed as bytes; `len` never
                // exceeds the u64 buffer's byte size.
                unsafe { std::slice::from_raw_parts(words.as_ptr() as *const u8, *len) }
            }
        }
    }

    /// Mapped length in bytes.
    pub fn len(&self) -> usize {
        match self {
            #[cfg(unix)]
            MapBuf::Mapped(m) => m.len,
            MapBuf::Heap { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether this view is page-cache backed (`mmap`) rather than an
    /// owned heap copy.
    pub fn is_mapped(&self) -> bool {
        match self {
            #[cfg(unix)]
            MapBuf::Mapped(_) => true,
            MapBuf::Heap { .. } => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("zac_mapbuf_{}_{name}", std::process::id()))
    }

    #[test]
    fn mapped_and_heap_views_agree_with_the_file() {
        let path = tmp("agree");
        let data: Vec<u8> = (0..1000u32).map(|i| (i * 7) as u8).collect();
        std::fs::File::create(&path)
            .unwrap()
            .write_all(&data)
            .unwrap();
        let file = File::open(&path).unwrap();
        let map = MapBuf::open(&file, data.len()).unwrap();
        assert_eq!(map.as_bytes(), &data[..]);
        assert_eq!(map.len(), data.len());
        assert!(!map.is_empty());
        // The base pointer is 8-byte aligned on both paths.
        assert_eq!(map.as_bytes().as_ptr().align_offset(8), 0);
        let heap = MapBuf::read_heap(&file, data.len()).unwrap();
        assert_eq!(heap.as_bytes(), &data[..]);
        assert!(!heap.is_mapped());
        assert_eq!(heap.as_bytes().as_ptr().align_offset(8), 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_file_maps_to_an_empty_view() {
        let path = tmp("empty");
        std::fs::File::create(&path).unwrap();
        let file = File::open(&path).unwrap();
        let map = MapBuf::open(&file, 0).unwrap();
        assert!(map.is_empty());
        assert_eq!(map.as_bytes(), &[] as &[u8]);
        std::fs::remove_file(&path).ok();
    }
}
