//! The `.zactrace` decoder: an mmap-backed reader whose frames
//! materialize as zero-copy [`LineChunk`] views borrowing the mapped
//! pages. Total over truncated or corrupt input — `open` validates the
//! header strictly, scans the frame directory structurally, and every
//! payload access re-checks that frame's CRC, so a multi-GiB trace
//! streams straight into the engines without the whole file (or any
//! decoded copy of it) resident in RAM, and a corrupt frame surfaces
//! as its own frame-indexed [`WireError`] instead of a panic.

use std::fs::File;
use std::path::Path;
use std::sync::Arc;

use crate::trace::{ChipWords, LineBacking, LineChunk, LINE_BYTES};
use crate::util::table::TextTable;

use super::writer::le_bytes_to_lines;
use super::{crc32, io, u32_le, Header, MapBuf, WireError, FRAME_HEADER_BYTES, HEADER_BYTES};

/// Directory entry for one frame: where its payload lives and what its
/// header declared. Built once at open from frame headers alone.
#[derive(Clone, Copy, Debug)]
struct FrameEntry {
    /// Payload offset in the file.
    payload: usize,
    /// Lines in the frame.
    lines: u32,
    /// Frame flags (bit 0 = approximate).
    flags: u32,
    /// Declared payload CRC32.
    stored_crc: u32,
}

/// An open, memory-mapped `.zactrace`.
///
/// Opening validates the header and walks the frame chain (offsets and
/// lengths only — no payload reads). A structurally broken tail does
/// not fail `open` — the inspector still needs the readable prefix —
/// but [`verify`](Self::verify) reports it, and [`chunk`](Self::chunk)
/// on the broken frame returns the same error. Replay paths call
/// `verify` first, so a truncated recording never silently replays
/// short.
pub struct TraceFile {
    map: Arc<MapBuf>,
    header: Header,
    frames: Vec<FrameEntry>,
    /// The structural error the directory scan stopped at, if any.
    scan_error: Option<WireError>,
    total_lines: u64,
}

impl TraceFile {
    /// Open and map a recorded trace.
    pub fn open(path: impl AsRef<Path>) -> Result<TraceFile, WireError> {
        let file = File::open(path).map_err(io("opening trace file"))?;
        let len = file.metadata().map_err(io("reading trace file length"))?.len() as usize;
        let map = MapBuf::open(&file, len).map_err(io("mapping trace file"))?;
        Self::from_map(Arc::new(map))
    }

    fn from_map(map: Arc<MapBuf>) -> Result<TraceFile, WireError> {
        let bytes = map.as_bytes();
        let header = Header::parse(bytes)?;
        let mut frames = Vec::new();
        let mut scan_error = None;
        let mut total_lines = 0u64;
        let mut off = HEADER_BYTES;
        while off < bytes.len() {
            let frame = frames.len();
            if off + FRAME_HEADER_BYTES > bytes.len() {
                scan_error = Some(WireError::TruncatedFrame {
                    frame,
                    offset: off,
                    needed: FRAME_HEADER_BYTES,
                    available: bytes.len() - off,
                });
                break;
            }
            let lines = u32_le(bytes, off);
            if lines == 0 {
                scan_error = Some(WireError::EmptyFrame { frame });
                break;
            }
            let payload = off + FRAME_HEADER_BYTES;
            let payload_len = lines as usize * LINE_BYTES;
            if payload + payload_len > bytes.len() {
                scan_error = Some(WireError::TruncatedFrame {
                    frame,
                    offset: off,
                    needed: FRAME_HEADER_BYTES + payload_len,
                    available: bytes.len() - off,
                });
                break;
            }
            frames.push(FrameEntry {
                payload,
                lines,
                flags: u32_le(bytes, off + 4),
                stored_crc: u32_le(bytes, off + 8),
            });
            total_lines += lines as u64;
            off = payload + payload_len;
        }
        Ok(TraceFile {
            map,
            header,
            frames,
            scan_error,
            total_lines,
        })
    }

    /// The parsed file header.
    pub fn header(&self) -> &Header {
        &self.header
    }

    /// Frames actually present in the file (readable prefix).
    pub fn frame_count(&self) -> usize {
        self.frames.len()
    }

    /// Lines over all present frames.
    pub fn total_lines(&self) -> u64 {
        self.total_lines
    }

    /// Recorded stream length in bytes.
    pub fn byte_len(&self) -> u64 {
        self.header.byte_len
    }

    /// Lines in frame `i` (panics if out of range — iterate with
    /// [`frame_count`](Self::frame_count)).
    pub fn frame_lines(&self, i: usize) -> usize {
        self.frames[i].lines as usize
    }

    /// Whether frame `i` was recorded as approximate traffic.
    pub fn frame_approx(&self, i: usize) -> bool {
        self.frames[i].flags & 1 != 0
    }

    /// Structural validation: the frame chain parsed to the end of the
    /// file, the header's frame count matches, and the line total can
    /// carry the declared byte length. Cheap — no payload reads;
    /// [`verify_payloads`](Self::verify_payloads) adds the CRC pass.
    pub fn verify(&self) -> Result<(), WireError> {
        if let Some(e) = &self.scan_error {
            return Err(e.clone());
        }
        if self.header.frame_count != self.frames.len() as u64 {
            return Err(WireError::FrameCountMismatch {
                header: self.header.frame_count,
                found: self.frames.len() as u64,
            });
        }
        let need = self.header.byte_len.div_ceil(LINE_BYTES as u64);
        if need != self.total_lines {
            return Err(WireError::LengthMismatch {
                lines: self.total_lines,
                byte_len: self.header.byte_len,
            });
        }
        Ok(())
    }

    /// [`verify`](Self::verify) plus a CRC32 check of every payload.
    pub fn verify_payloads(&self) -> Result<(), WireError> {
        self.verify()?;
        for i in 0..self.frames.len() {
            self.check_crc(i)?;
        }
        Ok(())
    }

    fn entry(&self, i: usize) -> Result<&FrameEntry, WireError> {
        match self.frames.get(i) {
            Some(f) => Ok(f),
            // Past the readable prefix: surface why the scan stopped.
            None => match self.scan_error.clone() {
                Some(e) => Err(e),
                None => Err(WireError::FrameCountMismatch {
                    header: self.header.frame_count,
                    found: self.frames.len() as u64,
                }),
            },
        }
    }

    fn payload(&self, f: &FrameEntry) -> &[u8] {
        &self.map.as_bytes()[f.payload..f.payload + f.lines as usize * LINE_BYTES]
    }

    fn check_crc(&self, i: usize) -> Result<(), WireError> {
        let f = &self.frames[i];
        let computed = crc32(self.payload(f));
        if computed != f.stored_crc {
            return Err(WireError::CrcMismatch {
                frame: i,
                stored: f.stored_crc,
                computed,
            });
        }
        Ok(())
    }

    /// Frame `i` as a [`LineChunk`] under its recorded traffic class.
    pub fn chunk(&self, i: usize) -> Result<LineChunk, WireError> {
        self.entry(i)?;
        self.chunk_as(i, self.frame_approx(i))
    }

    /// Frame `i` as a [`LineChunk`] with an explicit traffic class. The
    /// payload CRC is checked first — a corrupt frame is a
    /// frame-indexed error, never a panic. On little-endian hosts the
    /// chunk borrows the mapped pages directly (zero-copy); big-endian
    /// hosts (or a misaligned payload, which the format precludes)
    /// decode a per-frame copy.
    pub fn chunk_as(&self, i: usize, approx: bool) -> Result<LineChunk, WireError> {
        let f = *self.entry(i)?;
        self.check_crc(i)?;
        #[cfg(target_endian = "little")]
        {
            let align = std::mem::align_of::<ChipWords>();
            if self.payload(&f).as_ptr().align_offset(align) == 0 {
                let backing: Arc<dyn LineBacking> = Arc::new(MappedFrame {
                    map: self.map.clone(),
                    payload: f.payload,
                    lines: f.lines as usize,
                });
                return Ok(LineChunk::from_backing(backing, approx));
            }
        }
        let lines = le_bytes_to_lines(self.payload(&f));
        let flags = vec![approx; f.lines as usize];
        Ok(LineChunk::from_lines(lines, flags))
    }

    /// Decode every frame into owned cache lines (CRC-checked) — the
    /// whole-file materializer `Trace::from_file` and the sweep's
    /// baseline comparison use. Verifies structure first.
    pub fn read_lines(&self) -> Result<Vec<ChipWords>, WireError> {
        self.verify()?;
        let mut out = Vec::with_capacity(self.total_lines as usize);
        for i in 0..self.frames.len() {
            self.check_crc(i)?;
            out.extend(le_bytes_to_lines(self.payload(&self.frames[i])));
        }
        Ok(out)
    }

    /// Per-frame health and a zero-line census without decoding any
    /// payload into cache lines — the `trace-info` inspector. Never
    /// fails: corruption shows up as per-frame status and the recorded
    /// structural error.
    pub fn inspect(&self) -> TraceInfo {
        let mut frames = Vec::with_capacity(self.frames.len());
        let mut zero_lines = 0u64;
        let mut corrupt_frames = 0usize;
        for f in &self.frames {
            let payload = self.payload(f);
            let crc_ok = crc32(payload) == f.stored_crc;
            if !crc_ok {
                corrupt_frames += 1;
            }
            let zeros = payload
                .chunks_exact(LINE_BYTES)
                .filter(|line| line.iter().all(|&b| b == 0))
                .count() as u64;
            zero_lines += zeros;
            frames.push(FrameStatus {
                lines: f.lines,
                approx: f.flags & 1 != 0,
                crc_ok,
                zero_lines: zeros,
            });
        }
        TraceInfo {
            header: self.header,
            frames,
            total_lines: self.total_lines,
            zero_lines,
            corrupt_frames,
            scan_error: self.scan_error.clone(),
            structure: self.verify().err(),
        }
    }
}

/// One frame's payload as a [`LineBacking`]: keeps the whole mapping
/// alive and reinterprets the payload bytes as cache lines in place.
#[cfg(target_endian = "little")]
#[derive(Debug)]
struct MappedFrame {
    map: Arc<MapBuf>,
    payload: usize,
    lines: usize,
}

#[cfg(target_endian = "little")]
impl LineBacking for MappedFrame {
    fn lines(&self) -> &[ChipWords] {
        let bytes = &self.map.as_bytes()[self.payload..self.payload + self.lines * LINE_BYTES];
        debug_assert_eq!(bytes.as_ptr().align_offset(std::mem::align_of::<ChipWords>()), 0);
        // SAFETY: the payload is 8-byte aligned (checked before this
        // backing was constructed), spans exactly `lines * 64` bytes of
        // live mapping, and on little-endian hosts `[u64; 8]` has
        // exactly the on-disk byte layout.
        unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const ChipWords, self.lines) }
    }
}

/// Health of one frame, as the inspector reports it.
#[derive(Clone, Copy, Debug)]
pub struct FrameStatus {
    /// Lines in the frame.
    pub lines: u32,
    /// Recorded traffic class.
    pub approx: bool,
    /// Whether the payload matches its declared CRC32.
    pub crc_ok: bool,
    /// All-zero lines in the frame (the zero-skip opportunity).
    pub zero_lines: u64,
}

/// Everything `zac-dest trace-info` prints: header, per-frame CRC
/// status, zero-line census and any structural error.
#[derive(Clone, Debug)]
pub struct TraceInfo {
    /// The parsed file header.
    pub header: Header,
    /// Per-frame status, in file order (readable prefix only).
    pub frames: Vec<FrameStatus>,
    /// Lines over all present frames.
    pub total_lines: u64,
    /// All-zero lines over all present frames.
    pub zero_lines: u64,
    /// Frames whose payload fails its CRC.
    pub corrupt_frames: usize,
    /// The structural error the directory scan stopped at, if any.
    pub scan_error: Option<WireError>,
    /// The error [`TraceFile::verify`] reports, if any (scan error,
    /// frame-count or length mismatch).
    pub structure: Option<WireError>,
}

impl TraceInfo {
    /// Whether the file is structurally sound and every CRC matches.
    pub fn is_healthy(&self) -> bool {
        self.structure.is_none() && self.corrupt_frames == 0
    }

    /// Zero lines as a fraction of all present lines.
    pub fn zero_fraction(&self) -> f64 {
        if self.total_lines == 0 {
            0.0
        } else {
            self.zero_lines as f64 / self.total_lines as f64
        }
    }

    /// Render the inspector report (frame rows capped at 16).
    pub fn render(&self) -> String {
        let h = &self.header;
        let mut out = format!(
            ".zactrace v{}: {} layout, {} B lines, nominal {} lines/frame\n\
             stream: {} bytes in {} frames ({} lines), recorded {}\n\
             zero lines: {} ({:.1}%)\n",
            h.version,
            h.layout.label(),
            h.line_bytes,
            h.chunk_lines,
            h.byte_len,
            self.frames.len(),
            self.total_lines,
            if h.traffic_approx { "approximate" } else { "critical" },
            self.zero_lines,
            100.0 * self.zero_fraction(),
        );
        let mut t = TextTable::new(&["frame", "lines", "class", "zero", "crc"]);
        const MAX_ROWS: usize = 16;
        for (i, f) in self.frames.iter().take(MAX_ROWS).enumerate() {
            t.row(vec![
                format!("{i}"),
                format!("{}", f.lines),
                if f.approx { "approx" } else { "critical" }.into(),
                format!("{}", f.zero_lines),
                if f.crc_ok { "ok" } else { "MISMATCH" }.into(),
            ]);
        }
        out.push_str(&t.render());
        if self.frames.len() > MAX_ROWS {
            out.push_str(&format!(
                "... ({} more frames not shown)\n",
                self.frames.len() - MAX_ROWS
            ));
        }
        match (&self.structure, self.corrupt_frames) {
            (Some(e), _) => out.push_str(&format!("status: BROKEN ({e})\n")),
            (None, 0) => out.push_str("status: ok\n"),
            (None, n) => out.push_str(&format!("status: {n} corrupt frame(s)\n")),
        }
        out
    }
}
