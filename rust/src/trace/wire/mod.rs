//! The `.zactrace` on-disk trace format: framed, self-describing,
//! CRC-checked persistence for the traffic a [`Session`] consumes —
//! the workload set stops being "what we can synthesize" and becomes
//! "anything anyone can record".
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! offset  size  field
//! ------  ----  -----------------------------------------------------
//!      0     8  magic "ZACTRACE"
//!      8     4  version (this writer: 1)
//!     12     4  line width in bytes (this crate models 64 B lines)
//!     16     4  nominal chunk size in lines (the writer's frame size)
//!     20     4  payload layout: 0 = raw bytes, 1 = f32 little-endian
//!     24     4  stream flags: bit 0 = recorded as approximate traffic
//!     28     4  reserved (zero)
//!     32     8  total stream length in bytes (patched on finish)
//!     40     8  frame count (patched on finish)
//!     48     8  reserved (zero)
//!     56     4  CRC32 of header bytes [0, 56)
//!     60     4  reserved (zero)
//! ```
//!
//! Frames follow back to back from offset 64. Each frame is a 16-byte
//! header plus a length-prefixed payload:
//!
//! ```text
//! offset  size  field
//! ------  ----  -----------------------------------------------------
//!      0     4  lines in this frame (n > 0)
//!      4     4  frame flags: bit 0 = approximate traffic
//!      8     4  CRC32 of the payload
//!     12     4  reserved (zero)
//!     16  64·n  payload: n cache lines, each 8 chip words as u64 LE
//! ```
//!
//! Every frame offset is ≡ 0 (mod 16), so payloads are 8-byte aligned
//! and a little-endian host can reinterpret a mapped payload as
//! `&[ChipWords]` in place — the zero-copy replay path
//! ([`TraceFile::chunk_as`] → [`LineChunk`](crate::trace::LineChunk)).
//!
//! The framing follows the defmt/rzCOBS discipline (SNIPPETS.md §1):
//! encoder ([`TraceWriter`]) and decoder ([`TraceFile`]) are separate,
//! and the decoder is *total* over truncated or corrupt input — every
//! failure mode maps to a named [`WireError`] carrying the offending
//! frame index (`frame 17: crc mismatch`), never a panic.
//!
//! [`Session`]: crate::session::Session

mod mmap;
mod reader;
mod writer;

use std::fmt;

use crate::encoding::ENCODE_BATCH;
use crate::trace::LINE_BYTES;

pub use mmap::MapBuf;
pub use reader::{FrameStatus, TraceFile, TraceInfo};
pub use writer::{write_trace, TraceWriter};

/// File magic: the first 8 bytes of every `.zactrace`.
pub const MAGIC: [u8; 8] = *b"ZACTRACE";

/// Format version this crate reads and writes.
pub const VERSION: u32 = 1;

/// Fixed file-header size in bytes.
pub const HEADER_BYTES: usize = 64;

/// Fixed per-frame header size in bytes.
pub const FRAME_HEADER_BYTES: usize = 16;

/// Default lines per frame: the data plane's encode batch, so replayed
/// frames feed the engines at their native chunk granularity.
pub const DEFAULT_CHUNK_LINES: u32 = ENCODE_BATCH as u32;

/// How a recorded payload's bytes are to be interpreted after
/// reconstruction (the line encoding on disk is the same either way).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Layout {
    /// An opaque byte stream.
    #[default]
    Raw,
    /// Little-endian packed f32s (weights traffic): the stream length
    /// must be 4-byte aligned, checked at open.
    F32Le,
}

impl Layout {
    fn tag(self) -> u32 {
        match self {
            Layout::Raw => 0,
            Layout::F32Le => 1,
        }
    }

    fn from_tag(tag: u32) -> Result<Layout, WireError> {
        match tag {
            0 => Ok(Layout::Raw),
            1 => Ok(Layout::F32Le),
            found => Err(WireError::BadLayout { found }),
        }
    }

    /// Human label for the inspector.
    pub fn label(self) -> &'static str {
        match self {
            Layout::Raw => "raw",
            Layout::F32Le => "f32-le",
        }
    }
}

/// Parsed `.zactrace` file header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Header {
    /// Format version the file was written with.
    pub version: u32,
    /// Line width in bytes (always [`LINE_BYTES`] for readable files).
    pub line_bytes: u32,
    /// The writer's nominal frame size in lines.
    pub chunk_lines: u32,
    /// Payload interpretation.
    pub layout: Layout,
    /// Whether the stream was recorded as approximate traffic.
    pub traffic_approx: bool,
    /// Total stream length in bytes (the padded tail of the last line
    /// is not part of the stream).
    pub byte_len: u64,
    /// Number of frames in the file.
    pub frame_count: u64,
}

impl Header {
    /// Serialize to the fixed 64-byte on-disk header (CRC included).
    pub fn to_bytes(&self) -> [u8; HEADER_BYTES] {
        let mut b = [0u8; HEADER_BYTES];
        b[0..8].copy_from_slice(&MAGIC);
        b[8..12].copy_from_slice(&self.version.to_le_bytes());
        b[12..16].copy_from_slice(&self.line_bytes.to_le_bytes());
        b[16..20].copy_from_slice(&self.chunk_lines.to_le_bytes());
        b[20..24].copy_from_slice(&self.layout.tag().to_le_bytes());
        b[24..28].copy_from_slice(&(self.traffic_approx as u32).to_le_bytes());
        b[32..40].copy_from_slice(&self.byte_len.to_le_bytes());
        b[40..48].copy_from_slice(&self.frame_count.to_le_bytes());
        let crc = crc32(&b[0..56]);
        b[56..60].copy_from_slice(&crc.to_le_bytes());
        b
    }

    /// Parse and validate a file header. Strict: bad magic, unsupported
    /// version, checksum mismatch, foreign line width, unknown layout
    /// and a misaligned f32 stream are each a distinct [`WireError`].
    pub fn parse(bytes: &[u8]) -> Result<Header, WireError> {
        if bytes.len() < HEADER_BYTES {
            return Err(WireError::TruncatedHeader {
                available: bytes.len(),
            });
        }
        if bytes[0..8] != MAGIC {
            let mut found = [0u8; 8];
            found.copy_from_slice(&bytes[0..8]);
            return Err(WireError::BadMagic { found });
        }
        let version = u32_le(bytes, 8);
        if version == 0 || version > VERSION {
            return Err(WireError::UnsupportedVersion {
                found: version,
                supported: VERSION,
            });
        }
        let stored = u32_le(bytes, 56);
        let computed = crc32(&bytes[0..56]);
        if stored != computed {
            return Err(WireError::HeaderCorrupt { stored, computed });
        }
        let line_bytes = u32_le(bytes, 12);
        if line_bytes as usize != LINE_BYTES {
            return Err(WireError::BadLineBytes { found: line_bytes });
        }
        let chunk_lines = u32_le(bytes, 16);
        if chunk_lines == 0 {
            return Err(WireError::BadChunkLines);
        }
        let layout = Layout::from_tag(u32_le(bytes, 20))?;
        let byte_len = u64_le(bytes, 32);
        if layout == Layout::F32Le && byte_len % 4 != 0 {
            return Err(WireError::MisalignedF32 { byte_len });
        }
        Ok(Header {
            version,
            line_bytes,
            chunk_lines,
            layout,
            traffic_approx: u32_le(bytes, 24) & 1 != 0,
            byte_len,
            frame_count: u64_le(bytes, 40),
        })
    }
}

/// Typed `.zactrace` decode/encode errors. Frame-level failures carry
/// the zero-based frame index — `frame 17: crc mismatch` — matching the
/// name-the-offending-token contract of `resolve_scheme_name` and
/// `FaultSpec` parsing. The decoder is total: every corruption mode
/// lands here, never in a panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// File shorter than the fixed 64-byte header.
    TruncatedHeader { available: usize },
    /// The first 8 bytes are not `ZACTRACE`.
    BadMagic { found: [u8; 8] },
    /// Written by a newer writer than this reader understands.
    UnsupportedVersion { found: u32, supported: u32 },
    /// Header checksum mismatch: the header fields are corrupt.
    HeaderCorrupt { stored: u32, computed: u32 },
    /// Line width other than the 64 B cache line this crate models.
    BadLineBytes { found: u32 },
    /// Unknown payload layout tag.
    BadLayout { found: u32 },
    /// Zero nominal chunk size.
    BadChunkLines,
    /// An f32-layout stream whose byte length is not 4-byte aligned —
    /// the typed form of the `bytes_to_f32s` alignment panic, caught at
    /// the file-ingestion boundary.
    MisalignedF32 { byte_len: u64 },
    /// A frame header or payload runs past the end of the file.
    TruncatedFrame {
        frame: usize,
        offset: usize,
        needed: usize,
        available: usize,
    },
    /// A frame declaring zero lines.
    EmptyFrame { frame: usize },
    /// A frame payload's CRC32 does not match its header.
    CrcMismatch {
        frame: usize,
        stored: u32,
        computed: u32,
    },
    /// The header's frame count disagrees with the frames present
    /// (an unfinished writer, or a tail cut exactly on a frame edge).
    FrameCountMismatch { header: u64, found: u64 },
    /// The frames' line total cannot carry the header's byte length.
    LengthMismatch { lines: u64, byte_len: u64 },
    /// Underlying I/O failure.
    Io { op: &'static str, message: String },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::TruncatedHeader { available } => write!(
                f,
                "trace header truncated: {available} bytes, need {HEADER_BYTES}"
            ),
            WireError::BadMagic { found } => write!(
                f,
                "bad magic {found:?}; not a .zactrace file (expected {MAGIC:?})"
            ),
            WireError::UnsupportedVersion { found, supported } => write!(
                f,
                "unsupported trace version {found} (this reader supports 1..={supported})"
            ),
            WireError::HeaderCorrupt { stored, computed } => write!(
                f,
                "header crc mismatch (stored {stored:#010x}, computed {computed:#010x})"
            ),
            WireError::BadLineBytes { found } => write!(
                f,
                "unsupported line width {found} B (this crate models {LINE_BYTES} B cache lines)"
            ),
            WireError::BadLayout { found } => {
                write!(f, "unknown payload layout tag {found} (known: 0=raw, 1=f32-le)")
            }
            WireError::BadChunkLines => write!(f, "nominal chunk size must be at least one line"),
            WireError::MisalignedF32 { byte_len } => write!(
                f,
                "f32-layout stream length {byte_len} is not 4-byte aligned"
            ),
            WireError::TruncatedFrame {
                frame,
                offset,
                needed,
                available,
            } => write!(
                f,
                "frame {frame}: truncated frame ({needed} bytes needed at offset {offset}, \
                 {available} left in file)"
            ),
            WireError::EmptyFrame { frame } => {
                write!(f, "frame {frame}: empty frame (zero lines)")
            }
            WireError::CrcMismatch {
                frame,
                stored,
                computed,
            } => write!(
                f,
                "frame {frame}: crc mismatch (stored {stored:#010x}, computed {computed:#010x})"
            ),
            WireError::FrameCountMismatch { header, found } => write!(
                f,
                "frame count mismatch: header says {header}, file has {found}"
            ),
            WireError::LengthMismatch { lines, byte_len } => write!(
                f,
                "length mismatch: {lines} recorded lines cannot carry a {byte_len}-byte stream"
            ),
            WireError::Io { op, message } => write!(f, "{op}: {message}"),
        }
    }
}

impl std::error::Error for WireError {}

/// CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`) — the header
/// and frame checksum. Table-driven; the table is built at compile time.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

static CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

fn u32_le(bytes: &[u8], offset: usize) -> u32 {
    u32::from_le_bytes([
        bytes[offset],
        bytes[offset + 1],
        bytes[offset + 2],
        bytes[offset + 3],
    ])
}

fn u64_le(bytes: &[u8], offset: usize) -> u64 {
    let mut b = [0u8; 8];
    b.copy_from_slice(&bytes[offset..offset + 8]);
    u64::from_le_bytes(b)
}

fn io(op: &'static str) -> impl FnOnce(std::io::Error) -> WireError {
    move |e| WireError::Io {
        op,
        message: e.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_the_ieee_check_value() {
        // The standard CRC-32 check vector.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"a"), crc32(b"b"));
    }

    #[test]
    fn header_round_trips_through_bytes() {
        let h = Header {
            version: VERSION,
            line_bytes: LINE_BYTES as u32,
            chunk_lines: DEFAULT_CHUNK_LINES,
            layout: Layout::F32Le,
            traffic_approx: true,
            byte_len: 123_456,
            frame_count: 77,
        };
        assert_eq!(Header::parse(&h.to_bytes()).unwrap(), h);
    }

    #[test]
    fn header_rejects_every_corruption_mode_with_a_named_error() {
        let good = Header {
            version: VERSION,
            line_bytes: LINE_BYTES as u32,
            chunk_lines: 256,
            layout: Layout::Raw,
            traffic_approx: false,
            byte_len: 640,
            frame_count: 1,
        }
        .to_bytes();

        assert!(matches!(
            Header::parse(&good[..HEADER_BYTES - 1]),
            Err(WireError::TruncatedHeader { available }) if available == HEADER_BYTES - 1
        ));

        let mut bad = good;
        bad[0] = b'X';
        assert!(matches!(
            Header::parse(&bad),
            Err(WireError::BadMagic { .. })
        ));

        // A future version is rejected before the CRC is even consulted
        // (a v2 header may checksum differently).
        let mut bad = good;
        bad[8..12].copy_from_slice(&(VERSION + 1).to_le_bytes());
        assert!(matches!(
            Header::parse(&bad),
            Err(WireError::UnsupportedVersion { found, supported })
                if found == VERSION + 1 && supported == VERSION
        ));

        // Any field flip breaks the header CRC.
        let mut bad = good;
        bad[16] ^= 0x01;
        assert!(matches!(
            Header::parse(&bad),
            Err(WireError::HeaderCorrupt { .. })
        ));

        // Consistent (re-checksummed) but unsupported field values.
        let reseal = |mutate: &dyn Fn(&mut [u8; HEADER_BYTES])| {
            let mut b = good;
            mutate(&mut b);
            let crc = crc32(&b[0..56]);
            b[56..60].copy_from_slice(&crc.to_le_bytes());
            b
        };
        assert!(matches!(
            Header::parse(&reseal(&|b| b[12..16].copy_from_slice(&128u32.to_le_bytes()))),
            Err(WireError::BadLineBytes { found: 128 })
        ));
        assert!(matches!(
            Header::parse(&reseal(&|b| b[20..24].copy_from_slice(&9u32.to_le_bytes()))),
            Err(WireError::BadLayout { found: 9 })
        ));
        assert!(matches!(
            Header::parse(&reseal(&|b| b[16..20].copy_from_slice(&0u32.to_le_bytes()))),
            Err(WireError::BadChunkLines)
        ));
        assert!(matches!(
            Header::parse(&reseal(&|b| {
                b[20..24].copy_from_slice(&1u32.to_le_bytes());
                b[32..40].copy_from_slice(&641u64.to_le_bytes());
            })),
            Err(WireError::MisalignedF32 { byte_len: 641 })
        ));
    }

    #[test]
    fn frame_errors_name_the_frame() {
        let e = WireError::CrcMismatch {
            frame: 17,
            stored: 1,
            computed: 2,
        };
        assert!(e.to_string().starts_with("frame 17: crc mismatch"));
        let e = WireError::TruncatedFrame {
            frame: 3,
            offset: 640,
            needed: 80,
            available: 12,
        };
        assert!(e.to_string().starts_with("frame 3: truncated frame"));
    }
}
