//! IEEE-754 f32 field utilities (paper Fig. 19 and §VIII-G).
//!
//! Weight traffic is approximated like image traffic, but the sign and
//! exponent bits are pinned with the Tolerance mask — the paper measures
//! ~60% output-quality loss from approximating even the last exponent
//! bit, which `exponent_flip_damage` reproduces.

/// Sign bit mask of an f32.
pub const SIGN_MASK: u32 = 0x8000_0000;
/// Exponent field mask.
pub const EXP_MASK: u32 = 0x7F80_0000;
/// Mantissa field mask.
pub const MANTISSA_MASK: u32 = 0x007F_FFFF;

/// Decompose an f32 into (sign, exponent, mantissa) fields.
pub fn fields(x: f32) -> (u32, u32, u32) {
    let b = x.to_bits();
    ((b >> 31) & 1, (b >> 23) & 0xFF, b & MANTISSA_MASK)
}

/// The per-64-bit-word tolerance mask protecting sign+exponent of both
/// packed f32 lanes (chunk width 32, top 9 bits).
pub fn weight_tolerance_mask() -> u64 {
    let lane = (SIGN_MASK | EXP_MASK) as u64;
    lane | (lane << 32)
}

/// Flip the lowest exponent bit of every float — the §VIII-G ablation
/// showing why Tolerance must cover the exponent.
pub fn flip_low_exponent_bit(xs: &[f32]) -> Vec<f32> {
    xs.iter()
        .map(|x| f32::from_bits(x.to_bits() ^ (1 << 23)))
        .collect()
}

/// Zero the low `n` mantissa bits (mantissa-side truncation).
pub fn truncate_mantissa(xs: &[f32], n: u32) -> Vec<f32> {
    assert!(n <= 23);
    let mask = !((1u32 << n) - 1);
    xs.iter().map(|x| f32::from_bits(x.to_bits() & mask)).collect()
}

/// Mean relative error between two slices (the "damage" metric used for
/// the Fig. 19 narrative).
pub fn mean_relative_error(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let denom = x.abs().max(1e-12) as f64;
        acc += ((x - y).abs() as f64) / denom;
    }
    acc / a.len() as f64
}

/// Quantify the §VIII-G claim: relative damage from one exponent-bit flip
/// vs from truncating `n` mantissa bits, over the given weights.
pub fn exponent_flip_damage(xs: &[f32], mantissa_bits: u32) -> (f64, f64) {
    let exp = flip_low_exponent_bit(xs);
    let man = truncate_mantissa(xs, mantissa_bits);
    (mean_relative_error(xs, &exp), mean_relative_error(xs, &man))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn field_decomposition() {
        let (s, e, m) = fields(-1.5);
        assert_eq!(s, 1);
        assert_eq!(e, 127);
        assert_eq!(m, 1 << 22);
        let (s, e, m) = fields(0.0);
        assert_eq!((s, e, m), (0, 0, 0));
    }

    #[test]
    fn tolerance_mask_covers_sign_exponent_only() {
        let m = weight_tolerance_mask();
        assert_eq!(m, 0xFF80_0000_FF80_0000);
        assert_eq!(m.count_ones(), 18);
    }

    #[test]
    fn exponent_flip_is_catastrophic_vs_mantissa_truncation() {
        let mut r = Rng::new(81);
        let xs: Vec<f32> = (0..4096).map(|_| r.normal_f32(0.0, 0.1)).collect();
        let (exp_err, man_err) = exponent_flip_damage(&xs, 12);
        // Flipping the low exponent bit halves/doubles values (~50-100%
        // relative error); truncating 12 mantissa bits is < 0.1%.
        assert!(exp_err > 0.4, "exponent damage {exp_err}");
        assert!(man_err < 0.01, "mantissa damage {man_err}");
        assert!(exp_err / man_err.max(1e-9) > 50.0);
    }

    #[test]
    fn mantissa_truncation_preserves_magnitude() {
        let xs = [1.000001f32, -2.3456789, 1e-4];
        let t = truncate_mantissa(&xs, 10);
        for (a, b) in xs.iter().zip(&t) {
            assert!((a - b).abs() / a.abs() < 1e-3);
            assert_eq!(a.signum(), b.signum());
        }
    }
}
