//! The zero-copy chunk currency of the data plane.
//!
//! v1 moved line data between layers as owned `Box<[ChipWords]>` copies:
//! the `Pipeline` built one boxed per-chip chunk per worker, the channel
//! array copied every pending chunk into a box per shard, and every hop
//! re-owned the bytes. A [`LineChunk`] replaces all of those with one
//! reference-counted view: an `Arc<[ChipWords]>` backing store (usually
//! the [`Trace`](crate::session::Trace)'s own line buffer) plus either a
//! contiguous window or an explicit index list into it, and either a
//! uniform or a per-line approx flag. Cloning a chunk bumps a refcount;
//! line data is copied exactly once — when the trace was split into
//! lines — no matter how many queues, shards or chip workers it crosses.

use std::sync::Arc;

use super::ChipWords;

/// A foreign backing store a chunk can borrow lines from without the
/// lines living in an `Arc<[ChipWords]>` — the seam the mmap-backed
/// `.zactrace` reader plugs into: its frame views implement this over
/// the mapped pages, so a replayed chunk borrows file-backed memory
/// with the exact same currency the in-memory paths use. Implementors
/// guarantee the slice is stable and immutable for the handle's
/// lifetime.
pub trait LineBacking: std::fmt::Debug + Send + Sync {
    /// The lines this backing exposes, in store order.
    fn lines(&self) -> &[ChipWords];
}

/// Where a chunk's lines live: the usual owned shared store, or a
/// foreign [`LineBacking`] (mapped file pages).
#[derive(Clone, Debug)]
enum Store {
    Owned(Arc<[ChipWords]>),
    Foreign(Arc<dyn LineBacking>),
}

impl Store {
    #[inline]
    fn slice(&self) -> &[ChipWords] {
        match self {
            Store::Owned(s) => s,
            Store::Foreign(b) => b.lines(),
        }
    }
}

/// Which store lines a chunk covers, in transfer order.
#[derive(Clone, Debug)]
enum Select {
    /// Contiguous window `[start, start + len)` of the store.
    Window { start: usize, len: usize },
    /// Explicit store indices (the sharded router's scatter view).
    Indices(Arc<[u32]>),
}

/// Error-resilience flags for a chunk's lines.
#[derive(Clone, Debug)]
enum Flags {
    /// One class for the whole chunk (whole-stream `TrafficClass`).
    Uniform(bool),
    /// One flag per chunk line, in the same order as the selection.
    Per(Arc<[bool]>),
}

/// A reference-counted view of cache lines: the one chunk type every
/// queue and worker of the batch, pipelined and sharded executions
/// exchanges. Cheap to clone (two refcount bumps), never copies line
/// data.
#[derive(Clone, Debug)]
pub struct LineChunk {
    store: Store,
    select: Select,
    flags: Flags,
}

impl LineChunk {
    /// A contiguous window of a shared store with one traffic class.
    pub fn window(store: Arc<[ChipWords]>, start: usize, len: usize, approx: bool) -> LineChunk {
        assert!(start + len <= store.len(), "window out of store bounds");
        LineChunk {
            store: Store::Owned(store),
            select: Select::Window { start, len },
            flags: Flags::Uniform(approx),
        }
    }

    /// A whole foreign backing store as one uniform-class chunk — the
    /// mmap replay path: the chunk borrows the mapped pages directly,
    /// no line is copied out of the file.
    pub fn from_backing(backing: Arc<dyn LineBacking>, approx: bool) -> LineChunk {
        let len = backing.lines().len();
        LineChunk {
            store: Store::Foreign(backing),
            select: Select::Window { start: 0, len },
            flags: Flags::Uniform(approx),
        }
    }

    /// Adopt owned lines (the streaming `push_line` accumulation path):
    /// the single allocation that freezes a pending buffer into the
    /// shared currency.
    pub fn from_lines(lines: Vec<ChipWords>, flags: Vec<bool>) -> LineChunk {
        assert_eq!(lines.len(), flags.len());
        let store: Arc<[ChipWords]> = lines.into();
        LineChunk {
            select: Select::Window {
                start: 0,
                len: store.len(),
            },
            store: Store::Owned(store),
            flags: Flags::Per(flags.into()),
        }
    }

    /// A scatter view: explicit store indices in transfer order (what
    /// the address-mapped channel array ships per shard — 4 bytes per
    /// line instead of a 64-byte copy).
    pub fn indexed(store: Arc<[ChipWords]>, indices: Vec<u32>, approx: bool) -> LineChunk {
        assert!(
            indices.iter().all(|&i| (i as usize) < store.len()),
            "chunk index out of store bounds"
        );
        LineChunk {
            store: Store::Owned(store),
            select: Select::Indices(indices.into()),
            flags: Flags::Uniform(approx),
        }
    }

    /// A scatter view of this chunk: chunk-local indices (in transfer
    /// order) remapped onto the same backing store — what the channel
    /// array ships per shard when a replayed chunk's lines route to
    /// different channels. No line data is copied, whichever store
    /// (owned or mapped) backs the parent.
    pub fn subset(&self, local: &[u32]) -> LineChunk {
        let mapped: Vec<u32> = local
            .iter()
            .map(|&l| {
                assert!((l as usize) < self.len(), "subset index out of chunk bounds");
                match &self.select {
                    Select::Window { start, .. } => (start + l as usize) as u32,
                    Select::Indices(idx) => idx[l as usize],
                }
            })
            .collect();
        let flags = match &self.flags {
            Flags::Uniform(a) => Flags::Uniform(*a),
            Flags::Per(f) => Flags::Per(
                local
                    .iter()
                    .map(|&l| f[l as usize])
                    .collect::<Vec<bool>>()
                    .into(),
            ),
        };
        LineChunk {
            store: self.store.clone(),
            select: Select::Indices(mapped.into()),
            flags,
        }
    }

    /// Lines in this chunk.
    pub fn len(&self) -> usize {
        match &self.select {
            Select::Window { len, .. } => *len,
            Select::Indices(idx) => idx.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `i`-th line of the chunk.
    pub fn line(&self, i: usize) -> &ChipWords {
        match &self.select {
            Select::Window { start, len } => {
                assert!(i < *len);
                &self.store.slice()[start + i]
            }
            Select::Indices(idx) => &self.store.slice()[idx[i] as usize],
        }
    }

    /// The `i`-th line's approx flag.
    pub fn approx(&self, i: usize) -> bool {
        match &self.flags {
            Flags::Uniform(a) => {
                assert!(i < self.len());
                *a
            }
            Flags::Per(f) => f[i],
        }
    }

    /// Gather chip `chip`'s 64-bit lane for chunk lines
    /// `[start, start + out.len())` — the strided gather every chip
    /// worker runs once per batch into its reusable buffer.
    pub fn gather_chip(&self, chip: usize, start: usize, out: &mut [u64]) {
        let store = self.store.slice();
        match &self.select {
            Select::Window { start: s, len } => {
                assert!(start + out.len() <= *len);
                let lines = &store[s + start..s + start + out.len()];
                for (o, l) in out.iter_mut().zip(lines) {
                    *o = l[chip];
                }
            }
            Select::Indices(idx) => {
                for (o, &i) in out.iter_mut().zip(&idx[start..start + out.len()]) {
                    *o = store[i as usize][chip];
                }
            }
        }
    }

    /// Fill the approx flags for chunk lines `[start, start + out.len())`.
    pub fn fill_approx(&self, start: usize, out: &mut [bool]) {
        assert!(start + out.len() <= self.len());
        match &self.flags {
            Flags::Uniform(a) => out.fill(*a),
            Flags::Per(f) => out.copy_from_slice(&f[start..start + out.len()]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::CHIPS;

    fn store(n: usize) -> Arc<[ChipWords]> {
        (0..n)
            .map(|l| std::array::from_fn(|j| (l * CHIPS + j) as u64))
            .collect::<Vec<ChipWords>>()
            .into()
    }

    #[test]
    fn window_views_the_store_without_copying() {
        let st = store(10);
        let c = LineChunk::window(st.clone(), 3, 4, true);
        assert_eq!(c.len(), 4);
        assert!(!c.is_empty());
        assert_eq!(c.line(0), &st[3]);
        assert_eq!(c.line(3), &st[6]);
        assert!(c.approx(2));
        // Clones share the same backing store: the clone's lines live
        // at the same addresses, and only refcounts moved.
        let d = c.clone();
        assert!(std::ptr::eq(c.line(0), d.line(0)));
        assert_eq!(Arc::strong_count(&st), 3);
    }

    #[test]
    fn foreign_backing_serves_lines_without_copying() {
        #[derive(Debug)]
        struct Fixed(Vec<ChipWords>);
        impl LineBacking for Fixed {
            fn lines(&self) -> &[ChipWords] {
                &self.0
            }
        }
        let lines: Vec<ChipWords> = (0..6)
            .map(|l| std::array::from_fn(|j| (l * CHIPS + j) as u64))
            .collect();
        let backing: Arc<dyn LineBacking> = Arc::new(Fixed(lines.clone()));
        let c = LineChunk::from_backing(backing.clone(), true);
        assert_eq!(c.len(), 6);
        assert!(c.approx(0));
        for i in 0..6 {
            assert_eq!(c.line(i), &lines[i]);
            // The chunk's lines are the backing's own memory.
            assert!(std::ptr::eq(c.line(i), &backing.lines()[i]));
        }
        let mut lane = [0u64; 4];
        c.gather_chip(3, 1, &mut lane);
        assert_eq!(lane, [lines[1][3], lines[2][3], lines[3][3], lines[4][3]]);
    }

    #[test]
    fn subset_remaps_through_windows_and_index_lists() {
        let st = store(10);
        // Window parent: local l maps to start + l.
        let w = LineChunk::window(st.clone(), 2, 6, true);
        let sub = w.subset(&[5, 0, 3]);
        assert_eq!(sub.len(), 3);
        assert_eq!(sub.line(0), &st[7]);
        assert_eq!(sub.line(1), &st[2]);
        assert_eq!(sub.line(2), &st[5]);
        assert!(sub.approx(1));
        // Indexed parent: local l maps through the parent's index list,
        // and per-line flags follow the selection.
        let flags: Vec<bool> = vec![true, false, true, false];
        let p = LineChunk::from_lines(st[..4].to_vec(), flags);
        let sub = p.subset(&[3, 1]);
        assert_eq!(sub.line(0), &st[3]);
        assert_eq!(sub.line(1), &st[1]);
        assert!(!sub.approx(0));
        assert!(!sub.approx(1));
        let deeper = sub.subset(&[1]);
        assert_eq!(deeper.line(0), &st[1]);
    }

    #[test]
    #[should_panic(expected = "subset index out of chunk bounds")]
    fn subset_bounds_are_checked() {
        let _ = LineChunk::window(store(4), 0, 2, true).subset(&[2]);
    }

    #[test]
    fn indexed_selection_scatters_in_order() {
        let st = store(8);
        let c = LineChunk::indexed(st.clone(), vec![7, 0, 3], false);
        assert_eq!(c.len(), 3);
        assert_eq!(c.line(0), &st[7]);
        assert_eq!(c.line(1), &st[0]);
        assert!(!c.approx(0));
        let mut lane = [0u64; 3];
        c.gather_chip(2, 0, &mut lane);
        assert_eq!(lane, [st[7][2], st[0][2], st[3][2]]);
        let mut tail = [0u64; 2];
        c.gather_chip(5, 1, &mut tail);
        assert_eq!(tail, [st[0][5], st[3][5]]);
    }

    #[test]
    fn gather_and_flags_match_per_line_accessors() {
        let st = store(12);
        let flags: Vec<bool> = (0..5).map(|i| i % 2 == 0).collect();
        let lines: Vec<ChipWords> = st[4..9].to_vec();
        let c = LineChunk::from_lines(lines, flags.clone());
        assert_eq!(c.len(), 5);
        for j in 0..CHIPS {
            let mut buf = vec![0u64; 3];
            c.gather_chip(j, 1, &mut buf);
            let want: Vec<u64> = (1..4).map(|i| c.line(i)[j]).collect();
            assert_eq!(buf, want, "chip {j}");
        }
        let mut got = vec![false; 5];
        c.fill_approx(0, &mut got);
        assert_eq!(got, flags);
        let mut tail = vec![true; 2];
        c.fill_approx(3, &mut tail);
        assert_eq!(tail, flags[3..]);
    }

    #[test]
    fn uniform_flags_fill() {
        let c = LineChunk::window(store(4), 0, 4, true);
        let mut out = vec![false; 4];
        c.fill_approx(0, &mut out);
        assert!(out.iter().all(|&a| a));
    }

    #[test]
    #[should_panic(expected = "window out of store bounds")]
    fn window_bounds_are_checked() {
        let _ = LineChunk::window(store(4), 2, 3, true);
    }

    #[test]
    #[should_panic(expected = "chunk index out of store bounds")]
    fn index_bounds_are_checked() {
        let _ = LineChunk::indexed(store(4), vec![4], true);
    }
}
