//! Hex trace emit/parse (paper §VII: "converting their inputs to
//! hexadecimal traces"). One cache line per row: eight 16-hex-digit
//! chip words separated by spaces.

use super::ChipWords;
use crate::channel::CHIPS;

/// Serialize cache lines to the hex trace format.
pub fn emit(lines: &[ChipWords]) -> String {
    let mut out = String::with_capacity(lines.len() * (17 * CHIPS + 1));
    for line in lines {
        for (j, w) in line.iter().enumerate() {
            if j > 0 {
                out.push(' ');
            }
            out.push_str(&format!("{w:016x}"));
        }
        out.push('\n');
    }
    out
}

/// Parse the hex trace format back into cache lines.
pub fn parse(text: &str) -> anyhow::Result<Vec<ChipWords>> {
    let mut out = Vec::new();
    for (lineno, row) in text.lines().enumerate() {
        let row = row.trim();
        if row.is_empty() || row.starts_with('#') {
            continue;
        }
        let mut words = [0u64; CHIPS];
        let mut count = 0;
        for (j, tok) in row.split_whitespace().enumerate() {
            anyhow::ensure!(j < CHIPS, "trace line {}: too many words", lineno + 1);
            words[j] = u64::from_str_radix(tok, 16)
                .map_err(|e| anyhow::anyhow!("trace line {}: {:?}: {}", lineno + 1, tok, e))?;
            count = j + 1;
        }
        anyhow::ensure!(
            count == CHIPS,
            "trace line {}: expected {CHIPS} words, got {count}",
            lineno + 1
        );
        out.push(words);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn round_trip() {
        let mut r = Rng::new(71);
        let lines: Vec<ChipWords> = (0..20)
            .map(|_| {
                let mut w = [0u64; CHIPS];
                for x in w.iter_mut() {
                    *x = r.next_u64();
                }
                w
            })
            .collect();
        let text = emit(&lines);
        assert_eq!(parse(&text).unwrap(), lines);
    }

    #[test]
    fn skips_comments_and_blanks() {
        let text = "# header\n\n0 0 0 0 0 0 0 0\n";
        let lines = parse(text).unwrap();
        assert_eq!(lines.len(), 1);
        assert_eq!(lines[0], [0u64; CHIPS]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("1 2 3\n").is_err()); // short row
        assert!(parse("x y z w a b c d\n").is_err()); // not hex
        assert!(parse("0 0 0 0 0 0 0 0 0\n").is_err()); // long row
    }
}
