//! Trace machinery: tensors ⇄ cache lines ⇄ per-chip 64-bit words, plus
//! reconstruction of approximate tensors from the receiver's output
//! (paper §VII, Fig. 9 workflow steps 1 and 3).
//!
//! Layout (§III): a 64 B cache line is transferred as 8 beats of 64 bits;
//! chip *j* (x8) drives bits `[8j, 8j+8)` of every beat, so over the
//! burst chip *j* carries bytes `{8b + j : b ∈ 0..8}` of the line — one
//! byte per beat, i.e. one 64-bit word per chip per line.

pub mod chunk;
pub mod float_layout;
pub mod hex;
pub mod wire;

pub use chunk::{LineBacking, LineChunk};

use crate::channel::CHIPS;

/// Bytes per cache line.
pub const LINE_BYTES: usize = 64;

/// One cache line as the 8 per-chip words the encoders consume.
pub type ChipWords = [u64; CHIPS];

/// Split a byte stream into cache lines of per-chip words. The tail is
/// zero-padded to a full line (callers trim with the original length).
pub fn bytes_to_chip_words(bytes: &[u8]) -> Vec<ChipWords> {
    let lines = bytes.len().div_ceil(LINE_BYTES);
    let mut out = Vec::with_capacity(lines);
    for l in 0..lines {
        let base = l * LINE_BYTES;
        let mut words = [0u64; CHIPS];
        for (j, w) in words.iter_mut().enumerate() {
            let mut word = 0u64;
            for beat in 0..8 {
                let idx = base + beat * CHIPS + j;
                let byte = bytes.get(idx).copied().unwrap_or(0);
                word |= (byte as u64) << (beat * 8);
            }
            *w = word;
        }
        out.push(words);
    }
    out
}

/// Inverse of [`bytes_to_chip_words`]; truncates to `len` bytes.
pub fn chip_words_to_bytes(lines: &[ChipWords], len: usize) -> Vec<u8> {
    let mut out = vec![0u8; lines.len() * LINE_BYTES];
    for (l, words) in lines.iter().enumerate() {
        let base = l * LINE_BYTES;
        for (j, &w) in words.iter().enumerate() {
            for beat in 0..8 {
                out[base + beat * CHIPS + j] = (w >> (beat * 8)) as u8;
            }
        }
    }
    out.truncate(len);
    out
}

/// Copy chip `chip`'s 64-bit lane out of a block of cache lines — the
/// strided gather the per-chip drivers run once per batch into a
/// reusable buffer, instead of cloning the whole stream per chip.
#[inline]
pub fn gather_chip_lane(lines: &[ChipWords], chip: usize, out: &mut [u64]) {
    assert_eq!(lines.len(), out.len());
    assert!(chip < CHIPS);
    for (o, l) in out.iter_mut().zip(lines) {
        *o = l[chip];
    }
}

/// f32 slice → little-endian byte stream (weights traffic, Fig. 19).
pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Byte stream → f32 slice (panics on misaligned length).
pub fn bytes_to_f32s(bytes: &[u8]) -> Vec<f32> {
    assert_eq!(bytes.len() % 4, 0, "f32 trace must be 4-byte aligned");
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// [`bytes_to_f32s`] with the misaligned-length panic surfaced as a
/// typed error — the file-ingestion form: a corrupt or truncated
/// recorded trace must never abort a replay process.
pub fn try_bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>, wire::WireError> {
    if bytes.len() % 4 != 0 {
        return Err(wire::WireError::MisalignedF32 {
            byte_len: bytes.len() as u64,
        });
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Fig. 1's approximation: flip a fraction of the 1s in the low `nbits`
/// of every byte to 0 (deterministic order: every k-th candidate 1).
pub fn flip_lsb_ones(bytes: &[u8], nbits: u32, fraction: f64) -> Vec<u8> {
    assert!(nbits <= 8);
    let mask: u8 = ((1u16 << nbits) - 1) as u8;
    let total: u64 = bytes.iter().map(|b| (b & mask).count_ones() as u64).sum();
    let to_flip = (total as f64 * fraction).round() as u64;
    if to_flip == 0 {
        return bytes.to_vec();
    }
    let stride = (total as f64 / to_flip as f64).max(1.0);
    let mut out = bytes.to_vec();
    let mut seen = 0u64;
    let mut next = 0.0f64;
    for b in out.iter_mut() {
        let mut low = *b & mask;
        if low == 0 {
            continue;
        }
        for bit in 0..nbits {
            if low & (1 << bit) != 0 {
                if seen as f64 >= next {
                    low &= !(1 << bit);
                    next += stride;
                }
                seen += 1;
            }
        }
        *b = (*b & !mask) | low;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn chip_mapping_round_trips() {
        let mut r = Rng::new(61);
        for len in [0usize, 1, 63, 64, 65, 640, 1000] {
            let bytes: Vec<u8> = (0..len).map(|_| r.next_u32() as u8).collect();
            let lines = bytes_to_chip_words(&bytes);
            assert_eq!(lines.len(), len.div_ceil(LINE_BYTES));
            assert_eq!(chip_words_to_bytes(&lines, len), bytes);
        }
    }

    #[test]
    fn chip_j_carries_interleaved_bytes() {
        // Line with byte i = i: chip 0 sees bytes 0,8,16,... beat-ordered.
        let bytes: Vec<u8> = (0..64u8).collect();
        let lines = bytes_to_chip_words(&bytes);
        let w0 = lines[0][0];
        for beat in 0..8 {
            assert_eq!((w0 >> (beat * 8)) as u8, (beat * 8) as u8);
        }
        let w3 = lines[0][3];
        for beat in 0..8 {
            assert_eq!((w3 >> (beat * 8)) as u8, (beat * 8 + 3) as u8);
        }
    }

    #[test]
    fn gather_chip_lane_matches_indexing() {
        let mut r = Rng::new(63);
        let bytes: Vec<u8> = (0..640).map(|_| r.next_u32() as u8).collect();
        let lines = bytes_to_chip_words(&bytes);
        let mut buf = vec![0u64; lines.len()];
        for j in 0..CHIPS {
            gather_chip_lane(&lines, j, &mut buf);
            let expect: Vec<u64> = lines.iter().map(|l| l[j]).collect();
            assert_eq!(buf, expect, "chip {j}");
        }
    }

    #[test]
    fn f32_round_trip() {
        let xs = [0.0f32, -1.5, 3.14159, f32::MIN_POSITIVE, 1e30];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&xs)), xs);
    }

    #[test]
    fn flip_lsb_ones_fraction() {
        let bytes = vec![0xFFu8; 1000];
        let out = flip_lsb_ones(&bytes, 4, 0.2);
        let before: u64 = bytes.iter().map(|b| (b & 0x0F).count_ones() as u64).sum();
        let after: u64 = out.iter().map(|b| (b & 0x0F).count_ones() as u64).sum();
        let frac = (before - after) as f64 / before as f64;
        assert!((frac - 0.2).abs() < 0.02, "flipped fraction {frac}");
        // High nibble untouched.
        assert!(out.iter().all(|b| b & 0xF0 == 0xF0));
    }

    #[test]
    fn flip_zero_fraction_is_identity() {
        let bytes: Vec<u8> = (0..=255).collect();
        assert_eq!(flip_lsb_ones(&bytes, 4, 0.0), bytes);
    }

    #[test]
    fn prop_round_trip_any_stream() {
        prop::check(
            "bytes -> chip words -> bytes",
            62,
            |r| {
                let len = r.range(0, 512);
                (0..len).map(|_| r.next_u32() as u64).collect::<Vec<u64>>()
            },
            |words| {
                let bytes: Vec<u8> = words.iter().map(|&w| w as u8).collect();
                let lines = bytes_to_chip_words(&bytes);
                let back = chip_words_to_bytes(&lines, bytes.len());
                if back == bytes {
                    Ok(())
                } else {
                    Err("round trip mismatch".to_string())
                }
            },
        );
    }
}
