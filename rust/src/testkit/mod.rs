//! Registry conformance testkit: the invariants **every** codec behind
//! a [`CodecSpec`] must keep — built-in or out-of-tree — packaged as a
//! reusable harness so future registry schemes (including fault-aware
//! ones) get their contract checked for free.
//!
//! The contract, distilled from three PRs of codec/session surface:
//!
//! 1. **Critical traffic is exact.** `decode(encode(w, approx=false))
//!    == w` for every word, even interleaved with approximate traffic —
//!    the `TrafficClass::Critical` guarantee every driver relies on.
//! 2. **Batch ≡ scalar.** `encode_batch`/`decode_batch` over any
//!    chunking produce exactly the scalar sequence's wires and decodes,
//!    including all table side effects (the hot-path contract from the
//!    batch-first PR).
//! 3. **Zero words ride free.** An all-zero word crosses the wire with
//!    all-zero data lines and decodes back to zero, from any table
//!    state (the paper's §V-A zero-skip economics; exact schemes
//!    satisfy it trivially).
//! 4. **Construction + reset are deterministic.** Two codecs built
//!    from the same spec produce identical wire streams, and `reset()`
//!    restores a codec to its freshly-built behaviour — no hidden
//!    entropy, no state surviving reset.
//! 5. **Unknown knobs are rejected.** `CodecSpec::set_knob` with a key
//!    the scheme does not have errors instead of silently absorbing it.
//!
//! Usage (also in `ARCHITECTURE.md`):
//!
//! ```
//! use zac_dest::encoding::CodecSpec;
//! use zac_dest::testkit::assert_codec_conforms;
//!
//! assert_codec_conforms(&CodecSpec::zac(80)); // panics with a
//!                                             // scheme-named message
//! ```
//!
//! Out-of-tree codecs pass their registry:
//! `assert_codec_conforms_in(&my_registry, &CodecSpec::named("ROT1"))`.
//! The full run is exercised against all five built-ins plus the ROT1
//! fixture (and a deliberately broken codec) in
//! `rust/tests/conformance.rs`.
//!
//! **Correcting codecs** (`SECDED`, `PARITY`, `EDEN`, `ECC+<base>`)
//! additionally go through [`check_correcting_codec`], which layers
//! three more laws on top of the five above:
//!
//! 6. **In-budget errors are corrected exactly.** Flipping `t` wire
//!    bits per word, in `t` distinct beats, decodes to the same words
//!    a clean channel produces, and `take_corrections()` reports
//!    exactly the flips (then drains to zero).
//! 7. **Check bits are paid for.** `total_ones()` charges every
//!    sideband check bit to termination energy — resilience is never
//!    free on the wire — and a scheme drives its declared band
//!    (sideband vs in-band) and no other.
//! 8. **Clean channel ≡ base.** A wrapper or sideband scheme with a
//!    declared base decodes a fault-free stream bit-identically to
//!    that base.

use crate::encoding::{
    default_registry, Codec, CodecRegistry, CodecSpec, CorrectionCounts,
    Outcome, WireWord, ENCODE_BATCH,
};
use crate::util::rng::seeded_rng;

/// Number of words each conformance stream drives (long enough to wrap
/// a 64-entry table several times).
const STREAM_LEN: usize = 600;

/// Assert conformance against the default (built-in) registry. Panics
/// with a scheme-named message on the first violated invariant.
pub fn assert_codec_conforms(spec: &CodecSpec) {
    assert_codec_conforms_in(default_registry(), spec);
}

/// Assert conformance against an explicit registry (out-of-tree
/// schemes). Panics with a scheme-named message on violation.
pub fn assert_codec_conforms_in(registry: &CodecRegistry, spec: &CodecSpec) {
    if let Err(msg) = check_codec_conforms(registry, spec) {
        panic!(
            "codec scheme {:?} ({}) failed conformance: {msg}",
            spec.scheme,
            spec.label()
        );
    }
}

/// The non-panicking core: run every invariant, returning the first
/// violation as a message naming the check and the offending word.
pub fn check_codec_conforms(
    registry: &CodecRegistry,
    spec: &CodecSpec,
) -> Result<(), String> {
    spec.validate()
        .map_err(|e| format!("spec validation failed: {e}"))?;
    if !registry.contains(&spec.scheme) {
        return Err(format!(
            "scheme not registered (known: {:?})",
            registry.schemes()
        ));
    }
    critical_traffic_is_exact(registry, spec)?;
    batch_matches_scalar(registry, spec)?;
    zero_words_ride_free(registry, spec)?;
    construction_and_reset_are_deterministic(registry, spec)?;
    unknown_knobs_are_rejected(spec)?;
    Ok(())
}

/// Assert the correcting-codec laws against the default registry.
/// `base` is the scheme the correcting variant must match on a clean
/// channel (None for lossy in-band schemes like EDEN); `t` is the
/// per-word error budget (0 for detect-only schemes); `sideband` says
/// whether the scheme spends dedicated check lines (`ecc_line`) or
/// embeds its redundancy in the data beats.
pub fn assert_correcting_codec(
    spec: &CodecSpec,
    base: Option<&CodecSpec>,
    t: u32,
    sideband: bool,
) {
    assert_correcting_codec_in(default_registry(), spec, base, t, sideband);
}

/// [`assert_correcting_codec`] against an explicit registry.
pub fn assert_correcting_codec_in(
    registry: &CodecRegistry,
    spec: &CodecSpec,
    base: Option<&CodecSpec>,
    t: u32,
    sideband: bool,
) {
    if let Err(msg) = check_correcting_codec(registry, spec, base, t, sideband) {
        panic!(
            "correcting codec {:?} ({}) failed conformance: {msg}",
            spec.scheme,
            spec.label()
        );
    }
}

/// Non-panicking correcting-codec harness: the five base laws plus
/// laws 6–8 (exact correction inside the `t`-error budget, check-bit
/// energy accounting, clean-channel equivalence with `base`).
pub fn check_correcting_codec(
    registry: &CodecRegistry,
    spec: &CodecSpec,
    base: Option<&CodecSpec>,
    t: u32,
    sideband: bool,
) -> Result<(), String> {
    check_codec_conforms(registry, spec)?;
    correction_is_exact(registry, spec, t)?;
    check_bits_are_paid_for(registry, spec, sideband)?;
    if let Some(base) = base {
        clean_channel_matches_base(registry, spec, base)?;
    }
    Ok(())
}

fn build(registry: &CodecRegistry, spec: &CodecSpec) -> Result<Codec, String> {
    registry
        .build(spec)
        .map_err(|e| format!("factory failed: {e}"))
}

/// Deterministic conformance stream: zeros, repeats, 1-bit neighbours,
/// sparse words, all-ones and full-entropy words — every codec path.
fn stream(seed: u64) -> Vec<u64> {
    let mut r = seeded_rng(seed);
    let mut base = r.next_u64();
    (0..STREAM_LEN)
        .map(|i| match i % 7 {
            0 => 0,
            1 => base,
            2 => {
                if i % 21 == 2 {
                    base = r.next_u64();
                }
                base ^ (1u64 << r.below(64))
            }
            3 => r.next_u64() & 0x0F0F_0F0F,
            4 => u64::MAX,
            _ => r.next_u64(),
        })
        .collect()
}

/// Mixed criticality flags for the stream (deterministic).
fn flags(seed: u64) -> Vec<bool> {
    let mut r = seeded_rng(seed ^ 0xF1A6);
    (0..STREAM_LEN).map(|_| r.chance(0.6)).collect()
}

fn critical_traffic_is_exact(
    registry: &CodecRegistry,
    spec: &CodecSpec,
) -> Result<(), String> {
    let words = stream(11);
    let approx = flags(11);
    let mut codec = build(registry, spec)?;
    for (i, (&w, &a)) in words.iter().zip(&approx).enumerate() {
        let wire = codec.encoder.encode(w, a);
        let got = codec.decoder.decode(&wire);
        if !a && got != w {
            return Err(format!(
                "critical traffic not exact: word {i} ({w:#018x}) decoded \
                 to {got:#018x} with approx=false"
            ));
        }
    }
    Ok(())
}

fn batch_matches_scalar(
    registry: &CodecRegistry,
    spec: &CodecSpec,
) -> Result<(), String> {
    let words = stream(13);
    let approx = flags(13);

    let mut scalar = build(registry, spec)?;
    let scalar_wires: Vec<WireWord> = words
        .iter()
        .zip(&approx)
        .map(|(&w, &a)| scalar.encoder.encode(w, a))
        .collect();
    let scalar_out: Vec<u64> = scalar_wires
        .iter()
        .map(|w| scalar.decoder.decode(w))
        .collect();

    // Irregular chunk sizes: boundaries land everywhere, including a
    // full ENCODE_BATCH and single words.
    let mut batch = build(registry, spec)?;
    let mut wires = vec![WireWord::raw(0); words.len()];
    let mut out = Vec::new();
    let (mut i, mut k) = (0usize, 0usize);
    while i < words.len() {
        let n = [1usize, 7, ENCODE_BATCH, 64, 3][k % 5].min(words.len() - i);
        k += 1;
        let buf = &mut wires[i..i + n];
        batch.encoder.encode_batch(&words[i..i + n], &approx[i..i + n], buf);
        batch.decoder.decode_batch(buf, &mut out);
        i += n;
    }
    for (i, (s, b)) in scalar_wires.iter().zip(&wires).enumerate() {
        if s != b {
            return Err(format!(
                "batch != scalar: wire {i} diverged ({s:?} vs {b:?})"
            ));
        }
    }
    for (i, (s, b)) in scalar_out.iter().zip(&out).enumerate() {
        if s != b {
            return Err(format!(
                "batch != scalar: decode {i} diverged ({s:#018x} vs {b:#018x})"
            ));
        }
    }
    Ok(())
}

fn zero_words_ride_free(
    registry: &CodecRegistry,
    spec: &CodecSpec,
) -> Result<(), String> {
    for approx in [false, true] {
        let mut codec = build(registry, spec)?;
        // Warm the tables with a realistic prefix, keeping the decoder
        // mirror in sync, then check a zero from this state.
        for (&w, &a) in stream(17).iter().zip(&flags(17)) {
            let wire = codec.encoder.encode(w, a);
            codec.decoder.decode(&wire);
        }
        let wire = codec.encoder.encode(0, approx);
        if wire.data != 0 {
            return Err(format!(
                "zero word drove data lines {:#018x} (approx={approx}); \
                 zeros must ride the wire as all-zero data",
                wire.data
            ));
        }
        let got = codec.decoder.decode(&wire);
        if got != 0 {
            return Err(format!(
                "zero word decoded to {got:#018x} (approx={approx})"
            ));
        }
    }
    Ok(())
}

fn construction_and_reset_are_deterministic(
    registry: &CodecRegistry,
    spec: &CodecSpec,
) -> Result<(), String> {
    let words = stream(19);
    let approx = flags(19);
    let run = |codec: &mut Codec| -> Vec<WireWord> {
        words
            .iter()
            .zip(&approx)
            .map(|(&w, &a)| {
                let wire = codec.encoder.encode(w, a);
                codec.decoder.decode(&wire);
                wire
            })
            .collect()
    };
    let mut a = build(registry, spec)?;
    let mut b = build(registry, spec)?;
    let first = run(&mut a);
    if first != run(&mut b) {
        return Err(
            "two codecs built from the same spec produced different wire \
             streams (nondeterministic construction)"
                .into(),
        );
    }
    a.reset();
    if first != run(&mut a) {
        return Err(
            "reset() did not restore freshly-built behaviour (state \
             survived reset)"
                .into(),
        );
    }
    Ok(())
}

fn unknown_knobs_are_rejected(spec: &CodecSpec) -> Result<(), String> {
    let mut probe = spec.clone();
    if probe.set_knob("__testkit_bogus_knob__", "1").is_ok() {
        return Err(
            "set_knob silently absorbed an unknown knob key (the god-struct \
             behaviour the per-scheme knob bags removed)"
                .into(),
        );
    }
    Ok(())
}

/// Law 6: flip `t` data bits per word — one per beat, so every flip is
/// inside a SECDED/Hamming codeword's single-error budget — on an
/// all-approximate stream and require the decoder to undo every one,
/// with `take_corrections()` reporting exactly the flips applied.
/// Zero-skip wires are left untouched: their payload rides the
/// hardened outcome flag, not the data lines.
fn correction_is_exact(
    registry: &CodecRegistry,
    spec: &CodecSpec,
    t: u32,
) -> Result<(), String> {
    if t == 0 {
        return Ok(()); // detect-only scheme: nothing to correct
    }
    let words = stream(23);
    let mut faulty = build(registry, spec)?;
    let mut clean = build(registry, spec)?;
    let mut expected_flips = 0u64;
    for (i, &w) in words.iter().enumerate() {
        let wire = clean.encoder.encode(w, true);
        let mut dirty = faulty.encoder.encode(w, true);
        if dirty.outcome != Outcome::ZeroSkip {
            for j in 0..t {
                let beat = (i as u32 + j) % 8;
                let line = (i as u32 / 7 + 3 * j) % 8;
                dirty.data ^= 1u64 << (8 * beat + line);
                expected_flips += 1;
            }
        }
        let want = clean.decoder.decode(&wire);
        let got = faulty.decoder.decode(&dirty);
        if got != want {
            return Err(format!(
                "word {i} ({w:#018x}): {t} in-budget flips were not \
                 corrected (got {got:#018x}, clean channel {want:#018x})"
            ));
        }
    }
    let counts = faulty.decoder.take_corrections();
    if counts.corrected_bits != expected_flips {
        return Err(format!(
            "corrected_bits miscounted: {} reported for {expected_flips} \
             injected flips",
            counts.corrected_bits
        ));
    }
    if faulty.decoder.take_corrections() != CorrectionCounts::default() {
        return Err(
            "take_corrections() did not drain: a second call returned \
             nonzero counts"
                .into(),
        );
    }
    if clean.decoder.take_corrections() != CorrectionCounts::default() {
        return Err(
            "clean channel reported corrections with no injected errors"
                .into(),
        );
    }
    Ok(())
}

/// Law 7: every check bit the scheme drives shows up in
/// `total_ones()` — resilience costs termination energy — and the
/// scheme uses exactly its declared band: sideband schemes must drive
/// `ecc_line`, in-band schemes must leave it untouched.
fn check_bits_are_paid_for(
    registry: &CodecRegistry,
    spec: &CodecSpec,
    sideband: bool,
) -> Result<(), String> {
    let words = stream(29);
    let approx = flags(29);
    let mut codec = build(registry, spec)?;
    let mut sideband_ones = 0u64;
    for (i, (&w, &a)) in words.iter().zip(&approx).enumerate() {
        let wire = codec.encoder.encode(w, a);
        let mut bare = wire;
        bare.ecc_line = 0;
        let check_ones = wire.ecc_line.count_ones();
        if wire.total_ones() != bare.total_ones() + check_ones {
            return Err(format!(
                "word {i}: {check_ones} check bits not charged to \
                 termination ({} total vs {} bare)",
                wire.total_ones(),
                bare.total_ones()
            ));
        }
        sideband_ones += u64::from(check_ones);
        codec.decoder.decode(&wire);
    }
    if sideband && sideband_ones == 0 {
        return Err(
            "scheme declared a check sideband but never drove a check bit \
             across the whole stream"
                .into(),
        );
    }
    if !sideband && sideband_ones != 0 {
        return Err(format!(
            "scheme declared in-band redundancy but drove {sideband_ones} \
             sideband check bits"
        ));
    }
    Ok(())
}

/// Law 8: on a fault-free channel the correcting variant is
/// transparent — it decodes the mixed-criticality stream to exactly
/// the words its declared base scheme produces.
fn clean_channel_matches_base(
    registry: &CodecRegistry,
    spec: &CodecSpec,
    base: &CodecSpec,
) -> Result<(), String> {
    let words = stream(31);
    let approx = flags(31);
    let mut wrapped = build(registry, spec)?;
    let mut plain = build(registry, base)?;
    for (i, (&w, &a)) in words.iter().zip(&approx).enumerate() {
        let wire = wrapped.encoder.encode(w, a);
        let got = wrapped.decoder.decode(&wire);
        let wire = plain.encoder.encode(w, a);
        let want = plain.decoder.decode(&wire);
        if got != want {
            return Err(format!(
                "word {i} ({w:#018x}, approx={a}): clean-channel decode \
                 {got:#018x} != base {} decode {want:#018x}",
                base.label()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::Scheme;

    #[test]
    fn all_five_builtins_conform() {
        for scheme in Scheme::all() {
            assert_codec_conforms(&CodecSpec::named(scheme.label()));
        }
    }

    #[test]
    fn knobbed_zac_variants_conform() {
        for spec in [
            CodecSpec::zac(90),
            CodecSpec::zac(70),
            CodecSpec::zac_full(75, 2, 1),
            CodecSpec::zac_weights(60),
        ] {
            assert_codec_conforms(&spec);
        }
    }

    #[test]
    fn secded_sideband_corrects_two_flips_in_distinct_beats() {
        assert_correcting_codec(
            &CodecSpec::named("SECDED"),
            Some(&CodecSpec::named("ORG")),
            2,
            true,
        );
    }

    #[test]
    fn parity_sideband_is_detect_only_but_transparent() {
        assert_correcting_codec(
            &CodecSpec::named("PARITY"),
            Some(&CodecSpec::named("ORG")),
            0,
            true,
        );
    }

    #[test]
    fn eden_truncation_corrects_in_band() {
        // Lossy by design (low nibbles sacrificed), so no base to match;
        // the Hamming(7,4)+P codewords ride the data beats, not a
        // sideband.
        assert_correcting_codec(&CodecSpec::named("EDEN"), None, 2, false);
    }

    #[test]
    fn ecc_wrappers_correct_one_flip_and_match_their_base() {
        for base in ["ORG", "DBI", "BDE_ORG", "BDE", "OHE"] {
            assert_correcting_codec(
                &CodecSpec::named(&format!("ECC+{base}")),
                Some(&CodecSpec::named(base)),
                1,
                true,
            );
        }
    }

    #[test]
    fn correction_law_catches_a_codec_that_ignores_errors() {
        // ORG never corrects anything: a single flip must surface as a
        // law-6 violation, proving the harness has teeth.
        let err = check_correcting_codec(
            default_registry(),
            &CodecSpec::named("ORG"),
            None,
            1,
            false,
        )
        .unwrap_err();
        assert!(err.contains("not"), "{err}");
    }

    #[test]
    fn unregistered_scheme_is_reported_by_name() {
        let err = check_codec_conforms(default_registry(), &CodecSpec::named("NOPE"))
            .unwrap_err();
        assert!(err.contains("not registered"), "{err}");
    }

    #[test]
    fn invalid_spec_fails_before_any_stream_runs() {
        let mut spec = CodecSpec::zac(80);
        spec.zac_knobs_mut().unwrap().similarity_limit_pct = 200;
        let err = check_codec_conforms(default_registry(), &spec).unwrap_err();
        assert!(err.contains("spec validation"), "{err}");
    }

    #[test]
    fn conformance_streams_are_deterministic() {
        assert_eq!(stream(7), stream(7));
        assert_ne!(stream(7), stream(8));
        assert_eq!(flags(7), flags(7));
        // The stream exercises zeros, all-ones and dense words.
        let s = stream(7);
        assert!(s.contains(&0));
        assert!(s.contains(&u64::MAX));
    }
}
