//! `zac-dest` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//! * `figure <id>`   — regenerate a paper figure/table (see DESIGN.md §6)
//! * `figures`       — regenerate every figure
//! * `encode`        — encode a hex trace (or a synthetic stream) and
//!                     report energy + outcome statistics, optionally
//!                     sharded across channels
//! * `record`        — record a trace (hex or synthetic) to a framed
//!                     `.zactrace` file
//! * `replay`        — stream a recorded `.zactrace` through the
//!                     engines via mmap-backed zero-copy chunks
//! * `trace-info`    — inspect a `.zactrace` (header, per-frame CRC
//!                     status, zero-line census) without decoding
//! * `schemes`       — list the registered codec schemes
//! * `workload <k>`  — evaluate one workload under a config
//! * `run --config`  — full run from a TOML config file
//! * `sweep`         — multi-channel scenario grid (channels × scheme ×
//!                     knobs) over the sharded channel array, emitting
//!                     `BENCH_system.json`; cells fan across a
//!                     work-stealing pool (`--workers`/`ZAC_SWEEP_WORKERS`),
//!                     `--resume` skips already-completed cells, and
//!                     `--open-loop <rates>` drives the load generator
//!                     (`BENCH_loadgen.json`); honors `ZAC_CHANNELS` and
//!                     `ZAC_BENCH_BYTES`
//! * `circuit`       — §VI circuit-overhead report
//! * `artifacts`     — list/verify the AOT artifacts
//!
//! Every codec flag funnels through the uniform `CodecSpec` ingestion
//! path (`CodecSpec::set_knob` + `validate()`), the same one the TOML
//! configs and env overrides use — a bad knob is an error, never a
//! silent fallback.

use anyhow::Result;

use zac_dest::coordinator::RunConfig;
use zac_dest::encoding::{default_registry, CodecSpec, Knobs, Outcome, Scheme};
use zac_dest::faults::FaultSpec;
use zac_dest::figures::{self, FigureCtx};
use zac_dest::runtime::Runtime;
use zac_dest::session::{Session, Trace, TrafficClass};
use zac_dest::system::AddressSpec;
use zac_dest::util::cli::Command;
use zac_dest::util::table::{pct, TextTable};
use zac_dest::workloads::{Kind, Suite, SuiteBudget};

fn app() -> Command {
    Command::new("zac-dest", "ZAC-DEST full-system reproduction (Jha et al., 2021)")
        .subcommand(
            Command::new("figure", "regenerate one paper figure/table")
                .positional("id", "fig1..fig22, table1, sec6")
                .opt("seed", "42", "experiment seed")
                .opt("budget", "full", "suite budget: quick | full"),
        )
        .subcommand(
            Command::new("figures", "regenerate every figure")
                .opt("seed", "42", "experiment seed")
                .opt("budget", "full", "suite budget: quick | full")
                .opt("out", "-", "output file ('-' = stdout)"),
        )
        .subcommand(
            Command::new("encode", "encode a trace and report energy")
                .opt("input", "-", "hex trace file ('-' = synthetic stream)")
                .opt("scheme", "OHE", "any registered scheme (see `schemes`)")
                .opt("limit", "80", "similarity limit %")
                .opt("truncation", "0", "truncation bits per 8-bit chunk")
                .opt("tolerance", "0", "tolerance bits per 8-bit chunk")
                .opt("table-size", "64", "data-table entries per chip")
                .opt("channels", "1", "8-chip channels to shard across")
                .opt(
                    "address",
                    "round_robin",
                    "address map: round_robin | capacity:<w0>/<w1>/... | steer[:<pages>]",
                )
                .opt("bytes", "1048576", "synthetic stream size")
                .opt("seed", "42", "synthetic stream seed")
                .opt(
                    "faults",
                    "perfect",
                    "fault model: perfect | uniform:<ber>[:<frac>] | voltage:<mV> | mram:<bin> (suffix @<seed>)",
                )
                .opt(
                    "metrics-out",
                    "-",
                    "telemetry JSON path ('-' = skip; implies telemetry)",
                )
                .opt(
                    "simd",
                    "",
                    "CAM search backend: auto | scalar | avx2 | neon ('' = ZAC_SIMD/auto)",
                )
                .env("ZAC_METRICS", "1 = collect runtime telemetry (0 = off)")
                .env("ZAC_SIMD", "default CAM search backend: auto|scalar|avx2|neon"),
        )
        .subcommand(
            Command::new("record", "record a trace to a framed .zactrace file")
                .positional("out", "output .zactrace path")
                .opt("input", "-", "hex trace file ('-' = synthetic stream)")
                .opt("bytes", "1048576", "synthetic stream size")
                .opt("seed", "42", "synthetic stream seed")
                .opt("chunk-lines", "256", "lines per frame")
                .opt("traffic", "approximate", "recorded class: approximate | critical"),
        )
        .subcommand(
            Command::new("replay", "replay a recorded .zactrace through the engines")
                .positional("input", "recorded .zactrace path")
                .opt("scheme", "OHE", "any registered scheme (see `schemes`)")
                .opt("limit", "80", "similarity limit %")
                .opt("truncation", "0", "truncation bits per 8-bit chunk")
                .opt("tolerance", "0", "tolerance bits per 8-bit chunk")
                .opt("table-size", "64", "data-table entries per chip")
                .opt("channels", "1", "8-chip channels to shard across")
                .opt(
                    "address",
                    "round_robin",
                    "address map: round_robin | capacity:<w0>/<w1>/... | steer[:<pages>]",
                )
                .opt(
                    "faults",
                    "perfect",
                    "fault model: perfect | uniform:<ber>[:<frac>] | voltage:<mV> | mram:<bin> (suffix @<seed>)",
                )
                .opt(
                    "metrics-out",
                    "-",
                    "telemetry JSON path ('-' = skip; implies telemetry)",
                )
                .opt(
                    "simd",
                    "",
                    "CAM search backend: auto | scalar | avx2 | neon ('' = ZAC_SIMD/auto)",
                )
                .env("ZAC_METRICS", "1 = collect runtime telemetry (0 = off)")
                .env("ZAC_SIMD", "default CAM search backend: auto|scalar|avx2|neon"),
        )
        .subcommand(
            Command::new("trace-info", "inspect a .zactrace without decoding payloads")
                .positional("file", "recorded .zactrace path"),
        )
        .subcommand(Command::new("schemes", "list the registered codec schemes"))
        .subcommand(
            Command::new("workload", "evaluate one workload under a config")
                .positional("kind", "imagenet | resnet | quant | eigen | svm")
                .opt("limit", "80", "similarity limit %")
                .opt("truncation", "0", "truncation bits per 8-bit chunk")
                .opt("tolerance", "0", "tolerance bits per 8-bit chunk")
                .opt("seed", "42", "experiment seed")
                .opt("budget", "quick", "suite budget: quick | full")
                .opt("faults", "perfect", "fault model under the channel"),
        )
        .subcommand(
            Command::new("run", "full run from a TOML config file")
                .req("config", "path to run config (see configs/)"),
        )
        .subcommand(
            Command::new("sweep", "multi-channel scenario grid over the channel array")
                .opt("spec", "-", "sweep spec TOML ('-' = built-in default grid)")
                .opt("channels", "", "channel counts, e.g. 1,2,4 (overrides spec)")
                .opt("bytes", "0", "synthetic trace bytes (0 = spec/env value)")
                .opt("seed", "0", "synthetic trace seed (0 = spec value)")
                .opt("trace", "-", "recorded .zactrace source ('-' = synthetic, overrides spec)")
                .opt(
                    "faults",
                    "",
                    "fault axis, e.g. perfect,voltage:1050,mram:weak (overrides spec)",
                )
                .opt(
                    "schemes",
                    "",
                    "scheme axis, e.g. BDE,ECC+BDE,SECDED (overrides spec)",
                )
                .opt(
                    "address",
                    "",
                    "address axis, e.g. round_robin,steer (overrides spec)",
                )
                .opt("out", "BENCH_system.json", "JSON report path ('-' = skip)")
                .opt(
                    "metrics-out",
                    "-",
                    "telemetry JSON path ('-' = skip; implies telemetry)",
                )
                .opt(
                    "workers",
                    "",
                    "worker threads for grid cells: N or 'auto' (default: env/spec)",
                )
                .flag("resume", "load --out and skip already-completed cells")
                .opt(
                    "open-loop",
                    "",
                    "offered rates in lines/sec, e.g. 5e4,2e5 (runs the load generator)",
                )
                .opt(
                    "loadgen-out",
                    "BENCH_loadgen.json",
                    "load-generator JSON path ('-' = skip)",
                )
                .env(
                    "ZAC_CHANNELS",
                    "default channel counts for sweep + e2e example (comma-separated)",
                )
                .env(
                    "ZAC_SWEEP_WORKERS",
                    "default sweep worker count: N or 'auto' (flag wins)",
                )
                .env(
                    "ZAC_BENCH_BYTES",
                    "default trace size in bytes for sweep + bench smokes",
                )
                .env("ZAC_METRICS", "1 = collect runtime telemetry (0 = off)")
                .env("ZAC_SIMD", "default CAM search backend: auto|scalar|avx2|neon"),
        )
        .subcommand(
            Command::new("budget", "per-workload max tolerable BER bin at a quality-loss cap")
                .opt("scheme", "ECC+BDE", "codec to budget (any registered scheme)")
                .opt("cap", "2e-4", "max quality loss (1 - quality ratio)")
                .opt("seed", "42", "proxy corpus / suite seed")
                .opt("channels", "1", "8-chip channels to shard across")
                .opt(
                    "workloads",
                    "imagenet,resnet,quant,eigen,svm",
                    "workloads to budget (comma-separated)",
                )
                .opt("mode", "proxy", "proxy (trace quality) | full (trained suite)")
                .opt("budget", "quick", "suite budget when --mode full: quick | full")
                .opt(
                    "out",
                    "BENCH_system.json",
                    "merge table under key 'budget' ('-' = skip)",
                )
                .opt(
                    "metrics-out",
                    "-",
                    "telemetry JSON path ('-' = skip; implies telemetry)",
                )
                .env("ZAC_METRICS", "1 = collect runtime telemetry (0 = off)")
                .env("ZAC_SIMD", "default CAM search backend: auto|scalar|avx2|neon"),
        )
        .subcommand(Command::new("circuit", "§VI circuit overhead report").opt(
            "vectors",
            "10000",
            "random vectors for switching activity",
        ))
        .subcommand(Command::new("artifacts", "list and verify AOT artifacts"))
}

fn budget(name: &str) -> SuiteBudget {
    if name == "quick" {
        SuiteBudget::quick()
    } else {
        SuiteBudget::full()
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let app = app();
    if args.is_empty() {
        println!("{}", app.help());
        return Ok(());
    }
    let m = match app.parse(&args) {
        Ok(m) => m,
        Err(e) => {
            // --help surfaces as an "error" carrying the help text.
            println!("{e}");
            return Ok(());
        }
    };
    match m.path.first().map(|s| s.as_str()) {
        Some("figure") => {
            let id = m
                .positionals
                .first()
                .ok_or_else(|| anyhow::anyhow!("figure id required"))?;
            let ctx = FigureCtx::new(
                m.get_usize("seed")? as u64,
                budget(m.get_or("budget", "full")),
            );
            println!("{}", figures::render(&ctx, id)?);
        }
        Some("figures") => {
            let ctx = FigureCtx::new(
                m.get_usize("seed")? as u64,
                budget(m.get_or("budget", "full")),
            );
            let mut out = String::new();
            for id in figures::ALL {
                eprintln!("[figures] rendering {id} ...");
                out.push_str(&figures::render(&ctx, id)?);
                out.push_str("\n\n");
            }
            let path = m.get_or("out", "-");
            if path == "-" {
                println!("{out}");
            } else {
                std::fs::write(path, &out)?;
                eprintln!("wrote {path}");
            }
        }
        Some("encode") => cmd_encode(&m)?,
        Some("record") => cmd_record(&m)?,
        Some("replay") => cmd_replay(&m)?,
        Some("trace-info") => cmd_trace_info(&m)?,
        Some("schemes") => {
            let reg = default_registry();
            let mut t = TextTable::new(&["scheme", "knobs", "description"]);
            for name in reg.schemes() {
                let spec = CodecSpec::named(&name);
                let knobs = match spec.knobs {
                    Knobs::None => "-",
                    Knobs::Table(_) => "table_size",
                    Knobs::Zac(_) => "limit, truncation, tolerance, table_size, ...",
                };
                let desc = Scheme::parse(&name)
                    .map(|s| s.description().to_string())
                    .unwrap_or_else(|| "(registered out-of-tree)".into());
                t.row(vec![name, knobs.into(), desc]);
            }
            println!("{}", t.render());
        }
        Some("workload") => {
            let kind = m
                .positionals
                .first()
                .and_then(|s| Kind::parse(s))
                .ok_or_else(|| {
                    anyhow::anyhow!("workload kind required (imagenet|resnet|quant|eigen|svm)")
                })?;
            let mut spec = CodecSpec::named("OHE");
            spec.set_knob("limit", m.get_or("limit", "80"))?;
            spec.set_knob("truncation", m.get_or("truncation", "0"))?;
            spec.set_knob("tolerance", m.get_or("tolerance", "0"))?;
            spec.validate()?;
            let faults = FaultSpec::parse(m.get_or("faults", "perfect"))?;
            let rt = Runtime::load(Runtime::default_dir())?;
            let suite = Suite::build(
                rt,
                m.get_usize("seed")? as u64,
                budget(m.get_or("budget", "quick")),
            )?;
            let r = suite.eval_under(&spec, &faults, kind)?;
            println!(
                "{} under {} ({} channel):\n  quality ratio  {:.3}  (original {:.3} -> approx {:.3})\n  termination 1s {}  switching {}  unencoded {:.1}%\n  {}",
                kind.label(),
                spec.label(),
                faults.label(),
                r.quality,
                r.original_metric,
                r.approx_metric,
                r.run.counts.termination_ones,
                r.run.counts.switching_transitions,
                100.0 * r.run.stats.unencoded_fraction(),
                r.run.quality_delta(),
            );
        }
        Some("run") => cmd_run(m.get("config").unwrap())?,
        Some("sweep") => cmd_sweep(&m)?,
        Some("budget") => cmd_budget(&m)?,
        Some("circuit") => {
            let (bd, zd) = zac_dest::circuits::evaluate(m.get_usize("vectors")?, 42);
            println!(
                "BD-Coder : {} transistors, {:.2} pJ/access, {:.2} ns",
                bd.transistors, bd.energy_pj, bd.latency_ns
            );
            println!(
                "ZAC-DEST : {} transistors, {:.2} pJ/access, {:.2} ns",
                zd.transistors, zd.energy_pj, zd.latency_ns
            );
            println!(
                "overheads: area {} energy {}",
                pct(zd.area_overhead_pct(&bd)),
                pct(zd.energy_overhead_pct(&bd))
            );
        }
        Some("artifacts") => {
            let dir = Runtime::default_dir();
            let rt = Runtime::load(&dir)?;
            let mut t = TextTable::new(&["artifact", "args", "outputs"]);
            let mut names: Vec<_> = rt.manifest().artifacts.keys().collect();
            names.sort();
            for name in names {
                let s = &rt.manifest().artifacts[name];
                t.row(vec![
                    name.clone(),
                    format!("{}", s.args.len()),
                    format!("{}", s.outputs.len()),
                ]);
            }
            println!("artifacts dir: {}\n{}", dir.display(), t.render());
            rt.precompile(&["trace_stats"])?;
            println!("PJRT compile check: ok");
        }
        _ => println!("{}", app.help()),
    }
    Ok(())
}

/// Build the codec spec the `encode` flags describe, through the
/// uniform `CodecSpec` ingestion path. A flag left at its declared
/// default is applied only when the scheme has that knob; a flag set
/// to any other value must be accepted by the scheme or it is an
/// error — the same "no silent knob absorption" contract as the TOML
/// path.
fn encode_spec(m: &zac_dest::util::cli::Matches) -> Result<CodecSpec> {
    let scheme = m.get_or("scheme", "OHE");
    let mut spec = CodecSpec::named(scheme);
    anyhow::ensure!(
        default_registry().contains(&spec.scheme),
        "unknown scheme {scheme:?}; registered: {:?}",
        default_registry().schemes()
    );
    for (flag, key, default) in [
        ("limit", "limit", "80"),
        ("truncation", "truncation", "0"),
        ("tolerance", "tolerance", "0"),
        ("table-size", "table_size", "64"),
    ] {
        let value = m.get_or(flag, default);
        let supported = match key {
            "table_size" => !matches!(spec.knobs, Knobs::None),
            _ => spec.zac_knobs().is_some(),
        };
        if supported || value != default {
            spec.set_knob(key, value)?;
        }
    }
    spec.validate()?;
    Ok(spec)
}

/// Resolve the `--input` traffic source `encode` and `record` share:
/// the standard synthetic image-like stream ('-', sized by
/// `--bytes`/`--seed`) or a hex trace file.
fn trace_source(m: &zac_dest::util::cli::Matches) -> Result<Vec<u8>> {
    let input = m.get_or("input", "-");
    if input == "-" {
        let n = m.get_usize("bytes")?;
        let seed = m.get_usize("seed")? as u64;
        return Ok(zac_dest::system::synthetic_trace(n, seed));
    }
    let text = std::fs::read_to_string(input)?;
    let lines = zac_dest::trace::hex::parse(&text)?;
    Ok(zac_dest::trace::chip_words_to_bytes(&lines, lines.len() * 64))
}

/// Parse the optional `--simd` override: empty string defers to the
/// `ZAC_SIMD` env / auto-detection default inside the session builder.
fn simd_pref(m: &zac_dest::util::cli::Matches) -> Result<Option<zac_dest::encoding::SimdPref>> {
    match m.get_or("simd", "") {
        "" => Ok(None),
        s => Ok(Some(zac_dest::encoding::SimdPref::parse(s)?)),
    }
}

fn cmd_encode(m: &zac_dest::util::cli::Matches) -> Result<()> {
    let spec = encode_spec(m)?;
    let faults = FaultSpec::parse(m.get_or("faults", "perfect"))?;
    let address = AddressSpec::parse(m.get_or("address", "round_robin"))?;
    let channels = m.get_usize("channels")?;
    let trace = Trace::from_bytes(trace_source(m)?);
    let metrics_out = m.get_or("metrics-out", "-");
    let telemetry = metrics_out != "-" || zac_dest::obs::metrics_from_env()?;
    let simd = simd_pref(m)?;
    let mut builder = Session::builder()
        .codec(spec.clone())
        .channels(channels)
        .address(address.clone())
        .traffic(TrafficClass::Approximate)
        .faults(faults)
        .telemetry(telemetry);
    if let Some(pref) = simd {
        builder = builder.simd(pref);
    }
    let session = builder.build()?;
    let t0 = std::time::Instant::now();
    let out = session.run(&trace)?;
    let dt = t0.elapsed();
    let mut base_builder = Session::builder()
        .codec(CodecSpec::named("ORG"))
        .channels(channels)
        .address(address.clone())
        .traffic(TrafficClass::Approximate);
    if let Some(pref) = simd {
        base_builder = base_builder.simd(pref);
    }
    let base = base_builder.build()?.run(&trace)?;
    let bytes = trace.bytes();
    println!("scheme        : {}", spec.label());
    println!("channels      : {channels}");
    println!("address       : {}", address.label());
    println!("faults        : {}", faults.label());
    println!("bytes         : {}", bytes.len());
    println!(
        "termination 1s: {} ({} vs ORG)",
        out.counts.termination_ones,
        pct(out.counts.termination_savings_vs(&base.counts))
    );
    println!(
        "switching     : {} ({} vs ORG)",
        out.counts.switching_transitions,
        pct(out.counts.switching_savings_vs(&base.counts))
    );
    for o in Outcome::all() {
        println!("  {:<10}: {:.1}%", o.label(), 100.0 * out.stats.fraction(o));
    }
    println!(
        "throughput    : {:.1} MB/s ({} lines in {:.1} ms)",
        bytes.len() as f64 / dt.as_secs_f64() / 1e6,
        bytes.len() / 64,
        dt.as_secs_f64() * 1e3
    );
    if out.faults.injected_bits > 0 {
        println!("{}", out.quality_delta());
    }
    if channels > 1 {
        // The sharded render already carries the telemetry section.
        println!("\n{}", out.render());
    } else if let Some(t) = &out.telemetry {
        println!("\n{}", t.render_table());
    }
    if let Some(t) = &out.telemetry {
        if metrics_out != "-" {
            zac_dest::util::json_lite::write_file(metrics_out, &t.to_json())?;
            eprintln!("metrics -> {metrics_out}");
        }
    }
    Ok(())
}

fn cmd_record(m: &zac_dest::util::cli::Matches) -> Result<()> {
    use zac_dest::trace::wire::{Layout, TraceWriter};
    let out = m
        .positionals
        .first()
        .ok_or_else(|| anyhow::anyhow!("output .zactrace path required"))?;
    let approx = match m.get_or("traffic", "approximate") {
        "approximate" => true,
        "critical" => false,
        other => anyhow::bail!("unknown traffic class {other:?}; valid: approximate, critical"),
    };
    let chunk_lines = m.get_usize("chunk-lines")? as u32;
    let trace = Trace::from_bytes(trace_source(m)?);
    let mut w = TraceWriter::create_with_chunk(out, Layout::Raw, approx, chunk_lines)?;
    w.write_lines(trace.lines(), approx)?;
    let header = w.finish(trace.byte_len())?;
    println!(
        "recorded {out}: {} bytes, {} lines in {} frames, {} class",
        header.byte_len,
        trace.line_count(),
        header.frame_count,
        if approx { "approximate" } else { "critical" }
    );
    Ok(())
}

fn cmd_replay(m: &zac_dest::util::cli::Matches) -> Result<()> {
    use zac_dest::trace::wire::TraceFile;
    let input = m
        .positionals
        .first()
        .ok_or_else(|| anyhow::anyhow!("input .zactrace path required"))?;
    let spec = encode_spec(m)?;
    let faults = FaultSpec::parse(m.get_or("faults", "perfect"))?;
    let address = AddressSpec::parse(m.get_or("address", "round_robin"))?;
    let channels = m.get_usize("channels")?;
    let metrics_out = m.get_or("metrics-out", "-");
    let telemetry = metrics_out != "-" || zac_dest::obs::metrics_from_env()?;
    let file = TraceFile::open(input).map_err(|e| anyhow::anyhow!("{input}: {e}"))?;
    let simd = simd_pref(m)?;
    let mut builder = Session::builder()
        .codec(spec.clone())
        .channels(channels)
        .address(address.clone())
        .traffic(TrafficClass::Approximate)
        .faults(faults)
        .telemetry(telemetry);
    if let Some(pref) = simd {
        builder = builder.simd(pref);
    }
    let session = builder.build()?;
    let t0 = std::time::Instant::now();
    let out = session.replay(&file)?;
    let dt = t0.elapsed();
    // The savings baseline replays the same recorded frames, so the
    // comparison is trace-for-trace fair.
    let mut base_builder = Session::builder()
        .codec(CodecSpec::named("ORG"))
        .channels(channels)
        .address(address.clone())
        .traffic(TrafficClass::Approximate);
    if let Some(pref) = simd {
        base_builder = base_builder.simd(pref);
    }
    let base = base_builder.build()?.replay(&file)?;
    println!("scheme        : {}", spec.label());
    println!("channels      : {channels}");
    println!("address       : {}", address.label());
    println!("faults        : {}", faults.label());
    println!(
        "trace         : {input} ({} bytes, {} lines, {} frames)",
        file.byte_len(),
        file.total_lines(),
        file.frame_count()
    );
    println!(
        "termination 1s: {} ({} vs ORG)",
        out.counts.termination_ones,
        pct(out.counts.termination_savings_vs(&base.counts))
    );
    println!(
        "switching     : {} ({} vs ORG)",
        out.counts.switching_transitions,
        pct(out.counts.switching_savings_vs(&base.counts))
    );
    for o in Outcome::all() {
        println!("  {:<10}: {:.1}%", o.label(), 100.0 * out.stats.fraction(o));
    }
    println!(
        "throughput    : {:.1} MB/s ({} lines in {:.1} ms)",
        file.byte_len() as f64 / dt.as_secs_f64() / 1e6,
        file.total_lines(),
        dt.as_secs_f64() * 1e3
    );
    if out.faults.injected_bits > 0 {
        println!("{}", out.quality_delta());
    }
    if channels > 1 {
        // The sharded render already carries the telemetry section.
        println!("\n{}", out.render());
    } else if let Some(t) = &out.telemetry {
        println!("\n{}", t.render_table());
    }
    if let Some(t) = &out.telemetry {
        if metrics_out != "-" {
            zac_dest::util::json_lite::write_file(metrics_out, &t.to_json())?;
            eprintln!("metrics -> {metrics_out}");
        }
    }
    Ok(())
}

fn cmd_trace_info(m: &zac_dest::util::cli::Matches) -> Result<()> {
    use zac_dest::trace::wire::TraceFile;
    let path = m
        .positionals
        .first()
        .ok_or_else(|| anyhow::anyhow!("trace file path required"))?;
    let file = TraceFile::open(path).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    print!("{}", file.inspect().render());
    Ok(())
}

fn cmd_sweep(m: &zac_dest::util::cli::Matches) -> Result<()> {
    use zac_dest::system::{
        bench_bytes_from_env, channels_from_env, parse_channel_list, parse_rates, parse_workers,
        run_loadgen, run_sweep_resume, sweep_trace, sweep_workers_from_env, LoadGenSpec,
        SweepReport, SweepSpec,
    };
    let mut spec = match m.get_or("spec", "-") {
        "-" => SweepSpec::default(),
        path => SweepSpec::from_file(path)?,
    };
    // Precedence for each knob: explicit flag > environment > spec.
    match m.get_or("channels", "") {
        "" => {
            if let Some(ch) = channels_from_env()? {
                spec.channels = ch;
            }
        }
        list => spec.channels = parse_channel_list(list)?,
    }
    let bytes = m.get_usize("bytes")?;
    if bytes > 0 {
        spec.bytes = bytes;
    } else if let Some(n) = bench_bytes_from_env()? {
        // A set-but-malformed value errors inside the helper, never a
        // silent fallback.
        spec.bytes = n;
    }
    let seed = m.get_usize("seed")? as u64;
    if seed > 0 {
        spec.seed = seed;
    }
    let faults_flag = m.get_or("faults", "");
    if !faults_flag.is_empty() {
        spec.faults = FaultSpec::parse_list(faults_flag)?;
    }
    let schemes_flag = m.get_or("schemes", "");
    if !schemes_flag.is_empty() {
        spec.schemes = schemes_flag
            .split(',')
            .map(zac_dest::system::resolve_scheme_name)
            .collect::<Result<_>>()?;
        spec.validate()?;
    }
    let address_flag = m.get_or("address", "");
    if !address_flag.is_empty() {
        spec.address = AddressSpec::parse_list(address_flag)?;
    }
    match m.get_or("trace", "-") {
        "-" => {}
        path => spec.trace = Some(path.to_string()),
    }
    // `--metrics-out` or `ZAC_METRICS=1` turn telemetry on; a spec with
    // `telemetry = true` keeps it on even without either.
    let metrics_out = m.get_or("metrics-out", "-");
    if metrics_out != "-" || zac_dest::obs::metrics_from_env()? {
        spec.telemetry = true;
    }
    // Worker precedence mirrors the other knobs: flag > env > spec.
    match m.get_or("workers", "") {
        "" => {
            if let Some(w) = sweep_workers_from_env()? {
                spec.workers = w;
            }
        }
        text => spec.workers = parse_workers(text)?,
    }
    let trace = sweep_trace(&spec)?;
    eprintln!(
        "[sweep] {:?}: channels {:?}, {} B trace, baseline {}, faults {:?}, address {:?}, workers {}",
        spec.name,
        spec.channels,
        trace.byte_len(),
        spec.baseline,
        spec.faults.iter().map(|f| f.label()).collect::<Vec<_>>(),
        spec.address.iter().map(|a| a.label()).collect::<Vec<_>>(),
        spec.workers
    );
    let out = m.get_or("out", "BENCH_system.json");
    // `--resume` reloads the previous `--out` file and skips every cell
    // whose fingerprint already appears there; a missing file just means
    // a fresh run, not an error.
    let prior = if m.flag("resume") && out != "-" {
        if std::path::Path::new(out).exists() {
            Some(SweepReport::from_json_file(out)?)
        } else {
            eprintln!("[sweep] --resume: no prior report at {out}, running from scratch");
            None
        }
    } else {
        None
    };
    let report = run_sweep_resume(&spec, &trace, prior.as_ref())?;
    println!("{}", report.render_table());
    if out != "-" {
        report.write_json(out)?;
    }
    if metrics_out != "-" {
        report.write_metrics(metrics_out)?;
    }
    let rates_flag = m.get_or("open-loop", "");
    if !rates_flag.is_empty() {
        let lg = LoadGenSpec::from_sweep(&spec, parse_rates(rates_flag)?)?;
        let lg_report = run_loadgen(&lg, &trace)?;
        println!("{}", lg_report.render_table());
        let lg_out = m.get_or("loadgen-out", "BENCH_loadgen.json");
        if lg_out != "-" {
            lg_report.write_json(lg_out)?;
        }
    }
    Ok(())
}

/// Parse the `budget --workloads` list, naming the offending token and
/// listing the valid kinds (the `--faults` error contract).
fn parse_workload_list(text: &str) -> Result<Vec<Kind>> {
    let list: Vec<Kind> = text
        .split(',')
        .map(|p| {
            Kind::parse(p.trim()).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown workload {:?}; valid workloads: imagenet, resnet, quant, eigen, svm",
                    p.trim()
                )
            })
        })
        .collect::<Result<_>>()?;
    anyhow::ensure!(!list.is_empty(), "empty workload list");
    Ok(list)
}

fn cmd_budget(m: &zac_dest::util::cli::Matches) -> Result<()> {
    use zac_dest::workloads::{derive_budgets, derive_budgets_full, BudgetSpec};
    let name = zac_dest::system::resolve_scheme_name(m.get_or("scheme", "ECC+BDE"))?;
    let cap_text = m.get_or("cap", "2e-4");
    let cap: f64 = cap_text
        .parse()
        .map_err(|e| anyhow::anyhow!("bad cap {cap_text:?}: {e}"))?;
    let mut bspec = BudgetSpec::new(CodecSpec::named(&name), cap);
    bspec.seed = m.get_usize("seed")? as u64;
    bspec.channels = m.get_usize("channels")?;
    bspec.workloads = parse_workload_list(m.get_or("workloads", "imagenet,resnet,quant,eigen,svm"))?;
    let metrics_out = m.get_or("metrics-out", "-");
    bspec.telemetry = metrics_out != "-" || zac_dest::obs::metrics_from_env()?;
    let report = match m.get_or("mode", "proxy") {
        "proxy" => derive_budgets(&bspec)?,
        "full" => {
            let rt = Runtime::load(Runtime::default_dir())?;
            let suite = Suite::build(
                rt,
                bspec.seed,
                budget(m.get_or("budget", "quick")),
            )?;
            derive_budgets_full(&suite, &bspec)?
        }
        other => anyhow::bail!("unknown mode {other:?}; valid modes: proxy, full"),
    };
    println!("{}", report.render_table());
    let out = m.get_or("out", "BENCH_system.json");
    if out != "-" {
        report.merge_into(out)?;
    }
    if metrics_out != "-" {
        report.write_metrics(metrics_out)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matches(line: &str) -> zac_dest::util::cli::Matches {
        let argv: Vec<String> = line.split_whitespace().map(|s| s.to_string()).collect();
        app().parse(&argv).unwrap()
    }

    #[test]
    fn cli_flags_build_a_validated_spec() {
        let spec = encode_spec(&matches("encode --limit 75 --truncation 2")).unwrap();
        let k = spec.zac_knobs().unwrap();
        assert_eq!(k.similarity_limit_pct, 75);
        assert_eq!(k.truncation_bits, 2);
        let spec = encode_spec(&matches("encode --scheme BDE --table-size 32")).unwrap();
        assert_eq!(spec.scheme, "BDE");
        assert_eq!(spec.table_size(), 32);
        // Knob-free schemes ignore the zac defaults, as before.
        let spec = encode_spec(&matches("encode --scheme ORG")).unwrap();
        assert_eq!(spec.knobs, Knobs::None);
    }

    #[test]
    fn cli_rejects_bad_specs() {
        // Satellite: validate() runs (and surfaces an error, not a
        // panic) on the CLI flag ingestion path.
        let err = encode_spec(&matches("encode --limit 200"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("similarity limit"), "{err}");
        assert!(encode_spec(&matches("encode --truncation 9")).is_err());
        assert!(encode_spec(&matches("encode --scheme BDE --table-size 0")).is_err());
        assert!(encode_spec(&matches("encode --scheme NOPE")).is_err());
        assert!(encode_spec(&matches("encode --limit eighty")).is_err());
        // An explicitly non-default knob a scheme doesn't have is an
        // error, not silently dropped (same contract as the TOML path).
        let err = encode_spec(&matches("encode --scheme BDE --limit 75"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("no knob"), "{err}");
        assert!(encode_spec(&matches("encode --scheme ORG --table-size 32")).is_err());
    }

    #[test]
    fn cli_address_flag_parses_and_rejects_garbage() {
        let m = matches("encode --address steer --channels 2");
        let a = AddressSpec::parse(m.get_or("address", "round_robin")).unwrap();
        assert_eq!(a.label(), "steer");
        let m = matches("encode --address capacity:2/1");
        assert_eq!(
            AddressSpec::parse(m.get_or("address", "round_robin"))
                .unwrap()
                .label(),
            "cap2/1"
        );
        let m = matches("encode");
        assert!(AddressSpec::parse(m.get_or("address", "round_robin"))
            .unwrap()
            .is_round_robin());
        let m = matches("encode --address banana");
        assert!(AddressSpec::parse(m.get_or("address", "round_robin")).is_err());
        // The sweep axis form.
        let m = matches("sweep --address round_robin,steer");
        assert_eq!(
            AddressSpec::parse_list(m.get_or("address", "")).unwrap().len(),
            2
        );
    }

    #[test]
    fn record_replay_and_trace_info_cli_flags_parse() {
        let m = matches("record out.zactrace --bytes 4096 --seed 7 --chunk-lines 64");
        assert_eq!(m.positionals.first().map(|s| s.as_str()), Some("out.zactrace"));
        assert_eq!(m.get_usize("bytes").unwrap(), 4096);
        assert_eq!(m.get_usize("chunk-lines").unwrap(), 64);
        assert_eq!(m.get_or("traffic", "approximate"), "approximate");
        let m = matches("replay in.zactrace --scheme BDE --channels 2 --faults voltage:1050");
        assert_eq!(m.positionals.first().map(|s| s.as_str()), Some("in.zactrace"));
        assert_eq!(encode_spec(&m).unwrap().scheme, "BDE");
        assert_eq!(m.get_usize("channels").unwrap(), 2);
        let m = matches("trace-info t.zactrace");
        assert_eq!(m.positionals.first().map(|s| s.as_str()), Some("t.zactrace"));
        // The sweep source override rides the same flag surface.
        let m = matches("sweep --trace ci.zactrace");
        assert_eq!(m.get_or("trace", "-"), "ci.zactrace");
        let m = matches("sweep");
        assert_eq!(m.get_or("trace", "-"), "-");
    }

    #[test]
    fn metrics_out_flag_parses_on_each_subcommand() {
        for cmd in ["encode", "sweep", "budget"] {
            let m = matches(&format!("{cmd} --metrics-out M.json"));
            assert_eq!(m.get_or("metrics-out", "-"), "M.json", "{cmd}");
            let m = matches(cmd);
            assert_eq!(m.get_or("metrics-out", "-"), "-", "{cmd}");
        }
    }

    #[test]
    fn budget_workload_list_names_the_token_and_lists_valid_kinds() {
        assert_eq!(
            parse_workload_list("imagenet, svm").unwrap(),
            vec![Kind::ImageNet, Kind::Svm]
        );
        let err = parse_workload_list("imagenet,wat").unwrap_err().to_string();
        assert!(err.contains("\"wat\""), "{err}");
        assert!(err.contains("valid workloads"), "{err}");
        // The sweep --schemes axis shares the same contract.
        let err = zac_dest::system::resolve_scheme_name("NOPE")
            .unwrap_err()
            .to_string();
        assert!(err.contains("\"NOPE\"") && err.contains("registered schemes"), "{err}");
    }

    #[test]
    fn budget_cli_flags_parse() {
        let m = matches("budget --scheme ecc+org --cap 1e-3 --workloads quant");
        assert_eq!(
            zac_dest::system::resolve_scheme_name(m.get_or("scheme", "ECC+BDE")).unwrap(),
            "ECC+ORG"
        );
        assert_eq!(m.get_or("cap", "2e-4"), "1e-3");
        assert_eq!(
            parse_workload_list(m.get_or("workloads", "svm")).unwrap(),
            vec![Kind::Quant]
        );
    }

    #[test]
    fn simd_flag_parses_and_rejects_garbage() {
        // Absent flag defers to ZAC_SIMD / auto-detection (None).
        let m = matches("encode");
        assert_eq!(simd_pref(&m).unwrap(), None);
        let m = matches("encode --simd scalar");
        assert_eq!(
            simd_pref(&m).unwrap(),
            Some(zac_dest::encoding::SimdPref::Scalar)
        );
        let m = matches("replay in.zactrace --simd AVX2");
        assert_eq!(
            simd_pref(&m).unwrap(),
            Some(zac_dest::encoding::SimdPref::Avx2)
        );
        let m = matches("encode --simd banana");
        let err = simd_pref(&m).unwrap_err().to_string();
        assert!(err.contains("banana"), "{err}");
    }

    #[test]
    fn sweep_worker_resume_and_loadgen_flags_parse() {
        use zac_dest::system::{parse_rates, parse_workers};
        // --workers: explicit N, 'auto', and the default empty string
        // (which defers to ZAC_SWEEP_WORKERS / the spec).
        let m = matches("sweep --workers 4");
        assert_eq!(parse_workers(m.get_or("workers", "")).unwrap(), 4);
        let m = matches("sweep --workers auto");
        assert!(parse_workers(m.get_or("workers", "")).unwrap() >= 1);
        let m = matches("sweep");
        assert_eq!(m.get_or("workers", ""), "");
        assert!(parse_workers("0").is_err());
        assert!(parse_workers("lots").is_err());
        // --resume is a bare flag.
        assert!(matches("sweep --resume").flag("resume"));
        assert!(!matches("sweep").flag("resume"));
        // --open-loop carries the offered-rate list; --loadgen-out the
        // artifact path.
        let m = matches("sweep --open-loop 5e4,2e5 --loadgen-out LG.json");
        assert_eq!(parse_rates(m.get_or("open-loop", "")).unwrap(), vec![5e4, 2e5]);
        assert_eq!(m.get_or("loadgen-out", "BENCH_loadgen.json"), "LG.json");
        let m = matches("sweep");
        assert_eq!(m.get_or("open-loop", ""), "");
        assert_eq!(m.get_or("loadgen-out", "BENCH_loadgen.json"), "BENCH_loadgen.json");
    }

    #[test]
    fn cli_fault_flag_parses_and_rejects_garbage() {
        let m = matches("encode --faults voltage:1050@3");
        let f = FaultSpec::parse(m.get_or("faults", "perfect")).unwrap();
        assert_eq!(f.label(), "vdd1050mV");
        assert_eq!(f.seed, 3);
        let m = matches("encode");
        assert!(FaultSpec::parse(m.get_or("faults", "perfect"))
            .unwrap()
            .is_perfect());
        let m = matches("encode --faults banana");
        assert!(FaultSpec::parse(m.get_or("faults", "perfect")).is_err());
    }
}

fn cmd_run(path: &str) -> Result<()> {
    let rc = RunConfig::from_file(path)?;
    if let Some(trace) = &rc.trace {
        return run_recorded_config(&rc, trace);
    }
    println!(
        "run {:?}: {} over {:?} ({} channel, {} shard(s), address {})",
        rc.name,
        rc.encoder.label(),
        rc.workloads,
        rc.faults.label(),
        rc.channels,
        rc.address.label()
    );
    let rt = Runtime::load(Runtime::default_dir())?;
    let mut b = SuiteBudget::full();
    b.eval_images = rc.eval_images.max(32);
    b.train_steps = rc.train_steps;
    b.lr = rc.lr;
    let mut suite = Suite::build(rt, rc.seed, b)?;
    suite.channels = rc.channels;
    suite.address = rc.address.clone();
    let mut t = TextTable::new(&[
        "workload",
        "quality",
        "term 1s",
        "switching",
        "unencoded",
        "flips",
    ]);
    for w in &rc.workloads {
        let kind = Kind::parse(w).ok_or_else(|| anyhow::anyhow!("unknown workload {w:?}"))?;
        let r = suite.eval_under(&rc.encoder, &rc.faults, kind)?;
        t.row(vec![
            kind.label().into(),
            format!("{:.3}", r.quality),
            format!("{}", r.run.counts.termination_ones),
            format!("{}", r.run.counts.switching_transitions),
            pct(100.0 * r.run.stats.unencoded_fraction()),
            format!("{}", r.run.faults.injected_bits),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// `run` with a `trace = "..."` key: replay the recorded file under
/// the config's encoder/faults/channels/address topology instead of
/// the workload suite.
fn run_recorded_config(rc: &RunConfig, path: &str) -> Result<()> {
    use zac_dest::trace::wire::TraceFile;
    println!(
        "run {:?}: {} over recorded trace {path:?} ({}, {} shard(s), address {})",
        rc.name,
        rc.encoder.label(),
        rc.faults.label(),
        rc.channels,
        rc.address.label()
    );
    let file = TraceFile::open(path).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let report = Session::builder()
        .codec(rc.encoder.clone())
        .channels(rc.channels)
        .address(rc.address.clone())
        .faults(rc.faults)
        .traffic(TrafficClass::Approximate)
        .build()?
        .replay(&file)?;
    println!("{}", report.render());
    Ok(())
}
