//! Run configuration: a TOML file (or CLI flags) describing the encoder
//! knobs and workload parameters for one simulation run.

use crate::encoding::{Scheme, ZacConfig};
use crate::util::json_lite::Json;
use crate::util::toml_lite;

/// Full run configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub name: String,
    pub seed: u64,
    pub encoder: ZacConfig,
    /// Workloads to run (imagenet / resnet / quant / eigen / svm).
    pub workloads: Vec<String>,
    /// Images per workload evaluation.
    pub eval_images: usize,
    /// Training steps for trainable workloads.
    pub train_steps: usize,
    /// Learning rate.
    pub lr: f32,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            name: "default".into(),
            seed: 42,
            encoder: ZacConfig::default(),
            workloads: vec![
                "imagenet".into(),
                "resnet".into(),
                "quant".into(),
                "eigen".into(),
                "svm".into(),
            ],
            eval_images: 64,
            train_steps: 60,
            lr: 0.05,
        }
    }
}

impl RunConfig {
    /// Parse from TOML text. Unknown keys are rejected to catch typos.
    pub fn from_toml(text: &str) -> anyhow::Result<RunConfig> {
        let doc = toml_lite::parse(text)?;
        let mut cfg = RunConfig::default();
        let root = doc.as_obj()?;
        for (k, v) in root {
            match k.as_str() {
                "name" => cfg.name = v.as_str()?.to_string(),
                "seed" => cfg.seed = v.as_f64()? as u64,
                "encoder" => cfg.encoder = parse_encoder(v)?,
                "workload" => parse_workload(v, &mut cfg)?,
                other => anyhow::bail!("unknown top-level key {other:?}"),
            }
        }
        cfg.encoder.validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> anyhow::Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        Self::from_toml(&text)
    }
}

fn parse_encoder(v: &Json) -> anyhow::Result<ZacConfig> {
    let mut cfg = ZacConfig::default();
    for (k, val) in v.as_obj()? {
        match k.as_str() {
            "scheme" => {
                let s = val.as_str()?;
                cfg.scheme = Scheme::parse(s)
                    .ok_or_else(|| anyhow::anyhow!("unknown scheme {s:?}"))?;
            }
            "similarity_limit" => cfg.similarity_limit_pct = val.as_f64()? as u32,
            "chunk_width" => cfg.chunk_width = val.as_f64()? as u32,
            "tolerance" => cfg.tolerance_bits = val.as_f64()? as u32,
            "truncation" => cfg.truncation_bits = val.as_f64()? as u32,
            "table_size" => cfg.table_size = val.as_usize()?,
            "weights_mode" => {
                if matches!(val, Json::Bool(true)) {
                    cfg.chunk_width = 32;
                    cfg.tolerance_mask_override =
                        Some(crate::trace::float_layout::weight_tolerance_mask());
                }
            }
            other => anyhow::bail!("unknown [encoder] key {other:?}"),
        }
    }
    Ok(cfg)
}

fn parse_workload(v: &Json, cfg: &mut RunConfig) -> anyhow::Result<()> {
    for (k, val) in v.as_obj()? {
        match k.as_str() {
            "kinds" => {
                cfg.workloads = val
                    .as_arr()?
                    .iter()
                    .map(|s| Ok(s.as_str()?.to_string()))
                    .collect::<anyhow::Result<_>>()?;
            }
            "eval_images" => cfg.eval_images = val.as_usize()?,
            "train_steps" => cfg.train_steps = val.as_usize()?,
            "lr" => cfg.lr = val.as_f64()? as f32,
            other => anyhow::bail!("unknown [workload] key {other:?}"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = RunConfig::from_toml(
            r#"
            name = "fig15-cell"
            seed = 7
            [encoder]
            scheme = "ZAC-DEST"
            similarity_limit = 75
            truncation = 2
            tolerance = 0
            table_size = 64
            [workload]
            kinds = ["quant", "svm"]
            eval_images = 32
            train_steps = 10
            lr = 0.1
            "#,
        )
        .unwrap();
        assert_eq!(cfg.name, "fig15-cell");
        assert_eq!(cfg.encoder.similarity_limit_pct, 75);
        assert_eq!(cfg.encoder.truncation_bits, 2);
        assert_eq!(cfg.workloads, vec!["quant", "svm"]);
        assert_eq!(cfg.train_steps, 10);
    }

    #[test]
    fn weights_mode_sets_mask() {
        let cfg = RunConfig::from_toml(
            "[encoder]\nscheme = \"OHE\"\nsimilarity_limit = 60\nweights_mode = true\n",
        )
        .unwrap();
        assert_eq!(cfg.encoder.chunk_width, 32);
        assert_eq!(
            cfg.encoder.tolerance_mask_override,
            Some(0xFF80_0000_FF80_0000)
        );
    }

    #[test]
    fn rejects_unknown_keys_and_bad_scheme() {
        assert!(RunConfig::from_toml("bogus = 1\n").is_err());
        assert!(RunConfig::from_toml("[encoder]\nscheme = \"WAT\"\n").is_err());
        assert!(RunConfig::from_toml("[encoder]\nsimilarity_limit = 10\n").is_err());
    }

    #[test]
    fn defaults_are_valid() {
        RunConfig::default().encoder.validate().unwrap();
    }
}
