//! Run configuration: a TOML file (or CLI flags) describing the encoder
//! knobs and workload parameters for one simulation run. The `[encoder]`
//! table feeds the uniform [`CodecSpec::set_knob`] ingestion path, so
//! TOML, CLI flags and env overrides all apply (and reject) knobs
//! identically, and `validate()` runs before the config is accepted.

use crate::encoding::CodecSpec;
use crate::faults::FaultSpec;
use crate::system::AddressSpec;
use crate::util::json_lite::Json;
use crate::util::toml_lite;

/// Full run configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub name: String,
    pub seed: u64,
    pub encoder: CodecSpec,
    /// Fault model the channel runs under (`faults = "voltage:1050"`;
    /// default: perfect channel).
    pub faults: FaultSpec,
    /// Channels the workload traces shard across (`channels = 2`;
    /// default 1, the paper's single-channel setup).
    pub channels: usize,
    /// Address-mapping policy for sharded traffic (`address = "steer"`;
    /// default: round-robin).
    pub address: AddressSpec,
    /// Recorded `.zactrace` to replay instead of the workloads
    /// (`trace = "run.zactrace"`): the file streams zero-copy through
    /// the configured encoder/faults/channels/address topology.
    pub trace: Option<String>,
    /// Workloads to run (imagenet / resnet / quant / eigen / svm).
    pub workloads: Vec<String>,
    /// Images per workload evaluation.
    pub eval_images: usize,
    /// Training steps for trainable workloads.
    pub train_steps: usize,
    /// Learning rate.
    pub lr: f32,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            name: "default".into(),
            seed: 42,
            encoder: CodecSpec::named("OHE"),
            faults: FaultSpec::perfect(),
            channels: 1,
            address: AddressSpec::round_robin(),
            trace: None,
            workloads: vec![
                "imagenet".into(),
                "resnet".into(),
                "quant".into(),
                "eigen".into(),
                "svm".into(),
            ],
            eval_images: 64,
            train_steps: 60,
            lr: 0.05,
        }
    }
}

impl RunConfig {
    /// Parse from TOML text. Unknown keys are rejected to catch typos.
    pub fn from_toml(text: &str) -> anyhow::Result<RunConfig> {
        let doc = toml_lite::parse(text)?;
        let mut cfg = RunConfig::default();
        let root = doc.as_obj()?;
        for (k, v) in root {
            match k.as_str() {
                "name" => cfg.name = v.as_str()?.to_string(),
                "seed" => cfg.seed = v.as_f64()? as u64,
                "encoder" => cfg.encoder = parse_encoder(v)?,
                "faults" => cfg.faults = FaultSpec::parse(v.as_str()?)?,
                "channels" => {
                    let n = v.as_usize()?;
                    anyhow::ensure!(
                        (1..=64).contains(&n),
                        "channels {n} out of range 1..=64"
                    );
                    cfg.channels = n;
                }
                "address" => cfg.address = AddressSpec::parse(v.as_str()?)?,
                "trace" => cfg.trace = Some(v.as_str()?.to_string()),
                "workload" => parse_workload(v, &mut cfg)?,
                other => anyhow::bail!("unknown top-level key {other:?}"),
            }
        }
        cfg.encoder.validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> anyhow::Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        Self::from_toml(&text)
    }
}

fn parse_encoder(v: &Json) -> anyhow::Result<CodecSpec> {
    let table = v.as_obj()?;
    // Two passes: the scheme decides which knobs exist, and TOML table
    // iteration is key-sorted, so resolve the scheme first.
    let mut spec = match table.get("scheme") {
        Some(s) => {
            let name = s.as_str()?;
            let spec = CodecSpec::named(name);
            anyhow::ensure!(
                crate::encoding::default_registry().contains(&spec.scheme),
                "unknown scheme {name:?}"
            );
            spec
        }
        None => CodecSpec::named("OHE"),
    };
    for (k, val) in table {
        match k.as_str() {
            "scheme" => {}
            "similarity_limit" | "chunk_width" | "tolerance" | "truncation" | "table_size" => {
                // Numbers ride through toml_lite as f64; knobs must be
                // exact non-negative integers (no silent truncation).
                let x = val.as_f64()?;
                anyhow::ensure!(
                    x >= 0.0 && x.fract() == 0.0,
                    "[encoder] {k} must be a non-negative integer, got {x}"
                );
                spec.set_knob(k, &format!("{}", x as u64))?;
            }
            "weights_mode" => match val {
                Json::Bool(b) => spec.set_knob("weights_mode", if *b { "true" } else { "false" })?,
                other => anyhow::bail!("weights_mode must be true/false, got {other:?}"),
            },
            other => anyhow::bail!("unknown [encoder] key {other:?}"),
        }
    }
    Ok(spec)
}

fn parse_workload(v: &Json, cfg: &mut RunConfig) -> anyhow::Result<()> {
    for (k, val) in v.as_obj()? {
        match k.as_str() {
            "kinds" => {
                cfg.workloads = val
                    .as_arr()?
                    .iter()
                    .map(|s| Ok(s.as_str()?.to_string()))
                    .collect::<anyhow::Result<_>>()?;
            }
            "eval_images" => cfg.eval_images = val.as_usize()?,
            "train_steps" => cfg.train_steps = val.as_usize()?,
            "lr" => cfg.lr = val.as_f64()? as f32,
            other => anyhow::bail!("unknown [workload] key {other:?}"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = RunConfig::from_toml(
            r#"
            name = "fig15-cell"
            seed = 7
            [encoder]
            scheme = "ZAC-DEST"
            similarity_limit = 75
            truncation = 2
            tolerance = 0
            table_size = 64
            [workload]
            kinds = ["quant", "svm"]
            eval_images = 32
            train_steps = 10
            lr = 0.1
            "#,
        )
        .unwrap();
        assert_eq!(cfg.name, "fig15-cell");
        let knobs = cfg.encoder.zac_knobs().unwrap();
        assert_eq!(knobs.similarity_limit_pct, 75);
        assert_eq!(knobs.truncation_bits, 2);
        assert_eq!(cfg.workloads, vec!["quant", "svm"]);
        assert_eq!(cfg.train_steps, 10);
    }

    #[test]
    fn weights_mode_sets_mask() {
        let cfg = RunConfig::from_toml(
            "[encoder]\nscheme = \"OHE\"\nsimilarity_limit = 60\nweights_mode = true\n",
        )
        .unwrap();
        let knobs = cfg.encoder.zac_knobs().unwrap();
        assert_eq!(knobs.chunk_width, 32);
        assert_eq!(knobs.tolerance_mask_override, Some(0xFF80_0000_FF80_0000));
    }

    #[test]
    fn faults_key_parses_and_rejects_garbage() {
        let cfg = RunConfig::from_toml("faults = \"voltage:1050\"\n").unwrap();
        assert_eq!(cfg.faults.label(), "vdd1050mV");
        let cfg = RunConfig::from_toml("faults = \"uniform:1e-4@9\"\n").unwrap();
        assert_eq!(cfg.faults.seed, 9);
        assert_eq!(RunConfig::default().faults, FaultSpec::perfect());
        assert!(RunConfig::from_toml("faults = \"wat\"\n").is_err());
        assert!(RunConfig::from_toml("faults = \"voltage:100\"\n").is_err());
    }

    #[test]
    fn channels_and_address_keys_parse_and_reject_garbage() {
        let cfg =
            RunConfig::from_toml("channels = 2\naddress = \"steer\"\n").unwrap();
        assert_eq!(cfg.channels, 2);
        assert_eq!(cfg.address.label(), "steer");
        let cfg = RunConfig::from_toml("address = \"capacity:2/1\"\n").unwrap();
        assert_eq!(cfg.address.label(), "cap2/1");
        assert_eq!(RunConfig::default().channels, 1);
        assert!(RunConfig::default().address.is_round_robin());
        assert!(RunConfig::from_toml("channels = 0\n").is_err());
        assert!(RunConfig::from_toml("channels = 99\n").is_err());
        assert!(RunConfig::from_toml("address = \"wat\"\n").is_err());
        assert!(RunConfig::from_toml("address = \"capacity:0\"\n").is_err());
    }

    #[test]
    fn trace_key_parses_and_rejects_non_strings() {
        assert_eq!(RunConfig::default().trace, None);
        let cfg = RunConfig::from_toml("trace = \"run.zactrace\"\n").unwrap();
        assert_eq!(cfg.trace.as_deref(), Some("run.zactrace"));
        assert!(RunConfig::from_toml("trace = 3\n").is_err());
    }

    #[test]
    fn rejects_unknown_keys_and_bad_scheme() {
        assert!(RunConfig::from_toml("bogus = 1\n").is_err());
        assert!(RunConfig::from_toml("[encoder]\nscheme = \"WAT\"\n").is_err());
        assert!(RunConfig::from_toml("[encoder]\nsimilarity_limit = 10\n").is_err());
        // Knob values must be exact non-negative integers.
        assert!(RunConfig::from_toml("[encoder]\ntable_size = 32.9\n").is_err());
        assert!(RunConfig::from_toml("[encoder]\nsimilarity_limit = -80\n").is_err());
    }

    #[test]
    fn knobs_of_other_schemes_are_rejected_not_absorbed() {
        // The god-struct used to silently accept ZAC knobs on any
        // scheme; the per-scheme knob structs reject them.
        let err = RunConfig::from_toml("[encoder]\nscheme = \"BDE\"\nsimilarity_limit = 80\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("no knob"), "{err}");
        // table_size is a BDE knob, so that still parses.
        let cfg =
            RunConfig::from_toml("[encoder]\nscheme = \"BDE\"\ntable_size = 32\n").unwrap();
        assert_eq!(cfg.encoder.table_size(), 32);
    }

    #[test]
    fn defaults_are_valid() {
        RunConfig::default().encoder.validate().unwrap();
    }
}
