//! The streaming coordinator: drives whole byte/float traces through the
//! 8-chip channel (encode → wire → decode), aggregating energy and
//! encoding statistics, and reassembling the receiver-side (possibly
//! approximate) stream for the workloads.
//!
//! Two drivers:
//! * [`simulate_bytes`] — batch mode: one worker per DRAM chip via
//!   [`par_map`] (chips are architecturally independent: separate
//!   tables, lines and sidebands).
//! * [`Pipeline`] — streaming mode with bounded per-chip queues
//!   (`sync_channel`), giving real backpressure when a producer outruns
//!   the encoder workers; used by the e2e example and the service loop.

pub mod config;

pub use config::RunConfig;

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;

use crate::channel::{ChipChannel, EnergyCounts, CHIPS};
use crate::encoding::{make_codec, EncodeStats, ZacConfig};
use crate::trace::{bytes_to_chip_words, chip_words_to_bytes, ChipWords};

/// Result of a trace simulation.
#[derive(Clone, Debug)]
pub struct RunOutput {
    /// The receiver-side byte stream (exact or approximate).
    pub bytes: Vec<u8>,
    /// Channel-wide energy counts (summed over chips).
    pub counts: EnergyCounts,
    /// Encoding outcome statistics (summed over chips).
    pub stats: EncodeStats,
}

/// Batch simulation of a byte stream under one encoder configuration.
/// `approx` marks the whole stream as error-resilient (the paper
/// approximates only accesses known resilient a priori; instruction-like
/// traffic passes `false` and is never approximated).
pub fn simulate_bytes(cfg: &ZacConfig, bytes: &[u8], approx: bool) -> RunOutput {
    let lines = bytes_to_chip_words(bytes);
    simulate_lines(cfg, &lines, approx, bytes.len())
}

/// Batch simulation over pre-split cache lines.
pub fn simulate_lines(
    cfg: &ZacConfig,
    lines: &[ChipWords],
    approx: bool,
    byte_len: usize,
) -> RunOutput {
    let cfgs: Vec<ZacConfig> = (0..CHIPS).map(|_| cfg.clone()).collect();
    simulate_lines_per_chip(&cfgs, lines, approx, byte_len)
}

/// Batch simulation with a distinct configuration per chip. The DRAM
/// layout interleaves bytes across chips (chip *j* carries byte `j % 4`
/// of every f32, see [`crate::trace`]), so field-aware knobs — e.g. the
/// weights-mode tolerance over sign+exponent — must be expressed
/// per chip. See [`weight_chip_configs`].
pub fn simulate_lines_per_chip(
    cfgs: &[ZacConfig],
    lines: &[ChipWords],
    approx: bool,
    byte_len: usize,
) -> RunOutput {
    assert_eq!(cfgs.len(), CHIPS);
    let per_chip: Vec<(ZacConfig, Vec<u64>)> = (0..CHIPS)
        .map(|j| (cfgs[j].clone(), lines.iter().map(|l| l[j]).collect()))
        .collect();
    let results = crate::util::par::par_map(per_chip, CHIPS, |(cfg, words)| {
        let mut chan = ChipChannel::new();
        let mut stats = EncodeStats::default();
        let approx_flags = vec![approx; words.len()];
        let decoded =
            crate::encoding::run_chip_stream(&cfg, &words, &approx_flags, &mut chan, &mut stats);
        (decoded, *chan.energy(), stats)
    });
    assemble(results, lines.len(), byte_len)
}

/// Derive the per-chip configurations that realize a 32-bit-lane
/// tolerance/truncation mask on the byte-interleaved channel: chip *j*
/// sees byte `j % 4` of every float, so its 64-bit word gets that byte
/// of the lane mask replicated across all 8 beats. For the IEEE-754
/// sign+exponent mask (0xFF80_0000) this pins chips 3/7 entirely (sign +
/// exp[7:1]) and bit 7 of every byte on chips 2/6 (exp[0]).
pub fn weight_chip_configs(base: &ZacConfig) -> Vec<ZacConfig> {
    let lane_mask: u32 = match base.tolerance_mask_override {
        Some(m) => (m & 0xFFFF_FFFF) as u32,
        None => 0xFF80_0000, // default weights mode: sign + exponent
    };
    (0..CHIPS)
        .map(|j| {
            let byte = ((lane_mask >> (8 * (j % 4))) & 0xFF) as u64;
            let mut chip_mask = 0u64;
            for beat in 0..8 {
                chip_mask |= byte << (beat * 8);
            }
            let mut cfg = base.clone();
            cfg.chunk_width = 8;
            cfg.tolerance_bits = 0;
            cfg.truncation_bits = 0;
            cfg.tolerance_mask_override = Some(chip_mask);
            cfg
        })
        .collect()
}

fn assemble(
    results: Vec<(Vec<u64>, EnergyCounts, EncodeStats)>,
    nlines: usize,
    byte_len: usize,
) -> RunOutput {
    let mut counts = EnergyCounts::default();
    let mut stats = EncodeStats::default();
    let mut out_lines = vec![[0u64; CHIPS]; nlines];
    for (j, (decoded, c, s)) in results.into_iter().enumerate() {
        counts.merge(&c);
        stats.merge(&s);
        for (l, w) in decoded.into_iter().enumerate() {
            out_lines[l][j] = w;
        }
    }
    RunOutput {
        bytes: chip_words_to_bytes(&out_lines, byte_len),
        counts,
        stats,
    }
}

/// Simulate an f32 (weight) stream; returns the reconstructed floats.
/// When the config carries a tolerance-mask override (weights mode), it
/// is projected onto the byte-interleaved chips via
/// [`weight_chip_configs`] so sign/exponent protection actually lands on
/// the bytes that hold those fields.
pub fn simulate_f32s(cfg: &ZacConfig, xs: &[f32], approx: bool) -> (Vec<f32>, RunOutput) {
    let bytes = crate::trace::f32s_to_bytes(xs);
    let lines = bytes_to_chip_words(&bytes);
    let out = if cfg.tolerance_mask_override.is_some() {
        let cfgs = weight_chip_configs(cfg);
        simulate_lines_per_chip(&cfgs, &lines, approx, bytes.len())
    } else {
        simulate_lines(cfg, &lines, approx, bytes.len())
    };
    let floats = crate::trace::bytes_to_f32s(&out.bytes);
    (floats, out)
}

/// Streaming pipeline: one worker thread per chip behind a bounded queue.
///
/// `push_line` blocks when a queue is full — backpressure toward the
/// producer, exactly what a memory controller's write queue does.
pub struct Pipeline {
    senders: Vec<SyncSender<(u64, bool)>>,
    workers: Vec<JoinHandle<(Vec<u64>, EnergyCounts, EncodeStats)>>,
    lines_pushed: usize,
}

impl Pipeline {
    /// Spawn the per-chip workers with queue `capacity` (lines).
    pub fn new(cfg: &ZacConfig, capacity: usize) -> Pipeline {
        let mut senders = Vec::with_capacity(CHIPS);
        let mut workers = Vec::with_capacity(CHIPS);
        for _ in 0..CHIPS {
            let (tx, rx): (SyncSender<(u64, bool)>, Receiver<(u64, bool)>) =
                sync_channel(capacity.max(1));
            let cfg = cfg.clone();
            workers.push(std::thread::spawn(move || {
                let (mut enc, mut dec) = make_codec(&cfg);
                let mut chan = ChipChannel::new();
                let mut stats = EncodeStats::default();
                let mut decoded = Vec::new();
                while let Ok((word, approx)) = rx.recv() {
                    let wire = enc.encode(word, approx);
                    chan.transmit(&wire);
                    stats.record(&wire, word);
                    decoded.push(dec.decode(&wire));
                }
                (decoded, *chan.energy(), stats)
            }));
            senders.push(tx);
        }
        Pipeline {
            senders,
            workers,
            lines_pushed: 0,
        }
    }

    /// Enqueue one cache line (blocks when workers are behind).
    pub fn push_line(&mut self, line: ChipWords, approx: bool) {
        for (j, tx) in self.senders.iter().enumerate() {
            tx.send((line[j], approx)).expect("worker died");
        }
        self.lines_pushed += 1;
    }

    /// Number of lines accepted so far.
    pub fn lines_pushed(&self) -> usize {
        self.lines_pushed
    }

    /// Close the queues, join the workers, reassemble the output.
    pub fn finish(self, byte_len: usize) -> RunOutput {
        drop(self.senders);
        let results: Vec<_> = self
            .workers
            .into_iter()
            .map(|w| w.join().expect("worker panicked"))
            .collect();
        assemble(results, self.lines_pushed, byte_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::Scheme;
    use crate::util::rng::Rng;

    fn bytes(n: usize, seed: u64) -> Vec<u8> {
        let mut r = Rng::new(seed);
        // Image-like: slowly varying values.
        let mut v = 128i32;
        (0..n)
            .map(|_| {
                v = (v + (r.below(9) as i32 - 4)).clamp(0, 255);
                v as u8
            })
            .collect()
    }

    #[test]
    fn exact_schemes_preserve_bytes_end_to_end() {
        let data = bytes(4096, 3);
        for scheme in [Scheme::Org, Scheme::Dbi, Scheme::BdeOrg, Scheme::Bde] {
            let out = simulate_bytes(&ZacConfig::scheme(scheme), &data, true);
            assert_eq!(out.bytes, data, "{scheme:?}");
            assert_eq!(out.stats.total(), (data.len() / 8) as u64);
        }
    }

    #[test]
    fn streaming_matches_batch() {
        let data = bytes(8192, 5);
        let cfg = ZacConfig::zac(80);
        let batch = simulate_bytes(&cfg, &data, true);
        let lines = bytes_to_chip_words(&data);
        let mut p = Pipeline::new(&cfg, 4);
        for l in &lines {
            p.push_line(*l, true);
        }
        let streamed = p.finish(data.len());
        assert_eq!(streamed.bytes, batch.bytes);
        assert_eq!(streamed.counts, batch.counts);
        assert_eq!(streamed.stats.total(), batch.stats.total());
    }

    #[test]
    fn zac_saves_energy_vs_bde_on_image_like_stream() {
        let data = bytes(65536, 7);
        let bde = simulate_bytes(&ZacConfig::scheme(Scheme::Bde), &data, true);
        let zac = simulate_bytes(&ZacConfig::zac(70), &data, true);
        let t = zac.counts.termination_savings_vs(&bde.counts);
        assert!(t > 0.0, "zac should save termination energy, got {t}%");
    }

    #[test]
    fn f32_round_trip_exact_scheme() {
        let mut r = Rng::new(11);
        let xs: Vec<f32> = (0..2048).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let (got, _) = simulate_f32s(&ZacConfig::scheme(Scheme::Bde), &xs, true);
        assert_eq!(got, xs);
    }

    #[test]
    fn weights_config_bounds_relative_error() {
        let mut r = Rng::new(13);
        let xs: Vec<f32> = (0..4096).map(|_| r.normal_f32(0.0, 0.05)).collect();
        let (got, out) = simulate_f32s(&ZacConfig::zac_weights(50), &xs, true);
        // Sign+exponent pinned => worst case is a full-mantissa error,
        // i.e. strictly less than 2x in magnitude, never sign flips.
        for (a, b) in xs.iter().zip(&got) {
            assert!(a.signum() == b.signum() || *b == 0.0, "{a} -> {b}");
            assert!(b.abs() < a.abs() * 2.0 + 1e-12, "{a} -> {b}");
        }
        assert!(out.stats.total() > 0);
    }
}
