//! The streaming coordinator: drives whole byte/float traces through the
//! 8-chip channel (encode → wire → decode), aggregating energy and
//! encoding statistics, and reassembling the receiver-side (possibly
//! approximate) stream for the workloads.
//!
//! Two drivers:
//! * [`simulate_bytes`] — batch mode: one worker per DRAM chip via
//!   [`par_map`] (chips are architecturally independent: separate
//!   tables, lines and sidebands).
//! * [`Pipeline`] — streaming mode with bounded per-chip queues
//!   (`sync_channel`), giving real backpressure when a producer outruns
//!   the encoder workers. The multi-channel layer
//!   ([`crate::system`]) reuses this chunked-queue discipline as the
//!   per-shard mailbox of its channel array.
//!
//! Both drivers are batch-first: words move in
//! [`ENCODE_BATCH`](crate::encoding::ENCODE_BATCH)-sized chunks through
//! `encode_batch`/`transmit_batch`/`record_batch`/`decode_batch` over
//! preallocated buffers. The per-chip lane is gathered per batch
//! ([`gather_chip_lane`]) instead of cloning each chip's whole word
//! stream, and the pipeline's queue element is a boxed chunk of lines,
//! amortizing the channel send ~256× versus the old per-word send.

pub mod config;

pub use config::RunConfig;

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;

use crate::channel::{ChipChannel, EnergyCounts, CHIPS};
use crate::encoding::{make_codec, EncodeStats, WireWord, ZacConfig, ENCODE_BATCH};
use crate::trace::{bytes_to_chip_words, chip_words_to_bytes, gather_chip_lane, ChipWords};

/// Result of a trace simulation.
#[derive(Clone, Debug)]
pub struct RunOutput {
    /// The receiver-side byte stream (exact or approximate).
    pub bytes: Vec<u8>,
    /// Channel-wide energy counts (summed over chips).
    pub counts: EnergyCounts,
    /// Encoding outcome statistics (summed over chips).
    pub stats: EncodeStats,
}

/// Batch simulation of a byte stream under one encoder configuration.
/// `approx` marks the whole stream as error-resilient (the paper
/// approximates only accesses known resilient a priori; instruction-like
/// traffic passes `false` and is never approximated).
pub fn simulate_bytes(cfg: &ZacConfig, bytes: &[u8], approx: bool) -> RunOutput {
    let lines = bytes_to_chip_words(bytes);
    simulate_lines(cfg, &lines, approx, bytes.len())
}

/// Batch simulation over pre-split cache lines.
pub fn simulate_lines(
    cfg: &ZacConfig,
    lines: &[ChipWords],
    approx: bool,
    byte_len: usize,
) -> RunOutput {
    let cfgs: Vec<ZacConfig> = (0..CHIPS).map(|_| cfg.clone()).collect();
    simulate_lines_per_chip(&cfgs, lines, approx, byte_len)
}

/// Batch simulation with a distinct configuration per chip. The DRAM
/// layout interleaves bytes across chips (chip *j* carries byte `j % 4`
/// of every f32, see [`crate::trace`]), so field-aware knobs — e.g. the
/// weights-mode tolerance over sign+exponent — must be expressed
/// per chip. See [`weight_chip_configs`].
pub fn simulate_lines_per_chip(
    cfgs: &[ZacConfig],
    lines: &[ChipWords],
    approx: bool,
    byte_len: usize,
) -> RunOutput {
    assert_eq!(cfgs.len(), CHIPS);
    // One worker per chip over the shared line matrix: each batch
    // gathers its lane into a fixed buffer — no per-chip clone of the
    // whole stream, no per-chip approx-flag Vec.
    let results = crate::util::par::par_map((0..CHIPS).collect(), CHIPS, |j| {
        let (mut enc, mut dec) = make_codec(&cfgs[j]);
        let mut chan = ChipChannel::new();
        let mut stats = EncodeStats::default();
        let mut decoded = Vec::with_capacity(lines.len());
        let mut words = [0u64; ENCODE_BATCH];
        let mut wires = [WireWord::raw(0); ENCODE_BATCH];
        let flags = [approx; ENCODE_BATCH];
        for chunk in lines.chunks(ENCODE_BATCH) {
            let n = chunk.len();
            gather_chip_lane(chunk, j, &mut words[..n]);
            enc.encode_batch(&words[..n], &flags[..n], &mut wires[..n]);
            chan.transmit_batch(&wires[..n]);
            stats.record_batch(&wires[..n], &words[..n]);
            dec.decode_batch(&wires[..n], &mut decoded);
        }
        (decoded, *chan.energy(), stats)
    });
    assemble(results, lines.len(), byte_len)
}

/// Derive the per-chip configurations that realize a 32-bit-lane
/// tolerance/truncation mask on the byte-interleaved channel: chip *j*
/// sees byte `j % 4` of every float, so its 64-bit word gets that byte
/// of the lane mask replicated across all 8 beats. For the IEEE-754
/// sign+exponent mask (0xFF80_0000) this pins chips 3/7 entirely (sign +
/// exp[7:1]) and bit 7 of every byte on chips 2/6 (exp[0]).
pub fn weight_chip_configs(base: &ZacConfig) -> Vec<ZacConfig> {
    let lane_mask: u32 = match base.tolerance_mask_override {
        Some(m) => (m & 0xFFFF_FFFF) as u32,
        None => 0xFF80_0000, // default weights mode: sign + exponent
    };
    (0..CHIPS)
        .map(|j| {
            let byte = ((lane_mask >> (8 * (j % 4))) & 0xFF) as u64;
            let mut chip_mask = 0u64;
            for beat in 0..8 {
                chip_mask |= byte << (beat * 8);
            }
            let mut cfg = base.clone();
            cfg.chunk_width = 8;
            cfg.tolerance_bits = 0;
            cfg.truncation_bits = 0;
            cfg.tolerance_mask_override = Some(chip_mask);
            cfg
        })
        .collect()
}

fn assemble(
    results: Vec<(Vec<u64>, EnergyCounts, EncodeStats)>,
    nlines: usize,
    byte_len: usize,
) -> RunOutput {
    let mut counts = EnergyCounts::default();
    let mut stats = EncodeStats::default();
    let mut out_lines = vec![[0u64; CHIPS]; nlines];
    for (j, (decoded, c, s)) in results.into_iter().enumerate() {
        counts.merge(&c);
        stats.merge(&s);
        for (l, w) in decoded.into_iter().enumerate() {
            out_lines[l][j] = w;
        }
    }
    RunOutput {
        bytes: chip_words_to_bytes(&out_lines, byte_len),
        counts,
        stats,
    }
}

/// Simulate an f32 (weight) stream; returns the reconstructed floats.
/// When the config carries a tolerance-mask override (weights mode), it
/// is projected onto the byte-interleaved chips via
/// [`weight_chip_configs`] so sign/exponent protection actually lands on
/// the bytes that hold those fields.
pub fn simulate_f32s(cfg: &ZacConfig, xs: &[f32], approx: bool) -> (Vec<f32>, RunOutput) {
    let bytes = crate::trace::f32s_to_bytes(xs);
    let lines = bytes_to_chip_words(&bytes);
    let out = if cfg.tolerance_mask_override.is_some() {
        let cfgs = weight_chip_configs(cfg);
        simulate_lines_per_chip(&cfgs, &lines, approx, bytes.len())
    } else {
        simulate_lines(cfg, &lines, approx, bytes.len())
    };
    let floats = crate::trace::bytes_to_f32s(&out.bytes);
    (floats, out)
}

/// One queue element: a chip's words for up to [`ENCODE_BATCH`] lines
/// plus the matching approx flags, boxed so the channel moves two
/// pointers instead of per-word tuples.
type LineChunk = (Box<[u64]>, Box<[bool]>);

/// Streaming pipeline: one worker thread per chip behind a bounded queue.
///
/// `push_line` blocks when the chunk queue is full — backpressure toward
/// the producer, exactly what a memory controller's write queue does.
/// Lines accumulate in a pending buffer and ship as boxed
/// [`ENCODE_BATCH`]-line chunks, so the `sync_channel` send/recv
/// overhead amortizes ~256× and the workers run the batch codec path.
/// Note the granularity change vs the per-word queue: backpressure now
/// engages at whole-chunk boundaries, so a producer can run up to
/// `capacity.div_ceil(ENCODE_BATCH) * ENCODE_BATCH` queued lines plus
/// one partially-filled pending chunk ahead of the workers.
pub struct Pipeline {
    senders: Vec<SyncSender<LineChunk>>,
    workers: Vec<JoinHandle<(Vec<u64>, EnergyCounts, EncodeStats)>>,
    /// Per-chip words awaiting the next chunk flush.
    pending: Vec<Vec<u64>>,
    /// Approx flags for the pending lines (shared across chips).
    pending_approx: Vec<bool>,
    lines_pushed: usize,
}

impl Pipeline {
    /// Spawn the per-chip workers with queue `capacity` (in lines;
    /// rounded up to whole chunks).
    pub fn new(cfg: &ZacConfig, capacity: usize) -> Pipeline {
        let chunk_capacity = capacity.div_ceil(ENCODE_BATCH).max(1);
        let mut senders = Vec::with_capacity(CHIPS);
        let mut workers = Vec::with_capacity(CHIPS);
        for _ in 0..CHIPS {
            let (tx, rx): (SyncSender<LineChunk>, Receiver<LineChunk>) =
                sync_channel(chunk_capacity);
            let cfg = cfg.clone();
            workers.push(std::thread::spawn(move || {
                let (mut enc, mut dec) = make_codec(&cfg);
                let mut chan = ChipChannel::new();
                let mut stats = EncodeStats::default();
                let mut decoded = Vec::new();
                let mut wires = [WireWord::raw(0); ENCODE_BATCH];
                while let Ok((words, approx)) = rx.recv() {
                    for (wc, ac) in words.chunks(ENCODE_BATCH).zip(approx.chunks(ENCODE_BATCH)) {
                        let buf = &mut wires[..wc.len()];
                        enc.encode_batch(wc, ac, buf);
                        chan.transmit_batch(buf);
                        stats.record_batch(buf, wc);
                        dec.decode_batch(buf, &mut decoded);
                    }
                }
                (decoded, *chan.energy(), stats)
            }));
            senders.push(tx);
        }
        Pipeline {
            senders,
            workers,
            pending: (0..CHIPS).map(|_| Vec::with_capacity(ENCODE_BATCH)).collect(),
            pending_approx: Vec::with_capacity(ENCODE_BATCH),
            lines_pushed: 0,
        }
    }

    /// Enqueue one cache line (blocks when workers are behind and the
    /// chunk queues are full).
    pub fn push_line(&mut self, line: ChipWords, approx: bool) {
        for (words, &w) in self.pending.iter_mut().zip(line.iter()) {
            words.push(w);
        }
        self.pending_approx.push(approx);
        self.lines_pushed += 1;
        if self.pending_approx.len() == ENCODE_BATCH {
            self.flush();
        }
    }

    /// Ship the pending lines to the workers as one boxed chunk per chip.
    fn flush(&mut self) {
        if self.pending_approx.is_empty() {
            return;
        }
        let approx: Box<[bool]> = self.pending_approx.as_slice().into();
        self.pending_approx.clear();
        for (tx, words) in self.senders.iter().zip(self.pending.iter_mut()) {
            let chunk = std::mem::replace(words, Vec::with_capacity(ENCODE_BATCH));
            // A failed send means that chip's worker died (receiver
            // dropped mid-panic). Don't panic here: keep feeding the
            // healthy workers so their queues drain, and let `finish`
            // join everyone and surface the original panic.
            let _ = tx.send((chunk.into_boxed_slice(), approx.clone()));
        }
    }

    /// Number of lines accepted so far.
    pub fn lines_pushed(&self) -> usize {
        self.lines_pushed
    }

    /// Close the queues, join the workers, reassemble the output.
    ///
    /// Panic path: every worker is joined (drained) before any panic is
    /// surfaced, then the *original* worker panic payload is re-raised
    /// — one dying chip worker can neither leak its siblings' threads
    /// nor mask its own root cause behind a generic join error.
    pub fn finish(mut self, byte_len: usize) -> RunOutput {
        self.flush();
        let Pipeline {
            senders,
            workers,
            lines_pushed,
            ..
        } = self;
        drop(senders);
        let results = crate::util::par::join_all_reraise(workers);
        assemble(results, lines_pushed, byte_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::Scheme;
    use crate::util::rng::Rng;

    fn bytes(n: usize, seed: u64) -> Vec<u8> {
        let mut r = Rng::new(seed);
        // Image-like: slowly varying values.
        let mut v = 128i32;
        (0..n)
            .map(|_| {
                v = (v + (r.below(9) as i32 - 4)).clamp(0, 255);
                v as u8
            })
            .collect()
    }

    #[test]
    fn exact_schemes_preserve_bytes_end_to_end() {
        let data = bytes(4096, 3);
        for scheme in [Scheme::Org, Scheme::Dbi, Scheme::BdeOrg, Scheme::Bde] {
            let out = simulate_bytes(&ZacConfig::scheme(scheme), &data, true);
            assert_eq!(out.bytes, data, "{scheme:?}");
            assert_eq!(out.stats.total(), (data.len() / 8) as u64);
        }
    }

    #[test]
    fn streaming_matches_batch() {
        let data = bytes(8192, 5);
        let cfg = ZacConfig::zac(80);
        let batch = simulate_bytes(&cfg, &data, true);
        let lines = bytes_to_chip_words(&data);
        let mut p = Pipeline::new(&cfg, 4);
        for l in &lines {
            p.push_line(*l, true);
        }
        let streamed = p.finish(data.len());
        assert_eq!(streamed.bytes, batch.bytes);
        assert_eq!(streamed.counts, batch.counts);
        assert_eq!(streamed.stats.total(), batch.stats.total());
    }

    #[test]
    fn streaming_matches_batch_across_chunk_boundaries() {
        // 300 lines + a partial tail line: one full 256-line chunk, a
        // 44-line remainder flush, and zero-padding — all boundary cases
        // of the chunked queue at once.
        let data = bytes(300 * 64 + 32, 15);
        let cfg = ZacConfig::zac_full(75, 1, 1);
        let batch = simulate_bytes(&cfg, &data, true);
        let lines = bytes_to_chip_words(&data);
        let mut p = Pipeline::new(&cfg, 1);
        for l in &lines {
            p.push_line(*l, true);
        }
        assert_eq!(p.lines_pushed(), lines.len());
        let streamed = p.finish(data.len());
        assert_eq!(streamed.bytes, batch.bytes);
        assert_eq!(streamed.counts, batch.counts);
        assert_eq!(streamed.stats.total(), batch.stats.total());
    }

    #[test]
    fn zac_saves_energy_vs_bde_on_image_like_stream() {
        let data = bytes(65536, 7);
        let bde = simulate_bytes(&ZacConfig::scheme(Scheme::Bde), &data, true);
        let zac = simulate_bytes(&ZacConfig::zac(70), &data, true);
        let t = zac.counts.termination_savings_vs(&bde.counts);
        assert!(t > 0.0, "zac should save termination energy, got {t}%");
    }

    #[test]
    fn f32_round_trip_exact_scheme() {
        let mut r = Rng::new(11);
        let xs: Vec<f32> = (0..2048).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let (got, _) = simulate_f32s(&ZacConfig::scheme(Scheme::Bde), &xs, true);
        assert_eq!(got, xs);
    }

    #[test]
    fn weights_config_bounds_relative_error() {
        let mut r = Rng::new(13);
        let xs: Vec<f32> = (0..4096).map(|_| r.normal_f32(0.0, 0.05)).collect();
        let (got, out) = simulate_f32s(&ZacConfig::zac_weights(50), &xs, true);
        // Sign+exponent pinned => worst case is a full-mantissa error,
        // i.e. strictly less than 2x in magnitude, never sign flips.
        for (a, b) in xs.iter().zip(&got) {
            assert!(a.signum() == b.signum() || *b == 0.0, "{a} -> {b}");
            assert!(b.abs() < a.abs() * 2.0 + 1e-12, "{a} -> {b}");
        }
        assert!(out.stats.total() > 0);
    }
}
