//! The single-channel coordinator: the shared batch engine
//! ([`drive_lines`]) behind [`Session`](crate::session::Session)'s
//! batch execution, plus the v1 free-function drivers kept as thin
//! deprecated shims.
//!
//! v2 layering (see `ARCHITECTURE.md`):
//!
//! * [`Session`](crate::session::Session) is the public entry point —
//!   codec specs resolve through the
//!   [`CodecRegistry`](crate::encoding::CodecRegistry) and every
//!   execution strategy funnels into the one
//!   [`ChipLane`](crate::encoding::ChipLane) drive loop.
//! * [`drive_lines`] here is the batch engine: one worker per DRAM chip
//!   via [`par_map`](crate::util::par::par_map) (chips are
//!   architecturally independent: separate tables, lines, sidebands),
//!   per-batch lane gather ([`gather_chip_lane`]) instead of per-chip
//!   stream clones.
//! * [`Pipeline`] is the streaming engine: bounded per-chip queues
//!   (`sync_channel`) of reference-counted
//!   [`LineChunk`](crate::trace::LineChunk) views (up to
//!   [`ENCODE_BATCH`] lines each), giving real backpressure when a
//!   producer outruns the encoder workers without copying line data per
//!   chip. The multi-channel [`crate::system`] array reuses this
//!   chunked-queue discipline per shard.
//!
//! **Deprecated shims** (prefer `Session`): [`simulate_bytes`],
//! [`simulate_lines`], [`simulate_lines_per_chip`], [`simulate_f32s`].
//! They stay pinned bit-identical to `Session` runs by the property
//! tests in `rust/tests/integration.rs`.

pub mod config;

pub use config::RunConfig;

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::channel::{EnergyCounts, CHIPS};
use crate::encoding::{ChipLane, Codec, EncodeStats, ZacConfig, ENCODE_BATCH};
use crate::faults::{FaultSpec, FaultStats};
use crate::obs::StageSet;
use crate::trace::{
    bytes_to_chip_words, chip_words_to_bytes, gather_chip_lane, ChipWords, LineChunk,
};

/// Result of a trace simulation.
#[derive(Clone, Debug)]
pub struct RunOutput {
    /// The receiver-side byte stream (exact or approximate).
    pub bytes: Vec<u8>,
    /// Channel-wide energy counts (summed over chips).
    pub counts: EnergyCounts,
    /// Encoding outcome statistics (summed over chips).
    pub stats: EncodeStats,
    /// Fault-injection + end-to-end error statistics (summed over
    /// chips; all-zero injection under a perfect channel).
    pub faults: FaultStats,
}

/// **Deprecated shim** — batch simulation of a byte stream under one
/// legacy config. Prefer [`Session`](crate::session::Session). `approx`
/// marks the whole stream as error-resilient (the paper approximates
/// only accesses known resilient a priori; instruction-like traffic
/// passes `false` and is never approximated).
pub fn simulate_bytes(cfg: &ZacConfig, bytes: &[u8], approx: bool) -> RunOutput {
    let lines = bytes_to_chip_words(bytes);
    simulate_lines(cfg, &lines, approx, bytes.len())
}

/// **Deprecated shim** — batch simulation over pre-split cache lines.
/// Prefer [`Session`](crate::session::Session).
pub fn simulate_lines(
    cfg: &ZacConfig,
    lines: &[ChipWords],
    approx: bool,
    byte_len: usize,
) -> RunOutput {
    let cfgs: Vec<ZacConfig> = (0..CHIPS).map(|_| cfg.clone()).collect();
    simulate_lines_per_chip(&cfgs, lines, approx, byte_len)
}

/// **Deprecated shim** — batch simulation with a distinct configuration
/// per chip. Prefer `Session::builder().codec_per_chip(...)`. The DRAM
/// layout interleaves bytes across chips (chip *j* carries byte `j % 4`
/// of every f32, see [`crate::trace`]), so field-aware knobs — e.g. the
/// weights-mode tolerance over sign+exponent — must be expressed
/// per chip. See [`weight_chip_configs`].
pub fn simulate_lines_per_chip(
    cfgs: &[ZacConfig],
    lines: &[ChipWords],
    approx: bool,
    byte_len: usize,
) -> RunOutput {
    assert_eq!(cfgs.len(), CHIPS);
    drive_lines(
        cfgs.iter().map(Codec::from_config).collect(),
        lines,
        approx,
        byte_len,
        &FaultSpec::perfect(),
        None,
    )
}

/// The shared batch engine: one worker per chip over the shared line
/// matrix, each batch gathering its lane into a fixed buffer (no
/// per-chip clone of the whole stream) and running the one
/// [`ChipLane`] drive loop with its per-chip fault model. Both the
/// legacy shims above (perfect channel) and
/// [`Session`](crate::session::Session) batch execution land here.
pub(crate) fn drive_lines(
    codecs: Vec<Codec>,
    lines: &[ChipWords],
    approx: bool,
    byte_len: usize,
    fault_spec: &FaultSpec,
    stages: Option<Arc<StageSet>>,
) -> RunOutput {
    assert_eq!(codecs.len(), CHIPS);
    let chips: Vec<(usize, Codec, Box<dyn crate::faults::FaultModel>)> = codecs
        .into_iter()
        .enumerate()
        .map(|(j, codec)| (j, codec, fault_spec.build(0, j)))
        .collect();
    let results = crate::util::par::par_map(chips, CHIPS, move |(j, codec, faults)| {
        let mut lane = ChipLane::with_faults(codec, lines.len(), faults);
        if let Some(set) = &stages {
            lane.instrument(set.clone());
        }
        let mut words = [0u64; ENCODE_BATCH];
        let flags = [approx; ENCODE_BATCH];
        for chunk in lines.chunks(ENCODE_BATCH) {
            let n = chunk.len();
            gather_chip_lane(chunk, j, &mut words[..n]);
            lane.drive(&words[..n], &flags[..n]);
        }
        lane.finish()
    });
    assemble(results, lines.len(), byte_len)
}

/// Derive the per-chip configurations that realize a 32-bit-lane
/// tolerance/truncation mask on the byte-interleaved channel: chip *j*
/// sees byte `j % 4` of every float, so its 64-bit word gets that byte
/// of the lane mask replicated across all 8 beats. For the IEEE-754
/// sign+exponent mask (0xFF80_0000) this pins chips 3/7 entirely (sign +
/// exp[7:1]) and bit 7 of every byte on chips 2/6 (exp[0]).
pub fn weight_chip_configs(base: &ZacConfig) -> Vec<ZacConfig> {
    let lane_mask: u32 = match base.tolerance_mask_override {
        Some(m) => (m & 0xFFFF_FFFF) as u32,
        None => 0xFF80_0000, // default weights mode: sign + exponent
    };
    (0..CHIPS)
        .map(|j| {
            let byte = ((lane_mask >> (8 * (j % 4))) & 0xFF) as u64;
            let mut chip_mask = 0u64;
            for beat in 0..8 {
                chip_mask |= byte << (beat * 8);
            }
            let mut cfg = base.clone();
            cfg.chunk_width = 8;
            cfg.tolerance_bits = 0;
            cfg.truncation_bits = 0;
            cfg.tolerance_mask_override = Some(chip_mask);
            cfg
        })
        .collect()
}

fn assemble(
    results: Vec<(Vec<u64>, EnergyCounts, EncodeStats, FaultStats)>,
    nlines: usize,
    byte_len: usize,
) -> RunOutput {
    let mut counts = EnergyCounts::default();
    let mut stats = EncodeStats::default();
    let mut faults = FaultStats::default();
    let mut out_lines = vec![[0u64; CHIPS]; nlines];
    for (j, (decoded, c, s, f)) in results.into_iter().enumerate() {
        counts.merge(&c);
        stats.merge(&s);
        faults.merge(&f);
        for (l, w) in decoded.into_iter().enumerate() {
            out_lines[l][j] = w;
        }
    }
    RunOutput {
        bytes: chip_words_to_bytes(&out_lines, byte_len),
        counts,
        stats,
        faults,
    }
}

/// **Deprecated shim** — simulate an f32 (weight) stream; returns the
/// reconstructed floats. Prefer `Session::builder().codec_weights(...)`
/// with [`Trace::from_f32s`](crate::session::Trace::from_f32s). When the
/// config carries a tolerance-mask override (weights mode), it is
/// projected onto the byte-interleaved chips via [`weight_chip_configs`]
/// so sign/exponent protection actually lands on the bytes that hold
/// those fields.
pub fn simulate_f32s(cfg: &ZacConfig, xs: &[f32], approx: bool) -> (Vec<f32>, RunOutput) {
    let bytes = crate::trace::f32s_to_bytes(xs);
    let lines = bytes_to_chip_words(&bytes);
    let out = if cfg.tolerance_mask_override.is_some() {
        let cfgs = weight_chip_configs(cfg);
        simulate_lines_per_chip(&cfgs, &lines, approx, bytes.len())
    } else {
        simulate_lines(cfg, &lines, approx, bytes.len())
    };
    let floats = crate::trace::bytes_to_f32s(&out.bytes);
    (floats, out)
}

/// Streaming pipeline: one worker thread per chip behind a bounded queue.
///
/// `push_line` blocks when the chunk queue is full — backpressure toward
/// the producer, exactly what a memory controller's write queue does.
/// Lines accumulate in one shared pending buffer and ship as a single
/// reference-counted [`LineChunk`] that all 8 chip workers view (the
/// zero-copy currency: one Arc allocation per chunk instead of 8 boxed
/// per-chip copies; each worker gathers its own lane straight from the
/// shared lines). Bulk callers skip even that one allocation with
/// [`push_chunk`](Pipeline::push_chunk), shipping borrowed windows of
/// the trace store. The `sync_channel` send/recv overhead amortizes
/// ~256× and the workers run the batch codec path. Note the granularity
/// vs a per-word queue: backpressure engages at whole-chunk boundaries,
/// so a producer can run up to
/// `capacity.div_ceil(ENCODE_BATCH) * ENCODE_BATCH` queued lines plus
/// one partially-filled pending chunk ahead of the workers.
pub struct Pipeline {
    senders: Vec<SyncSender<LineChunk>>,
    workers: Vec<JoinHandle<(Vec<u64>, EnergyCounts, EncodeStats, FaultStats)>>,
    /// Lines awaiting the next chunk flush (shared across chips).
    pending: Vec<ChipWords>,
    /// Approx flags for the pending lines.
    pending_approx: Vec<bool>,
    lines_pushed: usize,
}

impl Pipeline {
    /// Spawn the per-chip workers for a legacy config with queue
    /// `capacity` (in lines; rounded up to whole chunks).
    pub fn new(cfg: &ZacConfig, capacity: usize) -> Pipeline {
        Self::with_codecs(
            (0..CHIPS).map(|_| Codec::from_config(cfg)).collect(),
            capacity,
        )
    }

    /// Spawn the per-chip workers around pre-built codecs (one per
    /// chip) over a perfect channel — the registry-driven construction
    /// path legacy callers use for pipelined runs.
    pub fn with_codecs(codecs: Vec<Codec>, capacity: usize) -> Pipeline {
        Self::with_codecs_and_faults(codecs, capacity, &FaultSpec::perfect())
    }

    /// Spawn the per-chip workers with each chip's wire running through
    /// the fault model `fault_spec` describes — what
    /// [`Session`](crate::session::Session) uses for pipelined runs.
    pub fn with_codecs_and_faults(
        codecs: Vec<Codec>,
        capacity: usize,
        fault_spec: &FaultSpec,
    ) -> Pipeline {
        Self::with_codecs_faults_and_stages(codecs, capacity, fault_spec, None)
    }

    /// Fully-general constructor: like
    /// [`with_codecs_and_faults`](Self::with_codecs_and_faults), with
    /// an optional telemetry stage set shared by the chip workers.
    pub fn with_codecs_faults_and_stages(
        codecs: Vec<Codec>,
        capacity: usize,
        fault_spec: &FaultSpec,
        stages: Option<Arc<StageSet>>,
    ) -> Pipeline {
        assert_eq!(codecs.len(), CHIPS, "pipeline needs one codec per chip");
        let chunk_capacity = capacity.div_ceil(ENCODE_BATCH).max(1);
        let mut senders = Vec::with_capacity(CHIPS);
        let mut workers = Vec::with_capacity(CHIPS);
        for (j, codec) in codecs.into_iter().enumerate() {
            let faults = fault_spec.build(0, j);
            let stages = stages.clone();
            let (tx, rx): (SyncSender<LineChunk>, Receiver<LineChunk>) =
                sync_channel(chunk_capacity);
            workers.push(std::thread::spawn(move || {
                let mut lane = ChipLane::with_faults(codec, 0, faults);
                if let Some(set) = stages {
                    lane.instrument(set);
                }
                while let Ok(chunk) = rx.recv() {
                    lane.drive_chunk(j, &chunk);
                }
                lane.finish()
            }));
            senders.push(tx);
        }
        Pipeline {
            senders,
            workers,
            pending: Vec::with_capacity(ENCODE_BATCH),
            pending_approx: Vec::with_capacity(ENCODE_BATCH),
            lines_pushed: 0,
        }
    }

    /// Enqueue one cache line (blocks when workers are behind and the
    /// chunk queues are full). Copies the line into the pending buffer —
    /// the streaming path; bulk callers should prefer the zero-copy
    /// [`push_chunk`](Self::push_chunk).
    pub fn push_line(&mut self, line: ChipWords, approx: bool) {
        self.pending.push(line);
        self.pending_approx.push(approx);
        self.lines_pushed += 1;
        if self.pending_approx.len() == ENCODE_BATCH {
            self.flush();
        }
    }

    /// Enqueue a reference-counted chunk view directly — the zero-copy
    /// bulk path [`Session`](crate::session::Session) streams trace
    /// windows through. Any pending `push_line` lines flush first so
    /// ordering is preserved.
    pub fn push_chunk(&mut self, chunk: LineChunk) {
        self.flush();
        if chunk.is_empty() {
            return;
        }
        self.lines_pushed += chunk.len();
        self.send_to_all(chunk);
    }

    /// Ship the pending lines as one shared chunk viewed by every chip
    /// worker.
    fn flush(&mut self) {
        if self.pending_approx.is_empty() {
            return;
        }
        let lines = std::mem::replace(&mut self.pending, Vec::with_capacity(ENCODE_BATCH));
        let flags =
            std::mem::replace(&mut self.pending_approx, Vec::with_capacity(ENCODE_BATCH));
        self.send_to_all(LineChunk::from_lines(lines, flags));
    }

    /// Send refcounted clones of one chunk to all chip workers. A failed
    /// send means that chip's worker died (receiver dropped mid-panic):
    /// stop accepting lines, join every worker and re-raise the original
    /// panic right here at the call site instead of silently dropping
    /// the chunk.
    fn send_to_all(&mut self, chunk: LineChunk) {
        let dead = self
            .senders
            .iter()
            .any(|tx| tx.send(chunk.clone()).is_err());
        if dead {
            self.senders.clear();
            let workers = std::mem::take(&mut self.workers);
            crate::util::par::join_all_reraise(workers);
            panic!("pipeline worker exited without panicking (queue closed)");
        }
    }

    /// Number of lines accepted so far.
    pub fn lines_pushed(&self) -> usize {
        self.lines_pushed
    }

    /// Close the queues, join the workers, reassemble the output.
    ///
    /// Panic path: every worker is joined (drained) before any panic is
    /// surfaced, then the *original* worker panic payload is re-raised
    /// — one dying chip worker can neither leak its siblings' threads
    /// nor mask its own root cause behind a generic join error.
    pub fn finish(mut self, byte_len: usize) -> RunOutput {
        self.flush();
        let Pipeline {
            senders,
            workers,
            lines_pushed,
            ..
        } = self;
        drop(senders);
        let results = crate::util::par::join_all_reraise(workers);
        assemble(results, lines_pushed, byte_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::Scheme;
    use crate::util::rng::Rng;

    fn bytes(n: usize, seed: u64) -> Vec<u8> {
        let mut r = Rng::new(seed);
        // Image-like: slowly varying values.
        let mut v = 128i32;
        (0..n)
            .map(|_| {
                v = (v + (r.below(9) as i32 - 4)).clamp(0, 255);
                v as u8
            })
            .collect()
    }

    #[test]
    fn exact_schemes_preserve_bytes_end_to_end() {
        let data = bytes(4096, 3);
        for scheme in [Scheme::Org, Scheme::Dbi, Scheme::BdeOrg, Scheme::Bde] {
            let out = simulate_bytes(&ZacConfig::scheme(scheme), &data, true);
            assert_eq!(out.bytes, data, "{scheme:?}");
            assert_eq!(out.stats.total(), (data.len() / 8) as u64);
        }
    }

    #[test]
    fn streaming_matches_batch() {
        let data = bytes(8192, 5);
        let cfg = ZacConfig::zac(80);
        let batch = simulate_bytes(&cfg, &data, true);
        let lines = bytes_to_chip_words(&data);
        let mut p = Pipeline::new(&cfg, 4);
        for l in &lines {
            p.push_line(*l, true);
        }
        let streamed = p.finish(data.len());
        assert_eq!(streamed.bytes, batch.bytes);
        assert_eq!(streamed.counts, batch.counts);
        assert_eq!(streamed.stats.total(), batch.stats.total());
    }

    #[test]
    fn streaming_matches_batch_across_chunk_boundaries() {
        // 300 lines + a partial tail line: one full 256-line chunk, a
        // 44-line remainder flush, and zero-padding — all boundary cases
        // of the chunked queue at once.
        let data = bytes(300 * 64 + 32, 15);
        let cfg = ZacConfig::zac_full(75, 1, 1);
        let batch = simulate_bytes(&cfg, &data, true);
        let lines = bytes_to_chip_words(&data);
        let mut p = Pipeline::new(&cfg, 1);
        for l in &lines {
            p.push_line(*l, true);
        }
        assert_eq!(p.lines_pushed(), lines.len());
        let streamed = p.finish(data.len());
        assert_eq!(streamed.bytes, batch.bytes);
        assert_eq!(streamed.counts, batch.counts);
        assert_eq!(streamed.stats.total(), batch.stats.total());
    }

    #[test]
    fn push_chunk_windows_match_push_line_streaming() {
        use std::sync::Arc;
        // The zero-copy window path (what Session pipelined execution
        // ships) must be bit-identical to per-line streaming.
        let data = bytes(350 * 64 + 24, 17);
        let cfg = ZacConfig::zac_full(75, 1, 0);
        let lines = bytes_to_chip_words(&data);
        let mut by_line = Pipeline::new(&cfg, 4);
        for l in &lines {
            by_line.push_line(*l, true);
        }
        let want = by_line.finish(data.len());

        let store: Arc<[ChipWords]> = lines.into();
        let mut by_chunk = Pipeline::new(&cfg, 4);
        let mut pos = 0;
        // Irregular window sizes, including one spanning several
        // ENCODE_BATCH batches and an interleaved push_line.
        for span in [300usize, 1, 0, 40] {
            by_chunk.push_chunk(LineChunk::window(store.clone(), pos, span, true));
            pos += span;
        }
        while pos < store.len() {
            by_chunk.push_line(store[pos], true);
            pos += 1;
        }
        assert_eq!(by_chunk.lines_pushed(), want_lines(&data));
        let got = by_chunk.finish(data.len());
        assert_eq!(got.bytes, want.bytes);
        assert_eq!(got.counts, want.counts);
        assert_eq!(got.stats, want.stats);
    }

    fn want_lines(data: &[u8]) -> usize {
        data.len().div_ceil(64)
    }

    #[test]
    fn dead_pipeline_worker_panic_surfaces_at_the_push_site() {
        use crate::encoding::{ChipDecoder, ChipEncoder, Scheme, WireWord};
        struct BoomEncoder;
        impl ChipEncoder for BoomEncoder {
            fn encode(&mut self, _word: u64, _approx: bool) -> WireWord {
                panic!("pipeline worker boom");
            }
            fn scheme(&self) -> Scheme {
                Scheme::Org
            }
            fn reset(&mut self) {}
        }
        struct NopDecoder;
        impl ChipDecoder for NopDecoder {
            fn decode(&mut self, wire: &WireWord) -> u64 {
                wire.data
            }
            fn reset(&mut self) {}
        }
        let codecs = (0..CHIPS)
            .map(|_| Codec::new(Box::new(BoomEncoder), Box::new(NopDecoder)))
            .collect();
        let mut p = Pipeline::with_codecs(codecs, 1);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            for i in 0..64 * ENCODE_BATCH {
                p.push_line([i as u64; CHIPS], true);
            }
            p.finish(0);
        }));
        let payload = caught.expect_err("dead worker must surface a panic");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .map(String::from)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("pipeline worker boom"), "payload: {msg:?}");
    }

    #[test]
    fn zac_saves_energy_vs_bde_on_image_like_stream() {
        let data = bytes(65536, 7);
        let bde = simulate_bytes(&ZacConfig::scheme(Scheme::Bde), &data, true);
        let zac = simulate_bytes(&ZacConfig::zac(70), &data, true);
        let t = zac.counts.termination_savings_vs(&bde.counts);
        assert!(t > 0.0, "zac should save termination energy, got {t}%");
    }

    #[test]
    fn f32_round_trip_exact_scheme() {
        let mut r = Rng::new(11);
        let xs: Vec<f32> = (0..2048).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let (got, _) = simulate_f32s(&ZacConfig::scheme(Scheme::Bde), &xs, true);
        assert_eq!(got, xs);
    }

    #[test]
    fn weights_config_bounds_relative_error() {
        let mut r = Rng::new(13);
        let xs: Vec<f32> = (0..4096).map(|_| r.normal_f32(0.0, 0.05)).collect();
        let (got, out) = simulate_f32s(&ZacConfig::zac_weights(50), &xs, true);
        // Sign+exponent pinned => worst case is a full-mantissa error,
        // i.e. strictly less than 2x in magnitude, never sign flips.
        for (a, b) in xs.iter().zip(&got) {
            assert!(a.signum() == b.signum() || *b == 0.0, "{a} -> {b}");
            assert!(b.abs() < a.abs() * 2.0 + 1e-12, "{a} -> {b}");
        }
        assert!(out.stats.total() > 0);
    }

    #[test]
    fn prop_weight_chip_masks_reassemble_the_lane_mask_exactly() {
        // Chip j carries byte j % 4 of every f32, so the four distinct
        // per-chip masks must (a) replicate their lane byte across all 8
        // beats, (b) reassemble the 32-bit lane mask exactly — every
        // lane bit covered once across chips 0..4 — and (c) repeat for
        // the mirror chips 4..8.
        crate::util::prop::check(
            "weight_chip_configs masks reassemble the lane mask",
            106,
            |r| vec![r.next_u64()],
            |v| {
                let lane_mask = (v[0] & 0xFFFF_FFFF) as u32;
                let mut base = ZacConfig::zac_weights(60);
                base.tolerance_mask_override = Some(lane_mask as u64);
                let cfgs = weight_chip_configs(&base);
                if cfgs.len() != CHIPS {
                    return Err(format!("{} configs for {CHIPS} chips", cfgs.len()));
                }
                let mut reassembled = 0u32;
                for (j, cfg) in cfgs.iter().enumerate() {
                    let m = cfg
                        .tolerance_mask_override
                        .ok_or_else(|| format!("chip {j}: override dropped"))?;
                    let want_byte = ((lane_mask >> (8 * (j % 4))) & 0xFF) as u64;
                    for beat in 0..8 {
                        let got = (m >> (beat * 8)) & 0xFF;
                        if got != want_byte {
                            return Err(format!(
                                "chip {j} beat {beat}: {got:#04x} != {want_byte:#04x}"
                            ));
                        }
                    }
                    cfg.validate().map_err(|e| format!("chip {j}: {e}"))?;
                    if j < 4 {
                        reassembled |= ((m & 0xFF) as u32) << (8 * j);
                    } else if cfg.tolerance_mask_override != cfgs[j - 4].tolerance_mask_override {
                        return Err(format!("chip {j} differs from its mirror chip {}", j - 4));
                    }
                }
                if reassembled != lane_mask {
                    return Err(format!(
                        "reassembled {reassembled:#010x} != lane mask {lane_mask:#010x}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn default_weight_mask_pins_sign_exponent_chips() {
        // Default sign+exponent lane mask 0xFF80_0000: float byte 3
        // (sign + exp[7:1]) pins chips 3/7 entirely, float byte 2
        // (exp[0] in bit 7) pins bit 7 of every byte on chips 2/6, and
        // the mantissa chips 0/1/4/5 are unconstrained.
        let cfgs = weight_chip_configs(&ZacConfig {
            tolerance_mask_override: None,
            ..ZacConfig::zac_weights(60)
        });
        for j in [3usize, 7] {
            assert_eq!(cfgs[j].tolerance_mask(), u64::MAX, "chip {j} fully pinned");
        }
        for j in [2usize, 6] {
            assert_eq!(cfgs[j].tolerance_mask(), 0x8080_8080_8080_8080, "chip {j}");
        }
        for j in [0usize, 1, 4, 5] {
            assert_eq!(cfgs[j].tolerance_mask(), 0, "chip {j} unconstrained");
        }
    }
}
