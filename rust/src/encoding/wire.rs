//! What one chip drives on its wires for one 64-bit transfer (8 beats).

use super::stats::Outcome;

/// Wire-level view of one chip transfer.
///
/// Line inventory per x8 DRAM chip (matching §III / §IV-B):
/// * 8 **data lines** × 8 beats — `data` (byte *b* = beat *b*, bit *l* =
///   line *l*).
/// * 1 **DBI line** — `dbi_mask`, one inversion flag per beat.
/// * 1 **index line** — `index_line`, the 6-bit binary table address
///   serialized over the burst (BD-Coder/MBDC; ZAC-DEST's skip path puts
///   the index on the *data* lines one-hot instead).
/// * up to 8 **ECC sideband lines** — `ecc_line`, check bits driven by
///   the correcting codec family (0 for every non-correcting scheme);
///   bit `8*b + l` = beat *b* on sideband line *l*, the same layout as
///   `data`. Fault models treat the sidebands as hardened (stronger
///   cells / higher-margin routing), matching the hardened-metadata
///   assumption of the base fault layer.
/// * flag signalling — `outcome` stands for the mode flag the receiver
///   needs (data vs xor vs address); its wire cost is
///   [`WireWord::flag_ones`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireWord {
    /// Bits driven on the 8 data lines over the 8-beat burst.
    pub data: u64,
    /// Per-beat DBI inversion flags (0 when the scheme has no DBI stage).
    pub dbi_mask: u8,
    /// Serialized binary index on the index sideband line (0 when unused).
    pub index_line: u8,
    /// Whether the index line is driven this transfer.
    pub index_used: bool,
    /// Check bits on the ECC sideband lines (bit `8*b + l` = beat `b`,
    /// sideband line `l`; 0 for non-correcting schemes).
    pub ecc_line: u64,
    /// Transfer mode (wire-visible via the flag line in hardware).
    pub outcome: Outcome,
}

impl WireWord {
    /// A raw, sideband-free transfer (ORG baseline).
    pub fn raw(data: u64) -> Self {
        WireWord {
            data,
            dbi_mask: 0,
            index_line: 0,
            index_used: false,
            ecc_line: 0,
            outcome: Outcome::Raw,
        }
    }

    /// Ones on the mode-flag signalling for this transfer: encoded modes
    /// (xor or one-hot address) pulse the flag line once per burst.
    pub fn flag_ones(&self) -> u32 {
        match self.outcome {
            Outcome::Bde | Outcome::OheSkip => 1,
            Outcome::Raw | Outcome::ZeroSkip => 0,
        }
    }

    /// Total ones this transfer drives across data + sidebands
    /// (the termination-energy contribution, paper §III). ECC check
    /// bits are real wire bits: a correcting scheme pays termination
    /// for every sideband 1 it drives.
    pub fn total_ones(&self) -> u32 {
        self.data.count_ones()
            + self.dbi_mask.count_ones()
            + if self.index_used {
                self.index_line.count_ones()
            } else {
                0
            }
            + self.ecc_line.count_ones()
            + self.flag_ones()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_has_no_sideband_cost() {
        let w = WireWord::raw(0xFF00);
        assert_eq!(w.total_ones(), 8);
        assert_eq!(w.flag_ones(), 0);
    }

    #[test]
    fn encoded_modes_pulse_flag() {
        let mut w = WireWord::raw(0);
        w.outcome = Outcome::Bde;
        assert_eq!(w.flag_ones(), 1);
        w.outcome = Outcome::OheSkip;
        assert_eq!(w.flag_ones(), 1);
        w.outcome = Outcome::ZeroSkip;
        assert_eq!(w.flag_ones(), 0);
    }

    #[test]
    fn index_counts_only_when_used() {
        let mut w = WireWord::raw(0);
        w.index_line = 0b111111;
        assert_eq!(w.total_ones(), 0);
        w.index_used = true;
        assert_eq!(w.total_ones(), 6);
    }

    #[test]
    fn ecc_sideband_is_charged_to_termination() {
        let mut w = WireWord::raw(0x0F);
        assert_eq!(w.total_ones(), 4);
        w.ecc_line = 0b101;
        assert_eq!(w.total_ones(), 6);
        // raw() never carries check bits.
        assert_eq!(WireWord::raw(0xFF).ecc_line, 0);
    }
}
