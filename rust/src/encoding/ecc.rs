//! Correcting codec families: schemes that spend wire bits on
//! resilience instead of (or on top of) energy.
//!
//! ZAC-DEST's evaluation assumes the channel itself is reliable and
//! only the *stored* data is approximate. Once the fault layer scales
//! voltage or relaxes MRAM retention, the wire words themselves lie,
//! and the interesting design space is codecs that buy back quality
//! with redundant wire bits — charged to the same termination/switching
//! energy model as every data bit, so the resilience-vs-energy
//! trade-off is measurable, not assumed. Three families live here:
//!
//! * [`SECDED`](SecdedEncoder) — a per-beat Hamming(12,8)+parity
//!   sideband over the 8 data lines: 5 extra sideband lines carry 4
//!   check bits + overall parity per beat, correcting any single data
//!   bit per beat and detecting double bits. The classic server-DRAM
//!   answer, at the classic cost: every check 1 pays termination.
//! * [`EDEN`](EdenEncoder) — EDEN-style (arXiv:1910.05340)
//!   error-correcting *truncation*: approximate traffic sacrifices the
//!   low nibble of every byte so the high nibble travels inside an
//!   in-band Hamming(7,4)+parity codeword. No sideband lines at all —
//!   resilience is paid for with precision, the purest
//!   approximate-computing trade.
//! * [`ECC+`](EccWrapEncoder) — an EnforceSNN-style (arXiv:2304.04039)
//!   efficient-ECC wrapper composable over *any* registered scheme:
//!   one sideband line carries a SECDED(72,64) code over the base
//!   scheme's (possibly encoded) wire word, repairing the wire before
//!   the base decoder runs — which also protects table-based codecs
//!   from mirror desynchronization, their dominant fault-amplification
//!   path.
//!
//! Check bits ride [`WireWord::ecc_line`] (same `8*b + l` packing as
//! the data lines) and are charged by [`WireWord::total_ones`] and the
//! channel's switching accounting. Fault models treat the sidebands as
//! hardened, matching the hardened-metadata assumption of the base
//! fault layer (see `faults::model`).
//!
//! Decoders report repairs through [`ChipDecoder::take_corrections`];
//! the one shared drive loop drains them into
//! [`FaultStats`](crate::faults::FaultStats) after every batch.

use super::config::Scheme;
use super::knobs::Knobs;
use super::registry::{Codec, CodecRegistry, CodecSpec};
use super::stats::Outcome;
use super::wire::WireWord;
use super::{ChipDecoder, ChipEncoder};

/// Repairs and detections a correcting decoder accumulated since the
/// last drain — the counts behind `corrected_bits`/`detected_bits` in
/// [`FaultStats`](crate::faults::FaultStats).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CorrectionCounts {
    /// Data bits repaired in place before the word left the decoder.
    pub corrected_bits: u64,
    /// Error bits flagged but not repairable (double-bit detections;
    /// everything, for detection-only schemes).
    pub detected_bits: u64,
}

impl CorrectionCounts {
    /// Accumulate another decoder's counts (wrapper + inner).
    pub fn merge(&mut self, o: CorrectionCounts) {
        self.corrected_bits += o.corrected_bits;
        self.detected_bits += o.detected_bits;
    }

    /// Drain: return the counts and reset to zero.
    pub fn take(&mut self) -> CorrectionCounts {
        std::mem::take(self)
    }
}

/// Even parity of a byte (1 iff an odd number of bits are set).
#[inline]
fn parity8(byte: u8) -> u8 {
    (byte.count_ones() & 1) as u8
}

// ---------------------------------------------------------------------------
// SECDED — per-beat Hamming sideband over the 8 data lines.
// ---------------------------------------------------------------------------

/// The 4 Hamming check bits for one beat's byte. Data bit `i` carries
/// column `i + 1`, so check `k` covers the bits whose `(i+1)` has bit
/// `k` set; a single-bit error at `i` yields syndrome `i + 1` ∈ [1, 8].
#[inline]
fn secded_checks(byte: u8) -> u8 {
    let c0 = parity8(byte & 0x55); // i ∈ {0,2,4,6}
    let c1 = parity8(byte & 0x66); // i ∈ {1,2,5,6}
    let c2 = parity8(byte & 0x78); // i ∈ {3,4,5,6}
    let c3 = parity8(byte & 0x80); // i = 7
    c0 | (c1 << 1) | (c2 << 2) | (c3 << 3)
}

/// Full-word SECDED sideband: per beat `b`, checks `c0..c3` on sideband
/// lines 0..3 and overall byte parity on line 4 (bits `8*b + k`).
fn secded_sideband(data: u64) -> u64 {
    let mut ecc = 0u64;
    for b in 0..8 {
        let byte = ((data >> (8 * b)) & 0xFF) as u8;
        let bits = (secded_checks(byte) | (parity8(byte) << 4)) as u64;
        ecc |= bits << (8 * b);
    }
    ecc
}

/// SECDED sideband encoder: raw data on the 8 data lines plus 5 check
/// lines per beat. Single-bit correction + double-bit detection per
/// beat, fully lossless on a clean channel.
#[derive(Default)]
pub struct SecdedEncoder;

impl ChipEncoder for SecdedEncoder {
    fn encode(&mut self, word: u64, _approx: bool) -> WireWord {
        let mut w = WireWord::raw(word);
        w.ecc_line = secded_sideband(word);
        if word == 0 {
            // Classified for stats only; all checks of zero are zero,
            // so the wire really is free.
            w.outcome = Outcome::ZeroSkip;
        }
        w
    }

    fn scheme(&self) -> Scheme {
        Scheme::Org // closed legacy enum: nearest label for stat buckets
    }

    fn reset(&mut self) {}
}

/// SECDED sideband decoder: per beat, recompute checks from the
/// received byte, correct on a single-bit syndrome, count a double-bit
/// detection otherwise.
#[derive(Default)]
pub struct SecdedDecoder {
    counts: CorrectionCounts,
}

impl ChipDecoder for SecdedDecoder {
    fn decode(&mut self, wire: &WireWord) -> u64 {
        let mut data = wire.data;
        for b in 0..8 {
            let byte = ((data >> (8 * b)) & 0xFF) as u8;
            let stored = ((wire.ecc_line >> (8 * b)) & 0x1F) as u8;
            let s = (stored & 0x0F) ^ secded_checks(byte);
            let pm = ((stored >> 4) ^ parity8(byte)) & 1;
            if pm == 1 {
                if (1..=8).contains(&s) {
                    // Odd error count, valid column: single-bit repair.
                    data ^= 1u64 << (8 * b + (s - 1) as usize);
                    self.counts.corrected_bits += 1;
                } else {
                    // Odd count, no locatable column (≥3 flips).
                    self.counts.detected_bits += 1;
                }
            } else if s != 0 {
                // Even error count with a nonzero syndrome: the classic
                // uncorrectable double-bit case.
                self.counts.detected_bits += 2;
            }
        }
        data
    }

    fn take_corrections(&mut self) -> CorrectionCounts {
        self.counts.take()
    }

    fn reset(&mut self) {
        self.counts = CorrectionCounts::default();
    }
}

// ---------------------------------------------------------------------------
// PARITY — one sideband line, detect-only.
// ---------------------------------------------------------------------------

/// Per-beat even parity on a single sideband line (bit `8*b`, line 0).
fn parity_sideband(data: u64) -> u64 {
    let mut ecc = 0u64;
    for b in 0..8 {
        let byte = ((data >> (8 * b)) & 0xFF) as u8;
        ecc |= (parity8(byte) as u64) << (8 * b);
    }
    ecc
}

/// Parity sideband encoder: the cheapest correcting-family member —
/// one extra line, detection only. The floor of the family's
/// energy-vs-resilience curve.
#[derive(Default)]
pub struct ParityEncoder;

impl ChipEncoder for ParityEncoder {
    fn encode(&mut self, word: u64, _approx: bool) -> WireWord {
        let mut w = WireWord::raw(word);
        w.ecc_line = parity_sideband(word);
        if word == 0 {
            w.outcome = Outcome::ZeroSkip;
        }
        w
    }

    fn scheme(&self) -> Scheme {
        Scheme::Org
    }

    fn reset(&mut self) {}
}

/// Parity decoder: counts every beat whose parity mismatches as one
/// detected (never corrected) bit; data passes through untouched.
#[derive(Default)]
pub struct ParityDecoder {
    counts: CorrectionCounts,
}

impl ChipDecoder for ParityDecoder {
    fn decode(&mut self, wire: &WireWord) -> u64 {
        let mismatch = parity_sideband(wire.data) ^ wire.ecc_line;
        self.counts.detected_bits += mismatch.count_ones() as u64;
        wire.data
    }

    fn take_corrections(&mut self) -> CorrectionCounts {
        self.counts.take()
    }

    fn reset(&mut self) {
        self.counts = CorrectionCounts::default();
    }
}

// ---------------------------------------------------------------------------
// EDEN — in-band error-correcting truncation (Hamming(7,4)+P per byte).
// ---------------------------------------------------------------------------

/// Encode a nibble into the 8-bit Hamming(7,4)+overall-parity codeword.
/// Standard positions 1..7 in bits 0..6 (parity bits at positions
/// 1, 2, 4; data `n0..n3` at 3, 5, 6, 7), overall parity in bit 7.
#[inline]
fn hamming74_encode(nibble: u8) -> u8 {
    let n0 = nibble & 1;
    let n1 = (nibble >> 1) & 1;
    let n2 = (nibble >> 2) & 1;
    let n3 = (nibble >> 3) & 1;
    let p1 = n0 ^ n1 ^ n3;
    let p2 = n0 ^ n2 ^ n3;
    let p4 = n1 ^ n2 ^ n3;
    let bits = p1 | (p2 << 1) | (n0 << 2) | (p4 << 3) | (n1 << 4) | (n2 << 5) | (n3 << 6);
    bits | (parity8(bits) << 7)
}

/// Decode one received codeword byte back to its nibble, repairing a
/// single flipped bit (data, check or overall parity) and counting
/// double flips as detected.
#[inline]
fn hamming74_decode(byte: u8, counts: &mut CorrectionCounts) -> u8 {
    let mut cw = byte;
    let bit = |c: u8, i: u8| (c >> i) & 1;
    let s1 = bit(cw, 0) ^ bit(cw, 2) ^ bit(cw, 4) ^ bit(cw, 6);
    let s2 = bit(cw, 1) ^ bit(cw, 2) ^ bit(cw, 5) ^ bit(cw, 6);
    let s4 = bit(cw, 3) ^ bit(cw, 4) ^ bit(cw, 5) ^ bit(cw, 6);
    let s = s1 | (s2 << 1) | (s4 << 2);
    let pm = parity8(cw);
    if s != 0 && pm == 1 {
        cw ^= 1 << (s - 1); // single error at position s
        counts.corrected_bits += 1;
    } else if s != 0 {
        counts.detected_bits += 2; // double error, uncorrectable
    } else if pm == 1 {
        cw ^= 1 << 7; // the overall parity bit itself flipped
        counts.corrected_bits += 1;
    }
    bit(cw, 2) | (bit(cw, 4) << 1) | (bit(cw, 5) << 2) | (bit(cw, 6) << 3)
}

/// Within-word mask of the bits EDEN represents at all: the high
/// nibble of every byte. Errors below it are the scheme's *declared*
/// precision loss, not fault damage.
pub const EDEN_RESILIENCE_MASK: u64 = 0xF0F0_F0F0_F0F0_F0F0;

/// EDEN-style error-correcting truncation encoder. Approximate bytes
/// travel as Hamming(7,4)+P codewords of their high nibble — the low
/// nibble is sacrificed for single-bit correction with zero sideband
/// lines. Critical traffic passes through raw and exact.
#[derive(Default)]
pub struct EdenEncoder;

impl ChipEncoder for EdenEncoder {
    fn encode(&mut self, word: u64, approx: bool) -> WireWord {
        if word == 0 {
            let mut w = WireWord::raw(0);
            w.outcome = Outcome::ZeroSkip;
            return w;
        }
        if !approx {
            return WireWord::raw(word);
        }
        let mut data = 0u64;
        for b in 0..8 {
            let v = ((word >> (8 * b)) & 0xFF) as u8;
            data |= (hamming74_encode(v >> 4) as u64) << (8 * b);
        }
        WireWord {
            data,
            dbi_mask: 0,
            index_line: 0,
            index_used: false,
            ecc_line: 0,
            // Encoded mode: the flag line tells the receiver to run the
            // Hamming path instead of passthrough.
            outcome: Outcome::Bde,
        }
    }

    fn scheme(&self) -> Scheme {
        Scheme::Org
    }

    fn reset(&mut self) {}
}

/// EDEN decoder: Hamming-decode encoded transfers back to
/// `high_nibble << 4` per byte; raw (critical) and zero transfers pass
/// through exact.
#[derive(Default)]
pub struct EdenDecoder {
    counts: CorrectionCounts,
}

impl ChipDecoder for EdenDecoder {
    fn decode(&mut self, wire: &WireWord) -> u64 {
        match wire.outcome {
            Outcome::Bde => {
                let mut out = 0u64;
                for b in 0..8 {
                    let cw = ((wire.data >> (8 * b)) & 0xFF) as u8;
                    let nib = hamming74_decode(cw, &mut self.counts) as u64;
                    out |= (nib << 4) << (8 * b);
                }
                out
            }
            // Zero rides the hardened flag, not the (corruptible) data
            // lines — same immunity the ZAC zero-skip path has.
            Outcome::ZeroSkip => 0,
            // Raw = critical traffic, which injection never touches.
            _ => wire.data,
        }
    }

    fn take_corrections(&mut self) -> CorrectionCounts {
        self.counts.take()
    }

    fn resilience_mask(&self) -> u64 {
        EDEN_RESILIENCE_MASK
    }

    fn reset(&mut self) {
        self.counts = CorrectionCounts::default();
    }
}

// ---------------------------------------------------------------------------
// ECC+ — SECDED(72,64) wrapper over any registered base scheme.
// ---------------------------------------------------------------------------

/// Column masks of the whole-word code: data bit `i` carries column
/// `i + 1`, so check `k` covers the bits whose `(i+1)` has bit `k` set
/// and a single-bit error at `i` yields syndrome `i + 1` ∈ [1, 64].
const fn col_masks() -> [u64; 7] {
    let mut m = [0u64; 7];
    let mut i = 0;
    while i < 64 {
        let col = (i + 1) as u64;
        let mut k = 0;
        while k < 7 {
            if (col >> k) & 1 == 1 {
                m[k] |= 1u64 << i;
            }
            k += 1;
        }
        i += 1;
    }
    m
}
const COL_MASKS: [u64; 7] = col_masks();

/// The 7 whole-word Hamming checks over a wire word's data bits.
#[inline]
fn word_checks(data: u64) -> u8 {
    let mut c = 0u8;
    for (k, mask) in COL_MASKS.iter().enumerate() {
        c |= (((data & mask).count_ones() & 1) as u8) << k;
    }
    c
}

/// Whole-word SECDED sideband on one line: check `c_k` on beat `k`
/// (bit `8*k`, line 0) and overall data parity on beat 7 (bit 56).
fn wrap_sideband(data: u64) -> u64 {
    let mut ecc = 0u64;
    for k in 0..7 {
        ecc |= (((data & COL_MASKS[k]).count_ones() & 1) as u64) << (8 * k);
    }
    ecc | (((data.count_ones() & 1) as u64) << 56)
}

/// EnforceSNN-style efficient-ECC wrapper encoder: runs the base
/// scheme untouched, then drives a SECDED(72,64) code over the
/// resulting wire word on one extra sideband line. Composes over any
/// scheme whose own ECC sideband is idle.
pub struct EccWrapEncoder {
    inner: Box<dyn ChipEncoder>,
}

impl EccWrapEncoder {
    pub fn new(inner: Box<dyn ChipEncoder>) -> EccWrapEncoder {
        EccWrapEncoder { inner }
    }
}

impl ChipEncoder for EccWrapEncoder {
    fn encode(&mut self, word: u64, approx: bool) -> WireWord {
        let mut wire = self.inner.encode(word, approx);
        debug_assert_eq!(wire.ecc_line, 0, "ECC+ needs a sideband-free base");
        wire.ecc_line = wrap_sideband(wire.data);
        wire
    }

    /// Delegate to the base scheme's batch path (keeping its
    /// batch == scalar guarantees), then stamp the sideband per word.
    fn encode_batch(&mut self, words: &[u64], approx: &[bool], out: &mut [WireWord]) {
        self.inner.encode_batch(words, approx, out);
        for w in out.iter_mut() {
            debug_assert_eq!(w.ecc_line, 0, "ECC+ needs a sideband-free base");
            w.ecc_line = wrap_sideband(w.data);
        }
    }

    fn scheme(&self) -> Scheme {
        self.inner.scheme()
    }

    fn reset(&mut self) {
        self.inner.reset();
    }
}

/// The wrapper decoder: repairs the wire word *before* the base
/// decoder runs. For table-based codecs this is the load-bearing
/// ordering — a repaired wire also repairs the dedup/update decision,
/// keeping the mirrored tables synchronized where an unprotected run
/// would amplify one flipped bit into a desynchronized stream.
pub struct EccWrapDecoder {
    inner: Box<dyn ChipDecoder>,
    counts: CorrectionCounts,
    scratch: Vec<WireWord>,
}

impl EccWrapDecoder {
    pub fn new(inner: Box<dyn ChipDecoder>) -> EccWrapDecoder {
        EccWrapDecoder {
            inner,
            counts: CorrectionCounts::default(),
            scratch: Vec::new(),
        }
    }

    /// Syndrome-decode one received wire word into its repaired copy.
    fn repair(&mut self, wire: &WireWord) -> WireWord {
        let mut w = *wire;
        let mut stored = 0u8;
        for k in 0..7 {
            stored |= (((w.ecc_line >> (8 * k)) & 1) as u8) << k;
        }
        let stored_p = ((w.ecc_line >> 56) & 1) as u8;
        let s = stored ^ word_checks(w.data);
        let pm = stored_p ^ ((w.data.count_ones() & 1) as u8);
        if pm == 1 {
            if (1..=64).contains(&s) {
                w.data ^= 1u64 << (s - 1);
                self.counts.corrected_bits += 1;
            } else {
                self.counts.detected_bits += 1;
            }
        } else if s != 0 {
            self.counts.detected_bits += 2;
        }
        w
    }
}

impl ChipDecoder for EccWrapDecoder {
    fn decode(&mut self, wire: &WireWord) -> u64 {
        let repaired = self.repair(wire);
        self.inner.decode(&repaired)
    }

    /// Repair the whole batch into a scratch copy, then hand it to the
    /// base decoder's batch path in one call.
    fn decode_batch(&mut self, wires: &[WireWord], out: &mut Vec<u64>) {
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        scratch.reserve(wires.len());
        for w in wires {
            let repaired = self.repair(w);
            scratch.push(repaired);
        }
        self.inner.decode_batch(&scratch, out);
        self.scratch = scratch;
    }

    fn take_corrections(&mut self) -> CorrectionCounts {
        let mut c = self.counts.take();
        c.merge(self.inner.take_corrections());
        c
    }

    fn resilience_mask(&self) -> u64 {
        self.inner.resilience_mask()
    }

    fn reset(&mut self) {
        self.inner.reset();
        self.counts = CorrectionCounts::default();
    }
}

// ---------------------------------------------------------------------------
// Registration.
// ---------------------------------------------------------------------------

/// Register the `ECC+<base>` wrapper for one base scheme. The factory
/// holds a snapshot of `reg` as of this call, so the base must already
/// be registered; knob bags pass through to the base (with the base's
/// defaults when the spec carries none — `CodecSpec::named("ECC+OHE")`
/// builds ZAC at paper defaults). This is the out-of-tree composition
/// hook: register a custom scheme, then `ecc::wrap(reg, "MYSCHEME")`.
pub fn wrap(reg: &mut CodecRegistry, base: &str) {
    let snapshot = reg.clone();
    let base_name = base.to_string();
    reg.register(&format!("ECC+{base}"), move |spec| {
        let knobs = match spec.knobs {
            Knobs::None => match Scheme::parse(&base_name) {
                Some(s) => Knobs::for_scheme(s),
                None => Knobs::None,
            },
            k => k,
        };
        let inner = snapshot.build(&CodecSpec::with_knobs(&base_name, knobs))?;
        Ok(Codec::new(
            Box::new(EccWrapEncoder::new(inner.encoder)),
            Box::new(EccWrapDecoder::new(inner.decoder)),
        ))
    });
}

/// Self-register the correcting family: the three standalone schemes
/// plus `ECC+<base>` wrappers over every scheme already in `reg`
/// at this point (the five Table I builtins, when called from
/// [`CodecRegistry::with_builtins`]).
pub fn register(reg: &mut CodecRegistry) {
    reg.register("SECDED", |_spec| {
        Ok(Codec::new(
            Box::new(SecdedEncoder),
            Box::new(SecdedDecoder::default()),
        ))
    });
    reg.register("PARITY", |_spec| {
        Ok(Codec::new(
            Box::new(ParityEncoder),
            Box::new(ParityDecoder::default()),
        ))
    });
    reg.register("EDEN", |_spec| {
        Ok(Codec::new(
            Box::new(EdenEncoder),
            Box::new(EdenDecoder::default()),
        ))
    });
    for base in ["ORG", "DBI", "BDE_ORG", "BDE", "OHE"] {
        wrap(reg, base);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::default_registry;
    use crate::util::rng::Rng;

    fn drain(dec: &mut dyn ChipDecoder) -> CorrectionCounts {
        dec.take_corrections()
    }

    #[test]
    fn secded_is_lossless_on_a_clean_channel() {
        let mut e = SecdedEncoder;
        let mut d = SecdedDecoder::default();
        let mut r = Rng::new(91);
        for _ in 0..2000 {
            let w = r.next_u64();
            let wire = e.encode(w, true);
            assert_eq!(d.decode(&wire), w);
        }
        assert_eq!(drain(&mut d), CorrectionCounts::default());
    }

    #[test]
    fn secded_corrects_every_single_bit_flip() {
        let mut e = SecdedEncoder;
        let mut d = SecdedDecoder::default();
        let word = 0xDEAD_BEEF_CAFE_F00D;
        for bit in 0..64 {
            let mut wire = e.encode(word, true);
            wire.data ^= 1u64 << bit;
            assert_eq!(d.decode(&wire), word, "bit {bit}");
            let c = drain(&mut d);
            assert_eq!(c.corrected_bits, 1, "bit {bit}");
            assert_eq!(c.detected_bits, 0, "bit {bit}");
        }
    }

    #[test]
    fn secded_detects_double_flips_in_one_beat() {
        let mut e = SecdedEncoder;
        let mut d = SecdedDecoder::default();
        let word = 0x0123_4567_89AB_CDEF;
        let mut wire = e.encode(word, true);
        wire.data ^= 0b11 << 16; // two flips, same beat
        let _ = d.decode(&wire);
        let c = drain(&mut d);
        assert_eq!(c.corrected_bits, 0);
        assert_eq!(c.detected_bits, 2);
    }

    #[test]
    fn secded_sideband_matches_hand_derivation() {
        // 0xFF beat: c0 = c1 = c2 = 0 (even pairs), c3 = d7 = 1,
        // parity = 0 -> only line 3 of beat 7 is driven.
        let mut e = SecdedEncoder;
        let wire = e.encode(0xFF00_0000_0000_0000, true);
        assert_eq!(wire.ecc_line, 0x0800_0000_0000_0000);
        // Check bits are charged to termination: 8 data ones + 1 check.
        assert_eq!(wire.total_ones(), 9);
        // Zero stays free.
        assert_eq!(e.encode(0, true).total_ones(), 0);
    }

    #[test]
    fn parity_detects_but_never_corrects() {
        let mut e = ParityEncoder;
        let mut d = ParityDecoder::default();
        let word = 0xA5A5_0000_FFFF_0001;
        let clean = e.encode(word, true);
        assert_eq!(d.decode(&clean), word);
        assert_eq!(drain(&mut d), CorrectionCounts::default());
        let mut wire = clean;
        wire.data ^= (1u64 << 3) | (1u64 << 40); // two beats hit
        let got = d.decode(&wire);
        assert_eq!(got, wire.data, "parity is detect-only");
        let c = drain(&mut d);
        assert_eq!(c.corrected_bits, 0);
        assert_eq!(c.detected_bits, 2);
        // W3 = 0xFF00000000000001: odd-parity beats 0 and 7.
        assert_eq!(
            e.encode(0xFF00_0000_0000_0001, true).ecc_line,
            (1u64 << 56) | 1
        );
    }

    #[test]
    fn eden_codeword_construction() {
        // Nibble 0xF: all parity and data positions set -> 0xFF.
        assert_eq!(hamming74_encode(0xF), 0xFF);
        assert_eq!(hamming74_encode(0x0), 0x00);
        // Every codeword decodes back clean.
        let mut c = CorrectionCounts::default();
        for n in 0..16u8 {
            assert_eq!(hamming74_decode(hamming74_encode(n), &mut c), n);
        }
        assert_eq!(c, CorrectionCounts::default());
    }

    #[test]
    fn eden_truncates_to_high_nibbles_and_keeps_critical_exact() {
        let mut e = EdenEncoder;
        let mut d = EdenDecoder::default();
        let word = 0x1234_5678_9ABC_DEF5;
        let wire = e.encode(word, true);
        assert_eq!(wire.outcome, Outcome::Bde);
        assert_eq!(d.decode(&wire), word & EDEN_RESILIENCE_MASK);
        // Critical traffic bypasses the truncation entirely.
        let wire = e.encode(word, false);
        assert_eq!(wire.outcome, Outcome::Raw);
        assert_eq!(d.decode(&wire), word);
        // Zero is still the free transfer.
        let wire = e.encode(0, true);
        assert_eq!(wire.outcome, Outcome::ZeroSkip);
        assert_eq!(wire.total_ones(), 0);
        assert_eq!(d.decode(&wire), 0);
        assert_eq!(drain(&mut d), CorrectionCounts::default());
    }

    #[test]
    fn eden_repairs_single_flips_per_codeword() {
        let mut e = EdenEncoder;
        let mut d = EdenDecoder::default();
        let word = 0x70F0_A050_3090_C010;
        let want = word & EDEN_RESILIENCE_MASK;
        for bit in 0..64 {
            let mut wire = e.encode(word, true);
            wire.data ^= 1u64 << bit;
            assert_eq!(d.decode(&wire), want, "bit {bit}");
            assert_eq!(drain(&mut d).corrected_bits, 1, "bit {bit}");
        }
    }

    #[test]
    fn wrapper_sideband_matches_hand_derivation() {
        // W1 = 0xFF00...00: columns 57..64 xor to 0b1111000, parity of
        // eight ones is 0 -> checks c3..c6 on beats 3..6 of line 0.
        assert_eq!(
            wrap_sideband(0xFF00_0000_0000_0000),
            0x0001_0101_0100_0000
        );
        assert_eq!(wrap_sideband(0), 0);
    }

    #[test]
    fn wrapper_corrects_single_flips_over_org() {
        let mut codec = default_registry()
            .build(&CodecSpec::named("ECC+ORG"))
            .unwrap();
        let word = 0x5A5A_1234_ABCD_EF01;
        for bit in 0..64 {
            let mut wire = codec.encoder.encode(word, true);
            wire.data ^= 1u64 << bit;
            assert_eq!(codec.decoder.decode(&wire), word, "bit {bit}");
            let c = codec.decoder.take_corrections();
            assert_eq!(c.corrected_bits, 1, "bit {bit}");
        }
    }

    #[test]
    fn wrapper_keeps_table_mirrors_synchronized_under_flips() {
        // A single wire flip desynchronizes an unprotected BDE mirror
        // (wrong dedup decision); the wrapper repairs the wire before
        // the inner decode, so the whole downstream stream stays exact.
        let mut codec = default_registry()
            .build(&CodecSpec::named("ECC+BDE"))
            .unwrap();
        let mut r = Rng::new(92);
        let base = r.next_u64();
        let words: Vec<u64> = (0..500).map(|_| base ^ (1u64 << r.below(64))).collect();
        for (i, &w) in words.iter().enumerate() {
            let mut wire = codec.encoder.encode(w, true);
            if i % 7 == 3 {
                wire.data ^= 1u64 << (i % 64); // one flip on the wire
            }
            assert_eq!(codec.decoder.decode(&wire), w, "word {i}");
        }
        let c = codec.decoder.take_corrections();
        assert!(c.corrected_bits > 0);
        assert_eq!(c.detected_bits, 0);
    }

    #[test]
    fn wrapper_batch_matches_scalar() {
        let mut r = Rng::new(93);
        let words: Vec<u64> = (0..600)
            .map(|i| if i % 11 == 0 { 0 } else { r.next_u64() & 0xFFFF })
            .collect();
        let approx: Vec<bool> = (0..words.len()).map(|_| r.chance(0.6)).collect();
        let build = || {
            default_registry()
                .build(&CodecSpec::named("ECC+BDE"))
                .unwrap()
        };
        let mut scalar = build();
        let scalar_wires: Vec<WireWord> = words
            .iter()
            .zip(&approx)
            .map(|(&w, &a)| scalar.encoder.encode(w, a))
            .collect();
        let scalar_out: Vec<u64> = scalar_wires
            .iter()
            .map(|w| scalar.decoder.decode(w))
            .collect();
        let mut batch = build();
        let mut wires = vec![WireWord::raw(0); words.len()];
        batch.encoder.encode_batch(&words, &approx, &mut wires);
        let mut out = Vec::new();
        batch.decoder.decode_batch(&wires, &mut out);
        assert_eq!(wires, scalar_wires);
        assert_eq!(out, scalar_out);
        assert_eq!(
            scalar.decoder.take_corrections(),
            batch.decoder.take_corrections()
        );
    }

    #[test]
    fn wrapper_charges_its_check_bits_to_the_wire() {
        let mut plain = default_registry().build(&CodecSpec::named("ORG")).unwrap();
        let mut wrapped = default_registry()
            .build(&CodecSpec::named("ECC+ORG"))
            .unwrap();
        let w = 0x0123_4567_89AB_CDEF;
        let p = plain.encoder.encode(w, true);
        let q = wrapped.encoder.encode(w, true);
        assert_eq!(q.data, p.data);
        assert_eq!(q.total_ones(), p.total_ones() + q.ecc_line.count_ones());
        assert!(q.ecc_line.count_ones() > 0);
    }
}
