//! The paper's contribution: DRAM-channel data-encoding engines.
//!
//! Five schemes (paper Table I):
//!
//! | scheme    | module       | paper name |
//! |-----------|--------------|------------|
//! | `ORG`     | [`org`]      | original unencoded data (baseline) |
//! | `DBI`     | [`dbi`]      | Dynamic Bus Inversion |
//! | `BDE_ORG` | [`bde_org`]  | original Bitwise Difference Coder (Alg. 1) |
//! | `BDE`     | [`mbdc`]     | Modified BD-Coder (zero bypass, index-aware condition, dedup table) |
//! | `OHE`     | [`zac_dest`] | ZAC-DEST (Alg. 2: skip-transfer + one-hot index + DBI) |
//!
//! All encoders operate at the hardware granularity: one 64-bit word per
//! DRAM chip per cache-line transfer (8 chips × 64 bits = one 64 B line),
//! mirrored tables at sender (DRAM) and receiver (memory controller).

pub mod bde_org;
pub mod config;
pub mod data_table;
pub mod dbi;
pub mod mbdc;
pub mod org;
pub mod stats;
pub mod wire;
pub mod zac_dest;

pub use config::{Scheme, ZacConfig};
pub use data_table::DataTable;
pub use stats::{EncodeStats, Outcome};
pub use wire::WireWord;

use crate::channel::ChipChannel;

/// One DRAM chip's encoder: turns a 64-bit word into what is driven on
/// the wires. `approx` is the per-access error-resilience hint (false for
/// instruction/critical traffic — such words are never approximated).
pub trait ChipEncoder: Send {
    /// Encode one 64-bit word for transfer.
    fn encode(&mut self, word: u64, approx: bool) -> WireWord;
    /// Which scheme this encoder implements.
    fn scheme(&self) -> Scheme;
    /// Reset all internal state (tables, line history is channel-side).
    fn reset(&mut self);
}

/// The matching memory-controller-side decoder. It sees exactly the
/// wire-visible information (data lines + sideband flags/index) and keeps
/// its own mirror of the data table.
pub trait ChipDecoder: Send {
    /// Reconstruct the received word (approximate under ZAC-DEST skips).
    fn decode(&mut self, wire: &WireWord) -> u64;
    fn reset(&mut self);
}

/// Construct the (encoder, decoder) pair for a scheme.
pub fn make_codec(cfg: &ZacConfig) -> (Box<dyn ChipEncoder>, Box<dyn ChipDecoder>) {
    match cfg.scheme {
        Scheme::Org => (
            Box::new(org::OrgEncoder::new()),
            Box::new(org::OrgDecoder::new()),
        ),
        Scheme::Dbi => (
            Box::new(dbi::DbiEncoder::new()),
            Box::new(dbi::DbiDecoder::new()),
        ),
        Scheme::BdeOrg => (
            Box::new(bde_org::BdeOrgEncoder::new(cfg.table_size)),
            Box::new(bde_org::BdeOrgDecoder::new(cfg.table_size)),
        ),
        Scheme::Bde => (
            Box::new(mbdc::MbdcEncoder::new(cfg.table_size)),
            Box::new(mbdc::MbdcDecoder::new(cfg.table_size)),
        ),
        Scheme::ZacDest => (
            Box::new(zac_dest::ZacDestEncoder::new(cfg.clone())),
            Box::new(zac_dest::ZacDestDecoder::new(cfg.clone())),
        ),
    }
}

/// Convenience: run a word stream through one chip's encoder + channel +
/// decoder, returning reconstructed words and accumulating stats/energy.
pub fn run_chip_stream(
    cfg: &ZacConfig,
    words: &[u64],
    approx: &[bool],
    chan: &mut ChipChannel,
    stats: &mut EncodeStats,
) -> Vec<u64> {
    assert_eq!(words.len(), approx.len());
    let (mut enc, mut dec) = make_codec(cfg);
    let mut out = Vec::with_capacity(words.len());
    for (&w, &a) in words.iter().zip(approx) {
        let wire = enc.encode(w, a);
        chan.transmit(&wire);
        stats.record(&wire, w);
        out.push(dec.decode(&wire));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::ChipChannel;
    use crate::util::rng::Rng;

    fn stream(n: usize, seed: u64) -> Vec<u64> {
        let mut r = Rng::new(seed);
        // Locally-similar stream: random walk over a base word, plus zeros.
        let mut base = r.next_u64();
        (0..n)
            .map(|i| {
                if i % 17 == 0 {
                    0
                } else {
                    if i % 5 == 0 {
                        base = r.next_u64();
                    }
                    base ^ (1u64 << r.below(64)) // 1-bit neighbour
                }
            })
            .collect()
    }

    #[test]
    fn exact_schemes_round_trip() {
        let words = stream(500, 11);
        let approx = vec![true; words.len()];
        for scheme in [Scheme::Org, Scheme::Dbi, Scheme::BdeOrg, Scheme::Bde] {
            let cfg = ZacConfig::scheme(scheme);
            let mut chan = ChipChannel::new();
            let mut st = EncodeStats::default();
            let got = run_chip_stream(&cfg, &words, &approx, &mut chan, &mut st);
            assert_eq!(got, words, "{scheme:?} must be lossless");
        }
    }

    #[test]
    fn zac_dest_respects_similarity_envelope() {
        let words = stream(500, 13);
        let approx = vec![true; words.len()];
        let cfg = ZacConfig::zac(80);
        let mut chan = ChipChannel::new();
        let mut st = EncodeStats::default();
        let got = run_chip_stream(&cfg, &words, &approx, &mut chan, &mut st);
        let thr = cfg.dissimilar_threshold();
        for (g, w) in got.iter().zip(&words) {
            let d = (g ^ w).count_ones();
            assert!(d < thr, "reconstruction differs by {d} >= {thr}");
        }
        assert!(st.total() == words.len() as u64);
    }

    #[test]
    fn non_approx_accesses_are_exact_under_zac() {
        let words = stream(300, 17);
        let approx = vec![false; words.len()];
        let cfg = ZacConfig::zac(70);
        let mut chan = ChipChannel::new();
        let mut st = EncodeStats::default();
        let got = run_chip_stream(&cfg, &words, &approx, &mut chan, &mut st);
        assert_eq!(got, words);
        assert_eq!(st.count(Outcome::OheSkip), 0);
    }

    #[test]
    fn zac_beats_bde_on_energy_for_similar_stream() {
        let words = stream(2000, 19);
        let approx = vec![true; words.len()];
        let mut e = Vec::new();
        for cfg in [ZacConfig::scheme(Scheme::Bde), ZacConfig::zac(70)] {
            let mut chan = ChipChannel::new();
            let mut st = EncodeStats::default();
            run_chip_stream(&cfg, &words, &approx, &mut chan, &mut st);
            e.push(chan.energy().termination_ones);
        }
        assert!(
            e[1] < e[0],
            "zac {} should beat bde {} on this stream",
            e[1],
            e[0]
        );
    }
}
