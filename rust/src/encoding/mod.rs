//! The paper's contribution: DRAM-channel data-encoding engines.
//!
//! Five schemes (paper Table I):
//!
//! | scheme    | module       | paper name |
//! |-----------|--------------|------------|
//! | `ORG`     | [`org`]      | original unencoded data (baseline) |
//! | `DBI`     | [`dbi`]      | Dynamic Bus Inversion |
//! | `BDE_ORG` | [`bde_org`]  | original Bitwise Difference Coder (Alg. 1) |
//! | `BDE`     | [`mbdc`]     | Modified BD-Coder (zero bypass, index-aware condition, dedup table) |
//! | `OHE`     | [`zac_dest`] | ZAC-DEST (Alg. 2: skip-transfer + one-hot index + DBI) |
//!
//! Plus the correcting family in [`ecc`] (`SECDED`, `PARITY`, `EDEN`
//! and the `ECC+<base>` wrapper over every scheme above): codecs that
//! spend wire bits on resilience under the fault layer instead of on
//! energy alone.
//!
//! All encoders operate at the hardware granularity: one 64-bit word per
//! DRAM chip per cache-line transfer (8 chips × 64 bits = one 64 B line),
//! mirrored tables at sender (DRAM) and receiver (memory controller).
//!
//! # Batch API contract
//!
//! The hot path is batch-first: every driver moves words in
//! [`ENCODE_BATCH`]-sized chunks through [`ChipEncoder::encode_batch`] /
//! [`ChipDecoder::decode_batch`] with preallocated buffers, so per-word
//! virtual dispatch, queue sends and `Vec` growth amortize away. The
//! contract every implementation must keep:
//!
//! * **Bit-identical to scalar.** `encode_batch` over any chunking of a
//!   stream produces exactly the wire words the per-word [`ChipEncoder::encode`]
//!   sequence would, including all table side effects — batch boundaries
//!   are invisible on the wire (`batch_is_bit_identical_to_scalar_for_every_scheme`).
//! * **Stateful across calls.** A batch call continues from the table
//!   state the previous call left behind; callers may freely mix scalar
//!   and batch calls on one codec.
//! * **No allocation.** `encode_batch` writes into a caller-provided
//!   slice of exactly `words.len()`; `decode_batch` appends to a
//!   caller-provided `Vec` (reserve up front for zero growth).
//!
//! # v2 construction path
//!
//! Codecs are described by a [`CodecSpec`] (scheme name + per-scheme
//! [`Knobs`]) and constructed through a [`CodecRegistry`] of factory
//! functions into a [`Codec`] handle owning the matched encoder/decoder
//! pair. The five built-in schemes self-register
//! ([`CodecRegistry::with_builtins`]); `registry.register(...)` admits
//! out-of-tree schemes with no dispatch `match` to edit here. The one
//! shared drive loop lives in [`lane`] ([`ChipLane`] /
//! [`lane::drive_batches`]) and is what every driver — coordinator,
//! pipeline, channel array, [`Session`](crate::session::Session) — runs.
//!
//! [`make_codec`] and [`run_chip_stream`] remain as thin deprecated
//! shims over the registry + lane for v1 callers.

pub mod bde_org;
pub mod config;
pub mod data_table;
pub mod dbi;
pub mod ecc;
pub mod knobs;
pub mod lane;
pub mod mbdc;
pub mod org;
pub mod registry;
pub mod simd;
pub mod stats;
pub mod wire;
pub mod zac_dest;

pub use config::{Scheme, ZacConfig};
pub use data_table::DataTable;
pub use ecc::CorrectionCounts;
pub use knobs::{Knobs, TableKnobs, ZacKnobs};
pub use lane::ChipLane;
pub use registry::{default_registry, Codec, CodecRegistry, CodecSpec};
pub use simd::{Backend, SimdPref};
pub use stats::{EncodeStats, Outcome};
pub use wire::WireWord;

use crate::channel::ChipChannel;

/// Words per batch in the chunked drivers (coordinator, pipeline,
/// [`run_chip_stream`]): large enough to amortize per-word dispatch and
/// per-chunk queue overhead ~256×, small enough that the word + wire +
/// flag buffers stay resident in L1.
pub const ENCODE_BATCH: usize = 256;

/// One DRAM chip's encoder: turns a 64-bit word into what is driven on
/// the wires. `approx` is the per-access error-resilience hint (false for
/// instruction/critical traffic — such words are never approximated).
pub trait ChipEncoder: Send {
    /// Encode one 64-bit word for transfer.
    fn encode(&mut self, word: u64, approx: bool) -> WireWord;

    /// Encode a batch into `out` (exactly `words.len()` slots). The
    /// default is the scalar loop; schemes override it to hoist config
    /// loads, pre-screen zero words and amortize table lookups. Must
    /// stay bit-identical to the scalar sequence (see the module-level
    /// batch contract).
    fn encode_batch(&mut self, words: &[u64], approx: &[bool], out: &mut [WireWord]) {
        assert_eq!(words.len(), approx.len());
        assert_eq!(words.len(), out.len());
        for ((&w, &a), slot) in words.iter().zip(approx).zip(out.iter_mut()) {
            *slot = self.encode(w, a);
        }
    }

    /// Which scheme this encoder implements.
    fn scheme(&self) -> Scheme;
    /// Reset all internal state (tables, line history is channel-side).
    fn reset(&mut self);
}

/// The matching memory-controller-side decoder. It sees exactly the
/// wire-visible information (data lines + sideband flags/index) and keeps
/// its own mirror of the data table.
pub trait ChipDecoder: Send {
    /// Reconstruct the received word (approximate under ZAC-DEST skips).
    fn decode(&mut self, wire: &WireWord) -> u64;

    /// Decode a batch, appending to `out` (same bit-identical/stateful
    /// contract as [`ChipEncoder::encode_batch`]).
    fn decode_batch(&mut self, wires: &[WireWord], out: &mut Vec<u64>) {
        out.reserve(wires.len());
        for w in wires {
            out.push(self.decode(w));
        }
    }

    /// Drain the repairs/detections accumulated since the last drain.
    /// Non-correcting schemes keep the default (always zero); the one
    /// shared drive loop calls this after every decoded batch and
    /// folds the counts into [`FaultStats`](crate::faults::FaultStats).
    fn take_corrections(&mut self) -> ecc::CorrectionCounts {
        ecc::CorrectionCounts::default()
    }

    /// Within-word mask of the bits this codec claims to deliver at
    /// all: end-to-end damage *outside* it is declared precision loss
    /// (e.g. EDEN's sacrificed low nibbles), not fault residue. The
    /// default claims every bit.
    fn resilience_mask(&self) -> u64 {
        u64::MAX
    }

    fn reset(&mut self);
}

/// **Deprecated shim** — construct the (encoder, decoder) pair for a
/// legacy [`ZacConfig`]. New code resolves a [`CodecSpec`] through a
/// [`CodecRegistry`] into a [`Codec`] handle instead; this delegates to
/// exactly that path, so the closed `match` is gone.
pub fn make_codec(cfg: &ZacConfig) -> (Box<dyn ChipEncoder>, Box<dyn ChipDecoder>) {
    let codec = Codec::from_config(cfg);
    (codec.encoder, codec.decoder)
}

/// **Deprecated shim** — run a word stream through one chip's encoder +
/// channel + decoder, returning reconstructed words and accumulating
/// stats/energy into the caller's `chan`/`stats`. Delegates to the one
/// shared batch loop ([`lane::drive_batches`]); prefer
/// [`Session`](crate::session::Session) for whole-trace runs.
pub fn run_chip_stream(
    cfg: &ZacConfig,
    words: &[u64],
    approx: &[bool],
    chan: &mut ChipChannel,
    stats: &mut EncodeStats,
) -> Vec<u64> {
    let mut codec = Codec::from_config(cfg);
    let mut out = Vec::with_capacity(words.len());
    let mut wires = [WireWord::raw(0); ENCODE_BATCH];
    let mut faults = crate::faults::PerfectChannel;
    let mut fstats = crate::faults::FaultStats::default();
    lane::drive_batches(
        &mut codec,
        chan,
        stats,
        &mut faults,
        &mut fstats,
        words,
        approx,
        &mut wires,
        &mut out,
        None,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::ChipChannel;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn stream(n: usize, seed: u64) -> Vec<u64> {
        let mut r = Rng::new(seed);
        // Locally-similar stream: random walk over a base word, plus zeros.
        let mut base = r.next_u64();
        (0..n)
            .map(|i| {
                if i % 17 == 0 {
                    0
                } else {
                    if i % 5 == 0 {
                        base = r.next_u64();
                    }
                    base ^ (1u64 << r.below(64)) // 1-bit neighbour
                }
            })
            .collect()
    }

    #[test]
    fn exact_schemes_round_trip() {
        let words = stream(500, 11);
        let approx = vec![true; words.len()];
        for scheme in [Scheme::Org, Scheme::Dbi, Scheme::BdeOrg, Scheme::Bde] {
            let cfg = ZacConfig::scheme(scheme);
            let mut chan = ChipChannel::new();
            let mut st = EncodeStats::default();
            let got = run_chip_stream(&cfg, &words, &approx, &mut chan, &mut st);
            assert_eq!(got, words, "{scheme:?} must be lossless");
        }
    }

    #[test]
    fn zac_dest_respects_similarity_envelope() {
        let words = stream(500, 13);
        let approx = vec![true; words.len()];
        let cfg = ZacConfig::zac(80);
        let mut chan = ChipChannel::new();
        let mut st = EncodeStats::default();
        let got = run_chip_stream(&cfg, &words, &approx, &mut chan, &mut st);
        let thr = cfg.dissimilar_threshold();
        for (g, w) in got.iter().zip(&words) {
            let d = (g ^ w).count_ones();
            assert!(d < thr, "reconstruction differs by {d} >= {thr}");
        }
        assert!(st.total() == words.len() as u64);
    }

    #[test]
    fn non_approx_accesses_are_exact_under_zac() {
        let words = stream(300, 17);
        let approx = vec![false; words.len()];
        let cfg = ZacConfig::zac(70);
        let mut chan = ChipChannel::new();
        let mut st = EncodeStats::default();
        let got = run_chip_stream(&cfg, &words, &approx, &mut chan, &mut st);
        assert_eq!(got, words);
        assert_eq!(st.count(Outcome::OheSkip), 0);
    }

    #[test]
    fn zac_beats_bde_on_energy_for_similar_stream() {
        let words = stream(2000, 19);
        let approx = vec![true; words.len()];
        let mut e = Vec::new();
        for cfg in [ZacConfig::scheme(Scheme::Bde), ZacConfig::zac(70)] {
            let mut chan = ChipChannel::new();
            let mut st = EncodeStats::default();
            run_chip_stream(&cfg, &words, &approx, &mut chan, &mut st);
            e.push(chan.energy().termination_ones);
        }
        assert!(
            e[1] < e[0],
            "zac {} should beat bde {} on this stream",
            e[1],
            e[0]
        );
    }

    /// Every config worth testing: all schemes, plus ZAC variants that
    /// exercise truncation, tolerance and the weights mask.
    fn codec_matrix() -> Vec<ZacConfig> {
        let mut cfgs: Vec<ZacConfig> = [Scheme::Org, Scheme::Dbi, Scheme::BdeOrg, Scheme::Bde]
            .into_iter()
            .map(ZacConfig::scheme)
            .collect();
        cfgs.push(ZacConfig::zac(80));
        cfgs.push(ZacConfig::zac_full(75, 2, 1));
        cfgs.push(ZacConfig::zac_weights(60));
        cfgs
    }

    #[test]
    fn batch_is_bit_identical_to_scalar_for_every_scheme() {
        let mut r = Rng::new(23);
        for cfg in codec_matrix() {
            let words = stream(1500, 29);
            let approx: Vec<bool> = (0..words.len()).map(|_| r.chance(0.6)).collect();

            let (mut scalar_enc, mut scalar_dec) = make_codec(&cfg);
            let scalar_wires: Vec<WireWord> = words
                .iter()
                .zip(&approx)
                .map(|(&w, &a)| scalar_enc.encode(w, a))
                .collect();
            let scalar_out: Vec<u64> = scalar_wires.iter().map(|w| scalar_dec.decode(w)).collect();

            // Irregular chunk sizes so chunk boundaries land everywhere.
            let (mut batch_enc, mut batch_dec) = make_codec(&cfg);
            let mut batch_wires = vec![WireWord::raw(0); words.len()];
            let mut batch_out = Vec::new();
            let (mut i, mut k) = (0usize, 0usize);
            while i < words.len() {
                let n = [1usize, 7, ENCODE_BATCH, 64, 3][k % 5].min(words.len() - i);
                k += 1;
                let buf = &mut batch_wires[i..i + n];
                batch_enc.encode_batch(&words[i..i + n], &approx[i..i + n], buf);
                batch_dec.decode_batch(buf, &mut batch_out);
                i += n;
            }
            assert_eq!(batch_wires, scalar_wires, "{} wires", cfg.label());
            assert_eq!(batch_out, scalar_out, "{} decodes", cfg.label());
        }
    }

    #[test]
    fn prop_batch_equals_scalar_on_random_mixes() {
        prop::check(
            "encode_batch/decode_batch == scalar",
            31,
            |r| {
                let n = r.range(0, 96);
                let words: Vec<u64> = (0..n)
                    .map(|_| match r.below(3) {
                        0 => 0u64,
                        1 => r.next_u64() & 0x0F0F,
                        _ => r.next_u64(),
                    })
                    .collect();
                let flags: Vec<bool> = (0..n).map(|_| r.chance(0.5)).collect();
                (words, flags)
            },
            |(words, flags)| {
                let n = words.len().min(flags.len()); // shrinking may desync lengths
                let (words, flags) = (&words[..n], &flags[..n]);
                for cfg in [ZacConfig::zac_full(75, 1, 1), ZacConfig::scheme(Scheme::Bde)] {
                    let (mut se, mut sd) = make_codec(&cfg);
                    let (mut be, mut bd) = make_codec(&cfg);
                    let mut wires = vec![WireWord::raw(0); n];
                    be.encode_batch(words, flags, &mut wires);
                    let mut batch_out = Vec::new();
                    bd.decode_batch(&wires, &mut batch_out);
                    for (i, (&w, &a)) in words.iter().zip(flags).enumerate() {
                        let wire = se.encode(w, a);
                        if wire != wires[i] {
                            return Err(format!("{}: wire {i} diverged", cfg.label()));
                        }
                        if sd.decode(&wire) != batch_out[i] {
                            return Err(format!("{}: decode {i} diverged", cfg.label()));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
