//! The one shared chip drive loop. Every driver — the legacy
//! [`run_chip_stream`](super::run_chip_stream), the batch coordinator,
//! the [`Pipeline`](crate::coordinator::Pipeline) workers, the
//! channel-array shard service loops and [`Session`](crate::session::Session)
//! runs — moves words through the same
//! encode_batch → transmit_batch → record_batch → decode_batch body,
//! so the batch contract (bit-identical to scalar, stateful across
//! calls, no allocation) is enforced in exactly one place.
//!
//! Fault injection also lives here, at the only correct point: **after**
//! `transmit_batch` (the energy was already spent driving the true
//! bits) and **before** `decode_batch` (the receiver senses the
//! corrupted lines). Words flagged non-approximate — critical traffic —
//! are never corrupted *directly* (SparkXD's criticality split), and a
//! [`PerfectChannel`] skips the whole pass.
//!
//! Scope of the criticality guarantee: injection is gated per access,
//! so an all-critical stream (what
//! [`TrafficClass::Critical`](crate::session::TrafficClass) produces —
//! the only session-level knob) is bit-exact end to end. In a *mixed*
//! per-word stream, a corrupted approximate transfer can still
//! desynchronize the shared mirrored table of a table-based codec and
//! thereby perturb a *later* critical decode — faithful to the
//! hardware, where per-access protection of a shared CAM would require
//! criticality-partitioned tables (a future fault-aware codec family;
//! see ROADMAP).

use std::sync::Arc;

use crate::channel::{ChipChannel, EnergyCounts};
use crate::faults::{FaultModel, FaultStats, PerfectChannel};
use crate::obs::{Stage, StageClock, StageSet};
use crate::trace::LineChunk;

use super::registry::Codec;
use super::stats::EncodeStats;
use super::wire::WireWord;
use super::ENCODE_BATCH;

/// Drive a word stream through one chip's codec and channel in
/// [`ENCODE_BATCH`]-sized chunks over the caller's buffers, applying
/// `faults` to the wire for approximate words. `wires` must hold at
/// least `min(words.len(), ENCODE_BATCH)` slots; decoded words append
/// to `out`; injection and end-to-end error counts accumulate into
/// `fstats`. When `stages` is `Some`, per-stage wall time accumulates
/// into it (one clock read per stage boundary); `None` costs nothing.
#[allow(clippy::too_many_arguments)]
pub fn drive_batches(
    codec: &mut Codec,
    chan: &mut ChipChannel,
    stats: &mut EncodeStats,
    faults: &mut dyn FaultModel,
    fstats: &mut FaultStats,
    words: &[u64],
    approx: &[bool],
    wires: &mut [WireWord],
    out: &mut Vec<u64>,
    stages: Option<&StageSet>,
) {
    assert_eq!(words.len(), approx.len());
    assert!(wires.len() >= words.len().min(ENCODE_BATCH));
    let active = faults.is_active();
    for (wc, ac) in words.chunks(ENCODE_BATCH).zip(approx.chunks(ENCODE_BATCH)) {
        let mut clock = StageClock::start(stages);
        let buf = &mut wires[..wc.len()];
        codec.encoder.encode_batch(wc, ac, buf);
        clock.lap(Stage::Encode);
        chan.transmit_batch(buf);
        stats.record_batch(buf, wc);
        clock.lap(Stage::Transmit);
        if active {
            // Wire-level injection: the energy above reflects the true
            // bits; only what the receiver senses is corrupted, and
            // only on error-resilient accesses.
            for (wire, &a) in buf.iter_mut().zip(ac) {
                if a {
                    let flips = faults.corrupt(wire);
                    if flips > 0 {
                        fstats.injected_bits += flips as u64;
                        fstats.injected_words += 1;
                    }
                }
            }
        }
        clock.lap(Stage::Inject);
        let start = out.len();
        codec.decoder.decode_batch(buf, out);
        let mask = codec.decoder.resilience_mask();
        for (&orig, &dec) in wc.iter().zip(&out[start..]) {
            fstats.observed_error_bits += (orig ^ dec).count_ones() as u64;
            if active {
                // Residual = end-to-end damage inside the codec's
                // resilience mask while faults were live. On a perfect
                // channel it stays 0 by the `active` gate, so codec
                // approximation alone never reads as fault residue.
                fstats.residual_error_bits +=
                    ((orig ^ dec) & mask).count_ones() as u64;
            }
        }
        let corrections = codec.decoder.take_corrections();
        fstats.corrected_bits += corrections.corrected_bits;
        fstats.detected_bits += corrections.detected_bits;
        fstats.words += wc.len() as u64;
        clock.lap(Stage::Decode);
        if let Some(set) = stages {
            set.add_batch();
        }
    }
}

/// One chip's full lane state: codec + channel + fault model + stats +
/// decoded output and the reusable wire buffer. Workers own one
/// `ChipLane` per chip and feed it word runs of any length.
pub struct ChipLane {
    codec: Codec,
    chan: ChipChannel,
    stats: EncodeStats,
    faults: Box<dyn FaultModel>,
    fstats: FaultStats,
    decoded: Vec<u64>,
    wires: [WireWord; ENCODE_BATCH],
    /// Telemetry sink; `None` (the default) keeps the drive loop free
    /// of clock reads.
    stages: Option<Arc<StageSet>>,
}

impl ChipLane {
    /// Lane over a perfect (fault-free) channel.
    pub fn new(codec: Codec) -> ChipLane {
        ChipLane::with_capacity(codec, 0)
    }

    /// Perfect-channel lane with the decoded buffer preallocated for
    /// `nwords` words.
    pub fn with_capacity(codec: Codec, nwords: usize) -> ChipLane {
        ChipLane::with_faults(codec, nwords, Box::new(PerfectChannel))
    }

    /// Lane whose wire runs through `faults` (built per (shard, chip)
    /// by [`FaultSpec::build`](crate::faults::FaultSpec::build)).
    pub fn with_faults(codec: Codec, nwords: usize, faults: Box<dyn FaultModel>) -> ChipLane {
        ChipLane {
            codec,
            chan: ChipChannel::new(),
            stats: EncodeStats::default(),
            faults,
            fstats: FaultStats::default(),
            decoded: Vec::with_capacity(nwords),
            wires: [WireWord::raw(0); ENCODE_BATCH],
            stages: None,
        }
    }

    /// Attach a telemetry stage set: subsequent drives charge
    /// per-stage wall time to it. Several lanes (the 8 chips of one
    /// shard) may share one set.
    pub fn instrument(&mut self, stages: Arc<StageSet>) {
        self.stages = Some(stages);
    }

    /// Encode → transmit → record → inject → decode a run of words
    /// (chunked internally; state carries across calls).
    pub fn drive(&mut self, words: &[u64], approx: &[bool]) {
        drive_batches(
            &mut self.codec,
            &mut self.chan,
            &mut self.stats,
            self.faults.as_mut(),
            &mut self.fstats,
            words,
            approx,
            &mut self.wires,
            &mut self.decoded,
            self.stages.as_deref(),
        );
    }

    /// Drive this chip's lane of a shared [`LineChunk`] — the zero-copy
    /// entry every queue worker uses: the chunk is a borrowed view into
    /// the trace (or a frozen pending buffer), and only the per-batch
    /// lane gather into the local buffers below ever touches the data.
    pub fn drive_chunk(&mut self, chip: usize, chunk: &LineChunk) {
        let mut words = [0u64; ENCODE_BATCH];
        let mut flags = [false; ENCODE_BATCH];
        let mut pos = 0;
        while pos < chunk.len() {
            let n = (chunk.len() - pos).min(ENCODE_BATCH);
            let mut clock = StageClock::start(self.stages.as_deref());
            chunk.gather_chip(chip, pos, &mut words[..n]);
            chunk.fill_approx(pos, &mut flags[..n]);
            clock.lap(Stage::Gather);
            self.drive(&words[..n], &flags[..n]);
            pos += n;
        }
    }

    /// Words decoded so far.
    pub fn decoded_len(&self) -> usize {
        self.decoded.len()
    }

    /// Tear down into (decoded words, energy counts, encode stats,
    /// fault stats).
    pub fn finish(self) -> (Vec<u64>, EnergyCounts, EncodeStats, FaultStats) {
        (self.decoded, *self.chan.energy(), self.stats, self.fstats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::registry::CodecSpec;
    use crate::encoding::{default_registry, make_codec, ZacConfig};
    use crate::faults::FaultSpec;
    use crate::util::rng::seeded_rng;

    #[test]
    fn lane_matches_hand_rolled_scalar_loop() {
        let mut r = seeded_rng(77);
        let words: Vec<u64> = (0..700)
            .map(|i| if i % 9 == 0 { 0 } else { r.next_u64() & 0xFFF })
            .collect();
        let approx: Vec<bool> = (0..words.len()).map(|_| r.chance(0.7)).collect();

        let cfg = ZacConfig::zac_full(75, 1, 1);
        let (mut enc, mut dec) = make_codec(&cfg);
        let mut chan = ChipChannel::new();
        let mut stats = EncodeStats::default();
        let mut want = Vec::new();
        for (&w, &a) in words.iter().zip(&approx) {
            let wire = enc.encode(w, a);
            chan.transmit(&wire);
            stats.record(&wire, w);
            want.push(dec.decode(&wire));
        }

        let codec = default_registry()
            .build(&CodecSpec::from_config(&cfg))
            .unwrap();
        let mut lane = ChipLane::with_capacity(codec, words.len());
        // Irregular run lengths: chunk boundaries land everywhere.
        let (mut i, mut k) = (0usize, 0usize);
        while i < words.len() {
            let n = [3usize, ENCODE_BATCH, 1, 17][k % 4].min(words.len() - i);
            k += 1;
            lane.drive(&words[i..i + n], &approx[i..i + n]);
            i += n;
        }
        assert_eq!(lane.decoded_len(), words.len());
        let (decoded, counts, lane_stats, fstats) = lane.finish();
        assert_eq!(decoded, want);
        assert_eq!(counts, *chan.energy());
        assert_eq!(lane_stats, stats);
        // Perfect channel: nothing injected; observed errors are the
        // pure codec approximation.
        assert_eq!(fstats.injected_bits, 0);
        assert_eq!(fstats.injected_words, 0);
        assert_eq!(fstats.words, words.len() as u64);
        let approx_err: u64 = words
            .iter()
            .zip(&want)
            .map(|(&w, &d)| (w ^ d).count_ones() as u64)
            .sum();
        assert_eq!(fstats.observed_error_bits, approx_err);
    }

    #[test]
    fn drive_chunk_matches_drive_over_every_view_kind() {
        use crate::trace::{bytes_to_chip_words, LineChunk};
        use std::sync::Arc;
        let mut r = seeded_rng(80);
        let bytes: Vec<u8> = (0..600 * 64).map(|_| r.next_u32() as u8).collect();
        let store: Arc<[_]> = bytes_to_chip_words(&bytes).into();
        let flags: Vec<bool> = (0..store.len()).map(|_| r.chance(0.5)).collect();
        let spec = CodecSpec::from_config(&ZacConfig::zac_full(75, 1, 0));
        let build = || default_registry().build(&spec).unwrap();

        for chip in [0usize, 5] {
            // Reference: plain drive over the gathered lane.
            let mut want = ChipLane::new(build());
            let words: Vec<u64> = store.iter().map(|l| l[chip]).collect();
            want.drive(&words, &flags);
            let (want_dec, want_counts, want_stats, _) = want.finish();

            // Window views (uniform flags differ, so compare a per-line
            // from_lines chunk and window chunks separately).
            let mut lane = ChipLane::new(build());
            lane.drive_chunk(chip, &LineChunk::from_lines(store.to_vec(), flags.clone()));
            let (dec, counts, stats, _) = lane.finish();
            assert_eq!(dec, want_dec, "chip {chip} from_lines");
            assert_eq!(counts, want_counts);
            assert_eq!(stats, want_stats);

            // Indexed identity view ≡ window view, chunked irregularly
            // (spans > ENCODE_BATCH exercise the internal chunking).
            let mut by_window = ChipLane::new(build());
            let mut by_index = ChipLane::new(build());
            let mut pos = 0;
            for span in [300usize, 1, 299] {
                by_window.drive_chunk(chip, &LineChunk::window(store.clone(), pos, span, true));
                let idx: Vec<u32> = (pos..pos + span).map(|i| i as u32).collect();
                by_index.drive_chunk(chip, &LineChunk::indexed(store.clone(), idx, true));
                pos += span;
            }
            let (wd, wc, ws, _) = by_window.finish();
            let (id, ic, is_, _) = by_index.finish();
            assert_eq!(wd, id, "chip {chip} window vs indexed");
            assert_eq!(wc, ic);
            assert_eq!(ws, is_);
        }
    }

    #[test]
    fn instrumented_lane_is_bit_identical_and_records_stages() {
        use crate::obs::StageSet;
        let mut r = seeded_rng(81);
        let words: Vec<u64> = (0..3 * ENCODE_BATCH + 11).map(|_| r.next_u64()).collect();
        let approx = vec![true; words.len()];
        let spec = CodecSpec::from_config(&ZacConfig::zac_full(80, 1, 1));
        let build = || default_registry().build(&spec).unwrap();

        let mut plain = ChipLane::with_capacity(build(), words.len());
        plain.drive(&words, &approx);
        let (want_dec, want_counts, want_stats, want_f) = plain.finish();

        let set = Arc::new(StageSet::default());
        let mut timed = ChipLane::with_capacity(build(), words.len());
        timed.instrument(set.clone());
        timed.drive(&words, &approx);
        let (dec, counts, stats, fstats) = timed.finish();
        assert_eq!(dec, want_dec);
        assert_eq!(counts, want_counts);
        assert_eq!(stats, want_stats);
        assert_eq!(fstats, want_f);
        // 4 batches (3 full + the 11-word tail), each timed.
        assert_eq!(set.batches(), 4);
        assert!(set.ns(Stage::Encode) > 0 || set.ns(Stage::Transmit) > 0);
    }

    #[test]
    fn injection_corrupts_approx_words_and_counts_them() {
        let mut r = seeded_rng(78);
        let words: Vec<u64> = (0..2048).map(|_| r.next_u64()).collect();
        let approx = vec![true; words.len()];
        let spec = FaultSpec::uniform(0.01).with_seed(5);

        // ORG is a passthrough, so every injected flip surfaces 1:1 in
        // the decoded stream.
        let build = || {
            default_registry()
                .build(&CodecSpec::named("ORG"))
                .unwrap()
        };
        let mut clean = ChipLane::with_capacity(build(), words.len());
        clean.drive(&words, &approx);
        let (clean_out, clean_counts, _, clean_f) = clean.finish();
        assert_eq!(clean_out, words);
        assert_eq!(clean_f.injected_bits, 0);

        let mut faulty = ChipLane::with_faults(build(), words.len(), spec.build(0, 0));
        faulty.drive(&words, &approx);
        let (out, counts, _, fstats) = faulty.finish();
        assert!(fstats.injected_bits > 0, "no flips at 1% BER");
        assert_eq!(fstats.observed_error_bits, fstats.injected_bits);
        let hamming: u64 = words
            .iter()
            .zip(&out)
            .map(|(&w, &d)| (w ^ d).count_ones() as u64)
            .sum();
        assert_eq!(hamming, fstats.injected_bits);
        // Energy is counted at transmit time, before injection.
        assert_eq!(counts, clean_counts);
    }

    #[test]
    fn critical_words_bypass_injection() {
        let mut r = seeded_rng(79);
        let words: Vec<u64> = (0..1024).map(|_| r.next_u64()).collect();
        let approx = vec![false; words.len()];
        let codec = default_registry()
            .build(&CodecSpec::named("ORG"))
            .unwrap();
        let mut lane = ChipLane::with_faults(
            codec,
            words.len(),
            FaultSpec::uniform(0.5).with_seed(6).build(0, 0),
        );
        lane.drive(&words, &approx);
        let (out, _, _, fstats) = lane.finish();
        assert_eq!(out, words, "critical traffic must be exact");
        assert_eq!(fstats.injected_bits, 0);
        assert_eq!(fstats.observed_error_bits, 0);
    }
}
