//! The one shared chip drive loop. Every driver — the legacy
//! [`run_chip_stream`](super::run_chip_stream), the batch coordinator,
//! the [`Pipeline`](crate::coordinator::Pipeline) workers, the
//! channel-array shard service loops and [`Session`](crate::session::Session)
//! runs — moves words through the same
//! encode_batch → transmit_batch → record_batch → decode_batch body,
//! so the batch contract (bit-identical to scalar, stateful across
//! calls, no allocation) is enforced in exactly one place.

use crate::channel::{ChipChannel, EnergyCounts};

use super::registry::Codec;
use super::stats::EncodeStats;
use super::wire::WireWord;
use super::ENCODE_BATCH;

/// Drive a word stream through one chip's codec and channel in
/// [`ENCODE_BATCH`]-sized chunks over the caller's buffers. `wires`
/// must hold at least `min(words.len(), ENCODE_BATCH)` slots; decoded
/// words append to `out`.
pub fn drive_batches(
    codec: &mut Codec,
    chan: &mut ChipChannel,
    stats: &mut EncodeStats,
    words: &[u64],
    approx: &[bool],
    wires: &mut [WireWord],
    out: &mut Vec<u64>,
) {
    assert_eq!(words.len(), approx.len());
    assert!(wires.len() >= words.len().min(ENCODE_BATCH));
    for (wc, ac) in words.chunks(ENCODE_BATCH).zip(approx.chunks(ENCODE_BATCH)) {
        let buf = &mut wires[..wc.len()];
        codec.encoder.encode_batch(wc, ac, buf);
        chan.transmit_batch(buf);
        stats.record_batch(buf, wc);
        codec.decoder.decode_batch(buf, out);
    }
}

/// One chip's full lane state: codec + channel + stats + decoded output
/// and the reusable wire buffer. Workers own one `ChipLane` per chip and
/// feed it word runs of any length.
pub struct ChipLane {
    codec: Codec,
    chan: ChipChannel,
    stats: EncodeStats,
    decoded: Vec<u64>,
    wires: [WireWord; ENCODE_BATCH],
}

impl ChipLane {
    pub fn new(codec: Codec) -> ChipLane {
        ChipLane::with_capacity(codec, 0)
    }

    /// Lane with the decoded buffer preallocated for `nwords` words.
    pub fn with_capacity(codec: Codec, nwords: usize) -> ChipLane {
        ChipLane {
            codec,
            chan: ChipChannel::new(),
            stats: EncodeStats::default(),
            decoded: Vec::with_capacity(nwords),
            wires: [WireWord::raw(0); ENCODE_BATCH],
        }
    }

    /// Encode → transmit → record → decode a run of words (chunked
    /// internally; state carries across calls).
    pub fn drive(&mut self, words: &[u64], approx: &[bool]) {
        drive_batches(
            &mut self.codec,
            &mut self.chan,
            &mut self.stats,
            words,
            approx,
            &mut self.wires,
            &mut self.decoded,
        );
    }

    /// Words decoded so far.
    pub fn decoded_len(&self) -> usize {
        self.decoded.len()
    }

    /// Tear down into (decoded words, energy counts, encode stats).
    pub fn finish(self) -> (Vec<u64>, EnergyCounts, EncodeStats) {
        (self.decoded, *self.chan.energy(), self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::registry::CodecSpec;
    use crate::encoding::{default_registry, make_codec, ZacConfig};
    use crate::util::rng::Rng;

    #[test]
    fn lane_matches_hand_rolled_scalar_loop() {
        let mut r = Rng::new(77);
        let words: Vec<u64> = (0..700)
            .map(|i| if i % 9 == 0 { 0 } else { r.next_u64() & 0xFFF })
            .collect();
        let approx: Vec<bool> = (0..words.len()).map(|_| r.chance(0.7)).collect();

        let cfg = ZacConfig::zac_full(75, 1, 1);
        let (mut enc, mut dec) = make_codec(&cfg);
        let mut chan = ChipChannel::new();
        let mut stats = EncodeStats::default();
        let mut want = Vec::new();
        for (&w, &a) in words.iter().zip(&approx) {
            let wire = enc.encode(w, a);
            chan.transmit(&wire);
            stats.record(&wire, w);
            want.push(dec.decode(&wire));
        }

        let codec = default_registry()
            .build(&CodecSpec::from_config(&cfg))
            .unwrap();
        let mut lane = ChipLane::with_capacity(codec, words.len());
        // Irregular run lengths: chunk boundaries land everywhere.
        let (mut i, mut k) = (0usize, 0usize);
        while i < words.len() {
            let n = [3usize, ENCODE_BATCH, 1, 17][k % 4].min(words.len() - i);
            k += 1;
            lane.drive(&words[i..i + n], &approx[i..i + n]);
            i += n;
        }
        assert_eq!(lane.decoded_len(), words.len());
        let (decoded, counts, lane_stats) = lane.finish();
        assert_eq!(decoded, want);
        assert_eq!(counts, *chan.energy());
        assert_eq!(lane_stats, stats);
    }
}
