//! The BD-Coder / ZAC-DEST data table: a software model of the NOR-CAM
//! of Fig. 6 (64 entries × 64 bits per DRAM chip, mirrored at the
//! memory controller).
//!
//! Hardware correspondence:
//! * `most_similar` = the CAM search phase (SL/SL' compare + replica-row
//!   hamming count); ties resolve to the lowest slot index, as a
//!   priority encoder would.
//! * `most_similar_sliced` / `most_similar_batch` = the same search over
//!   a column-major (bit-plane) mirror of the array: one XOR compares
//!   the query bit against *all* rows at once, exactly like the CAM's
//!   search lines driving every row in parallel.
//! * `contains` = the exact-match CAM lookup MBDC uses to keep entries
//!   unique.
//! * `push` = FIFO write via BL/BL' (round-robin replacement, matching
//!   BD-Coder's update behaviour).

/// Fixed-capacity FIFO CAM model, kept in two mirrored layouts:
///
/// * row-major `entries` (slot -> word), the reference layout;
/// * column-major `planes` (bit -> one u64 whose bit *s* is bit *b* of
///   slot *s*), maintained incrementally and only when the capacity fits
///   the 64 lanes of a word (`capacity <= 64`, always true for paper
///   configs — `ZacConfig::validate` caps `table_size` at 64).
#[derive(Clone, Debug)]
pub struct DataTable {
    entries: Vec<u64>,
    /// Bit-plane mirror: `planes[b]` bit `s` == bit `b` of `entries[s]`.
    /// Stale above `len` (masked out by every sliced search).
    planes: [u64; 64],
    /// Next slot to overwrite (round-robin FIFO).
    head: usize,
    /// Number of valid entries (≤ capacity).
    len: usize,
}

/// Result of a most-similar-entry search.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SearchHit {
    /// Slot index of the most similar entry (wire index).
    pub index: usize,
    /// The stored word.
    pub entry: u64,
    /// Hamming distance to the query.
    pub distance: u32,
}

impl DataTable {
    /// An empty table with `capacity` slots (paper: 64).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        DataTable {
            entries: vec![0; capacity],
            planes: [0; 64],
            head: 0,
            len: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.entries.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether the bit-plane mirror covers this table (it needs one lane
    /// per slot in a `u64`).
    #[inline]
    fn bit_sliced(&self) -> bool {
        self.entries.len() <= 64
    }

    /// Lane mask of the valid slots (callable only when `bit_sliced`).
    #[inline]
    fn valid_mask(&self) -> u64 {
        if self.len >= 64 {
            u64::MAX
        } else {
            (1u64 << self.len) - 1
        }
    }

    /// The FIFO slot the next `push` will write (wire-visible write
    /// address in BDE_ORG's raw branch).
    pub fn next_slot(&self) -> usize {
        self.head
    }

    /// Entry at a wire index (panics if out of the valid range — the
    /// decoder can only receive indices the encoder produced).
    pub fn get(&self, index: usize) -> u64 {
        debug_assert!(index < self.len, "index {index} >= len {}", self.len);
        self.entries[index]
    }

    /// Entry at a wire index, or 0 when the index points outside the
    /// valid entries. This is the total variant the fault-tolerant
    /// decode paths use: wire-level injection can synthesize an address
    /// the encoder never produced, and a real receiver reads *some*
    /// deterministic value rather than faulting (an unwritten CAM row
    /// reads as zeros here).
    pub fn get_or_zero(&self, index: usize) -> u64 {
        if index < self.len {
            self.entries[index]
        } else {
            0
        }
    }

    /// CAM search: the valid entry with minimum hamming distance to
    /// `word`; ties resolve to the lowest index. `None` when empty.
    ///
    /// Reference (row-major) implementation: the (distance, index) pair
    /// is packed as `distance * 256 + index`, so a single branchless
    /// `min` (cmov) yields both the minimum distance *and* the
    /// lowest-index tie-break; the XOR+POPCNT per entry pipelines with
    /// no data-dependent branches in the loop. The bit-sliced variants
    /// below must stay bit-identical to this oracle
    /// (`search_matches_naive_reference`).
    #[inline]
    pub fn most_similar(&self, word: u64) -> Option<SearchHit> {
        if self.len == 0 {
            return None;
        }
        debug_assert!(self.entries.len() <= 256, "packed key assumes index < 256");
        let mut best_key = u32::MAX;
        for (i, &e) in self.entries[..self.len].iter().enumerate() {
            let key = ((e ^ word).count_ones() << 8) | i as u32;
            best_key = best_key.min(key);
        }
        let index = (best_key & 0xFF) as usize;
        Some(SearchHit {
            index,
            entry: self.entries[index],
            distance: best_key >> 8,
        })
    }

    /// Bit-sliced CAM search: compare `word` against **all** entries at
    /// once, one bit plane per step — the software analogue of the
    /// NOR-CAM match phase where the search lines drive every row
    /// simultaneously.
    ///
    /// Per plane, one XOR against the broadcast query bit yields the
    /// per-entry mismatch lane vector, which is accumulated into seven
    /// vertical (bit-serial SWAR) counters: bit *s* of `counts[k]` is
    /// bit *k* of entry *s*'s running hamming distance (≤ 64, so 7
    /// planes suffice). The argmin then narrows a candidate lane mask
    /// from the counter MSB down, and `trailing_zeros` plays the
    /// priority encoder for the lowest-index tie-break.
    ///
    /// Falls back to the row-major scan for capacities above 64 (no
    /// plane mirror). Bit-identical to [`Self::most_similar`].
    pub fn most_similar_sliced(&self, word: u64) -> Option<SearchHit> {
        if self.len == 0 {
            return None;
        }
        if !self.bit_sliced() {
            return self.most_similar(word);
        }
        let mut counts = [0u64; 7];
        for (b, &plane) in self.planes.iter().enumerate() {
            // Broadcast query bit b across all 64 lanes (all-ones when set).
            let query = ((word >> b) & 1).wrapping_neg();
            // Ripple the per-entry mismatch bit into the vertical counters;
            // the carry thins out geometrically, so this loop runs ~2
            // levels on average.
            let mut carry = plane ^ query;
            for c in counts.iter_mut() {
                let t = *c & carry;
                *c ^= carry;
                carry = t;
                if carry == 0 {
                    break;
                }
            }
        }
        // Minimum distance over valid lanes: from the counter MSB down,
        // any candidate with a 0 at this magnitude beats every candidate
        // with a 1.
        let mut cand = self.valid_mask();
        for c in counts.iter().rev() {
            let zeros = cand & !c;
            if zeros != 0 {
                cand = zeros;
            }
        }
        let index = cand.trailing_zeros() as usize;
        let mut distance = 0u32;
        for (k, c) in counts.iter().enumerate() {
            distance |= (((c >> index) & 1) as u32) << k;
        }
        Some(SearchHit {
            index,
            entry: self.entries[index],
            distance,
        })
    }

    /// Batched fixed-table search: resolves each query exactly as
    /// [`Self::most_similar`] would against the *current* table state
    /// (callers interleaving `push` must re-issue). Results are appended
    /// to `out` after clearing it, so a preallocated buffer is reused
    /// across batches.
    pub fn most_similar_batch(&self, queries: &[u64], out: &mut Vec<Option<SearchHit>>) {
        out.clear();
        out.reserve(queries.len());
        for &q in queries {
            out.push(self.most_similar_sliced(q));
        }
    }

    /// Exact-match CAM lookup. With the plane mirror this is an
    /// AND-reduction over bit planes with early exit (a random mismatch
    /// kills every lane within a few planes).
    pub fn contains(&self, word: u64) -> bool {
        if self.len == 0 {
            return false;
        }
        if !self.bit_sliced() {
            return self.entries[..self.len].contains(&word);
        }
        let mut lanes = self.valid_mask();
        for (b, &plane) in self.planes.iter().enumerate() {
            let query = ((word >> b) & 1).wrapping_neg();
            lanes &= !(plane ^ query);
            if lanes == 0 {
                return false;
            }
        }
        true
    }

    /// FIFO insert (BD-Coder update policy: overwrite the oldest slot).
    pub fn push(&mut self, word: u64) {
        let slot = self.head;
        // Compare-and-wrap: no division on the hot path.
        self.head += 1;
        if self.head == self.entries.len() {
            self.head = 0;
        }
        if self.len < self.entries.len() {
            self.len += 1;
        }
        // Incremental plane maintenance: only the planes where the new
        // word differs from the overwritten one change — cheap exactly
        // when the stream is similar, which is when pushes also matter.
        if self.bit_sliced() {
            let slot_bit = 1u64 << slot;
            let mut diff = self.entries[slot] ^ word;
            while diff != 0 {
                self.planes[diff.trailing_zeros() as usize] ^= slot_bit;
                diff &= diff - 1;
            }
        }
        self.entries[slot] = word;
    }

    /// Insert only if not already present (MBDC dedup policy, §IV-A).
    /// Returns true if inserted.
    pub fn push_unique(&mut self, word: u64) -> bool {
        if self.contains(word) {
            return false;
        }
        self.push(word);
        true
    }

    /// Clear all entries. The plane mirror tracks the full `entries`
    /// array (stale slots are masked by `len`), so it stays valid
    /// without being touched.
    pub fn reset(&mut self) {
        self.head = 0;
        self.len = 0;
    }

    /// Valid entries in slot order (for the L2 `trace_screen` bridge and
    /// the figure harness).
    pub fn snapshot(&self) -> &[u64] {
        &self.entries[..self.len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn empty_table_has_no_hit() {
        assert!(DataTable::new(4).most_similar(123).is_none());
        assert!(DataTable::new(4).most_similar_sliced(123).is_none());
    }

    #[test]
    fn finds_exact_then_nearest() {
        let mut t = DataTable::new(8);
        t.push(0xFF);
        t.push(0x0F);
        let h = t.most_similar(0x0F).unwrap();
        assert_eq!((h.index, h.distance), (1, 0));
        let h = t.most_similar(0x1F).unwrap();
        assert_eq!(h.entry, 0x0F);
        assert_eq!(h.distance, 1);
    }

    #[test]
    fn tie_breaks_to_lowest_index() {
        let mut t = DataTable::new(4);
        t.push(0b0001); // distance 1 from 0b0000
        t.push(0b0010); // also distance 1
        let h = t.most_similar(0).unwrap();
        assert_eq!(h.index, 0);
        let h = t.most_similar_sliced(0).unwrap();
        assert_eq!(h.index, 0);
    }

    #[test]
    fn fifo_overwrites_oldest() {
        let mut t = DataTable::new(2);
        t.push(1);
        t.push(2);
        t.push(3); // evicts 1
        assert!(!t.contains(1));
        assert!(t.contains(2) && t.contains(3));
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn push_unique_dedups() {
        let mut t = DataTable::new(4);
        assert!(t.push_unique(7));
        assert!(!t.push_unique(7));
        assert_eq!(t.len(), 1);
    }

    /// Naive argmin with lowest-index ties — the oracle every search
    /// implementation must match bit-for-bit.
    fn naive_argmin(t: &DataTable, q: u64) -> (usize, u32) {
        let (mut bi, mut bd) = (0usize, u32::MAX);
        for (i, &e) in t.snapshot().iter().enumerate() {
            let d = (e ^ q).count_ones();
            if d < bd {
                bd = d;
                bi = i;
            }
        }
        (bi, bd)
    }

    #[test]
    fn search_matches_naive_reference() {
        let mut r = Rng::new(9);
        let mut t = DataTable::new(64);
        for _ in 0..64 {
            t.push(r.next_u64());
        }
        for _ in 0..500 {
            let q = r.next_u64();
            let (bi, bd) = naive_argmin(&t, q);
            let hit = t.most_similar(q).unwrap();
            assert_eq!((hit.index, hit.distance), (bi, bd));
            let hit = t.most_similar_sliced(q).unwrap();
            assert_eq!((hit.index, hit.distance), (bi, bd), "sliced");
        }
    }

    #[test]
    fn sliced_matches_oracle_across_fill_levels_and_sizes() {
        // Partially-filled and odd-sized tables, near-duplicate queries
        // (tie-heavy), and words at the extremes.
        let mut r = Rng::new(10);
        for cap in [1usize, 2, 7, 16, 63, 64] {
            let mut t = DataTable::new(cap);
            for round in 0..(cap * 3) {
                t.push(if round % 3 == 0 { 0 } else { r.next_u64() });
                for _ in 0..20 {
                    let q = match r.below(4) {
                        0 => 0,
                        1 => u64::MAX,
                        2 => t.snapshot()[r.below(t.len() as u64) as usize]
                            ^ (1u64 << r.below(64)),
                        _ => r.next_u64(),
                    };
                    let (bi, bd) = naive_argmin(&t, q);
                    let hit = t.most_similar_sliced(q).unwrap();
                    assert_eq!(
                        (hit.index, hit.distance),
                        (bi, bd),
                        "cap {cap} round {round} query {q:#x}"
                    );
                    assert_eq!(hit.entry, t.get(bi));
                }
            }
        }
    }

    #[test]
    fn batch_search_matches_oracle() {
        let mut r = Rng::new(11);
        let mut t = DataTable::new(64);
        for _ in 0..40 {
            t.push(r.next_u64());
        }
        let queries: Vec<u64> = (0..257).map(|_| r.next_u64()).collect();
        let mut hits = Vec::new();
        t.most_similar_batch(&queries, &mut hits);
        assert_eq!(hits.len(), queries.len());
        for (q, hit) in queries.iter().zip(&hits) {
            let hit = hit.expect("table not empty");
            assert_eq!((hit.index, hit.distance), naive_argmin(&t, *q));
        }
        // Reuses the buffer (cleared, not appended).
        t.most_similar_batch(&queries[..3], &mut hits);
        assert_eq!(hits.len(), 3);
    }

    #[test]
    fn contains_agrees_with_linear_scan() {
        let mut r = Rng::new(12);
        let mut t = DataTable::new(32);
        for _ in 0..48 {
            t.push(r.next_u64() & 0xFF); // small domain => real collisions
            for _ in 0..8 {
                let q = r.next_u64() & 0xFF;
                assert_eq!(t.contains(q), t.snapshot().contains(&q), "{q:#x}");
            }
        }
    }

    #[test]
    fn planes_survive_wraparound_and_reset() {
        let mut r = Rng::new(13);
        let mut t = DataTable::new(8);
        for _ in 0..100 {
            t.push(r.next_u64());
        }
        t.reset();
        assert!(t.most_similar_sliced(1).is_none());
        // Refill after reset: the mirror must still agree with the oracle.
        for _ in 0..12 {
            t.push(r.next_u64());
        }
        for _ in 0..100 {
            let q = r.next_u64();
            let hit = t.most_similar_sliced(q).unwrap();
            assert_eq!((hit.index, hit.distance), naive_argmin(&t, q));
        }
    }

    #[test]
    fn get_returns_pushed_value() {
        let mut t = DataTable::new(64);
        for i in 0..10u64 {
            t.push(i * 1000);
        }
        for i in 0..10usize {
            assert_eq!(t.get(i), i as u64 * 1000);
        }
    }
}
