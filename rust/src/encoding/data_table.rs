//! The BD-Coder / ZAC-DEST data table: a software model of the NOR-CAM
//! of Fig. 6 (64 entries × 64 bits per DRAM chip, mirrored at the
//! memory controller).
//!
//! Hardware correspondence:
//! * `most_similar` = the CAM search phase (SL/SL' compare + replica-row
//!   hamming count); ties resolve to the lowest slot index, as a
//!   priority encoder would.
//! * `most_similar_sliced` / `most_similar_batch` = the same search
//!   dispatched to the backend captured at construction (see
//!   [`simd`]): the portable path runs over a column-major (bit-plane)
//!   mirror of the array — one XOR compares the query bit against a
//!   whole 64-slot lane group at once, exactly like the CAM's search
//!   lines driving every row in parallel — while the AVX2/NEON kernels
//!   run vectorized XOR+popcount over the row-major entries. All
//!   backends are pinned bit-identical to [`DataTable::most_similar`].
//! * `contains` = the exact-match CAM lookup MBDC uses to keep entries
//!   unique (dispatched the same way).
//! * `push` = FIFO write via BL/BL' (round-robin replacement, matching
//!   BD-Coder's update behaviour).

use crate::encoding::simd::{self, Backend};

/// Fixed-capacity FIFO CAM model, kept in two mirrored layouts:
///
/// * row-major `entries` (slot -> word), the reference layout;
/// * column-major `planes` (one 64-plane group per 64 slots, so any
///   capacity is covered — paper configs stay at one group,
///   `table_size <= 64`), maintained incrementally on every push.
#[derive(Clone, Debug)]
pub struct DataTable {
    entries: Vec<u64>,
    /// Bit-plane mirror, lane-group major: `planes[(s / 64) * 64 + b]`
    /// bit `s % 64` == bit `b` of `entries[s]`. Stale above `len`
    /// (masked out by every sliced search).
    planes: Vec<u64>,
    /// Next slot to overwrite (round-robin FIFO).
    head: usize,
    /// Number of valid entries (≤ capacity).
    len: usize,
    /// Search backend captured at construction ([`simd::current`]).
    backend: Backend,
}

/// Result of a most-similar-entry search.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SearchHit {
    /// Slot index of the most similar entry (wire index).
    pub index: usize,
    /// The stored word.
    pub entry: u64,
    /// Hamming distance to the query.
    pub distance: u32,
}

impl DataTable {
    /// An empty table with `capacity` slots (paper: 64), searching with
    /// the thread's current dispatched backend.
    pub fn new(capacity: usize) -> Self {
        Self::with_backend(capacity, simd::current())
    }

    /// As [`Self::new`] with an explicit search backend — the
    /// bit-identity property tests and the `simd_compare` bench pin
    /// backends side by side regardless of the process default.
    pub fn with_backend(capacity: usize, backend: Backend) -> Self {
        assert!(capacity > 0);
        // The packed search key carries the slot index in its low 32
        // bits (`simd::most_similar_scalar`), so the index must fit — a
        // hard error here, not a debug_assert a release build skips.
        assert!(
            capacity <= u32::MAX as usize,
            "DataTable capacity {capacity} exceeds the packed-key limit of 2^32 - 1"
        );
        let groups = capacity.div_ceil(64);
        DataTable {
            entries: vec![0; capacity],
            planes: vec![0; groups * 64],
            head: 0,
            len: 0,
            backend,
        }
    }

    pub fn capacity(&self) -> usize {
        self.entries.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The search backend this table dispatches to.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Lane mask of the valid slots within one 64-slot plane group.
    #[inline]
    fn group_valid_mask(&self, group: usize) -> u64 {
        let filled = self.len.saturating_sub(group * 64);
        if filled >= 64 {
            u64::MAX
        } else {
            (1u64 << filled) - 1
        }
    }

    /// The FIFO slot the next `push` will write (wire-visible write
    /// address in BDE_ORG's raw branch).
    pub fn next_slot(&self) -> usize {
        self.head
    }

    /// Entry at a wire index (panics if out of the valid range — the
    /// decoder can only receive indices the encoder produced).
    pub fn get(&self, index: usize) -> u64 {
        debug_assert!(index < self.len, "index {index} >= len {}", self.len);
        self.entries[index]
    }

    /// Entry at a wire index, or 0 when the index points outside the
    /// valid entries. This is the total variant the fault-tolerant
    /// decode paths use: wire-level injection can synthesize an address
    /// the encoder never produced, and a real receiver reads *some*
    /// deterministic value rather than faulting (an unwritten CAM row
    /// reads as zeros here).
    pub fn get_or_zero(&self, index: usize) -> u64 {
        if index < self.len {
            self.entries[index]
        } else {
            0
        }
    }

    /// CAM search: the valid entry with minimum hamming distance to
    /// `word`; ties resolve to the lowest index. `None` when empty.
    ///
    /// Reference (row-major) implementation: delegates to the portable
    /// scalar kernel, which packs the (distance, index) pair as
    /// `(distance << 32) | index` so a single branchless `min` (cmov)
    /// yields both the minimum distance *and* the lowest-index
    /// tie-break; the XOR+POPCNT per entry pipelines with no
    /// data-dependent branches in the loop. Every dispatched backend
    /// must stay bit-identical to this oracle
    /// (`search_matches_naive_reference`, `rust/tests/simd_backends.rs`).
    #[inline]
    pub fn most_similar(&self, word: u64) -> Option<SearchHit> {
        if self.len == 0 {
            return None;
        }
        let (index, distance) = simd::most_similar_scalar(&self.entries[..self.len], word);
        Some(SearchHit {
            index,
            entry: self.entries[index],
            distance,
        })
    }

    /// Backend-dispatched CAM search: compare `word` against **all**
    /// entries at once — the software analogue of the NOR-CAM match
    /// phase where the search lines drive every row simultaneously.
    ///
    /// The portable scalar backend runs [`Self::plane_argmin`] over the
    /// bit-plane mirror; AVX2/NEON run vectorized row-major kernels
    /// (`simd::most_similar`). Bit-identical to [`Self::most_similar`]
    /// on every backend.
    pub fn most_similar_sliced(&self, word: u64) -> Option<SearchHit> {
        if self.len == 0 {
            return None;
        }
        let (index, distance) = match self.backend {
            Backend::Scalar => self.plane_argmin(word),
            b => simd::most_similar(b, &self.entries[..self.len], word),
        };
        Some(SearchHit {
            index,
            entry: self.entries[index],
            distance,
        })
    }

    /// Bit-sliced argmin over the plane mirror (the scalar backend's
    /// search path), one 64-slot lane group at a time.
    ///
    /// Per plane, one XOR against the broadcast query bit yields the
    /// per-entry mismatch lane vector, which is accumulated into seven
    /// vertical (bit-serial SWAR) counters: bit *s* of `counts[k]` is
    /// bit *k* of entry *s*'s running hamming distance (≤ 64, so 7
    /// planes suffice). The argmin then narrows a candidate lane mask
    /// from the counter MSB down, and `trailing_zeros` plays the
    /// priority encoder for the lowest-index tie-break; groups fold
    /// together through the same packed `(distance << 32) | index` key
    /// as the row-major oracle, so earlier groups win ties.
    fn plane_argmin(&self, word: u64) -> (usize, u32) {
        let mut best_key = u64::MAX;
        for group in 0..self.planes.len() / 64 {
            let base = group * 64;
            if base >= self.len {
                break;
            }
            let mut counts = [0u64; 7];
            for (b, &plane) in self.planes[base..base + 64].iter().enumerate() {
                // Broadcast query bit b across all 64 lanes (all-ones when set).
                let query = ((word >> b) & 1).wrapping_neg();
                // Ripple the per-entry mismatch bit into the vertical
                // counters; the carry thins out geometrically, so this
                // loop runs ~2 levels on average.
                let mut carry = plane ^ query;
                for c in counts.iter_mut() {
                    let t = *c & carry;
                    *c ^= carry;
                    carry = t;
                    if carry == 0 {
                        break;
                    }
                }
            }
            // Minimum distance over valid lanes: from the counter MSB
            // down, any candidate with a 0 at this magnitude beats every
            // candidate with a 1.
            let mut cand = self.group_valid_mask(group);
            for c in counts.iter().rev() {
                let zeros = cand & !c;
                if zeros != 0 {
                    cand = zeros;
                }
            }
            let slot = cand.trailing_zeros() as usize;
            let mut distance = 0u32;
            for (k, c) in counts.iter().enumerate() {
                distance |= (((c >> slot) & 1) as u32) << k;
            }
            best_key = best_key.min((u64::from(distance) << 32) | (base + slot) as u64);
        }
        ((best_key & 0xFFFF_FFFF) as usize, (best_key >> 32) as u32)
    }

    /// Batched fixed-table search: resolves each query exactly as
    /// [`Self::most_similar`] would against the *current* table state
    /// (callers interleaving `push` must re-issue), routed through the
    /// table's dispatched backend. Results are appended to `out` after
    /// clearing it, so a preallocated buffer is reused across batches.
    pub fn most_similar_batch(&self, queries: &[u64], out: &mut Vec<Option<SearchHit>>) {
        out.clear();
        out.reserve(queries.len());
        for &q in queries {
            out.push(self.most_similar_sliced(q));
        }
    }

    /// Exact-match CAM lookup, dispatched like the search: the scalar
    /// backend AND-reduces bit planes with early exit (a random
    /// mismatch kills every lane within a few planes); AVX2/NEON
    /// compare vectors of row-major slots.
    pub fn contains(&self, word: u64) -> bool {
        if self.len == 0 {
            return false;
        }
        match self.backend {
            Backend::Scalar => self.plane_contains(word),
            b => simd::contains(b, &self.entries[..self.len], word),
        }
    }

    /// Plane-mirror exact-match (the scalar backend's `contains` path).
    fn plane_contains(&self, word: u64) -> bool {
        for group in 0..self.planes.len() / 64 {
            let base = group * 64;
            if base >= self.len {
                break;
            }
            let mut lanes = self.group_valid_mask(group);
            for (b, &plane) in self.planes[base..base + 64].iter().enumerate() {
                let query = ((word >> b) & 1).wrapping_neg();
                lanes &= !(plane ^ query);
                if lanes == 0 {
                    break;
                }
            }
            if lanes != 0 {
                return true;
            }
        }
        false
    }

    /// FIFO insert (BD-Coder update policy: overwrite the oldest slot).
    pub fn push(&mut self, word: u64) {
        let slot = self.head;
        // Compare-and-wrap: no division on the hot path.
        self.head += 1;
        if self.head == self.entries.len() {
            self.head = 0;
        }
        if self.len < self.entries.len() {
            self.len += 1;
        }
        // Incremental plane maintenance: only the planes where the new
        // word differs from the overwritten one change — cheap exactly
        // when the stream is similar, which is when pushes also matter.
        let base = (slot / 64) * 64;
        let slot_bit = 1u64 << (slot % 64);
        let mut diff = self.entries[slot] ^ word;
        while diff != 0 {
            self.planes[base + diff.trailing_zeros() as usize] ^= slot_bit;
            diff &= diff - 1;
        }
        self.entries[slot] = word;
    }

    /// Insert only if not already present (MBDC dedup policy, §IV-A).
    /// Returns true if inserted.
    pub fn push_unique(&mut self, word: u64) -> bool {
        if self.contains(word) {
            return false;
        }
        self.push(word);
        true
    }

    /// Clear all entries. The plane mirror tracks the full `entries`
    /// array (stale slots are masked by `len`), so it stays valid
    /// without being touched.
    pub fn reset(&mut self) {
        self.head = 0;
        self.len = 0;
    }

    /// Valid entries in slot order (for the L2 `trace_screen` bridge and
    /// the figure harness).
    pub fn snapshot(&self) -> &[u64] {
        &self.entries[..self.len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn empty_table_has_no_hit() {
        assert!(DataTable::new(4).most_similar(123).is_none());
        assert!(DataTable::new(4).most_similar_sliced(123).is_none());
    }

    #[test]
    fn finds_exact_then_nearest() {
        let mut t = DataTable::new(8);
        t.push(0xFF);
        t.push(0x0F);
        let h = t.most_similar(0x0F).unwrap();
        assert_eq!((h.index, h.distance), (1, 0));
        let h = t.most_similar(0x1F).unwrap();
        assert_eq!(h.entry, 0x0F);
        assert_eq!(h.distance, 1);
    }

    #[test]
    fn tie_breaks_to_lowest_index() {
        let mut t = DataTable::new(4);
        t.push(0b0001); // distance 1 from 0b0000
        t.push(0b0010); // also distance 1
        let h = t.most_similar(0).unwrap();
        assert_eq!(h.index, 0);
        let h = t.most_similar_sliced(0).unwrap();
        assert_eq!(h.index, 0);
    }

    #[test]
    fn fifo_overwrites_oldest() {
        let mut t = DataTable::new(2);
        t.push(1);
        t.push(2);
        t.push(3); // evicts 1
        assert!(!t.contains(1));
        assert!(t.contains(2) && t.contains(3));
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn push_unique_dedups() {
        let mut t = DataTable::new(4);
        assert!(t.push_unique(7));
        assert!(!t.push_unique(7));
        assert_eq!(t.len(), 1);
    }

    /// Naive argmin with lowest-index ties — the oracle every search
    /// implementation must match bit-for-bit.
    fn naive_argmin(t: &DataTable, q: u64) -> (usize, u32) {
        let (mut bi, mut bd) = (0usize, u32::MAX);
        for (i, &e) in t.snapshot().iter().enumerate() {
            let d = (e ^ q).count_ones();
            if d < bd {
                bd = d;
                bi = i;
            }
        }
        (bi, bd)
    }

    #[test]
    fn search_matches_naive_reference() {
        let mut r = Rng::new(9);
        let mut t = DataTable::new(64);
        for _ in 0..64 {
            t.push(r.next_u64());
        }
        for _ in 0..500 {
            let q = r.next_u64();
            let (bi, bd) = naive_argmin(&t, q);
            let hit = t.most_similar(q).unwrap();
            assert_eq!((hit.index, hit.distance), (bi, bd));
            let hit = t.most_similar_sliced(q).unwrap();
            assert_eq!((hit.index, hit.distance), (bi, bd), "sliced");
        }
    }

    #[test]
    fn sliced_matches_oracle_across_fill_levels_and_sizes() {
        // Partially-filled and odd-sized tables, near-duplicate queries
        // (tie-heavy), and words at the extremes. Capacities span one
        // plane-lane group (≤ 64) and several (65..257).
        let mut r = Rng::new(10);
        for cap in [1usize, 2, 7, 16, 63, 64, 65, 100, 257] {
            let mut t = DataTable::new(cap);
            for round in 0..(cap.min(64) * 3) {
                t.push(if round % 3 == 0 { 0 } else { r.next_u64() });
                for _ in 0..20 {
                    let q = match r.below(4) {
                        0 => 0,
                        1 => u64::MAX,
                        2 => t.snapshot()[r.below(t.len() as u64) as usize]
                            ^ (1u64 << r.below(64)),
                        _ => r.next_u64(),
                    };
                    let (bi, bd) = naive_argmin(&t, q);
                    let hit = t.most_similar_sliced(q).unwrap();
                    assert_eq!(
                        (hit.index, hit.distance),
                        (bi, bd),
                        "cap {cap} round {round} query {q:#x}"
                    );
                    assert_eq!(hit.entry, t.get(bi));
                }
            }
        }
    }

    #[test]
    fn capacity_beyond_256_returns_exact_index() {
        // Regression for the release-mode packed-key truncation: the
        // old `(distance << 8) | index` u32 key silently wrapped
        // indices ≥ 256 (debug_assert-only guard), returning slot 0
        // here. The widened u64 key must report slot 256 exactly.
        let mut t = DataTable::new(257);
        for _ in 0..256 {
            t.push(u64::MAX);
        }
        t.push(0);
        let h = t.most_similar(0).unwrap();
        assert_eq!((h.index, h.entry, h.distance), (256, 0, 0));
        let h = t.most_similar_sliced(0).unwrap();
        assert_eq!((h.index, h.entry, h.distance), (256, 0, 0));
        assert!(t.contains(0));
    }

    #[test]
    fn multi_group_mirror_survives_wraparound() {
        // Capacities past one 64-slot lane group, driven through >2×
        // capacity so the FIFO wraps across group boundaries.
        let mut r = Rng::new(21);
        for cap in [65usize, 128, 130] {
            let mut t = DataTable::with_backend(cap, Backend::Scalar);
            for _ in 0..cap * 2 + 7 {
                t.push(r.next_u64() & 0xFFFF); // small domain => ties
                let q = r.next_u64() & 0xFFFF;
                let hit = t.most_similar_sliced(q).unwrap();
                assert_eq!((hit.index, hit.distance), naive_argmin(&t, q), "cap {cap}");
                assert_eq!(t.contains(q), t.snapshot().contains(&q), "cap {cap}");
            }
        }
    }

    #[test]
    fn batch_search_matches_oracle() {
        let mut r = Rng::new(11);
        let mut t = DataTable::new(64);
        for _ in 0..40 {
            t.push(r.next_u64());
        }
        let queries: Vec<u64> = (0..257).map(|_| r.next_u64()).collect();
        let mut hits = Vec::new();
        t.most_similar_batch(&queries, &mut hits);
        assert_eq!(hits.len(), queries.len());
        for (q, hit) in queries.iter().zip(&hits) {
            let hit = hit.expect("table not empty");
            assert_eq!((hit.index, hit.distance), naive_argmin(&t, *q));
        }
        // Reuses the buffer (cleared, not appended).
        t.most_similar_batch(&queries[..3], &mut hits);
        assert_eq!(hits.len(), 3);
    }

    #[test]
    fn contains_agrees_with_linear_scan() {
        let mut r = Rng::new(12);
        let mut t = DataTable::new(32);
        for _ in 0..48 {
            t.push(r.next_u64() & 0xFF); // small domain => real collisions
            for _ in 0..8 {
                let q = r.next_u64() & 0xFF;
                assert_eq!(t.contains(q), t.snapshot().contains(&q), "{q:#x}");
            }
        }
    }

    #[test]
    fn planes_survive_wraparound_and_reset() {
        let mut r = Rng::new(13);
        let mut t = DataTable::new(8);
        for _ in 0..100 {
            t.push(r.next_u64());
        }
        t.reset();
        assert!(t.most_similar_sliced(1).is_none());
        // Refill after reset: the mirror must still agree with the oracle.
        for _ in 0..12 {
            t.push(r.next_u64());
        }
        for _ in 0..100 {
            let q = r.next_u64();
            let hit = t.most_similar_sliced(q).unwrap();
            assert_eq!((hit.index, hit.distance), naive_argmin(&t, q));
        }
    }

    #[test]
    fn get_returns_pushed_value() {
        let mut t = DataTable::new(64);
        for i in 0..10u64 {
            t.push(i * 1000);
        }
        for i in 0..10usize {
            assert_eq!(t.get(i), i as u64 * 1000);
        }
    }

    #[test]
    fn with_backend_pins_the_backend() {
        let t = DataTable::with_backend(8, Backend::Scalar);
        assert_eq!(t.backend(), Backend::Scalar);
        let default = DataTable::new(8);
        assert!(simd::available_backends().contains(&default.backend()));
    }
}
