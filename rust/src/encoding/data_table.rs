//! The BD-Coder / ZAC-DEST data table: a software model of the NOR-CAM
//! of Fig. 6 (64 entries × 64 bits per DRAM chip, mirrored at the
//! memory controller).
//!
//! Hardware correspondence:
//! * `most_similar` = the CAM search phase (SL/SL' compare + replica-row
//!   hamming count); ties resolve to the lowest slot index, as a
//!   priority encoder would.
//! * `contains` = the exact-match CAM lookup MBDC uses to keep entries
//!   unique.
//! * `push` = FIFO write via BL/BL' (round-robin replacement, matching
//!   BD-Coder's update behaviour).

/// Fixed-capacity FIFO CAM model.
#[derive(Clone, Debug)]
pub struct DataTable {
    entries: Vec<u64>,
    /// Next slot to overwrite (round-robin FIFO).
    head: usize,
    /// Number of valid entries (≤ capacity).
    len: usize,
}

/// Result of a most-similar-entry search.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SearchHit {
    /// Slot index of the most similar entry (wire index).
    pub index: usize,
    /// The stored word.
    pub entry: u64,
    /// Hamming distance to the query.
    pub distance: u32,
}

impl DataTable {
    /// An empty table with `capacity` slots (paper: 64).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        DataTable {
            entries: vec![0; capacity],
            head: 0,
            len: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.entries.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The FIFO slot the next `push` will write (wire-visible write
    /// address in BDE_ORG's raw branch).
    pub fn next_slot(&self) -> usize {
        self.head
    }

    /// Entry at a wire index (panics if out of the valid range — the
    /// decoder can only receive indices the encoder produced).
    pub fn get(&self, index: usize) -> u64 {
        debug_assert!(index < self.len, "index {index} >= len {}", self.len);
        self.entries[index]
    }

    /// CAM search: the valid entry with minimum hamming distance to
    /// `word`; ties resolve to the lowest index. `None` when empty.
    ///
    /// Hot path: the (distance, index) pair is packed as
    /// `distance * 256 + index`, so a single branchless `min` (cmov)
    /// yields both the minimum distance *and* the lowest-index
    /// tie-break; the XOR+POPCNT per entry pipelines with no
    /// data-dependent branches in the loop.
    #[inline]
    pub fn most_similar(&self, word: u64) -> Option<SearchHit> {
        if self.len == 0 {
            return None;
        }
        debug_assert!(self.entries.len() <= 256, "packed key assumes index < 256");
        let mut best_key = u32::MAX;
        for (i, &e) in self.entries[..self.len].iter().enumerate() {
            let key = ((e ^ word).count_ones() << 8) | i as u32;
            best_key = best_key.min(key);
        }
        let index = (best_key & 0xFF) as usize;
        Some(SearchHit {
            index,
            entry: self.entries[index],
            distance: best_key >> 8,
        })
    }

    /// Exact-match CAM lookup.
    pub fn contains(&self, word: u64) -> bool {
        self.entries[..self.len].contains(&word)
    }

    /// FIFO insert (BD-Coder update policy: overwrite the oldest slot).
    pub fn push(&mut self, word: u64) {
        self.entries[self.head] = word;
        self.head = (self.head + 1) % self.entries.len();
        self.len = (self.len + 1).min(self.entries.len());
    }

    /// Insert only if not already present (MBDC dedup policy, §IV-A).
    /// Returns true if inserted.
    pub fn push_unique(&mut self, word: u64) -> bool {
        if self.contains(word) {
            return false;
        }
        self.push(word);
        true
    }

    /// Clear all entries.
    pub fn reset(&mut self) {
        self.head = 0;
        self.len = 0;
    }

    /// Valid entries in slot order (for the L2 `trace_screen` bridge and
    /// the figure harness).
    pub fn snapshot(&self) -> &[u64] {
        &self.entries[..self.len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn empty_table_has_no_hit() {
        assert!(DataTable::new(4).most_similar(123).is_none());
    }

    #[test]
    fn finds_exact_then_nearest() {
        let mut t = DataTable::new(8);
        t.push(0xFF);
        t.push(0x0F);
        let h = t.most_similar(0x0F).unwrap();
        assert_eq!((h.index, h.distance), (1, 0));
        let h = t.most_similar(0x1F).unwrap();
        assert_eq!(h.entry, 0x0F);
        assert_eq!(h.distance, 1);
    }

    #[test]
    fn tie_breaks_to_lowest_index() {
        let mut t = DataTable::new(4);
        t.push(0b0001); // distance 1 from 0b0000
        t.push(0b0010); // also distance 1
        let h = t.most_similar(0).unwrap();
        assert_eq!(h.index, 0);
    }

    #[test]
    fn fifo_overwrites_oldest() {
        let mut t = DataTable::new(2);
        t.push(1);
        t.push(2);
        t.push(3); // evicts 1
        assert!(!t.contains(1));
        assert!(t.contains(2) && t.contains(3));
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn push_unique_dedups() {
        let mut t = DataTable::new(4);
        assert!(t.push_unique(7));
        assert!(!t.push_unique(7));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn search_matches_naive_reference() {
        let mut r = Rng::new(9);
        let mut t = DataTable::new(64);
        for _ in 0..64 {
            t.push(r.next_u64());
        }
        for _ in 0..500 {
            let q = r.next_u64();
            let hit = t.most_similar(q).unwrap();
            // Naive argmin with lowest-index ties.
            let (mut bi, mut bd) = (0usize, u32::MAX);
            for (i, &e) in t.snapshot().iter().enumerate() {
                let d = (e ^ q).count_ones();
                if d < bd {
                    bd = d;
                    bi = i;
                }
            }
            assert_eq!((hit.index, hit.distance), (bi, bd));
        }
    }

    #[test]
    fn get_returns_pushed_value() {
        let mut t = DataTable::new(64);
        for i in 0..10u64 {
            t.push(i * 1000);
        }
        for i in 0..10usize {
            assert_eq!(t.get(i), i as u64 * 1000);
        }
    }
}
