//! ZAC-DEST — Algorithm 2: skip-transfer with one-hot index, on top of
//! MBDC, with the Similarity-Limit / Truncation / Tolerance knobs and a
//! final DBI stage (Fig. 7b).
//!
//! Per 64-bit chip word:
//! 1. **Truncation** (approx-eligible accesses only): the configured LSBs
//!    of every chunk are zeroed — they are neither compared nor sent.
//! 2. **Zero check**: an all-zero (post-truncation) word is sent as zeros,
//!    no encoding, no table update (§V-A).
//! 3. **CAM search** for the most similar entry.
//! 4. **ZAC-DEST condition**: `hamming(MSET XOR DCDT) < threshold` *and*
//!    zero mismatches in the Tolerance mask. If it fires, the data lines
//!    carry the MSE's index **one-hot encoded** (exactly one 1 — cheaper
//!    than the worst-case 6 ones of a binary index, §IV-B) and the
//!    receiver substitutes its mirrored entry: an approximation within
//!    the similarity envelope. The table is *not* updated (§IV-A: only
//!    exact transfers update it).
//! 5. Otherwise, fall back to MBDC (exact), updating the table.
//! 6. **DBI** is applied to whatever goes out on the data lines.
//!
//! Accesses with `approx = false` (instructions, critical data) skip
//! steps 1 and 4 entirely and go straight to the exact MBDC path.

use super::config::{Scheme, ZacConfig};
use super::data_table::DataTable;
use super::dbi::{dbi_decode, dbi_encode};
use super::mbdc::{MbdcDecoder, MbdcEncoder};
use super::stats::Outcome;
use super::wire::WireWord;
use super::{ChipDecoder, ChipEncoder};

pub struct ZacDestEncoder {
    table: DataTable,
    threshold: u32,
    tol_mask: u64,
    trunc_keep: u64,
    ablation: super::config::Ablation,
}

impl ZacDestEncoder {
    pub fn new(cfg: ZacConfig) -> Self {
        cfg.validate().expect("invalid ZAC-DEST config");
        ZacDestEncoder {
            threshold: cfg.dissimilar_threshold(),
            tol_mask: cfg.tolerance_mask(),
            trunc_keep: !cfg.truncation_mask(),
            table: DataTable::new(cfg.table_size),
            ablation: cfg.ablation,
        }
    }

    /// Apply the final DBI stage to a wire word's data lines.
    #[inline]
    fn dbi_stage(mut wire: WireWord) -> WireWord {
        let (data, mask) = dbi_encode(wire.data);
        wire.data = data;
        wire.dbi_mask = mask;
        wire
    }

    /// Per-word encode core, shared by the scalar and batch paths. The
    /// knobs arrive as arguments so the batch loop hoists them once;
    /// `sliced` selects the backend-dispatched CAM search (batch hot
    /// path: bit-plane mirror on scalar, AVX2/NEON kernels otherwise)
    /// vs the row-major reference scan — all pinned to identical hits.
    #[inline]
    fn encode_one(
        table: &mut DataTable,
        word: u64,
        approx: bool,
        threshold: u32,
        tol_mask: u64,
        trunc_keep: u64,
        ablation: super::config::Ablation,
        sliced: bool,
    ) -> WireWord {
        // (1) Truncation — approximate traffic only.
        let dcdt = if approx { word & trunc_keep } else { word };

        // (2) Zero check: cheapest possible transfer, leave the CAM alone.
        // (ablation zero_skip=false: zeros flow through the normal
        // search/BDE path and update the table, as original BD-Coder.)
        if dcdt == 0 && ablation.zero_skip {
            return WireWord {
                data: 0,
                dbi_mask: 0,
                index_line: 0,
                index_used: false,
                ecc_line: 0,
                outcome: Outcome::ZeroSkip,
            };
        }

        // One CAM search serves both the skip check and the MBDC
        // fallback (the hardware searches once too — Fig. 7b).
        let hit = if sliced {
            table.most_similar_sliced(dcdt)
        } else {
            table.most_similar(dcdt)
        };

        // (3)+(4) ZAC-DEST skip check.
        if approx {
            if let Some(hit) = hit {
                let diff = dcdt ^ hit.entry;
                if diff.count_ones() < threshold && diff & tol_mask == 0 {
                    debug_assert!(hit.index < 64);
                    return Self::dbi_stage(if ablation.ohe_index {
                        // One-hot index on the otherwise idle data lines.
                        WireWord {
                            data: 1u64 << hit.index,
                            dbi_mask: 0,
                            index_line: 0,
                            index_used: false,
                            ecc_line: 0,
                            outcome: Outcome::OheSkip,
                        }
                    } else {
                        // Ablation: binary index on the sideband, data
                        // lines idle (BD-Coder-style addressing).
                        WireWord {
                            data: 0,
                            dbi_mask: 0,
                            index_line: hit.index as u8,
                            index_used: true,
                            ecc_line: 0,
                            outcome: Outcome::OheSkip,
                        }
                    });
                }
            }
        }

        // (5) Exact fallback: MBDC (updates the table), then (6) DBI.
        Self::dbi_stage(MbdcEncoder::encode_word_with_hit(
            table,
            dcdt,
            hit,
            ablation.dedup_update,
        ))
    }
}

impl ChipEncoder for ZacDestEncoder {
    fn encode(&mut self, word: u64, approx: bool) -> WireWord {
        Self::encode_one(
            &mut self.table,
            word,
            approx,
            self.threshold,
            self.tol_mask,
            self.trunc_keep,
            self.ablation,
            false,
        )
    }

    /// Batch hot path: config knobs hoisted out of the loop, each
    /// (post-truncation) all-zero word short-circuiting ahead of its CAM
    /// access, and the search dispatched to the table's backend.
    fn encode_batch(&mut self, words: &[u64], approx: &[bool], out: &mut [WireWord]) {
        assert_eq!(words.len(), approx.len());
        assert_eq!(words.len(), out.len());
        let threshold = self.threshold;
        let tol_mask = self.tol_mask;
        let trunc_keep = self.trunc_keep;
        let ablation = self.ablation;
        for ((&word, &approx), slot) in words.iter().zip(approx).zip(out.iter_mut()) {
            *slot = Self::encode_one(
                &mut self.table,
                word,
                approx,
                threshold,
                tol_mask,
                trunc_keep,
                ablation,
                true,
            );
        }
    }

    fn scheme(&self) -> Scheme {
        Scheme::ZacDest
    }

    fn reset(&mut self) {
        self.table.reset();
    }
}

pub struct ZacDestDecoder {
    table: DataTable,
    ablation: super::config::Ablation,
}

impl ZacDestDecoder {
    pub fn new(cfg: ZacConfig) -> Self {
        ZacDestDecoder {
            table: DataTable::new(cfg.table_size),
            ablation: cfg.ablation,
        }
    }
}

impl ChipDecoder for ZacDestDecoder {
    fn decode(&mut self, wire: &WireWord) -> u64 {
        match wire.outcome {
            Outcome::ZeroSkip => 0,
            Outcome::OheSkip => {
                let index = if wire.index_used {
                    // Ablation path: binary index on the sideband.
                    wire.index_line as usize
                } else {
                    // A fault-free OHE word has exactly one 1; under
                    // wire-level fault injection the hot bit can be
                    // cleared or an extra one raised. The receiver's
                    // priority decoder resolves the lowest driven line
                    // (matching the CAM's tie-break); an all-low burst
                    // addresses no row and reads as zero.
                    let ohe = dbi_decode(wire.data, wire.dbi_mask);
                    if ohe == 0 {
                        return 0;
                    }
                    ohe.trailing_zeros() as usize
                };
                // Approximate reconstruction: the mirrored entry, no
                // update. Total over fault-synthesized indices (an
                // unwritten row reads as zero).
                self.table.get_or_zero(index)
            }
            Outcome::Bde | Outcome::Raw => {
                let mut undone = *wire;
                undone.data = dbi_decode(wire.data, wire.dbi_mask);
                MbdcDecoder::decode_word_policy(
                    &mut self.table,
                    &undone,
                    self.ablation.dedup_update,
                )
            }
        }
    }

    fn reset(&mut self) {
        self.table.reset();
    }
}

/// Self-register ZAC-DEST (Table I "OHE") in a
/// [`CodecRegistry`](super::registry::CodecRegistry).
pub fn register(reg: &mut super::registry::CodecRegistry) {
    reg.register("OHE", |spec| {
        let knobs = spec.zac_knobs().ok_or_else(|| {
            anyhow::anyhow!("OHE codec requires ZAC knobs, got {:?}", spec.knobs)
        })?;
        let cfg = knobs.to_config();
        Ok(super::registry::Codec::new(
            Box::new(ZacDestEncoder::new(cfg.clone())),
            Box::new(ZacDestDecoder::new(cfg)),
        ))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn codec(cfg: ZacConfig) -> (ZacDestEncoder, ZacDestDecoder) {
        (
            ZacDestEncoder::new(cfg.clone()),
            ZacDestDecoder::new(cfg),
        )
    }

    #[test]
    fn exact_when_not_approx() {
        let (mut e, mut d) = codec(ZacConfig::zac_full(70, 2, 0));
        let mut r = Rng::new(51);
        for _ in 0..2000 {
            let w = r.next_u64();
            let wire = e.encode(w, false);
            assert_ne!(wire.outcome, Outcome::OheSkip);
            assert_eq!(d.decode(&wire), w);
        }
    }

    #[test]
    fn skip_reconstruction_within_envelope() {
        let cfg = ZacConfig::zac(80);
        let (mut e, mut d) = codec(cfg.clone());
        let mut r = Rng::new(52);
        let mut base = r.next_u64();
        let mut skips = 0;
        for i in 0..3000 {
            if i % 50 == 0 {
                base = r.next_u64();
            }
            let w = base ^ (r.next_u64() & r.next_u64() & r.next_u64() & 0xFF); // few flipped bits
            let wire = e.encode(w, true);
            let got = d.decode(&wire);
            let trunc = w & !cfg.truncation_mask();
            let d_bits = (got ^ trunc).count_ones();
            assert!(
                d_bits < cfg.dissimilar_threshold(),
                "approximation outside envelope: {d_bits}"
            );
            if wire.outcome == Outcome::OheSkip {
                skips += 1;
                assert_eq!(wire.total_ones(), 2); // one data 1 + one flag 1
            }
        }
        assert!(skips > 100, "skip path barely exercised: {skips}");
    }

    #[test]
    fn truncation_zeroes_lsbs() {
        let cfg = ZacConfig::zac_full(90, 2, 0); // 2 LSBs per byte
        let (mut e, mut d) = codec(cfg.clone());
        let w = 0xFFFF_FFFF_FFFF_FFFFu64;
        let wire = e.encode(w, true);
        let got = d.decode(&wire);
        assert_eq!(got, w & !cfg.truncation_mask());
        assert_eq!(got & cfg.truncation_mask(), 0);
    }

    #[test]
    fn tolerance_vetoes_skip_on_msb_mismatch() {
        let cfg = ZacConfig::zac_full(50, 0, 2); // very loose limit, strict MSBs
        let (mut e, _) = codec(cfg);
        let a = 0x0101_0101_0101_0101u64;
        e.encode(a, true); // stored
        // Flip an MSB (tolerance bit) of one byte: within the similarity
        // budget but vetoed by tolerance.
        let b = a ^ 0x8000_0000_0000_0000;
        let wire = e.encode(b, true);
        assert_ne!(wire.outcome, Outcome::OheSkip);
        // Flipping a non-tolerance bit instead does skip.
        let c = a ^ 0x0000_0000_0000_1000; // bit 12 = byte 1 bit 4 (not MSB 2)
        let wire = e.encode(c, true);
        assert_eq!(wire.outcome, Outcome::OheSkip);
    }

    #[test]
    fn zero_after_truncation_is_zero_skip() {
        let cfg = ZacConfig::zac_full(80, 2, 0);
        let (mut e, mut d) = codec(cfg);
        let w = 0x0303_0303_0303_0303u64; // only truncated LSBs set
        let wire = e.encode(w, true);
        assert_eq!(wire.outcome, Outcome::ZeroSkip);
        assert_eq!(wire.total_ones(), 0);
        assert_eq!(d.decode(&wire), 0);
    }

    #[test]
    fn weights_config_never_skips_on_exponent_mismatch() {
        let cfg = ZacConfig::zac_weights(50);
        let (mut e, _) = codec(cfg);
        let w1 = f32_pair(1.5, 2.5);
        e.encode(w1, true);
        // Same mantissa-ish bits, different exponent -> no skip.
        let w2 = f32_pair(3.0, 5.0);
        let wire = e.encode(w2, true);
        assert_ne!(wire.outcome, Outcome::OheSkip);
        // Tiny mantissa perturbation -> skip allowed.
        let w3 = f32_pair(1.5000002, 2.5000004);
        let wire = e.encode(w3, true);
        assert_eq!(wire.outcome, Outcome::OheSkip);
    }

    fn f32_pair(a: f32, b: f32) -> u64 {
        (a.to_bits() as u64) | ((b.to_bits() as u64) << 32)
    }

    #[test]
    fn mirror_consistency_under_mixed_traffic() {
        let cfg = ZacConfig::zac_full(75, 1, 1);
        let (mut e, mut d) = codec(cfg);
        let mut r = Rng::new(53);
        for _ in 0..5000 {
            let w = match r.below(4) {
                0 => 0,
                1 => r.next_u64() & 0x0F0F,
                _ => r.next_u64(),
            };
            let approx = r.chance(0.7);
            let wire = e.encode(w, approx);
            let _ = d.decode(&wire);
            assert_eq!(e.table.snapshot(), d.table.snapshot());
        }
    }

    #[test]
    fn ohe_word_survives_dbi() {
        // DBI must never mangle the one-hot index (≤1 one per byte).
        for i in 0..64 {
            let (data, mask) = dbi_encode(1u64 << i);
            assert_eq!(data, 1u64 << i);
            assert_eq!(mask, 0);
        }
    }
}
