//! Open codec registry: a string-keyed table of factory functions that
//! resolves a [`CodecSpec`] into a [`Codec`] handle (the matched
//! encoder/decoder pair).
//!
//! The five built-in schemes self-register
//! ([`CodecRegistry::with_builtins`] calls each scheme module's
//! `register`), and `registry.register("NAME", factory)` admits
//! out-of-tree schemes without touching any dispatch `match` in
//! `encoding/mod.rs` — the closed [`make_codec`](super::make_codec)
//! construction path is now a thin shim over this registry.
//!
//! A [`CodecSpec`] is the uniform codec description every ingestion
//! boundary produces (CLI flags, run-config TOML, sweep TOML, env
//! overrides): a scheme name plus the per-scheme [`Knobs`] bag, with
//! [`CodecSpec::validate`] enforced before any factory runs.

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock};

use super::config::{Scheme, ZacConfig};
use super::knobs::{Knobs, TableKnobs, ZacKnobs};
use super::{ChipDecoder, ChipEncoder};

/// The matched sender-side encoder and receiver-side decoder of one
/// chip's codec — constructed together so their mirrored table state
/// can never be paired across schemes or knob settings.
pub struct Codec {
    pub encoder: Box<dyn ChipEncoder>,
    pub decoder: Box<dyn ChipDecoder>,
}

impl Codec {
    pub fn new(encoder: Box<dyn ChipEncoder>, decoder: Box<dyn ChipDecoder>) -> Codec {
        Codec { encoder, decoder }
    }

    /// Build the codec a legacy [`ZacConfig`] describes, through the
    /// default registry (the shim path under
    /// [`make_codec`](super::make_codec)). Panics on an invalid config
    /// — the legacy free functions had no error channel, and the ZAC
    /// encoder constructor already panicked on bad knobs in v1; the
    /// panic message carries the real validation error.
    pub fn from_config(cfg: &ZacConfig) -> Codec {
        default_registry()
            .build(&CodecSpec::from_config(cfg))
            .unwrap_or_else(|e| panic!("legacy ZacConfig codec construction failed: {e}"))
    }

    /// Reset both sides (tables; channel line state is channel-side).
    pub fn reset(&mut self) {
        self.encoder.reset();
        self.decoder.reset();
    }

    /// The scheme label the encoder reports (wire-stat bucketing).
    pub fn scheme(&self) -> Scheme {
        self.encoder.scheme()
    }
}

/// A codec description: registry key plus the knobs that scheme
/// understands. Parsed uniformly from CLI flags, env overrides and
/// sweep/run TOML via [`CodecSpec::set_knob`].
#[derive(Clone, Debug, PartialEq)]
pub struct CodecSpec {
    /// Registry key (Table I label for the built-ins, e.g. `"OHE"`;
    /// aliases like `"zac-dest"` resolve through [`Scheme::parse`]).
    pub scheme: String,
    /// Per-scheme knob bag.
    pub knobs: Knobs,
}

impl CodecSpec {
    /// Spec for a scheme by name, with that scheme's default knobs
    /// (out-of-tree names get [`Knobs::None`]; their factories carry
    /// their own configuration).
    pub fn named(scheme: &str) -> CodecSpec {
        let knobs = match Scheme::parse(scheme) {
            Some(s) => Knobs::for_scheme(s),
            None => Knobs::None,
        };
        CodecSpec {
            scheme: scheme.to_string(),
            knobs,
        }
    }

    /// Spec with an explicit knob bag.
    pub fn with_knobs(scheme: &str, knobs: Knobs) -> CodecSpec {
        CodecSpec {
            scheme: scheme.to_string(),
            knobs,
        }
    }

    /// ZAC-DEST at a similarity limit (other knobs at paper defaults).
    pub fn zac(similarity_limit_pct: u32) -> CodecSpec {
        CodecSpec::with_knobs("OHE", Knobs::Zac(ZacKnobs::limit(similarity_limit_pct)))
    }

    /// ZAC-DEST with all three §V knobs (chunk width 8, byte data).
    pub fn zac_full(limit_pct: u32, truncation_bits: u32, tolerance_bits: u32) -> CodecSpec {
        CodecSpec::with_knobs(
            "OHE",
            Knobs::Zac(ZacKnobs::full(limit_pct, truncation_bits, tolerance_bits)),
        )
    }

    /// ZAC-DEST for IEEE-754 f32 weight traffic (sign+exponent pinned).
    pub fn zac_weights(limit_pct: u32) -> CodecSpec {
        CodecSpec::with_knobs("OHE", Knobs::Zac(ZacKnobs::weights(limit_pct)))
    }

    /// The ZAC knobs, when this spec carries them.
    pub fn zac_knobs(&self) -> Option<ZacKnobs> {
        match self.knobs {
            Knobs::Zac(k) => Some(k),
            _ => None,
        }
    }

    /// Mutable access to the ZAC knobs, when this spec carries them.
    pub fn zac_knobs_mut(&mut self) -> Option<&mut ZacKnobs> {
        match &mut self.knobs {
            Knobs::Zac(k) => Some(k),
            _ => None,
        }
    }

    /// Table size (64 for knob-free schemes).
    pub fn table_size(&self) -> usize {
        self.knobs.table_size()
    }

    /// Validate the spec (non-empty scheme name + knob invariants).
    /// Every ingestion boundary calls this before a codec is built.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.scheme.trim().is_empty(), "empty codec scheme name");
        self.knobs.validate()
    }

    /// Apply one knob by key — the single ingestion path shared by CLI
    /// flags, run-config TOML and env overrides. Keys a scheme does not
    /// have are rejected (the old god-struct silently absorbed them).
    pub fn set_knob(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        fn num(key: &str, value: &str) -> anyhow::Result<u64> {
            value
                .trim()
                .parse::<u64>()
                .map_err(|e| anyhow::anyhow!("knob {key} = {value:?}: {e}"))
        }
        fn boolean(key: &str, value: &str) -> anyhow::Result<bool> {
            match value.trim() {
                "true" | "1" => Ok(true),
                "false" | "0" => Ok(false),
                other => Err(anyhow::anyhow!("knob {key} = {other:?}: expected true/false")),
            }
        }
        match (&mut self.knobs, key) {
            (Knobs::Zac(k), "limit" | "similarity_limit") => {
                k.similarity_limit_pct = num(key, value)? as u32;
            }
            (Knobs::Zac(k), "chunk_width") => k.chunk_width = num(key, value)? as u32,
            (Knobs::Zac(k), "truncation") => k.truncation_bits = num(key, value)? as u32,
            (Knobs::Zac(k), "tolerance") => k.tolerance_bits = num(key, value)? as u32,
            (Knobs::Zac(k), "table_size") => k.table_size = num(key, value)? as usize,
            (Knobs::Zac(k), "weights_mode") => {
                if boolean(key, value)? {
                    // One definition of the weights-mode geometry/mask.
                    let w = ZacKnobs::weights(k.similarity_limit_pct);
                    k.chunk_width = w.chunk_width;
                    k.tolerance_mask_override = w.tolerance_mask_override;
                }
            }
            (Knobs::Table(k), "table_size") => k.table_size = num(key, value)? as usize,
            _ => {
                let valid = match self.knobs {
                    Knobs::Zac(_) => {
                        "limit/similarity_limit, chunk_width, truncation, \
                         tolerance, table_size, weights_mode"
                    }
                    Knobs::Table(_) => "table_size",
                    Knobs::None => "(none — this scheme has no knobs)",
                };
                anyhow::bail!(
                    "scheme {:?} has no knob {key:?}; valid knobs: {valid} \
                     (per-scheme knobs replaced the ZacConfig god-struct)",
                    self.scheme
                )
            }
        }
        Ok(())
    }

    /// Short label for figure legends / sweep rows, e.g. `ZAC(L80,T16,O8)`.
    pub fn label(&self) -> String {
        match &self.knobs {
            Knobs::Zac(k) => format!(
                "ZAC(L{},T{},O{})",
                k.similarity_limit_pct,
                k.truncated_bits_total(),
                k.tolerance_mask().count_ones()
            ),
            _ => match Scheme::parse(&self.scheme) {
                Some(s) => s.label().to_string(),
                None => self.scheme.clone(),
            },
        }
    }

    /// The spec a legacy [`ZacConfig`] describes.
    pub fn from_config(cfg: &ZacConfig) -> CodecSpec {
        let knobs = match cfg.scheme {
            Scheme::ZacDest => Knobs::Zac(ZacKnobs::from_config(cfg)),
            Scheme::Bde | Scheme::BdeOrg => Knobs::Table(TableKnobs {
                table_size: cfg.table_size,
            }),
            Scheme::Org | Scheme::Dbi => Knobs::None,
        };
        CodecSpec {
            scheme: cfg.scheme.label().to_string(),
            knobs,
        }
    }

    /// The legacy [`ZacConfig`] equivalent (errors for out-of-tree
    /// schemes, which have no god-struct representation).
    pub fn to_config(&self) -> anyhow::Result<ZacConfig> {
        let scheme = Scheme::parse(&self.scheme).ok_or_else(|| {
            anyhow::anyhow!("scheme {:?} has no legacy ZacConfig equivalent", self.scheme)
        })?;
        let mut cfg = match self.knobs {
            Knobs::Zac(k) => k.to_config(),
            Knobs::Table(t) => {
                let mut c = ZacConfig::scheme(scheme);
                c.table_size = t.table_size;
                c
            }
            Knobs::None => ZacConfig::scheme(scheme),
        };
        cfg.scheme = scheme;
        Ok(cfg)
    }
}

/// A codec factory: builds the matched encoder/decoder pair for one
/// chip from a validated spec.
pub type CodecFactory = Arc<dyn Fn(&CodecSpec) -> anyhow::Result<Codec> + Send + Sync>;

/// String-keyed factory table. Cloning is cheap (the factories are
/// shared), so sessions and worker threads each hold their own handle.
#[derive(Clone, Default)]
pub struct CodecRegistry {
    factories: BTreeMap<String, CodecFactory>,
}

fn canonical(scheme: &str) -> String {
    scheme.trim().to_ascii_uppercase()
}

impl CodecRegistry {
    /// An empty registry (no schemes).
    pub fn empty() -> CodecRegistry {
        CodecRegistry::default()
    }

    /// Registry with the five paper schemes plus the correcting family
    /// (`SECDED`, `PARITY`, `EDEN`, and `ECC+<base>` over each of the
    /// five) — each registered by its own module, no central dispatch
    /// `match` to extend. The correcting family registers last so its
    /// wrappers can snapshot the base factories.
    pub fn with_builtins() -> CodecRegistry {
        let mut reg = CodecRegistry::empty();
        super::org::register(&mut reg);
        super::dbi::register(&mut reg);
        super::bde_org::register(&mut reg);
        super::mbdc::register(&mut reg);
        super::zac_dest::register(&mut reg);
        super::ecc::register(&mut reg);
        reg
    }

    /// Register (or replace) a scheme factory under `scheme`
    /// (case-insensitive). This is the extension point for out-of-tree
    /// codecs: registering requires no edits to `encoding/`.
    pub fn register<F>(&mut self, scheme: &str, factory: F)
    where
        F: Fn(&CodecSpec) -> anyhow::Result<Codec> + Send + Sync + 'static,
    {
        self.factories.insert(canonical(scheme), Arc::new(factory));
    }

    fn lookup(&self, scheme: &str) -> Option<&CodecFactory> {
        if let Some(f) = self.factories.get(&canonical(scheme)) {
            return Some(f);
        }
        // Built-in aliases ("ZAC", "zac-dest", "MBDC", ...) resolve to
        // the canonical Table I label.
        Scheme::parse(scheme).and_then(|s| self.factories.get(s.label()))
    }

    /// Whether `scheme` (or a built-in alias of it) is registered.
    pub fn contains(&self, scheme: &str) -> bool {
        self.lookup(scheme).is_some()
    }

    /// Registered scheme keys, sorted.
    pub fn schemes(&self) -> Vec<String> {
        self.factories.keys().cloned().collect()
    }

    /// Validate `spec` and build its codec.
    pub fn build(&self, spec: &CodecSpec) -> anyhow::Result<Codec> {
        spec.validate()?;
        let factory = self.lookup(&spec.scheme).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown codec scheme {:?}; registered: {:?}",
                spec.scheme,
                self.schemes()
            )
        })?;
        factory(spec)
    }
}

/// The process-wide registry of built-in schemes. Sessions clone it and
/// may extend their copy without affecting other callers.
pub fn default_registry() -> &'static CodecRegistry {
    static DEFAULT: OnceLock<CodecRegistry> = OnceLock::new();
    DEFAULT.get_or_init(CodecRegistry::with_builtins)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::wire::WireWord;

    #[test]
    fn builtins_register_all_five_schemes() {
        let reg = CodecRegistry::with_builtins();
        for s in Scheme::all() {
            assert!(reg.contains(s.label()), "{} missing", s.label());
            let codec = reg.build(&CodecSpec::named(s.label())).unwrap();
            assert_eq!(codec.scheme(), s, "{}", s.label());
        }
        // 5 Table I schemes + SECDED/PARITY/EDEN + 5 ECC+ wrappers.
        assert_eq!(reg.schemes().len(), 13);
    }

    #[test]
    fn correcting_family_registers_and_builds() {
        let reg = CodecRegistry::with_builtins();
        for name in [
            "SECDED", "PARITY", "EDEN", "ECC+ORG", "ECC+DBI", "ECC+BDE_ORG",
            "ECC+BDE", "ECC+OHE",
        ] {
            assert!(reg.contains(name), "{name} missing");
            let mut codec = reg.build(&CodecSpec::named(name)).unwrap();
            // Every correcting scheme round-trips a word on a clean wire
            // when the traffic is critical (exactness is per-scheme on
            // approx traffic — EDEN truncates).
            let w = 0xDEAD_BEEF_0F0F_1234;
            let wire = codec.encoder.encode(w, false);
            assert_eq!(codec.decoder.decode(&wire), w, "{name}");
        }
        // Wrapper knob pass-through: ECC+BDE accepts BDE's table_size.
        let mut spec = CodecSpec::with_knobs(
            "ECC+BDE",
            Knobs::Table(TableKnobs { table_size: 16 }),
        );
        reg.build(&spec).unwrap();
        spec.set_knob("table_size", "32").unwrap();
        assert_eq!(spec.table_size(), 32);
    }

    #[test]
    fn aliases_resolve_to_builtins() {
        let reg = CodecRegistry::with_builtins();
        for alias in ["zac-dest", "ZAC", "ohe", "mbdc", "BdeOrg"] {
            assert!(reg.contains(alias), "{alias}");
            reg.build(&CodecSpec::named(alias)).unwrap();
        }
        assert!(!reg.contains("NOPE"));
    }

    #[test]
    fn build_validates_the_spec_first() {
        let reg = CodecRegistry::with_builtins();
        let mut spec = CodecSpec::zac(80);
        spec.zac_knobs_mut().unwrap().similarity_limit_pct = 200;
        let err = reg.build(&spec).unwrap_err().to_string();
        assert!(err.contains("similarity limit"), "{err}");
        let err = reg
            .build(&CodecSpec::named("UNREGISTERED"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("registered"), "{err}");
    }

    #[test]
    fn set_knob_rejects_foreign_knobs() {
        let mut spec = CodecSpec::named("BDE");
        spec.set_knob("table_size", "16").unwrap();
        assert_eq!(spec.table_size(), 16);
        let err = spec.set_knob("limit", "80").unwrap_err().to_string();
        assert!(err.contains("no knob"), "{err}");
        let mut org = CodecSpec::named("ORG");
        assert!(org.set_knob("table_size", "16").is_err());
        let mut zac = CodecSpec::zac(80);
        zac.set_knob("weights_mode", "true").unwrap();
        assert_eq!(
            zac.zac_knobs().unwrap().tolerance_mask_override,
            Some(0xFF80_0000_FF80_0000)
        );
        assert!(zac.set_knob("limit", "eighty").is_err());
    }

    #[test]
    fn spec_round_trips_through_legacy_config() {
        for spec in [
            CodecSpec::named("ORG"),
            CodecSpec::named("DBI"),
            CodecSpec::named("BDE"),
            CodecSpec::named("BDE_ORG"),
            CodecSpec::zac(75),
            CodecSpec::zac_full(70, 2, 1),
            CodecSpec::zac_weights(60),
        ] {
            let cfg = spec.to_config().unwrap();
            assert_eq!(CodecSpec::from_config(&cfg), spec, "{}", spec.label());
            assert_eq!(cfg.label(), spec.label(), "{}", spec.label());
        }
        assert!(CodecSpec::named("CUSTOM").to_config().is_err());
    }

    #[test]
    fn out_of_tree_factory_registers_and_builds() {
        struct XorEnc;
        impl crate::encoding::ChipEncoder for XorEnc {
            fn encode(&mut self, word: u64, _approx: bool) -> WireWord {
                WireWord::raw(word ^ 0xA5A5_A5A5_A5A5_A5A5)
            }
            fn scheme(&self) -> Scheme {
                Scheme::Org // closed legacy enum: reuse the nearest label
            }
            fn reset(&mut self) {}
        }
        struct XorDec;
        impl crate::encoding::ChipDecoder for XorDec {
            fn decode(&mut self, wire: &WireWord) -> u64 {
                wire.data ^ 0xA5A5_A5A5_A5A5_A5A5
            }
            fn reset(&mut self) {}
        }
        let mut reg = CodecRegistry::with_builtins();
        reg.register("XOR_MASK", |_spec| {
            Ok(Codec::new(Box::new(XorEnc), Box::new(XorDec)))
        });
        assert_eq!(reg.schemes().len(), 14);
        let mut codec = reg.build(&CodecSpec::named("xor_mask")).unwrap();
        let wire = codec.encoder.encode(42, true);
        assert_eq!(codec.decoder.decode(&wire), 42);
        // Out-of-tree schemes compose with the ECC wrapper too.
        crate::encoding::ecc::wrap(&mut reg, "XOR_MASK");
        let mut wrapped = reg.build(&CodecSpec::named("ECC+XOR_MASK")).unwrap();
        let mut wire = wrapped.encoder.encode(42, true);
        wire.data ^= 1 << 17;
        assert_eq!(wrapped.decoder.decode(&wire), 42);
        assert_eq!(wrapped.decoder.take_corrections().corrected_bits, 1);
    }
}
