//! Per-stream encoding statistics (feeds Fig. 22 and the energy reports).

use super::wire::WireWord;

/// How a word went over the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Outcome {
    /// All-zero word: transferred as zeros, nothing encoded (§V-A).
    ZeroSkip,
    /// ZAC-DEST skip: one-hot table address instead of data (§IV-B).
    OheSkip,
    /// Bitwise-difference encoded (BD-Coder / MBDC xor + index).
    Bde,
    /// Unencoded data on the data lines (possibly DBI-inverted).
    Raw,
}

impl Outcome {
    pub fn all() -> [Outcome; 4] {
        [
            Outcome::ZeroSkip,
            Outcome::OheSkip,
            Outcome::Bde,
            Outcome::Raw,
        ]
    }

    pub fn label(self) -> &'static str {
        match self {
            Outcome::ZeroSkip => "zero",
            Outcome::OheSkip => "ohe-skip",
            Outcome::Bde => "bde",
            Outcome::Raw => "unencoded",
        }
    }
}

/// Aggregate statistics over an encoded stream.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EncodeStats {
    counts: [u64; 4],
    /// Ones in the original (pre-encoding) words.
    pub original_ones: u64,
    /// Ones actually driven on all lines (data + sidebands).
    pub wire_ones: u64,
}

impl EncodeStats {
    fn slot(o: Outcome) -> usize {
        match o {
            Outcome::ZeroSkip => 0,
            Outcome::OheSkip => 1,
            Outcome::Bde => 2,
            Outcome::Raw => 3,
        }
    }

    /// Record one transfer.
    #[inline]
    pub fn record(&mut self, wire: &WireWord, original: u64) {
        self.counts[Self::slot(wire.outcome)] += 1;
        self.original_ones += original.count_ones() as u64;
        self.wire_ones += wire.total_ones() as u64;
    }

    /// Record a batch of transfers (one pass, counters stay enregistered).
    pub fn record_batch(&mut self, wires: &[WireWord], originals: &[u64]) {
        debug_assert_eq!(wires.len(), originals.len());
        for (w, &o) in wires.iter().zip(originals) {
            self.record(w, o);
        }
    }

    pub fn count(&self, o: Outcome) -> u64 {
        self.counts[Self::slot(o)]
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Fraction of transfers with the given outcome.
    pub fn fraction(&self, o: Outcome) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.count(o) as f64 / self.total() as f64
        }
    }

    /// Fraction of accesses not encoded at all (paper reports ~6.5% for
    /// ZAC-DEST / ~6.6% for BDE in Fig. 22).
    pub fn unencoded_fraction(&self) -> f64 {
        self.fraction(Outcome::Raw)
    }

    /// `DataTable` hit rate: the fraction of transfers served as a
    /// one-hot table address (ZAC-DEST skip). This is the metric the
    /// address-mapping layer moves — steering similar lines onto the
    /// same channel raises each channel's hit rate.
    pub fn table_hit_rate(&self) -> f64 {
        self.fraction(Outcome::OheSkip)
    }

    /// Merge another stream's stats (per-chip aggregation).
    pub fn merge(&mut self, other: &EncodeStats) {
        for i in 0..4 {
            self.counts[i] += other.counts[i];
        }
        self.original_ones += other.original_ones;
        self.wire_ones += other.wire_ones;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_fractions() {
        let mut s = EncodeStats::default();
        let mut w = WireWord::raw(0b111);
        s.record(&w, 0b111);
        w.outcome = Outcome::OheSkip;
        w.data = 1;
        s.record(&w, 0xFFFF);
        assert_eq!(s.total(), 2);
        assert_eq!(s.count(Outcome::Raw), 1);
        assert_eq!(s.fraction(Outcome::OheSkip), 0.5);
        assert_eq!(s.table_hit_rate(), 0.5);
        assert_eq!(s.original_ones, 3 + 16);
        // ohe transfer drives 1 data one + 1 flag one.
        assert_eq!(s.wire_ones, 3 + 2);
    }

    #[test]
    fn merge_of_split_halves_equals_whole_run() {
        // The shard reduction in `system::ChannelArray` relies on this:
        // recording a stream in two halves and merging must be
        // indistinguishable from one whole-run recording, at any split.
        use crate::util::rng::Rng;
        let mut r = Rng::new(21);
        let outcomes = Outcome::all();
        let pairs: Vec<(WireWord, u64)> = (0..512)
            .map(|i| {
                let original = r.next_u64();
                let mut w = WireWord::raw(r.next_u64());
                w.outcome = outcomes[i % 4];
                w.dbi_mask = r.next_u64() as u8;
                w.index_line = r.next_u64() as u8;
                w.index_used = i % 3 == 0;
                (w, original)
            })
            .collect();
        let wires: Vec<WireWord> = pairs.iter().map(|(w, _)| *w).collect();
        let originals: Vec<u64> = pairs.iter().map(|(_, o)| *o).collect();
        let mut whole = EncodeStats::default();
        whole.record_batch(&wires, &originals);
        for split in [0usize, 1, 255, 256, 511, 512] {
            let mut a = EncodeStats::default();
            let mut b = EncodeStats::default();
            a.record_batch(&wires[..split], &originals[..split]);
            b.record_batch(&wires[split..], &originals[split..]);
            a.merge(&b);
            assert_eq!(a, whole, "split at {split}");
        }
    }

    #[test]
    fn merge_adds() {
        let mut a = EncodeStats::default();
        let mut b = EncodeStats::default();
        let w = WireWord::raw(1);
        a.record(&w, 1);
        b.record(&w, 1);
        b.record(&w, 1);
        a.merge(&b);
        assert_eq!(a.total(), 3);
        assert_eq!(a.wire_ones, 3);
    }
}
