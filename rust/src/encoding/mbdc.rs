//! MBDC — the paper's Modified Bitwise Difference Coder ("BDE" in the
//! evaluation), the stricter baseline ZAC-DEST is compared against.
//!
//! Three changes over BDE_ORG (§IV-A, §V-A, §VIII-H):
//! 1. **Zero bypass** — an all-zero word is sent as-is (zeros are the
//!    cheapest possible transfer under POD) and does *not* update the
//!    table, keeping zero out of the CAM.
//! 2. **Index-aware condition** — BDE fires only when
//!    `hamming(data) > hamming(xor) + hamming(index)`, charging the
//!    sideband cost the original coder ignored.
//! 3. **Dedup table update** — the table is updated at every (non-zero)
//!    access but only with values not already present, so the CAM holds
//!    unique entries and the MSE hit-rate rises (§IV-A).

use super::config::Scheme;
use super::data_table::DataTable;
use super::stats::Outcome;
use super::wire::WireWord;
use super::{ChipDecoder, ChipEncoder};

pub struct MbdcEncoder {
    table: DataTable,
}

impl MbdcEncoder {
    pub fn new(table_size: usize) -> Self {
        MbdcEncoder {
            table: DataTable::new(table_size),
        }
    }

    /// The MBDC decision + wire construction, shared with ZAC-DEST's
    /// fallback path. Updates the table.
    pub(crate) fn encode_word(table: &mut DataTable, word: u64) -> WireWord {
        Self::encode_one(table, word, false)
    }

    /// Shared per-word core; `sliced` picks the CAM search path (the
    /// batch path runs the table's dispatched backend — bit-plane
    /// mirror on scalar, AVX2/NEON row-major kernels otherwise — with
    /// results pinned identical either way).
    #[inline]
    fn encode_one(table: &mut DataTable, word: u64, sliced: bool) -> WireWord {
        if word == 0 {
            return WireWord {
                data: 0,
                dbi_mask: 0,
                index_line: 0,
                index_used: false,
                ecc_line: 0,
                outcome: Outcome::ZeroSkip,
            };
        }
        let hit = if sliced {
            table.most_similar_sliced(word)
        } else {
            table.most_similar(word)
        };
        Self::encode_word_with_hit(table, word, hit, true)
    }

    /// Same as [`Self::encode_word`] but reusing an already-computed CAM
    /// search (hot path: ZAC-DEST's fallback already searched). The hit's
    /// distance doubles as the dedup check — distance 0 means the word is
    /// already stored, so the update is skipped without a second scan.
    /// `dedup` = false reverts to BD-Coder's update-after-every-transfer
    /// policy (the §IV-A ablation).
    #[inline]
    pub(crate) fn encode_word_with_hit(
        table: &mut DataTable,
        word: u64,
        hit: Option<super::data_table::SearchHit>,
        dedup: bool,
    ) -> WireWord {
        let wire = match hit {
            Some(hit) => {
                let xored = word ^ hit.entry;
                let index = hit.index as u8;
                if word.count_ones() > xored.count_ones() + index.count_ones() {
                    WireWord {
                        data: xored,
                        dbi_mask: 0,
                        index_line: index,
                        index_used: true,
                        ecc_line: 0,
                        outcome: Outcome::Bde,
                    }
                } else {
                    WireWord::raw(word)
                }
            }
            None => WireWord::raw(word),
        };
        // Update at every non-zero access, unique entries only; the
        // search already told us whether the word is present.
        if !dedup || hit.map_or(true, |h| h.distance != 0) {
            table.push(word);
        }
        wire
    }
}

impl ChipEncoder for MbdcEncoder {
    fn encode(&mut self, word: u64, _approx: bool) -> WireWord {
        Self::encode_word(&mut self.table, word)
    }

    /// Batch path: the shared core with the CAM search running against
    /// the bit-plane mirror (bit-identical to [`MbdcEncoder::encode_word`]).
    fn encode_batch(&mut self, words: &[u64], approx: &[bool], out: &mut [WireWord]) {
        assert_eq!(words.len(), approx.len());
        assert_eq!(words.len(), out.len());
        for (&word, slot) in words.iter().zip(out.iter_mut()) {
            *slot = Self::encode_one(&mut self.table, word, true);
        }
    }

    fn scheme(&self) -> Scheme {
        Scheme::Bde
    }

    fn reset(&mut self) {
        self.table.reset();
    }
}

pub struct MbdcDecoder {
    table: DataTable,
}

impl MbdcDecoder {
    pub fn new(table_size: usize) -> Self {
        MbdcDecoder {
            table: DataTable::new(table_size),
        }
    }

    /// Decode + mirror update, shared with ZAC-DEST's decoder.
    pub(crate) fn decode_word(table: &mut DataTable, wire: &WireWord) -> u64 {
        Self::decode_word_policy(table, wire, true)
    }

    /// Decode with an explicit update policy mirroring the encoder's.
    ///
    /// Total over corrupted wires: under fault injection the data lines
    /// can lie, which may desynchronize the mirrored table (the dedup
    /// decision rides on `wire.data`); an index the mirror has not
    /// written yet then reads as zero instead of faulting — fault
    /// propagation is simulated, never a panic. Fault-free streams
    /// always present valid indices, so behaviour there is unchanged.
    pub(crate) fn decode_word_policy(table: &mut DataTable, wire: &WireWord, dedup: bool) -> u64 {
        match wire.outcome {
            Outcome::ZeroSkip => 0, // no table update for zeros
            Outcome::Bde => {
                let entry = table.get_or_zero(wire.index_line as usize);
                let word = wire.data ^ entry;
                // Encoder pushed iff search distance != 0; under BDE the
                // xor on the wire *is* the distance pattern, so data != 0
                // replicates the dedup decision without a CAM scan.
                if !dedup || wire.data != 0 {
                    table.push(word);
                }
                word
            }
            _ => {
                // Raw: replicate the encoder's dedup with an exact-match
                // lookup (one scan, same cost as the encoder side).
                if dedup {
                    table.push_unique(wire.data);
                } else {
                    table.push(wire.data);
                }
                wire.data
            }
        }
    }
}

impl ChipDecoder for MbdcDecoder {
    fn decode(&mut self, wire: &WireWord) -> u64 {
        Self::decode_word(&mut self.table, wire)
    }

    fn reset(&mut self) {
        self.table.reset();
    }
}

/// Self-register MBDC (Table I "BDE") in a
/// [`CodecRegistry`](super::registry::CodecRegistry).
pub fn register(reg: &mut super::registry::CodecRegistry) {
    reg.register("BDE", |spec| {
        let t = spec.table_size();
        Ok(super::registry::Codec::new(
            Box::new(MbdcEncoder::new(t)),
            Box::new(MbdcDecoder::new(t)),
        ))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn round_trip(words: &[u64]) -> (MbdcEncoder, MbdcDecoder) {
        let mut e = MbdcEncoder::new(64);
        let mut d = MbdcDecoder::new(64);
        for &w in words {
            let wire = e.encode(w, true);
            assert_eq!(d.decode(&wire), w, "word {w:#x}");
        }
        (e, d)
    }

    #[test]
    fn lossless_on_random_and_similar_streams() {
        let mut r = Rng::new(41);
        let random: Vec<u64> = (0..2000).map(|_| r.next_u64()).collect();
        round_trip(&random);
        let base = r.next_u64();
        let similar: Vec<u64> = (0..2000).map(|_| base ^ (1 << r.below(64))).collect();
        round_trip(&similar);
    }

    #[test]
    fn zero_bypass_no_table_update() {
        let mut e = MbdcEncoder::new(64);
        let wire = e.encode(0, true);
        assert_eq!(wire.outcome, Outcome::ZeroSkip);
        assert_eq!(wire.total_ones(), 0);
        assert_eq!(e.table.len(), 0);
    }

    #[test]
    fn dedup_keeps_unique_entries() {
        let mut e = MbdcEncoder::new(64);
        for _ in 0..10 {
            e.encode(0xABCD, true);
        }
        assert_eq!(e.table.len(), 1);
    }

    #[test]
    fn condition_charges_index_hamming() {
        let mut e = MbdcEncoder::new(64);
        // Fill slots so that the matching entry lands at index 63 (6 ones).
        for i in 0..63u64 {
            e.encode(0xF000_0000_0000_0000 | (i << 32), true);
        }
        e.encode(0x0000_0000_0000_001F, true); // 5 ones, slot 63
        // Word at distance 1 from slot-63 entry: xor=1 one, index 63 = 6
        // ones, total 7 > hamming(word)=6 -> raw wins under MBDC.
        let wire = e.encode(0x0000_0000_0000_003F, true);
        assert_eq!(wire.outcome, Outcome::Raw);
    }

    #[test]
    fn mirror_tables_stay_consistent() {
        let mut r = Rng::new(42);
        let mut e = MbdcEncoder::new(16);
        let mut d = MbdcDecoder::new(16);
        for _ in 0..5000 {
            let w = if r.chance(0.3) { 0 } else { r.next_u64() & 0xFFFF };
            let wire = e.encode(w, true);
            assert_eq!(d.decode(&wire), w);
            assert_eq!(e.table.snapshot(), d.table.snapshot());
        }
    }
}
