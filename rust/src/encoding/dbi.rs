//! DBI — Dynamic Bus Inversion at 8-bit granularity (Stan & Burleson).
//!
//! Per beat (byte): if more than 4 of the 8 bits are 1, the byte is
//! inverted and the chip's DBI line is asserted for that beat, so at most
//! four 1s ever cross the data lines per beat (§III).

use super::config::Scheme;
use super::stats::Outcome;
use super::wire::WireWord;
use super::{ChipDecoder, ChipEncoder};

/// Apply DBI to a 64-bit transfer: returns (encoded word, per-beat mask).
///
/// Branchless SWAR: per-byte popcounts land one count per byte, a
/// `+3 / bit-3` trick flags bytes with more than four 1s, and the flags
/// expand to full-byte inversion masks with a carry-free multiply.
#[inline]
pub fn dbi_encode(word: u64) -> (u64, u8) {
    // Per-byte popcount (each byte of `v` = ones in that byte of word).
    let mut v = word - ((word >> 1) & 0x5555_5555_5555_5555);
    v = (v & 0x3333_3333_3333_3333) + ((v >> 2) & 0x3333_3333_3333_3333);
    v = (v + (v >> 4)) & 0x0F0F_0F0F_0F0F_0F0F;
    // count > 4  <=>  count + 3 >= 8  <=>  bit 3 of (count + 3).
    let flags = (v.wrapping_add(0x0303_0303_0303_0303) & 0x0808_0808_0808_0808) >> 3;
    // Expand 0/1 byte flags to 0x00/0xFF (255 * flag never carries).
    let invert = flags.wrapping_mul(0xFF);
    // Gather each byte's flag bit into one u8 (bit b = beat b).
    let mask = (flags.wrapping_mul(0x0102_0408_1020_4080) >> 56) as u8;
    (word ^ invert, mask)
}

/// Invert the beats flagged in `mask` (the decoder side).
#[inline]
pub fn dbi_decode(word: u64, mask: u8) -> u64 {
    // Replicate the mask into every byte, isolate bit b in byte b
    // (power-of-two residue), then saturate any nonzero byte to 0xFF.
    let replicated = (mask as u64).wrapping_mul(0x0101_0101_0101_0101);
    let residue = replicated & 0x8040_2010_0804_0201;
    let high = residue.wrapping_add(0x7F7F_7F7F_7F7F_7F7F) & 0x8080_8080_8080_8080;
    word ^ (high >> 7).wrapping_mul(0xFF)
}

/// Standalone DBI encoder (Table I row "DBI").
#[derive(Default)]
pub struct DbiEncoder;

impl DbiEncoder {
    pub fn new() -> Self {
        DbiEncoder
    }
}

impl ChipEncoder for DbiEncoder {
    // Stateless SWAR transform: the default `encode_batch` loop inlines
    // `dbi_encode` per word with nothing left to hoist, so no override.
    fn encode(&mut self, word: u64, _approx: bool) -> WireWord {
        let (data, mask) = dbi_encode(word);
        WireWord {
            data,
            dbi_mask: mask,
            index_line: 0,
            index_used: false,
            ecc_line: 0,
            outcome: if word == 0 { Outcome::ZeroSkip } else { Outcome::Raw },
        }
    }

    fn scheme(&self) -> Scheme {
        Scheme::Dbi
    }

    fn reset(&mut self) {}
}

/// Standalone DBI decoder.
#[derive(Default)]
pub struct DbiDecoder;

impl DbiDecoder {
    pub fn new() -> Self {
        DbiDecoder
    }
}

impl ChipDecoder for DbiDecoder {
    fn decode(&mut self, wire: &WireWord) -> u64 {
        dbi_decode(wire.data, wire.dbi_mask)
    }

    fn reset(&mut self) {}
}

/// Self-register DBI in a [`CodecRegistry`](super::registry::CodecRegistry).
pub fn register(reg: &mut super::registry::CodecRegistry) {
    reg.register("DBI", |_spec| {
        Ok(super::registry::Codec::new(
            Box::new(DbiEncoder::new()),
            Box::new(DbiDecoder::new()),
        ))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn round_trip_random() {
        let mut r = Rng::new(21);
        for _ in 0..1000 {
            let w = r.next_u64();
            let (enc, mask) = dbi_encode(w);
            assert_eq!(dbi_decode(enc, mask), w);
        }
    }

    #[test]
    fn at_most_four_ones_per_byte() {
        let mut r = Rng::new(22);
        for _ in 0..1000 {
            let w = r.next_u64();
            let (enc, _) = dbi_encode(w);
            for beat in 0..8 {
                let byte = ((enc >> (beat * 8)) & 0xFF) as u8;
                assert!(byte.count_ones() <= 4, "byte {byte:08b}");
            }
        }
    }

    #[test]
    fn never_increases_total_ones_including_dbi_line() {
        let mut r = Rng::new(23);
        for _ in 0..1000 {
            let w = r.next_u64();
            let (enc, mask) = dbi_encode(w);
            // Inversion fires only for >4 ones: 8-k+1 <= k for k >= 5.
            assert!(enc.count_ones() + mask.count_ones() <= w.count_ones().max(4 * 8));
            for beat in 0..8 {
                let orig = ((w >> (beat * 8)) & 0xFF) as u8;
                let new = ((enc >> (beat * 8)) & 0xFF) as u8;
                let cost = new.count_ones() + ((mask >> beat) & 1) as u32;
                assert!(cost <= orig.count_ones().max(4));
            }
        }
    }

    #[test]
    fn all_ones_inverts_everywhere() {
        let (enc, mask) = dbi_encode(u64::MAX);
        assert_eq!(enc, 0);
        assert_eq!(mask, 0xFF);
    }

    #[test]
    fn exactly_four_ones_does_not_invert() {
        let (enc, mask) = dbi_encode(0x0F); // 4 ones in beat 0
        assert_eq!(enc, 0x0F);
        assert_eq!(mask, 0);
    }
}
