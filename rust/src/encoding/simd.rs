//! Runtime-dispatched SIMD backends for the CAM search primitives.
//!
//! `DataTable::most_similar_sliced` / `most_similar_batch` / `contains`
//! sit under every codec, the batch engine, `Pipeline` and
//! `ChannelArray`, so this module gives the search a backend seam:
//!
//! * **scalar** — the portable path, always available: the row-major
//!   XOR+POPCNT reference kernel here plus the bit-plane vertical
//!   counters in `data_table.rs`.
//! * **avx2** (`x86_64`) — 256-bit lanes: four table slots per XOR, a
//!   `vpshufb` nibble-LUT popcount (the shuffle-table method), and a
//!   packed `(distance << 32) | index` key min so the lowest-index
//!   tie-break falls out of a branchless vector min.
//! * **neon** (`aarch64`) — 128-bit lanes with `vcnt`+pairwise-add
//!   popcounts, same packed-key argmin.
//!
//! # Selection order
//!
//! The process-wide default is resolved **once** and cached: an explicit
//! `ZAC_SIMD=auto|scalar|avx2|neon` override first, then runtime feature
//! detection (`is_x86_feature_detected!` and its aarch64 twin), then the
//! scalar fallback. `Session::builder().simd(..)` and the CLI `--simd`
//! flag override it per session via a thread-scoped
//! [`with_backend`] around codec construction, so concurrent sessions
//! and tests never fight over a global. Requesting a backend the host
//! cannot run (`ZAC_SIMD=avx2` on a non-AVX2 machine) is an error at
//! the ingestion boundary, never a silent fallback.
//!
//! # Safety contract
//!
//! All `unsafe` lives inside this module. The public kernels re-probe
//! the (cached) CPU feature before entering a `#[target_feature]`
//! function and fall back to the scalar kernel otherwise, so they are
//! sound for any [`Backend`] value a caller can construct — call sites
//! stay unsafe-free. Every backend must be **bit-identical** to
//! [`most_similar_scalar`] (hit index, entry, distance, tie-breaks);
//! `rust/tests/simd_backends.rs` pins this property on every backend
//! the host can run.

use anyhow::Result;

/// A concrete, host-runnable search backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Portable scalar path (bit-plane mirror / row-major reference).
    Scalar,
    /// 256-bit AVX2 kernels (x86-64 only, runtime detected).
    Avx2,
    /// 128-bit NEON kernels (aarch64 only, runtime detected).
    Neon,
}

impl Backend {
    pub fn label(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }
}

/// A backend *preference*, as ingested from `ZAC_SIMD`, `--simd` or
/// [`Session::builder().simd(..)`](crate::session::SessionBuilder::simd)
/// — resolved against the host's feature set by [`SimdPref::resolve`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimdPref {
    /// Best available: avx2, else neon, else scalar.
    #[default]
    Auto,
    Scalar,
    Avx2,
    Neon,
}

impl SimdPref {
    /// Parse a preference token (case-insensitive; empty means `auto`).
    pub fn parse(s: &str) -> Result<SimdPref> {
        match s.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => Ok(SimdPref::Auto),
            "scalar" => Ok(SimdPref::Scalar),
            "avx2" => Ok(SimdPref::Avx2),
            "neon" => Ok(SimdPref::Neon),
            other => anyhow::bail!("unknown SIMD backend {other:?} (want auto|scalar|avx2|neon)"),
        }
    }

    /// The `ZAC_SIMD` environment preference (`Auto` when unset).
    pub fn from_env() -> Result<SimdPref> {
        match std::env::var("ZAC_SIMD") {
            Ok(v) => SimdPref::parse(&v).map_err(|e| anyhow::anyhow!("ZAC_SIMD: {e}")),
            Err(_) => Ok(SimdPref::Auto),
        }
    }

    /// Resolve against this host: `Auto` picks the best detected
    /// backend; an explicit `avx2`/`neon` request the host cannot run
    /// is an error, never a silent fallback.
    pub fn resolve(self) -> Result<Backend> {
        match self {
            SimdPref::Auto => Ok(if avx2_available() {
                Backend::Avx2
            } else if neon_available() {
                Backend::Neon
            } else {
                Backend::Scalar
            }),
            SimdPref::Scalar => Ok(Backend::Scalar),
            SimdPref::Avx2 => {
                anyhow::ensure!(
                    avx2_available(),
                    "SIMD backend avx2 requested but this host has no AVX2"
                );
                Ok(Backend::Avx2)
            }
            SimdPref::Neon => {
                anyhow::ensure!(
                    neon_available(),
                    "SIMD backend neon requested but this host has no NEON"
                );
                Ok(Backend::Neon)
            }
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            SimdPref::Auto => "auto",
            SimdPref::Scalar => "scalar",
            SimdPref::Avx2 => "avx2",
            SimdPref::Neon => "neon",
        }
    }
}

/// Whether the AVX2 kernels can run here (cached CPUID probe).
#[cfg(target_arch = "x86_64")]
pub fn avx2_available() -> bool {
    is_x86_feature_detected!("avx2")
}
#[cfg(not(target_arch = "x86_64"))]
pub fn avx2_available() -> bool {
    false
}

/// Whether the NEON kernels can run here.
#[cfg(target_arch = "aarch64")]
pub fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}
#[cfg(not(target_arch = "aarch64"))]
pub fn neon_available() -> bool {
    false
}

/// Every backend this host can run, scalar first (property tests and
/// the `simd_compare` bench iterate this).
pub fn available_backends() -> Vec<Backend> {
    let mut v = vec![Backend::Scalar];
    if avx2_available() {
        v.push(Backend::Avx2);
    }
    if neon_available() {
        v.push(Backend::Neon);
    }
    v
}

static DEFAULT: std::sync::OnceLock<Backend> = std::sync::OnceLock::new();

thread_local! {
    static OVERRIDE: std::cell::Cell<Option<Backend>> = const { std::cell::Cell::new(None) };
}

/// The process-wide default backend, resolved once from `ZAC_SIMD` +
/// feature detection and cached. Errors (malformed `ZAC_SIMD`, or an
/// explicit backend the host lacks) surface here — `Session::build()`
/// and the CLI call this before any table exists.
pub fn default_backend() -> Result<Backend> {
    if let Some(b) = DEFAULT.get() {
        return Ok(*b);
    }
    let resolved = SimdPref::from_env()?.resolve()?;
    Ok(*DEFAULT.get_or_init(|| resolved))
}

/// The backend a `DataTable` constructed *now* on this thread captures:
/// the innermost [`with_backend`] scope if one is active, else the
/// process default. Panics on a malformed `ZAC_SIMD` only when no
/// ingestion boundary validated it first (the session builder and the
/// CLI both do).
pub fn current() -> Backend {
    if let Some(b) = OVERRIDE.with(|c| c.get()) {
        return b;
    }
    default_backend().unwrap_or_else(|e| panic!("{e}"))
}

/// Run `f` with `backend` as the table-construction backend on this
/// thread. Session builds wrap codec construction in this, so a
/// per-session `--simd`/builder override never leaks into other
/// sessions, threads or tests. Restores the previous scope even if `f`
/// unwinds.
pub fn with_backend<R>(backend: Backend, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<Backend>);
    impl Drop for Restore {
        fn drop(&mut self) {
            OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(OVERRIDE.with(|c| c.replace(Some(backend))));
    f()
}

/// Portable scalar reference kernel: one XOR + POPCNT per entry, the
/// (distance, index) pair packed as `(distance << 32) | index` so a
/// single branchless `min` yields both the minimum distance *and* the
/// lowest-index tie-break. The widened u64 key carries any index a
/// `DataTable` can hold (capacity is capped at `u32::MAX` by its
/// constructor) — the old `(distance << 8) | index` u32 packing
/// silently truncated indices ≥ 256 in release builds.
///
/// Every other backend must stay bit-identical to this. `entries` must
/// be non-empty.
pub fn most_similar_scalar(entries: &[u64], word: u64) -> (usize, u32) {
    debug_assert!(!entries.is_empty());
    let mut best_key = u64::MAX;
    for (i, &e) in entries.iter().enumerate() {
        let key = (u64::from((e ^ word).count_ones()) << 32) | i as u64;
        best_key = best_key.min(key);
    }
    ((best_key & 0xFFFF_FFFF) as usize, (best_key >> 32) as u32)
}

/// Scalar exact-match kernel (the row-major reference for `contains`).
pub fn contains_scalar(entries: &[u64], word: u64) -> bool {
    entries.contains(&word)
}

/// Dispatched most-similar search over the valid row-major entries.
/// Returns `(index, distance)` of the best hit, bit-identical to
/// [`most_similar_scalar`]. Falls back to the scalar kernel when
/// `backend`'s CPU feature is absent — unreachable for backends from
/// [`SimdPref::resolve`], which probes first, but it keeps this
/// function sound (and unsafe-free to call) for any hand-constructed
/// [`Backend`].
pub fn most_similar(backend: Backend, entries: &[u64], word: u64) -> (usize, u32) {
    match backend {
        Backend::Scalar => most_similar_scalar(entries, word),
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 if avx2_available() => unsafe { avx2::most_similar(entries, word) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon if neon_available() => unsafe { neon::most_similar(entries, word) },
        _ => most_similar_scalar(entries, word),
    }
}

/// Dispatched exact-match lookup over the valid row-major entries.
/// Same soundness/fallback contract as [`most_similar`].
pub fn contains(backend: Backend, entries: &[u64], word: u64) -> bool {
    match backend {
        Backend::Scalar => contains_scalar(entries, word),
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 if avx2_available() => unsafe { avx2::contains(entries, word) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon if neon_available() => unsafe { neon::contains(entries, word) },
        _ => contains_scalar(entries, word),
    }
}

/// AVX2 kernels: four 64-bit table slots per 256-bit vector.
///
/// # Safety
/// Every function here is `#[target_feature(enable = "avx2")]` and must
/// only be entered after `avx2_available()` returned true — the safe
/// wrappers above enforce that.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    #[allow(clippy::wildcard_imports)]
    use std::arch::x86_64::*;

    /// Per-64-bit-lane popcount via the `vpshufb` nibble LUT +
    /// `vpsadbw` horizontal byte sum (the classic shuffle-table
    /// popcount — no AVX-512 `vpopcntq` needed).
    #[target_feature(enable = "avx2")]
    unsafe fn popcnt_epi64(v: __m256i) -> __m256i {
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(v, low);
        let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), low);
        let nibbles = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        _mm256_sad_epu8(nibbles, _mm256_setzero_si256())
    }

    /// `(index, distance)` of the entry nearest `word`. Packs
    /// `(distance << 32) | index` into each lane and vector-mins; keys
    /// are < 2^39, so signed 64-bit compares are exact. The tail (< 4
    /// slots) folds in scalar, at higher indices than every vector
    /// lane, so the lowest-index tie-break is preserved.
    #[target_feature(enable = "avx2")]
    pub unsafe fn most_similar(entries: &[u64], word: u64) -> (usize, u32) {
        let q = _mm256_set1_epi64x(word as i64);
        let mut best = _mm256_set1_epi64x(i64::MAX);
        let mut idx = _mm256_setr_epi64x(0, 1, 2, 3);
        let step = _mm256_set1_epi64x(4);
        let chunks = entries.chunks_exact(4);
        let tail = chunks.remainder();
        for chunk in chunks {
            let e = _mm256_loadu_si256(chunk.as_ptr() as *const __m256i);
            let d = popcnt_epi64(_mm256_xor_si256(e, q));
            let key = _mm256_or_si256(_mm256_slli_epi64::<32>(d), idx);
            let worse = _mm256_cmpgt_epi64(best, key);
            best = _mm256_blendv_epi8(best, key, worse);
            idx = _mm256_add_epi64(idx, step);
        }
        let mut lanes = [u64::MAX; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, best);
        // Untouched lanes hold i64::MAX (> any real key < 2^39).
        let mut best_key = lanes.iter().copied().min().unwrap_or(u64::MAX);
        let base = entries.len() - tail.len();
        for (j, &e) in tail.iter().enumerate() {
            let key = (u64::from((e ^ word).count_ones()) << 32) | (base + j) as u64;
            best_key = best_key.min(key);
        }
        ((best_key & 0xFFFF_FFFF) as usize, (best_key >> 32) as u32)
    }

    /// Exact-match lookup: four slots per compare, movemask early exit.
    #[target_feature(enable = "avx2")]
    pub unsafe fn contains(entries: &[u64], word: u64) -> bool {
        let q = _mm256_set1_epi64x(word as i64);
        let chunks = entries.chunks_exact(4);
        let tail = chunks.remainder();
        for chunk in chunks {
            let e = _mm256_loadu_si256(chunk.as_ptr() as *const __m256i);
            if _mm256_movemask_epi8(_mm256_cmpeq_epi64(e, q)) != 0 {
                return true;
            }
        }
        tail.contains(&word)
    }
}

/// NEON kernels: two 64-bit table slots per 128-bit vector, `vcnt`
/// per-byte popcount folded by pairwise widening adds.
///
/// # Safety
/// `#[target_feature(enable = "neon")]`; entered only after
/// `neon_available()` returned true (NEON is baseline on aarch64, but
/// the probe keeps the contract uniform).
#[cfg(target_arch = "aarch64")]
mod neon {
    #[allow(clippy::wildcard_imports)]
    use std::arch::aarch64::*;

    /// Same packed-key argmin as the AVX2 kernel; the 2-lane min folds
    /// scalar (lane 0 first, preserving the lowest-index tie-break).
    #[target_feature(enable = "neon")]
    pub unsafe fn most_similar(entries: &[u64], word: u64) -> (usize, u32) {
        let q = vdupq_n_u64(word);
        let mut best_key = u64::MAX;
        let chunks = entries.chunks_exact(2);
        let tail = chunks.remainder();
        let mut base = 0u64;
        for chunk in chunks {
            let e = vld1q_u64(chunk.as_ptr());
            let x = veorq_u64(e, q);
            let d = vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(vcntq_u8(vreinterpretq_u8_u64(x)))));
            let k0 = (vgetq_lane_u64::<0>(d) << 32) | base;
            let k1 = (vgetq_lane_u64::<1>(d) << 32) | (base + 1);
            best_key = best_key.min(k0).min(k1);
            base += 2;
        }
        for (j, &e) in tail.iter().enumerate() {
            let key = (u64::from((e ^ word).count_ones()) << 32) | (base + j as u64);
            best_key = best_key.min(key);
        }
        ((best_key & 0xFFFF_FFFF) as usize, (best_key >> 32) as u32)
    }

    /// Exact-match lookup, two slots per compare.
    #[target_feature(enable = "neon")]
    pub unsafe fn contains(entries: &[u64], word: u64) -> bool {
        let q = vdupq_n_u64(word);
        let chunks = entries.chunks_exact(2);
        let tail = chunks.remainder();
        for chunk in chunks {
            let e = vld1q_u64(chunk.as_ptr());
            let eq = vceqq_u64(e, q);
            if vmaxvq_u32(vreinterpretq_u32_u64(eq)) != 0 {
                return true;
            }
        }
        tail.contains(&word)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::seeded_rng;

    #[test]
    fn pref_parses_all_tokens_and_rejects_garbage() {
        assert_eq!(SimdPref::parse("auto").unwrap(), SimdPref::Auto);
        assert_eq!(SimdPref::parse("").unwrap(), SimdPref::Auto);
        assert_eq!(SimdPref::parse("SCALAR").unwrap(), SimdPref::Scalar);
        assert_eq!(SimdPref::parse("avx2").unwrap(), SimdPref::Avx2);
        assert_eq!(SimdPref::parse(" neon ").unwrap(), SimdPref::Neon);
        let err = SimdPref::parse("avx512").unwrap_err().to_string();
        assert!(err.contains("avx512"), "{err}");
        assert!(err.contains("auto|scalar|avx2|neon"), "{err}");
    }

    #[test]
    fn auto_resolves_and_scalar_is_always_available() {
        let auto = SimdPref::Auto.resolve().unwrap();
        assert!(available_backends().contains(&auto));
        assert_eq!(SimdPref::Scalar.resolve().unwrap(), Backend::Scalar);
        assert_eq!(available_backends()[0], Backend::Scalar);
    }

    #[test]
    fn unavailable_explicit_backend_is_an_error_not_a_fallback() {
        if !avx2_available() {
            let e = SimdPref::Avx2.resolve().unwrap_err().to_string();
            assert!(e.contains("avx2"), "{e}");
        }
        if !neon_available() {
            let e = SimdPref::Neon.resolve().unwrap_err().to_string();
            assert!(e.contains("neon"), "{e}");
        }
    }

    #[test]
    fn with_backend_scopes_nest_and_restore() {
        let outer = current();
        with_backend(Backend::Scalar, || {
            assert_eq!(current(), Backend::Scalar);
            if let Some(&simd) = available_backends().last() {
                with_backend(simd, || assert_eq!(current(), simd));
            }
            assert_eq!(current(), Backend::Scalar);
        });
        assert_eq!(current(), outer);
    }

    #[test]
    fn with_backend_restores_on_unwind() {
        let outer = current();
        let _ = std::panic::catch_unwind(|| {
            with_backend(Backend::Scalar, || panic!("boom"));
        });
        assert_eq!(current(), outer);
    }

    #[test]
    fn every_available_kernel_matches_the_scalar_reference() {
        let mut r = seeded_rng(0x51D);
        // Lengths around the 4-lane (AVX2) and 2-lane (NEON) chunk
        // boundaries, plus multi-hundred tables past the old 256 cap.
        for n in [1usize, 2, 3, 4, 5, 7, 8, 9, 63, 64, 65, 255, 256, 257, 300] {
            let entries: Vec<u64> = (0..n)
                .map(|i| if i % 5 == 0 { 0 } else { r.next_u64() })
                .collect();
            for _ in 0..40 {
                let q = match r.below(4) {
                    0 => 0,
                    1 => u64::MAX,
                    2 => entries[r.below(n as u64) as usize] ^ (1u64 << r.below(64)),
                    _ => r.next_u64(),
                };
                let want = most_similar_scalar(&entries, q);
                let want_in = contains_scalar(&entries, q);
                for &b in &available_backends() {
                    assert_eq!(most_similar(b, &entries, q), want, "{} n={n} q={q:#x}", b.label());
                    assert_eq!(contains(b, &entries, q), want_in, "{} n={n} q={q:#x}", b.label());
                }
            }
        }
    }

    #[test]
    fn scalar_tie_break_is_lowest_index() {
        // Duplicate entries: index 1 and 5 tie at distance 0.
        let entries = [7u64, 3, 9, 11, 13, 3, 3];
        for &b in &available_backends() {
            assert_eq!(most_similar(b, &entries, 3), (1, 0), "{}", b.label());
        }
    }
}
