//! Per-scheme knob structs — the typed replacement for the `ZacConfig`
//! god-struct at every v2 API boundary.
//!
//! Each built-in scheme declares exactly the knobs it understands:
//! [`ZacKnobs`] for ZAC-DEST (similarity limit, chunk geometry,
//! tolerance/truncation, table size, ablation switches), [`TableKnobs`]
//! for the table-based exact coders (BDE / BDE_ORG), and nothing for
//! ORG / DBI. A [`Knobs`] value rides inside a
//! [`CodecSpec`](super::registry::CodecSpec) and is validated at every
//! ingestion boundary (CLI flags, run-config TOML, sweep TOML,
//! environment overrides) before any codec is constructed — a knob a
//! scheme does not have can no longer leak into it.
//!
//! The legacy [`ZacConfig`] keeps its shape for the deprecated shim
//! paths but delegates all derived-mask/validation logic here, so the
//! rules live in exactly one place.

use crate::util::bits::{lsb_chunk_mask, msb_chunk_mask};

use super::config::{Ablation, Scheme, ZacConfig};

/// ZAC-DEST knobs (paper §V-B/§VIII-G plus the §IV/§V ablation switches).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ZacKnobs {
    /// Similarity limit in percent (50..=100).
    pub similarity_limit_pct: u32,
    /// Chunk width in bits: 8, 16, 32 or 64 (the data element width).
    pub chunk_width: u32,
    /// Tolerance bits per chunk (MSB side); paper circuit offers {0, W/8, W/4}.
    pub tolerance_bits: u32,
    /// Truncation bits per chunk (LSB side); {0, W/8, W/4}.
    pub truncation_bits: u32,
    /// Optional explicit tolerance mask overriding the per-chunk MSB rule
    /// (used for IEEE-754 weights: sign+exponent bits, Fig. 19).
    pub tolerance_mask_override: Option<u64>,
    /// Data-table entries per chip (paper: 64).
    pub table_size: usize,
    /// Design-choice ablation switches (paper defaults normally).
    pub ablation: Ablation,
}

impl Default for ZacKnobs {
    fn default() -> Self {
        ZacKnobs {
            similarity_limit_pct: 80,
            chunk_width: 8,
            tolerance_bits: 0,
            truncation_bits: 0,
            tolerance_mask_override: None,
            table_size: 64,
            ablation: Ablation::default(),
        }
    }
}

impl ZacKnobs {
    /// Knobs with a similarity limit only (the common case).
    pub fn limit(similarity_limit_pct: u32) -> Self {
        ZacKnobs {
            similarity_limit_pct,
            ..Default::default()
        }
    }

    /// All three §V knobs (chunk width 8, byte data).
    pub fn full(limit_pct: u32, truncation_bits: u32, tolerance_bits: u32) -> Self {
        ZacKnobs {
            similarity_limit_pct: limit_pct,
            truncation_bits,
            tolerance_bits,
            ..Default::default()
        }
    }

    /// IEEE-754 f32 weight traffic: 32-bit chunks with sign+exponent as
    /// the tolerance mask (§VIII-G). The one definition of the protected
    /// field set lives in
    /// [`float_layout::weight_tolerance_mask`](crate::trace::float_layout::weight_tolerance_mask).
    pub fn weights(limit_pct: u32) -> Self {
        ZacKnobs {
            similarity_limit_pct: limit_pct,
            chunk_width: 32,
            tolerance_mask_override: Some(crate::trace::float_layout::weight_tolerance_mask()),
            ..Default::default()
        }
    }

    /// Maximum number of dissimilar bits for the skip to fire:
    /// `ceil(64 * (100 - limit) / 100)` (strict `<` in Alg. 2).
    pub fn dissimilar_threshold(&self) -> u32 {
        let num = 64 * (100 - self.similarity_limit_pct);
        num.div_ceil(100).max(1)
    }

    /// Effective tolerance mask (bits that must match exactly).
    pub fn tolerance_mask(&self) -> u64 {
        if let Some(m) = self.tolerance_mask_override {
            return m;
        }
        msb_chunk_mask(self.chunk_width, self.tolerance_bits)
    }

    /// Truncation mask (bits zeroed / excluded from comparison).
    pub fn truncation_mask(&self) -> u64 {
        lsb_chunk_mask(self.chunk_width, self.truncation_bits)
    }

    /// Total truncated bits per 64-bit word.
    pub fn truncated_bits_total(&self) -> u32 {
        self.truncation_mask().count_ones()
    }

    /// Validate invariants (chunk sizes, knob ranges, mask disjointness).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            matches!(self.chunk_width, 8 | 16 | 32 | 64),
            "chunk_width must be 8/16/32/64, got {}",
            self.chunk_width
        );
        anyhow::ensure!(
            (50..=100).contains(&self.similarity_limit_pct),
            "similarity limit {}% out of range [50,100]",
            self.similarity_limit_pct
        );
        anyhow::ensure!(
            self.tolerance_bits + self.truncation_bits <= self.chunk_width,
            "tolerance {} + truncation {} exceed chunk width {}",
            self.tolerance_bits,
            self.truncation_bits,
            self.chunk_width
        );
        anyhow::ensure!(
            self.table_size > 0 && self.table_size <= 64,
            "table_size {} out of range (OHE index must fit 64 data lines)",
            self.table_size
        );
        anyhow::ensure!(
            self.tolerance_mask() & self.truncation_mask() == 0,
            "tolerance and truncation masks overlap"
        );
        Ok(())
    }

    /// The legacy god-struct carrying these knobs (shim paths and the
    /// ZAC encoder internals still speak [`ZacConfig`]).
    pub fn to_config(&self) -> ZacConfig {
        ZacConfig {
            scheme: Scheme::ZacDest,
            similarity_limit_pct: self.similarity_limit_pct,
            chunk_width: self.chunk_width,
            tolerance_bits: self.tolerance_bits,
            truncation_bits: self.truncation_bits,
            tolerance_mask_override: self.tolerance_mask_override,
            table_size: self.table_size,
            ablation: self.ablation,
        }
    }

    /// Extract the ZAC knobs out of a legacy [`ZacConfig`].
    pub fn from_config(cfg: &ZacConfig) -> ZacKnobs {
        ZacKnobs {
            similarity_limit_pct: cfg.similarity_limit_pct,
            chunk_width: cfg.chunk_width,
            tolerance_bits: cfg.tolerance_bits,
            truncation_bits: cfg.truncation_bits,
            tolerance_mask_override: cfg.tolerance_mask_override,
            table_size: cfg.table_size,
            ablation: cfg.ablation,
        }
    }
}

/// Knobs of the table-based exact coders (BDE / BDE_ORG).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TableKnobs {
    /// Data-table entries per chip (paper: 64).
    pub table_size: usize,
}

impl Default for TableKnobs {
    fn default() -> Self {
        TableKnobs { table_size: 64 }
    }
}

impl TableKnobs {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.table_size > 0 && self.table_size <= 64,
            "table_size {} out of range 1..=64",
            self.table_size
        );
        Ok(())
    }
}

/// The knob bag a [`CodecSpec`](super::registry::CodecSpec) carries:
/// exactly the knobs its scheme understands.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Knobs {
    /// Knob-free schemes (ORG, DBI) and out-of-tree codecs whose
    /// factories carry their own configuration.
    None,
    /// Table-based exact coders (BDE, BDE_ORG).
    Table(TableKnobs),
    /// ZAC-DEST.
    Zac(ZacKnobs),
}

impl Knobs {
    /// The default knob bag for a built-in scheme.
    pub fn for_scheme(scheme: Scheme) -> Knobs {
        match scheme {
            Scheme::ZacDest => Knobs::Zac(ZacKnobs::default()),
            Scheme::Bde | Scheme::BdeOrg => Knobs::Table(TableKnobs::default()),
            Scheme::Org | Scheme::Dbi => Knobs::None,
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        match self {
            Knobs::None => Ok(()),
            Knobs::Table(t) => t.validate(),
            Knobs::Zac(z) => z.validate(),
        }
    }

    /// The table size every table-carrying variant agrees on (the
    /// paper's 64 for knob-free schemes).
    pub fn table_size(&self) -> usize {
        match self {
            Knobs::None => 64,
            Knobs::Table(t) => t.table_size,
            Knobs::Zac(z) => z.table_size,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zac_knobs_mirror_legacy_config() {
        let k = ZacKnobs::weights(60);
        let cfg = k.to_config();
        assert_eq!(cfg.scheme, Scheme::ZacDest);
        assert_eq!(cfg.tolerance_mask(), 0xFF80_0000_FF80_0000);
        assert_eq!(ZacKnobs::from_config(&cfg), k);
        assert_eq!(k.dissimilar_threshold(), cfg.dissimilar_threshold());
    }

    #[test]
    fn knob_validation_matches_config_validation() {
        let mut k = ZacKnobs::default();
        k.chunk_width = 12;
        assert!(k.validate().is_err());
        let mut k = ZacKnobs::default();
        k.tolerance_bits = 6;
        k.truncation_bits = 4;
        assert!(k.validate().is_err());
        assert!(TableKnobs { table_size: 0 }.validate().is_err());
        assert!(TableKnobs { table_size: 65 }.validate().is_err());
        assert!(TableKnobs { table_size: 16 }.validate().is_ok());
        assert!(Knobs::None.validate().is_ok());
    }

    #[test]
    fn per_scheme_defaults() {
        assert_eq!(Knobs::for_scheme(Scheme::Org), Knobs::None);
        assert_eq!(Knobs::for_scheme(Scheme::Dbi), Knobs::None);
        assert!(matches!(Knobs::for_scheme(Scheme::Bde), Knobs::Table(_)));
        assert!(matches!(Knobs::for_scheme(Scheme::BdeOrg), Knobs::Table(_)));
        assert!(matches!(Knobs::for_scheme(Scheme::ZacDest), Knobs::Zac(_)));
        assert_eq!(Knobs::for_scheme(Scheme::Org).table_size(), 64);
    }
}
