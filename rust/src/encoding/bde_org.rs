//! BDE_ORG — the original Bitwise Difference Coder (Seol et al. [14]),
//! paper Algorithm 1.
//!
//! * MSE search over the data table; if `hamming(data)` >
//!   `hamming(data XOR mse)` the xor is sent plus the MSE's binary index
//!   on the dedicated index line; otherwise raw data is sent.
//! * The index line carries an address in *both* branches ("the index
//!   lines send the address", §III) — in the raw branch it is the slot
//!   the receiver must update, which is what drags BDE_ORG's sideband
//!   energy up and makes it lose to DBI in Fig. 10.
//! * Table update: only on raw (unencoded) transfers, per Algorithm 1's
//!   `else` branch — the "not updated regularly" behaviour §VIII-B blames
//!   for its weakness on uniform workloads like Eigen.

use super::config::Scheme;
use super::data_table::DataTable;
use super::stats::Outcome;
use super::wire::WireWord;
use super::{ChipDecoder, ChipEncoder};

pub struct BdeOrgEncoder {
    table: DataTable,
}

impl BdeOrgEncoder {
    pub fn new(table_size: usize) -> Self {
        BdeOrgEncoder {
            table: DataTable::new(table_size),
        }
    }

    /// Slot the next raw word will occupy (FIFO head) — driven on the
    /// index line in the raw branch so the mirror updates the same slot.
    fn next_slot(&self) -> usize {
        self.table.next_slot()
    }

    /// Per-word encode core; `sliced` picks the CAM search path (the
    /// batch path runs the table's dispatched backend — bit-plane
    /// mirror on scalar, AVX2/NEON row-major kernels otherwise — with
    /// results pinned identical either way).
    #[inline]
    fn encode_one(&mut self, word: u64, sliced: bool) -> WireWord {
        let hit = if sliced {
            self.table.most_similar_sliced(word)
        } else {
            self.table.most_similar(word)
        };
        if let Some(hit) = hit {
            let xored = word ^ hit.entry;
            if word.count_ones() > xored.count_ones() {
                // Encoded branch: xor on data lines, MSE index sideband.
                return WireWord {
                    data: xored,
                    dbi_mask: 0,
                    index_line: hit.index as u8,
                    index_used: true,
                    ecc_line: 0,
                    outcome: Outcome::Bde,
                };
            }
        }
        // Raw branch: data as-is, write-slot address on the index line,
        // table updated (FIFO) on both sides.
        let slot = self.next_slot();
        self.table.push(word);
        WireWord {
            data: word,
            dbi_mask: 0,
            index_line: slot as u8,
            index_used: true,
            ecc_line: 0,
            outcome: if word == 0 { Outcome::ZeroSkip } else { Outcome::Raw },
        }
    }
}

impl ChipEncoder for BdeOrgEncoder {
    fn encode(&mut self, word: u64, _approx: bool) -> WireWord {
        self.encode_one(word, false)
    }

    fn encode_batch(&mut self, words: &[u64], approx: &[bool], out: &mut [WireWord]) {
        assert_eq!(words.len(), approx.len());
        assert_eq!(words.len(), out.len());
        for (&word, slot) in words.iter().zip(out.iter_mut()) {
            *slot = self.encode_one(word, true);
        }
    }

    fn scheme(&self) -> Scheme {
        Scheme::BdeOrg
    }

    fn reset(&mut self) {
        self.table.reset();
    }
}

pub struct BdeOrgDecoder {
    table: DataTable,
}

impl BdeOrgDecoder {
    pub fn new(table_size: usize) -> Self {
        BdeOrgDecoder {
            table: DataTable::new(table_size),
        }
    }
}

impl ChipDecoder for BdeOrgDecoder {
    fn decode(&mut self, wire: &WireWord) -> u64 {
        match wire.outcome {
            Outcome::Bde => {
                // Total over fault-corrupted wires: an index the mirror
                // has not written reads as zero (see MbdcDecoder).
                let entry = self.table.get_or_zero(wire.index_line as usize);
                wire.data ^ entry
            }
            _ => {
                // Raw/zero: mirror the FIFO update.
                self.table.push(wire.data);
                wire.data
            }
        }
    }

    fn reset(&mut self) {
        self.table.reset();
    }
}

/// Self-register BDE_ORG in a [`CodecRegistry`](super::registry::CodecRegistry).
pub fn register(reg: &mut super::registry::CodecRegistry) {
    reg.register("BDE_ORG", |spec| {
        let t = spec.table_size();
        Ok(super::registry::Codec::new(
            Box::new(BdeOrgEncoder::new(t)),
            Box::new(BdeOrgDecoder::new(t)),
        ))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn round_trip(words: &[u64]) {
        let mut e = BdeOrgEncoder::new(64);
        let mut d = BdeOrgDecoder::new(64);
        for &w in words {
            let wire = e.encode(w, true);
            assert_eq!(d.decode(&wire), w);
        }
    }

    #[test]
    fn lossless_on_random_stream() {
        let mut r = Rng::new(31);
        let words: Vec<u64> = (0..2000).map(|_| r.next_u64()).collect();
        round_trip(&words);
    }

    #[test]
    fn lossless_on_similar_stream() {
        let mut r = Rng::new(32);
        let base = r.next_u64();
        let words: Vec<u64> = (0..2000).map(|_| base ^ (1 << r.below(64))).collect();
        round_trip(&words);
    }

    #[test]
    fn encodes_repeat_as_low_weight() {
        let mut e = BdeOrgEncoder::new(64);
        let w = 0xFFFF_FFFF_0000_0000;
        let first = e.encode(w, true);
        assert_eq!(first.outcome, Outcome::Raw);
        let second = e.encode(w, true);
        assert_eq!(second.outcome, Outcome::Bde);
        assert_eq!(second.data, 0); // exact repeat xors to zero
    }

    #[test]
    fn table_not_updated_on_encoded_transfers() {
        let mut e = BdeOrgEncoder::new(64);
        e.encode(0xFF00, true); // raw, stored
        e.encode(0xFF01, true); // encoded against 0xFF00
        // Third similar word should still match 0xFF00 (no new entry).
        let wire = e.encode(0xFF02, true);
        assert_eq!(wire.outcome, Outcome::Bde);
        assert_eq!(wire.index_line, 0);
        assert_eq!(wire.data, 0xFF00 ^ 0xFF02);
    }

    #[test]
    fn index_line_driven_in_both_branches() {
        let mut e = BdeOrgEncoder::new(64);
        let raw = e.encode(0xABCD, true);
        assert!(raw.index_used);
        let enc = e.encode(0xABCF, true);
        assert!(enc.index_used);
    }
}
