//! ORG — the unencoded baseline (paper Table I).

use super::config::Scheme;
use super::stats::Outcome;
use super::wire::WireWord;
use super::{ChipDecoder, ChipEncoder};

/// Passthrough encoder: drives the word as-is, no sidebands.
#[derive(Default)]
pub struct OrgEncoder;

impl OrgEncoder {
    pub fn new() -> Self {
        OrgEncoder
    }
}

impl ChipEncoder for OrgEncoder {
    // Stateless passthrough: the default `encode_batch` loop already
    // compiles to the optimal per-word copy, so no override is needed.
    fn encode(&mut self, word: u64, _approx: bool) -> WireWord {
        let mut w = WireWord::raw(word);
        if word == 0 {
            // Classified for stats only; the wire is identical.
            w.outcome = Outcome::ZeroSkip;
        }
        w
    }

    fn scheme(&self) -> Scheme {
        Scheme::Org
    }

    fn reset(&mut self) {}
}

/// Passthrough decoder.
#[derive(Default)]
pub struct OrgDecoder;

impl OrgDecoder {
    pub fn new() -> Self {
        OrgDecoder
    }
}

impl ChipDecoder for OrgDecoder {
    fn decode(&mut self, wire: &WireWord) -> u64 {
        wire.data
    }

    fn reset(&mut self) {}
}

/// Self-register ORG in a [`CodecRegistry`](super::registry::CodecRegistry).
pub fn register(reg: &mut super::registry::CodecRegistry) {
    reg.register("ORG", |_spec| {
        Ok(super::registry::Codec::new(
            Box::new(OrgEncoder::new()),
            Box::new(OrgDecoder::new()),
        ))
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passthrough_round_trip() {
        let mut e = OrgEncoder::new();
        let mut d = OrgDecoder::new();
        for w in [0u64, 1, u64::MAX, 0xDEADBEEF_CAFEBABE] {
            let wire = e.encode(w, true);
            assert_eq!(wire.data, w);
            assert_eq!(d.decode(&wire), w);
            assert_eq!(wire.total_ones(), w.count_ones());
        }
    }
}
