//! Scheme taxonomy (paper Table I) and the legacy `ZacConfig` knob
//! struct.
//!
//! **Deprecated shim:** `ZacConfig` is the v1 god-struct — ZAC-only
//! knobs leaking into every scheme. New code describes codecs with a
//! [`CodecSpec`](super::registry::CodecSpec) carrying per-scheme
//! [`Knobs`](super::knobs::Knobs) instead; `ZacConfig` remains for the
//! legacy free-function paths and the ZAC encoder internals, and
//! delegates all derived-mask/validation logic to
//! [`ZacKnobs`](super::knobs::ZacKnobs) so the rules live in one place.

use super::knobs::ZacKnobs;

/// Encoding schemes under evaluation (paper Table I).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Original unencoded data (baseline).
    Org,
    /// Dynamic Bus Inversion.
    Dbi,
    /// Original Bitwise Difference Coder (Seol et al., Algorithm 1).
    BdeOrg,
    /// Modified BD-Coder (the paper's stricter baseline, "BDE").
    Bde,
    /// ZAC-DEST one-hot skip encoding (Algorithm 2, includes DBI stage).
    ZacDest,
}

impl Scheme {
    /// Paper Table I label.
    pub fn label(self) -> &'static str {
        match self {
            Scheme::Org => "ORG",
            Scheme::Dbi => "DBI",
            Scheme::BdeOrg => "BDE_ORG",
            Scheme::Bde => "BDE",
            Scheme::ZacDest => "OHE",
        }
    }

    /// Paper Table I description.
    pub fn description(self) -> &'static str {
        match self {
            Scheme::Org => "Original Unencoded Data (Baseline)",
            Scheme::Dbi => "Dynamic Bus Inversion",
            Scheme::BdeOrg => "Original Bitwise Difference Coder",
            Scheme::Bde => "Modified Bitwise Difference Coder",
            Scheme::ZacDest => "One-Hot Encoding of ZAC-DEST",
        }
    }

    /// All schemes, in Table I order.
    pub fn all() -> [Scheme; 5] {
        [
            Scheme::ZacDest,
            Scheme::BdeOrg,
            Scheme::Bde,
            Scheme::Dbi,
            Scheme::Org,
        ]
    }

    pub fn parse(s: &str) -> Option<Scheme> {
        match s.to_ascii_uppercase().as_str() {
            "ORG" => Some(Scheme::Org),
            "DBI" => Some(Scheme::Dbi),
            "BDE_ORG" | "BDEORG" => Some(Scheme::BdeOrg),
            "BDE" | "MBDC" => Some(Scheme::Bde),
            "OHE" | "ZAC" | "ZAC-DEST" | "ZACDEST" | "ZAC_DEST" => Some(Scheme::ZacDest),
            _ => None,
        }
    }
}

/// Full encoder configuration: scheme + the three ZAC-DEST knobs.
///
/// * **Similarity Limit** — % of the 64 bits that must match the most
///   similar table entry for the skip-transfer to fire. Paper evaluates
///   {90, 80, 75, 70} (⇒ at most {7, 13, 16, 20} dissimilar bits) for
///   images and {70, 65, 60, 50} for weights.
/// * **Truncation** — LSBs per chunk zeroed before comparison and
///   reconstruction (removed from the transfer entirely).
/// * **Tolerance** — MSBs per chunk that must match *exactly* for the
///   skip to fire (protects sign/exponent-like bits).
#[derive(Clone, Debug, PartialEq)]
pub struct ZacConfig {
    pub scheme: Scheme,
    /// Similarity limit in percent (50..=100). Only used by ZacDest.
    pub similarity_limit_pct: u32,
    /// Chunk width in bits: 8, 16, 32 or 64 (the data element width).
    pub chunk_width: u32,
    /// Tolerance bits per chunk (MSB side); paper circuit offers {0, W/8, W/4}.
    pub tolerance_bits: u32,
    /// Truncation bits per chunk (LSB side); {0, W/8, W/4}.
    pub truncation_bits: u32,
    /// Optional explicit tolerance mask overriding the per-chunk MSB rule
    /// (used for IEEE-754 weights: sign+exponent bits, Fig. 19).
    pub tolerance_mask_override: Option<u64>,
    /// Data-table entries per chip (paper: 64).
    pub table_size: usize,
    /// Ablation knobs (paper defaults; the `ablation` harness flips them
    /// to quantify each §IV/§V design choice).
    pub ablation: Ablation,
}

/// Design-choice ablation switches (all `true`/paper-default normally).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ablation {
    /// §IV-B: one-hot index on the data lines (false = binary index on
    /// the sideband even for skips, as BD-Coder would do).
    pub ohe_index: bool,
    /// §V-A: all-zero words bypass encoding and the table.
    pub zero_skip: bool,
    /// §IV-A: update the table only with exact transfers, deduplicated
    /// (false = BD-Coder's update-after-every-transfer FIFO policy).
    pub dedup_update: bool,
}

impl Default for Ablation {
    fn default() -> Self {
        Ablation {
            ohe_index: true,
            zero_skip: true,
            dedup_update: true,
        }
    }
}

impl Default for ZacConfig {
    fn default() -> Self {
        ZacConfig {
            scheme: Scheme::ZacDest,
            similarity_limit_pct: 80,
            chunk_width: 8,
            tolerance_bits: 0,
            truncation_bits: 0,
            tolerance_mask_override: None,
            table_size: 64,
            ablation: Ablation::default(),
        }
    }
}

impl ZacConfig {
    /// Plain configuration for a non-ZAC scheme.
    pub fn scheme(scheme: Scheme) -> Self {
        ZacConfig {
            scheme,
            ..Default::default()
        }
    }

    /// ZAC-DEST with a similarity limit (knobs at 0).
    pub fn zac(similarity_limit_pct: u32) -> Self {
        ZacConfig {
            scheme: Scheme::ZacDest,
            similarity_limit_pct,
            ..Default::default()
        }
    }

    /// ZAC-DEST with all three knobs (chunk width 8, byte data).
    pub fn zac_full(limit_pct: u32, truncation_bits: u32, tolerance_bits: u32) -> Self {
        ZacConfig {
            scheme: Scheme::ZacDest,
            similarity_limit_pct: limit_pct,
            truncation_bits,
            tolerance_bits,
            ..Default::default()
        }
    }

    /// ZAC-DEST configured for IEEE-754 f32 weight traffic: 32-bit chunks
    /// with sign+exponent as the tolerance mask (§VIII-G: approximating
    /// even the last exponent bit costs ~60% output quality, so those
    /// bits are always pinned). Delegates to [`ZacKnobs::weights`], the
    /// one definition of the weights-mode geometry.
    pub fn zac_weights(limit_pct: u32) -> Self {
        ZacKnobs::weights(limit_pct).to_config()
    }

    /// The typed ZAC knob struct these fields carry (the v2 canonical
    /// form; all derived-mask logic lives there).
    pub fn knobs(&self) -> ZacKnobs {
        ZacKnobs::from_config(self)
    }

    /// Maximum number of dissimilar bits for the skip to fire:
    /// `ceil(64 * (100 - limit) / 100)`. Reproduces the paper's mapping
    /// 90→7, 80→13, 75→16, 70→20 (strict `<` comparison in Alg. 2).
    pub fn dissimilar_threshold(&self) -> u32 {
        self.knobs().dissimilar_threshold()
    }

    /// Effective tolerance mask (bits that must match exactly).
    pub fn tolerance_mask(&self) -> u64 {
        self.knobs().tolerance_mask()
    }

    /// Truncation mask (bits zeroed / excluded from comparison).
    pub fn truncation_mask(&self) -> u64 {
        self.knobs().truncation_mask()
    }

    /// Total truncated bits per 64-bit word.
    pub fn truncated_bits_total(&self) -> u32 {
        self.knobs().truncated_bits_total()
    }

    /// Validate invariants (chunk sizes, knob ranges, mask disjointness).
    pub fn validate(&self) -> anyhow::Result<()> {
        self.knobs().validate()
    }

    /// Short config label for figure legends, e.g. `ZAC(L80,T16,O8)`.
    pub fn label(&self) -> String {
        match self.scheme {
            Scheme::ZacDest => format!(
                "ZAC(L{},T{},O{})",
                self.similarity_limit_pct,
                self.truncated_bits_total(),
                self.tolerance_mask().count_ones()
            ),
            s => s.label().to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_similarity_thresholds() {
        // §V-B: 90/80/75/70 % ⇒ 7/13/16/20 dissimilar bits.
        for (pct, thr) in [(90, 7), (80, 13), (75, 16), (70, 20)] {
            assert_eq!(ZacConfig::zac(pct).dissimilar_threshold(), thr, "{pct}%");
        }
        // §VIII-G weight limits.
        for (pct, thr) in [(65, 23), (60, 26), (50, 32)] {
            assert_eq!(ZacConfig::zac(pct).dissimilar_threshold(), thr, "{pct}%");
        }
    }

    #[test]
    fn weight_config_pins_sign_exponent() {
        let cfg = ZacConfig::zac_weights(70);
        let m = cfg.tolerance_mask();
        // Top 9 bits of each 32-bit lane: sign + 8 exponent bits.
        assert_eq!(m, 0xFF80_0000_FF80_0000);
        cfg.validate().unwrap();
    }

    #[test]
    fn validation_rejects_bad_knobs() {
        let mut cfg = ZacConfig::default();
        cfg.chunk_width = 12;
        assert!(cfg.validate().is_err());
        let mut cfg = ZacConfig::default();
        cfg.tolerance_bits = 6;
        cfg.truncation_bits = 4;
        assert!(cfg.validate().is_err());
        let mut cfg = ZacConfig::default();
        cfg.table_size = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn paper_knob_grid_is_valid() {
        for limit in [90, 80, 75, 70] {
            for chunk in [8u32, 16, 32, 64] {
                for tol in [0, chunk / 8, chunk / 4] {
                    for trunc in [0, chunk / 8, chunk / 4] {
                        let cfg = ZacConfig {
                            scheme: Scheme::ZacDest,
                            similarity_limit_pct: limit,
                            chunk_width: chunk,
                            tolerance_bits: tol,
                            truncation_bits: trunc,
                            tolerance_mask_override: None,
                            table_size: 64,
                            ablation: Ablation::default(),
                        };
                        cfg.validate().unwrap();
                    }
                }
            }
        }
    }

    #[test]
    fn scheme_parse_round_trip() {
        for s in Scheme::all() {
            assert_eq!(Scheme::parse(s.label()), Some(s));
        }
        assert_eq!(Scheme::parse("zac-dest"), Some(Scheme::ZacDest));
        assert_eq!(Scheme::parse("nope"), None);
    }
}
