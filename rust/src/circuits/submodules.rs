//! The Fig. 7a ZAC-DEST sub-modules as explicit gate netlists:
//!
//! 1. **Zero checker** — 64-input NOR (output 1 iff all data bits 0).
//! 2. **Similarity checker** — popcount of the 64 bitwise-difference
//!    bits + a `< threshold` comparator (threshold muxed over the four
//!    §V-B limits 7/13/16/20).
//! 3. **Tolerance checker** — NOR over the masked difference bits
//!    (mask muxed over the supported tolerance patterns).
//! 4. **Truncation gating** — per-bit AND with the truncation line
//!    (the CAM-side series NMOS lives in the CAM model).
//! 5. Final AND of similarity & tolerance (the ZAC-DEST condition).

use super::netlist::Netlist;
use crate::util::rng::Rng;

/// The built sub-module block.
pub struct SubModules {
    pub net: Netlist,
    /// Input node ids: 64 data bits then 64 difference bits, then 2
    /// threshold-select bits, then 2 mask-select bits.
    pub data_in: Vec<usize>,
    pub diff_in: Vec<usize>,
    pub sel_in: Vec<usize>,
    /// Outputs.
    pub zero_out: usize,
    pub similar_out: usize,
    pub tolerance_out: usize,
    pub zac_out: usize,
}

/// Build the full Fig. 7 sub-module block.
pub fn build_zac_submodules() -> SubModules {
    let mut n = Netlist::new();
    let data_in = n.inputs(64);
    let diff_in = n.inputs(64);
    let sel_in = n.inputs(4); // threshold select (2) + tolerance select (2)

    // (1) Zero checker.
    let zero_out = n.nor_tree(&data_in.clone());

    // (2) Similarity checker: popcount(diff) < threshold, threshold in
    // {7, 13, 16, 20} selected by sel[0..2].
    let sum = n.popcount(&diff_in.clone());
    let lt: Vec<usize> = [7u32, 13, 16, 20]
        .iter()
        .map(|&k| n.less_than_const(&sum, k))
        .collect();
    let m0 = n.mux(sel_in[0], lt[0], lt[1]);
    let m1 = n.mux(sel_in[0], lt[2], lt[3]);
    let similar_out = n.mux(sel_in[1], m0, m1);

    // (3) Tolerance checker: masked diff bits must all be 0. Mask
    // patterns: none / 1 MSB per byte / 2 MSB per byte, selected by
    // sel[2..4]; a masked bit contributes diff AND mask.
    let mask1: u64 = 0x8080_8080_8080_8080;
    let mask2: u64 = 0xC0C0_C0C0_C0C0_C0C0;
    let mut masked = Vec::with_capacity(64);
    for (i, &d) in diff_in.iter().enumerate() {
        let in1 = (mask1 >> i) & 1 == 1;
        let in2 = (mask2 >> i) & 1 == 1;
        if in2 {
            // Bit participates when sel2 (1-bit) or sel3 (2-bit) chosen.
            let sel = if in1 {
                n.or(sel_in[2], sel_in[3])
            } else {
                sel_in[3]
            };
            masked.push(n.and(d, sel));
        }
    }
    let any_viol = n.or_tree(&masked);
    let tolerance_out = n.not(any_viol);

    // (5) ZAC condition.
    let zac_out = n.and(similar_out, tolerance_out);

    SubModules {
        net: n,
        data_in,
        diff_in,
        sel_in,
        zero_out,
        similar_out,
        tolerance_out,
        zac_out,
    }
}

/// Activity-run output for the sub-modules.
#[derive(Clone, Copy, Debug)]
pub struct SubActivity {
    pub toggles_per_access: f64,
    pub transistors: u64,
    pub depth: u32,
}

/// Drive `vectors` random input vectors (the §VI SAIF methodology) and
/// report mean toggles per access.
pub fn activity(subs: &mut SubModules, vectors: usize, rng: &mut Rng) -> SubActivity {
    let start_toggles = subs.net.toggles;
    let mut bits = vec![false; subs.net.num_inputs()];
    for i in 0..vectors {
        let data = rng.next_u64();
        // Difference bits are sparse for similar traffic.
        let diff = rng.next_u64() & rng.next_u64() & rng.next_u64();
        for b in 0..64 {
            bits[b] = (data >> b) & 1 == 1;
            bits[64 + b] = (diff >> b) & 1 == 1;
        }
        for s in 0..4 {
            bits[128 + s] = (i >> s) & 1 == 1;
        }
        subs.net.eval(&bits);
    }
    SubActivity {
        toggles_per_access: (subs.net.toggles - start_toggles) as f64 / vectors.max(1) as f64,
        transistors: subs.net.transistors(),
        depth: subs.net.depth(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set_word(bits: &mut [bool], offset: usize, w: u64) {
        for b in 0..64 {
            bits[offset + b] = (w >> b) & 1 == 1;
        }
    }

    fn drive(subs: &mut SubModules, data: u64, diff: u64, sel: [bool; 4]) {
        let mut bits = vec![false; subs.net.num_inputs()];
        set_word(&mut bits, 0, data);
        set_word(&mut bits, 64, diff);
        bits[128..132].copy_from_slice(&sel);
        subs.net.eval(&bits);
    }

    #[test]
    fn zero_checker_fires_only_on_zero() {
        let mut s = build_zac_submodules();
        drive(&mut s, 0, 0, [false; 4]);
        assert!(s.net.get(s.zero_out));
        drive(&mut s, 1, 0, [false; 4]);
        assert!(!s.net.get(s.zero_out));
    }

    #[test]
    fn similarity_thresholds_select() {
        let mut s = build_zac_submodules();
        // diff with 10 ones: < 13 yes (sel=01 -> threshold 13), < 7 no.
        let diff = (1u64 << 10) - 1 | (1 << 63); // 10 ones? (2^10-1 has 10 ones) plus bit63 = 11
        let diff = diff & !(1 << 63); // keep exactly 10 ones
        assert_eq!(diff.count_ones(), 10);
        drive(&mut s, 0, diff, [false, false, false, false]); // threshold 7
        assert!(!s.net.get(s.similar_out));
        drive(&mut s, 0, diff, [true, false, false, false]); // threshold 13
        assert!(s.net.get(s.similar_out));
        // 17 ones: threshold 16 (sel=[0,1]) no, threshold 20 ([1,1]) yes.
        let diff17 = (1u64 << 17) - 1;
        drive(&mut s, 0, diff17, [false, true, false, false]);
        assert!(!s.net.get(s.similar_out));
        drive(&mut s, 0, diff17, [true, true, false, false]);
        assert!(s.net.get(s.similar_out));
    }

    #[test]
    fn tolerance_masks_select() {
        let mut s = build_zac_submodules();
        let msb_diff = 0x8000_0000_0000_0000u64; // MSB of top byte differs
        // No tolerance: ok.
        drive(&mut s, 0, msb_diff, [false, false, false, false]);
        assert!(s.net.get(s.tolerance_out));
        // 1-MSB-per-byte tolerance: violation.
        drive(&mut s, 0, msb_diff, [false, false, true, false]);
        assert!(!s.net.get(s.tolerance_out));
        // Second-MSB differs: only the 2-bit mask catches it.
        let bit62 = 1u64 << 62;
        drive(&mut s, 0, bit62, [false, false, true, false]);
        assert!(s.net.get(s.tolerance_out));
        drive(&mut s, 0, bit62, [false, false, false, true]);
        assert!(!s.net.get(s.tolerance_out));
    }

    #[test]
    fn zac_condition_is_and_of_both() {
        let mut s = build_zac_submodules();
        let small_diff = 0b11u64; // 2 ones, passes any threshold
        drive(&mut s, 0, small_diff, [false, false, false, false]);
        assert!(s.net.get(s.zac_out));
        // Small diff but in a tolerance-bit position with mask on -> veto.
        drive(&mut s, 0, 0x80, [false, false, true, false]);
        assert!(!s.net.get(s.zac_out));
    }

    #[test]
    fn submodule_size_is_modest_vs_cam() {
        let s = build_zac_submodules();
        let cam = super::super::cam::CamModel::bd_coder(64, 64).transistors();
        let ratio = s.net.transistors() as f64 / cam as f64;
        // Fig. 7 submodules are a fraction of the 64x64 CAM (~15% area
        // overhead per §VI).
        assert!(ratio < 0.35, "submodules/CAM transistor ratio {ratio}");
    }
}
