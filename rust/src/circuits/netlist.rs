//! Levelized combinational gate netlist with toggle counting.
//!
//! Gates use standard static-CMOS transistor counts. Evaluation walks
//! nodes in creation order (inputs precede uses), and an attached toggle
//! counter accumulates per-node switching activity across vectors — the
//! SAIF methodology of §VI in miniature.

/// Gate kinds with CMOS transistor costs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gate {
    Input,
    Not(usize),
    And(usize, usize),
    Or(usize, usize),
    Nand(usize, usize),
    Nor(usize, usize),
    Xor(usize, usize),
    Mux(usize, usize, usize), // sel, a (sel=0), b (sel=1)
}

impl Gate {
    pub fn transistors(&self) -> u64 {
        match self {
            Gate::Input => 0,
            Gate::Not(_) => 2,
            Gate::Nand(..) | Gate::Nor(..) => 4,
            Gate::And(..) | Gate::Or(..) => 6,
            Gate::Xor(..) => 8, // transmission-gate XOR
            Gate::Mux(..) => 12,
        }
    }
}

/// A combinational netlist.
#[derive(Clone, Debug, Default)]
pub struct Netlist {
    gates: Vec<Gate>,
    level: Vec<u32>,
    /// Current node values.
    value: Vec<bool>,
    /// Previous values (for toggle counting).
    prev: Vec<bool>,
    /// Total node toggles accumulated.
    pub toggles: u64,
    /// Evaluations run.
    pub evals: u64,
    inputs: Vec<usize>,
}

impl Netlist {
    pub fn new() -> Self {
        Netlist::default()
    }

    pub fn input(&mut self) -> usize {
        let id = self.push(Gate::Input, 0);
        self.inputs.push(id);
        id
    }

    pub fn inputs(&mut self, n: usize) -> Vec<usize> {
        (0..n).map(|_| self.input()).collect()
    }

    fn push(&mut self, g: Gate, level: u32) -> usize {
        self.gates.push(g);
        self.level.push(level);
        self.value.push(false);
        self.prev.push(false);
        self.gates.len() - 1
    }

    fn lvl(&self, a: usize) -> u32 {
        self.level[a]
    }

    pub fn not(&mut self, a: usize) -> usize {
        let l = self.lvl(a) + 1;
        self.push(Gate::Not(a), l)
    }

    pub fn and(&mut self, a: usize, b: usize) -> usize {
        let l = self.lvl(a).max(self.lvl(b)) + 1;
        self.push(Gate::And(a, b), l)
    }

    pub fn or(&mut self, a: usize, b: usize) -> usize {
        let l = self.lvl(a).max(self.lvl(b)) + 1;
        self.push(Gate::Or(a, b), l)
    }

    pub fn nand(&mut self, a: usize, b: usize) -> usize {
        let l = self.lvl(a).max(self.lvl(b)) + 1;
        self.push(Gate::Nand(a, b), l)
    }

    pub fn nor(&mut self, a: usize, b: usize) -> usize {
        let l = self.lvl(a).max(self.lvl(b)) + 1;
        self.push(Gate::Nor(a, b), l)
    }

    pub fn xor(&mut self, a: usize, b: usize) -> usize {
        let l = self.lvl(a).max(self.lvl(b)) + 1;
        self.push(Gate::Xor(a, b), l)
    }

    pub fn mux(&mut self, sel: usize, a: usize, b: usize) -> usize {
        let l = self.lvl(sel).max(self.lvl(a)).max(self.lvl(b)) + 1;
        self.push(Gate::Mux(sel, a, b), l)
    }

    /// Wide NOR via a balanced NOR/NAND tree (returns 1 iff all inputs 0).
    pub fn nor_tree(&mut self, xs: &[usize]) -> usize {
        assert!(!xs.is_empty());
        // OR-reduce then invert; balanced for realistic depth.
        let or = self.or_tree(xs);
        self.not(or)
    }

    /// Balanced OR reduction.
    pub fn or_tree(&mut self, xs: &[usize]) -> usize {
        let mut layer: Vec<usize> = xs.to_vec();
        while layer.len() > 1 {
            let mut next = Vec::with_capacity(layer.len().div_ceil(2));
            for pair in layer.chunks(2) {
                next.push(if pair.len() == 2 {
                    self.or(pair[0], pair[1])
                } else {
                    pair[0]
                });
            }
            layer = next;
        }
        layer[0]
    }

    /// Balanced AND reduction.
    pub fn and_tree(&mut self, xs: &[usize]) -> usize {
        let mut layer: Vec<usize> = xs.to_vec();
        while layer.len() > 1 {
            let mut next = Vec::with_capacity(layer.len().div_ceil(2));
            for pair in layer.chunks(2) {
                next.push(if pair.len() == 2 {
                    self.and(pair[0], pair[1])
                } else {
                    pair[0]
                });
            }
            layer = next;
        }
        layer[0]
    }

    /// Full adder; returns (sum, carry).
    pub fn full_adder(&mut self, a: usize, b: usize, c: usize) -> (usize, usize) {
        let ab = self.xor(a, b);
        let sum = self.xor(ab, c);
        let t1 = self.and(a, b);
        let t2 = self.and(ab, c);
        let carry = self.or(t1, t2);
        (sum, carry)
    }

    /// Population count of `bits` as a ripple adder tree (Fig. 7(2)'s
    /// "sums up the count of dissimilar bits"). Returns LSB-first sum bits.
    pub fn popcount(&mut self, bits: &[usize]) -> Vec<usize> {
        // Reduce vectors of equal-weight bits with full adders (CSA tree).
        let mut columns: Vec<Vec<usize>> = vec![bits.to_vec()];
        loop {
            let mut done = true;
            let mut next: Vec<Vec<usize>> = vec![Vec::new(); columns.len() + 1];
            for (w, col) in columns.iter().enumerate() {
                let mut i = 0;
                while i + 3 <= col.len() {
                    let (s, c) = self.full_adder(col[i], col[i + 1], col[i + 2]);
                    next[w].push(s);
                    next[w + 1].push(c);
                    i += 3;
                    done = false;
                }
                if i + 2 == col.len() {
                    // Half adder.
                    let s = self.xor(col[i], col[i + 1]);
                    let c = self.and(col[i], col[i + 1]);
                    next[w].push(s);
                    next[w + 1].push(c);
                    done = false;
                } else if i + 1 == col.len() {
                    next[w].push(col[i]);
                }
            }
            while next.last().is_some_and(|c| c.is_empty()) {
                next.pop();
            }
            columns = next;
            if done {
                break;
            }
        }
        columns.into_iter().map(|c| c[0]).collect()
    }

    /// Comparator: popcount-sum-bits < constant. Builds a ripple borrow.
    pub fn less_than_const(&mut self, sum_bits: &[usize], k: u32) -> usize {
        // a < k  ==  NOT (a >= k). Compute a >= k by scanning from MSB.
        // ge = 1 if at the first differing bit a has 1 where k has 0.
        let mut ge: Option<usize> = None; // a > prefix
        let mut eq: Option<usize> = None; // prefix equal so far
        for i in (0..sum_bits.len()).rev() {
            let kb = (k >> i) & 1 == 1;
            let a = sum_bits[i];
            let (gt_here, eq_here) = if kb {
                // a_i must be 1 to stay equal; can't be greater at this bit.
                let e = a;
                (None, e)
            } else {
                // a_i = 1 makes a greater; a_i = 0 stays equal.
                let na = self.not(a);
                (Some(a), na)
            };
            let eq_in = eq;
            // gt accumulates: gt || (eq_so_far && gt_here)
            if let Some(g) = gt_here {
                let term = match eq_in {
                    Some(e) => self.and(e, g),
                    None => g,
                };
                ge = Some(match ge {
                    Some(prev) => self.or(prev, term),
                    None => term,
                });
            }
            eq = Some(match eq_in {
                Some(e) => self.and(e, eq_here),
                None => eq_here,
            });
        }
        // a >= k == gt || eq
        let e = eq.expect("nonempty");
        let ge_node = match ge {
            Some(g) => self.or(g, e),
            None => e,
        };
        self.not(ge_node)
    }

    /// Evaluate with the given input values, accumulating toggles.
    pub fn eval(&mut self, input_values: &[bool]) -> &[bool] {
        assert_eq!(input_values.len(), self.inputs.len());
        std::mem::swap(&mut self.value, &mut self.prev);
        for (&id, &v) in self.inputs.iter().zip(input_values) {
            self.value[id] = v;
        }
        for i in 0..self.gates.len() {
            let v = match self.gates[i] {
                Gate::Input => self.value[i],
                Gate::Not(a) => !self.value[a],
                Gate::And(a, b) => self.value[a] & self.value[b],
                Gate::Or(a, b) => self.value[a] | self.value[b],
                Gate::Nand(a, b) => !(self.value[a] & self.value[b]),
                Gate::Nor(a, b) => !(self.value[a] | self.value[b]),
                Gate::Xor(a, b) => self.value[a] ^ self.value[b],
                Gate::Mux(s, a, b) => {
                    if self.value[s] {
                        self.value[b]
                    } else {
                        self.value[a]
                    }
                }
            };
            self.value[i] = v;
            if v != self.prev[i] {
                self.toggles += 1;
            }
        }
        self.evals += 1;
        &self.value
    }

    pub fn get(&self, node: usize) -> bool {
        self.value[node]
    }

    pub fn transistors(&self) -> u64 {
        self.gates.iter().map(|g| g.transistors()).sum()
    }

    pub fn depth(&self) -> u32 {
        self.level.iter().copied().max().unwrap_or(0)
    }

    pub fn num_inputs(&self) -> usize {
        self.inputs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn popcount_matches_software() {
        let mut n = Netlist::new();
        let ins = n.inputs(16);
        let sum = n.popcount(&ins);
        let mut r = Rng::new(101);
        for _ in 0..200 {
            let x = r.next_u32() as u16;
            let bits: Vec<bool> = (0..16).map(|i| (x >> i) & 1 == 1).collect();
            n.eval(&bits);
            let mut got = 0u32;
            for (i, &s) in sum.iter().enumerate() {
                got |= (n.get(s) as u32) << i;
            }
            assert_eq!(got, x.count_ones(), "x={x:016b}");
        }
    }

    #[test]
    fn less_than_const_matches() {
        let mut n = Netlist::new();
        let ins = n.inputs(8);
        let sum = n.popcount(&ins);
        let lt = n.less_than_const(&sum, 5);
        for x in 0u16..256 {
            let bits: Vec<bool> = (0..8).map(|i| (x >> i) & 1 == 1).collect();
            n.eval(&bits);
            assert_eq!(n.get(lt), (x as u8).count_ones() < 5, "x={x:08b}");
        }
    }

    #[test]
    fn nor_tree_detects_zero() {
        let mut n = Netlist::new();
        let ins = n.inputs(64);
        let z = n.nor_tree(&ins);
        let mut r = Rng::new(102);
        let zero = vec![false; 64];
        n.eval(&zero);
        assert!(n.get(z));
        for _ in 0..50 {
            let x = r.next_u64() | 1;
            let bits: Vec<bool> = (0..64).map(|i| (x >> i) & 1 == 1).collect();
            n.eval(&bits);
            assert!(!n.get(z));
        }
    }

    #[test]
    fn toggles_accumulate_only_on_change() {
        let mut n = Netlist::new();
        let a = n.input();
        let _ = n.not(a);
        n.eval(&[false]);
        let t0 = n.toggles;
        n.eval(&[false]); // no change
        assert_eq!(n.toggles, t0);
        n.eval(&[true]); // both nodes flip
        assert_eq!(n.toggles, t0 + 2);
    }

    #[test]
    fn transistor_counts() {
        let mut n = Netlist::new();
        let a = n.input();
        let b = n.input();
        n.xor(a, b);
        n.nand(a, b);
        assert_eq!(n.transistors(), 8 + 4);
        assert_eq!(n.depth(), 1);
    }
}
