//! Gate/transistor-level structural model of the ZAC-DEST encoder
//! (paper §VI, Fig. 6-7) — the stand-in for the UMC 65 nm implementation
//! we cannot synthesize here.
//!
//! The model builds explicit gate netlists for every sub-module the paper
//! adds on top of BD-Coder (zero checker, similarity checker, tolerance
//! checker, truncation gating) plus a transistor-count + activity model
//! of the CAM data table itself, then:
//!
//! * **area** = transistor count (proxy for layout area),
//! * **energy** = node-toggle count over 10 000 random input vectors
//!   (exactly the SAIF-style switching-activity methodology §VI uses),
//!   calibrated so the BD-Coder data table matches its published
//!   7 pJ / access,
//! * **latency** = levelized gate depth, calibrated to the published
//!   2.4 ns BD-Coder table latency.
//!
//! Reproduced §VI claims: ZAC-DEST ≈ +15 % area, ≈ +9 % sub-module
//! energy (7.66 pJ combined), 3.4 ns combined latency.

pub mod cam;
pub mod netlist;
pub mod submodules;

use crate::util::rng::Rng;

/// §VI published constants used for calibration and comparison.
pub mod paper {
    /// BD-Coder data-table energy per access (pJ), 65 nm, from [14].
    pub const BDCODER_ENERGY_PJ: f64 = 7.0;
    /// BD-Coder data-table latency (ns).
    pub const BDCODER_LATENCY_NS: f64 = 2.4;
    /// ZAC-DEST combined (table + sub-modules) energy per access (pJ).
    pub const ZACDEST_ENERGY_PJ: f64 = 7.66;
    /// ZAC-DEST combined latency (ns).
    pub const ZACDEST_LATENCY_NS: f64 = 3.4;
    /// Area overhead of the ZAC-DEST sub-modules over BD-Coder.
    pub const AREA_OVERHEAD_PCT: f64 = 15.0;
    /// Energy overhead of the added sub-modules.
    pub const ENERGY_OVERHEAD_PCT: f64 = 9.0;
    /// Random vectors used for the switching-activity (SAIF) run.
    pub const ACTIVITY_VECTORS: usize = 10_000;
}

/// Capacitance of a standard-cell logic node relative to a CAM
/// match/search line. A 64-cell CAM line is wire + 64 drains (tens of
/// fF); a logic node is a couple of fF — ratio ≈ 0.12 at 65 nm.
pub const LOGIC_CAP_RATIO: f64 = 0.12;

/// Standard-cell logic delay per level at 65 nm (≈ FO4 ≈ 28 ps). CAM
/// "levels" are wire-dominated and calibrated separately from the
/// published 2.4 ns table latency.
pub const LOGIC_NS_PER_LEVEL: f64 = 0.028;

/// Aggregate report for one design (BD-Coder or ZAC-DEST).
#[derive(Clone, Debug)]
pub struct DesignReport {
    pub name: &'static str,
    pub transistors: u64,
    pub energy_pj: f64,
    pub latency_ns: f64,
    /// Raw toggle count from the activity run (pre-calibration).
    pub toggles_per_access: f64,
    pub gate_depth: u32,
}

/// Run the full §VI evaluation: build both designs, drive
/// [`paper::ACTIVITY_VECTORS`] random vectors, calibrate to the BD-Coder
/// published numbers, and report both designs.
pub fn evaluate(vectors: usize, seed: u64) -> (DesignReport, DesignReport) {
    let mut rng = Rng::new(seed);

    // --- BD-Coder baseline: CAM table + replica row. ---
    let cam = cam::CamModel::bd_coder(64, 64);
    let cam_act = cam.activity(vectors, &mut rng);

    // --- ZAC-DEST additions: modified CAM + the Fig. 7 sub-modules. ---
    let zcam = cam::CamModel::zac_dest(64, 64);
    let zcam_act = zcam.activity(vectors, &mut rng);
    let mut subs = submodules::build_zac_submodules();
    let sub_act = submodules::activity(&mut subs, vectors, &mut rng);

    // Calibration: map BD-Coder's toggle count + depth onto its published
    // 7 pJ / 2.4 ns; the same scale factors then price ZAC-DEST.
    let pj_per_toggle = paper::BDCODER_ENERGY_PJ / cam_act.toggles_per_access;
    let ns_per_level = paper::BDCODER_LATENCY_NS / cam.gate_depth() as f64;

    let bd = DesignReport {
        name: "BD-Coder",
        transistors: cam.transistors(),
        energy_pj: cam_act.toggles_per_access * pj_per_toggle,
        latency_ns: cam.gate_depth() as f64 * ns_per_level,
        toggles_per_access: cam_act.toggles_per_access,
        gate_depth: cam.gate_depth(),
    };

    // ZAC-DEST: modified CAM (truncation transistor per cell) + the
    // sub-modules appended after the table (Fig. 7b: the table search
    // feeds similarity/tolerance). Logic toggles/levels are weighted by
    // the standard-cell vs CAM-line capacitance/delay ratios.
    let z_toggles =
        zcam_act.toggles_per_access + sub_act.toggles_per_access * LOGIC_CAP_RATIO;
    let z_depth = zcam.gate_depth() + sub_act.depth;
    let zd = DesignReport {
        name: "ZAC-DEST",
        transistors: zcam.transistors() + sub_act.transistors,
        energy_pj: z_toggles * pj_per_toggle,
        latency_ns: zcam.gate_depth() as f64 * ns_per_level
            + sub_act.depth as f64 * LOGIC_NS_PER_LEVEL,
        toggles_per_access: z_toggles,
        gate_depth: z_depth,
    };
    (bd, zd)
}

impl DesignReport {
    pub fn area_overhead_pct(&self, base: &DesignReport) -> f64 {
        100.0 * (self.transistors as f64 / base.transistors as f64 - 1.0)
    }

    pub fn energy_overhead_pct(&self, base: &DesignReport) -> f64 {
        100.0 * (self.energy_pj / base.energy_pj - 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_section6_shape() {
        let (bd, zd) = evaluate(2000, 1);
        // BD-Coder is calibrated exactly to its published numbers.
        assert!((bd.energy_pj - paper::BDCODER_ENERGY_PJ).abs() < 1e-9);
        assert!((bd.latency_ns - paper::BDCODER_LATENCY_NS).abs() < 1e-9);
        // ZAC-DEST overheads in the paper's ballpark: small single-digit
        // to low-tens percent energy, ~15% area, latency 2.4 -> ~3.4 ns.
        let area = zd.area_overhead_pct(&bd);
        let energy = zd.energy_overhead_pct(&bd);
        assert!((5.0..30.0).contains(&area), "area overhead {area}%");
        assert!((2.0..25.0).contains(&energy), "energy overhead {energy}%");
        assert!(zd.latency_ns > bd.latency_ns);
        assert!(zd.latency_ns < 2.0 * bd.latency_ns);
    }

    #[test]
    fn deterministic_given_seed() {
        let (a, _) = evaluate(500, 3);
        let (b, _) = evaluate(500, 3);
        assert_eq!(a.toggles_per_access, b.toggles_per_access);
    }
}
