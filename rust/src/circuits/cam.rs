//! Transistor-count + switching-activity model of the BD-Coder /
//! ZAC-DEST NOR-CAM data table (Fig. 6).
//!
//! Per CAM cell (Fig. 6a): 6T SRAM storage + 5T comparator = 11T; the
//! ZAC-DEST cell (Fig. 6b) adds one truncation-line NMOS = 12T. One
//! replica row (Fig. 6c) counts the input word's ones. Periphery
//! (search-line drivers, match-line sense, priority encoder) is modeled
//! as per-column/per-row gate-equivalents.
//!
//! Activity: a CAM search toggles the differential search lines that
//! change vs the previous query and discharges the match lines of
//! non-matching rows — both are modeled per vector, which is what
//! dominates CAM energy in practice.

use crate::util::rng::Rng;

/// Transistors per original CAM cell (6T SRAM + 5T comparator).
pub const CELL_T: u64 = 11;
/// Extra truncation NMOS in the modified cell (Fig. 6b).
pub const TRUNC_T: u64 = 1;

/// Structural CAM model.
#[derive(Clone, Debug)]
pub struct CamModel {
    pub rows: usize,
    pub cols: usize,
    /// Truncation support (ZAC-DEST variant).
    pub truncation: bool,
    /// Stored words (row-major), for activity simulation.
    entries: Vec<u64>,
}

/// Activity-run output.
#[derive(Clone, Copy, Debug)]
pub struct Activity {
    /// Mean toggles per access across the run.
    pub toggles_per_access: f64,
}

impl CamModel {
    pub fn bd_coder(rows: usize, cols: usize) -> Self {
        CamModel {
            rows,
            cols,
            truncation: false,
            entries: vec![0; rows],
        }
    }

    pub fn zac_dest(rows: usize, cols: usize) -> Self {
        CamModel {
            rows,
            cols,
            truncation: true,
            entries: vec![0; rows],
        }
    }

    /// Total transistor count: cell array + replica row + peripheral
    /// logic (sense amp per row ≈ 10T, SL driver per column ≈ 4T,
    /// priority encoder ≈ 16T per row).
    pub fn transistors(&self) -> u64 {
        let cell = CELL_T + if self.truncation { TRUNC_T } else { 0 };
        let array = cell * (self.rows as u64) * (self.cols as u64);
        let replica = cell * self.cols as u64;
        let sense = 10 * self.rows as u64;
        let drivers = 4 * self.cols as u64 * 2; // SL + SL'
        let prio = 16 * self.rows as u64;
        array + replica + sense + drivers + prio
    }

    /// Equivalent gate depth of one search: SL drive (1) + cell compare
    /// (1) + match-line wired-NOR (log2 cols) + replica count + priority
    /// encode (log2 rows). The truncation gate adds one series device.
    pub fn gate_depth(&self) -> u32 {
        let base = 2 + (self.cols as f64).log2().ceil() as u32
            + (self.rows as f64).log2().ceil() as u32;
        base + if self.truncation { 1 } else { 0 }
    }

    /// Run a search-dominated activity simulation: each access searches a
    /// random query (locally correlated with the previous one, like real
    /// traffic) and then writes it to a FIFO slot — counting search-line,
    /// match-line and bitline toggles.
    pub fn activity(&self, vectors: usize, rng: &mut Rng) -> Activity {
        let mut entries = self.entries.clone();
        let mut head = 0usize;
        let mut prev_query = 0u64;
        let mut toggles: u64 = 0;
        let mask = if self.cols >= 64 {
            u64::MAX
        } else {
            (1u64 << self.cols) - 1
        };
        for i in 0..vectors {
            // Locally-similar query stream.
            let query = if i % 7 == 0 {
                rng.next_u64() & mask
            } else {
                (prev_query ^ (1u64 << rng.below(self.cols as u64))) & mask
            };
            // Search-line toggles: changed query bits drive SL and SL'.
            toggles += 2 * (query ^ prev_query).count_ones() as u64;
            // Match lines: every row that mismatches discharges (and
            // precharges next cycle): 1 toggle-pair per mismatching row.
            for &e in &entries {
                if e != query {
                    toggles += 2;
                }
            }
            // Replica row counts the query's ones (adder-ish activity).
            toggles += query.count_ones() as u64 / 2;
            // Truncation line activity (ZAC-DEST): occasionally reconfigured.
            if self.truncation && i % 64 == 0 {
                toggles += self.cols as u64 / 4;
            }
            // FIFO write: bitline toggles for changed bits in the slot.
            toggles += (entries[head] ^ query).count_ones() as u64;
            entries[head] = query;
            head = (head + 1) % self.rows;
            prev_query = query;
        }
        Activity {
            toggles_per_access: toggles as f64 / vectors.max(1) as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transistor_counts_scale() {
        let bd = CamModel::bd_coder(64, 64);
        let zd = CamModel::zac_dest(64, 64);
        // 64x64 array: 11T vs 12T per cell dominates.
        assert!(zd.transistors() > bd.transistors());
        let ratio = zd.transistors() as f64 / bd.transistors() as f64;
        assert!(ratio > 1.05 && ratio < 1.12, "cell ratio {ratio}");
    }

    #[test]
    fn depth_increases_with_truncation() {
        assert_eq!(
            CamModel::zac_dest(64, 64).gate_depth(),
            CamModel::bd_coder(64, 64).gate_depth() + 1
        );
    }

    #[test]
    fn activity_is_positive_and_deterministic() {
        let cam = CamModel::bd_coder(64, 64);
        let a = cam.activity(500, &mut Rng::new(5));
        let b = cam.activity(500, &mut Rng::new(5));
        assert!(a.toggles_per_access > 0.0);
        assert_eq!(a.toggles_per_access, b.toggles_per_access);
    }
}
