//! Synthetic dataset generators — the sandbox has no network, GPUs, or
//! pretrained models, so each of the paper's corpora is replaced by a
//! procedurally generated equivalent that preserves the property the
//! experiment measures (see DESIGN.md §5 for the substitution table):
//!
//! * [`synth_images`] — ImageNet/CIFAR stand-in: 10-class 32×32 RGB,
//!   class = shape family × palette, with texture and noise.
//! * [`kodak_like`] — photographic statistics (smooth gradients, blobs,
//!   edges) for the K-Means colour-quantization workload.
//! * [`faces`] — Yale-faces stand-in: per-identity deformed base face.
//! * [`fmnist_like`] — sparse 28×28 silhouettes (most pixels zero — the
//!   property the paper picked Fashion-MNIST for, §VII-A5).

use crate::util::rng::Rng;

/// An interleaved 8-bit image.
#[derive(Clone, Debug, PartialEq)]
pub struct Image {
    pub w: usize,
    pub h: usize,
    pub channels: usize,
    pub data: Vec<u8>,
    /// Ground-truth class / identity.
    pub label: i32,
}

impl Image {
    pub fn new(w: usize, h: usize, channels: usize, label: i32) -> Self {
        Image {
            w,
            h,
            channels,
            data: vec![0; w * h * channels],
            label,
        }
    }

    #[inline]
    pub fn at(&self, x: usize, y: usize, c: usize) -> u8 {
        self.data[(y * self.w + x) * self.channels + c]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, c: usize, v: u8) {
        self.data[(y * self.w + x) * self.channels + c] = v;
    }

    /// Replace the pixel payload (e.g. with a reconstructed trace),
    /// keeping geometry + label.
    pub fn with_data(&self, data: Vec<u8>) -> Image {
        assert_eq!(data.len(), self.data.len());
        Image {
            data,
            ..self.clone()
        }
    }

    /// Normalized f32 pixels in [0,1] (NHWC order, what `cnn_*` expects).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&b| b as f32 / 255.0).collect()
    }

    /// Dump as a binary PGM/PPM (for eyeballing Fig. 12-style output).
    pub fn to_pnm(&self) -> Vec<u8> {
        let magic = if self.channels == 3 { "P6" } else { "P5" };
        let mut out = format!("{magic}\n{} {}\n255\n", self.w, self.h).into_bytes();
        out.extend_from_slice(&self.data);
        out
    }
}

/// Number of classes in the synthetic classification corpus.
pub const NUM_CLASSES: usize = 10;

/// 10-class 32×32×3 corpus (ImageNet/CIFAR-100 stand-in). Class encodes
/// a shape family (0-4) × palette (0-1); textured background + noise
/// keeps LSBs informative so bit-level approximation has a measurable
/// effect, as in the paper's image experiments.
pub fn synth_images(n: usize, seed: u64) -> Vec<Image> {
    let mut r = Rng::new(seed ^ 0x5397_1a2b);
    (0..n)
        .map(|i| {
            let label = (i % NUM_CLASSES) as i32;
            synth_image(label, &mut r)
        })
        .collect()
}

fn palette(p: i32, r: &mut Rng) -> ([f32; 3], [f32; 3]) {
    // Two palettes: warm fg / cool bg and the reverse.
    let jitter = |base: f32, r: &mut Rng| (base + r.normal_f32(0.0, 0.05)).clamp(0.0, 1.0);
    if p == 0 {
        (
            [jitter(0.85, r), jitter(0.35, r), jitter(0.2, r)],
            [jitter(0.15, r), jitter(0.3, r), jitter(0.6, r)],
        )
    } else {
        (
            [jitter(0.2, r), jitter(0.55, r), jitter(0.85, r)],
            [jitter(0.7, r), jitter(0.5, r), jitter(0.25, r)],
        )
    }
}

fn synth_image(label: i32, r: &mut Rng) -> Image {
    let (w, h) = (32usize, 32usize);
    let mut img = Image::new(w, h, 3, label);
    let shape = label % 5;
    let (fg, bg) = palette(label / 5, r);
    let cx = 16.0 + r.normal_f32(0.0, 2.5) as f64;
    let cy = 16.0 + r.normal_f32(0.0, 2.5) as f64;
    let size = 7.0 + r.f64() * 4.0;
    let angle = r.f64() * std::f64::consts::TAU;
    for y in 0..h {
        for x in 0..w {
            // Textured background gradient.
            let gx = x as f64 / w as f64;
            let gy = y as f64 / h as f64;
            let tex = 0.08 * ((x as f64 * 0.9).sin() * (y as f64 * 0.7).cos());
            let inside = shape_test(shape, x as f64 - cx, y as f64 - cy, size, angle);
            let base = if inside { fg } else { bg };
            let shade = if inside { 1.0 } else { 0.55 + 0.45 * (gx * 0.5 + gy * 0.5) };
            for c in 0..3 {
                let v = (base[c] as f64 * shade + tex + r.normal() * 0.02).clamp(0.0, 1.0);
                img.set(x, y, c, (v * 255.0) as u8);
            }
        }
    }
    img
}

fn shape_test(shape: i32, dx: f64, dy: f64, size: f64, angle: f64) -> bool {
    let (s, c) = angle.sin_cos();
    let rx = dx * c - dy * s;
    let ry = dx * s + dy * c;
    match shape {
        0 => rx * rx + ry * ry < size * size, // disc
        1 => rx.abs() < size && ry.abs() < size * 0.7, // rectangle
        2 => rx.abs() + ry.abs() < size * 1.2, // diamond
        3 => ry > -size * 0.8 && ry < size * 0.8 && (rx / 3.0).sin() > 0.0, // stripes
        _ => (rx * rx + ry * ry).sqrt() < size && ry < 0.25 * size, // half disc
    }
}

/// Photographic-statistics images for Quant (Kodak stand-in): smooth
/// background gradients + soft colour blobs + a few hard edges + noise.
pub fn kodak_like(n: usize, w: usize, h: usize, seed: u64) -> Vec<Image> {
    let mut r = Rng::new(seed ^ 0x0dacbeef);
    (0..n)
        .map(|i| {
            let mut img = Image::new(w, h, 3, i as i32);
            // Background gradient anchors.
            let c0: Vec<f64> = (0..3).map(|_| r.f64()).collect();
            let c1: Vec<f64> = (0..3).map(|_| r.f64()).collect();
            // 6 colour blobs.
            let blobs: Vec<(f64, f64, f64, [f64; 3])> = (0..6)
                .map(|_| {
                    (
                        r.f64() * w as f64,
                        r.f64() * h as f64,
                        (0.08 + 0.2 * r.f64()) * w as f64,
                        [r.f64(), r.f64(), r.f64()],
                    )
                })
                .collect();
            // One hard vertical edge.
            let edge_x = (0.3 + 0.4 * r.f64()) * w as f64;
            for y in 0..h {
                for x in 0..w {
                    let t = (x as f64 / w as f64 + y as f64 / h as f64) / 2.0;
                    for c in 0..3 {
                        let mut v = c0[c] * (1.0 - t) + c1[c] * t;
                        for (bx, by, br, col) in &blobs {
                            let d2 = (x as f64 - bx).powi(2) + (y as f64 - by).powi(2);
                            let wgt = (-d2 / (2.0 * br * br)).exp();
                            v = v * (1.0 - wgt) + col[c] * wgt;
                        }
                        if (x as f64) > edge_x {
                            v *= 0.7;
                        }
                        v += r.normal() * 0.015;
                        img.set(x, y, c, (v.clamp(0.0, 1.0) * 255.0) as u8);
                    }
                }
            }
            img
        })
        .collect()
}

/// Gallery/probe split of the face corpus: the *same* identities, with
/// disjoint per-sample variation (illumination/noise), as in the Yale
/// protocol. Returns (train, test).
pub fn faces_split(
    identities: usize,
    train_per: usize,
    test_per: usize,
    seed: u64,
) -> (Vec<Image>, Vec<Image>) {
    let all = faces(identities, train_per + test_per, seed);
    let mut train = Vec::with_capacity(identities * train_per);
    let mut test = Vec::with_capacity(identities * test_per);
    for (i, img) in all.into_iter().enumerate() {
        if i % (train_per + test_per) < train_per {
            train.push(img);
        } else {
            test.push(img);
        }
    }
    (train, test)
}

/// Face-like 24×24 grayscale corpus (Yale stand-in): a shared base face,
/// per-identity geometry offsets, per-sample illumination + noise.
pub fn faces(identities: usize, per_identity: usize, seed: u64) -> Vec<Image> {
    let mut r = Rng::new(seed ^ 0xFACE);
    let (w, h) = (24usize, 24usize);
    // Per-identity parameters.
    let params: Vec<[f64; 6]> = (0..identities)
        .map(|_| {
            [
                r.normal() * 1.6,  // eye spacing
                r.normal() * 1.2,  // eye height
                r.normal() * 1.6,  // mouth width
                r.normal() * 1.2,  // mouth height
                r.normal() * 0.9,  // face width
                r.normal() * 0.8,  // brow
            ]
        })
        .collect();
    let mut out = Vec::with_capacity(identities * per_identity);
    for (id, p) in params.iter().enumerate() {
        for _ in 0..per_identity {
            let mut img = Image::new(w, h, 1, id as i32);
            let light = 0.88 + 0.12 * r.f64(); // illumination variation
            let lx = r.normal() * 0.15;
            for y in 0..h {
                for x in 0..w {
                    let dx = x as f64 - 11.5;
                    let dy = y as f64 - 11.5;
                    // Face oval.
                    let face = dx * dx / (60.0 + 8.0 * p[4]) + dy * dy / 90.0;
                    let mut v = if face < 1.0 { 0.75 } else { 0.12 };
                    // Eyes.
                    let es = 4.0 + p[0];
                    let ey = -3.0 + p[1];
                    for ex in [-es, es] {
                        let d2 = (dx - ex).powi(2) + (dy - ey).powi(2);
                        if d2 < 2.4 {
                            v = 0.08;
                        }
                    }
                    // Brow line.
                    if dy > ey - 2.8 - p[5] && dy < ey - 1.8 - p[5] && dx.abs() < es + 1.6 {
                        v *= 0.55;
                    }
                    // Mouth.
                    let mw = 4.0 + p[2];
                    let my = 5.0 + p[3];
                    if dx.abs() < mw && (dy - my).abs() < 1.0 {
                        v = 0.2;
                    }
                    // Nose shadow.
                    if dx.abs() < 0.9 && dy > -1.0 && dy < 3.0 {
                        v *= 0.8;
                    }
                    let shade = light * (1.0 + lx * dx / 12.0);
                    let v = (v * shade + r.normal() * 0.02).clamp(0.0, 1.0);
                    img.set(x, y, 0, (v * 255.0) as u8);
                }
            }
            out.push(img);
        }
    }
    out
}

/// Sparse 28×28 grayscale corpus (Fashion-MNIST stand-in): a centered
/// silhouette per class, background exactly 0 — preserving the zero-heavy
/// access pattern §VII-A5 selected FMNIST for.
pub fn fmnist_like(n: usize, seed: u64) -> Vec<Image> {
    let mut r = Rng::new(seed ^ 0xF817);
    (0..n)
        .map(|i| {
            let label = (i % NUM_CLASSES) as i32;
            let mut img = Image::new(28, 28, 1, label);
            let jx = r.normal() * 1.2;
            let jy = r.normal() * 1.2;
            let scale = 1.0 + r.normal() * 0.08;
            for y in 0..28 {
                for x in 0..28 {
                    let dx = (x as f64 - 14.0 - jx) / scale;
                    let dy = (y as f64 - 14.0 - jy) / scale;
                    if silhouette(label, dx, dy) {
                        let v = 0.55 + 0.4 * r.f64();
                        img.set(x, y, 0, (v * 255.0) as u8);
                    }
                }
            }
            img
        })
        .collect()
}

fn silhouette(label: i32, dx: f64, dy: f64) -> bool {
    match label % 10 {
        0 => dx.abs() < 6.0 && dy.abs() < 9.0,                       // shirt body
        1 => dx.abs() < 3.5 && dy.abs() < 10.0,                      // trouser
        2 => dx.abs() < 7.0 - dy * 0.3 && dy.abs() < 8.0,            // pullover
        3 => dx.abs() < 4.0 + dy * 0.35 && dy.abs() < 10.0,          // dress
        4 => dx.abs() < 8.0 && dy.abs() < 6.0,                       // coat
        5 => dy > 2.0 && dy < 7.0 && dx.abs() < 9.0 - (dy - 4.0),    // sandal
        6 => dx.abs() < 5.5 && dy.abs() < 9.5 && dx.abs() + dy.abs() > 2.0, // open shirt
        7 => dy > 0.0 && dy < 6.5 && dx.abs() < 8.5,                 // sneaker
        8 => dx.abs() < 6.5 && dy.abs() < 7.0 && !(dx.abs() < 2.0 && dy < -2.0), // bag
        _ => dy > -2.0 && dy < 7.0 && dx.abs() < 4.0 + (dy > 4.0) as i32 as f64 * 4.0, // boot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_images_deterministic_and_labeled() {
        let a = synth_images(20, 7);
        let b = synth_images(20, 7);
        assert_eq!(a, b);
        assert_eq!(a.len(), 20);
        for (i, img) in a.iter().enumerate() {
            assert_eq!(img.label, (i % NUM_CLASSES) as i32);
            assert_eq!(img.data.len(), 32 * 32 * 3);
        }
        // Different seeds differ.
        assert_ne!(a, synth_images(20, 8));
    }

    #[test]
    fn classes_are_visually_distinct() {
        // Mean pixel distance between two classes should exceed the
        // within-class distance (sanity that a classifier can learn).
        let imgs = synth_images(40, 9);
        let dist = |a: &Image, b: &Image| -> f64 {
            a.data
                .iter()
                .zip(&b.data)
                .map(|(&x, &y)| ((x as f64) - (y as f64)).abs())
                .sum::<f64>()
                / a.data.len() as f64
        };
        let within = dist(&imgs[0], &imgs[10]); // same class (label 0)
        let between = dist(&imgs[0], &imgs[15]); // class 0 vs 5 (other palette)
        assert!(between > within, "between {between} within {within}");
    }

    #[test]
    fn fmnist_like_is_sparse() {
        let imgs = fmnist_like(50, 11);
        let zeros: usize = imgs
            .iter()
            .flat_map(|i| i.data.iter())
            .filter(|&&b| b == 0)
            .count();
        let total: usize = imgs.iter().map(|i| i.data.len()).sum();
        let frac = zeros as f64 / total as f64;
        assert!(frac > 0.5, "zero fraction {frac} too low for FMNIST-like");
    }

    #[test]
    fn faces_split_shares_identities() {
        let (train, test) = faces_split(4, 3, 2, 21);
        assert_eq!(train.len(), 12);
        assert_eq!(test.len(), 8);
        assert_eq!(train[0].label, 0);
        assert_eq!(test[0].label, 0);
        assert_eq!(test[7].label, 3);
        // Same identity, different samples.
        assert_ne!(train[0].data, test[0].data);
    }

    #[test]
    fn faces_group_by_identity() {
        let fs = faces(4, 3, 13);
        assert_eq!(fs.len(), 12);
        let dist = |a: &Image, b: &Image| -> f64 {
            a.data
                .iter()
                .zip(&b.data)
                .map(|(&x, &y)| ((x as f64) - (y as f64)).powi(2))
                .sum::<f64>()
        };
        // Same identity closer than different identity, on average.
        let same = dist(&fs[0], &fs[1]) + dist(&fs[3], &fs[4]);
        let diff = dist(&fs[0], &fs[3]) + dist(&fs[3], &fs[6]);
        assert!(same < diff, "same {same} diff {diff}");
    }

    #[test]
    fn kodak_like_has_smooth_and_edge_regions() {
        let img = &kodak_like(1, 64, 48, 17)[0];
        // Neighbouring-pixel deltas: mostly small (smooth) but some large.
        let mut small = 0;
        let mut large = 0;
        for y in 0..48 {
            for x in 1..64 {
                let d = (img.at(x, y, 0) as i32 - img.at(x - 1, y, 0) as i32).abs();
                if d < 8 {
                    small += 1;
                } else if d > 24 {
                    large += 1;
                }
            }
        }
        assert!(small > 1500, "smooth pixels {small}");
        assert!(large > 5, "edge pixels {large}");
    }

    #[test]
    fn pnm_header() {
        let img = Image::new(4, 2, 3, 0);
        let pnm = img.to_pnm();
        assert!(pnm.starts_with(b"P6\n4 2\n255\n"));
        assert_eq!(pnm.len(), 11 + 24);
    }

    #[test]
    fn to_f32_normalizes() {
        let mut img = Image::new(2, 1, 1, 0);
        img.set(0, 0, 0, 255);
        img.set(1, 0, 0, 0);
        assert_eq!(img.to_f32(), vec![1.0, 0.0]);
    }
}
