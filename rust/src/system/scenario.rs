//! Declarative sweep engine: a (channels × scheme × knob-grid) spec,
//! expanded into concrete validated [`CodecSpec`] scenarios and fanned
//! out over sharded [`Session`] runs. The spec is a TOML subset (parsed
//! with [`toml_lite`](crate::util::toml_lite)):
//!
//! ```toml
//! name = "smoke"
//! seed = 42
//! bytes = 262144
//! approx = true
//!
//! [grid]
//! channels = [1, 2]
//! schemes = ["BDE", "OHE"]
//! limits = [90, 80, 75]
//! truncations = [0]
//! tolerances = [0]
//! baseline = "BDE"
//! ```
//!
//! Non-ZAC schemes contribute one scenario per channel count; the ZAC
//! scheme takes the full limits × truncations × tolerances grid. Every
//! scenario's savings are measured against the baseline scheme run at
//! the *same* channel count (sharding changes per-table history, so the
//! baseline must shard identically to be comparable).
//!
//! Execution is parallel and resumable: grid cells fan across a
//! work-stealing worker pool (`workers` in the TOML, `--workers`,
//! `ZAC_SWEEP_WORKERS`; 1 = the sequential engine, pinned
//! bit-identical), every [`ScenarioResult`] carries a stable
//! [`cell_fingerprint`], and [`run_sweep_resume`] skips cells whose
//! fingerprints already sit in a prior report, merging old and new
//! rows in grid order.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::channel::EnergyCounts;
use crate::encoding::{default_registry, CodecSpec, Outcome, Scheme};
use crate::faults::FaultSpec;
use crate::obs::TelemetrySnapshot;
use crate::quality::psnr_u8;
use crate::session::{Execution, RunReport, Session, Trace, TrafficClass};
use crate::system::address::AddressSpec;
use crate::system::array::load_imbalance;
use crate::system::report::{ScenarioResult, SweepReport};
use crate::util::par::par_map;
use crate::util::toml_lite;

/// A declarative sweep: the grid axes plus trace parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepSpec {
    pub name: String,
    /// Synthetic-trace seed.
    pub seed: u64,
    /// Synthetic-trace size in bytes (callers may substitute their own
    /// trace in [`run_sweep`]; this sizes the default one).
    pub bytes: usize,
    /// Recorded `.zactrace` to sweep instead of the synthetic trace
    /// (`bytes`/`seed` are ignored when set) — see
    /// [`sweep_trace_bytes`].
    pub trace: Option<String>,
    /// Mark the stream error-resilient.
    pub approx: bool,
    /// Channel counts to shard across.
    pub channels: Vec<usize>,
    /// Schemes to evaluate — registry names, so out-of-tree and
    /// correcting schemes (`"SECDED"`, `"ECC+BDE"`, …) sweep exactly
    /// like the Table I five.
    pub schemes: Vec<String>,
    /// ZAC similarity limits (%).
    pub limits: Vec<u32>,
    /// ZAC truncation knob values (bits per 8-bit chunk).
    pub truncations: Vec<u32>,
    /// ZAC tolerance knob values (bits per 8-bit chunk).
    pub tolerances: Vec<u32>,
    /// Fault-model axis (EDEN/SparkXD error models; default: perfect
    /// channel only). Every codec cell runs once per fault spec, so the
    /// report carries energy-vs-quality frontiers.
    pub faults: Vec<FaultSpec>,
    /// Address-mapping axis (default: round-robin only). Every codec
    /// cell runs once per policy, so the report carries per-policy
    /// `DataTable` hit rates and termination energy side by side.
    pub address: Vec<AddressSpec>,
    /// Savings reference scheme (registry name).
    pub baseline: String,
    /// Collect runtime telemetry (per-stage timings, mailbox pressure,
    /// service latency) for every cell and carry it into the report.
    pub telemetry: bool,
    /// Worker threads the grid cells fan across (work-stealing over
    /// the scenario list). 1 = the sequential engine, pinned
    /// bit-identical; every figure except wall clock and telemetry is
    /// bit-identical at any degree.
    pub workers: usize,
}

impl Default for SweepSpec {
    /// The built-in smoke grid: {1, 2} channels × (BDE + ZAC at three
    /// limits) = 8 scenarios.
    fn default() -> Self {
        SweepSpec {
            name: "default-grid".into(),
            seed: 42,
            bytes: 1 << 18,
            trace: None,
            approx: true,
            channels: vec![1, 2],
            schemes: vec!["BDE".into(), "OHE".into()],
            limits: vec![90, 80, 75],
            truncations: vec![0],
            tolerances: vec![0],
            faults: vec![FaultSpec::perfect()],
            address: vec![AddressSpec::round_robin()],
            baseline: "BDE".into(),
            telemetry: false,
            workers: 1,
        }
    }
}

/// One concrete cell of the sweep grid: a validated codec spec at a
/// channel count under one fault model and one address policy.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub channels: usize,
    pub spec: CodecSpec,
    pub faults: FaultSpec,
    pub address: AddressSpec,
}

impl Scenario {
    pub fn label(&self) -> String {
        let mut label = format!("{}@{}ch", self.spec.label(), self.channels);
        if !self.faults.is_perfect() {
            label.push_str(&format!("+{}", self.faults.label()));
        }
        if !self.address.is_round_robin() {
            label.push_str(&format!("+{}", self.address.label()));
        }
        label
    }
}

impl SweepSpec {
    /// Parse a spec file; unknown keys are rejected to catch typos.
    pub fn from_toml(text: &str) -> anyhow::Result<SweepSpec> {
        let doc = toml_lite::parse(text)?;
        let mut spec = SweepSpec::default();
        for (k, v) in doc.as_obj()? {
            match k.as_str() {
                "name" => spec.name = v.as_str()?.to_string(),
                "seed" => spec.seed = parse_seed(v)?,
                "bytes" => spec.bytes = v.as_usize()?,
                "trace" => spec.trace = Some(v.as_str()?.to_string()),
                "approx" => match v {
                    crate::util::json_lite::Json::Bool(b) => spec.approx = *b,
                    other => anyhow::bail!("approx must be true/false, got {other:?}"),
                },
                "telemetry" => match v {
                    crate::util::json_lite::Json::Bool(b) => spec.telemetry = *b,
                    other => anyhow::bail!("telemetry must be true/false, got {other:?}"),
                },
                "workers" => {
                    spec.workers = validate_workers(v.as_usize()?)
                        .map_err(|e| anyhow::anyhow!("workers: {e}"))?;
                }
                "grid" => {
                    for (gk, gv) in v.as_obj()? {
                        match gk.as_str() {
                            "channels" => {
                                spec.channels = gv
                                    .as_arr()?
                                    .iter()
                                    .map(|x| x.as_usize())
                                    .collect::<anyhow::Result<_>>()?;
                            }
                            "schemes" => {
                                spec.schemes = gv
                                    .as_arr()?
                                    .iter()
                                    .map(|x| resolve_scheme_name(x.as_str()?))
                                    .collect::<anyhow::Result<_>>()?;
                            }
                            "limits" => spec.limits = parse_u32_list(gv)?,
                            "truncations" => spec.truncations = parse_u32_list(gv)?,
                            "tolerances" => spec.tolerances = parse_u32_list(gv)?,
                            "faults" => {
                                spec.faults = gv
                                    .as_arr()?
                                    .iter()
                                    .map(|x| FaultSpec::parse(x.as_str()?))
                                    .collect::<anyhow::Result<_>>()?;
                            }
                            "address" => {
                                spec.address = gv
                                    .as_arr()?
                                    .iter()
                                    .map(|x| AddressSpec::parse(x.as_str()?))
                                    .collect::<anyhow::Result<_>>()?;
                            }
                            "baseline" => {
                                spec.baseline = resolve_scheme_name(gv.as_str()?)
                                    .map_err(|e| anyhow::anyhow!("baseline: {e}"))?;
                            }
                            other => anyhow::bail!("unknown [grid] key {other:?}"),
                        }
                    }
                }
                other => anyhow::bail!("unknown top-level key {other:?}"),
            }
        }
        spec.validate()?;
        // Validate every concrete grid cell at the ingestion boundary,
        // not at run time: a bad limit/knob in the TOML is rejected
        // before any simulation starts.
        spec.scenarios()?;
        Ok(spec)
    }

    pub fn from_file(path: &str) -> anyhow::Result<SweepSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        Self::from_toml(&text)
    }

    /// Basic axis sanity (per-cell knob validity is checked when the
    /// grid expands).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.channels.is_empty(), "empty channels axis");
        anyhow::ensure!(
            self.channels.iter().all(|&c| (1..=64).contains(&c)),
            "channel counts must be in 1..=64, got {:?}",
            self.channels
        );
        anyhow::ensure!(!self.schemes.is_empty(), "empty schemes axis");
        for name in &self.schemes {
            resolve_scheme_name(name)?;
        }
        resolve_scheme_name(&self.baseline)
            .map_err(|e| anyhow::anyhow!("baseline: {e}"))?;
        anyhow::ensure!(!self.faults.is_empty(), "empty faults axis");
        for f in &self.faults {
            f.validate()?;
        }
        anyhow::ensure!(!self.address.is_empty(), "empty address axis");
        for a in &self.address {
            a.validate()?;
        }
        validate_workers(self.workers)?;
        if self.schemes.iter().any(|s| takes_zac_grid(s)) {
            anyhow::ensure!(!self.limits.is_empty(), "ZAC in grid but no limits");
            anyhow::ensure!(!self.truncations.is_empty(), "ZAC in grid but no truncations");
            anyhow::ensure!(!self.tolerances.is_empty(), "ZAC in grid but no tolerances");
        }
        Ok(())
    }

    /// Expand the grid into concrete, validated scenarios.
    pub fn scenarios(&self) -> anyhow::Result<Vec<Scenario>> {
        self.validate()?;
        let mut out = Vec::new();
        for &faults in &self.faults {
            for &channels in &self.channels {
                for address in &self.address {
                    for scheme in &self.schemes {
                        if takes_zac_grid(scheme) {
                            // ZAC — bare or ECC-wrapped — takes the full
                            // knob grid; the wrapper shares the knob bag
                            // of its base.
                            for &limit in &self.limits {
                                for &trunc in &self.truncations {
                                    for &tol in &self.tolerances {
                                        let mut spec = CodecSpec::zac_full(limit, trunc, tol);
                                        spec.scheme = scheme.clone();
                                        spec.validate()?;
                                        out.push(Scenario {
                                            channels,
                                            spec,
                                            faults,
                                            address: address.clone(),
                                        });
                                    }
                                }
                            }
                        } else {
                            out.push(Scenario {
                                channels,
                                spec: CodecSpec::named(scheme),
                                faults,
                                address: address.clone(),
                            });
                        }
                    }
                }
            }
        }
        Ok(out)
    }
}

/// Does this registry name take the ZAC knob grid (limits ×
/// truncations × tolerances)? True for the ZAC scheme itself and its
/// ECC-wrapped variant, which shares the same knob bag.
fn takes_zac_grid(name: &str) -> bool {
    let inner = name.strip_prefix("ECC+").unwrap_or(name);
    Scheme::parse(inner) == Some(Scheme::ZacDest)
}

/// Resolve a scheme name from CLI/TOML against the default registry,
/// naming the offending token and listing every registered scheme on
/// failure (the same error contract `--faults` keeps).
pub fn resolve_scheme_name(name: &str) -> anyhow::Result<String> {
    let canonical = name.trim().to_ascii_uppercase();
    anyhow::ensure!(
        default_registry().contains(&canonical),
        "unknown scheme {name:?}; registered schemes: {}",
        default_registry().schemes().join(", ")
    );
    Ok(canonical)
}

/// Seeds ride through `toml_lite` as f64, which is exact only below
/// 2^53 — reject anything that would silently round to a different
/// (irreproducible) seed.
fn parse_seed(v: &crate::util::json_lite::Json) -> anyhow::Result<u64> {
    let x = v.as_f64()?;
    anyhow::ensure!(
        x >= 0.0 && x.fract() == 0.0 && x <= 9_007_199_254_740_992.0,
        "seed must be a non-negative integer <= 2^53, got {x}"
    );
    Ok(x as u64)
}

fn parse_u32_list(v: &crate::util::json_lite::Json) -> anyhow::Result<Vec<u32>> {
    v.as_arr()?
        .iter()
        .map(|x| Ok(x.as_usize()? as u32))
        .collect()
}

/// Parse a comma-separated channel list, e.g. `"1,2,4"`.
pub fn parse_channel_list(text: &str) -> anyhow::Result<Vec<usize>> {
    let list: Vec<usize> = text
        .split(',')
        .map(|p| {
            let p = p.trim();
            p.parse::<usize>()
                .map_err(|e| anyhow::anyhow!("bad channel count {p:?}: {e}"))
        })
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(!list.is_empty(), "empty channel list");
    anyhow::ensure!(
        list.iter().all(|&c| (1..=64).contains(&c)),
        "channel counts must be in 1..=64, got {list:?}"
    );
    Ok(list)
}

/// The `ZAC_CHANNELS` override (comma-separated shard counts), shared by
/// `zac-dest sweep` and the e2e example. `Ok(None)` when unset; a set
/// but malformed value is an error (a typo must not silently fall back
/// to the defaults).
pub fn channels_from_env() -> anyhow::Result<Option<Vec<usize>>> {
    match std::env::var("ZAC_CHANNELS") {
        Err(_) => Ok(None),
        Ok(v) => parse_channel_list(&v)
            .map(Some)
            .map_err(|e| anyhow::anyhow!("ZAC_CHANNELS: {e}")),
    }
}

/// Parse a trace-size override value (the `ZAC_BENCH_BYTES` format).
pub fn parse_bench_bytes(text: &str) -> anyhow::Result<usize> {
    let n: usize = text
        .trim()
        .parse()
        .map_err(|e| anyhow::anyhow!("bad byte count {text:?}: {e}"))?;
    anyhow::ensure!(n > 0, "byte count must be positive, got {text:?}");
    Ok(n)
}

/// The `ZAC_BENCH_BYTES` override, shared by `zac-dest sweep` and the
/// bench smokes. `Ok(None)` when unset; a set-but-malformed value is an
/// error, never a silent fallback.
pub fn bench_bytes_from_env() -> anyhow::Result<Option<usize>> {
    match std::env::var("ZAC_BENCH_BYTES") {
        Err(_) => Ok(None),
        Ok(v) => parse_bench_bytes(&v)
            .map(Some)
            .map_err(|e| anyhow::anyhow!("ZAC_BENCH_BYTES: {e}")),
    }
}

/// Bound a sweep worker count (1..=512; 0 would silently mean
/// "sequential", which a caller asking for parallelism must not get).
fn validate_workers(n: usize) -> anyhow::Result<usize> {
    anyhow::ensure!(
        (1..=512).contains(&n),
        "worker count must be in 1..=512, got {n}"
    );
    Ok(n)
}

/// Parse a `--workers` / `ZAC_SWEEP_WORKERS` value: a positive thread
/// count, or `auto` for this host's available parallelism.
pub fn parse_workers(text: &str) -> anyhow::Result<usize> {
    let t = text.trim();
    if t.eq_ignore_ascii_case("auto") {
        return validate_workers(crate::util::par::default_threads());
    }
    let n: usize = t
        .parse()
        .map_err(|e| anyhow::anyhow!("bad worker count {text:?}: {e}"))?;
    validate_workers(n)
}

/// The `ZAC_SWEEP_WORKERS` override (sweep worker-pool degree).
/// `Ok(None)` when unset; a set-but-malformed value is an error, never
/// a silent fallback.
pub fn sweep_workers_from_env() -> anyhow::Result<Option<usize>> {
    match std::env::var("ZAC_SWEEP_WORKERS") {
        Err(_) => Ok(None),
        Ok(v) => parse_workers(&v)
            .map(Some)
            .map_err(|e| anyhow::anyhow!("ZAC_SWEEP_WORKERS: {e}")),
    }
}

/// FNV-1a 64-bit: the stable zero-dependency content hash under cell
/// fingerprints (byte-order independent, identical across runs,
/// platforms and worker counts).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Stable fingerprint of one grid cell over one trace: a 16-hex-digit
/// FNV-1a hash of the canonical cell description — codec label (scheme
/// + every knob), channel count, fault spec (label + seed), address
/// policy, traffic class, baseline scheme, and the trace content hash
/// + length. Two cells collide only if they would produce identical
/// figures, so `sweep --resume` can key completed work on it across
/// process restarts.
pub fn cell_fingerprint(
    sc: &Scenario,
    spec: &SweepSpec,
    trace_hash: u64,
    trace_len: usize,
) -> String {
    let canon = format!(
        "zacfp1|{}|{}|ch={}|faults={}@{}|addr={}|approx={}|base={}|trace={:016x}:{}",
        sc.spec.scheme,
        sc.spec.label(),
        sc.channels,
        sc.faults.label(),
        sc.faults.seed,
        sc.address.label(),
        spec.approx,
        spec.baseline,
        trace_hash,
        trace_len,
    );
    format!("{:016x}", fnv1a(canon.as_bytes()))
}

/// Resolve a sweep's traffic source: the recorded `.zactrace` its
/// `trace` key names (structure and every frame CRC checked at the
/// ingestion boundary), or the standard synthetic trace sized by
/// `bytes`/`seed`. Shared by `zac-dest sweep --trace` and the TOML
/// key. The returned [`Trace`] owns the one and only copy of the
/// stream: every grid cell shares its `Arc`-backed line store.
pub fn sweep_trace(spec: &SweepSpec) -> anyhow::Result<Trace> {
    match &spec.trace {
        Some(path) => Trace::from_file(path).map_err(|e| anyhow::anyhow!("trace file {path}: {e}")),
        None => Ok(Trace::from_bytes(synthetic_trace(spec.bytes, spec.seed))),
    }
}

/// Byte view of [`sweep_trace`] for callers that only need the stream.
pub fn sweep_trace_bytes(spec: &SweepSpec) -> anyhow::Result<Vec<u8>> {
    Ok(sweep_trace(spec)?.bytes().to_vec())
}

/// The standard image-like synthetic trace (slowly varying byte walk)
/// used by the CLI, benches and CI smokes.
pub fn synthetic_trace(n: usize, seed: u64) -> Vec<u8> {
    let mut r = crate::util::rng::Rng::new(seed);
    let mut v = 128i32;
    (0..n)
        .map(|_| {
            v = (v + (r.below(9) as i32 - 4)).clamp(0, 255);
            v as u8
        })
        .collect()
}

/// Run one grid cell through a sharded [`Session`].
fn run_cell(
    spec: &CodecSpec,
    channels: usize,
    approx: bool,
    faults: &FaultSpec,
    address: &AddressSpec,
    telemetry: bool,
    trace: &Trace,
) -> anyhow::Result<RunReport> {
    Session::builder()
        .codec(spec.clone())
        .channels(channels)
        .traffic(TrafficClass::from_approx_flag(approx))
        .execution(Execution::Sharded)
        .faults(*faults)
        .address(address.clone())
        .telemetry(telemetry)
        .build()?
        .run(trace)
}

/// One executed cell's deterministic figures, with the receiver-side
/// byte stream already reduced to its quality metrics. The cell's
/// [`RunReport`] — `bytes` vector included — is dropped inside
/// [`measure_cell`], so a sweep (and its baseline map) holds O(cells)
/// memory, not O(cells × trace bytes).
#[derive(Clone, Debug)]
struct CellOutcome {
    table_hit_rate: f64,
    load_imbalance: f64,
    injected_bits: u64,
    injected_words: u64,
    observed_error_bits: u64,
    corrected_bits: u64,
    detected_bits: u64,
    residual_error_bits: u64,
    counts: EnergyCounts,
    outcome_fracs: [f64; 4],
    mae: f64,
    psnr_db: Option<f64>,
    wall: f64,
    shard_lines: Vec<usize>,
    telemetry: Option<TelemetrySnapshot>,
}

/// Run one cell and reduce its report to figures: the decoded stream
/// is compared against the source (MAE / PSNR) and then dropped right
/// here — received bytes never outlive the cell that produced them.
fn measure_cell(
    spec: &CodecSpec,
    channels: usize,
    approx: bool,
    faults: &FaultSpec,
    address: &AddressSpec,
    telemetry: bool,
    trace: &Trace,
) -> anyhow::Result<CellOutcome> {
    let t0 = Instant::now();
    let out = run_cell(spec, channels, approx, faults, address, telemetry, trace)?;
    let wall = t0.elapsed().as_secs_f64();
    let src = trace.bytes();
    let mae = if src.is_empty() {
        0.0
    } else {
        src.iter()
            .zip(&out.bytes)
            .map(|(&a, &b)| (a as f64 - b as f64).abs())
            .sum::<f64>()
            / src.len() as f64
    };
    let psnr = psnr_u8(src, &out.bytes);
    Ok(CellOutcome {
        table_hit_rate: out.stats.table_hit_rate(),
        load_imbalance: load_imbalance(&out.shards),
        injected_bits: out.faults.injected_bits,
        injected_words: out.faults.injected_words,
        observed_error_bits: out.faults.observed_error_bits,
        corrected_bits: out.faults.corrected_bits,
        detected_bits: out.faults.detected_bits,
        residual_error_bits: out.faults.residual_error_bits,
        counts: out.counts,
        outcome_fracs: Outcome::all().map(|o| out.stats.fraction(o)),
        mae,
        psnr_db: psnr.is_finite().then_some(psnr),
        wall,
        shard_lines: out.shards.iter().map(|s| s.lines).collect(),
        telemetry: out.telemetry,
    })
}

/// Run every scenario of the grid over `trace`, measuring energy savings
/// against the baseline scheme at the same channel count and address
/// policy plus the trace-level quality of the reconstructed stream.
/// Every cell runs through the unified [`Session`] API over the sharded
/// channel array; cells fan across `spec.workers` work-stealing
/// threads (1 = sequential, pinned bit-identical on every figure).
pub fn run_sweep(spec: &SweepSpec, trace: &Trace) -> anyhow::Result<SweepReport> {
    run_sweep_resume(spec, trace, None)
}

/// [`run_sweep`] with resume: cells whose [`cell_fingerprint`] already
/// sits in `prior` are carried over verbatim (figures, wall clock and
/// telemetry of the original run) instead of re-executing; only the
/// missing cells run. Merge rules: the merged report contains exactly
/// the current grid's cells in grid order — prior rows outside the
/// grid (or with no fingerprint, e.g. from a pre-fingerprint report)
/// are dropped, and a fully completed prior report re-runs zero cells
/// (including zero baseline runs).
pub fn run_sweep_resume(
    spec: &SweepSpec,
    trace: &Trace,
    prior: Option<&SweepReport>,
) -> anyhow::Result<SweepReport> {
    let t_start = Instant::now();
    let scenarios = spec.scenarios()?;
    let workers = spec.workers.max(1);
    let trace_hash = fnv1a(trace.bytes());
    let prints: Vec<String> = scenarios
        .iter()
        .map(|sc| cell_fingerprint(sc, spec, trace_hash, trace.byte_len()))
        .collect();
    let done: BTreeMap<&str, &ScenarioResult> = prior
        .map(|p| {
            p.scenarios
                .iter()
                .filter(|r| !r.fingerprint.is_empty())
                .map(|r| (r.fingerprint.as_str(), r))
                .collect()
        })
        .unwrap_or_default();
    let jobs: Vec<usize> = (0..scenarios.len())
        .filter(|&i| !done.contains_key(prints[i].as_str()))
        .collect();

    // One baseline run per (channel count, address policy) the pending
    // cells reference: sharding and placement both shape the per-table
    // history, so the fair baseline shards and places the same way.
    // Baselines run once up front (across the same worker pool) and
    // are shared immutably by every cell worker; a grid cell that IS
    // the baseline config reuses the outcome instead of simulating
    // twice. A fully resumed sweep has no pending cells and therefore
    // runs no baselines either.
    let base_spec = CodecSpec::named(&spec.baseline);
    let mut base_keys: Vec<(usize, AddressSpec)> = Vec::new();
    for &i in &jobs {
        let sc = &scenarios[i];
        if !base_keys
            .iter()
            .any(|(c, a)| *c == sc.channels && a.label() == sc.address.label())
        {
            base_keys.push((sc.channels, sc.address.clone()));
        }
    }
    let base_outs = par_map(base_keys.clone(), workers, |(c, a)| {
        measure_cell(
            &base_spec,
            c,
            spec.approx,
            &FaultSpec::perfect(),
            &a,
            spec.telemetry,
            trace,
        )
    });
    let mut baselines: BTreeMap<(usize, String), CellOutcome> = BTreeMap::new();
    for ((c, a), out) in base_keys.into_iter().zip(base_outs) {
        baselines.insert((c, a.label()), out?);
    }

    // Fan the pending cells across the pool. Each index is one unit of
    // work-stealing (cells vary wildly in cost), results come back in
    // grid order, and a worker panic re-raises its original payload.
    let cell_outs = par_map(jobs.clone(), workers, |i| {
        let sc = &scenarios[i];
        // A cell that IS the baseline config may reuse the baseline run
        // — but only on a perfect channel: a faulty cell has different
        // receiver-side bytes (energy would match, quality would not).
        if sc.spec == base_spec && sc.faults.is_perfect() {
            Ok(baselines[&(sc.channels, sc.address.label())].clone())
        } else {
            measure_cell(
                &sc.spec,
                sc.channels,
                spec.approx,
                &sc.faults,
                &sc.address,
                spec.telemetry,
                trace,
            )
        }
    });
    let mut computed: BTreeMap<usize, CellOutcome> = BTreeMap::new();
    for (&i, out) in jobs.iter().zip(cell_outs) {
        computed.insert(i, out?);
    }

    let mut results = Vec::with_capacity(scenarios.len());
    for (i, sc) in scenarios.iter().enumerate() {
        if let Some(prev) = done.get(prints[i].as_str()) {
            results.push((*prev).clone());
            continue;
        }
        let out = computed
            .remove(&i)
            .expect("every pending cell was executed");
        let base = &baselines[&(sc.channels, sc.address.label())].counts;
        let (limit, trunc, tol) = match sc.spec.zac_knobs() {
            Some(k) => (k.similarity_limit_pct, k.truncation_bits, k.tolerance_bits),
            None => (0, 0, 0),
        };
        results.push(ScenarioResult {
            label: sc.label(),
            fingerprint: prints[i].clone(),
            scheme: sc.spec.scheme.clone(),
            channels: sc.channels,
            limit,
            truncation_bits: trunc,
            tolerance_bits: tol,
            fault_label: sc.faults.label(),
            address: sc.address.label(),
            table_hit_rate: out.table_hit_rate,
            load_imbalance: out.load_imbalance,
            injected_bits: out.injected_bits,
            injected_words: out.injected_words,
            observed_error_bits: out.observed_error_bits,
            corrected_bits: out.corrected_bits,
            detected_bits: out.detected_bits,
            residual_error_bits: out.residual_error_bits,
            counts: out.counts,
            term_savings_pct: out.counts.termination_savings_vs(base),
            switch_savings_pct: out.counts.switching_savings_vs(base),
            outcome_fracs: out.outcome_fracs,
            quality_ratio: 1.0 - out.mae / 255.0,
            psnr_db: out.psnr_db,
            wall_ms: out.wall * 1e3,
            bytes_per_sec: if out.wall > 0.0 {
                trace.byte_len() as f64 / out.wall
            } else {
                0.0
            },
            shard_lines: out.shard_lines,
            telemetry: out.telemetry,
        });
    }
    Ok(SweepReport {
        name: spec.name.clone(),
        trace_bytes: trace.byte_len(),
        baseline: spec.baseline.clone(),
        workers,
        cells_run: jobs.len(),
        cells_skipped: scenarios.len() - jobs.len(),
        wall_s: t_start.elapsed().as_secs_f64(),
        scenarios: results,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_has_at_least_six_scenarios() {
        let spec = SweepSpec::default();
        let sc = spec.scenarios().unwrap();
        assert!(sc.len() >= 6, "only {} scenarios", sc.len());
        // Every channel count × every scheme is represented.
        for &c in &spec.channels {
            assert!(sc.iter().any(|x| x.channels == c && x.spec.scheme == "BDE"));
            assert!(sc
                .iter()
                .any(|x| x.channels == c && x.spec.zac_knobs().is_some()));
        }
    }

    #[test]
    fn spec_parses_from_toml() {
        let spec = SweepSpec::from_toml(
            r#"
            name = "ci-smoke"
            seed = 7
            bytes = 65536
            approx = true
            [grid]
            channels = [1, 2, 4]
            schemes = ["ORG", "OHE"]
            limits = [80]
            truncations = [0, 1]
            tolerances = [0]
            baseline = "ORG"
            "#,
        )
        .unwrap();
        assert_eq!(spec.name, "ci-smoke");
        assert_eq!(spec.channels, vec![1, 2, 4]);
        assert_eq!(spec.baseline, "ORG");
        // 3 channels × (ORG + ZAC 1×2×1) = 9 scenarios.
        assert_eq!(spec.scenarios().unwrap().len(), 9);
    }

    #[test]
    fn telemetry_key_parses_from_toml() {
        assert!(!SweepSpec::default().telemetry, "telemetry must be opt-in");
        let spec = SweepSpec::from_toml("telemetry = true\n").unwrap();
        assert!(spec.telemetry);
        assert!(SweepSpec::from_toml("telemetry = 1\n").is_err());
    }

    #[test]
    fn trace_key_parses_and_selects_the_traffic_source() {
        assert_eq!(SweepSpec::default().trace, None);
        let spec = SweepSpec::from_toml("trace = \"/tmp/x.zactrace\"\n").unwrap();
        assert_eq!(spec.trace.as_deref(), Some("/tmp/x.zactrace"));
        assert!(SweepSpec::from_toml("trace = 1\n").is_err());
        // No trace key: the synthetic source, sized by bytes/seed.
        let spec = SweepSpec {
            bytes: 4096,
            ..SweepSpec::default()
        };
        assert_eq!(
            sweep_trace_bytes(&spec).unwrap(),
            synthetic_trace(4096, spec.seed)
        );
        // A missing file is a named error, never a panic.
        let missing = SweepSpec {
            trace: Some("/nonexistent/zac.zactrace".into()),
            ..SweepSpec::default()
        };
        let err = sweep_trace_bytes(&missing).unwrap_err().to_string();
        assert!(err.contains("/nonexistent/zac.zactrace"), "{err}");
    }

    #[test]
    fn sweep_trace_source_round_trips_through_a_recorded_file() {
        let bytes = synthetic_trace(6000, 9);
        let name = format!("zac_sweep_src_{}.zactrace", std::process::id());
        let path = std::env::temp_dir().join(name);
        Trace::from_bytes(bytes.clone()).record(&path, true).unwrap();
        let spec = SweepSpec {
            trace: Some(path.to_str().unwrap().to_string()),
            ..SweepSpec::default()
        };
        assert_eq!(sweep_trace_bytes(&spec).unwrap(), bytes);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn spec_rejects_unknown_keys_and_bad_axes() {
        assert!(SweepSpec::from_toml("bogus = 1\n").is_err());
        assert!(SweepSpec::from_toml("[grid]\nwat = [1]\n").is_err());
        assert!(SweepSpec::from_toml("[grid]\nschemes = [\"NOPE\"]\n").is_err());
        assert!(SweepSpec::from_toml("[grid]\nchannels = [0]\n").is_err());
        let mut spec = SweepSpec::default();
        spec.limits.clear();
        assert!(spec.validate().is_err());
    }

    #[test]
    fn toml_ingestion_rejects_invalid_codec_knobs() {
        // Satellite: validate() runs at the TOML ingestion boundary —
        // a knob the codec layer would reject fails at parse time.
        let err = SweepSpec::from_toml("[grid]\nlimits = [200]\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("similarity limit"), "{err}");
        assert!(SweepSpec::from_toml("[grid]\ntruncations = [9]\n").is_err());
    }

    #[test]
    fn bench_bytes_parsing_rejects_garbage() {
        assert_eq!(parse_bench_bytes("65536").unwrap(), 65536);
        assert_eq!(parse_bench_bytes(" 1024 ").unwrap(), 1024);
        assert!(parse_bench_bytes("64KiB").is_err());
        assert!(parse_bench_bytes("").is_err());
        assert!(parse_bench_bytes("0").is_err());
        assert!(parse_bench_bytes("-1").is_err());
    }

    #[test]
    fn channel_list_parsing() {
        assert_eq!(parse_channel_list("1,2,4").unwrap(), vec![1, 2, 4]);
        assert_eq!(parse_channel_list(" 2 ").unwrap(), vec![2]);
        assert!(parse_channel_list("0").is_err());
        assert!(parse_channel_list("a,b").is_err());
        assert!(parse_channel_list("").is_err());
    }

    #[test]
    fn sweep_runs_end_to_end_and_writes_json() {
        let spec = SweepSpec {
            bytes: 8192,
            ..SweepSpec::default()
        };
        let trace = synthetic_trace(spec.bytes, spec.seed);
        let report = run_sweep(&spec, &Trace::from_bytes(trace.clone())).unwrap();
        assert!(report.scenarios.len() >= 6);
        // Baseline scenario at its own channel count saves ~0% vs itself.
        let bde = report
            .scenarios
            .iter()
            .find(|r| r.scheme == "BDE" && r.channels == 1)
            .unwrap();
        assert!(bde.term_savings_pct.abs() < 1e-9);
        assert_eq!(bde.quality_ratio, 1.0);
        assert!(bde.psnr_db.is_none());
        // Every scenario covers the whole trace.
        for r in &report.scenarios {
            assert_eq!(
                r.shard_lines.iter().sum::<usize>(),
                trace.len() / 64,
                "{}",
                r.label
            );
            assert_eq!(r.counts.transfers, (trace.len() / 64 * 8) as u64);
        }
        let path = std::env::temp_dir().join("zac_sweep_test.json");
        let path = path.to_str().unwrap();
        report.write_json(path).unwrap();
        let parsed =
            crate::util::json_lite::Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(
            parsed.get("scenarios").unwrap().as_arr().unwrap().len(),
            report.scenarios.len()
        );
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn faults_axis_parses_and_expands_the_grid() {
        let spec = SweepSpec::from_toml(
            r#"
            name = "faulty"
            bytes = 8192
            [grid]
            channels = [1]
            schemes = ["BDE"]
            faults = ["perfect", "voltage:1050", "uniform:1e-3@7"]
            "#,
        )
        .unwrap();
        assert_eq!(spec.faults.len(), 3);
        assert_eq!(spec.faults[2].seed, 7);
        let sc = spec.scenarios().unwrap();
        assert_eq!(sc.len(), 3);
        assert!(sc.iter().any(|s| s.label() == "BDE@1ch"));
        assert!(sc.iter().any(|s| s.label() == "BDE@1ch+vdd1050mV"));
        // Bad fault strings are rejected at the TOML boundary.
        assert!(
            SweepSpec::from_toml("[grid]\nfaults = [\"wat\"]\n").is_err(),
            "unknown fault model accepted"
        );
        assert!(SweepSpec::from_toml("[grid]\nfaults = []\n").is_err());
    }

    #[test]
    fn faulty_sweep_keeps_energy_and_degrades_quality() {
        let spec = SweepSpec {
            bytes: 16384,
            channels: vec![2],
            schemes: vec!["BDE".into()],
            faults: vec![FaultSpec::perfect(), FaultSpec::uniform(1e-2)],
            ..SweepSpec::default()
        };
        let trace = Trace::from_bytes(synthetic_trace(spec.bytes, spec.seed));
        let report = run_sweep(&spec, &trace).unwrap();
        assert_eq!(report.scenarios.len(), 2);
        let perfect = &report.scenarios[0];
        let faulty = &report.scenarios[1];
        assert_eq!(perfect.injected_bits, 0);
        assert_eq!(perfect.quality_ratio, 1.0);
        assert!(faulty.injected_bits > 0, "no flips at 1e-2 BER");
        // Injection happens after transmit: energy identical.
        assert_eq!(faulty.counts, perfect.counts);
        assert!(
            faulty.quality_ratio < 1.0,
            "faults must cost quality, got {}",
            faulty.quality_ratio
        );
        assert!(report.render_table().contains("vdd") || report.render_table().contains("ber"));
    }

    #[test]
    fn address_axis_parses_and_expands_the_grid() {
        let spec = SweepSpec::from_toml(
            r#"
            name = "steered"
            bytes = 8192
            [grid]
            channels = [2]
            schemes = ["BDE"]
            address = ["round_robin", "steer", "capacity:2/1"]
            "#,
        )
        .unwrap();
        assert_eq!(spec.address.len(), 3);
        let sc = spec.scenarios().unwrap();
        assert_eq!(sc.len(), 3);
        assert!(sc.iter().any(|s| s.label() == "BDE@2ch"));
        assert!(sc.iter().any(|s| s.label() == "BDE@2ch+steer"));
        assert!(sc.iter().any(|s| s.label() == "BDE@2ch+cap2/1"));
        // Bad address strings are rejected at the TOML boundary.
        assert!(SweepSpec::from_toml("[grid]\naddress = [\"wat\"]\n").is_err());
        assert!(SweepSpec::from_toml("[grid]\naddress = []\n").is_err());
    }

    #[test]
    fn steered_sweep_reports_per_policy_hit_rates() {
        // Acceptance: LocalitySteer must raise the per-channel DataTable
        // hit rate (and not cost termination energy) vs RoundRobin on
        // the image-like trace, and both must land in the report fields
        // BENCH_system.json persists.
        let spec = SweepSpec {
            bytes: 1 << 17,
            channels: vec![4],
            schemes: vec!["OHE".into()],
            limits: vec![75],
            address: vec![AddressSpec::round_robin(), AddressSpec::steer()],
            ..SweepSpec::default()
        };
        let trace = synthetic_trace(spec.bytes, 31);
        let report = run_sweep(&spec, &Trace::from_bytes(trace.clone())).unwrap();
        let rr = report
            .scenarios
            .iter()
            .find(|r| r.address == "round_robin")
            .unwrap();
        let steer = report
            .scenarios
            .iter()
            .find(|r| r.address == "steer")
            .unwrap();
        assert!(
            steer.table_hit_rate > rr.table_hit_rate,
            "steer hit rate {} must beat round-robin {}",
            steer.table_hit_rate,
            rr.table_hit_rate
        );
        assert!(
            steer.counts.termination_ones <= rr.counts.termination_ones,
            "steer termination {} must not exceed round-robin {}",
            steer.counts.termination_ones,
            rr.counts.termination_ones
        );
        assert!(steer.load_imbalance >= 1.0);
        assert_eq!(
            steer.shard_lines.iter().sum::<usize>(),
            trace.len() / 64,
            "steering must still cover the whole trace"
        );
    }

    #[test]
    fn correcting_schemes_join_the_grid_and_wrapped_zac_takes_knobs() {
        let spec = SweepSpec::from_toml(
            r#"
            name = "ecc-grid"
            bytes = 8192
            [grid]
            channels = [1]
            schemes = ["secded", "ECC+OHE"]
            limits = [80, 75]
            "#,
        )
        .unwrap();
        // Lower-case names canonicalize against the registry.
        assert_eq!(spec.schemes, vec!["SECDED".to_string(), "ECC+OHE".into()]);
        let sc = spec.scenarios().unwrap();
        // SECDED is knob-free (1 cell); wrapped ZAC takes the limit grid.
        assert_eq!(sc.len(), 3);
        assert!(sc.iter().any(|s| s.spec.scheme == "SECDED"));
        let wrapped: Vec<_> = sc.iter().filter(|s| s.spec.scheme == "ECC+OHE").collect();
        assert_eq!(wrapped.len(), 2);
        assert!(wrapped.iter().all(|s| s.spec.zac_knobs().is_some()));
    }

    #[test]
    fn scheme_parse_errors_name_the_token_and_list_registered_schemes() {
        // Satellite: the CLI, run TOML and sweep [grid] share this
        // message shape with --faults.
        let err = SweepSpec::from_toml("[grid]\nschemes = [\"NOPE\"]\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("\"NOPE\""), "{err}");
        assert!(err.contains("registered schemes"), "{err}");
        assert!(err.contains("SECDED") && err.contains("ECC+BDE"), "{err}");
        let err = SweepSpec::from_toml("[grid]\nbaseline = \"WAT\"\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("baseline") && err.contains("\"WAT\""), "{err}");
    }

    #[test]
    fn ecc_wrapper_shrinks_residual_errors_at_a_fixed_eden_bin() {
        // Acceptance: at the same EDEN voltage bin, the corrected
        // variant ends with strictly fewer residual error bits than its
        // uncorrected base, and both pay identical injection pressure.
        let spec = SweepSpec {
            bytes: 1 << 16,
            channels: vec![1],
            schemes: vec!["BDE".into(), "ECC+BDE".into()],
            faults: vec![FaultSpec::parse("voltage:1050").unwrap()],
            ..SweepSpec::default()
        };
        let trace = Trace::from_bytes(synthetic_trace(spec.bytes, spec.seed));
        let report = run_sweep(&spec, &trace).unwrap();
        let bde = report.scenarios.iter().find(|r| r.scheme == "BDE").unwrap();
        let ecc = report
            .scenarios
            .iter()
            .find(|r| r.scheme == "ECC+BDE")
            .unwrap();
        assert!(bde.injected_bits > 0, "no flips injected at vdd1050mV");
        assert!(ecc.injected_bits > 0, "no flips injected into ECC+BDE");
        assert!(ecc.corrected_bits > 0, "wrapper never corrected a flip");
        assert!(
            ecc.residual_error_bits < bde.residual_error_bits,
            "ECC+BDE residual {} must beat uncorrected BDE {}",
            ecc.residual_error_bits,
            bde.residual_error_bits
        );
        // The uncorrected base reports no correction activity.
        assert_eq!(bde.corrected_bits, 0);
        // Check bits cost energy: the wrapper terminates more ones.
        assert!(
            ecc.counts.termination_ones > bde.counts.termination_ones,
            "sideband check bits must show up in termination energy"
        );
    }

    #[test]
    fn zac_beats_baseline_on_image_like_trace() {
        let spec = SweepSpec {
            bytes: 65536,
            channels: vec![2],
            ..SweepSpec::default()
        };
        let trace = Trace::from_bytes(synthetic_trace(spec.bytes, 7));
        let report = run_sweep(&spec, &trace).unwrap();
        let zac = report
            .scenarios
            .iter()
            .find(|r| r.scheme == "OHE" && r.limit == 75)
            .unwrap();
        assert!(
            zac.term_savings_pct > 0.0,
            "ZAC L75 should save termination energy vs BDE, got {}",
            zac.term_savings_pct
        );
    }

    #[test]
    fn workers_key_parses_and_rejects_out_of_range() {
        assert_eq!(SweepSpec::default().workers, 1, "parallelism must be opt-in");
        let spec = SweepSpec::from_toml("workers = 4\n").unwrap();
        assert_eq!(spec.workers, 4);
        assert!(SweepSpec::from_toml("workers = 0\n").is_err());
        assert!(SweepSpec::from_toml("workers = 1000\n").is_err());
        assert_eq!(parse_workers("8").unwrap(), 8);
        assert_eq!(parse_workers(" 2 ").unwrap(), 2);
        assert!(parse_workers("auto").unwrap() >= 1);
        assert!(parse_workers("0").is_err());
        assert!(parse_workers("lots").is_err());
        assert!(parse_workers("").is_err());
    }

    #[test]
    fn cell_fingerprints_are_stable_distinct_and_trace_sensitive() {
        let spec = SweepSpec {
            bytes: 4096,
            faults: vec![FaultSpec::perfect(), FaultSpec::uniform(1e-3)],
            ..SweepSpec::default()
        };
        let scenarios = spec.scenarios().unwrap();
        let h = fnv1a(b"trace");
        let prints: Vec<String> = scenarios
            .iter()
            .map(|sc| cell_fingerprint(sc, &spec, h, 4096))
            .collect();
        // Stable across calls — the resume key must survive a restart.
        let again: Vec<String> = scenarios
            .iter()
            .map(|sc| cell_fingerprint(sc, &spec, h, 4096))
            .collect();
        assert_eq!(prints, again);
        // 16 lowercase hex digits each, all distinct within one grid.
        let set: std::collections::BTreeSet<&String> = prints.iter().collect();
        assert_eq!(set.len(), prints.len(), "fingerprint collision inside a grid");
        assert!(prints
            .iter()
            .all(|p| p.len() == 16 && p.chars().all(|c| c.is_ascii_hexdigit())));
        // Sensitive to trace content, trace length and the baseline.
        assert_ne!(cell_fingerprint(&scenarios[0], &spec, fnv1a(b"other"), 4096), prints[0]);
        assert_ne!(cell_fingerprint(&scenarios[0], &spec, h, 8192), prints[0]);
        let other_base = SweepSpec {
            baseline: "ORG".into(),
            ..spec.clone()
        };
        assert_ne!(cell_fingerprint(&scenarios[0], &other_base, h, 4096), prints[0]);
    }

    #[test]
    fn parallel_sweep_is_bit_identical_to_sequential() {
        // Quick pin; the full multi-scheme × faults × address grid at
        // workers = 2 and 4 lives in tests/sweep_parallel.rs.
        let seq = SweepSpec {
            bytes: 8192,
            ..SweepSpec::default()
        };
        let par = SweepSpec {
            workers: 4,
            ..seq.clone()
        };
        let trace = Trace::from_bytes(synthetic_trace(seq.bytes, seq.seed));
        let a = run_sweep(&seq, &trace).unwrap();
        let b = run_sweep(&par, &trace).unwrap();
        assert_eq!(a.workers, 1);
        assert_eq!(b.workers, 4);
        assert_eq!(a.scenarios.len(), b.scenarios.len());
        for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
            assert_eq!(x.label, y.label, "grid order must not depend on workers");
            assert_eq!(x.fingerprint, y.fingerprint);
            assert_eq!(x.counts, y.counts, "{}", x.label);
            assert_eq!(x.term_savings_pct, y.term_savings_pct, "{}", x.label);
            assert_eq!(x.quality_ratio, y.quality_ratio, "{}", x.label);
            assert_eq!(x.table_hit_rate, y.table_hit_rate, "{}", x.label);
            assert_eq!(x.shard_lines, y.shard_lines, "{}", x.label);
        }
        assert_eq!(b.cells_run, b.scenarios.len());
        assert_eq!(b.cells_skipped, 0);
        assert!(b.wall_s > 0.0);
    }

    #[test]
    fn resume_skips_completed_cells_and_merges_in_grid_order() {
        let spec = SweepSpec {
            bytes: 8192,
            ..SweepSpec::default()
        };
        let trace = Trace::from_bytes(synthetic_trace(spec.bytes, spec.seed));
        let full = run_sweep(&spec, &trace).unwrap();
        // A completed prior report re-runs zero cells (and zero
        // baselines — resume over finished work must cost nothing).
        let resumed = run_sweep_resume(&spec, &trace, Some(&full)).unwrap();
        assert_eq!(resumed.cells_run, 0);
        assert_eq!(resumed.cells_skipped, full.scenarios.len());
        // An interrupted report (first 3 cells survived) re-runs
        // exactly the missing cells; the merge equals a from-scratch
        // run figure for figure, in grid order.
        let mut partial = full.clone();
        partial.scenarios.truncate(3);
        let merged = run_sweep_resume(&spec, &trace, Some(&partial)).unwrap();
        assert_eq!(merged.cells_skipped, 3);
        assert_eq!(merged.cells_run, full.scenarios.len() - 3);
        assert_eq!(merged.scenarios.len(), full.scenarios.len());
        for (x, y) in full.scenarios.iter().zip(&merged.scenarios) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.fingerprint, y.fingerprint);
            assert_eq!(x.counts, y.counts, "{}", x.label);
            assert_eq!(x.quality_ratio, y.quality_ratio, "{}", x.label);
            assert_eq!(x.term_savings_pct, y.term_savings_pct, "{}", x.label);
        }
        // A prior row with no fingerprint (pre-resume report format)
        // is ignored, not trusted.
        let mut legacy = full.clone();
        for r in &mut legacy.scenarios {
            r.fingerprint.clear();
        }
        let refreshed = run_sweep_resume(&spec, &trace, Some(&legacy)).unwrap();
        assert_eq!(refreshed.cells_run, full.scenarios.len());
        assert_eq!(refreshed.cells_skipped, 0);
    }
}
