//! Open-loop load generator: replay a trace into a sharded
//! [`ChannelArray`](crate::system::array::ChannelArray) at a target
//! offered rate (lines/sec) and measure what the load does to service
//! latency and mailbox depth — the closed-loop sweep engine pushes as
//! fast as the mailboxes drain, so it can never see where the queues
//! back up.
//!
//! Open-loop means arrivals are scheduled by the clock, not by
//! completions: chunk *i* is offered at `i × gap ± jitter` regardless
//! of how far behind the shards are. Below saturation the producer
//! sleeps between sends; past the knee the mailboxes fill, sends
//! block, and the per-shard `service_p99_ns` / `mailbox_max_depth`
//! telemetry captures the queueing delay — one [`LoadGenStep`] row per
//! offered-rate step lands in `BENCH_loadgen.json`, so the knee of the
//! latency curve is a committed artifact.
//!
//! The arrival schedule is deterministic for a fixed seed and rate
//! ([`arrival_schedule`] is a pure function of both), and the encoded
//! figures (energy counts, bytes) are identical at every offered rate
//! — pacing changes *when* chunks arrive, never *what* they carry.

use std::time::Instant;

use crate::channel::EnergyCounts;
use crate::encoding::{CodecSpec, ENCODE_BATCH};
use crate::faults::FaultSpec;
use crate::obs::TelemetrySnapshot;
use crate::session::{Execution, Session, Trace, TrafficClass};
use crate::system::address::AddressSpec;
use crate::system::scenario::SweepSpec;
use crate::trace::LineChunk;
use crate::util::json_lite::{self, num, obj, s, Json};
use crate::util::rng::Rng;
use crate::util::table::{f, TextTable};

/// One open-loop experiment: a single grid cell driven at each offered
/// rate in `rates`.
#[derive(Clone, Debug)]
pub struct LoadGenSpec {
    pub name: String,
    /// The codec under load.
    pub spec: CodecSpec,
    pub channels: usize,
    pub approx: bool,
    pub faults: FaultSpec,
    pub address: AddressSpec,
    /// Arrival-jitter seed (mixed with each rate's bits, so steps get
    /// decorrelated but reproducible schedules).
    pub seed: u64,
    /// Offered rates in lines/sec — one [`LoadGenStep`] per entry.
    pub rates: Vec<f64>,
    /// Lines per arrival (one mailbox chunk; default [`ENCODE_BATCH`]).
    pub chunk_lines: usize,
    /// Uniform jitter amplitude as a fraction of the inter-arrival gap
    /// (0 = strictly periodic arrivals).
    pub jitter_frac: f64,
}

impl LoadGenSpec {
    /// Derive the load-generator config from a sweep spec: the first
    /// cell of the expanded grid (its codec, channel count, fault model
    /// and address policy) is the system under load, so `sweep
    /// --open-loop` needs no second config format.
    pub fn from_sweep(spec: &SweepSpec, rates: Vec<f64>) -> anyhow::Result<LoadGenSpec> {
        let sc = spec
            .scenarios()?
            .into_iter()
            .next()
            .ok_or_else(|| anyhow::anyhow!("empty sweep grid"))?;
        let lg = LoadGenSpec {
            name: format!("{}-loadgen", spec.name),
            spec: sc.spec,
            channels: sc.channels,
            approx: spec.approx,
            faults: sc.faults,
            address: sc.address,
            seed: spec.seed,
            rates,
            chunk_lines: ENCODE_BATCH,
            jitter_frac: 0.2,
        };
        lg.validate()?;
        Ok(lg)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.rates.is_empty(), "no offered rates");
        anyhow::ensure!(
            self.rates.iter().all(|&r| r.is_finite() && r > 0.0),
            "offered rates must be finite and positive, got {:?}",
            self.rates
        );
        anyhow::ensure!(self.chunk_lines >= 1, "chunk_lines must be >= 1");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.jitter_frac),
            "jitter_frac must be in 0..=1, got {}",
            self.jitter_frac
        );
        Ok(())
    }

    /// Cell label, same shape as a sweep scenario's.
    pub fn label(&self) -> String {
        let mut l = format!("{}@{}ch", self.spec.label(), self.channels);
        if !self.faults.is_perfect() {
            l.push_str(&format!("+{}", self.faults.label()));
        }
        if !self.address.is_round_robin() {
            l.push_str(&format!("+{}", self.address.label()));
        }
        l
    }
}

/// Parse a comma-separated offered-rate list (lines/sec), e.g.
/// `"50000,200000,1e6"`.
pub fn parse_rates(text: &str) -> anyhow::Result<Vec<f64>> {
    let rates: Vec<f64> = text
        .split(',')
        .map(|p| {
            let p = p.trim();
            p.parse::<f64>()
                .map_err(|e| anyhow::anyhow!("bad offered rate {p:?}: {e}"))
        })
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(!rates.is_empty(), "empty rate list");
    anyhow::ensure!(
        rates.iter().all(|&r| r.is_finite() && r > 0.0),
        "offered rates must be finite and positive, got {rates:?}"
    );
    Ok(rates)
}

/// The deterministic open-loop arrival schedule: chunk `i`'s offered
/// time (seconds from step start) is `i × gap` plus a uniform jitter of
/// ±`jitter_frac/2 × gap`, where `gap = chunk_lines / rate`. A pure
/// function of `(rate, seed)` — the same inputs give the same schedule
/// on every host, which is what pins the load generator reproducible.
pub fn arrival_schedule(
    rate: f64,
    chunks: usize,
    chunk_lines: usize,
    jitter_frac: f64,
    seed: u64,
) -> Vec<f64> {
    let gap = chunk_lines as f64 / rate;
    let mut rng = Rng::new(seed ^ rate.to_bits());
    (0..chunks)
        .map(|i| {
            let jitter = (rng.f64() - 0.5) * jitter_frac * gap;
            (i as f64 * gap + jitter).max(0.0)
        })
        .collect()
}

/// One offered-rate step's measured outcome. The percentile fields are
/// the worst shard's (max across shards — the latency a line routed to
/// the hottest shard sees); `blocked_sends`/`send_block_ns` sum over
/// shards (total producer backpressure).
#[derive(Clone, Debug)]
pub struct LoadGenStep {
    pub offered_lines_per_sec: f64,
    /// Lines actually retired per wall-clock second of the step. Tracks
    /// the offered rate below saturation; flattens at the knee.
    pub achieved_lines_per_sec: f64,
    pub lines: usize,
    pub chunks: usize,
    pub wall_s: f64,
    pub service_p50_ns: u64,
    pub service_p95_ns: u64,
    pub service_p99_ns: u64,
    /// High-water mailbox depth over all shards — the queueing signal.
    pub peak_mailbox_depth: u64,
    pub blocked_sends: u64,
    pub send_block_ns: u64,
    /// Energy counts — identical at every offered rate (pacing changes
    /// arrival times, never content).
    pub counts: EnergyCounts,
    /// The full per-shard snapshot behind the summary columns.
    pub telemetry: TelemetrySnapshot,
}

impl LoadGenStep {
    fn to_json(&self) -> Json {
        obj(vec![
            ("offered_lines_per_sec", num(self.offered_lines_per_sec)),
            ("achieved_lines_per_sec", num(self.achieved_lines_per_sec)),
            ("lines", num(self.lines as f64)),
            ("chunks", num(self.chunks as f64)),
            ("wall_s", num(self.wall_s)),
            ("service_p50_ns", num(self.service_p50_ns as f64)),
            ("service_p95_ns", num(self.service_p95_ns as f64)),
            ("service_p99_ns", num(self.service_p99_ns as f64)),
            ("peak_mailbox_depth", num(self.peak_mailbox_depth as f64)),
            ("blocked_sends", num(self.blocked_sends as f64)),
            ("send_block_ns", num(self.send_block_ns as f64)),
            ("termination_ones", num(self.counts.termination_ones as f64)),
            (
                "switching_transitions",
                num(self.counts.switching_transitions as f64),
            ),
            ("transfers", num(self.counts.transfers as f64)),
            ("telemetry", self.telemetry.to_json()),
        ])
    }
}

/// Full load-generator result: one step per offered rate, plus the
/// config that produced it (the `BENCH_loadgen.json` artifact).
#[derive(Clone, Debug)]
pub struct LoadGenReport {
    pub name: String,
    /// The cell under load ([`LoadGenSpec::label`]).
    pub label: String,
    pub trace_bytes: usize,
    pub trace_lines: usize,
    pub chunk_lines: usize,
    pub jitter_frac: f64,
    pub seed: u64,
    pub steps: Vec<LoadGenStep>,
}

impl LoadGenReport {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("label", s(&self.label)),
            ("trace_bytes", num(self.trace_bytes as f64)),
            ("trace_lines", num(self.trace_lines as f64)),
            ("chunk_lines", num(self.chunk_lines as f64)),
            ("jitter_frac", num(self.jitter_frac)),
            ("seed", num(self.seed as f64)),
            (
                "steps",
                Json::Arr(self.steps.iter().map(|st| st.to_json()).collect()),
            ),
        ])
    }

    /// Persist as pretty JSON (the `BENCH_loadgen.json` artifact).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        json_lite::write_file(path, &self.to_json())?;
        eprintln!("loadgen report -> {path}");
        Ok(())
    }

    /// Human-readable latency curve, one row per offered-rate step.
    pub fn render_table(&self) -> String {
        let mut t = TextTable::new(&[
            "offered l/s",
            "achieved l/s",
            "svc p50",
            "svc p95",
            "svc p99",
            "peak mbox",
            "blocked",
        ]);
        for st in &self.steps {
            t.row(vec![
                f(st.offered_lines_per_sec, 0),
                f(st.achieved_lines_per_sec, 0),
                format!("{}ns", st.service_p50_ns),
                format!("{}ns", st.service_p95_ns),
                format!("{}ns", st.service_p99_ns),
                st.peak_mailbox_depth.to_string(),
                st.blocked_sends.to_string(),
            ]);
        }
        format!(
            "loadgen {:?}: {} over {} lines ({} B), chunk {} lines, jitter {:.0}%, seed {}\n{}",
            self.name,
            self.label,
            self.trace_lines,
            self.trace_bytes,
            self.chunk_lines,
            100.0 * self.jitter_frac,
            self.seed,
            t.render()
        )
    }
}

/// Run the open-loop experiment: for each offered rate, pace the
/// trace's chunks into a fresh sharded array along the deterministic
/// [`arrival_schedule`] and reduce the run's telemetry to one
/// [`LoadGenStep`]. Telemetry is forced on — latency under load is the
/// entire output.
pub fn run_loadgen(spec: &LoadGenSpec, trace: &Trace) -> anyhow::Result<LoadGenReport> {
    spec.validate()?;
    anyhow::ensure!(trace.line_count() > 0, "empty trace");
    let session = Session::builder()
        .codec(spec.spec.clone())
        .channels(spec.channels)
        .traffic(TrafficClass::from_approx_flag(spec.approx))
        .execution(Execution::Sharded)
        .faults(spec.faults)
        .address(spec.address.clone())
        .telemetry(true)
        .build()?;
    let store = trace.line_store();
    let nlines = trace.line_count();
    let nchunks = nlines.div_ceil(spec.chunk_lines);
    let mut steps = Vec::with_capacity(spec.rates.len());
    for &rate in &spec.rates {
        let schedule =
            arrival_schedule(rate, nchunks, spec.chunk_lines, spec.jitter_frac, spec.seed);
        let mut array = session.sharded_array()?;
        let t0 = Instant::now();
        for (i, &due) in schedule.iter().enumerate() {
            let now = t0.elapsed().as_secs_f64();
            if due > now {
                std::thread::sleep(std::time::Duration::from_secs_f64(due - now));
            }
            let start = i * spec.chunk_lines;
            let len = (nlines - start).min(spec.chunk_lines);
            array.push_chunk(&LineChunk::window(store.clone(), start, len, spec.approx));
        }
        let out = array.finish(trace.byte_len());
        let wall = t0.elapsed().as_secs_f64();
        let counts = out.counts;
        let telemetry = out
            .telemetry
            .ok_or_else(|| anyhow::anyhow!("load generator requires telemetry"))?;
        let achieved = if wall > 0.0 {
            nlines as f64 / wall
        } else {
            0.0
        };
        let shard_max = |f: fn(&crate::obs::ShardSnapshot) -> u64| {
            telemetry.shards.iter().map(f).max().unwrap_or(0)
        };
        steps.push(LoadGenStep {
            offered_lines_per_sec: rate,
            achieved_lines_per_sec: achieved,
            lines: nlines,
            chunks: nchunks,
            wall_s: wall,
            service_p50_ns: shard_max(|sh| sh.service_p50_ns),
            service_p95_ns: shard_max(|sh| sh.service_p95_ns),
            service_p99_ns: shard_max(|sh| sh.service_p99_ns),
            peak_mailbox_depth: telemetry
                .shards
                .iter()
                .map(|sh| sh.mailbox_max_depth)
                .max()
                .unwrap_or(0),
            blocked_sends: telemetry.shards.iter().map(|sh| sh.blocked_sends).sum(),
            send_block_ns: telemetry.shards.iter().map(|sh| sh.send_block_ns).sum(),
            counts,
            telemetry,
        });
    }
    Ok(LoadGenReport {
        name: spec.name.clone(),
        label: spec.label(),
        trace_bytes: trace.byte_len(),
        trace_lines: nlines,
        chunk_lines: spec.chunk_lines,
        jitter_frac: spec.jitter_frac,
        seed: spec.seed,
        steps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::scenario::synthetic_trace;

    fn quick_spec(rates: Vec<f64>) -> LoadGenSpec {
        LoadGenSpec {
            name: "unit".into(),
            spec: CodecSpec::named("BDE"),
            channels: 2,
            approx: true,
            faults: FaultSpec::perfect(),
            address: AddressSpec::round_robin(),
            seed: 42,
            rates,
            chunk_lines: 64,
            jitter_frac: 0.2,
        }
    }

    #[test]
    fn schedule_is_deterministic_per_seed_and_rate() {
        let a = arrival_schedule(1e5, 50, 256, 0.2, 42);
        let b = arrival_schedule(1e5, 50, 256, 0.2, 42);
        assert_eq!(a, b, "same seed+rate must give the same schedule");
        assert_ne!(a, arrival_schedule(1e5, 50, 256, 0.2, 43));
        assert_ne!(a, arrival_schedule(2e5, 50, 256, 0.2, 42));
        // Offsets are non-negative and track i × gap within the jitter
        // envelope (gap = 256/1e5 = 2.56ms, jitter ±10%).
        let gap = 256.0 / 1e5;
        for (i, &t) in a.iter().enumerate() {
            assert!(t >= 0.0);
            assert!((t - i as f64 * gap).abs() <= 0.5 * 0.2 * gap + 1e-12, "chunk {i}");
        }
        // Zero jitter is strictly periodic.
        let flat = arrival_schedule(1e5, 10, 256, 0.0, 42);
        for (i, &t) in flat.iter().enumerate() {
            assert!((t - i as f64 * gap).abs() < 1e-12);
        }
    }

    #[test]
    fn rates_parse_and_reject_garbage() {
        assert_eq!(parse_rates("50000,2e5").unwrap(), vec![50000.0, 2e5]);
        assert_eq!(parse_rates(" 1e6 ").unwrap(), vec![1e6]);
        assert!(parse_rates("").is_err());
        assert!(parse_rates("fast").is_err());
        assert!(parse_rates("0").is_err());
        assert!(parse_rates("-5").is_err());
        assert!(parse_rates("inf").is_err());
    }

    #[test]
    fn from_sweep_takes_the_first_grid_cell() {
        let sweep = SweepSpec::default();
        let lg = LoadGenSpec::from_sweep(&sweep, vec![1e5]).unwrap();
        let first = sweep.scenarios().unwrap().into_iter().next().unwrap();
        assert_eq!(lg.spec, first.spec);
        assert_eq!(lg.channels, first.channels);
        assert_eq!(lg.chunk_lines, ENCODE_BATCH);
        assert!(LoadGenSpec::from_sweep(&sweep, vec![]).is_err());
    }

    #[test]
    fn spec_validation_rejects_bad_knobs() {
        assert!(quick_spec(vec![1e5]).validate().is_ok());
        assert!(quick_spec(vec![]).validate().is_err());
        assert!(quick_spec(vec![0.0]).validate().is_err());
        assert!(quick_spec(vec![f64::INFINITY]).validate().is_err());
        let mut bad = quick_spec(vec![1e5]);
        bad.chunk_lines = 0;
        assert!(bad.validate().is_err());
        let mut bad = quick_spec(vec![1e5]);
        bad.jitter_frac = 1.5;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn loadgen_runs_a_step_per_rate_with_identical_content_figures() {
        // Huge offered rates → every arrival is already due, no
        // sleeping: the test runs at full speed.
        let spec = quick_spec(vec![1e12, 2e12]);
        let trace = Trace::from_bytes(synthetic_trace(16384, 7));
        let report = run_loadgen(&spec, &trace).unwrap();
        assert_eq!(report.steps.len(), 2);
        assert_eq!(report.label, "BDE@2ch");
        for st in &report.steps {
            assert_eq!(st.lines, trace.line_count());
            assert_eq!(st.chunks, trace.line_count().div_ceil(64));
            assert!(st.wall_s > 0.0);
            assert!(st.achieved_lines_per_sec > 0.0);
            assert_eq!(st.telemetry.shards.len(), 2);
            assert!(st.telemetry.shards.iter().any(|sh| sh.service_count > 0));
        }
        // Pacing changes arrival times, never content: both steps (and
        // a plain closed-loop session run) agree on every energy count.
        assert_eq!(report.steps[0].counts, report.steps[1].counts);
        let closed = Session::builder()
            .codec(spec.spec.clone())
            .channels(spec.channels)
            .traffic(TrafficClass::Approximate)
            .execution(Execution::Sharded)
            .build()
            .unwrap()
            .run(&trace)
            .unwrap();
        assert_eq!(report.steps[0].counts, closed.counts);
    }

    #[test]
    fn loadgen_json_carries_the_grep_keys() {
        let spec = quick_spec(vec![1e12]);
        let trace = Trace::from_bytes(synthetic_trace(8192, 3));
        let report = run_loadgen(&spec, &trace).unwrap();
        let text = report.to_json().to_pretty();
        for key in [
            "\"offered_lines_per_sec\"",
            "\"achieved_lines_per_sec\"",
            "\"service_p50_ns\"",
            "\"service_p95_ns\"",
            "\"service_p99_ns\"",
            "\"peak_mailbox_depth\"",
            "\"blocked_sends\"",
            "\"telemetry\"",
        ] {
            assert!(text.contains(key), "missing {key}");
        }
        let table = report.render_table();
        assert!(table.contains("svc p99"), "{table}");
        assert!(table.contains("peak mbox"), "{table}");
    }
}
