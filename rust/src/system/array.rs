//! Sharded channel array: N independent 8-chip channels behind bounded
//! chunk mailboxes, one service-loop worker thread per shard.
//!
//! Address interleaving is round-robin at cache-line granularity: line
//! `l` lands on shard `l % shards` ([`shard_of_line`]). Each shard owns
//! its own codecs (data tables) and [`ChipChannel`] line state, so its
//! behaviour over its subsequence is bit-identical to a single-channel
//! [`simulate_lines`](crate::coordinator::simulate_lines) run on that
//! subsequence — the shard worker is the same batch encode → transmit →
//! record → decode path, just fed from a mailbox of boxed
//! [`ENCODE_BATCH`]-line chunks instead of a slice.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;

use crate::channel::{EnergyCounts, CHIPS};
use crate::encoding::{ChipLane, Codec, EncodeStats, ZacConfig, ENCODE_BATCH};
use crate::faults::{FaultModel, FaultSpec, FaultStats};
use crate::trace::{chip_words_to_bytes, gather_chip_lane, ChipWords};
use crate::util::table::TextTable;

/// The shard a cache line lands on under round-robin interleaving.
#[inline]
pub fn shard_of_line(line: usize, shards: usize) -> usize {
    line % shards
}

/// One mailbox element: a boxed block of cache lines plus approx flags.
type ShardChunk = (Box<[ChipWords]>, Box<[bool]>);

/// What a shard worker hands back: its decoded lines (in shard-local
/// order), channel-wide energy counts, encode and fault statistics.
type ShardResult = (Vec<ChipWords>, EnergyCounts, EncodeStats, FaultStats);

/// Per-shard slice of the system report.
#[derive(Clone, Debug)]
pub struct ShardReport {
    /// Cache lines this shard served.
    pub lines: usize,
    /// Energy counts summed over the shard's 8 chips.
    pub counts: EnergyCounts,
    /// Encode statistics summed over the shard's 8 chips.
    pub stats: EncodeStats,
    /// Fault-injection statistics summed over the shard's 8 chips.
    pub faults: FaultStats,
}

/// Result of a channel-array run: the reassembled receiver-side stream
/// plus system-level (merged) and per-shard statistics.
#[derive(Clone, Debug)]
pub struct SystemOutput {
    /// Receiver-side byte stream, de-interleaved back into trace order.
    pub bytes: Vec<u8>,
    /// System-wide energy counts (merged over shards).
    pub counts: EnergyCounts,
    /// System-wide encode statistics (merged over shards).
    pub stats: EncodeStats,
    /// System-wide fault-injection statistics (merged over shards).
    pub faults: FaultStats,
    /// Per-shard breakdown, indexed by shard id.
    pub shards: Vec<ShardReport>,
}

impl SystemOutput {
    /// Render the system-level report: one row per shard + the merged
    /// totals (the table `examples/e2e_pipeline.rs` prints).
    pub fn report(&self) -> String {
        let mut t = TextTable::new(&["shard", "lines", "transfers", "term 1s", "switching"]);
        for (i, s) in self.shards.iter().enumerate() {
            t.row(vec![
                format!("{i}"),
                format!("{}", s.lines),
                format!("{}", s.counts.transfers),
                format!("{}", s.counts.termination_ones),
                format!("{}", s.counts.switching_transitions),
            ]);
        }
        t.row(vec![
            "TOTAL".into(),
            format!("{}", self.shards.iter().map(|s| s.lines).sum::<usize>()),
            format!("{}", self.counts.transfers),
            format!("{}", self.counts.termination_ones),
            format!("{}", self.counts.switching_transitions),
        ]);
        let faults = if self.faults.injected_bits > 0 {
            format!(
                "\nfaults: {} bits flipped in {} transfers (BER {:.2e}), \
                 end-to-end error {:.2e} bits/bit",
                self.faults.injected_bits,
                self.faults.injected_words,
                self.faults.injected_ber(),
                self.faults.observed_error_rate()
            )
        } else {
            String::new()
        };
        format!(
            "system report: {} channel(s), unencoded {:.1}%\n{}{}",
            self.shards.len(),
            100.0 * self.stats.unencoded_fraction(),
            t.render(),
            faults
        )
    }
}

/// N independent 8-chip channels fed by round-robin address interleaving.
///
/// `push_line` routes each line to its shard's pending buffer; full
/// [`ENCODE_BATCH`]-line chunks ship to that shard's bounded mailbox
/// (blocking when the shard is behind — per-shard backpressure, exactly
/// the memory controller's per-channel write queue). `finish` drains the
/// tails, joins every worker and merges the per-shard stats.
pub struct ChannelArray {
    senders: Vec<SyncSender<ShardChunk>>,
    workers: Vec<JoinHandle<ShardResult>>,
    /// Per-shard lines + approx flags awaiting the next chunk flush.
    pending: Vec<(Vec<ChipWords>, Vec<bool>)>,
    lines_pushed: usize,
}

impl ChannelArray {
    /// Spawn `shards` service-loop workers, all chips on one shard
    /// sharing `cfg`. `capacity` is the mailbox depth in lines (rounded
    /// up to whole chunks).
    pub fn new(cfg: &ZacConfig, shards: usize, capacity: usize) -> ChannelArray {
        let cfgs: Vec<ZacConfig> = (0..CHIPS).map(|_| cfg.clone()).collect();
        Self::with_chip_configs(&cfgs, shards, capacity)
    }

    /// Spawn the array with a distinct configuration per chip (same 8
    /// configs on every shard) — the multi-channel analogue of
    /// [`simulate_lines_per_chip`](crate::coordinator::simulate_lines_per_chip).
    pub fn with_chip_configs(cfgs: &[ZacConfig], shards: usize, capacity: usize) -> ChannelArray {
        assert_eq!(cfgs.len(), CHIPS);
        assert!(shards >= 1, "channel array needs at least one shard");
        let sets = (0..shards)
            .map(|_| cfgs.iter().map(Codec::from_config).collect())
            .collect();
        Self::with_codec_sets(sets, capacity)
    }

    /// Spawn the array around pre-built codecs over a perfect channel:
    /// one `Vec<Codec>` (one codec per chip) per shard — the
    /// registry-driven construction path legacy callers use, and the
    /// seam out-of-tree schemes shard through.
    pub fn with_codec_sets(codec_sets: Vec<Vec<Codec>>, capacity: usize) -> ChannelArray {
        Self::with_codec_sets_and_faults(codec_sets, capacity, &FaultSpec::perfect())
    }

    /// Spawn the array with every (shard, chip) lane's wire running
    /// through the fault model `fault_spec` describes — what
    /// [`Session`](crate::session::Session) uses for sharded runs. Each
    /// lane derives its own decorrelated injection stream from the base
    /// seed, so runs are reproducible at any shard count.
    pub fn with_codec_sets_and_faults(
        codec_sets: Vec<Vec<Codec>>,
        capacity: usize,
        fault_spec: &FaultSpec,
    ) -> ChannelArray {
        let shards = codec_sets.len();
        assert!(shards >= 1, "channel array needs at least one shard");
        let chunk_capacity = capacity.div_ceil(ENCODE_BATCH).max(1);
        let mut senders = Vec::with_capacity(shards);
        let mut workers = Vec::with_capacity(shards);
        for (s, codecs) in codec_sets.into_iter().enumerate() {
            assert_eq!(codecs.len(), CHIPS, "each shard needs one codec per chip");
            let models: Vec<Box<dyn FaultModel>> =
                (0..CHIPS).map(|j| fault_spec.build(s, j)).collect();
            let (tx, rx): (SyncSender<ShardChunk>, Receiver<ShardChunk>) =
                sync_channel(chunk_capacity);
            workers.push(std::thread::spawn(move || {
                shard_service_loop(codecs, models, rx)
            }));
            senders.push(tx);
        }
        ChannelArray {
            senders,
            workers,
            pending: (0..shards)
                .map(|_| (Vec::with_capacity(ENCODE_BATCH), Vec::with_capacity(ENCODE_BATCH)))
                .collect(),
            lines_pushed: 0,
        }
    }

    /// Number of shards (channels) in the array.
    pub fn shards(&self) -> usize {
        self.senders.len()
    }

    /// Lines accepted so far.
    pub fn lines_pushed(&self) -> usize {
        self.lines_pushed
    }

    /// Route one cache line to its shard (blocks when that shard's
    /// mailbox is full).
    pub fn push_line(&mut self, line: ChipWords, approx: bool) {
        let s = shard_of_line(self.lines_pushed, self.shards());
        self.lines_pushed += 1;
        let (lines, flags) = &mut self.pending[s];
        lines.push(line);
        flags.push(approx);
        if lines.len() == ENCODE_BATCH {
            self.flush_shard(s);
        }
    }

    /// Ship shard `s`'s pending lines as one boxed chunk.
    fn flush_shard(&mut self, s: usize) {
        let (lines, flags) = &mut self.pending[s];
        if lines.is_empty() {
            return;
        }
        let chunk: Box<[ChipWords]> =
            std::mem::replace(lines, Vec::with_capacity(ENCODE_BATCH)).into_boxed_slice();
        let approx: Box<[bool]> =
            std::mem::replace(flags, Vec::with_capacity(ENCODE_BATCH)).into_boxed_slice();
        // A failed send means the shard worker died (receiver dropped);
        // keep accepting traffic so the healthy shards drain normally —
        // `finish` joins every worker and surfaces the original panic.
        let _ = self.senders[s].send((chunk, approx));
    }

    /// Close the mailboxes, join every worker, merge the shard results
    /// and de-interleave the decoded stream back into trace order.
    ///
    /// If a shard worker panicked, every other worker is still joined
    /// (drained) first, then the original panic payload is re-raised —
    /// no sibling threads are leaked and the root cause is what the
    /// caller sees.
    pub fn finish(mut self, byte_len: usize) -> SystemOutput {
        for s in 0..self.shards() {
            self.flush_shard(s);
        }
        let ChannelArray {
            senders,
            workers,
            lines_pushed,
            ..
        } = self;
        drop(senders);
        let shards = workers.len();
        let results = crate::util::par::join_all_reraise(workers);

        // De-interleave: line l of the trace is entry l / shards of
        // shard l % shards.
        let mut out_lines = vec![[0u64; CHIPS]; lines_pushed];
        let mut reports = Vec::with_capacity(shards);
        let mut counts = EnergyCounts::default();
        let mut stats = EncodeStats::default();
        let mut faults = FaultStats::default();
        for (s, (decoded, c, st, f)) in results.into_iter().enumerate() {
            debug_assert_eq!(decoded.len(), (lines_pushed + shards - 1 - s) / shards);
            for (i, line) in decoded.iter().enumerate() {
                out_lines[i * shards + s] = *line;
            }
            counts.merge(&c);
            stats.merge(&st);
            faults.merge(&f);
            reports.push(ShardReport {
                lines: decoded.len(),
                counts: c,
                stats: st,
                faults: f,
            });
        }
        SystemOutput {
            bytes: chip_words_to_bytes(&out_lines, byte_len),
            counts,
            stats,
            faults,
            shards: reports,
        }
    }

    /// Batch driver: run a whole pre-split trace through a fresh array.
    pub fn run(
        cfg: &ZacConfig,
        shards: usize,
        lines: &[ChipWords],
        approx: bool,
        byte_len: usize,
    ) -> SystemOutput {
        let mut array = ChannelArray::new(cfg, shards, 4 * ENCODE_BATCH);
        for l in lines {
            array.push_line(*l, approx);
        }
        array.finish(byte_len)
    }
}

/// The per-shard service loop: receive boxed line chunks until the
/// mailbox closes, driving all 8 chips of this shard's channel through
/// the one shared [`ChipLane`] drive loop (per-batch lane gather, no
/// stream clones), each chip's wire through its own fault model.
fn shard_service_loop(
    codecs: Vec<Codec>,
    models: Vec<Box<dyn FaultModel>>,
    rx: Receiver<ShardChunk>,
) -> ShardResult {
    let mut lanes: Vec<ChipLane> = codecs
        .into_iter()
        .zip(models)
        .map(|(codec, m)| ChipLane::with_faults(codec, 0, m))
        .collect();
    let mut words = [0u64; ENCODE_BATCH];
    while let Ok((lines, approx)) = rx.recv() {
        for (lc, ac) in lines.chunks(ENCODE_BATCH).zip(approx.chunks(ENCODE_BATCH)) {
            let n = lc.len();
            for (j, lane) in lanes.iter_mut().enumerate() {
                gather_chip_lane(lc, j, &mut words[..n]);
                lane.drive(&words[..n], &ac[..n]);
            }
        }
    }
    let nlines = lanes[0].decoded_len();
    let mut lines_out = vec![[0u64; CHIPS]; nlines];
    let mut counts = EnergyCounts::default();
    let mut stats = EncodeStats::default();
    let mut faults = FaultStats::default();
    for (j, lane) in lanes.into_iter().enumerate() {
        let (decoded, c, s, f) = lane.finish();
        debug_assert_eq!(decoded.len(), nlines);
        for (l, w) in decoded.into_iter().enumerate() {
            lines_out[l][j] = w;
        }
        counts.merge(&c);
        stats.merge(&s);
        faults.merge(&f);
    }
    (lines_out, counts, stats, faults)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{simulate_bytes, simulate_lines};
    use crate::encoding::Scheme;
    use crate::system::scenario::synthetic_trace as image_like;
    use crate::trace::bytes_to_chip_words;

    #[test]
    fn round_robin_interleaving() {
        for l in 0..16 {
            assert_eq!(shard_of_line(l, 1), 0);
            assert_eq!(shard_of_line(l, 4), l % 4);
        }
    }

    #[test]
    fn single_shard_is_bit_identical_to_single_channel_path() {
        let bytes = image_like(300 * 64 + 32, 31);
        let lines = bytes_to_chip_words(&bytes);
        let cfg = ZacConfig::zac_full(75, 1, 1);
        let reference = simulate_bytes(&cfg, &bytes, true);
        let out = ChannelArray::run(&cfg, 1, &lines, true, bytes.len());
        assert_eq!(out.bytes, reference.bytes);
        assert_eq!(out.counts, reference.counts);
        assert_eq!(out.stats, reference.stats);
        assert_eq!(out.shards.len(), 1);
        assert_eq!(out.shards[0].lines, lines.len());
    }

    #[test]
    fn multi_shard_matches_merged_per_shard_reference() {
        // Each shard owns its own tables + line state, so the array must
        // equal N independent single-channel runs over the interleaved
        // subsequences, merged (the integration property test widens
        // this over random traces).
        let bytes = image_like(550 * 64, 33);
        let lines = bytes_to_chip_words(&bytes);
        let cfg = ZacConfig::zac(80);
        for shards in [2usize, 4] {
            let out = ChannelArray::run(&cfg, shards, &lines, true, bytes.len());
            let mut counts = EnergyCounts::default();
            let mut stats = EncodeStats::default();
            let mut ref_lines = vec![[0u64; CHIPS]; lines.len()];
            for s in 0..shards {
                let sub: Vec<_> = lines.iter().skip(s).step_by(shards).copied().collect();
                let r = simulate_lines(&cfg, &sub, true, sub.len() * 64);
                counts.merge(&r.counts);
                stats.merge(&r.stats);
                assert_eq!(out.shards[s].counts, r.counts, "shard {s}");
                assert_eq!(out.shards[s].stats, r.stats, "shard {s}");
                for (i, l) in bytes_to_chip_words(&r.bytes).iter().enumerate() {
                    ref_lines[i * shards + s] = *l;
                }
            }
            assert_eq!(out.counts, counts, "{shards} shards");
            assert_eq!(out.stats, stats, "{shards} shards");
            assert_eq!(out.bytes, chip_words_to_bytes(&ref_lines, bytes.len()));
        }
    }

    #[test]
    fn exact_schemes_lossless_for_every_shard_count() {
        let bytes = image_like(4096, 35);
        let lines = bytes_to_chip_words(&bytes);
        for scheme in [Scheme::Org, Scheme::Dbi, Scheme::BdeOrg, Scheme::Bde] {
            for shards in 1..=4 {
                let out =
                    ChannelArray::run(&ZacConfig::scheme(scheme), shards, &lines, true, bytes.len());
                assert_eq!(out.bytes, bytes, "{scheme:?} x{shards}");
                assert_eq!(out.stats.total(), lines.len() as u64 * CHIPS as u64);
            }
        }
    }

    #[test]
    fn shard_line_counts_cover_the_stream() {
        let bytes = image_like(103 * 64, 37);
        let lines = bytes_to_chip_words(&bytes);
        let out = ChannelArray::run(&ZacConfig::zac(80), 4, &lines, true, bytes.len());
        let total: usize = out.shards.iter().map(|s| s.lines).sum();
        assert_eq!(total, lines.len());
        // 103 = 4*25 + 3: shards 0..3 get 26,26,26,25.
        assert_eq!(
            out.shards.iter().map(|s| s.lines).collect::<Vec<_>>(),
            vec![26, 26, 26, 25]
        );
        assert!(out.report().contains("TOTAL"));
    }

    #[test]
    fn empty_stream_yields_empty_output() {
        let out = ChannelArray::run(&ZacConfig::zac(80), 3, &[], true, 0);
        assert!(out.bytes.is_empty());
        assert_eq!(out.stats.total(), 0);
        assert_eq!(out.shards.len(), 3);
    }
}
