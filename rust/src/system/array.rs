//! Sharded channel array: N independent 8-chip channels behind bounded
//! chunk mailboxes, one service-loop worker thread per shard.
//!
//! Line placement is a pluggable [`AddressMap`] policy (see
//! [`super::address`]): round-robin interleaving (the default, pinned
//! bit-identical to the v1 array), capacity-weighted interleaving, or
//! locality steering. Each shard owns its own codecs (data tables) and
//! [`ChipChannel`](crate::channel::ChipChannel) line state, so its
//! behaviour over its subsequence is bit-identical to a single-channel
//! [`simulate_lines`](crate::coordinator::simulate_lines) run on that
//! subsequence — the shard worker is the same batch encode → transmit →
//! record → decode path, fed from a mailbox of reference-counted
//! [`LineChunk`] views (up to [`ENCODE_BATCH`] lines each) instead of
//! owned boxed copies.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::channel::{EnergyCounts, CHIPS};
use crate::encoding::{ChipLane, Codec, EncodeStats, ZacConfig, ENCODE_BATCH};
use crate::faults::{FaultModel, FaultSpec, FaultStats};
use crate::obs::{MetricsRegistry, ShardMetrics, TelemetrySnapshot};
use crate::system::address::{AddressMap, AddressSpec, Inverse, PageHeat};
use crate::trace::{chip_words_to_bytes, ChipWords, LineChunk};
use crate::util::table::TextTable;

/// The shard a cache line lands on under round-robin interleaving.
#[inline]
pub fn shard_of_line(line: usize, shards: usize) -> usize {
    line % shards
}

/// What a shard worker hands back: its decoded lines (in shard-local
/// order), channel-wide energy counts, encode and fault statistics.
type ShardResult = (Vec<ChipWords>, EnergyCounts, EncodeStats, FaultStats);

/// Per-shard slice of the system report.
#[derive(Clone, Debug)]
pub struct ShardReport {
    /// Cache lines this shard served.
    pub lines: usize,
    /// Energy counts summed over the shard's 8 chips.
    pub counts: EnergyCounts,
    /// Encode statistics summed over the shard's 8 chips.
    pub stats: EncodeStats,
    /// Fault-injection statistics summed over the shard's 8 chips.
    pub faults: FaultStats,
}

/// Load-balance metric over a set of shard reports: max/mean lines per
/// shard (1.0 = perfectly balanced; higher = hotter hottest shard).
pub fn load_imbalance(shards: &[ShardReport]) -> f64 {
    let total: usize = shards.iter().map(|s| s.lines).sum();
    if total == 0 || shards.is_empty() {
        return 1.0;
    }
    let mean = total as f64 / shards.len() as f64;
    shards.iter().map(|s| s.lines).max().unwrap_or(0) as f64 / mean
}

/// Result of a channel-array run: the reassembled receiver-side stream
/// plus system-level (merged) and per-shard statistics.
#[derive(Clone, Debug)]
pub struct SystemOutput {
    /// Receiver-side byte stream, de-interleaved back into trace order.
    pub bytes: Vec<u8>,
    /// System-wide energy counts (merged over shards).
    pub counts: EnergyCounts,
    /// System-wide encode statistics (merged over shards).
    pub stats: EncodeStats,
    /// System-wide fault-injection statistics (merged over shards).
    pub faults: FaultStats,
    /// Per-shard breakdown, indexed by shard id.
    pub shards: Vec<ShardReport>,
    /// Telemetry snapshot (stage timings, mailbox backpressure,
    /// service latency); `None` when telemetry was off for the run.
    pub telemetry: Option<TelemetrySnapshot>,
}

impl SystemOutput {
    /// Max/mean lines per shard (1.0 = perfectly balanced).
    pub fn load_imbalance(&self) -> f64 {
        load_imbalance(&self.shards)
    }

    /// Render the system-level report: one row per shard + the merged
    /// totals (the table `examples/e2e_pipeline.rs` prints). Per-shard
    /// `DataTable` hit rates and the load-balance line make the effect
    /// of the address-mapping policy visible.
    pub fn report(&self) -> String {
        let mut t = TextTable::new(&[
            "shard",
            "lines",
            "transfers",
            "term 1s",
            "switching",
            "tbl hit",
        ]);
        for (i, s) in self.shards.iter().enumerate() {
            t.row(vec![
                format!("{i}"),
                format!("{}", s.lines),
                format!("{}", s.counts.transfers),
                format!("{}", s.counts.termination_ones),
                format!("{}", s.counts.switching_transitions),
                format!("{:.1}%", 100.0 * s.stats.table_hit_rate()),
            ]);
        }
        t.row(vec![
            "TOTAL".into(),
            format!("{}", self.shards.iter().map(|s| s.lines).sum::<usize>()),
            format!("{}", self.counts.transfers),
            format!("{}", self.counts.termination_ones),
            format!("{}", self.counts.switching_transitions),
            format!("{:.1}%", 100.0 * self.stats.table_hit_rate()),
        ]);
        let faults = if self.faults.injected_bits > 0 {
            let corrections = if self.faults.corrected_bits > 0
                || self.faults.detected_bits > 0
            {
                format!(
                    ", corrected {} / detected {} / residual {}",
                    self.faults.corrected_bits,
                    self.faults.detected_bits,
                    self.faults.residual_error_bits
                )
            } else {
                String::new()
            };
            format!(
                "\nfaults: {} bits flipped in {} transfers (BER {:.2e}), \
                 end-to-end error {:.2e} bits/bit{corrections}",
                self.faults.injected_bits,
                self.faults.injected_words,
                self.faults.injected_ber(),
                self.faults.observed_error_rate()
            )
        } else {
            String::new()
        };
        let telemetry = match &self.telemetry {
            Some(t) => format!("\n{}", t.render_table()),
            None => String::new(),
        };
        format!(
            "system report: {} channel(s), unencoded {:.1}%, load imbalance {:.2}x\n{}{}{}",
            self.shards.len(),
            100.0 * self.stats.unencoded_fraction(),
            self.load_imbalance(),
            t.render(),
            faults,
            telemetry
        )
    }
}

/// A shard's lines awaiting the next chunk flush: either owned copies
/// (streaming `push_line`) or indices into a shared store (the
/// zero-copy `push_store` path).
enum Pending {
    Copied {
        lines: Vec<ChipWords>,
        flags: Vec<bool>,
    },
    Indexed {
        store: Arc<[ChipWords]>,
        indices: Vec<u32>,
        approx: bool,
    },
}

impl Pending {
    fn is_empty(&self) -> bool {
        match self {
            Pending::Copied { lines, .. } => lines.is_empty(),
            Pending::Indexed { indices, .. } => indices.is_empty(),
        }
    }
}

/// N independent 8-chip channels fed by an [`AddressMap`] placement
/// policy (round-robin by default).
///
/// `push_line` routes each line to its shard's pending buffer; full
/// [`ENCODE_BATCH`]-line chunks ship to that shard's bounded mailbox
/// (blocking when the shard is behind — per-shard backpressure, exactly
/// the memory controller's per-channel write queue). `push_store` is the
/// zero-copy bulk path: lines stay in the shared store and each shard
/// receives an indexed [`LineChunk`] view. `finish` drains the tails,
/// joins every worker and merges the per-shard stats.
pub struct ChannelArray {
    senders: Vec<SyncSender<LineChunk>>,
    workers: Vec<JoinHandle<ShardResult>>,
    map: Box<dyn AddressMap>,
    heat: PageHeat,
    /// Per-shard lines awaiting the next chunk flush.
    pending: Vec<Option<Pending>>,
    /// Shard routed per line, in push order — the recorded inverse the
    /// receiver de-interleaves with (`None` under the analytic
    /// round-robin inverse).
    routes: Option<Vec<u32>>,
    lines_pushed: usize,
    /// Per-shard telemetry (disabled registries record nothing).
    metrics: MetricsRegistry,
    /// Mailbox depth in chunks — the depth gauge saturates here.
    chunk_capacity: usize,
}

impl ChannelArray {
    /// Spawn `shards` service-loop workers, all chips on one shard
    /// sharing `cfg`. `capacity` is the mailbox depth in lines (rounded
    /// up to whole chunks).
    pub fn new(cfg: &ZacConfig, shards: usize, capacity: usize) -> ChannelArray {
        let cfgs: Vec<ZacConfig> = (0..CHIPS).map(|_| cfg.clone()).collect();
        Self::with_chip_configs(&cfgs, shards, capacity)
    }

    /// Spawn the array with a distinct configuration per chip (same 8
    /// configs on every shard) — the multi-channel analogue of
    /// [`simulate_lines_per_chip`](crate::coordinator::simulate_lines_per_chip).
    pub fn with_chip_configs(cfgs: &[ZacConfig], shards: usize, capacity: usize) -> ChannelArray {
        assert_eq!(cfgs.len(), CHIPS);
        assert!(shards >= 1, "channel array needs at least one shard");
        let sets = (0..shards)
            .map(|_| cfgs.iter().map(Codec::from_config).collect())
            .collect();
        Self::with_codec_sets(sets, capacity)
    }

    /// Spawn the array around pre-built codecs over a perfect channel
    /// with round-robin placement: one `Vec<Codec>` (one codec per chip)
    /// per shard — the registry-driven construction path legacy callers
    /// use, and the seam out-of-tree schemes shard through.
    pub fn with_codec_sets(codec_sets: Vec<Vec<Codec>>, capacity: usize) -> ChannelArray {
        Self::with_codec_sets_and_faults(codec_sets, capacity, &FaultSpec::perfect())
    }

    /// Round-robin array with every (shard, chip) lane's wire running
    /// through the fault model `fault_spec` describes.
    pub fn with_codec_sets_and_faults(
        codec_sets: Vec<Vec<Codec>>,
        capacity: usize,
        fault_spec: &FaultSpec,
    ) -> ChannelArray {
        Self::with_codec_sets_faults_and_address(
            codec_sets,
            capacity,
            fault_spec,
            &AddressSpec::round_robin(),
        )
    }

    /// The fully-general constructor: pre-built codecs, fault model and
    /// address-mapping policy — what [`Session`](crate::session::Session)
    /// uses for sharded runs. Each lane derives its own decorrelated
    /// injection stream from the base seed, so runs are reproducible at
    /// any shard count; the address map decides which shard serves each
    /// line and how the receiver de-interleaves.
    pub fn with_codec_sets_faults_and_address(
        codec_sets: Vec<Vec<Codec>>,
        capacity: usize,
        fault_spec: &FaultSpec,
        address: &AddressSpec,
    ) -> ChannelArray {
        Self::with_codec_sets_faults_address_and_telemetry(
            codec_sets,
            capacity,
            fault_spec,
            address,
            false,
        )
    }

    /// [`with_codec_sets_faults_and_address`](Self::with_codec_sets_faults_and_address)
    /// plus the telemetry switch: when `telemetry` is on, each shard
    /// records drive-loop stage timings, mailbox depth/send-block
    /// backpressure and per-chunk service latency into a
    /// [`MetricsRegistry`], snapshotted on the [`SystemOutput`] at
    /// `finish`. Off (the default) costs nothing — no clock reads
    /// anywhere on the hot path.
    pub fn with_codec_sets_faults_address_and_telemetry(
        codec_sets: Vec<Vec<Codec>>,
        capacity: usize,
        fault_spec: &FaultSpec,
        address: &AddressSpec,
        telemetry: bool,
    ) -> ChannelArray {
        let shards = codec_sets.len();
        assert!(shards >= 1, "channel array needs at least one shard");
        let map = address.build(shards);
        debug_assert_eq!(map.shards(), shards);
        let chunk_capacity = capacity.div_ceil(ENCODE_BATCH).max(1);
        let metrics = MetricsRegistry::new(telemetry, shards);
        let mut senders = Vec::with_capacity(shards);
        let mut workers = Vec::with_capacity(shards);
        for (s, codecs) in codec_sets.into_iter().enumerate() {
            assert_eq!(codecs.len(), CHIPS, "each shard needs one codec per chip");
            let models: Vec<Box<dyn FaultModel>> =
                (0..CHIPS).map(|j| fault_spec.build(s, j)).collect();
            let sm = metrics.shard(s).clone();
            let (tx, rx): (SyncSender<LineChunk>, Receiver<LineChunk>) =
                sync_channel(chunk_capacity);
            workers.push(std::thread::spawn(move || {
                shard_service_loop(codecs, models, rx, sm)
            }));
            senders.push(tx);
        }
        let routes = match map.inverse() {
            Inverse::RoundRobin => None,
            Inverse::Recorded => Some(Vec::new()),
        };
        ChannelArray {
            senders,
            workers,
            heat: PageHeat::new(map.heat_slots()),
            map,
            pending: (0..shards).map(|_| None).collect(),
            routes,
            lines_pushed: 0,
            metrics,
            chunk_capacity,
        }
    }

    /// Number of shards (channels) in the array.
    pub fn shards(&self) -> usize {
        self.senders.len()
    }

    /// Lines accepted so far.
    pub fn lines_pushed(&self) -> usize {
        self.lines_pushed
    }

    /// Route the next line through the address map, returning its shard.
    fn route(&mut self, line: &ChipWords) -> usize {
        let idx = self.lines_pushed;
        let heat = self.heat.touch(idx);
        let s = self.map.shard_for(idx, line, heat);
        assert!(s < self.shards(), "address map routed to shard {s}");
        if let Some(routes) = &mut self.routes {
            routes.push(s as u32);
        }
        self.lines_pushed += 1;
        s
    }

    /// Route one cache line to its shard (blocks when that shard's
    /// mailbox is full). Copies the line into the shard's pending
    /// buffer — the streaming path; bulk callers should prefer the
    /// zero-copy [`push_store`](Self::push_store).
    pub fn push_line(&mut self, line: ChipWords, approx: bool) {
        let s = self.route(&line);
        if !matches!(self.pending[s], Some(Pending::Copied { .. })) {
            self.flush_shard(s);
            self.pending[s] = Some(Pending::Copied {
                lines: Vec::with_capacity(ENCODE_BATCH),
                flags: Vec::with_capacity(ENCODE_BATCH),
            });
        }
        let Some(Pending::Copied { lines, flags }) = &mut self.pending[s] else {
            unreachable!("pending buffer was just set to Copied");
        };
        lines.push(line);
        flags.push(approx);
        if lines.len() == ENCODE_BATCH {
            self.flush_shard(s);
        }
    }

    /// Zero-copy bulk ingestion: route every line of a shared store
    /// without copying line data — each shard's mailbox receives
    /// [`LineChunk`] index views into `store` (4 bytes per line instead
    /// of a 64-byte copy). Interleaves correctly with `push_line`.
    pub fn push_store(&mut self, store: &Arc<[ChipWords]>, approx: bool) {
        for i in 0..store.len() {
            let s = self.route(&store[i]);
            let reuse = matches!(
                &self.pending[s],
                Some(Pending::Indexed { store: st, approx: a, .. })
                    if Arc::ptr_eq(st, store) && *a == approx
            );
            if !reuse {
                self.flush_shard(s);
                self.pending[s] = Some(Pending::Indexed {
                    store: store.clone(),
                    indices: Vec::with_capacity(ENCODE_BATCH),
                    approx,
                });
            }
            let Some(Pending::Indexed { indices, .. }) = &mut self.pending[s] else {
                unreachable!("pending buffer was just set to Indexed");
            };
            indices.push(i as u32);
            if indices.len() == ENCODE_BATCH {
                self.flush_shard(s);
            }
        }
    }

    /// Route a whole replayed chunk: every line goes through the
    /// address map, then each shard receives one scatter view
    /// ([`LineChunk::subset`]) of the chunk's own backing store — the
    /// mmap replay path, where lines stay in the mapped file pages all
    /// the way to the shard workers. Interleaves correctly with
    /// `push_line`/`push_store`: a shard's pending buffer is flushed
    /// before its view ships, so per-shard arrival order always matches
    /// global push order.
    pub fn push_chunk(&mut self, chunk: &LineChunk) {
        let mut per: Vec<Vec<u32>> = vec![Vec::new(); self.shards()];
        for i in 0..chunk.len() {
            let s = self.route(chunk.line(i));
            per[s].push(i as u32);
        }
        for (s, local) in per.into_iter().enumerate() {
            if local.is_empty() {
                continue;
            }
            self.flush_shard(s);
            self.send_chunk(s, chunk.subset(&local));
        }
    }

    /// Ship shard `s`'s pending lines as one chunk.
    fn flush_shard(&mut self, s: usize) {
        let Some(pending) = self.pending[s].take() else {
            return;
        };
        if pending.is_empty() {
            return;
        }
        let chunk = match pending {
            Pending::Copied { lines, flags } => LineChunk::from_lines(lines, flags),
            Pending::Indexed {
                store,
                indices,
                approx,
            } => LineChunk::indexed(store, indices, approx),
        };
        self.send_chunk(s, chunk);
    }

    /// Send one chunk to shard `s`'s mailbox. A failed send means the
    /// shard worker died (receiver dropped mid-panic): the array stops
    /// accepting lines, joins every worker and re-raises the original
    /// shard panic right here at the call site — a dead worker can no
    /// longer silently swallow a whole chunk until `finish`.
    fn send_chunk(&mut self, s: usize, chunk: LineChunk) {
        // Backpressure accounting (deterministic: `in_flight` only
        // decreases when the worker has actually pulled a chunk, so a
        // pre-send sample equal to the mailbox capacity means this send
        // *will* block until the worker drains one).
        let sm = self.metrics.shard(s).clone();
        let blocking = sm.enabled() && {
            let depth = sm.in_flight().min(self.chunk_capacity as u64);
            sm.depth.set(depth);
            depth == self.chunk_capacity as u64
        };
        let t0 = blocking.then(Instant::now);
        if self.senders[s].send(chunk).is_err() {
            self.senders.clear();
            let workers = std::mem::take(&mut self.workers);
            crate::util::par::join_all_reraise(workers);
            panic!("shard {s} worker exited without panicking (mailbox closed)");
        }
        if let Some(t0) = t0 {
            sm.send_block_ns.add(t0.elapsed().as_nanos() as u64);
            sm.blocked_sends.add(1);
        }
        sm.chunk_sent();
    }

    /// Close the mailboxes, join every worker, merge the shard results
    /// and de-interleave the decoded stream back into trace order via
    /// the address map's inverse (closed-form for round-robin, the
    /// recorded route log otherwise).
    ///
    /// If a shard worker panicked, every other worker is still joined
    /// (drained) first, then the original panic payload is re-raised —
    /// no sibling threads are leaked and the root cause is what the
    /// caller sees.
    pub fn finish(mut self, byte_len: usize) -> SystemOutput {
        for s in 0..self.shards() {
            self.flush_shard(s);
        }
        let ChannelArray {
            senders,
            workers,
            routes,
            lines_pushed,
            metrics,
            ..
        } = self;
        drop(senders);
        let shards = workers.len();
        let results = crate::util::par::join_all_reraise(workers);
        // Snapshot after the workers joined: stage sets and service
        // histograms are complete and consistent.
        let telemetry = metrics.enabled().then(|| metrics.snapshot(lines_pushed as u64));

        let mut out_lines = vec![[0u64; CHIPS]; lines_pushed];
        match &routes {
            // Analytic round-robin inverse: line l of the trace is entry
            // l / shards of shard l % shards.
            None => {
                for (s, (decoded, ..)) in results.iter().enumerate() {
                    debug_assert_eq!(decoded.len(), (lines_pushed + shards - 1 - s) / shards);
                    for (i, line) in decoded.iter().enumerate() {
                        out_lines[i * shards + s] = *line;
                    }
                }
            }
            // Recorded inverse: walk the route log with one cursor per
            // shard.
            Some(routes) => {
                debug_assert_eq!(routes.len(), lines_pushed);
                let mut cursors = vec![0usize; shards];
                for (l, &s) in routes.iter().enumerate() {
                    let s = s as usize;
                    out_lines[l] = results[s].0[cursors[s]];
                    cursors[s] += 1;
                }
            }
        }

        let mut reports = Vec::with_capacity(shards);
        let mut counts = EnergyCounts::default();
        let mut stats = EncodeStats::default();
        let mut faults = FaultStats::default();
        for (decoded, c, st, f) in results {
            counts.merge(&c);
            stats.merge(&st);
            faults.merge(&f);
            reports.push(ShardReport {
                lines: decoded.len(),
                counts: c,
                stats: st,
                faults: f,
            });
        }
        SystemOutput {
            bytes: chip_words_to_bytes(&out_lines, byte_len),
            counts,
            stats,
            faults,
            shards: reports,
            telemetry,
        }
    }

    /// Batch driver: run a whole pre-split trace through a fresh
    /// round-robin array via the streaming (copying) path — kept as the
    /// v1-shaped reference the zero-copy path is pinned against.
    pub fn run(
        cfg: &ZacConfig,
        shards: usize,
        lines: &[ChipWords],
        approx: bool,
        byte_len: usize,
    ) -> SystemOutput {
        let mut array = ChannelArray::new(cfg, shards, 4 * ENCODE_BATCH);
        for l in lines {
            array.push_line(*l, approx);
        }
        array.finish(byte_len)
    }
}

/// The per-shard service loop: receive chunk views until the mailbox
/// closes, driving all 8 chips of this shard's channel through the one
/// shared [`ChipLane`] drive loop (per-batch lane gather straight out of
/// the shared store — no stream clones), each chip's wire through its
/// own fault model.
fn shard_service_loop(
    codecs: Vec<Codec>,
    models: Vec<Box<dyn FaultModel>>,
    rx: Receiver<LineChunk>,
    sm: Arc<ShardMetrics>,
) -> ShardResult {
    let mut lanes: Vec<ChipLane> = codecs
        .into_iter()
        .zip(models)
        .map(|(codec, m)| {
            let mut lane = ChipLane::with_faults(codec, 0, m);
            if sm.enabled() {
                lane.instrument(sm.stages.clone());
            }
            lane
        })
        .collect();
    while let Ok(chunk) = rx.recv() {
        // Acknowledge receipt first so the producer's in-flight count
        // (the depth gauge) drops as soon as the mailbox slot frees.
        sm.chunk_received();
        let t0 = sm.enabled().then(Instant::now);
        for (j, lane) in lanes.iter_mut().enumerate() {
            lane.drive_chunk(j, &chunk);
        }
        if let Some(t0) = t0 {
            sm.service.record(t0.elapsed().as_nanos() as u64);
        }
    }
    let nlines = lanes[0].decoded_len();
    let mut lines_out = vec![[0u64; CHIPS]; nlines];
    let mut counts = EnergyCounts::default();
    let mut stats = EncodeStats::default();
    let mut faults = FaultStats::default();
    for (j, lane) in lanes.into_iter().enumerate() {
        let (decoded, c, s, f) = lane.finish();
        debug_assert_eq!(decoded.len(), nlines);
        for (l, w) in decoded.into_iter().enumerate() {
            lines_out[l][j] = w;
        }
        counts.merge(&c);
        stats.merge(&s);
        faults.merge(&f);
    }
    (lines_out, counts, stats, faults)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{simulate_bytes, simulate_lines};
    use crate::encoding::Scheme;
    use crate::system::scenario::synthetic_trace as image_like;
    use crate::trace::bytes_to_chip_words;

    #[test]
    fn round_robin_interleaving() {
        for l in 0..16 {
            assert_eq!(shard_of_line(l, 1), 0);
            assert_eq!(shard_of_line(l, 4), l % 4);
        }
    }

    #[test]
    fn single_shard_is_bit_identical_to_single_channel_path() {
        let bytes = image_like(300 * 64 + 32, 31);
        let lines = bytes_to_chip_words(&bytes);
        let cfg = ZacConfig::zac_full(75, 1, 1);
        let reference = simulate_bytes(&cfg, &bytes, true);
        let out = ChannelArray::run(&cfg, 1, &lines, true, bytes.len());
        assert_eq!(out.bytes, reference.bytes);
        assert_eq!(out.counts, reference.counts);
        assert_eq!(out.stats, reference.stats);
        assert_eq!(out.shards.len(), 1);
        assert_eq!(out.shards[0].lines, lines.len());
    }

    #[test]
    fn multi_shard_matches_merged_per_shard_reference() {
        // Each shard owns its own tables + line state, so the array must
        // equal N independent single-channel runs over the interleaved
        // subsequences, merged (the integration property test widens
        // this over random traces).
        let bytes = image_like(550 * 64, 33);
        let lines = bytes_to_chip_words(&bytes);
        let cfg = ZacConfig::zac(80);
        for shards in [2usize, 4] {
            let out = ChannelArray::run(&cfg, shards, &lines, true, bytes.len());
            let mut counts = EnergyCounts::default();
            let mut stats = EncodeStats::default();
            let mut ref_lines = vec![[0u64; CHIPS]; lines.len()];
            for s in 0..shards {
                let sub: Vec<_> = lines.iter().skip(s).step_by(shards).copied().collect();
                let r = simulate_lines(&cfg, &sub, true, sub.len() * 64);
                counts.merge(&r.counts);
                stats.merge(&r.stats);
                assert_eq!(out.shards[s].counts, r.counts, "shard {s}");
                assert_eq!(out.shards[s].stats, r.stats, "shard {s}");
                for (i, l) in bytes_to_chip_words(&r.bytes).iter().enumerate() {
                    ref_lines[i * shards + s] = *l;
                }
            }
            assert_eq!(out.counts, counts, "{shards} shards");
            assert_eq!(out.stats, stats, "{shards} shards");
            assert_eq!(out.bytes, chip_words_to_bytes(&ref_lines, bytes.len()));
        }
    }

    #[test]
    fn push_store_is_bit_identical_to_push_line() {
        // The zero-copy bulk path must equal the streaming copy path for
        // every address policy — chunk representation (window / indexed
        // / owned) must never leak into results.
        let bytes = image_like(550 * 64 + 16, 39);
        let store: Arc<[ChipWords]> = bytes_to_chip_words(&bytes).into();
        let cfg = ZacConfig::zac(80);
        for address in [
            AddressSpec::round_robin(),
            AddressSpec::capacity(vec![2, 1]),
            AddressSpec::steer(),
        ] {
            for shards in [1usize, 3] {
                let build = |addr: &AddressSpec| {
                    let sets = (0..shards)
                        .map(|_| (0..CHIPS).map(|_| Codec::from_config(&cfg)).collect())
                        .collect();
                    ChannelArray::with_codec_sets_faults_and_address(
                        sets,
                        ENCODE_BATCH,
                        &FaultSpec::perfect(),
                        addr,
                    )
                };
                let mut streamed = build(&address);
                for l in store.iter() {
                    streamed.push_line(*l, true);
                }
                let a = streamed.finish(bytes.len());
                let mut bulk = build(&address);
                bulk.push_store(&store, true);
                let b = bulk.finish(bytes.len());
                let label = format!("{} x{shards}", address.label());
                assert_eq!(a.bytes, b.bytes, "{label}");
                assert_eq!(a.counts, b.counts, "{label}");
                assert_eq!(a.stats, b.stats, "{label}");
                for (x, y) in a.shards.iter().zip(&b.shards) {
                    assert_eq!(x.lines, y.lines, "{label}");
                    assert_eq!(x.stats, y.stats, "{label}");
                }
            }
        }
    }

    #[test]
    fn push_chunk_is_bit_identical_to_push_line() {
        // The replay ingestion path: whole chunks of irregular sizes
        // (what a recorded trace's frames look like) routed per chunk
        // must equal the streaming per-line path for every address
        // policy — per-shard subset views must preserve arrival order.
        let bytes = image_like(410 * 64 + 24, 51);
        let store: Arc<[ChipWords]> = bytes_to_chip_words(&bytes).into();
        let cfg = ZacConfig::zac(80);
        let spans = [0usize, 300, 301, 341, store.len()];
        for address in [AddressSpec::round_robin(), AddressSpec::steer()] {
            for shards in [1usize, 3] {
                let build = |addr: &AddressSpec| {
                    let sets = (0..shards)
                        .map(|_| (0..CHIPS).map(|_| Codec::from_config(&cfg)).collect())
                        .collect();
                    ChannelArray::with_codec_sets_faults_and_address(
                        sets,
                        ENCODE_BATCH,
                        &FaultSpec::perfect(),
                        addr,
                    )
                };
                let mut streamed = build(&address);
                for l in store.iter() {
                    streamed.push_line(*l, true);
                }
                let a = streamed.finish(bytes.len());
                let mut chunked = build(&address);
                for w in spans.windows(2) {
                    let chunk = LineChunk::window(store.clone(), w[0], w[1] - w[0], true);
                    chunked.push_chunk(&chunk);
                }
                let b = chunked.finish(bytes.len());
                let label = format!("{} x{shards}", address.label());
                assert_eq!(a.bytes, b.bytes, "{label}");
                assert_eq!(a.counts, b.counts, "{label}");
                assert_eq!(a.stats, b.stats, "{label}");
                for (x, y) in a.shards.iter().zip(&b.shards) {
                    assert_eq!(x.lines, y.lines, "{label}");
                    assert_eq!(x.stats, y.stats, "{label}");
                }
            }
        }
    }

    #[test]
    fn exact_schemes_lossless_for_every_shard_count() {
        let bytes = image_like(4096, 35);
        let lines = bytes_to_chip_words(&bytes);
        for scheme in [Scheme::Org, Scheme::Dbi, Scheme::BdeOrg, Scheme::Bde] {
            for shards in 1..=4 {
                let out =
                    ChannelArray::run(&ZacConfig::scheme(scheme), shards, &lines, true, bytes.len());
                assert_eq!(out.bytes, bytes, "{scheme:?} x{shards}");
                assert_eq!(out.stats.total(), lines.len() as u64 * CHIPS as u64);
            }
        }
    }

    #[test]
    fn shard_line_counts_cover_the_stream() {
        let bytes = image_like(103 * 64, 37);
        let lines = bytes_to_chip_words(&bytes);
        let out = ChannelArray::run(&ZacConfig::zac(80), 4, &lines, true, bytes.len());
        let total: usize = out.shards.iter().map(|s| s.lines).sum();
        assert_eq!(total, lines.len());
        // 103 = 4*25 + 3: shards 0..3 get 26,26,26,25.
        assert_eq!(
            out.shards.iter().map(|s| s.lines).collect::<Vec<_>>(),
            vec![26, 26, 26, 25]
        );
        assert!(out.report().contains("TOTAL"));
        assert!(out.report().contains("tbl hit"));
        assert!((out.load_imbalance() - 26.0 / 25.75).abs() < 1e-12);
    }

    #[test]
    fn empty_stream_yields_empty_output() {
        let out = ChannelArray::run(&ZacConfig::zac(80), 3, &[], true, 0);
        assert!(out.bytes.is_empty());
        assert_eq!(out.stats.total(), 0);
        assert_eq!(out.shards.len(), 3);
        assert_eq!(out.load_imbalance(), 1.0);
    }

    #[test]
    fn dead_shard_worker_panic_surfaces_at_the_push_site() {
        use crate::encoding::{ChipDecoder, ChipEncoder, WireWord};
        struct BoomEncoder;
        impl ChipEncoder for BoomEncoder {
            fn encode(&mut self, _word: u64, _approx: bool) -> WireWord {
                panic!("shard worker boom");
            }
            fn scheme(&self) -> Scheme {
                Scheme::Org
            }
            fn reset(&mut self) {}
        }
        struct NopDecoder;
        impl ChipDecoder for NopDecoder {
            fn decode(&mut self, wire: &WireWord) -> u64 {
                wire.data
            }
            fn reset(&mut self) {}
        }

        let sets = vec![(0..CHIPS)
            .map(|_| Codec::new(Box::new(BoomEncoder), Box::new(NopDecoder)))
            .collect()];
        let mut array = ChannelArray::with_codec_sets(sets, 1);
        // Regression (the v1 array swallowed the send error until
        // finish): pushing into a dead shard must re-raise the worker's
        // own panic at the push call site, not lose chunks silently.
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            for i in 0..64 * ENCODE_BATCH {
                array.push_line([i as u64; CHIPS], true);
            }
            array.finish(0);
        }));
        let payload = caught.expect_err("dead worker must surface a panic");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .map(String::from)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("shard worker boom"), "payload: {msg:?}");
    }
}
