//! Pluggable address mapping: which channel shard a cache line lands on.
//!
//! ZAC-DEST's savings come from the similarity between *recent transfers
//! on the same channel* — each channel's `DataTable` CAM only helps if
//! similar lines actually land on the same shard. The v1 array
//! hard-coded round-robin line interleaving, which scatters
//! spatially-similar neighborhoods across shards and dilutes per-channel
//! similarity. This layer makes the placement a policy:
//!
//! * [`RoundRobin`] — line `l` on shard `l % shards`; the default,
//!   pinned bit-identical to the v1 array by property tests.
//! * [`CapacityWeighted`] — deterministic smooth weighted round-robin
//!   for heterogeneous channels (a shard with weight 2 serves twice the
//!   lines of a weight-1 shard, interleaved as evenly as possible).
//! * [`LocalitySteer`] — hot/cold page steering: a small direct-mapped
//!   per-page heat/signature tracker routes all lines of a page — and
//!   revisits of warm pages — to one shard, and maps cold pages by a
//!   content signature (mean byte value band), so similar neighborhoods
//!   share a `DataTable` and the per-channel hit rate rises (EDEN's
//!   structural point, arXiv:1910.05340: steering data by its
//!   characteristics unlocks savings a uniform mapping cannot).
//!
//! [`AddressSpec`] is the serializable knob bag, parsed and validated
//! uniformly at every ingestion boundary (CLI `--address`, run/sweep
//! TOML, `Session::builder().address(..)`) — the addressing analogue of
//! [`FaultSpec`](crate::faults::FaultSpec) / `CodecSpec`.

use crate::trace::ChipWords;

/// Cache lines per DRAM page/row buffer (4 KiB page of 64 B lines).
pub const PAGE_LINES: usize = 64;

/// Default number of direct-mapped slots in the page trackers.
pub const DEFAULT_TRACKER_PAGES: usize = 1024;

/// How the receiver reassembles trace order from the per-shard decoded
/// streams — the inverse of the interleaving.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Inverse {
    /// Closed form: trace line `l` is entry `l / shards` of shard
    /// `l % shards`; no route log is kept.
    RoundRobin,
    /// No closed form — the sender records each line's shard and the
    /// receiver walks that route log with one cursor per shard.
    Recorded,
}

/// Deterministic placement of cache lines onto channel shards.
///
/// `shard_for` is called once per line, in trace order; `heat` is the
/// number of times the line's page has been touched so far (from the
/// array's shared [`PageHeat`] tracker). Implementations may keep
/// internal state (trackers, credit counters) but must be a pure
/// function of the call sequence — no wall-clock or OS entropy — so a
/// run is byte-for-byte reproducible.
pub trait AddressMap: Send {
    /// The shard line `line_index` (with contents `line`) lands on.
    fn shard_for(&mut self, line_index: usize, line: &ChipWords, heat: u32) -> usize;

    /// Number of shards this map routes across.
    fn shards(&self) -> usize;

    /// The de-interleaving description the receiver uses.
    fn inverse(&self) -> Inverse {
        Inverse::Recorded
    }

    /// Slot count the shared page-heat tracker should use.
    fn heat_slots(&self) -> usize {
        DEFAULT_TRACKER_PAGES
    }
}

/// Direct-mapped per-page access counter shared by every policy: the
/// `heat` argument of [`AddressMap::shard_for`] is this tracker's count
/// for the line's page (1 on first touch, saturating).
pub struct PageHeat {
    /// (page tag, touches) per slot.
    slots: Vec<(u64, u32)>,
}

impl PageHeat {
    pub fn new(slots: usize) -> PageHeat {
        PageHeat {
            slots: vec![(u64::MAX, 0); slots.max(1)],
        }
    }

    /// Record a touch of `line_index`'s page and return its heat.
    pub fn touch(&mut self, line_index: usize) -> u32 {
        let page = (line_index / PAGE_LINES) as u64;
        let slot = &mut self.slots[(page as usize) % self.slots.len()];
        if slot.0 != page {
            *slot = (page, 0);
        }
        slot.1 = slot.1.saturating_add(1);
        slot.1
    }
}

/// Round-robin line interleaving — the v1 behaviour and the default.
pub struct RoundRobin {
    shards: usize,
}

impl RoundRobin {
    pub fn new(shards: usize) -> RoundRobin {
        assert!(shards >= 1);
        RoundRobin { shards }
    }
}

impl AddressMap for RoundRobin {
    fn shard_for(&mut self, line_index: usize, _line: &ChipWords, _heat: u32) -> usize {
        super::array::shard_of_line(line_index, self.shards)
    }

    fn shards(&self) -> usize {
        self.shards
    }

    fn inverse(&self) -> Inverse {
        Inverse::RoundRobin
    }
}

/// Smooth weighted round-robin over non-uniform shard capacities: each
/// call every shard earns its weight in credit, the richest shard wins
/// the line and pays the total back. Over any `sum(weights)` consecutive
/// lines shard `s` serves exactly `weights[s]` of them, interleaved as
/// evenly as possible; with equal weights the schedule degenerates to
/// exact round-robin.
pub struct CapacityWeighted {
    weights: Vec<u32>,
    credit: Vec<i64>,
    total: i64,
}

impl CapacityWeighted {
    /// `weights` is cycled to cover `shards` entries (so a sweep can fix
    /// `capacity:2/1` while the channel-count axis varies).
    pub fn new(shards: usize, weights: &[u32]) -> CapacityWeighted {
        assert!(shards >= 1);
        assert!(!weights.is_empty() && weights.iter().all(|&w| w >= 1));
        let weights: Vec<u32> = (0..shards).map(|s| weights[s % weights.len()]).collect();
        let total = weights.iter().map(|&w| w as i64).sum();
        CapacityWeighted {
            credit: vec![0; shards],
            weights,
            total,
        }
    }
}

impl AddressMap for CapacityWeighted {
    fn shard_for(&mut self, _line_index: usize, _line: &ChipWords, _heat: u32) -> usize {
        for (c, &w) in self.credit.iter_mut().zip(&self.weights) {
            *c += w as i64;
        }
        let mut best = 0;
        for s in 1..self.credit.len() {
            if self.credit[s] > self.credit[best] {
                best = s;
            }
        }
        self.credit[best] -= self.total;
        best
    }

    fn shards(&self) -> usize {
        self.weights.len()
    }
}

/// Hot/cold page steering: a direct-mapped page → shard tracker.
///
/// * A page with a live tracker entry that has been touched before
///   (`heat > 1`) is *warm*: it stays on its shard, so all of its lines
///   — and later revisits — meet the `DataTable` history of their own
///   neighborhood (temporal locality).
/// * A *cold* (or evicted) page is routed by content: the mean byte
///   value of its first line picks one of `shards × BANDS` value bands,
///   bands cycle across shards, so pages with similar content share a
///   shard (spatial/content locality) while distinct value regions still
///   spread system-wide.
pub struct LocalitySteer {
    shards: usize,
    /// (page tag, shard) per direct-mapped slot.
    slots: Vec<(u64, u32)>,
}

impl LocalitySteer {
    /// Value bands per shard: narrow enough that one band is a genuinely
    /// similar neighborhood, wide enough that a slow-varying stream
    /// produces long same-shard runs.
    pub const BANDS: usize = 4;

    pub fn new(shards: usize, tracker_pages: usize) -> LocalitySteer {
        assert!(shards >= 1);
        LocalitySteer {
            shards,
            slots: vec![(u64::MAX, 0); tracker_pages.max(1)],
        }
    }
}

/// Mean byte value of a cache line (0..=255) — the content signature
/// cold pages are steered by.
pub fn line_signature(line: &ChipWords) -> u32 {
    let sum: u32 = line
        .iter()
        .map(|w| w.to_le_bytes().iter().map(|&b| b as u32).sum::<u32>())
        .sum();
    sum / 64
}

impl AddressMap for LocalitySteer {
    fn shard_for(&mut self, line_index: usize, line: &ChipWords, heat: u32) -> usize {
        let page = (line_index / PAGE_LINES) as u64;
        let slot = &mut self.slots[(page as usize) % self.slots.len()];
        if slot.0 == page && heat > 1 {
            return slot.1 as usize;
        }
        let band = (line_signature(line) as usize * self.shards * Self::BANDS) / 256;
        let shard = band % self.shards;
        *slot = (page, shard as u32);
        shard
    }

    fn shards(&self) -> usize {
        self.shards
    }

    fn heat_slots(&self) -> usize {
        self.slots.len()
    }
}

/// Which policy an [`AddressSpec`] builds.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AddressPolicy {
    /// Round-robin line interleaving (the v1 behaviour, default).
    RoundRobin,
    /// Smooth weighted round-robin; the weight list is cycled to the
    /// shard count at build time.
    CapacityWeighted { weights: Vec<u32> },
    /// Hot/cold page steering with a `tracker_pages`-slot page tracker.
    LocalitySteer { tracker_pages: usize },
}

/// A validated, serializable address-mapping description: the addressing
/// analogue of [`FaultSpec`](crate::faults::FaultSpec).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AddressSpec {
    pub policy: AddressPolicy,
}

impl Default for AddressSpec {
    fn default() -> Self {
        AddressSpec::round_robin()
    }
}

impl AddressSpec {
    /// The v1 round-robin interleaving (default).
    pub fn round_robin() -> AddressSpec {
        AddressSpec {
            policy: AddressPolicy::RoundRobin,
        }
    }

    /// Non-uniform shard capacities.
    pub fn capacity(weights: Vec<u32>) -> AddressSpec {
        AddressSpec {
            policy: AddressPolicy::CapacityWeighted { weights },
        }
    }

    /// Hot/cold page steering with the default tracker size.
    pub fn steer() -> AddressSpec {
        AddressSpec::steer_with(DEFAULT_TRACKER_PAGES)
    }

    /// Page steering with an explicit tracker size.
    pub fn steer_with(tracker_pages: usize) -> AddressSpec {
        AddressSpec {
            policy: AddressPolicy::LocalitySteer { tracker_pages },
        }
    }

    /// Whether this is the default (v1) interleaving.
    pub fn is_round_robin(&self) -> bool {
        self.policy == AddressPolicy::RoundRobin
    }

    /// Validate the spec; every ingestion boundary calls this before a
    /// map is built — mirrors `CodecSpec::validate`.
    pub fn validate(&self) -> anyhow::Result<()> {
        match &self.policy {
            AddressPolicy::RoundRobin => Ok(()),
            AddressPolicy::CapacityWeighted { weights } => {
                anyhow::ensure!(!weights.is_empty(), "capacity weights must not be empty");
                anyhow::ensure!(
                    weights.iter().all(|&w| (1..=1024).contains(&w)),
                    "capacity weights must be in 1..=1024, got {weights:?}"
                );
                Ok(())
            }
            AddressPolicy::LocalitySteer { tracker_pages } => {
                anyhow::ensure!(
                    (1..=1 << 20).contains(tracker_pages),
                    "steer tracker size {tracker_pages} out of range 1..=2^20 pages"
                );
                Ok(())
            }
        }
    }

    /// Short label for scenario rows / report columns: `round_robin`,
    /// `cap2/1`, `steer`, `steer:512`.
    pub fn label(&self) -> String {
        match &self.policy {
            AddressPolicy::RoundRobin => "round_robin".into(),
            AddressPolicy::CapacityWeighted { weights } => {
                let parts: Vec<String> = weights.iter().map(|w| w.to_string()).collect();
                format!("cap{}", parts.join("/"))
            }
            AddressPolicy::LocalitySteer { tracker_pages } => {
                if *tracker_pages == DEFAULT_TRACKER_PAGES {
                    "steer".into()
                } else {
                    format!("steer:{tracker_pages}")
                }
            }
        }
    }

    /// Parse the uniform textual form shared by CLI flags and TOML:
    ///
    /// * `round_robin` (also `rr`)
    /// * `capacity:<w0>/<w1>/...` (also `cap:`; `/`-separated so the
    ///   comma stays the list separator)
    /// * `steer` or `steer:<tracker_pages>`
    ///
    /// Unknown policies and malformed numbers are rejected — the same
    /// "no silent knob absorption" contract as `CodecSpec::set_knob`.
    pub fn parse(text: &str) -> anyhow::Result<AddressSpec> {
        let text = text.trim();
        let (name, args) = match text.split_once(':') {
            Some((n, a)) => (n.trim().to_ascii_lowercase(), Some(a.trim())),
            None => (text.to_ascii_lowercase(), None),
        };
        let spec = match name.as_str() {
            "round_robin" | "roundrobin" | "rr" => {
                anyhow::ensure!(args.is_none(), "round_robin takes no arguments");
                AddressSpec::round_robin()
            }
            "capacity" | "cap" | "weighted" => {
                let args = args
                    .ok_or_else(|| anyhow::anyhow!("capacity needs capacity:<w0>/<w1>/..."))?;
                let weights: Vec<u32> = args
                    .split('/')
                    .map(|p| {
                        let p = p.trim();
                        p.parse::<u32>()
                            .map_err(|e| anyhow::anyhow!("capacity weight {p:?}: {e}"))
                    })
                    .collect::<anyhow::Result<_>>()?;
                AddressSpec::capacity(weights)
            }
            "steer" => match args {
                None => AddressSpec::steer(),
                Some(a) => {
                    let pages: usize = a
                        .parse()
                        .map_err(|e| anyhow::anyhow!("steer tracker size {a:?}: {e}"))?;
                    AddressSpec::steer_with(pages)
                }
            },
            other => anyhow::bail!(
                "unknown address policy {other:?}; known: round_robin, \
                 capacity:<w0>/<w1>/..., steer[:<tracker_pages>]"
            ),
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a comma-separated address axis, e.g. `round_robin,steer`.
    pub fn parse_list(text: &str) -> anyhow::Result<Vec<AddressSpec>> {
        let list: Vec<AddressSpec> = text
            .split(',')
            .map(AddressSpec::parse)
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(!list.is_empty(), "empty address list");
        Ok(list)
    }

    /// Build the map instance for a concrete shard count. Capacity
    /// weights are cycled to cover the shards, so the same spec works at
    /// any point of a channel-count sweep axis.
    pub fn build(&self, shards: usize) -> Box<dyn AddressMap> {
        assert!(shards >= 1, "address map needs at least one shard");
        match &self.policy {
            AddressPolicy::RoundRobin => Box::new(RoundRobin::new(shards)),
            AddressPolicy::CapacityWeighted { weights } => {
                Box::new(CapacityWeighted::new(shards, weights))
            }
            AddressPolicy::LocalitySteer { tracker_pages } => {
                Box::new(LocalitySteer::new(shards, *tracker_pages))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn route(map: &mut dyn AddressMap, n: usize) -> Vec<usize> {
        let mut heat = PageHeat::new(map.heat_slots());
        (0..n)
            .map(|i| {
                let line = [0u64; 8];
                let h = heat.touch(i);
                map.shard_for(i, &line, h)
            })
            .collect()
    }

    #[test]
    fn round_robin_matches_modulo() {
        let mut m = RoundRobin::new(4);
        assert_eq!(route(&mut m, 8), vec![0, 1, 2, 3, 0, 1, 2, 3]);
        assert_eq!(m.inverse(), Inverse::RoundRobin);
        let mut one = RoundRobin::new(1);
        assert!(route(&mut one, 5).iter().all(|&s| s == 0));
    }

    #[test]
    fn equal_capacity_weights_degenerate_to_round_robin() {
        for shards in [1usize, 2, 3, 4] {
            let mut cap = CapacityWeighted::new(shards, &[3]);
            let mut rr = RoundRobin::new(shards);
            assert_eq!(route(&mut cap, 40), route(&mut rr, 40), "{shards} shards");
        }
    }

    #[test]
    fn capacity_weights_split_load_proportionally_and_deterministically() {
        let mut m = CapacityWeighted::new(2, &[3, 1]);
        let routes = route(&mut m, 400);
        assert_eq!(routes.iter().filter(|&&s| s == 0).count(), 300);
        assert_eq!(routes.iter().filter(|&&s| s == 1).count(), 100);
        // Smooth: the weight-1 shard is served once per 4-line window,
        // never starved to the end of the schedule.
        for w in routes.chunks(4) {
            assert_eq!(w.iter().filter(|&&s| s == 1).count(), 1, "{w:?}");
        }
        // Weight cycling: 2 weights over 4 shards.
        let mut m = CapacityWeighted::new(4, &[2, 1]);
        let routes = route(&mut m, 600);
        assert_eq!(routes.iter().filter(|&&s| s == 0).count(), 200);
        assert_eq!(routes.iter().filter(|&&s| s == 1).count(), 100);
        assert_eq!(routes.iter().filter(|&&s| s == 2).count(), 200);
        assert_eq!(routes.iter().filter(|&&s| s == 3).count(), 100);
        assert_eq!(m.inverse(), Inverse::Recorded);
    }

    #[test]
    fn steer_keeps_a_page_on_one_shard() {
        let mut m = LocalitySteer::new(4, 64);
        let mut heat = PageHeat::new(m.heat_slots());
        let mut shards = Vec::new();
        for i in 0..(3 * PAGE_LINES) {
            // Line content varies within the page; the page must not move.
            let line = [(i as u64).wrapping_mul(0x9E37_79B9); 8];
            let h = heat.touch(i);
            shards.push(m.shard_for(i, &line, h));
        }
        for p in 0..3 {
            let page = &shards[p * PAGE_LINES..(p + 1) * PAGE_LINES];
            assert!(page.iter().all(|&s| s == page[0]), "page {p} moved shards");
        }
    }

    #[test]
    fn steer_routes_similar_content_together_and_distinct_content_apart() {
        let mut m = LocalitySteer::new(2, 64);
        let low = [[0x0101_0101_0101_0101u64; 8]; 1]; // mean 1
        let high = [[0xF0F0_F0F0_F0F0_F0F0u64; 8]; 1]; // mean 240
        // Cold first touches of different pages (heat 1 each).
        let a = m.shard_for(0, &low[0], 1);
        let b = m.shard_for(PAGE_LINES, &low[0], 1);
        let c = m.shard_for(2 * PAGE_LINES, &high[0], 1);
        assert_eq!(a, b, "similar pages must share a shard");
        assert_ne!(a, c, "distinct value regions must spread");
    }

    #[test]
    fn page_heat_counts_touches_per_page() {
        let mut h = PageHeat::new(8);
        assert_eq!(h.touch(0), 1);
        assert_eq!(h.touch(1), 2); // same page
        assert_eq!(h.touch(PAGE_LINES), 1); // next page
        assert_eq!(h.touch(2), 3);
    }

    #[test]
    fn line_signature_is_the_mean_byte() {
        assert_eq!(line_signature(&[0u64; 8]), 0);
        assert_eq!(line_signature(&[u64::MAX; 8]), 255);
        let mut half = [0u64; 8];
        half[0] = u64::MAX;
        half[1] = u64::MAX;
        half[2] = u64::MAX;
        half[3] = u64::MAX;
        assert_eq!(line_signature(&half), 127);
    }

    #[test]
    fn spec_parses_validates_and_labels() {
        assert!(AddressSpec::parse("round_robin").unwrap().is_round_robin());
        assert!(AddressSpec::parse(" rr ").unwrap().is_round_robin());
        let cap = AddressSpec::parse("capacity:2/1").unwrap();
        assert_eq!(
            cap.policy,
            AddressPolicy::CapacityWeighted {
                weights: vec![2, 1]
            }
        );
        assert_eq!(cap.label(), "cap2/1");
        let st = AddressSpec::parse("steer").unwrap();
        assert_eq!(st.label(), "steer");
        assert_eq!(AddressSpec::parse("steer:512").unwrap().label(), "steer:512");
        assert_eq!(AddressSpec::default().label(), "round_robin");
        assert_eq!(
            AddressSpec::parse_list("round_robin,steer").unwrap().len(),
            2
        );
    }

    #[test]
    fn spec_rejects_unknown_policies_and_bad_numbers() {
        for bad in [
            "wat",
            "rr:1",
            "capacity",
            "capacity:",
            "capacity:0/1",
            "capacity:a/b",
            "capacity:9999",
            "steer:0",
            "steer:zzz",
        ] {
            assert!(AddressSpec::parse(bad).is_err(), "{bad:?} accepted");
        }
        assert!(AddressSpec::parse_list("").is_err());
        assert!(AddressSpec::capacity(vec![]).validate().is_err());
    }

    #[test]
    fn built_maps_cover_exactly_the_declared_shards() {
        for spec in [
            AddressSpec::round_robin(),
            AddressSpec::capacity(vec![2, 1]),
            AddressSpec::steer_with(16),
        ] {
            for shards in [1usize, 2, 4] {
                let mut map = spec.build(shards);
                assert_eq!(map.shards(), shards, "{}", spec.label());
                for s in route(map.as_mut(), 300) {
                    assert!(s < shards, "{}: shard {s} out of range", spec.label());
                }
            }
        }
    }
}
