//! System-level sweep reporting: per-scenario results, a text table for
//! humans and machine-readable JSON (`BENCH_system.json`) diffed across
//! PRs like `BENCH_encoder.json`.

use crate::channel::EnergyCounts;
use crate::encoding::Outcome;
use crate::obs::TelemetrySnapshot;
use crate::util::json_lite::{self, num, obj, s, Json};
use crate::util::table::{f, pct, TextTable};

/// One scenario's measured outcome.
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    /// Human label, e.g. `ZAC(L80,T0,O0)@2ch`.
    pub label: String,
    /// Stable cell fingerprint ([`cell_fingerprint`]
    /// (crate::system::cell_fingerprint)): the resume key `sweep
    /// --resume` matches completed cells on. Empty in reports written
    /// before the resume engine — such rows are never resumed.
    pub fingerprint: String,
    /// Scheme label (Table I name).
    pub scheme: String,
    /// Channel (shard) count the scenario ran on.
    pub channels: usize,
    /// ZAC knobs (0 for non-ZAC schemes).
    pub limit: u32,
    pub truncation_bits: u32,
    pub tolerance_bits: u32,
    /// Fault-model label (`"perfect"` when no injection ran).
    pub fault_label: String,
    /// Address-mapping policy label (`"round_robin"` = the v1 default).
    pub address: String,
    /// System-wide `DataTable` hit rate (OHE-skip fraction) — the metric
    /// the address policy moves.
    pub table_hit_rate: f64,
    /// Max/mean lines per shard (1.0 = perfectly balanced) — the
    /// load-balance cost a steering policy pays for locality.
    pub load_imbalance: f64,
    /// Wire bits flipped by the fault model.
    pub injected_bits: u64,
    /// Transfers with at least one injected flip.
    pub injected_words: u64,
    /// End-to-end error bits (approximation + fault propagation).
    pub observed_error_bits: u64,
    /// Bit errors repaired by a correcting codec before they reached
    /// the application.
    pub corrected_bits: u64,
    /// Bit errors detected but not repairable (flagged to the host).
    pub detected_bits: u64,
    /// Error bits that escaped past the codec's resilience envelope
    /// while injection was active — the residual the ECC family exists
    /// to shrink.
    pub residual_error_bits: u64,
    /// Merged system-wide energy counts.
    pub counts: EnergyCounts,
    /// Savings vs the spec's baseline scheme at the same channel count.
    pub term_savings_pct: f64,
    pub switch_savings_pct: f64,
    /// Transfer-outcome fractions, in [`Outcome::all`] order.
    pub outcome_fracs: [f64; 4],
    /// Trace-level quality proxy: `1 - MAE/255` (1.0 = bit-exact). The
    /// paper's full quality ratios come from the workload suite; this is
    /// the sweep engine's model-free stand-in.
    pub quality_ratio: f64,
    /// PSNR of the reconstructed trace (dB); `None` when bit-exact.
    pub psnr_db: Option<f64>,
    /// Wall time of the array run.
    pub wall_ms: f64,
    /// Trace bytes per second through the array.
    pub bytes_per_sec: f64,
    /// Lines served per shard (round-robin shares).
    pub shard_lines: Vec<usize>,
    /// Runtime telemetry (per-stage timings, mailbox pressure, service
    /// latency); `None` unless the sweep ran with telemetry enabled.
    pub telemetry: Option<TelemetrySnapshot>,
}

impl ScenarioResult {
    /// One row of `BENCH_system.json`; [`Self::from_json`] is the exact
    /// inverse (the resume round-trip depends on it).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("label", s(&self.label)),
            ("fingerprint", s(&self.fingerprint)),
            ("scheme", s(&self.scheme)),
            ("channels", num(self.channels as f64)),
            ("limit", num(self.limit as f64)),
            ("truncation_bits", num(self.truncation_bits as f64)),
            ("tolerance_bits", num(self.tolerance_bits as f64)),
            ("faults", s(&self.fault_label)),
            ("address", s(&self.address)),
            ("table_hit_rate", num(self.table_hit_rate)),
            ("load_imbalance", num(self.load_imbalance)),
            ("injected_bits", num(self.injected_bits as f64)),
            ("injected_words", num(self.injected_words as f64)),
            (
                "observed_error_bits",
                num(self.observed_error_bits as f64),
            ),
            ("corrected_bits", num(self.corrected_bits as f64)),
            ("detected_bits", num(self.detected_bits as f64)),
            (
                "residual_error_bits",
                num(self.residual_error_bits as f64),
            ),
            ("termination_ones", num(self.counts.termination_ones as f64)),
            (
                "switching_transitions",
                num(self.counts.switching_transitions as f64),
            ),
            ("transfers", num(self.counts.transfers as f64)),
            ("term_savings_pct", num(self.term_savings_pct)),
            ("switch_savings_pct", num(self.switch_savings_pct)),
            ("zero_frac", num(self.outcome_fracs[0])),
            ("ohe_frac", num(self.outcome_fracs[1])),
            ("bde_frac", num(self.outcome_fracs[2])),
            ("unencoded_frac", num(self.outcome_fracs[3])),
            ("quality_ratio", num(self.quality_ratio)),
            ("psnr_db", self.psnr_db.map_or(Json::Null, num)),
            ("wall_ms", num(self.wall_ms)),
            ("bytes_per_sec", num(self.bytes_per_sec)),
            (
                "shard_lines",
                Json::Arr(self.shard_lines.iter().map(|&l| num(l as f64)).collect()),
            ),
            (
                "telemetry",
                self.telemetry.as_ref().map_or(Json::Null, |t| t.to_json()),
            ),
        ])
    }

    /// Fraction for one outcome (in [`Outcome::all`] order).
    pub fn fraction(&self, o: Outcome) -> f64 {
        let idx = Outcome::all().iter().position(|&x| x == o).unwrap();
        self.outcome_fracs[idx]
    }

    /// Parse one scenario row back out of `BENCH_system.json` — the
    /// read half of [`Self::to_json`], used by `sweep --resume` to
    /// carry completed cells across process restarts. `json_lite`
    /// numbers round-trip exactly (shortest-repr f64), so a resumed
    /// row re-serializes bit-identical to the original.
    pub fn from_json(j: &Json) -> anyhow::Result<ScenarioResult> {
        let psnr_db = match j.get("psnr_db")? {
            Json::Null => None,
            v => Some(v.as_f64()?),
        };
        let telemetry = match j.get("telemetry") {
            Err(_) | Ok(Json::Null) => None,
            Ok(v) => Some(TelemetrySnapshot::from_json(v)?),
        };
        Ok(ScenarioResult {
            label: j.get("label")?.as_str()?.to_string(),
            // Pre-resume reports carry no fingerprint key; empty means
            // "never matches", so such rows re-run rather than resume.
            fingerprint: j
                .get("fingerprint")
                .and_then(|v| v.as_str())
                .unwrap_or_default()
                .to_string(),
            scheme: j.get("scheme")?.as_str()?.to_string(),
            channels: j.get("channels")?.as_usize()?,
            limit: j.get("limit")?.as_usize()? as u32,
            truncation_bits: j.get("truncation_bits")?.as_usize()? as u32,
            tolerance_bits: j.get("tolerance_bits")?.as_usize()? as u32,
            fault_label: j.get("faults")?.as_str()?.to_string(),
            address: j.get("address")?.as_str()?.to_string(),
            table_hit_rate: j.get("table_hit_rate")?.as_f64()?,
            load_imbalance: j.get("load_imbalance")?.as_f64()?,
            injected_bits: j.get("injected_bits")?.as_usize()? as u64,
            injected_words: j.get("injected_words")?.as_usize()? as u64,
            observed_error_bits: j.get("observed_error_bits")?.as_usize()? as u64,
            corrected_bits: j.get("corrected_bits")?.as_usize()? as u64,
            detected_bits: j.get("detected_bits")?.as_usize()? as u64,
            residual_error_bits: j.get("residual_error_bits")?.as_usize()? as u64,
            counts: EnergyCounts {
                termination_ones: j.get("termination_ones")?.as_usize()? as u64,
                switching_transitions: j.get("switching_transitions")?.as_usize()? as u64,
                transfers: j.get("transfers")?.as_usize()? as u64,
            },
            term_savings_pct: j.get("term_savings_pct")?.as_f64()?,
            switch_savings_pct: j.get("switch_savings_pct")?.as_f64()?,
            outcome_fracs: [
                j.get("zero_frac")?.as_f64()?,
                j.get("ohe_frac")?.as_f64()?,
                j.get("bde_frac")?.as_f64()?,
                j.get("unencoded_frac")?.as_f64()?,
            ],
            quality_ratio: j.get("quality_ratio")?.as_f64()?,
            psnr_db,
            wall_ms: j.get("wall_ms")?.as_f64()?,
            bytes_per_sec: j.get("bytes_per_sec")?.as_f64()?,
            shard_lines: j
                .get("shard_lines")?
                .as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect::<anyhow::Result<_>>()?,
            telemetry,
        })
    }
}

/// Full sweep result: every scenario over one trace.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub name: String,
    /// Trace size the grid ran over.
    pub trace_bytes: usize,
    /// Baseline scheme label the savings columns reference.
    pub baseline: String,
    /// Worker-pool degree the grid cells fanned across (1 = sequential).
    pub workers: usize,
    /// Cells executed in this run vs carried over from a `--resume`
    /// prior report (`cells_run + cells_skipped == scenarios.len()`).
    pub cells_run: usize,
    pub cells_skipped: usize,
    /// Wall clock of the whole sweep (baselines + cells), seconds.
    pub wall_s: f64,
    pub scenarios: Vec<ScenarioResult>,
}

impl SweepReport {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("trace_bytes", num(self.trace_bytes as f64)),
            ("baseline", s(&self.baseline)),
            ("workers", num(self.workers as f64)),
            ("cells_run", num(self.cells_run as f64)),
            ("cells_skipped", num(self.cells_skipped as f64)),
            ("wall_s", num(self.wall_s)),
            (
                "scenarios",
                Json::Arr(self.scenarios.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }

    /// Parse a report back out of its JSON form — the read half of
    /// [`Self::to_json`]. The wall-clock fields default for reports
    /// written before the parallel engine, so `--resume` still accepts
    /// them (their rows just carry no fingerprints and re-run).
    pub fn from_json(j: &Json) -> anyhow::Result<SweepReport> {
        Ok(SweepReport {
            name: j.get("name")?.as_str()?.to_string(),
            trace_bytes: j.get("trace_bytes")?.as_usize()?,
            baseline: j.get("baseline")?.as_str()?.to_string(),
            workers: j.get("workers").and_then(|v| v.as_usize()).unwrap_or(1),
            cells_run: j.get("cells_run").and_then(|v| v.as_usize()).unwrap_or(0),
            cells_skipped: j
                .get("cells_skipped")
                .and_then(|v| v.as_usize())
                .unwrap_or(0),
            wall_s: j.get("wall_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
            scenarios: j
                .get("scenarios")?
                .as_arr()?
                .iter()
                .map(ScenarioResult::from_json)
                .collect::<anyhow::Result<_>>()?,
        })
    }

    /// Load a previously written `BENCH_system.json` (the `--resume`
    /// entry point). Errors name the file.
    pub fn from_json_file(path: &str) -> anyhow::Result<SweepReport> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
        Self::from_json(&j).map_err(|e| anyhow::anyhow!("{path}: {e}"))
    }

    /// Persist as pretty JSON (the `BENCH_system.json` artifact). The
    /// status line goes to stderr so piped stdout stays clean CSV/table.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        json_lite::write_file(path, &self.to_json())?;
        eprintln!("sweep report -> {path}");
        Ok(())
    }

    /// Persist the telemetry-only view (the `--metrics-out` artifact):
    /// one entry per scenario that carried a snapshot, so CI can grep
    /// `stage_ns` / `mailbox_max_depth` / `service_p99_ns` without
    /// wading through the full energy report.
    pub fn write_metrics(&self, path: &str) -> std::io::Result<()> {
        let rows = self
            .scenarios
            .iter()
            .filter_map(|r| {
                r.telemetry.as_ref().map(|t| {
                    obj(vec![("label", s(&r.label)), ("telemetry", t.to_json())])
                })
            })
            .collect();
        let root = obj(vec![
            ("name", s(&self.name)),
            ("scenarios", Json::Arr(rows)),
        ]);
        json_lite::write_file(path, &root)?;
        eprintln!("metrics -> {path}");
        Ok(())
    }

    /// Human-readable table, one row per scenario.
    pub fn render_table(&self) -> String {
        let mut t = TextTable::new(&[
            "scenario",
            "ch",
            "addr",
            "faults",
            "term save",
            "switch save",
            "tbl hit",
            "imbal",
            "unenc",
            "flips",
            "quality",
            "MB/s",
        ]);
        for r in &self.scenarios {
            t.row(vec![
                r.label.clone(),
                format!("{}", r.channels),
                r.address.clone(),
                r.fault_label.clone(),
                pct(r.term_savings_pct),
                pct(r.switch_savings_pct),
                pct(100.0 * r.table_hit_rate),
                f(r.load_imbalance, 2),
                pct(100.0 * r.outcome_fracs[3]),
                format!("{}", r.injected_bits),
                f(r.quality_ratio, 4),
                f(r.bytes_per_sec / 1e6, 1),
            ]);
        }
        let mut out = format!(
            "sweep {:?}: {} scenarios over {} B (savings vs {} at equal channel count; \
             workers={}, {} run + {} resumed in {:.2}s)\n{}",
            self.name,
            self.scenarios.len(),
            self.trace_bytes,
            self.baseline,
            self.workers,
            self.cells_run,
            self.cells_skipped,
            self.wall_s,
            t.render()
        );
        for r in &self.scenarios {
            if let Some(t) = &r.telemetry {
                out.push_str(&format!("\n{}\n{}", r.label, t.render_table()));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SweepReport {
        SweepReport {
            name: "unit".into(),
            trace_bytes: 4096,
            baseline: "BDE".into(),
            workers: 2,
            cells_run: 1,
            cells_skipped: 0,
            wall_s: 0.75,
            scenarios: vec![ScenarioResult {
                label: "ZAC(L80,T0,O0)@2ch".into(),
                fingerprint: "00c0ffee00c0ffee".into(),
                scheme: "OHE".into(),
                channels: 2,
                limit: 80,
                truncation_bits: 0,
                tolerance_bits: 0,
                fault_label: "vdd1050mV".into(),
                address: "steer".into(),
                table_hit_rate: 0.4,
                load_imbalance: 1.25,
                injected_bits: 17,
                injected_words: 12,
                observed_error_bits: 40,
                corrected_bits: 9,
                detected_bits: 2,
                residual_error_bits: 5,
                counts: EnergyCounts {
                    termination_ones: 100,
                    switching_transitions: 50,
                    transfers: 512,
                },
                term_savings_pct: 12.5,
                switch_savings_pct: 3.25,
                outcome_fracs: [0.1, 0.4, 0.3, 0.2],
                quality_ratio: 0.998,
                psnr_db: Some(41.5),
                wall_ms: 1.25,
                bytes_per_sec: 3.2e6,
                shard_lines: vec![32, 32],
                telemetry: None,
            }],
        }
    }

    fn snapshot() -> TelemetrySnapshot {
        use crate::obs::ShardSnapshot;
        TelemetrySnapshot {
            wall_ns: 2_000_000,
            lines: 64,
            shards: vec![ShardSnapshot {
                stage_ns: [10, 20, 30, 0, 40],
                batches: 1,
                mailbox_depth: 0,
                mailbox_max_depth: 2,
                send_block_ns: 7,
                blocked_sends: 1,
                service_count: 1,
                service_p50_ns: 100,
                service_p95_ns: 100,
                service_p99_ns: 100,
                service_max_ns: 100,
            }],
        }
    }

    #[test]
    fn json_round_trips_and_carries_fields() {
        let rpt = sample();
        let j = Json::parse(&rpt.to_json().to_string()).unwrap();
        assert_eq!(j.get("baseline").unwrap().as_str().unwrap(), "BDE");
        let sc = &j.get("scenarios").unwrap().as_arr().unwrap()[0];
        assert_eq!(sc.get("channels").unwrap().as_usize().unwrap(), 2);
        assert!((sc.get("term_savings_pct").unwrap().as_f64().unwrap() - 12.5).abs() < 1e-12);
        assert_eq!(
            sc.get("shard_lines").unwrap().as_arr().unwrap().len(),
            2
        );
        // Fault fields persist into BENCH_system.json.
        assert_eq!(sc.get("faults").unwrap().as_str().unwrap(), "vdd1050mV");
        assert_eq!(sc.get("injected_bits").unwrap().as_usize().unwrap(), 17);
        // Address-policy fields persist too (the CI smoke greps them).
        assert_eq!(sc.get("address").unwrap().as_str().unwrap(), "steer");
        assert!((sc.get("table_hit_rate").unwrap().as_f64().unwrap() - 0.4).abs() < 1e-12);
        assert!((sc.get("load_imbalance").unwrap().as_f64().unwrap() - 1.25).abs() < 1e-12);
        assert_eq!(
            sc.get("observed_error_bits").unwrap().as_usize().unwrap(),
            40
        );
        // Correcting-codec counters persist (the CI smoke greps them).
        assert_eq!(sc.get("corrected_bits").unwrap().as_usize().unwrap(), 9);
        assert_eq!(sc.get("detected_bits").unwrap().as_usize().unwrap(), 2);
        assert_eq!(
            sc.get("residual_error_bits").unwrap().as_usize().unwrap(),
            5
        );
    }

    #[test]
    fn exact_scenario_serializes_psnr_as_null() {
        let mut rpt = sample();
        rpt.scenarios[0].psnr_db = None;
        let j = Json::parse(&rpt.to_json().to_string()).unwrap();
        let sc = &j.get("scenarios").unwrap().as_arr().unwrap()[0];
        assert_eq!(sc.get("psnr_db").unwrap(), &Json::Null);
    }

    #[test]
    fn table_renders_each_scenario() {
        let out = sample().render_table();
        assert!(out.contains("ZAC(L80,T0,O0)@2ch"), "{out}");
        assert!(out.contains("term save"), "{out}");
        assert!(out.contains("tbl hit"), "{out}");
        assert!(out.contains("steer"), "{out}");
    }

    #[test]
    fn telemetry_serializes_into_scenario_json_and_table() {
        // Without telemetry the key is null and no section renders.
        let rpt = sample();
        let j = Json::parse(&rpt.to_json().to_string()).unwrap();
        let sc = &j.get("scenarios").unwrap().as_arr().unwrap()[0];
        assert_eq!(sc.get("telemetry").unwrap(), &Json::Null);
        assert!(!rpt.render_table().contains("telemetry:"));

        // With a snapshot the grep keys land in BENCH_system.json and
        // the rendered report grows a per-scenario telemetry section.
        let mut rpt = sample();
        rpt.scenarios[0].telemetry = Some(snapshot());
        let text = rpt.to_json().to_pretty();
        for key in ["\"stage_ns\"", "\"mailbox_max_depth\"", "\"service_p99_ns\""] {
            assert!(text.contains(key), "missing {key}");
        }
        let table = rpt.render_table();
        assert!(table.contains("telemetry:"), "{table}");
        assert!(table.contains("svc p99"), "{table}");
    }

    #[test]
    fn write_metrics_emits_only_instrumented_scenarios() {
        let mut rpt = sample();
        rpt.scenarios.push(rpt.scenarios[0].clone());
        rpt.scenarios[1].label = "probe@1ch".into();
        rpt.scenarios[1].telemetry = Some(snapshot());
        let path = std::env::temp_dir().join("zac_metrics_report_test.json");
        let path = path.to_str().unwrap();
        rpt.write_metrics(path).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        let rows = parsed.get("scenarios").unwrap().as_arr().unwrap();
        // The telemetry-free scenario is skipped, not emitted as null.
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("label").unwrap().as_str().unwrap(), "probe@1ch");
        let snap = rows[0].get("telemetry").unwrap();
        assert!(snap.get("shards").is_ok());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn fraction_accessor_follows_outcome_order() {
        let r = &sample().scenarios[0];
        assert_eq!(r.fraction(Outcome::ZeroSkip), 0.1);
        assert_eq!(r.fraction(Outcome::Raw), 0.2);
    }

    #[test]
    fn report_parses_back_bit_identical() {
        // The resume contract: parse(serialize(report)) re-serializes
        // byte-for-byte, telemetry included — json_lite's shortest-repr
        // f64 makes the round trip exact, so a resumed row is
        // indistinguishable from the original run's row.
        let mut rpt = sample();
        rpt.scenarios[0].telemetry = Some(snapshot());
        let text = rpt.to_json().to_string();
        let back = SweepReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), text);
        assert_eq!(back.workers, 2);
        assert_eq!(back.cells_run, 1);
        assert_eq!(back.wall_s, 0.75);
        assert_eq!(back.scenarios[0].fingerprint, "00c0ffee00c0ffee");
        assert_eq!(back.scenarios[0].psnr_db, Some(41.5));
    }

    #[test]
    fn report_parse_tolerates_pre_resume_files() {
        // A report written before the parallel engine has no workers /
        // cells / wall_s / fingerprint keys: it must still load (with
        // defaults), its rows simply never match a resume fingerprint.
        let mut j = sample().to_json();
        if let Json::Obj(m) = &mut j {
            for k in ["workers", "cells_run", "cells_skipped", "wall_s"] {
                m.remove(k);
            }
            if let Json::Arr(rows) = m.get_mut("scenarios").unwrap() {
                if let Json::Obj(r) = &mut rows[0] {
                    r.remove("fingerprint");
                }
            }
        }
        let back = SweepReport::from_json(&j).unwrap();
        assert_eq!(back.workers, 1);
        assert_eq!(back.cells_run, 0);
        assert_eq!(back.wall_s, 0.0);
        assert_eq!(back.scenarios[0].fingerprint, "");
        // Corrupt files are named errors, not defaults.
        assert!(SweepReport::from_json(&Json::Null).is_err());
        let err = SweepReport::from_json_file("/nonexistent/bench.json")
            .unwrap_err()
            .to_string();
        assert!(err.contains("/nonexistent/bench.json"), "{err}");
    }

    #[test]
    fn table_header_carries_workers_cells_and_wall() {
        let out = sample().render_table();
        assert!(out.contains("workers=2"), "{out}");
        assert!(out.contains("1 run + 0 resumed"), "{out}");
    }
}
