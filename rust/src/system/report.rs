//! System-level sweep reporting: per-scenario results, a text table for
//! humans and machine-readable JSON (`BENCH_system.json`) diffed across
//! PRs like `BENCH_encoder.json`.

use crate::channel::EnergyCounts;
use crate::encoding::Outcome;
use crate::obs::TelemetrySnapshot;
use crate::util::json_lite::{self, num, obj, s, Json};
use crate::util::table::{f, pct, TextTable};

/// One scenario's measured outcome.
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    /// Human label, e.g. `ZAC(L80,T0,O0)@2ch`.
    pub label: String,
    /// Scheme label (Table I name).
    pub scheme: String,
    /// Channel (shard) count the scenario ran on.
    pub channels: usize,
    /// ZAC knobs (0 for non-ZAC schemes).
    pub limit: u32,
    pub truncation_bits: u32,
    pub tolerance_bits: u32,
    /// Fault-model label (`"perfect"` when no injection ran).
    pub fault_label: String,
    /// Address-mapping policy label (`"round_robin"` = the v1 default).
    pub address: String,
    /// System-wide `DataTable` hit rate (OHE-skip fraction) — the metric
    /// the address policy moves.
    pub table_hit_rate: f64,
    /// Max/mean lines per shard (1.0 = perfectly balanced) — the
    /// load-balance cost a steering policy pays for locality.
    pub load_imbalance: f64,
    /// Wire bits flipped by the fault model.
    pub injected_bits: u64,
    /// Transfers with at least one injected flip.
    pub injected_words: u64,
    /// End-to-end error bits (approximation + fault propagation).
    pub observed_error_bits: u64,
    /// Bit errors repaired by a correcting codec before they reached
    /// the application.
    pub corrected_bits: u64,
    /// Bit errors detected but not repairable (flagged to the host).
    pub detected_bits: u64,
    /// Error bits that escaped past the codec's resilience envelope
    /// while injection was active — the residual the ECC family exists
    /// to shrink.
    pub residual_error_bits: u64,
    /// Merged system-wide energy counts.
    pub counts: EnergyCounts,
    /// Savings vs the spec's baseline scheme at the same channel count.
    pub term_savings_pct: f64,
    pub switch_savings_pct: f64,
    /// Transfer-outcome fractions, in [`Outcome::all`] order.
    pub outcome_fracs: [f64; 4],
    /// Trace-level quality proxy: `1 - MAE/255` (1.0 = bit-exact). The
    /// paper's full quality ratios come from the workload suite; this is
    /// the sweep engine's model-free stand-in.
    pub quality_ratio: f64,
    /// PSNR of the reconstructed trace (dB); `None` when bit-exact.
    pub psnr_db: Option<f64>,
    /// Wall time of the array run.
    pub wall_ms: f64,
    /// Trace bytes per second through the array.
    pub bytes_per_sec: f64,
    /// Lines served per shard (round-robin shares).
    pub shard_lines: Vec<usize>,
    /// Runtime telemetry (per-stage timings, mailbox pressure, service
    /// latency); `None` unless the sweep ran with telemetry enabled.
    pub telemetry: Option<TelemetrySnapshot>,
}

impl ScenarioResult {
    fn to_json(&self) -> Json {
        obj(vec![
            ("label", s(&self.label)),
            ("scheme", s(&self.scheme)),
            ("channels", num(self.channels as f64)),
            ("limit", num(self.limit as f64)),
            ("truncation_bits", num(self.truncation_bits as f64)),
            ("tolerance_bits", num(self.tolerance_bits as f64)),
            ("faults", s(&self.fault_label)),
            ("address", s(&self.address)),
            ("table_hit_rate", num(self.table_hit_rate)),
            ("load_imbalance", num(self.load_imbalance)),
            ("injected_bits", num(self.injected_bits as f64)),
            ("injected_words", num(self.injected_words as f64)),
            (
                "observed_error_bits",
                num(self.observed_error_bits as f64),
            ),
            ("corrected_bits", num(self.corrected_bits as f64)),
            ("detected_bits", num(self.detected_bits as f64)),
            (
                "residual_error_bits",
                num(self.residual_error_bits as f64),
            ),
            ("termination_ones", num(self.counts.termination_ones as f64)),
            (
                "switching_transitions",
                num(self.counts.switching_transitions as f64),
            ),
            ("transfers", num(self.counts.transfers as f64)),
            ("term_savings_pct", num(self.term_savings_pct)),
            ("switch_savings_pct", num(self.switch_savings_pct)),
            ("zero_frac", num(self.outcome_fracs[0])),
            ("ohe_frac", num(self.outcome_fracs[1])),
            ("bde_frac", num(self.outcome_fracs[2])),
            ("unencoded_frac", num(self.outcome_fracs[3])),
            ("quality_ratio", num(self.quality_ratio)),
            ("psnr_db", self.psnr_db.map_or(Json::Null, num)),
            ("wall_ms", num(self.wall_ms)),
            ("bytes_per_sec", num(self.bytes_per_sec)),
            (
                "shard_lines",
                Json::Arr(self.shard_lines.iter().map(|&l| num(l as f64)).collect()),
            ),
            (
                "telemetry",
                self.telemetry.as_ref().map_or(Json::Null, |t| t.to_json()),
            ),
        ])
    }

    /// Fraction for one outcome (in [`Outcome::all`] order).
    pub fn fraction(&self, o: Outcome) -> f64 {
        let idx = Outcome::all().iter().position(|&x| x == o).unwrap();
        self.outcome_fracs[idx]
    }
}

/// Full sweep result: every scenario over one trace.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub name: String,
    /// Trace size the grid ran over.
    pub trace_bytes: usize,
    /// Baseline scheme label the savings columns reference.
    pub baseline: String,
    pub scenarios: Vec<ScenarioResult>,
}

impl SweepReport {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("trace_bytes", num(self.trace_bytes as f64)),
            ("baseline", s(&self.baseline)),
            (
                "scenarios",
                Json::Arr(self.scenarios.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }

    /// Persist as pretty JSON (the `BENCH_system.json` artifact). The
    /// status line goes to stderr so piped stdout stays clean CSV/table.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        json_lite::write_file(path, &self.to_json())?;
        eprintln!("sweep report -> {path}");
        Ok(())
    }

    /// Persist the telemetry-only view (the `--metrics-out` artifact):
    /// one entry per scenario that carried a snapshot, so CI can grep
    /// `stage_ns` / `mailbox_max_depth` / `service_p99_ns` without
    /// wading through the full energy report.
    pub fn write_metrics(&self, path: &str) -> std::io::Result<()> {
        let rows = self
            .scenarios
            .iter()
            .filter_map(|r| {
                r.telemetry.as_ref().map(|t| {
                    obj(vec![("label", s(&r.label)), ("telemetry", t.to_json())])
                })
            })
            .collect();
        let root = obj(vec![
            ("name", s(&self.name)),
            ("scenarios", Json::Arr(rows)),
        ]);
        json_lite::write_file(path, &root)?;
        eprintln!("metrics -> {path}");
        Ok(())
    }

    /// Human-readable table, one row per scenario.
    pub fn render_table(&self) -> String {
        let mut t = TextTable::new(&[
            "scenario",
            "ch",
            "addr",
            "faults",
            "term save",
            "switch save",
            "tbl hit",
            "imbal",
            "unenc",
            "flips",
            "quality",
            "MB/s",
        ]);
        for r in &self.scenarios {
            t.row(vec![
                r.label.clone(),
                format!("{}", r.channels),
                r.address.clone(),
                r.fault_label.clone(),
                pct(r.term_savings_pct),
                pct(r.switch_savings_pct),
                pct(100.0 * r.table_hit_rate),
                f(r.load_imbalance, 2),
                pct(100.0 * r.outcome_fracs[3]),
                format!("{}", r.injected_bits),
                f(r.quality_ratio, 4),
                f(r.bytes_per_sec / 1e6, 1),
            ]);
        }
        let mut out = format!(
            "sweep {:?}: {} scenarios over {} B (savings vs {} at equal channel count)\n{}",
            self.name,
            self.scenarios.len(),
            self.trace_bytes,
            self.baseline,
            t.render()
        );
        for r in &self.scenarios {
            if let Some(t) = &r.telemetry {
                out.push_str(&format!("\n{}\n{}", r.label, t.render_table()));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SweepReport {
        SweepReport {
            name: "unit".into(),
            trace_bytes: 4096,
            baseline: "BDE".into(),
            scenarios: vec![ScenarioResult {
                label: "ZAC(L80,T0,O0)@2ch".into(),
                scheme: "OHE".into(),
                channels: 2,
                limit: 80,
                truncation_bits: 0,
                tolerance_bits: 0,
                fault_label: "vdd1050mV".into(),
                address: "steer".into(),
                table_hit_rate: 0.4,
                load_imbalance: 1.25,
                injected_bits: 17,
                injected_words: 12,
                observed_error_bits: 40,
                corrected_bits: 9,
                detected_bits: 2,
                residual_error_bits: 5,
                counts: EnergyCounts {
                    termination_ones: 100,
                    switching_transitions: 50,
                    transfers: 512,
                },
                term_savings_pct: 12.5,
                switch_savings_pct: 3.25,
                outcome_fracs: [0.1, 0.4, 0.3, 0.2],
                quality_ratio: 0.998,
                psnr_db: Some(41.5),
                wall_ms: 1.25,
                bytes_per_sec: 3.2e6,
                shard_lines: vec![32, 32],
                telemetry: None,
            }],
        }
    }

    fn snapshot() -> TelemetrySnapshot {
        use crate::obs::ShardSnapshot;
        TelemetrySnapshot {
            wall_ns: 2_000_000,
            lines: 64,
            shards: vec![ShardSnapshot {
                stage_ns: [10, 20, 30, 0, 40],
                batches: 1,
                mailbox_depth: 0,
                mailbox_max_depth: 2,
                send_block_ns: 7,
                blocked_sends: 1,
                service_count: 1,
                service_p50_ns: 100,
                service_p95_ns: 100,
                service_p99_ns: 100,
                service_max_ns: 100,
            }],
        }
    }

    #[test]
    fn json_round_trips_and_carries_fields() {
        let rpt = sample();
        let j = Json::parse(&rpt.to_json().to_string()).unwrap();
        assert_eq!(j.get("baseline").unwrap().as_str().unwrap(), "BDE");
        let sc = &j.get("scenarios").unwrap().as_arr().unwrap()[0];
        assert_eq!(sc.get("channels").unwrap().as_usize().unwrap(), 2);
        assert!((sc.get("term_savings_pct").unwrap().as_f64().unwrap() - 12.5).abs() < 1e-12);
        assert_eq!(
            sc.get("shard_lines").unwrap().as_arr().unwrap().len(),
            2
        );
        // Fault fields persist into BENCH_system.json.
        assert_eq!(sc.get("faults").unwrap().as_str().unwrap(), "vdd1050mV");
        assert_eq!(sc.get("injected_bits").unwrap().as_usize().unwrap(), 17);
        // Address-policy fields persist too (the CI smoke greps them).
        assert_eq!(sc.get("address").unwrap().as_str().unwrap(), "steer");
        assert!((sc.get("table_hit_rate").unwrap().as_f64().unwrap() - 0.4).abs() < 1e-12);
        assert!((sc.get("load_imbalance").unwrap().as_f64().unwrap() - 1.25).abs() < 1e-12);
        assert_eq!(
            sc.get("observed_error_bits").unwrap().as_usize().unwrap(),
            40
        );
        // Correcting-codec counters persist (the CI smoke greps them).
        assert_eq!(sc.get("corrected_bits").unwrap().as_usize().unwrap(), 9);
        assert_eq!(sc.get("detected_bits").unwrap().as_usize().unwrap(), 2);
        assert_eq!(
            sc.get("residual_error_bits").unwrap().as_usize().unwrap(),
            5
        );
    }

    #[test]
    fn exact_scenario_serializes_psnr_as_null() {
        let mut rpt = sample();
        rpt.scenarios[0].psnr_db = None;
        let j = Json::parse(&rpt.to_json().to_string()).unwrap();
        let sc = &j.get("scenarios").unwrap().as_arr().unwrap()[0];
        assert_eq!(sc.get("psnr_db").unwrap(), &Json::Null);
    }

    #[test]
    fn table_renders_each_scenario() {
        let out = sample().render_table();
        assert!(out.contains("ZAC(L80,T0,O0)@2ch"), "{out}");
        assert!(out.contains("term save"), "{out}");
        assert!(out.contains("tbl hit"), "{out}");
        assert!(out.contains("steer"), "{out}");
    }

    #[test]
    fn telemetry_serializes_into_scenario_json_and_table() {
        // Without telemetry the key is null and no section renders.
        let rpt = sample();
        let j = Json::parse(&rpt.to_json().to_string()).unwrap();
        let sc = &j.get("scenarios").unwrap().as_arr().unwrap()[0];
        assert_eq!(sc.get("telemetry").unwrap(), &Json::Null);
        assert!(!rpt.render_table().contains("telemetry:"));

        // With a snapshot the grep keys land in BENCH_system.json and
        // the rendered report grows a per-scenario telemetry section.
        let mut rpt = sample();
        rpt.scenarios[0].telemetry = Some(snapshot());
        let text = rpt.to_json().to_pretty();
        for key in ["\"stage_ns\"", "\"mailbox_max_depth\"", "\"service_p99_ns\""] {
            assert!(text.contains(key), "missing {key}");
        }
        let table = rpt.render_table();
        assert!(table.contains("telemetry:"), "{table}");
        assert!(table.contains("svc p99"), "{table}");
    }

    #[test]
    fn write_metrics_emits_only_instrumented_scenarios() {
        let mut rpt = sample();
        rpt.scenarios.push(rpt.scenarios[0].clone());
        rpt.scenarios[1].label = "probe@1ch".into();
        rpt.scenarios[1].telemetry = Some(snapshot());
        let path = std::env::temp_dir().join("zac_metrics_report_test.json");
        let path = path.to_str().unwrap();
        rpt.write_metrics(path).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        let rows = parsed.get("scenarios").unwrap().as_arr().unwrap();
        // The telemetry-free scenario is skipped, not emitted as null.
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("label").unwrap().as_str().unwrap(), "probe@1ch");
        let snap = rows[0].get("telemetry").unwrap();
        assert!(snap.get("shards").is_ok());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn fraction_accessor_follows_outcome_order() {
        let r = &sample().scenarios[0];
        assert_eq!(r.fraction(Outcome::ZeroSkip), 0.1);
        assert_eq!(r.fraction(Outcome::Raw), 0.2);
    }
}
