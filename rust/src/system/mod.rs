//! Multi-channel memory-system layer: the step from "one 8-chip channel
//! per process" to a sharded channel array plus a declarative scenario
//! engine (ROADMAP: "shard the line stream across multiple 8-chip
//! channels, async service loop over the chunked queues").
//!
//! Five pieces:
//!
//! * [`address`] — [`AddressMap`]: the pluggable line-placement policy
//!   ([`RoundRobin`](address::RoundRobin) default,
//!   [`CapacityWeighted`](address::CapacityWeighted),
//!   [`LocalitySteer`](address::LocalitySteer)), described by the
//!   serializable [`AddressSpec`] every ingestion boundary parses
//!   (`--address`, TOML, `Session::builder().address(..)`).
//! * [`array`] — [`ChannelArray`]: N independent 8-chip channels, the
//!   line stream sharded across them by the address map. Each shard
//!   runs a service loop on its own worker thread, consuming
//!   reference-counted [`LineChunk`](crate::trace::LineChunk) views (up
//!   to [`ENCODE_BATCH`] lines each) from a bounded mailbox (the same
//!   chunked-queue discipline as
//!   [`Pipeline`](crate::coordinator::Pipeline)); per-shard
//!   [`EncodeStats`](crate::encoding::EncodeStats) /
//!   [`EnergyCounts`](crate::channel::EnergyCounts) merge into one
//!   system-level [`SystemOutput`].
//! * [`scenario`] — [`SweepSpec`]: a declarative (channels × scheme ×
//!   knob-grid) sweep, parsed from a TOML subset via
//!   [`toml_lite`](crate::util::toml_lite) or built from the default
//!   grid; every concrete cell is a validated
//!   [`CodecSpec`](crate::encoding::CodecSpec) run through a sharded
//!   [`Session`](crate::session::Session) by [`run_sweep`].
//! * [`report`] — [`SweepReport`]: per-scenario energy savings, outcome
//!   mix and trace-level quality, rendered as a text table and persisted
//!   as machine-readable `BENCH_system.json`.
//! * [`loadgen`] — the open-loop load generator: replay a trace into a
//!   [`ChannelArray`] at a target lines/sec with deterministic seeded
//!   arrival jitter and commit the latency curve (p50/p95/p99 service
//!   latency, peak mailbox depth per offered-rate step) to
//!   `BENCH_loadgen.json`.
//!
//! Physical model note: each channel owns its encoder tables and line
//! state, so a shard behaves exactly like a single-channel
//! [`simulate_lines`](crate::coordinator::simulate_lines) run over its
//! own interleaved subsequence — the property tests pin the array
//! bit-identical to that reference for 1/2/4 shards.
//!
//! [`ENCODE_BATCH`]: crate::encoding::ENCODE_BATCH

pub mod address;
pub mod array;
pub mod loadgen;
pub mod report;
pub mod scenario;

pub use address::{AddressMap, AddressPolicy, AddressSpec, Inverse, PageHeat};
pub use array::{load_imbalance, shard_of_line, ChannelArray, ShardReport, SystemOutput};
pub use loadgen::{
    arrival_schedule, parse_rates, run_loadgen, LoadGenReport, LoadGenSpec, LoadGenStep,
};
pub use report::{ScenarioResult, SweepReport};
pub use scenario::{
    bench_bytes_from_env, cell_fingerprint, channels_from_env, fnv1a, parse_bench_bytes,
    parse_channel_list, parse_workers, resolve_scheme_name, run_sweep, run_sweep_resume,
    sweep_trace, sweep_trace_bytes, sweep_workers_from_env, synthetic_trace, Scenario, SweepSpec,
};
