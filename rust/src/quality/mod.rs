//! Output-quality metrics (paper §VII-A): PSNR, SSIM, top-1 accuracy,
//! and the paper's *quality ratio* (approximated metric / original
//! metric; 1.0 = no degradation).

/// Peak signal-to-noise ratio between two u8 buffers (dB). `inf` for
/// identical buffers (the paper prints "PSNR=Inf" for the original).
pub fn psnr_u8(a: &[u8], b: &[u8]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return f64::INFINITY;
    }
    let mse: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64;
    if mse == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (255.0f64 * 255.0 / mse).log10()
    }
}

/// Mean structural similarity (Wang et al. [51]) over 8x8 windows with
/// stride 4, single channel. Inputs are row-major `w*h` u8 buffers.
pub fn ssim_u8(a: &[u8], b: &[u8], w: usize, h: usize) -> f64 {
    assert_eq!(a.len(), w * h);
    assert_eq!(b.len(), w * h);
    const C1: f64 = 6.5025; // (0.01 * 255)^2
    const C2: f64 = 58.5225; // (0.03 * 255)^2
    const WIN: usize = 8;
    const STRIDE: usize = 4;
    if w < WIN || h < WIN {
        // Degenerate: global statistics.
        return ssim_window(a, b, w, 0, 0, w.min(h), C1, C2);
    }
    let mut acc = 0.0;
    let mut n = 0usize;
    let mut y = 0;
    while y + WIN <= h {
        let mut x = 0;
        while x + WIN <= w {
            acc += ssim_window(a, b, w, x, y, WIN, C1, C2);
            n += 1;
            x += STRIDE;
        }
        y += STRIDE;
    }
    acc / n as f64
}

#[allow(clippy::too_many_arguments)]
fn ssim_window(a: &[u8], b: &[u8], stride: usize, x0: usize, y0: usize, win: usize, c1: f64, c2: f64) -> f64 {
    let n = (win * win) as f64;
    let (mut sa, mut sb, mut saa, mut sbb, mut sab) = (0.0, 0.0, 0.0, 0.0, 0.0);
    for y in y0..y0 + win {
        for x in x0..x0 + win {
            let pa = a[y * stride + x] as f64;
            let pb = b[y * stride + x] as f64;
            sa += pa;
            sb += pb;
            saa += pa * pa;
            sbb += pb * pb;
            sab += pa * pb;
        }
    }
    let (ma, mb) = (sa / n, sb / n);
    let va = (saa / n - ma * ma).max(0.0);
    let vb = (sbb / n - mb * mb).max(0.0);
    let cov = sab / n - ma * mb;
    ((2.0 * ma * mb + c1) * (2.0 * cov + c2)) / ((ma * ma + mb * mb + c1) * (va + vb + c2))
}

/// SSIM for interleaved RGB: mean over channels.
pub fn ssim_rgb(a: &[u8], b: &[u8], w: usize, h: usize) -> f64 {
    assert_eq!(a.len(), w * h * 3);
    assert_eq!(b.len(), w * h * 3);
    let mut acc = 0.0;
    for c in 0..3 {
        let pa: Vec<u8> = a.iter().skip(c).step_by(3).copied().collect();
        let pb: Vec<u8> = b.iter().skip(c).step_by(3).copied().collect();
        acc += ssim_u8(&pa, &pb, w, h);
    }
    acc / 3.0
}

/// Top-1 accuracy: fraction of `pred == label`.
pub fn top1(pred: &[i32], labels: &[i32]) -> f64 {
    assert_eq!(pred.len(), labels.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter().zip(labels).filter(|(p, l)| p == l).count() as f64 / pred.len() as f64
}

/// The paper's quality ratio: approx metric / original metric
/// (clamped at 0 when the original metric is 0).
pub fn quality_ratio(approx_metric: f64, original_metric: f64) -> f64 {
    if original_metric <= 0.0 {
        0.0
    } else {
        approx_metric / original_metric
    }
}

/// Argmax of each row of a logits matrix (B x C) → class indices.
pub fn argmax_rows(logits: &[f32], classes: usize) -> Vec<i32> {
    assert_eq!(logits.len() % classes, 0);
    logits
        .chunks_exact(classes)
        .map(|row| {
            let mut best = 0usize;
            for (i, v) in row.iter().enumerate() {
                if *v > row[best] {
                    best = i;
                }
            }
            best as i32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn psnr_identical_is_inf() {
        let a = vec![7u8; 100];
        assert!(psnr_u8(&a, &a).is_infinite());
    }

    #[test]
    fn psnr_known_value() {
        // Uniform error of 1 → MSE 1 → PSNR = 20*log10(255) ≈ 48.13 dB.
        let a = vec![100u8; 1000];
        let b = vec![101u8; 1000];
        assert!((psnr_u8(&a, &b) - 48.13).abs() < 0.01);
    }

    #[test]
    fn psnr_decreases_with_damage() {
        let mut r = Rng::new(91);
        let a: Vec<u8> = (0..4096).map(|_| r.next_u32() as u8).collect();
        let small: Vec<u8> = a.iter().map(|&x| x ^ 1).collect();
        let big: Vec<u8> = a.iter().map(|&x| x ^ 0x0F).collect();
        assert!(psnr_u8(&a, &small) > psnr_u8(&a, &big));
    }

    #[test]
    fn ssim_identity_is_one() {
        let mut r = Rng::new(92);
        let a: Vec<u8> = (0..64 * 64).map(|_| r.next_u32() as u8).collect();
        assert!((ssim_u8(&a, &a, 64, 64) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ssim_orders_degradation() {
        let mut r = Rng::new(93);
        // Structured image: gradient.
        let a: Vec<u8> = (0..64 * 64).map(|i| ((i % 64) * 4) as u8).collect();
        let slight: Vec<u8> = a.iter().map(|&x| x.saturating_add((r.next_u32() % 4) as u8)).collect();
        let heavy: Vec<u8> = a.iter().map(|&x| x ^ ((r.next_u32() % 128) as u8)).collect();
        let s1 = ssim_u8(&a, &slight, 64, 64);
        let s2 = ssim_u8(&a, &heavy, 64, 64);
        assert!(s1 > 0.8, "slight {s1}");
        assert!(s2 < s1, "heavy {s2} !< slight {s1}");
    }

    #[test]
    fn ssim_range() {
        let mut r = Rng::new(94);
        let a: Vec<u8> = (0..32 * 32).map(|_| r.next_u32() as u8).collect();
        let b: Vec<u8> = (0..32 * 32).map(|_| r.next_u32() as u8).collect();
        let s = ssim_u8(&a, &b, 32, 32);
        assert!((-1.0..=1.0).contains(&s));
    }

    #[test]
    fn top1_and_ratio() {
        assert_eq!(top1(&[1, 2, 3], &[1, 0, 3]), 2.0 / 3.0);
        assert_eq!(quality_ratio(0.4, 0.8), 0.5);
        assert_eq!(quality_ratio(0.4, 0.0), 0.0);
    }

    #[test]
    fn argmax_rows_picks_max() {
        let logits = [0.1f32, 0.9, 0.0, 1.0, -1.0, 0.5];
        assert_eq!(argmax_rows(&logits, 3), vec![1, 0]);
    }
}
