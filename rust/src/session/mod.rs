//! Engine API v2: the unified [`Session`] builder over every simulate
//! path.
//!
//! One entry point replaces the five divergent v1 drivers
//! (`run_chip_stream`, `simulate_bytes`, `simulate_lines`,
//! `simulate_lines_per_chip`, `Pipeline`, `ChannelArray::run`):
//!
//! ```text
//! Session::builder()
//!     .codec(CodecSpec::zac(80))          // registry-resolved codec
//!     .channels(2)                        // sharded channel array
//!     .traffic(TrafficClass::Approximate) // no bare `approx: bool`
//!     .build()?
//!     .run(&Trace::from_bytes(bytes))?    // -> RunReport
//! ```
//!
//! * [`Trace`] owns the bytes ⇄ cache-line conversion — callers no
//!   longer hand-thread `byte_len` through every call.
//! * [`TrafficClass`] replaces the positional `approx: bool`; the
//!   default is [`TrafficClass::Critical`] (never approximate unless
//!   the caller explicitly opts the stream in).
//! * [`Execution`] selects batch / pipelined / sharded execution behind
//!   the same `run`; `Auto` picks batch for one round-robin channel and
//!   the sharded array otherwise (including whenever a non-default
//!   [`AddressSpec`] asks for placement). All three are pinned
//!   bit-identical to the legacy paths by property tests
//!   (`rust/tests/integration.rs`), and all three exchange zero-copy
//!   [`LineChunk`](crate::trace::LineChunk) views of the trace.
//! * [`RunReport`] unifies the v1 `RunOutput`/`SystemOutput` pair:
//!   merged energy + stats plus per-shard detail, for any execution.
//!
//! Codecs come from a [`CodecRegistry`] (defaulting to the built-in
//! five), so an out-of-tree scheme registered at runtime runs through a
//! `Session` end-to-end without touching `encoding/` dispatch.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::channel::CHIPS;
use crate::coordinator::{drive_lines, weight_chip_configs, Pipeline, RunOutput};
use crate::encoding::{
    default_registry, simd, Codec, CodecRegistry, CodecSpec, EncodeStats, ENCODE_BATCH,
};
use crate::faults::{FaultSpec, FaultStats};
use crate::obs::{MetricsRegistry, TelemetrySnapshot};
use crate::system::address::AddressSpec;
use crate::system::array::{load_imbalance, ChannelArray, ShardReport, SystemOutput};
use crate::trace::wire::{self, TraceFile, WireError};
use crate::trace::{bytes_to_chip_words, bytes_to_f32s, f32s_to_bytes, ChipWords, LineChunk};
use crate::util::table::TextTable;

/// Error-resilience class of a whole stream (replaces the v1 bare
/// `approx: bool`). Critical traffic — instructions, pointers, anything
/// not known resilient a priori — is never approximated.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TrafficClass {
    /// Exact delivery required (the safe default).
    #[default]
    Critical,
    /// Error-resilient data; ZAC-DEST may skip-transfer within the
    /// similarity envelope.
    Approximate,
}

impl TrafficClass {
    pub fn is_approximate(self) -> bool {
        matches!(self, TrafficClass::Approximate)
    }

    /// Bridge from the legacy bool.
    pub fn from_approx_flag(approx: bool) -> TrafficClass {
        if approx {
            TrafficClass::Approximate
        } else {
            TrafficClass::Critical
        }
    }
}

/// Execution strategy behind [`Session::run`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Execution {
    /// Batch for one channel, sharded array otherwise.
    #[default]
    Auto,
    /// One worker per chip over the whole trace (v1 `simulate_lines`).
    Batch,
    /// Bounded per-chip queues with backpressure (v1 `Pipeline`).
    Pipelined,
    /// Address-mapped interleaving across N channels (v1 `ChannelArray`
    /// with round-robin; see
    /// [`SessionBuilder::address`] for steering policies).
    Sharded,
}

/// A trace plus its cache-line view. Owns the bytes ⇄ per-chip-word
/// conversion so drivers never hand-thread `byte_len`. The line buffer
/// is reference-counted: every execution engine borrows
/// [`LineChunk`](crate::trace::LineChunk) views of it instead of
/// cloning line data per queue hop.
#[derive(Clone, Debug)]
pub struct Trace {
    bytes: Vec<u8>,
    lines: Arc<[ChipWords]>,
}

impl Trace {
    /// Trace over a byte stream (tail zero-padded to a full cache line;
    /// reconstruction trims back to the original length).
    pub fn from_bytes(bytes: Vec<u8>) -> Trace {
        let lines: Arc<[ChipWords]> = bytes_to_chip_words(&bytes).into();
        Trace { bytes, lines }
    }

    /// Trace over an f32 (weights) stream, little-endian packed.
    pub fn from_f32s(xs: &[f32]) -> Trace {
        Trace::from_bytes(f32s_to_bytes(xs))
    }

    /// Trace from pre-split cache lines (`byte_len` trims the padded
    /// tail, exactly like the v1 `byte_len` argument did).
    pub fn from_lines(lines: Vec<ChipWords>, byte_len: usize) -> Trace {
        let bytes = crate::trace::chip_words_to_bytes(&lines, byte_len);
        Trace {
            bytes,
            lines: lines.into(),
        }
    }

    /// Materialize a recorded `.zactrace` into an in-memory trace
    /// (structure and every frame CRC checked). For streaming replay
    /// that never holds the whole file in RAM, see
    /// [`Session::replay`].
    pub fn from_file(path: impl AsRef<Path>) -> Result<Trace, WireError> {
        let file = TraceFile::open(path)?;
        Ok(Trace::from_lines(
            file.read_lines()?,
            file.byte_len() as usize,
        ))
    }

    /// Record this trace to a `.zactrace` file, framed at the engines'
    /// batch size; `approx` is the recorded traffic class.
    pub fn record(&self, path: impl AsRef<Path>, approx: bool) -> Result<(), WireError> {
        wire::write_trace(path, self.lines(), self.byte_len(), wire::Layout::Raw, approx)?;
        Ok(())
    }

    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    pub fn lines(&self) -> &[ChipWords] {
        &self.lines
    }

    /// The shared line store the zero-copy chunk views borrow from
    /// (a refcount bump, no copy).
    pub fn line_store(&self) -> Arc<[ChipWords]> {
        self.lines.clone()
    }

    pub fn byte_len(&self) -> usize {
        self.bytes.len()
    }

    pub fn line_count(&self) -> usize {
        self.lines.len()
    }
}

/// Unified result of any [`Session::run`]: the receiver-side stream,
/// merged energy/stats, and per-shard detail (one entry for
/// single-channel executions).
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Receiver-side byte stream (exact or approximate), trace order.
    pub bytes: Vec<u8>,
    /// Energy counts merged over all chips and shards.
    pub counts: crate::channel::EnergyCounts,
    /// Encode statistics merged over all chips and shards.
    pub stats: EncodeStats,
    /// Fault-injection + end-to-end error statistics merged over all
    /// chips and shards (all-zero injection under a perfect channel).
    pub faults: FaultStats,
    /// Per-shard breakdown, indexed by shard id.
    pub shards: Vec<ShardReport>,
    /// Telemetry snapshot (stage timings, backpressure, latency
    /// percentiles); `None` when telemetry was off for the run.
    pub telemetry: Option<TelemetrySnapshot>,
}

impl RunReport {
    /// Wrap a single-channel [`RunOutput`] (one shard covering the
    /// whole trace).
    pub fn from_output(out: RunOutput, lines: usize) -> RunReport {
        let shard = ShardReport {
            lines,
            counts: out.counts,
            stats: out.stats.clone(),
            faults: out.faults,
        };
        RunReport {
            bytes: out.bytes,
            counts: out.counts,
            stats: out.stats,
            faults: out.faults,
            shards: vec![shard],
            telemetry: None,
        }
    }

    /// Adopt a channel-array [`SystemOutput`].
    pub fn from_system(sys: SystemOutput) -> RunReport {
        RunReport {
            bytes: sys.bytes,
            counts: sys.counts,
            stats: sys.stats,
            faults: sys.faults,
            shards: sys.shards,
            telemetry: sys.telemetry,
        }
    }

    /// Number of channels (shards) the run used.
    pub fn channels(&self) -> usize {
        self.shards.len()
    }

    /// Reinterpret the reconstructed bytes as the f32 stream a
    /// [`Trace::from_f32s`] run carried.
    pub fn to_f32s(&self) -> Vec<f32> {
        bytes_to_f32s(&self.bytes)
    }

    /// [`to_f32s`](Self::to_f32s) with the misaligned-length panic
    /// surfaced as a typed error — for replayed streams of recorded
    /// (possibly foreign) provenance, where a short byte count must
    /// not abort the process.
    pub fn try_to_f32s(&self) -> Result<Vec<f32>, WireError> {
        crate::trace::try_bytes_to_f32s(&self.bytes)
    }

    /// Back-convert into the legacy single-channel result type.
    pub fn into_output(self) -> RunOutput {
        RunOutput {
            bytes: self.bytes,
            counts: self.counts,
            stats: self.stats,
            faults: self.faults,
        }
    }

    /// The quality-delta section: what injection did to the stream.
    /// Meaningful even on a perfect channel (pure approximation error).
    pub fn quality_delta(&self) -> String {
        let mut out = format!(
            "quality delta: injected {} bit flips in {} transfers (BER {:.2e}); \
             end-to-end error {} bits over {} words ({:.2e} per bit)",
            self.faults.injected_bits,
            self.faults.injected_words,
            self.faults.injected_ber(),
            self.faults.observed_error_bits,
            self.faults.words,
            self.faults.observed_error_rate()
        );
        if self.faults.corrected_bits > 0 || self.faults.detected_bits > 0 {
            out.push_str(&format!(
                "; codec corrected {} bits, detected {} more, residual {} \
                 ({:.2e} per bit)",
                self.faults.corrected_bits,
                self.faults.detected_bits,
                self.faults.residual_error_bits,
                self.faults.residual_error_rate()
            ));
        }
        out
    }

    /// Max/mean lines per shard (1.0 = perfectly balanced); the
    /// load-balance cost an address-steering policy pays for locality.
    pub fn load_imbalance(&self) -> f64 {
        load_imbalance(&self.shards)
    }

    /// Render the per-shard report table (one row per shard + totals),
    /// including each shard's `DataTable` hit rate and the system
    /// load-balance figure.
    pub fn render(&self) -> String {
        let mut t = TextTable::new(&[
            "shard",
            "lines",
            "transfers",
            "term 1s",
            "switching",
            "tbl hit",
        ]);
        for (i, s) in self.shards.iter().enumerate() {
            t.row(vec![
                format!("{i}"),
                format!("{}", s.lines),
                format!("{}", s.counts.transfers),
                format!("{}", s.counts.termination_ones),
                format!("{}", s.counts.switching_transitions),
                format!("{:.1}%", 100.0 * s.stats.table_hit_rate()),
            ]);
        }
        t.row(vec![
            "TOTAL".into(),
            format!("{}", self.shards.iter().map(|s| s.lines).sum::<usize>()),
            format!("{}", self.counts.transfers),
            format!("{}", self.counts.termination_ones),
            format!("{}", self.counts.switching_transitions),
            format!("{:.1}%", 100.0 * self.stats.table_hit_rate()),
        ]);
        let faults = if self.faults.injected_bits > 0 {
            format!("\n{}", self.quality_delta())
        } else {
            String::new()
        };
        let telemetry = match &self.telemetry {
            Some(t) => format!("\n{}", t.render_table()),
            None => String::new(),
        };
        format!(
            "run report: {} channel(s), unencoded {:.1}%, load imbalance {:.2}x\n{}{}{}",
            self.shards.len(),
            100.0 * self.stats.unencoded_fraction(),
            self.load_imbalance(),
            t.render(),
            faults,
            telemetry
        )
    }
}

/// Project a weights-mode spec onto the byte-interleaved chips: chip
/// *j* carries byte `j % 4` of every f32, so the 32-bit lane tolerance
/// mask splits into per-chip specs (see
/// [`weight_chip_configs`](crate::coordinator::weight_chip_configs)).
pub fn weight_chip_specs(spec: &CodecSpec) -> anyhow::Result<Vec<CodecSpec>> {
    let cfg = spec.to_config()?;
    Ok(weight_chip_configs(&cfg)
        .iter()
        .map(CodecSpec::from_config)
        .collect())
}

/// A validated, reusable simulation configuration. Each [`Session::run`]
/// constructs fresh codec state (tables, line history), so one session
/// can drive many traces with independent results.
pub struct Session {
    specs: Vec<CodecSpec>,
    registry: CodecRegistry,
    channels: usize,
    traffic: TrafficClass,
    execution: Execution,
    capacity: usize,
    faults: FaultSpec,
    address: AddressSpec,
    telemetry: bool,
    simd: simd::Backend,
    trace_file: Option<PathBuf>,
    record_to: Option<PathBuf>,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// The per-chip codec specs this session runs.
    pub fn specs(&self) -> &[CodecSpec] {
        &self.specs
    }

    pub fn channels(&self) -> usize {
        self.channels
    }

    pub fn traffic(&self) -> TrafficClass {
        self.traffic
    }

    /// The fault model the wires run through (perfect by default).
    pub fn faults(&self) -> &FaultSpec {
        &self.faults
    }

    /// The address-mapping policy sharded runs place lines with
    /// (round-robin by default).
    pub fn address(&self) -> &AddressSpec {
        &self.address
    }

    /// Whether runs record telemetry (stage timings, backpressure,
    /// latency percentiles) into the report's `telemetry` section.
    pub fn telemetry(&self) -> bool {
        self.telemetry
    }

    /// The CAM search backend this session's codecs dispatch to
    /// (resolved once at `build()` from the builder override, else
    /// `ZAC_SIMD`, else feature detection).
    pub fn simd_backend(&self) -> simd::Backend {
        self.simd
    }

    fn build_codecs(&self) -> anyhow::Result<Vec<Codec>> {
        // Scoped, not global: every `DataTable` constructed by the
        // factories captures this session's backend without leaking it
        // into concurrently-built sessions or tests.
        simd::with_backend(self.simd, || {
            self.specs.iter().map(|s| self.registry.build(s)).collect()
        })
    }

    /// Construct the sharded [`ChannelArray`] this session's `Sharded`
    /// runs drive — codec sets, mailbox capacity, fault model, address
    /// policy and telemetry all resolved from the session. Public for
    /// open-loop callers (the load generator) that pace `push_chunk`
    /// themselves instead of pushing the whole store at once.
    pub fn sharded_array(&self) -> anyhow::Result<ChannelArray> {
        let sets = (0..self.channels)
            .map(|_| self.build_codecs())
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(ChannelArray::with_codec_sets_faults_address_and_telemetry(
            sets,
            self.capacity,
            &self.faults,
            &self.address,
            self.telemetry,
        ))
    }

    /// Drive `trace` through the configured codec/channel topology.
    /// Every execution borrows zero-copy [`LineChunk`] views of the
    /// trace's shared line store — no per-hop cloning of line data.
    pub fn run(&self, trace: &Trace) -> anyhow::Result<RunReport> {
        let approx = self.traffic.is_approximate();
        if let Some(path) = &self.record_to {
            trace
                .record(path, approx)
                .map_err(|e| anyhow::anyhow!("recording trace to {}: {e}", path.display()))?;
        }
        let mode = match self.execution {
            Execution::Auto => {
                // A non-default address policy needs the sharded engine
                // even at one channel — never silently dropped.
                if self.channels > 1 || !self.address.is_round_robin() {
                    Execution::Sharded
                } else {
                    Execution::Batch
                }
            }
            m => m,
        };
        // Batch/pipelined runs have no mailbox registry of their own:
        // a 1-shard registry collects their drive-loop stage timings
        // and the run wall clock.
        let reg = self.telemetry.then(|| MetricsRegistry::new(true, 1));
        let stages = reg.as_ref().map(|r| r.shard(0).stages.clone());
        match mode {
            Execution::Batch => {
                let codecs = self.build_codecs()?;
                let out = drive_lines(
                    codecs,
                    trace.lines(),
                    approx,
                    trace.byte_len(),
                    &self.faults,
                    stages,
                );
                let mut report = RunReport::from_output(out, trace.line_count());
                report.telemetry = reg.map(|r| r.snapshot(trace.line_count() as u64));
                Ok(report)
            }
            Execution::Pipelined => {
                let mut p = Pipeline::with_codecs_faults_and_stages(
                    self.build_codecs()?,
                    self.capacity,
                    &self.faults,
                    stages,
                );
                let store = trace.line_store();
                let mut pos = 0;
                while pos < store.len() {
                    let len = (store.len() - pos).min(ENCODE_BATCH);
                    p.push_chunk(LineChunk::window(store.clone(), pos, len, approx));
                    pos += len;
                }
                let mut report =
                    RunReport::from_output(p.finish(trace.byte_len()), trace.line_count());
                report.telemetry = reg.map(|r| r.snapshot(trace.line_count() as u64));
                Ok(report)
            }
            Execution::Sharded => {
                let mut a = self.sharded_array()?;
                a.push_store(&trace.line_store(), approx);
                Ok(RunReport::from_system(a.finish(trace.byte_len())))
            }
            Execution::Auto => unreachable!("Auto resolved above"),
        }
    }

    /// Replay the recorded trace the builder's
    /// [`trace_file`](SessionBuilder::trace_file) named — open, map
    /// and stream it through [`replay`](Self::replay).
    pub fn run_recorded(&self) -> anyhow::Result<RunReport> {
        let path = match &self.trace_file {
            Some(p) => p,
            None => anyhow::bail!("no trace file configured; use SessionBuilder::trace_file"),
        };
        let file = TraceFile::open(path)
            .map_err(|e| anyhow::anyhow!("trace file {}: {e}", path.display()))?;
        self.replay(&file)
    }

    /// Stream a recorded `.zactrace` through the configured
    /// codec/channel topology. Frames enter the engines as zero-copy
    /// [`LineChunk`] views of the mapped pages — the whole trace is
    /// never materialized in RAM, so multi-GiB recordings replay in
    /// bounded memory. Pinned bit-identical to running the same trace
    /// in-memory (`rust/tests/tracefile.rs`).
    ///
    /// A frame's effective class is the session's [`TrafficClass`] AND
    /// the frame's recorded flag: a frame recorded critical stays
    /// critical even under an approximate session. A corrupt or
    /// truncated frame aborts the replay with its frame-indexed
    /// [`WireError`] — never a panic.
    ///
    /// The batch engine needs the whole trace resident, so `Batch`
    /// (and `Auto` at one round-robin channel) replays through the
    /// chunk-streaming pipelined drive, which the batch≡pipelined
    /// property pins bit-identical.
    pub fn replay(&self, file: &TraceFile) -> anyhow::Result<RunReport> {
        file.verify()
            .map_err(|e| anyhow::anyhow!("invalid trace file: {e}"))?;
        let stream_approx = self.traffic.is_approximate();
        let byte_len = file.byte_len() as usize;
        let nlines = file.total_lines() as usize;
        let sharded = match self.execution {
            Execution::Auto => self.channels > 1 || !self.address.is_round_robin(),
            Execution::Sharded => true,
            Execution::Batch | Execution::Pipelined => false,
        };
        if sharded {
            let mut a = self.sharded_array()?;
            for i in 0..file.frame_count() {
                let approx = stream_approx && file.frame_approx(i);
                a.push_chunk(&file.chunk_as(i, approx)?);
            }
            return Ok(RunReport::from_system(a.finish(byte_len)));
        }
        let reg = self.telemetry.then(|| MetricsRegistry::new(true, 1));
        let stages = reg.as_ref().map(|r| r.shard(0).stages.clone());
        let mut p = Pipeline::with_codecs_faults_and_stages(
            self.build_codecs()?,
            self.capacity,
            &self.faults,
            stages,
        );
        for i in 0..file.frame_count() {
            let approx = stream_approx && file.frame_approx(i);
            p.push_chunk(file.chunk_as(i, approx)?);
        }
        let mut report = RunReport::from_output(p.finish(byte_len), nlines);
        report.telemetry = reg.map(|r| r.snapshot(nlines as u64));
        Ok(report)
    }
}

/// Builder for [`Session`]. Exactly one codec source is required:
/// [`codec`](SessionBuilder::codec) (same spec on all 8 chips),
/// [`codec_per_chip`](SessionBuilder::codec_per_chip) (one spec per
/// chip), or [`codec_weights`](SessionBuilder::codec_weights)
/// (weights-mode spec projected per chip).
#[derive(Default)]
pub struct SessionBuilder {
    codec: Option<CodecSpec>,
    per_chip: Option<Vec<CodecSpec>>,
    weights: Option<CodecSpec>,
    registry: Option<CodecRegistry>,
    channels: Option<usize>,
    traffic: TrafficClass,
    execution: Execution,
    capacity: Option<usize>,
    faults: FaultSpec,
    address: AddressSpec,
    telemetry: Option<bool>,
    simd: Option<simd::SimdPref>,
    trace_file: Option<PathBuf>,
    record_to: Option<PathBuf>,
}

impl SessionBuilder {
    /// One codec spec, replicated on every chip.
    pub fn codec(mut self, spec: CodecSpec) -> SessionBuilder {
        self.codec = Some(spec);
        self
    }

    /// A distinct spec per chip (field-aware knobs on the
    /// byte-interleaved channel).
    pub fn codec_per_chip(mut self, specs: Vec<CodecSpec>) -> SessionBuilder {
        self.per_chip = Some(specs);
        self
    }

    /// Weights-mode spec for f32 traffic: a tolerance-mask override is
    /// projected onto the interleaved chips via [`weight_chip_specs`]
    /// so sign/exponent protection lands on the bytes holding those
    /// fields; specs without an override run as a plain [`codec`](Self::codec).
    pub fn codec_weights(mut self, spec: CodecSpec) -> SessionBuilder {
        self.weights = Some(spec);
        self
    }

    /// Number of independent 8-chip channels to shard across (1..=64).
    pub fn channels(mut self, n: usize) -> SessionBuilder {
        self.channels = Some(n);
        self
    }

    /// Error-resilience class of the stream (default: Critical).
    pub fn traffic(mut self, t: TrafficClass) -> SessionBuilder {
        self.traffic = t;
        self
    }

    /// Execution strategy (default: Auto).
    pub fn execution(mut self, e: Execution) -> SessionBuilder {
        self.execution = e;
        self
    }

    /// Queue/mailbox depth in cache lines for pipelined and sharded
    /// execution (default: 4 × [`ENCODE_BATCH`]).
    pub fn capacity_lines(mut self, lines: usize) -> SessionBuilder {
        self.capacity = Some(lines);
        self
    }

    /// Codec registry to resolve specs against (default: the built-in
    /// five; pass an extended clone for out-of-tree schemes).
    pub fn registry(mut self, registry: CodecRegistry) -> SessionBuilder {
        self.registry = Some(registry);
        self
    }

    /// Fault model applied to every lane's wire between transmit and
    /// decode (default: [`FaultSpec::perfect`], the historical no-fault
    /// channel). Only [`TrafficClass::Approximate`] words are ever
    /// corrupted — critical traffic bypasses injection.
    pub fn faults(mut self, spec: FaultSpec) -> SessionBuilder {
        self.faults = spec;
        self
    }

    /// Address-mapping policy for sharded execution (default:
    /// [`AddressSpec::round_robin`], the v1 interleaving; `steer` routes
    /// similar/hot pages to the same channel to raise each channel's
    /// `DataTable` hit rate). A non-default policy makes `Auto`
    /// execution pick the sharded engine even at one channel.
    pub fn address(mut self, spec: AddressSpec) -> SessionBuilder {
        self.address = spec;
        self
    }

    /// A recorded `.zactrace` to use as the session's traffic source:
    /// [`Session::run_recorded`] maps it and streams its frames
    /// zero-copy through the configured topology.
    pub fn trace_file(mut self, path: impl AsRef<Path>) -> SessionBuilder {
        self.trace_file = Some(path.as_ref().to_path_buf());
        self
    }

    /// Record every [`Session::run`]'s input trace to this `.zactrace`
    /// path before simulating (capture mode; the file is overwritten
    /// per run). Recording never changes results.
    pub fn record_to(mut self, path: impl AsRef<Path>) -> SessionBuilder {
        self.record_to = Some(path.as_ref().to_path_buf());
        self
    }

    /// Record telemetry (drive-loop stage timings, mailbox
    /// backpressure, service-latency percentiles) into every run's
    /// `telemetry` section. Default: the `ZAC_METRICS` environment
    /// toggle (off when unset). Telemetry never changes results — only
    /// the report gains a section.
    pub fn telemetry(mut self, on: bool) -> SessionBuilder {
        self.telemetry = Some(on);
        self
    }

    /// CAM search backend preference for this session's codecs
    /// (default: the `ZAC_SIMD` environment override, else runtime
    /// feature detection). An explicit `Avx2`/`Neon` request on a host
    /// without that feature is a `build()` error, never a silent
    /// fallback. Backends never change results — every one is pinned
    /// bit-identical to the scalar oracle
    /// (`rust/tests/simd_backends.rs`).
    pub fn simd(mut self, pref: simd::SimdPref) -> SessionBuilder {
        self.simd = Some(pref);
        self
    }

    /// Validate everything and produce the session. Errors — not
    /// panics — surface invalid knobs, unknown schemes, bad channel
    /// counts and conflicting codec sources.
    pub fn build(self) -> anyhow::Result<Session> {
        let registry = self
            .registry
            .unwrap_or_else(|| default_registry().clone());
        let sources = self.codec.is_some() as u8
            + self.per_chip.is_some() as u8
            + self.weights.is_some() as u8;
        anyhow::ensure!(
            sources == 1,
            "exactly one codec source required (codec / codec_per_chip / codec_weights), got {sources}"
        );
        let specs: Vec<CodecSpec> = if let Some(spec) = self.codec {
            vec![spec; CHIPS]
        } else if let Some(per_chip) = self.per_chip {
            anyhow::ensure!(
                per_chip.len() == CHIPS,
                "codec_per_chip needs {CHIPS} specs, got {}",
                per_chip.len()
            );
            per_chip
        } else {
            let spec = self.weights.expect("one source is set");
            let has_mask = spec
                .zac_knobs()
                .map_or(false, |k| k.tolerance_mask_override.is_some());
            if has_mask {
                weight_chip_specs(&spec)?
            } else {
                vec![spec; CHIPS]
            }
        };
        for spec in &specs {
            spec.validate()
                .map_err(|e| anyhow::anyhow!("codec spec {:?}: {e}", spec.scheme))?;
            anyhow::ensure!(
                registry.contains(&spec.scheme),
                "scheme {:?} not registered; known: {:?}",
                spec.scheme,
                registry.schemes()
            );
        }
        let channels = self.channels.unwrap_or(1);
        anyhow::ensure!(
            (1..=64).contains(&channels),
            "channels {channels} out of range 1..=64"
        );
        if matches!(self.execution, Execution::Batch | Execution::Pipelined) {
            anyhow::ensure!(
                channels == 1,
                "{:?} execution is single-channel; use Sharded (or Auto) for {channels} channels",
                self.execution
            );
            anyhow::ensure!(
                self.address.is_round_robin(),
                "{:?} execution has no address map; use Sharded (or Auto) for address {:?}",
                self.execution,
                self.address.label()
            );
        }
        self.faults
            .validate()
            .map_err(|e| anyhow::anyhow!("fault spec: {e}"))?;
        self.address
            .validate()
            .map_err(|e| anyhow::anyhow!("address spec: {e}"))?;
        let telemetry = match self.telemetry {
            Some(on) => on,
            None => crate::obs::metrics_from_env()?,
        };
        let simd = match self.simd {
            Some(pref) => pref.resolve()?,
            None => simd::default_backend()?,
        };
        Ok(Session {
            specs,
            registry,
            channels,
            traffic: self.traffic,
            execution: self.execution,
            capacity: self.capacity.unwrap_or(4 * ENCODE_BATCH).max(1),
            faults: self.faults,
            address: self.address,
            telemetry,
            simd,
            trace_file: self.trace_file,
            record_to: self.record_to,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{simulate_bytes, simulate_f32s};
    use crate::encoding::{ChipDecoder, ChipEncoder, Scheme, WireWord};
    use crate::system::scenario::synthetic_trace as image_like;
    use crate::util::rng::Rng;

    #[test]
    fn builder_rejects_bad_inputs() {
        assert!(Session::builder().build().is_err(), "no codec source");
        assert!(Session::builder()
            .codec(CodecSpec::zac(80))
            .codec_per_chip(vec![CodecSpec::zac(80); 8])
            .build()
            .is_err());
        assert!(Session::builder()
            .codec(CodecSpec::zac(30)) // limit out of range
            .build()
            .is_err());
        assert!(Session::builder()
            .codec(CodecSpec::named("NOPE"))
            .build()
            .is_err());
        assert!(Session::builder()
            .codec_per_chip(vec![CodecSpec::zac(80); 3])
            .build()
            .is_err());
        assert!(Session::builder()
            .codec(CodecSpec::zac(80))
            .channels(0)
            .build()
            .is_err());
        assert!(Session::builder()
            .codec(CodecSpec::zac(80))
            .channels(2)
            .execution(Execution::Batch)
            .build()
            .is_err());
        assert!(
            Session::builder()
                .codec(CodecSpec::zac(80))
                .faults(FaultSpec::uniform(2.0)) // BER out of range
                .build()
                .is_err(),
            "invalid fault spec must be rejected at build time"
        );
    }

    #[test]
    fn builder_address_policy_is_validated_and_routed_to_the_sharded_engine() {
        // A non-default address on a single-channel engine is an error,
        // never silently dropped.
        assert!(Session::builder()
            .codec(CodecSpec::zac(80))
            .address(AddressSpec::steer())
            .execution(Execution::Batch)
            .build()
            .is_err());
        assert!(Session::builder()
            .codec(CodecSpec::zac(80))
            .address(AddressSpec::steer())
            .execution(Execution::Pipelined)
            .build()
            .is_err());
        assert!(Session::builder()
            .codec(CodecSpec::zac(80))
            .address(AddressSpec::capacity(vec![]))
            .build()
            .is_err());
        // Auto + steering resolves to the sharded engine even at one
        // channel, and a 1-shard steered run is still lossless for an
        // exact scheme.
        let bytes = image_like(4096, 44);
        let report = Session::builder()
            .codec(CodecSpec::named("BDE"))
            .address(AddressSpec::steer())
            .traffic(TrafficClass::Approximate)
            .build()
            .unwrap()
            .run(&Trace::from_bytes(bytes.clone()))
            .unwrap();
        assert_eq!(report.bytes, bytes);
        assert_eq!(report.channels(), 1);
        assert_eq!(report.load_imbalance(), 1.0);
    }

    #[test]
    fn capacity_weighted_session_splits_load_by_weight() {
        let bytes = image_like(400 * 64, 45);
        let report = Session::builder()
            .codec(CodecSpec::zac(80))
            .channels(2)
            .address(AddressSpec::capacity(vec![3, 1]))
            .traffic(TrafficClass::Approximate)
            .build()
            .unwrap()
            .run(&Trace::from_bytes(bytes))
            .unwrap();
        assert_eq!(
            report.shards.iter().map(|s| s.lines).collect::<Vec<_>>(),
            vec![300, 100]
        );
        assert!((report.load_imbalance() - 1.5).abs() < 1e-12);
        assert!(report.render().contains("tbl hit"));
    }

    #[test]
    fn critical_traffic_is_exact_even_under_aggressive_faults() {
        let bytes = image_like(8192, 42);
        let report = Session::builder()
            .codec(CodecSpec::zac(70))
            .faults(FaultSpec::uniform(0.5))
            .build()
            .unwrap()
            .run(&Trace::from_bytes(bytes.clone()))
            .unwrap();
        assert_eq!(report.bytes, bytes, "critical traffic bypasses injection");
        assert_eq!(report.faults.injected_bits, 0);
        assert!(report.quality_delta().contains("injected 0 bit flips"));
    }

    #[test]
    fn default_traffic_class_is_critical_and_exact() {
        let bytes = image_like(8192, 41);
        let session = Session::builder().codec(CodecSpec::zac(70)).build().unwrap();
        let report = session.run(&Trace::from_bytes(bytes.clone())).unwrap();
        assert_eq!(report.bytes, bytes, "critical traffic must be exact");
        assert_eq!(report.channels(), 1);
    }

    #[test]
    fn batch_pipelined_and_sharded_agree_with_legacy_simulate() {
        let bytes = image_like(300 * 64 + 32, 43);
        let trace = Trace::from_bytes(bytes.clone());
        for spec in [
            CodecSpec::named("BDE"),
            CodecSpec::zac(80),
            CodecSpec::zac_full(75, 1, 1),
        ] {
            let legacy = simulate_bytes(&spec.to_config().unwrap(), &bytes, true);
            for exec in [Execution::Batch, Execution::Pipelined, Execution::Sharded] {
                let report = Session::builder()
                    .codec(spec.clone())
                    .traffic(TrafficClass::Approximate)
                    .execution(exec)
                    .build()
                    .unwrap()
                    .run(&trace)
                    .unwrap();
                assert_eq!(report.bytes, legacy.bytes, "{} {exec:?}", spec.label());
                assert_eq!(report.counts, legacy.counts, "{} {exec:?}", spec.label());
                assert_eq!(report.stats, legacy.stats, "{} {exec:?}", spec.label());
                assert_eq!(report.channels(), 1);
                assert_eq!(report.shards[0].lines, trace.line_count());
            }
        }
    }

    #[test]
    fn weights_session_matches_legacy_simulate_f32s() {
        let mut r = Rng::new(47);
        let xs: Vec<f32> = (0..4096).map(|_| r.normal_f32(0.0, 0.05)).collect();
        let spec = CodecSpec::zac_weights(60);
        let (legacy_f32s, legacy) = simulate_f32s(&spec.to_config().unwrap(), &xs, true);
        let report = Session::builder()
            .codec_weights(spec)
            .traffic(TrafficClass::Approximate)
            .build()
            .unwrap()
            .run(&Trace::from_f32s(&xs))
            .unwrap();
        assert_eq!(report.bytes, legacy.bytes);
        assert_eq!(report.counts, legacy.counts);
        assert_eq!(report.stats, legacy.stats);
        assert_eq!(report.to_f32s(), legacy_f32s);
    }

    #[test]
    fn trace_round_trips_lines_and_bytes() {
        let bytes = image_like(1000, 3);
        let t = Trace::from_bytes(bytes.clone());
        assert_eq!(t.byte_len(), 1000);
        assert_eq!(t.line_count(), 16);
        let t2 = Trace::from_lines(t.lines().to_vec(), t.byte_len());
        assert_eq!(t2.bytes(), t.bytes());
        let xs = [1.5f32, -2.25, 0.0, 1e-8];
        assert_eq!(Trace::from_f32s(&xs).byte_len(), 16);
    }

    #[test]
    fn report_renders_per_shard_rows() {
        let bytes = image_like(103 * 64, 5);
        let report = Session::builder()
            .codec(CodecSpec::zac(80))
            .channels(4)
            .traffic(TrafficClass::Approximate)
            .build()
            .unwrap()
            .run(&Trace::from_bytes(bytes))
            .unwrap();
        assert_eq!(report.channels(), 4);
        let text = report.render();
        assert!(text.contains("TOTAL"), "{text}");
        assert!(text.contains("4 channel(s)"), "{text}");
        assert_eq!(
            report.shards.iter().map(|s| s.lines).sum::<usize>(),
            103
        );
    }

    /// Acceptance: an out-of-tree scheme registered at runtime runs
    /// end-to-end through a `Session` — no `encoding/` dispatch edits.
    #[test]
    fn out_of_tree_scheme_runs_end_to_end_through_a_session() {
        struct Rot1Encoder;
        impl ChipEncoder for Rot1Encoder {
            fn encode(&mut self, word: u64, _approx: bool) -> WireWord {
                WireWord::raw(word.rotate_left(1))
            }
            fn scheme(&self) -> Scheme {
                Scheme::Org // stats bucketing only; legacy enum is closed
            }
            fn reset(&mut self) {}
        }
        struct Rot1Decoder;
        impl ChipDecoder for Rot1Decoder {
            fn decode(&mut self, wire: &WireWord) -> u64 {
                wire.data.rotate_right(1)
            }
            fn reset(&mut self) {}
        }

        let mut registry = default_registry().clone();
        registry.register("ROT1", |_spec| {
            Ok(Codec::new(Box::new(Rot1Encoder), Box::new(Rot1Decoder)))
        });

        let bytes = image_like(64 * 64, 7);
        let trace = Trace::from_bytes(bytes.clone());
        for channels in [1usize, 3] {
            let report = Session::builder()
                .codec(CodecSpec::named("rot1"))
                .registry(registry.clone())
                .channels(channels)
                .traffic(TrafficClass::Approximate)
                .build()
                .unwrap()
                .run(&trace)
                .unwrap();
            assert_eq!(report.bytes, bytes, "rot1 is lossless ({channels}ch)");
            assert_eq!(report.stats.total(), 64 * 8);
            assert_eq!(report.channels(), channels);
        }
        // The default registry is untouched.
        assert!(!default_registry().contains("ROT1"));
    }
}
