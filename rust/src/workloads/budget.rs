//! Per-workload BER budget derivation: for each workload, walk a
//! memory technology's reliability ladder (EDEN's DRAM voltage bins,
//! the approximate-MRAM retention bins) from the error-free rung
//! toward the aggressive ones and report the **max tolerable bin** —
//! the deepest rung whose end-to-end quality loss stays inside a fixed
//! cap under the chosen codec. Correcting codecs (SECDED, `ECC+<base>`)
//! push the tolerable bin deeper than their uncorrected bases; the
//! table this emits (merged into `BENCH_system.json` under `"budget"`)
//! is the artifact that shows by how much.
//!
//! Two fidelities:
//!
//! * **proxy** ([`derive_budgets`]) — quality is the trace-level
//!   `1 - MAE/255` of each workload's own input corpus reconstructed
//!   through a [`Session`]; no model training, runs in milliseconds.
//! * **full** ([`derive_budgets_full`]) — quality is the paper's
//!   quality ratio from [`Suite::eval_under`] (trained models, PJRT
//!   runtime required).

use anyhow::Result;

use crate::datasets;
use crate::encoding::CodecSpec;
use crate::faults::{FaultProfile, FaultSpec, MramBin};
use crate::obs::TelemetrySnapshot;
use crate::session::{Session, Trace, TrafficClass};
use crate::util::json_lite::{num, obj, s, Json};
use crate::util::table::{f, TextTable};

use super::{Kind, Suite};

/// One rung of a technology's reliability ladder.
#[derive(Clone, Debug)]
pub struct Rung {
    /// Fault label, e.g. `vdd1050mV` / `mramWeak`.
    pub label: String,
    /// Raw per-bit BER of the rung (before lane weighting).
    pub ber: f64,
    pub spec: FaultSpec,
}

/// The EDEN DRAM voltage ladder, nominal (error-free) first, BER
/// ascending.
pub fn dram_ladder() -> Vec<Rung> {
    FaultProfile::ladder()
        .iter()
        .map(|&(mv, ber)| {
            let spec = FaultSpec::voltage(mv);
            Rung {
                label: spec.label(),
                ber,
                spec,
            }
        })
        .collect()
}

/// The approximate-MRAM retention ladder, reliable first, BER
/// ascending.
pub fn mram_ladder() -> Vec<Rung> {
    MramBin::ALL
        .iter()
        .map(|&bin| {
            let spec = FaultSpec::mram(bin);
            Rung {
                label: spec.label(),
                ber: bin.base_ber(),
                spec,
            }
        })
        .collect()
}

/// What to derive budgets for.
#[derive(Clone, Debug)]
pub struct BudgetSpec {
    pub codec: CodecSpec,
    /// Max tolerable quality loss (`1 - quality`), e.g. `1e-4`.
    pub cap: f64,
    pub seed: u64,
    pub channels: usize,
    pub workloads: Vec<Kind>,
    /// Collect runtime telemetry from the probe sessions (proxy mode;
    /// full-mode suites honor `ZAC_METRICS` instead).
    pub telemetry: bool,
}

impl BudgetSpec {
    pub fn new(codec: CodecSpec, cap: f64) -> BudgetSpec {
        BudgetSpec {
            codec,
            cap,
            seed: 42,
            channels: 1,
            workloads: Kind::all().to_vec(),
            telemetry: false,
        }
    }

    pub fn validate(&self) -> Result<()> {
        self.codec.validate()?;
        anyhow::ensure!(
            self.cap.is_finite() && (0.0..=1.0).contains(&self.cap),
            "quality-loss cap must be in [0, 1], got {}",
            self.cap
        );
        anyhow::ensure!(!self.workloads.is_empty(), "empty workload list");
        Ok(())
    }
}

/// One (workload × technology) row of the budget table.
#[derive(Clone, Debug)]
pub struct BudgetRow {
    pub workload: String,
    /// `"dram"` or `"mram"`.
    pub technology: &'static str,
    /// Deepest rung inside the cap; `None` when even the error-free
    /// rung misses it (the codec's own approximation overruns the cap).
    pub max_bin: Option<String>,
    /// BER of that rung (0.0 when `max_bin` is `None`).
    pub max_tolerable_ber: f64,
    /// Quality at that rung (or at the error-free rung when `None`).
    pub quality_at_max: f64,
    /// Telemetry of the probe run at the budgeted rung, when the spec
    /// asked for it.
    pub telemetry: Option<TelemetrySnapshot>,
}

/// The full budget table for one codec.
#[derive(Clone, Debug)]
pub struct BudgetReport {
    pub codec: String,
    pub cap: f64,
    /// `"proxy"` or `"full"`.
    pub mode: &'static str,
    pub rows: Vec<BudgetRow>,
}

impl BudgetReport {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("codec", s(&self.codec)),
            ("quality_loss_cap", num(self.cap)),
            ("mode", s(self.mode)),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            obj(vec![
                                ("workload", s(&r.workload)),
                                ("technology", s(r.technology)),
                                (
                                    "max_bin",
                                    r.max_bin.as_deref().map_or(Json::Null, s),
                                ),
                                ("max_tolerable_ber", num(r.max_tolerable_ber)),
                                ("quality_at_max", num(r.quality_at_max)),
                                (
                                    "telemetry",
                                    r.telemetry.as_ref().map_or(Json::Null, |t| t.to_json()),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Human-readable table, one row per (workload × technology).
    pub fn render_table(&self) -> String {
        let mut t = TextTable::new(&["workload", "tech", "max bin", "max BER", "quality"]);
        for r in &self.rows {
            t.row(vec![
                r.workload.clone(),
                r.technology.into(),
                r.max_bin.clone().unwrap_or_else(|| "(none)".into()),
                format!("{:.0e}", r.max_tolerable_ber),
                f(r.quality_at_max, 4),
            ]);
        }
        format!(
            "BER budgets for {} at quality-loss cap {:.1e} ({} mode)\n{}",
            self.codec,
            self.cap,
            self.mode,
            t.render()
        )
    }

    /// Read-modify-write a `BENCH_system.json`-shaped file: set the
    /// `"budget"` key, preserving any sweep scenarios already there.
    /// Creates the file as `{"budget": ...}` when absent.
    pub fn merge_into(&self, path: &str) -> Result<()> {
        let mut root = match std::fs::read_to_string(path) {
            Ok(text) => Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("parsing existing {path}: {e}"))?,
            Err(_) => Json::Obj(Default::default()),
        };
        match &mut root {
            Json::Obj(m) => {
                m.insert("budget".into(), self.to_json());
            }
            other => anyhow::bail!("{path} is not a JSON object, got {other:?}"),
        }
        crate::util::json_lite::write_file(path, &root)?;
        eprintln!("budget table -> {path} (key \"budget\")");
        Ok(())
    }

    /// Persist the telemetry-only view (the `--metrics-out` artifact):
    /// one entry per row whose probe session carried a snapshot.
    pub fn write_metrics(&self, path: &str) -> Result<()> {
        let rows = self
            .rows
            .iter()
            .filter_map(|r| {
                r.telemetry.as_ref().map(|t| {
                    obj(vec![
                        ("workload", s(&r.workload)),
                        ("technology", s(r.technology)),
                        ("telemetry", t.to_json()),
                    ])
                })
            })
            .collect();
        let root = obj(vec![
            ("codec", s(&self.codec)),
            ("mode", s(self.mode)),
            ("rows", Json::Arr(rows)),
        ]);
        crate::util::json_lite::write_file(path, &root)?;
        eprintln!("metrics -> {path}");
        Ok(())
    }
}

/// Stable per-kind seed offset so proxy corpora don't depend on the
/// order workloads are listed in.
fn kind_index(kind: Kind) -> u64 {
    Kind::all().iter().position(|&k| k == kind).unwrap() as u64
}

/// A model-free stand-in corpus for each workload: the same dataset
/// family its full evaluation reconstructs, sized for millisecond
/// sweeps.
fn proxy_trace(kind: Kind, seed: u64) -> Vec<u8> {
    let seed = seed ^ (0xB0D6 + kind_index(kind));
    let images = match kind {
        Kind::ImageNet | Kind::ResNet => datasets::synth_images(12, seed),
        Kind::Quant => datasets::kodak_like(2, 64, 64, seed),
        Kind::Eigen => datasets::faces_split(8, 4, 4, seed).1,
        Kind::Svm => datasets::fmnist_like(48, seed),
    };
    images.into_iter().flat_map(|i| i.data).collect()
}

/// A ladder-rung quality measurement plus the probe run's telemetry
/// (when enabled).
type Probe = (f64, Option<TelemetrySnapshot>);

/// Trace-level quality proxy (`1 - MAE/255`) of `trace` reconstructed
/// through the codec under one fault model.
fn trace_quality(
    codec: &CodecSpec,
    faults: &FaultSpec,
    trace: &[u8],
    channels: usize,
    telemetry: bool,
) -> Result<Probe> {
    let out = Session::builder()
        .codec(codec.clone())
        .channels(channels)
        .traffic(TrafficClass::Approximate)
        .faults(*faults)
        .telemetry(telemetry)
        .build()?
        .run(&Trace::from_bytes(trace.to_vec()))?;
    let mae = trace
        .iter()
        .zip(&out.bytes)
        .map(|(&a, &b)| (a as f64 - b as f64).abs())
        .sum::<f64>()
        / trace.len().max(1) as f64;
    Ok((1.0 - mae / 255.0, out.telemetry))
}

/// The deepest ladder rung inside the cap, plus the probe telemetry at
/// that rung (or at the error-free rung when nothing fits).
struct LadderPick {
    max_bin: Option<String>,
    max_tolerable_ber: f64,
    quality_at_max: f64,
    telemetry: Option<TelemetrySnapshot>,
}

/// Walk one ladder (BER ascending), returning the deepest rung whose
/// quality loss stays inside the cap. The walk stops at the first
/// failing rung: tolerating a deeper bin but not a shallower one is
/// not a budget a DRAM/MRAM controller can act on.
fn walk_ladder(
    ladder: &[Rung],
    cap: f64,
    mut quality_of: impl FnMut(&FaultSpec) -> Result<Probe>,
) -> Result<LadderPick> {
    let mut best: Option<(String, f64, f64, Option<TelemetrySnapshot>)> = None;
    let mut first: Probe = (1.0, None);
    for (i, rung) in ladder.iter().enumerate() {
        let (q, telemetry) = quality_of(&rung.spec)?;
        if i == 0 {
            first = (q, telemetry.clone());
        }
        if 1.0 - q <= cap {
            best = Some((rung.label.clone(), rung.ber, q, telemetry));
        } else {
            break;
        }
    }
    Ok(match best {
        Some((label, ber, q, telemetry)) => LadderPick {
            max_bin: Some(label),
            max_tolerable_ber: ber,
            quality_at_max: q,
            telemetry,
        },
        None => LadderPick {
            max_bin: None,
            max_tolerable_ber: 0.0,
            quality_at_max: first.0,
            telemetry: first.1,
        },
    })
}

fn derive_with(
    spec: &BudgetSpec,
    mode: &'static str,
    mut quality_of: impl FnMut(Kind, &FaultSpec) -> Result<Probe>,
) -> Result<BudgetReport> {
    spec.validate()?;
    let mut rows = Vec::new();
    for &kind in &spec.workloads {
        for (technology, ladder) in [("dram", dram_ladder()), ("mram", mram_ladder())] {
            let pick = walk_ladder(&ladder, spec.cap, |f| quality_of(kind, f))?;
            rows.push(BudgetRow {
                workload: kind.label().to_string(),
                technology,
                max_bin: pick.max_bin,
                max_tolerable_ber: pick.max_tolerable_ber,
                quality_at_max: pick.quality_at_max,
                telemetry: pick.telemetry,
            });
        }
    }
    Ok(BudgetReport {
        codec: spec.codec.label(),
        cap: spec.cap,
        mode,
        rows,
    })
}

/// Derive the budget table in proxy mode: quality is the trace-level
/// reconstruction quality of each workload's stand-in corpus. No
/// runtime or training required.
pub fn derive_budgets(spec: &BudgetSpec) -> Result<BudgetReport> {
    spec.validate()?;
    // One corpus per workload, reused across every rung of both
    // ladders so rungs differ only in the fault model.
    let traces: Vec<(Kind, Vec<u8>)> = spec
        .workloads
        .iter()
        .map(|&k| (k, proxy_trace(k, spec.seed)))
        .collect();
    derive_with(spec, "proxy", |kind, faults| {
        let trace = &traces.iter().find(|(k, _)| *k == kind).unwrap().1;
        trace_quality(&spec.codec, faults, trace, spec.channels, spec.telemetry)
    })
}

/// Derive the budget table in full mode: quality is the paper's
/// quality ratio from the trained workload [`Suite`].
pub fn derive_budgets_full(suite: &Suite, spec: &BudgetSpec) -> Result<BudgetReport> {
    derive_with(spec, "full", |kind, faults| {
        let r = suite.eval_under(&spec.codec, faults, kind)?;
        Ok((r.quality, r.run.telemetry))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladders_start_error_free_and_ascend() {
        for ladder in [dram_ladder(), mram_ladder()] {
            assert_eq!(ladder[0].ber, 0.0, "{}", ladder[0].label);
            assert!(ladder[0].spec.is_perfect() || ladder[0].spec.validate().is_ok());
            for w in ladder.windows(2) {
                assert!(
                    w[1].ber > w[0].ber,
                    "{} ({}) !> {} ({})",
                    w[1].label,
                    w[1].ber,
                    w[0].label,
                    w[0].ber
                );
            }
        }
        assert_eq!(dram_ladder()[0].label, "vdd1250mV");
        assert_eq!(mram_ladder()[4].label, "mramSaturated");
    }

    #[test]
    fn lossless_codec_with_loose_cap_tolerates_the_deepest_bins() {
        let mut spec = BudgetSpec::new(CodecSpec::named("ORG"), 0.4);
        spec.workloads = vec![Kind::Svm];
        let report = derive_budgets(&spec).unwrap();
        assert_eq!(report.rows.len(), 2);
        let dram = &report.rows[0];
        assert_eq!(dram.technology, "dram");
        assert_eq!(dram.max_bin.as_deref(), Some("vdd900mV"));
        assert!((dram.max_tolerable_ber - 1e-2).abs() < 1e-12);
        // MRAM saturated inverts every bit of the mostly-dark FMNIST
        // corpus — far past the cap, so the budget stops at the
        // aggressive (1e-2) bin, not saturation.
        let mram = &report.rows[1];
        assert_eq!(mram.max_bin.as_deref(), Some("mramAggressive"));
    }

    #[test]
    fn correction_buys_a_deeper_dram_bin_than_the_uncorrected_base() {
        // Acceptance: at a tight quality-loss cap the ECC-wrapped codec
        // tolerates a strictly higher BER bin than its base on at least
        // the DRAM ladder.
        let cap = 2e-4;
        let mut base = BudgetSpec::new(CodecSpec::named("ORG"), cap);
        base.workloads = vec![Kind::ImageNet];
        let mut ecc = BudgetSpec::new(CodecSpec::named("ECC+ORG"), cap);
        ecc.workloads = vec![Kind::ImageNet];
        let b = derive_budgets(&base).unwrap();
        let e = derive_budgets(&ecc).unwrap();
        let b_dram = b.rows.iter().find(|r| r.technology == "dram").unwrap();
        let e_dram = e.rows.iter().find(|r| r.technology == "dram").unwrap();
        assert!(
            e_dram.max_tolerable_ber > b_dram.max_tolerable_ber,
            "ECC+ORG budget {} must beat ORG {}",
            e_dram.max_tolerable_ber,
            b_dram.max_tolerable_ber
        );
    }

    #[test]
    fn report_merges_into_bench_json_preserving_existing_keys() {
        let mut spec = BudgetSpec::new(CodecSpec::named("ORG"), 0.5);
        spec.workloads = vec![Kind::Quant];
        let report = derive_budgets(&spec).unwrap();
        let path = std::env::temp_dir().join("zac_budget_merge_test.json");
        let path = path.to_str().unwrap();
        std::fs::write(path, "{\"name\": \"sweep\", \"scenarios\": []}\n").unwrap();
        report.merge_into(path).unwrap();
        let root = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        // The sweep keys survive; the budget table landed beside them.
        assert_eq!(root.get("name").unwrap().as_str().unwrap(), "sweep");
        let budget = root.get("budget").unwrap();
        assert_eq!(budget.get("mode").unwrap().as_str().unwrap(), "proxy");
        let rows = budget.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(
            rows[0].get("workload").unwrap().as_str().unwrap(),
            "Quant"
        );
        assert!(rows[0].get("max_tolerable_ber").unwrap().as_f64().is_ok());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn telemetry_flag_populates_rows_and_write_metrics() {
        let mut spec = BudgetSpec::new(CodecSpec::named("ORG"), 0.5);
        spec.workloads = vec![Kind::Quant];
        assert!(
            derive_budgets(&spec)
                .unwrap()
                .rows
                .iter()
                .all(|r| r.telemetry.is_none()),
            "telemetry must stay off by default"
        );
        spec.telemetry = true;
        let report = derive_budgets(&spec).unwrap();
        assert!(report.rows.iter().all(|r| r.telemetry.is_some()));
        let snap = report.rows[0].telemetry.as_ref().unwrap();
        assert!(snap.shards[0].stage_ns.iter().sum::<u64>() > 0);
        let path = std::env::temp_dir().join("zac_budget_metrics_test.json");
        let path = path.to_str().unwrap();
        report.write_metrics(path).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.contains("\"stage_ns\""), "{text}");
        assert!(text.contains("\"service_p99_ns\""), "{text}");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn budget_spec_validates_cap_and_workloads() {
        let spec = BudgetSpec::new(CodecSpec::named("ORG"), 1.5);
        assert!(spec.validate().is_err());
        let mut spec = BudgetSpec::new(CodecSpec::named("ORG"), 0.1);
        spec.workloads.clear();
        assert!(spec.validate().is_err());
        assert!(BudgetSpec::new(CodecSpec::named("ORG"), 0.0)
            .validate()
            .is_ok());
    }
}
