//! The five evaluation workloads (paper §VII-A) and the [`Suite`] that
//! trains them once on clean data, then evaluates any encoder
//! configuration by reconstructing the test traces through the channel
//! and re-running the models (Fig. 9 workflow).
//!
//! | paper workload | here | quality metric |
//! |---|---|---|
//! | ImageNet CNN zoo | [`Kind::ImageNet`] | mean top-1 ratio over the zoo |
//! | ResNet/CIFAR-100 | [`Kind::ResNet`]   | top-1 ratio (supports train-on-reconstructed) |
//! | Quant (K-Means)  | [`Kind::Quant`]    | SSIM ratio |
//! | Eigen (PCA)      | [`Kind::Eigen`]    | identification-accuracy ratio |
//! | SVM (FMNIST)     | [`Kind::Svm`]      | accuracy ratio |

pub mod budget;
pub mod cnn;
pub mod eigen;
pub mod quant;
pub mod svm;

pub use budget::{derive_budgets, derive_budgets_full, BudgetReport, BudgetSpec};

use anyhow::Result;

use crate::datasets::{self, Image};
use crate::encoding::CodecSpec;
use crate::faults::FaultSpec;
use crate::quality::quality_ratio;
use crate::runtime::Runtime;
use crate::session::{RunReport, Session, Trace, TrafficClass};
use crate::system::AddressSpec;

/// Workload identifiers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Kind {
    ImageNet,
    ResNet,
    Quant,
    Eigen,
    Svm,
}

impl Kind {
    pub fn all() -> [Kind; 5] {
        [Kind::ImageNet, Kind::ResNet, Kind::Quant, Kind::Eigen, Kind::Svm]
    }

    pub fn label(self) -> &'static str {
        match self {
            Kind::ImageNet => "ImageNet",
            Kind::ResNet => "ResNet",
            Kind::Quant => "Quant",
            Kind::Eigen => "Eigen",
            Kind::Svm => "SVM",
        }
    }

    pub fn parse(s: &str) -> Option<Kind> {
        match s.to_ascii_lowercase().as_str() {
            "imagenet" => Some(Kind::ImageNet),
            "resnet" => Some(Kind::ResNet),
            "quant" => Some(Kind::Quant),
            "eigen" => Some(Kind::Eigen),
            "svm" => Some(Kind::Svm),
            _ => None,
        }
    }
}

/// One workload evaluation under one encoder configuration.
#[derive(Clone, Debug)]
pub struct WorkloadResult {
    pub kind: Kind,
    /// The paper's quality ratio (approx / original metric).
    pub quality: f64,
    pub original_metric: f64,
    pub approx_metric: f64,
    /// Channel counts + encoding stats of the workload's input trace.
    pub run: RunReport,
}

/// Training/evaluation budget (sized so the full suite builds in
/// minutes on CPU-PJRT; `quick()` for tests).
#[derive(Clone, Copy, Debug)]
pub struct SuiteBudget {
    pub zoo_size: usize,
    pub train_images: usize,
    pub eval_images: usize,
    pub train_steps: usize,
    pub lr: f32,
    pub svm_train: usize,
    pub svm_test: usize,
    pub svm_steps: usize,
    pub pca_iters: usize,
    pub kmeans_iters: usize,
    pub kodak_images: usize,
}

impl SuiteBudget {
    pub fn full() -> Self {
        SuiteBudget {
            zoo_size: 4,
            train_images: 512,
            eval_images: 128,
            train_steps: 240,
            lr: 0.08,
            svm_train: 640,
            svm_test: 128,
            svm_steps: 200,
            pca_iters: 25,
            kmeans_iters: 6,
            kodak_images: 4,
        }
    }

    pub fn quick() -> Self {
        SuiteBudget {
            zoo_size: 1,
            train_images: 128,
            eval_images: 32,
            train_steps: 12,
            lr: 0.08,
            svm_train: 128,
            svm_test: 64,
            svm_steps: 30,
            pca_iters: 8,
            kmeans_iters: 3,
            kodak_images: 1,
        }
    }
}

/// Everything trained/learned on clean data, reusable across encoder
/// configurations (the expensive part of the Fig. 9 workflow).
pub struct Suite {
    pub rt: Runtime,
    pub seed: u64,
    pub budget: SuiteBudget,
    /// Channels the reconstruction traffic shards across (run TOML
    /// `channels`; default 1, the paper's single-channel setup).
    pub channels: usize,
    /// Address-mapping policy for the sharded reconstruction traffic
    /// (run TOML `address`; default round-robin).
    pub address: AddressSpec,
    // ImageNet zoo + ResNet.
    pub train_images: Vec<Image>,
    pub test_images: Vec<Image>,
    pub zoo: Vec<cnn::CnnParams>,
    pub zoo_clean_acc: Vec<f64>,
    pub resnet: cnn::CnnParams,
    pub resnet_clean_acc: f64,
    // Quant.
    pub kodak: Vec<Image>,
    pub quant_clean_ssim: Vec<f64>,
    // Eigen.
    pub faces_test: Vec<Image>,
    pub eigen_model: eigen::EigenModel,
    pub eigen_clean_acc: f64,
    // SVM.
    pub fmnist_test: Vec<Image>,
    pub svm_w: crate::runtime::Tensor,
    pub svm_clean_acc: f64,
}

impl Suite {
    /// Train all five workloads on clean data. Deterministic per seed.
    pub fn build(rt: Runtime, seed: u64, budget: SuiteBudget) -> Result<Suite> {
        // --- CNN corpora. ---
        let train_images = datasets::synth_images(budget.train_images, seed);
        let test_images = datasets::synth_images(budget.eval_images, seed ^ 0x7e57);
        let mut zoo = Vec::with_capacity(budget.zoo_size);
        let mut zoo_clean_acc = Vec::with_capacity(budget.zoo_size);
        for m in 0..budget.zoo_size {
            let (p, _losses) = cnn::train(
                &rt,
                &train_images,
                budget.train_steps,
                budget.lr,
                seed + 1000 * m as u64,
            )?;
            zoo_clean_acc.push(cnn::accuracy(&rt, &p, &test_images)?);
            zoo.push(p);
        }
        // ResNet analogue: same architecture, trained longer.
        let (resnet, _) = cnn::train(
            &rt,
            &train_images,
            budget.train_steps * 3 / 2,
            budget.lr,
            seed ^ 0x2E5,
        )?;
        let resnet_clean_acc = cnn::accuracy(&rt, &resnet, &test_images)?;

        // --- Quant. ---
        let kodak = datasets::kodak_like(budget.kodak_images, 64, 64, seed ^ 0x0d);
        let mut quant_clean_ssim = Vec::with_capacity(kodak.len());
        for img in &kodak {
            quant_clean_ssim.push(quant::quant_ssim(&rt, img, img, budget.kmeans_iters)?);
        }

        // --- Eigen: same identities, disjoint samples (Yale protocol). ---
        let (faces_train, faces_test) = datasets::faces_split(16, 8, 8, seed ^ 0xFA);
        let eigen_model = eigen::fit(&rt, &faces_train, budget.pca_iters, seed)?;
        let eigen_clean_acc = eigen_model.identify_accuracy(&rt, &faces_test)?;

        // --- SVM. ---
        let fmnist_train = datasets::fmnist_like(budget.svm_train, seed ^ 0x5f);
        let fmnist_test = datasets::fmnist_like(budget.svm_test, seed ^ 0x5e);
        let (svm_w, _) = svm::train(&rt, &fmnist_train, budget.svm_steps, 0.05, seed)?;
        let svm_clean_acc = svm::accuracy(&rt, &svm_w, &fmnist_test)?;

        Ok(Suite {
            rt,
            seed,
            budget,
            channels: 1,
            address: AddressSpec::round_robin(),
            train_images,
            test_images,
            zoo,
            zoo_clean_acc,
            resnet,
            resnet_clean_acc,
            kodak,
            quant_clean_ssim,
            faces_test,
            eigen_model,
            eigen_clean_acc,
            fmnist_test,
            svm_w,
            svm_clean_acc,
        })
    }

    /// Reconstruct a set of images through the (perfect) channel under
    /// `spec`, returning the approximate images plus the trace
    /// energy/stats. Runs through the unified [`Session`] API (image
    /// traffic is the paper's error-resilient class).
    pub fn reconstruct_images(
        &self,
        spec: &CodecSpec,
        images: &[Image],
    ) -> Result<(Vec<Image>, RunReport)> {
        self.reconstruct_images_under(spec, &FaultSpec::perfect(), images)
    }

    /// [`Suite::reconstruct_images`] with the channel running under a
    /// fault model — the Fig. 9 workflow with an EDEN/SparkXD-style
    /// approximate-DRAM channel instead of a perfect one.
    pub fn reconstruct_images_under(
        &self,
        spec: &CodecSpec,
        faults: &FaultSpec,
        images: &[Image],
    ) -> Result<(Vec<Image>, RunReport)> {
        // One concatenated trace: better table locality and one energy
        // figure for the whole set, as in the paper's methodology.
        let mut bytes = Vec::new();
        for img in images {
            bytes.extend_from_slice(&img.data);
        }
        let out = Session::builder()
            .codec(spec.clone())
            .channels(self.channels)
            .address(self.address.clone())
            .traffic(TrafficClass::Approximate)
            .faults(*faults)
            .build()?
            .run(&Trace::from_bytes(bytes))?;
        let mut rebuilt = Vec::with_capacity(images.len());
        let mut off = 0usize;
        for img in images {
            let n = img.data.len();
            rebuilt.push(img.with_data(out.bytes[off..off + n].to_vec()));
            off += n;
        }
        Ok((rebuilt, out))
    }

    /// Evaluate one workload under one encoder configuration over a
    /// perfect channel.
    pub fn eval(&self, spec: &CodecSpec, kind: Kind) -> Result<WorkloadResult> {
        self.eval_under(spec, &FaultSpec::perfect(), kind)
    }

    /// Evaluate one workload with the channel running under a fault
    /// model: output quality under injection, the paper's quality axis
    /// extended with the EDEN error models.
    pub fn eval_under(
        &self,
        spec: &CodecSpec,
        faults: &FaultSpec,
        kind: Kind,
    ) -> Result<WorkloadResult> {
        match kind {
            Kind::ImageNet => {
                let (recon, run) =
                    self.reconstruct_images_under(spec, faults, &self.test_images)?;
                let mut ratios = Vec::new();
                let mut approx_mean = 0.0;
                for (p, &clean) in self.zoo.iter().zip(&self.zoo_clean_acc) {
                    let acc = cnn::accuracy(&self.rt, p, &recon)?;
                    approx_mean += acc;
                    ratios.push(quality_ratio(acc, clean));
                }
                let n = self.zoo.len() as f64;
                Ok(WorkloadResult {
                    kind,
                    quality: ratios.iter().sum::<f64>() / n,
                    original_metric: self.zoo_clean_acc.iter().sum::<f64>() / n,
                    approx_metric: approx_mean / n,
                    run,
                })
            }
            Kind::ResNet => {
                let (recon, run) =
                    self.reconstruct_images_under(spec, faults, &self.test_images)?;
                let acc = cnn::accuracy(&self.rt, &self.resnet, &recon)?;
                Ok(WorkloadResult {
                    kind,
                    quality: quality_ratio(acc, self.resnet_clean_acc),
                    original_metric: self.resnet_clean_acc,
                    approx_metric: acc,
                    run,
                })
            }
            Kind::Quant => {
                let (recon, run) = self.reconstruct_images_under(spec, faults, &self.kodak)?;
                let mut q = 0.0;
                let mut approx = 0.0;
                for ((r, orig), &clean) in
                    recon.iter().zip(&self.kodak).zip(&self.quant_clean_ssim)
                {
                    let ssim = quant::quant_ssim(&self.rt, r, orig, self.budget.kmeans_iters)?;
                    approx += ssim;
                    q += quality_ratio(ssim, clean);
                }
                let n = recon.len() as f64;
                Ok(WorkloadResult {
                    kind,
                    quality: q / n,
                    original_metric: self.quant_clean_ssim.iter().sum::<f64>() / n,
                    approx_metric: approx / n,
                    run,
                })
            }
            Kind::Eigen => {
                let (recon, run) =
                    self.reconstruct_images_under(spec, faults, &self.faces_test)?;
                let acc = self.eigen_model.identify_accuracy(&self.rt, &recon)?;
                Ok(WorkloadResult {
                    kind,
                    quality: quality_ratio(acc, self.eigen_clean_acc),
                    original_metric: self.eigen_clean_acc,
                    approx_metric: acc,
                    run,
                })
            }
            Kind::Svm => {
                let (recon, run) =
                    self.reconstruct_images_under(spec, faults, &self.fmnist_test)?;
                let acc = svm::accuracy(&self.rt, &self.svm_w, &recon)?;
                Ok(WorkloadResult {
                    kind,
                    quality: quality_ratio(acc, self.svm_clean_acc),
                    original_metric: self.svm_clean_acc,
                    approx_metric: acc,
                    run,
                })
            }
        }
    }

    /// The train/test-mismatch experiment, reshaped for fault injection
    /// (EDEN §5 / SparkXD Fig. 8): evaluate the ResNet under a faulty
    /// channel when it was trained (a) on clean data — *fault-oblivious*,
    /// the paper's up-to-large quality loss — versus (b) on data
    /// reconstructed through the *same* faulty channel — *fault-aware*
    /// (curriculum = deployment), which recovers most of the loss.
    /// Returns `(oblivious, aware)`.
    pub fn resnet_fault_mismatch(
        &self,
        spec: &CodecSpec,
        faults: &FaultSpec,
    ) -> Result<(WorkloadResult, WorkloadResult)> {
        let (recon_test, run) =
            self.reconstruct_images_under(spec, faults, &self.test_images)?;
        // (a) Fault-oblivious: the clean-trained model meets faults for
        // the first time at evaluation.
        let oblivious_acc = cnn::accuracy(&self.rt, &self.resnet, &recon_test)?;
        // (b) Fault-aware: train a fresh model on the same faulty
        // reconstruction pipeline it will be evaluated under.
        let (recon_train, _) =
            self.reconstruct_images_under(spec, faults, &self.train_images)?;
        let (aware_params, _) = cnn::train(
            &self.rt,
            &recon_train,
            self.budget.train_steps * 3 / 2,
            self.budget.lr,
            self.seed ^ 0xFA17,
        )?;
        let aware_acc = cnn::accuracy(&self.rt, &aware_params, &recon_test)?;
        let result = |acc: f64, run: RunReport| WorkloadResult {
            kind: Kind::ResNet,
            quality: quality_ratio(acc, self.resnet_clean_acc),
            original_metric: self.resnet_clean_acc,
            approx_metric: acc,
            run,
        };
        Ok((result(oblivious_acc, run.clone()), result(aware_acc, run)))
    }

    /// Fig. 18/21: train a fresh ResNet *on reconstructed* training
    /// images and evaluate it on reconstructed test images.
    pub fn resnet_trained_on_recon(&self, spec: &CodecSpec) -> Result<WorkloadResult> {
        let (recon_train, _) = self.reconstruct_images(spec, &self.train_images)?;
        let (recon_test, run) = self.reconstruct_images(spec, &self.test_images)?;
        let (p, _) = cnn::train(
            &self.rt,
            &recon_train,
            self.budget.train_steps * 3 / 2,
            self.budget.lr,
            self.seed ^ 0x18,
        )?;
        let acc = cnn::accuracy(&self.rt, &p, &recon_test)?;
        Ok(WorkloadResult {
            kind: Kind::ResNet,
            quality: quality_ratio(acc, self.resnet_clean_acc),
            original_metric: self.resnet_clean_acc,
            approx_metric: acc,
            run,
        })
    }

    /// Fig. 20/21: approximate the *weights* of the ResNet with a
    /// weights-mode spec (sign+exponent pinned, projected per chip by
    /// the session's weights codec path), optionally also approximating
    /// the input images, and measure accuracy + the weight-trace energy.
    pub fn resnet_with_approx_weights(
        &self,
        weight_spec: &CodecSpec,
        image_spec: Option<&CodecSpec>,
    ) -> Result<WorkloadResult> {
        let flat = self.resnet.flatten();
        let run = Session::builder()
            .codec_weights(weight_spec.clone())
            .traffic(TrafficClass::Approximate)
            .build()?
            .run(&Trace::from_f32s(&flat))?;
        let recon_w = run.to_f32s();
        let params = self.resnet.unflatten(&recon_w);
        let images = match image_spec {
            Some(ispec) => self.reconstruct_images(ispec, &self.test_images)?.0,
            None => self.test_images.clone(),
        };
        let acc = cnn::accuracy(&self.rt, &params, &images)?;
        Ok(WorkloadResult {
            kind: Kind::ResNet,
            quality: quality_ratio(acc, self.resnet_clean_acc),
            original_metric: self.resnet_clean_acc,
            approx_metric: acc,
            run,
        })
    }
}
