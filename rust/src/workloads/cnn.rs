//! CNN plumbing shared by the ImageNet-zoo and ResNet workloads:
//! parameter init (host-side He init), training loop and batched
//! inference through the `cnn_train_step` / `cnn_infer` artifacts.

use anyhow::Result;

use crate::datasets::Image;
use crate::quality::argmax_rows;
use crate::runtime::{Runtime, Tensor};
use crate::util::rng::Rng;

/// Fixed artifact geometry (must match python/compile/model.py).
pub const BATCH: usize = 32;
pub const IMG: usize = 32;
pub const CLASSES: usize = 10;

/// The six parameter tensors of the residual CNN.
#[derive(Clone, Debug)]
pub struct CnnParams(pub Vec<Tensor>);

/// Parameter shapes, mirroring `CNN_PARAM_SHAPES` in model.py.
pub fn param_shapes() -> Vec<(&'static str, Vec<usize>)> {
    let feat = (IMG / 4) * (IMG / 4) * 16;
    vec![
        ("w1", vec![3, 3, 3, 16]),
        ("b1", vec![16]),
        ("w2", vec![3, 3, 16, 16]),
        ("b2", vec![16]),
        ("w3", vec![feat, CLASSES]),
        ("b3", vec![CLASSES]),
    ]
}

impl CnnParams {
    /// He-initialized parameters (host RNG; deterministic per seed).
    pub fn init(seed: u64) -> CnnParams {
        let mut r = Rng::new(seed ^ 0xC44);
        let ps = param_shapes()
            .into_iter()
            .map(|(name, shape)| {
                let n: usize = shape.iter().product();
                let data = if name.starts_with('w') {
                    let fan_in: usize = shape[..shape.len() - 1].iter().product();
                    let std = (2.0 / fan_in as f64).sqrt() as f32;
                    (0..n).map(|_| r.normal_f32(0.0, std)).collect()
                } else {
                    vec![0.0f32; n]
                };
                Tensor::f32(data, &shape)
            })
            .collect();
        CnnParams(ps)
    }

    /// Flatten all parameters into one f32 stream (weight-trace order).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for t in &self.0 {
            out.extend_from_slice(t.as_f32().unwrap());
        }
        out
    }

    /// Rebuild from a flat stream (e.g. a reconstructed weight trace).
    pub fn unflatten(&self, flat: &[f32]) -> CnnParams {
        let mut out = Vec::with_capacity(self.0.len());
        let mut off = 0usize;
        for t in &self.0 {
            let n = t.shape().iter().product::<usize>();
            out.push(Tensor::f32(flat[off..off + n].to_vec(), t.shape()));
            off += n;
        }
        assert_eq!(off, flat.len());
        CnnParams(out)
    }
}

/// Pack a batch of images (exactly [`BATCH`]) as the NHWC f32 tensor.
pub fn batch_tensor(images: &[&Image]) -> Tensor {
    assert_eq!(images.len(), BATCH);
    let mut data = Vec::with_capacity(BATCH * IMG * IMG * 3);
    for img in images {
        assert_eq!((img.w, img.h, img.channels), (IMG, IMG, 3));
        data.extend(img.to_f32());
    }
    Tensor::f32(data, &[BATCH, IMG, IMG, 3])
}

fn labels_tensor(images: &[&Image]) -> Tensor {
    Tensor::i32(images.iter().map(|i| i.label).collect(), &[BATCH])
}

/// SGD training over shuffled batches; returns (params, loss history).
pub fn train(
    rt: &Runtime,
    images: &[Image],
    steps: usize,
    lr: f32,
    seed: u64,
) -> Result<(CnnParams, Vec<f32>)> {
    assert!(
        images.len() >= BATCH,
        "need at least one batch of training images"
    );
    let mut params = CnnParams::init(seed);
    let mut r = Rng::new(seed ^ 0x7ea1);
    let mut order: Vec<usize> = (0..images.len()).collect();
    let mut losses = Vec::with_capacity(steps);
    let mut cursor = images.len(); // force initial shuffle
    for _ in 0..steps {
        if cursor + BATCH > order.len() {
            r.shuffle(&mut order);
            cursor = 0;
        }
        let batch: Vec<&Image> = order[cursor..cursor + BATCH]
            .iter()
            .map(|&i| &images[i])
            .collect();
        cursor += BATCH;
        let mut args = vec![
            batch_tensor(&batch),
            labels_tensor(&batch),
            Tensor::scalar_f32(lr),
        ];
        args.extend(params.0.iter().cloned());
        let mut out = rt.exec("cnn_train_step", &args)?;
        let loss = out.pop().expect("loss").into_f32()?[0];
        losses.push(loss);
        params = CnnParams(out);
    }
    Ok((params, losses))
}

/// Batched inference; returns predicted classes for every image
/// (the image count must be a multiple of [`BATCH`]).
pub fn predict(rt: &Runtime, params: &CnnParams, images: &[Image]) -> Result<Vec<i32>> {
    assert_eq!(images.len() % BATCH, 0, "predict needs whole batches");
    let mut preds = Vec::with_capacity(images.len());
    for chunk in images.chunks(BATCH) {
        let refs: Vec<&Image> = chunk.iter().collect();
        let mut args = vec![batch_tensor(&refs)];
        args.extend(params.0.iter().cloned());
        let out = rt.exec("cnn_infer", &args)?;
        let logits = out[0].as_f32()?;
        preds.extend(argmax_rows(logits, CLASSES));
    }
    Ok(preds)
}

/// Top-1 accuracy of a parameter set over an image set.
pub fn accuracy(rt: &Runtime, params: &CnnParams, images: &[Image]) -> Result<f64> {
    let preds = predict(rt, params, images)?;
    let labels: Vec<i32> = images.iter().map(|i| i.label).collect();
    Ok(crate::quality::top1(&preds, &labels))
}
