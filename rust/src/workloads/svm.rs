//! SVM: multiclass linear SVM on the sparse FMNIST-analogue corpus
//! (paper §VII-A5 — chosen for its zero-heavy access pattern, which
//! exercises ZAC-DEST's zero-skip path).

use anyhow::Result;

use crate::datasets::Image;
use crate::quality::top1;
use crate::runtime::{Runtime, Tensor};
use crate::util::rng::Rng;

/// Geometry fixed by the artifacts (model.py SVM_*).
pub const D: usize = 784;
pub const C: usize = 10;
pub const B: usize = 64;

fn batch_tensor(images: &[&Image]) -> Tensor {
    assert_eq!(images.len(), B);
    let mut data = Vec::with_capacity(B * D);
    for img in images {
        assert_eq!((img.w * img.h, img.channels), (D, 1));
        data.extend(img.to_f32());
    }
    Tensor::f32(data, &[B, D])
}

/// Train a weight matrix with SGD on the hinge loss.
pub fn train(
    rt: &Runtime,
    images: &[Image],
    steps: usize,
    lr: f32,
    seed: u64,
) -> Result<(Tensor, Vec<f32>)> {
    assert!(images.len() >= B);
    let mut w = Tensor::f32(vec![0.0; D * C], &[D, C]);
    let mut r = Rng::new(seed ^ 0x57a);
    let mut order: Vec<usize> = (0..images.len()).collect();
    let mut cursor = images.len();
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        if cursor + B > order.len() {
            r.shuffle(&mut order);
            cursor = 0;
        }
        let batch: Vec<&Image> = order[cursor..cursor + B].iter().map(|&i| &images[i]).collect();
        cursor += B;
        let y = Tensor::i32(batch.iter().map(|i| i.label).collect(), &[B]);
        let out = rt.exec(
            "svm_train_step",
            &[w, batch_tensor(&batch), y, Tensor::scalar_f32(lr)],
        )?;
        let mut it = out.into_iter();
        w = it.next().expect("weights");
        losses.push(it.next().expect("loss").into_f32()?[0]);
    }
    Ok((w, losses))
}

/// Classification accuracy over whole batches of [`B`] images.
pub fn accuracy(rt: &Runtime, w: &Tensor, images: &[Image]) -> Result<f64> {
    assert_eq!(images.len() % B, 0, "svm eval needs whole batches");
    let mut preds = Vec::with_capacity(images.len());
    for chunk in images.chunks(B) {
        let refs: Vec<&Image> = chunk.iter().collect();
        let out = rt.exec("svm_infer", &[w.clone(), batch_tensor(&refs)])?;
        preds.extend_from_slice(out[0].as_i32()?);
    }
    let labels: Vec<i32> = images.iter().map(|i| i.label).collect();
    Ok(top1(&preds, &labels))
}
