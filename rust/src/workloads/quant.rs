//! Quant: K-Means colour quantization (paper §VII-A3).
//!
//! Pixels of a 64×64 image (= exactly the artifact's 4096-point block)
//! are clustered to K=64 colours via the `kmeans_step` artifact; output
//! quality is SSIM of the quantized image against the *original*
//! reference, and the workload quality is the paper's ratio
//! SSIM(quantize(reconstructed)) / SSIM(quantize(original)).

use anyhow::Result;

use crate::datasets::Image;
use crate::quality::ssim_rgb;
use crate::runtime::{Runtime, Tensor};

/// Geometry fixed by the artifact (model.py KMEANS_*).
pub const N: usize = 4096;
pub const K: usize = 64;

/// Pixels of an interleaved-RGB image as the (N, 3) f32 tensor.
fn pixels_tensor(img: &Image) -> Tensor {
    assert_eq!(img.channels, 3);
    assert_eq!(img.w * img.h, N, "quant expects 64x64 images");
    Tensor::f32(img.to_f32(), &[N, 3])
}

/// Deterministic init: K pixels evenly strided through the image.
fn init_centroids(img: &Image) -> Tensor {
    let px = img.to_f32();
    let stride = N / K;
    let mut c = Vec::with_capacity(K * 3);
    for k in 0..K {
        let p = k * stride + stride / 2;
        c.extend_from_slice(&px[p * 3..p * 3 + 3]);
    }
    Tensor::f32(c, &[K, 3])
}

/// Run Lloyd iterations and return the colour-quantized image.
pub fn quantize(rt: &Runtime, img: &Image, iters: usize) -> Result<Image> {
    let x = pixels_tensor(img);
    let mut c = init_centroids(img);
    let mut assign: Option<Vec<i32>> = None;
    for _ in 0..iters {
        let out = rt.exec("kmeans_step", &[x.clone(), c])?;
        let mut it = out.into_iter();
        c = it.next().expect("centroids");
        let _counts = it.next();
        assign = Some(it.next().expect("assign").into_i32()?);
    }
    let assign = match assign {
        Some(a) => a,
        None => rt.exec("kmeans_assign", &[x.clone(), c.clone()])?[0]
            .clone()
            .into_i32()?,
    };
    let cents = c.as_f32()?;
    let mut data = Vec::with_capacity(N * 3);
    for &a in &assign {
        let a = a as usize;
        for ch in 0..3 {
            data.push((cents[a * 3 + ch].clamp(0.0, 1.0) * 255.0) as u8);
        }
    }
    Ok(img.with_data(data))
}

/// SSIM of the quantized version of `input` against the `reference`
/// original (the paper's Quant quality metric).
pub fn quant_ssim(rt: &Runtime, input: &Image, reference: &Image, iters: usize) -> Result<f64> {
    let q = quantize(rt, input, iters)?;
    Ok(ssim_rgb(&q.data, &reference.data, reference.w, reference.h))
}
