//! Eigen: PCA face identification (paper §VII-A4).
//!
//! PCA basis learned from clean training faces via the `pca_cov` and
//! `pca_power_iter` artifacts (blocked power iteration with in-graph
//! Gram-Schmidt — no LAPACK custom-calls, which PJRT-CPU 0.5.1 cannot
//! execute); identification is nearest-neighbour in eigenspace.

use anyhow::Result;

use crate::datasets::Image;
use crate::runtime::{Runtime, Tensor};
use crate::util::rng::Rng;

/// Geometry fixed by the artifacts (model.py FACE_* / PCA_K).
pub const N: usize = 128;
pub const D: usize = 576; // 24*24
pub const KDIM: usize = 16;

/// The trained eigenface model.
#[derive(Clone, Debug)]
pub struct EigenModel {
    pub mean: Tensor,       // (D,)
    pub components: Tensor, // (D, KDIM)
    /// Projected gallery (training) faces + labels.
    gallery: Vec<[f32; KDIM]>,
    gallery_labels: Vec<i32>,
}

fn faces_tensor(faces: &[Image]) -> Tensor {
    assert_eq!(faces.len(), N, "eigen expects exactly {N} faces");
    let mut data = Vec::with_capacity(N * D);
    for f in faces {
        assert_eq!((f.w * f.h, f.channels), (D, 1));
        // Per-face photometric normalization (zero mean, unit norm) —
        // standard eigenfaces preprocessing so illumination does not
        // dominate the principal components.
        let px = f.to_f32();
        let mean = px.iter().sum::<f32>() / px.len() as f32;
        let norm = px
            .iter()
            .map(|v| (v - mean) * (v - mean))
            .sum::<f32>()
            .sqrt()
            .max(1e-6);
        data.extend(px.iter().map(|v| (v - mean) / norm));
    }
    Tensor::f32(data, &[N, D])
}

/// Fit PCA on clean training faces and index them as the gallery.
pub fn fit(rt: &Runtime, train: &[Image], power_iters: usize, seed: u64) -> Result<EigenModel> {
    let x = faces_tensor(train);
    let out = rt.exec("pca_cov", &[x.clone()])?;
    let cov = out[0].clone();
    let mean = out[1].clone();
    // Random init, then blocked power iteration.
    let mut r = Rng::new(seed ^ 0xe1ce);
    let mut v = Tensor::f32(
        (0..D * KDIM).map(|_| r.normal_f32(0.0, 1.0)).collect(),
        &[D, KDIM],
    );
    for _ in 0..power_iters {
        v = rt.exec("pca_power_iter", &[cov.clone(), v])?.remove(0);
    }
    let proj = project(rt, &x, &mean, &v)?;
    Ok(EigenModel {
        mean,
        components: v,
        gallery: proj,
        gallery_labels: train.iter().map(|f| f.label).collect(),
    })
}

fn project(rt: &Runtime, x: &Tensor, mean: &Tensor, v: &Tensor) -> Result<Vec<[f32; KDIM]>> {
    let out = rt.exec("pca_project", &[x.clone(), mean.clone(), v.clone()])?;
    let flat = out[0].as_f32()?;
    Ok(flat
        .chunks_exact(KDIM)
        .map(|c| {
            let mut a = [0f32; KDIM];
            a.copy_from_slice(c);
            a
        })
        .collect())
}

impl EigenModel {
    /// Identify each probe face by nearest gallery neighbour; returns
    /// identification accuracy.
    pub fn identify_accuracy(&self, rt: &Runtime, probes: &[Image]) -> Result<f64> {
        let x = faces_tensor(probes);
        let proj = project(rt, &x, &self.mean, &self.components)?;
        let mut correct = 0usize;
        for (p, face) in proj.iter().zip(probes) {
            let mut best = (f32::INFINITY, -1i32);
            for (g, &lab) in self.gallery.iter().zip(&self.gallery_labels) {
                let mut d = 0f32;
                for k in 0..KDIM {
                    let t = p[k] - g[k];
                    d += t * t;
                }
                if d < best.0 {
                    best = (d, lab);
                }
            }
            if best.1 == face.label {
                correct += 1;
            }
        }
        Ok(correct as f64 / probes.len() as f64)
    }
}
