//! DRAM channel substrate: burst serialization + energy accounting.
//!
//! Transfer granularity (§III): a 64 B cache line moves as 8 beats of
//! 64 bits; each of the 8 x8 chips drives 8 data lines, so one chip
//! contributes one 64-bit word per cache line (byte *b* of the word on
//! beat *b*). Termination energy (POD) is proportional to the 1s driven;
//! switching energy to 1→0 transitions per line, with line state
//! persisting across transfers.

pub mod energy;

pub use energy::{EnergyCounts, EnergyModel};

use crate::encoding::WireWord;
use crate::util::bits::{falling_edges, transpose8x8};

/// Number of x8 chips on the channel (§VIII-A: 8-chip DRAMs).
pub const CHIPS: usize = 8;
/// Data lines per chip.
pub const LINES_PER_CHIP: usize = 8;
/// Beats per burst.
pub const BEATS: usize = 8;

/// One chip's share of the channel: 8 data lines + DBI + index + flag +
/// ECC sidebands, with per-line persistent state for switching energy.
#[derive(Clone, Debug)]
pub struct ChipChannel {
    /// Last driven level of each data line, packed one line per byte
    /// (byte `l` ∈ {0, 1}) so all 8 lines update in one SWAR step.
    data_state: u64,
    /// Last driven level of each ECC sideband line, same packing as
    /// `data_state` (non-correcting schemes keep every line idle low:
    /// zero transitions, zero termination — free by construction).
    ecc_state: u64,
    dbi_state: bool,
    index_state: bool,
    flag_state: bool,
    counts: EnergyCounts,
}

impl Default for ChipChannel {
    fn default() -> Self {
        Self::new()
    }
}

impl ChipChannel {
    /// Lines idle low (POD idles terminated at V_dd = logic 0).
    pub fn new() -> Self {
        ChipChannel {
            data_state: 0,
            ecc_state: 0,
            dbi_state: false,
            index_state: false,
            flag_state: false,
            counts: EnergyCounts::default(),
        }
    }

    /// Serialize one wire word over the burst, accumulating termination
    /// ones and per-line 1→0 switching transitions.
    #[inline]
    pub fn transmit(&mut self, wire: &WireWord) {
        // Termination: every 1 driven on any line costs I_term for a beat.
        self.counts.termination_ones += wire.total_ones() as u64;

        // Switching on the 8 data lines, all at once: transpose the
        // (beat × line) bit matrix so byte `l` of `lanes` is line l's
        // per-beat sequence, then count falling edges of every lane with
        // one shift/mask/POPCNT (the per-lane loop this replaces cost
        // ~40 ns/word — EXPERIMENTS.md §Perf).
        let lanes = transpose8x8(wire.data);
        let shifted = ((lanes << 1) & 0xFEFE_FEFE_FEFE_FEFE) | self.data_state;
        self.counts.switching_transitions += (shifted & !lanes).count_ones() as u64;
        self.data_state = (lanes >> 7) & 0x0101_0101_0101_0101;

        // ECC sideband lines: same SWAR path as the data lines. Lines a
        // scheme never drives stay all-zero through the transpose and
        // contribute neither transitions nor state.
        let ecc_lanes = transpose8x8(wire.ecc_line);
        let shifted = ((ecc_lanes << 1) & 0xFEFE_FEFE_FEFE_FEFE) | self.ecc_state;
        self.counts.switching_transitions += (shifted & !ecc_lanes).count_ones() as u64;
        self.ecc_state = (ecc_lanes >> 7) & 0x0101_0101_0101_0101;

        // DBI line.
        let (falls, last) = falling_edges(wire.dbi_mask, self.dbi_state);
        self.counts.switching_transitions += falls as u64;
        self.dbi_state = last;

        // Index line (driven low when unused).
        let seq = if wire.index_used { wire.index_line } else { 0 };
        let (falls, last) = falling_edges(seq, self.index_state);
        self.counts.switching_transitions += falls as u64;
        self.index_state = last;

        // Flag line: single pulse at beat 0 for encoded modes.
        let seq = if wire.flag_ones() > 0 { 1u8 } else { 0 };
        let (falls, last) = falling_edges(seq, self.flag_state);
        self.counts.switching_transitions += falls as u64;
        self.flag_state = last;

        self.counts.transfers += 1;
    }

    /// Serialize a whole batch of wire words, equivalent to calling
    /// [`Self::transmit`] per word: the energy accounting reads the
    /// batch in one pass, letting the per-transfer SWAR steps inline
    /// and the line-state updates stay in registers across the loop.
    pub fn transmit_batch(&mut self, wires: &[WireWord]) {
        for w in wires {
            self.transmit(w);
        }
    }

    /// Accumulated counts.
    pub fn energy(&self) -> &EnergyCounts {
        &self.counts
    }

    /// Reset counts and line state.
    pub fn reset(&mut self) {
        *self = ChipChannel::new();
    }
}

/// The full 8-chip channel: one [`ChipChannel`] per chip.
#[derive(Clone, Debug, Default)]
pub struct Channel {
    chips: Vec<ChipChannel>,
}

impl Channel {
    pub fn new() -> Self {
        Channel {
            chips: (0..CHIPS).map(|_| ChipChannel::new()).collect(),
        }
    }

    pub fn chip_mut(&mut self, i: usize) -> &mut ChipChannel {
        &mut self.chips[i]
    }

    pub fn chips(&self) -> &[ChipChannel] {
        &self.chips
    }

    /// Channel-wide energy counts (sum over chips).
    pub fn total(&self) -> EnergyCounts {
        let mut t = EnergyCounts::default();
        for c in &self.chips {
            t.merge(c.energy());
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::WireWord;

    #[test]
    fn termination_counts_ones() {
        let mut ch = ChipChannel::new();
        ch.transmit(&WireWord::raw(0xFF));
        assert_eq!(ch.energy().termination_ones, 8);
        ch.transmit(&WireWord::raw(0));
        assert_eq!(ch.energy().termination_ones, 8);
    }

    #[test]
    fn switching_counts_falling_edges_across_transfers() {
        let mut ch = ChipChannel::new();
        // Beat 7 (MSByte) leaves all 8 data lines high...
        ch.transmit(&WireWord::raw(0xFF00_0000_0000_0000));
        let s0 = ch.energy().switching_transitions;
        // ...so an all-zero transfer costs 8 falls at entry.
        ch.transmit(&WireWord::raw(0));
        assert_eq!(ch.energy().switching_transitions - s0, 8);
    }

    #[test]
    fn ecc_sideband_costs_termination_and_switching() {
        let mut ch = ChipChannel::new();
        let mut w = WireWord::raw(0);
        // Sideband line 0 high on beat 7: one termination 1, and the
        // next idle transfer pays the falling edge.
        w.ecc_line = 0x0100_0000_0000_0000;
        ch.transmit(&w);
        assert_eq!(ch.energy().termination_ones, 1);
        let s0 = ch.energy().switching_transitions;
        ch.transmit(&WireWord::raw(0));
        assert_eq!(ch.energy().switching_transitions - s0, 1);
    }

    #[test]
    fn alternating_pattern_switches_per_line() {
        let mut ch = ChipChannel::new();
        // Line 0 alternates 1,0,1,0,... across beats: bytes 0x01, 0x00, ...
        let word = 0x0001_0001_0001_0001u64; // beats 0,2,4,6 have line0=1? bytes: b0=01,b1=00,...
        ch.transmit(&WireWord::raw(word));
        // Line 0 sequence = 1,0,1,0,1,0,1,0 -> 4 falling edges.
        assert_eq!(ch.energy().switching_transitions, 4);
    }

    #[test]
    fn energy_merge_of_split_halves_equals_whole_run() {
        // The shard reduction in `system::ChannelArray` sums per-shard
        // `EnergyCounts`. Pin merge(half on channel A, half on channel
        // B) == whole run on one channel, using words whose final beat
        // drives every line low (MSByte zero) so all line state returns
        // to idle at each word boundary and any split point is
        // equivalent to a fresh channel.
        use crate::util::rng::Rng;
        let mut r = Rng::new(22);
        let wires: Vec<WireWord> = (0..256)
            .map(|_| WireWord::raw(r.next_u64() & 0x00FF_FFFF_FFFF_FFFF))
            .collect();
        let mut whole = ChipChannel::new();
        whole.transmit_batch(&wires);
        for split in [0usize, 1, 100, 255, 256] {
            let mut a = ChipChannel::new();
            let mut b = ChipChannel::new();
            a.transmit_batch(&wires[..split]);
            b.transmit_batch(&wires[split..]);
            let mut merged = *a.energy();
            merged.merge(b.energy());
            assert_eq!(merged, *whole.energy(), "split at {split}");
        }
    }

    #[test]
    fn full_channel_aggregates() {
        let mut ch = Channel::new();
        for i in 0..CHIPS {
            ch.chip_mut(i).transmit(&WireWord::raw(0x0F));
        }
        let t = ch.total();
        assert_eq!(t.termination_ones, 4 * CHIPS as u64);
        assert_eq!(t.transfers, CHIPS as u64);
    }
}
