//! Energy bookkeeping: counts → picojoules, plus the DDR4 breakdown
//! constants behind Fig. 2.

/// Raw event counts accumulated by the channel model. The paper reports
/// results as *relative* termination/switching energy, so the counts are
/// the primary quantities; [`EnergyModel`] converts to pJ when absolute
/// numbers are wanted.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EnergyCounts {
    /// 1s driven on any line (termination-energy events, POD §III).
    pub termination_ones: u64,
    /// 1→0 transitions on any line (switching-energy events).
    pub switching_transitions: u64,
    /// Word transfers serialized.
    pub transfers: u64,
}

impl EnergyCounts {
    pub fn merge(&mut self, o: &EnergyCounts) {
        self.termination_ones += o.termination_ones;
        self.switching_transitions += o.switching_transitions;
        self.transfers += o.transfers;
    }

    /// Percent reduction of `self` relative to a baseline (positive =
    /// savings), for the termination metric.
    pub fn termination_savings_vs(&self, base: &EnergyCounts) -> f64 {
        savings(self.termination_ones, base.termination_ones)
    }

    /// Same for switching.
    pub fn switching_savings_vs(&self, base: &EnergyCounts) -> f64 {
        savings(self.switching_transitions, base.switching_transitions)
    }
}

fn savings(ours: u64, base: u64) -> f64 {
    if base == 0 {
        0.0
    } else {
        100.0 * (1.0 - ours as f64 / base as f64)
    }
}

/// Physical constants (DDR4-2400, §III and [9], [14]).
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// Supply voltage (V).
    pub vdd: f64,
    /// Extra termination current while driving a 1 (A) — POD15: 13.75 mA.
    pub i_term: f64,
    /// Beat time (s) — DDR4-2400: 0.833 ns per beat.
    pub t_beat: f64,
    /// Line capacitance (F) — 15 pF per channel line [14].
    pub c_line: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            vdd: 1.2,
            i_term: 13.75e-3,
            t_beat: 0.833e-9,
            c_line: 15e-12,
        }
    }
}

impl EnergyModel {
    /// Termination energy per driven 1 (J): V_dd · I_term · t_beat.
    pub fn term_energy_per_one(&self) -> f64 {
        self.vdd * self.i_term * self.t_beat
    }

    /// Switching energy per 1→0 transition (J): C · V_dd² .
    pub fn switch_energy_per_transition(&self) -> f64 {
        self.c_line * self.vdd * self.vdd
    }

    /// Convert counts to (termination pJ, switching pJ).
    pub fn to_picojoules(&self, c: &EnergyCounts) -> (f64, f64) {
        (
            c.termination_ones as f64 * self.term_energy_per_one() * 1e12,
            c.switching_transitions as f64 * self.switch_energy_per_transition() * 1e12,
        )
    }
}

/// DDR4 DRAM sub-system energy breakdown (Fig. 2, after Seol et al. [14]).
/// Percent of total DRAM energy.
#[derive(Clone, Copy, Debug)]
pub struct Ddr4Breakdown {
    pub io_termination_pct: f64,
    pub io_switching_pct: f64,
    pub core_pct: f64,
    pub background_pct: f64,
}

impl Ddr4Breakdown {
    /// The paper's cited numbers: DRAM I/O = 21% of DRAM energy, of which
    /// termination is 67%.
    pub fn paper() -> Self {
        let io = 21.0;
        let term = io * 0.67;
        Ddr4Breakdown {
            io_termination_pct: term,
            io_switching_pct: io - term,
            core_pct: 49.0,
            background_pct: 100.0 - io - 49.0,
        }
    }

    pub fn io_total_pct(&self) -> f64 {
        self.io_termination_pct + self.io_switching_pct
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn savings_math() {
        let a = EnergyCounts {
            termination_ones: 60,
            switching_transitions: 80,
            transfers: 1,
        };
        let b = EnergyCounts {
            termination_ones: 100,
            switching_transitions: 100,
            transfers: 1,
        };
        assert!((a.termination_savings_vs(&b) - 40.0).abs() < 1e-9);
        assert!((a.switching_savings_vs(&b) - 20.0).abs() < 1e-9);
    }

    #[test]
    fn picojoule_conversion_magnitudes() {
        let m = EnergyModel::default();
        // 13.75 mA * 1.2 V * 0.833 ns ≈ 13.7 pJ per driven 1.
        assert!((m.term_energy_per_one() * 1e12 - 13.74).abs() < 0.1);
        // 15 pF * 1.44 V² = 21.6 pJ per transition.
        assert!((m.switch_energy_per_transition() * 1e12 - 21.6).abs() < 0.1);
    }

    #[test]
    fn breakdown_sums_to_100() {
        let b = Ddr4Breakdown::paper();
        let total = b.io_termination_pct + b.io_switching_pct + b.core_pct + b.background_pct;
        assert!((total - 100.0).abs() < 1e-9);
        assert!((b.io_total_pct() - 21.0).abs() < 1e-9);
    }
}
