//! Declarative command-line parser (offline stand-in for `clap`).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with defaults, positional arguments, and generated `--help` text.

use std::collections::BTreeMap;

/// One option/flag specification.
#[derive(Clone, Debug)]
struct OptSpec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_flag: bool,
}

/// A (sub)command specification.
#[derive(Clone, Debug, Default)]
pub struct Command {
    name: &'static str,
    about: &'static str,
    opts: Vec<OptSpec>,
    positionals: Vec<(&'static str, &'static str)>,
    subs: Vec<Command>,
    /// Environment variables the command honors (documented in help).
    envs: Vec<(&'static str, &'static str)>,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Matches {
    /// Subcommand path, e.g. `["figure"]`.
    pub path: Vec<String>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positionals: Vec<String>,
}

impl Matches {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str) -> anyhow::Result<usize> {
        let v = self
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing --{name}"))?;
        v.parse()
            .map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> anyhow::Result<f64> {
        let v = self
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing --{name}"))?;
        v.parse()
            .map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            ..Default::default()
        }
    }

    /// `--name <value>` option with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// `--name <value>` required option.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: false,
        });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    /// Positional argument (documented; collected in order).
    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    pub fn subcommand(mut self, sub: Command) -> Self {
        self.subs.push(sub);
        self
    }

    /// Document an environment variable the command reads (rendered as
    /// an ENVIRONMENT help section; not parsed from argv).
    pub fn env(mut self, name: &'static str, help: &'static str) -> Self {
        self.envs.push((name, help));
        self
    }

    /// Render `--help`.
    pub fn help(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        if !self.subs.is_empty() {
            out.push_str(" <SUBCOMMAND>");
        }
        for (p, _) in &self.positionals {
            out.push_str(&format!(" <{p}>"));
        }
        out.push_str(" [OPTIONS]\n");
        if !self.positionals.is_empty() {
            out.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                out.push_str(&format!("  <{p:<18}> {h}\n"));
            }
        }
        if !self.opts.is_empty() {
            out.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let kind = if o.is_flag {
                    String::new()
                } else if let Some(d) = &o.default {
                    format!(" <v> [default: {d}]")
                } else {
                    " <v> (required)".to_string()
                };
                out.push_str(&format!("  --{:<22} {}{}\n", o.name, o.help, kind));
            }
        }
        if !self.envs.is_empty() {
            out.push_str("\nENVIRONMENT:\n");
            for (n, h) in &self.envs {
                out.push_str(&format!("  {n:<24} {h}\n"));
            }
        }
        if !self.subs.is_empty() {
            out.push_str("\nSUBCOMMANDS:\n");
            for s in &self.subs {
                out.push_str(&format!("  {:<14} {}\n", s.name, s.about));
            }
        }
        out
    }

    /// Parse an argv slice (without the program name).
    pub fn parse(&self, args: &[String]) -> anyhow::Result<Matches> {
        let mut m = Matches::default();
        self.parse_into(args, &mut m)?;
        Ok(m)
    }

    fn parse_into(&self, args: &[String], m: &mut Matches) -> anyhow::Result<()> {
        // Seed defaults.
        for o in &self.opts {
            if let Some(d) = &o.default {
                m.values.insert(o.name.to_string(), d.clone());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                anyhow::bail!("{}", self.help());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{key}\n\n{}", self.help()))?;
                if spec.is_flag {
                    m.flags.insert(key.to_string(), true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?
                                .clone()
                        }
                    };
                    m.values.insert(key.to_string(), v);
                }
            } else if let Some(sub) = self.subs.iter().find(|s| s.name == a.as_str()) {
                m.path.push(sub.name.to_string());
                return sub.parse_into(&args[i + 1..], m);
            } else {
                m.positionals.push(a.clone());
            }
            i += 1;
        }
        for o in &self.opts {
            if !o.is_flag && !m.values.contains_key(o.name) {
                anyhow::bail!("missing required --{}\n\n{}", o.name, self.help());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    fn app() -> Command {
        Command::new("zac-dest", "test app")
            .subcommand(
                Command::new("figure", "make a figure")
                    .positional("id", "figure id")
                    .opt("seed", "42", "rng seed")
                    .opt("out", "-", "output path")
                    .flag("verbose", "chatty"),
            )
            .subcommand(Command::new("encode", "encode a trace").req("input", "trace file"))
    }

    #[test]
    fn parses_subcommand_with_defaults() {
        let m = app().parse(&argv("figure fig10 --seed 7 --verbose")).unwrap();
        assert_eq!(m.path, vec!["figure"]);
        assert_eq!(m.positionals, vec!["fig10"]);
        assert_eq!(m.get_usize("seed").unwrap(), 7);
        assert_eq!(m.get_or("out", ""), "-");
        assert!(m.flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let m = app().parse(&argv("figure fig14 --seed=9")).unwrap();
        assert_eq!(m.get_usize("seed").unwrap(), 9);
    }

    #[test]
    fn missing_required_errors() {
        assert!(app().parse(&argv("encode")).is_err());
        assert!(app().parse(&argv("encode --input t.hex")).is_ok());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(app().parse(&argv("figure fig10 --nope 1")).is_err());
    }

    #[test]
    fn help_renders() {
        let h = app().help();
        assert!(h.contains("SUBCOMMANDS"));
        assert!(h.contains("figure"));
    }

    #[test]
    fn env_vars_render_in_help() {
        let c = Command::new("x", "env demo")
            .env("ZAC_CHANNELS", "channel counts")
            .env("ZAC_BENCH_BYTES", "trace size");
        let h = c.help();
        assert!(h.contains("ENVIRONMENT"), "{h}");
        assert!(h.contains("ZAC_CHANNELS"), "{h}");
        assert!(h.contains("ZAC_BENCH_BYTES"), "{h}");
        // Commands without env docs keep the section out of help.
        assert!(!app().help().contains("ENVIRONMENT"));
    }
}
