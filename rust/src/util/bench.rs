//! Mini-criterion: warmup + timed iterations with mean/p50/p99 and
//! throughput reporting (offline stand-in for `criterion`).
//!
//! `cargo bench` invokes the `[[bench]]` binaries with `harness = false`;
//! they construct a [`Bencher`] and register closures. Honors
//! `ZAC_BENCH_FAST=1` to shrink iteration counts (used by `make test` so
//! the bench binaries can be smoke-run in CI). Timings are kept in f64
//! nanoseconds — per-iteration costs can be sub-nanosecond once a batch
//! is amortized, which `Duration` would truncate to zero.

use std::time::{Duration, Instant};

/// Measurement statistics for one benchmark (all times in ns).
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// Optional units-per-iteration for throughput reporting.
    pub units: Option<(u64, &'static str)>,
}

impl Stats {
    /// e.g. "12.3 Melem/s".
    pub fn throughput(&self) -> Option<String> {
        let (n, unit) = self.units?;
        let per_sec = n as f64 / (self.mean_ns * 1e-9);
        Some(humanize_rate(per_sec, unit))
    }
}

fn humanize_rate(r: f64, unit: &str) -> String {
    if r >= 1e9 {
        format!("{:.2} G{unit}/s", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2} M{unit}/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2} K{unit}/s", r / 1e3)
    } else {
        format!("{r:.2} {unit}/s")
    }
}

fn humanize_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// The bench harness.
pub struct Bencher {
    /// Target sampling time per benchmark.
    pub sample_time: Duration,
    /// Warmup time before sampling.
    pub warmup: Duration,
    /// Max samples collected.
    pub max_samples: usize,
    results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        if std::env::var("ZAC_BENCH_FAST").map_or(false, |v| v == "1") {
            return Self::fast();
        }
        Bencher {
            sample_time: Duration::from_millis(800),
            warmup: Duration::from_millis(200),
            max_samples: 200,
            results: Vec::new(),
        }
    }

    /// The minimal-iteration configuration `ZAC_BENCH_FAST=1` selects,
    /// constructed directly — tests use this instead of mutating the
    /// process environment (racy under the parallel test runner).
    pub fn fast() -> Self {
        Bencher {
            sample_time: Duration::from_millis(50),
            warmup: Duration::from_millis(10),
            max_samples: 10,
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, preventing the result from being optimized out.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Stats {
        self.bench_units(name, None, &mut f)
    }

    /// Benchmark with a throughput annotation (`units` processed per call).
    pub fn bench_with_units<T>(
        &mut self,
        name: &str,
        units: u64,
        unit_name: &'static str,
        mut f: impl FnMut() -> T,
    ) -> &Stats {
        self.bench_units(name, Some((units, unit_name)), &mut f)
    }

    fn bench_units<T>(
        &mut self,
        name: &str,
        units: Option<(u64, &'static str)>,
        f: &mut dyn FnMut() -> T,
    ) -> &Stats {
        // Warmup.
        let start = Instant::now();
        let mut warm_iters: u64 = 0;
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        // Choose a batch size so one sample is ≥ ~20µs (timer noise floor).
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        let batch = ((20e-6 / per_iter.max(1e-9)).ceil() as usize).max(1);

        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.sample_time && samples.len() < self.max_samples {
            let t = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples.push(t.elapsed().as_secs_f64() * 1e9 / batch as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len().max(1);
        let mean = samples.iter().sum::<f64>() / n as f64;
        let stats = Stats {
            name: name.to_string(),
            iters: n * batch,
            mean_ns: mean,
            p50_ns: samples.get(n / 2).copied().unwrap_or(mean),
            p99_ns: samples.get(n * 99 / 100).copied().unwrap_or(mean),
            units,
        };
        // Unitless benches render "-" in the throughput column rather
        // than silently dropping it: a missing annotation should be
        // visible in the output, not an invisible formatting change.
        let tp = stats
            .throughput()
            .map_or("  (-)".to_string(), |t| format!("  ({t})"));
        println!(
            "bench {:<44} mean {:>10}  p50 {:>10}  p99 {:>10}  iters {:>8}{}",
            stats.name,
            humanize_ns(stats.mean_ns),
            humanize_ns(stats.p50_ns),
            humanize_ns(stats.p99_ns),
            stats.iters,
            tp
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Persist every collected result as machine-readable JSON — one
    /// object per benchmark with `name`, `iters`, `mean_ns`/`p50_ns`/
    /// `p99_ns` and, when the bench declared units, `units_per_iter`,
    /// `unit` and the derived `units_per_sec` (bytes/s for byte-unit
    /// benches). The perf trajectory across PRs diffs these files
    /// (`BENCH_encoder.json` et al.) instead of scraping stdout.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        use crate::util::json_lite::Json;
        let report = Json::Arr(self.json_entries());
        crate::util::json_lite::write_file(path, &report)?;
        println!("bench report -> {path}");
        Ok(())
    }

    /// Like [`write_json`](Self::write_json), but preserves entries an
    /// existing report already holds for benchmarks *not* re-measured
    /// this run (matched by `name`; re-measured names are replaced).
    /// Lets several bench binaries share one artifact — e.g.
    /// `simd_compare` folding into `BENCH_encoder.json` next to the
    /// encoder-throughput rows. A missing file starts fresh; an
    /// unparseable one is an error (fail loud, never clobber a report
    /// we could not read).
    pub fn merge_json(&self, path: &str) -> std::io::Result<()> {
        use crate::util::json_lite::Json;
        let corrupt = |e: String| std::io::Error::new(std::io::ErrorKind::InvalidData, e);
        let mut entries = match std::fs::read_to_string(path) {
            Ok(text) => {
                let prior = Json::parse(&text).map_err(|e| corrupt(format!("{path}: {e}")))?;
                let arr = prior.as_arr().map_err(|e| corrupt(format!("{path}: {e}")))?;
                let fresh: std::collections::HashSet<&str> =
                    self.results.iter().map(|st| st.name.as_str()).collect();
                arr.iter()
                    .filter(|entry| {
                        entry
                            .get("name")
                            .ok()
                            .and_then(|n| n.as_str().ok())
                            .map_or(true, |name| !fresh.contains(name))
                    })
                    .cloned()
                    .collect::<Vec<Json>>()
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e),
        };
        entries.extend(self.json_entries());
        crate::util::json_lite::write_file(path, &Json::Arr(entries))?;
        println!("bench report -> {path} (merged)");
        Ok(())
    }

    fn json_entries(&self) -> Vec<crate::util::json_lite::Json> {
        use crate::util::json_lite::{num, obj, s};
        self.results
            .iter()
            .map(|st| {
                let mut pairs = vec![
                    ("name", s(&st.name)),
                    ("iters", num(st.iters as f64)),
                    ("mean_ns", num(st.mean_ns)),
                    ("p50_ns", num(st.p50_ns)),
                    ("p99_ns", num(st.p99_ns)),
                ];
                if let Some((n, unit)) = st.units {
                    pairs.push(("units_per_iter", num(n as f64)));
                    pairs.push(("unit", s(unit)));
                    pairs.push(("units_per_sec", num(n as f64 / (st.mean_ns * 1e-9))));
                }
                obj(pairs)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher::fast();
        // Seed-audit: spin on the canonical seeded_rng, not an ad-hoc LCG.
        let mut r = crate::util::rng::seeded_rng(0xBE7C);
        let st = b.bench("spin", || std::hint::black_box(r.next_u64()));
        assert!(st.mean_ns > 0.0);
        assert!(st.iters > 0);
    }

    #[test]
    fn write_json_round_trips() {
        use crate::util::json_lite::Json;
        let mut b = Bencher::fast();
        b.bench_with_units("jsn", 64, "B", || std::hint::black_box(1 + 1));
        let path = std::env::temp_dir().join("zac_bench_test.json");
        let path = path.to_str().unwrap();
        b.write_json(path).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "jsn");
        assert!(arr[0].get("mean_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(arr[0].get("units_per_sec").unwrap().as_f64().unwrap() > 0.0);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn merge_json_keeps_other_entries_and_replaces_remeasured_ones() {
        use crate::util::json_lite::Json;
        let path = std::env::temp_dir().join("zac_bench_merge_test.json");
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);
        // First writer: two entries, no existing file (NotFound = fresh).
        let mut a = Bencher::fast();
        a.bench("keep/me", || std::hint::black_box(1 + 1));
        a.bench("replace/me", || std::hint::black_box(2 + 2));
        a.merge_json(path).unwrap();
        // Second writer re-measures one name and adds a new one.
        let mut b = Bencher::fast();
        b.bench("replace/me", || std::hint::black_box(3 + 3));
        b.bench("brand/new", || std::hint::black_box(4 + 4));
        b.merge_json(path).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        let names: Vec<&str> = parsed
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["keep/me", "replace/me", "brand/new"]);
        // A corrupt existing report is an error, never clobbered.
        std::fs::write(path, "not json").unwrap();
        let err = b.merge_json(path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert_eq!(std::fs::read_to_string(path).unwrap(), "not json");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn throughput_formats() {
        assert_eq!(humanize_rate(1.5e6, "elem"), "1.50 Melem/s");
        assert_eq!(humanize_rate(900.0, "word"), "900.00 word/s");
    }

    #[test]
    fn duration_formats() {
        assert_eq!(humanize_ns(500.0), "500.0 ns");
        assert_eq!(humanize_ns(1.5e6), "1.50 ms");
    }
}
