//! Property-testing mini-framework (offline stand-in for `proptest`).
//!
//! Generators are closures over the deterministic [`Rng`](super::rng::Rng);
//! failures report the seed and a shrunk counterexample (halving-style
//! shrinking for integer-like inputs via `Shrink`).

use super::rng::Rng;

/// Number of cases per property (env `ZAC_PROP_CASES` overrides).
pub fn default_cases() -> usize {
    std::env::var("ZAC_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256)
}

/// A value that can propose smaller versions of itself.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate shrinks, roughly ordered most-aggressive first.
    fn shrinks(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for u64 {
    fn shrinks(&self) -> Vec<Self> {
        let mut c = Vec::new();
        if *self > 0 {
            c.push(0);
            c.push(self / 2);
            c.push(self - 1);
            // Clear the highest set bit.
            c.push(self & !(1u64 << (63 - self.leading_zeros())));
        }
        c.dedup();
        c
    }
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        (*self as u64).shrinks().into_iter().map(|x| x as usize).collect()
    }
}

impl Shrink for bool {
    fn shrinks(&self) -> Vec<Self> {
        if *self {
            vec![false]
        } else {
            vec![]
        }
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrinks(&self) -> Vec<Self> {
        let mut c = Vec::new();
        if !self.is_empty() {
            c.push(self[..self.len() / 2].to_vec());
            c.push(self[1..].to_vec());
            let mut tail = self.clone();
            tail.pop();
            c.push(tail);
            // Shrink the first element.
            for s in self[0].shrinks().into_iter().take(2) {
                let mut v = self.clone();
                v[0] = s;
                c.push(v);
            }
        }
        c
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrinks(&self) -> Vec<Self> {
        let mut c: Vec<Self> = self
            .0
            .shrinks()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        c.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b)));
        c
    }
}

/// Run a property: generate `cases` inputs with `gen`, check `prop`,
/// shrink on failure. Panics with the seed + minimal counterexample.
pub fn check<T: Shrink>(
    name: &str,
    seed: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let cases = default_cases();
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Shrink.
            let mut best = (input, msg);
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 10_000 {
                improved = false;
                rounds += 1;
                for cand in best.0.shrinks() {
                    if let Err(m) = prop(&cand) {
                        best = (cand, m);
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property {name:?} failed (seed {seed}, case {case}):\n  \
                 counterexample: {:?}\n  error: {}",
                best.0, best.1
            );
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("u64 xor self is zero", 1, |r| r.next_u64(), |x| {
            if x ^ x == 0 {
                Ok(())
            } else {
                Err("xor".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let r = std::panic::catch_unwind(|| {
            check(
                "all u64 < 1000",
                2,
                |r| r.next_u64(),
                |x| {
                    if *x < 1000 {
                        Ok(())
                    } else {
                        Err(format!("{x} too big"))
                    }
                },
            );
        });
        let msg = format!("{:?}", r.unwrap_err().downcast_ref::<String>().unwrap());
        // Shrinker should land on the boundary value 1000.
        assert!(msg.contains("counterexample: 1000"), "{msg}");
    }

    #[test]
    fn vec_shrinks_reduce_length() {
        let v = vec![5u64, 6, 7];
        assert!(v.shrinks().iter().any(|s| s.len() < 3));
    }
}
