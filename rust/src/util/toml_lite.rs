//! TOML-subset parser (offline stand-in for `toml` + `serde`).
//!
//! Supports the subset the run-config files need: `[section]` and
//! `[section.sub]` headers, `key = value` with strings, integers, floats,
//! booleans and flat arrays, plus `#` comments. Values are exposed
//! through the same [`Json`](super::json_lite::Json) value type so config
//! and manifest plumbing share accessors.

use std::collections::BTreeMap;

use super::json_lite::Json;

/// Parse a TOML-subset document into a nested `Json::Obj`.
pub fn parse(text: &str) -> anyhow::Result<Json> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    let mut section: Vec<String> = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow::anyhow!("line {}: unclosed section", lineno + 1))?
                .trim();
            anyhow::ensure!(!name.is_empty(), "line {}: empty section", lineno + 1);
            section = name.split('.').map(|s| s.trim().to_string()).collect();
            ensure_section(&mut root, &section)?;
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = line[..eq].trim().trim_matches('"').to_string();
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow::anyhow!("line {}: {}", lineno + 1, e))?;
        insert(&mut root, &section, key, value)?;
    }
    Ok(Json::Obj(root))
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside of a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_section(root: &mut BTreeMap<String, Json>, path: &[String]) -> anyhow::Result<()> {
    let mut cur = root;
    for p in path {
        let entry = cur
            .entry(p.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        cur = match entry {
            Json::Obj(m) => m,
            _ => anyhow::bail!("section {p:?} conflicts with a value"),
        };
    }
    Ok(())
}

fn insert(
    root: &mut BTreeMap<String, Json>,
    path: &[String],
    key: String,
    value: Json,
) -> anyhow::Result<()> {
    let mut cur = root;
    for p in path {
        cur = match cur.get_mut(p) {
            Some(Json::Obj(m)) => m,
            _ => anyhow::bail!("missing section {p:?}"),
        };
    }
    anyhow::ensure!(!cur.contains_key(&key), "duplicate key {key:?}");
    cur.insert(key, value);
    Ok(())
}

fn parse_value(v: &str) -> anyhow::Result<Json> {
    anyhow::ensure!(!v.is_empty(), "empty value");
    if let Some(rest) = v.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| anyhow::anyhow!("unclosed array"))?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Json::Arr(items));
    }
    if v.starts_with('"') {
        let inner = v
            .strip_prefix('"')
            .and_then(|s| s.strip_suffix('"'))
            .ok_or_else(|| anyhow::anyhow!("unterminated string {v:?}"))?;
        return Ok(Json::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match v {
        "true" => return Ok(Json::Bool(true)),
        "false" => return Ok(Json::Bool(false)),
        _ => {}
    }
    let clean = v.replace('_', "");
    clean
        .parse::<f64>()
        .map(Json::Num)
        .map_err(|_| anyhow::anyhow!("cannot parse value {v:?}"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let (mut depth, mut in_str, mut start) = (0usize, false, 0usize);
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_run_config() {
        let doc = r#"
            # experiment config
            name = "fig14"
            seed = 42

            [encoder]
            scheme = "ZAC-DEST"
            similarity_limit = 80
            truncation = 0
            tolerance = 0
            table_size = 64

            [workload]
            kinds = ["imagenet", "quant"]
            images = 128
            lr = 0.05
        "#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "fig14");
        assert_eq!(
            v.get("encoder")
                .unwrap()
                .get("similarity_limit")
                .unwrap()
                .as_usize()
                .unwrap(),
            80
        );
        let kinds = v.get("workload").unwrap().get("kinds").unwrap();
        assert_eq!(kinds.as_arr().unwrap().len(), 2);
        assert!((v.get("workload").unwrap().get("lr").unwrap().as_f64().unwrap() - 0.05).abs() < 1e-12);
    }

    #[test]
    fn nested_sections() {
        let v = parse("[a.b]\nx = 1\n[a.c]\ny = 2\n").unwrap();
        assert_eq!(v.get("a").unwrap().get("b").unwrap().get("x").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.get("a").unwrap().get("c").unwrap().get("y").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn comments_and_strings_with_hash() {
        let v = parse("k = \"a#b\" # trailing\n").unwrap();
        assert_eq!(v.get("k").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(parse("a = 1\na = 2\n").is_err());
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("novalue\n").is_err());
        assert!(parse("x = @@\n").is_err());
    }

    #[test]
    fn arrays_of_numbers_and_strings() {
        let v = parse("xs = [1, 2, 3]\nss = [\"a\", \"b\"]\n").unwrap();
        assert_eq!(v.get("xs").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("ss").unwrap().as_arr().unwrap()[1].as_str().unwrap(), "b");
    }
}
