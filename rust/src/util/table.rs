//! Aligned text tables for the figure harness output.

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> Self {
        TextTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with a separator under the header.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(r[c].chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(c);
                let pad = widths[i].saturating_sub(c.chars().count());
                if i + 1 < cells.len() {
                    line.push_str(&" ".repeat(pad));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (cols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
        }
        out
    }
}

/// Format a float with fixed decimals (helper for rows).
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Format a percentage.
pub fn pct(v: f64) -> String {
    format!("{v:.1}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligns_columns() {
        let mut t = TextTable::new(&["scheme", "savings"]);
        t.row(vec!["DBI".into(), pct(28.0)]);
        t.row(vec!["BDE_ORG".into(), pct(20.5)]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert!(lines[0].starts_with("scheme"));
        assert!(lines[1].starts_with("---"));
        assert!(lines[2].contains("28.0%"));
        // Column start of "savings" aligns across rows.
        let col = lines[0].find("savings").unwrap();
        assert_eq!(&lines[3][col..col + 5], "20.5%");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        TextTable::new(&["a", "b"]).row(vec!["x".into()]);
    }
}
