//! Minimal JSON parser/serializer (offline stand-in for `serde_json`).
//!
//! Full JSON grammar (objects, arrays, strings with escapes, numbers,
//! booleans, null); numbers are held as `f64`. Used to read
//! `artifacts/manifest.json` and to write experiment reports.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        anyhow::ensure!(p.i == p.b.len(), "trailing garbage at byte {}", p.i);
        Ok(v)
    }

    pub fn as_obj(&self) -> anyhow::Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => anyhow::bail!("expected object, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> anyhow::Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => anyhow::bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => anyhow::bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => anyhow::bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> anyhow::Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// `obj[key]` with a decent error.
    pub fn get(&self, key: &str) -> anyhow::Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key {key:?}"))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(depth + 1));
                    x.write_pretty(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(depth));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(depth + 1));
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(depth));
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Helper to build objects tersely.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// The one way report artifacts reach disk: pretty-printed with a
/// trailing newline, so `BENCH_*.json` files diff cleanly across PRs
/// regardless of which subsystem wrote them.
pub fn write_file(path: &str, json: &Json) -> std::io::Result<()> {
    std::fs::write(path, json.to_pretty() + "\n")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.peek()? == c,
            "expected {:?} at byte {}, found {:?}",
            c as char,
            self.i,
            self.peek()? as char
        );
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => anyhow::bail!("expected , or }} at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => anyhow::bail!("expected , or ] at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => anyhow::bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // Re-decode UTF-8 from the byte stream.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    anyhow::ensure!(self.i <= self.b.len(), "truncated utf8");
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number {text:?} at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "artifacts": {
            "cnn_infer": {
              "file": "cnn_infer.hlo.txt",
              "args": [{"name": "images", "shape": [32, 32, 32, 3], "dtype": "f32"}],
              "outputs": [{"shape": [32, 10], "dtype": "f32"}]
            }
          }
        }"#;
        let v = Json::parse(doc).unwrap();
        let args = v
            .get("artifacts")
            .unwrap()
            .get("cnn_infer")
            .unwrap()
            .get("args")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(args[0].get("dtype").unwrap().as_str().unwrap(), "f32");
        let shape = args[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape.len(), 4);
        assert_eq!(shape[0].as_usize().unwrap(), 32);
    }

    #[test]
    fn round_trips_escapes_and_numbers() {
        let cases = [
            r#""a\nb\"c\\d""#,
            "[1,2.5,-3,0.001,1e6]",
            "{\"k\":[true,false,null]}",
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{c}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ≈ wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ≈ wörld");
    }

    #[test]
    fn write_file_emits_pretty_json_with_trailing_newline() {
        let v = obj(vec![("k", num(1.0))]);
        let path = std::env::temp_dir().join("zac_json_write_file_test.json");
        let path = path.to_str().unwrap();
        write_file(path, &v).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.ends_with('\n'));
        assert_eq!(text.trim_end(), v.to_pretty());
        assert_eq!(Json::parse(&text).unwrap(), v);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = obj(vec![
            ("a", Json::Arr(vec![num(1.0), num(2.0)])),
            ("b", s("x")),
        ]);
        let p = v.to_pretty();
        assert_eq!(Json::parse(&p).unwrap(), v);
    }
}
