//! Bit-level helpers shared by the encoders and the channel model.

/// Transpose an 8x8 bit matrix held in a `u64`.
///
/// Input layout: byte `b` of `x` is row `b` (beat `b` on the channel),
/// bit `l` of that byte is column `l` (data line `l`). The output has
/// byte `l` = the per-beat bit sequence seen by line `l` — exactly the
/// per-line view the switching-energy model needs.
///
/// Hacker's Delight 7-3 (straight-line, no branches) — this sits on the
/// simulator's hot path.
#[inline]
pub fn transpose8x8(mut x: u64) -> u64 {
    let t = (x ^ (x >> 7)) & 0x00AA00AA00AA00AA;
    x ^= t ^ (t << 7);
    let t = (x ^ (x >> 14)) & 0x0000CCCC0000CCCC;
    x ^= t ^ (t << 14);
    let t = (x ^ (x >> 28)) & 0x00000000F0F0F0F0;
    x ^= t ^ (t << 28);
    x
}

/// Per-line falling-edge (1→0) transition count for one 8-beat transfer.
///
/// `lane_seq` is the line's bit value per beat (bit 0 = first beat),
/// `prev` is the line state left by the previous transfer. Returns
/// (number of 1→0 transitions, final line state).
#[inline]
pub fn falling_edges(lane_seq: u8, prev: bool) -> (u32, bool) {
    // Sequence shifted so bit b holds the value *before* beat b.
    let shifted = (lane_seq << 1) | prev as u8;
    let falling = shifted & !lane_seq;
    (falling.count_ones(), lane_seq & 0x80 != 0)
}

/// Build a repeated per-chunk mask: `bits_per_chunk` ones placed at
/// `offset` within every `chunk_width`-bit chunk of a 64-bit word.
///
/// `make_chunk_mask(8, 2, 6)` = the top-2-bits-of-every-byte mask used by
/// the paper's Tolerance circuit (Fig. 8(1)).
pub fn make_chunk_mask(chunk_width: u32, bits_per_chunk: u32, offset: u32) -> u64 {
    assert!(chunk_width.is_power_of_two() && (8..=64).contains(&chunk_width));
    assert!(bits_per_chunk + offset <= chunk_width);
    if bits_per_chunk == 0 {
        return 0;
    }
    let ones = if bits_per_chunk == 64 {
        u64::MAX
    } else {
        (1u64 << bits_per_chunk) - 1
    };
    let chunk = ones << offset;
    let mut mask = 0u64;
    let mut pos = 0;
    while pos < 64 {
        mask |= chunk << pos;
        pos += chunk_width;
    }
    mask
}

/// MSB-side mask: top `bits_per_chunk` bits of every chunk (Tolerance).
pub fn msb_chunk_mask(chunk_width: u32, bits_per_chunk: u32) -> u64 {
    make_chunk_mask(chunk_width, bits_per_chunk, chunk_width - bits_per_chunk)
}

/// LSB-side mask: bottom `bits_per_chunk` bits of every chunk (Truncation).
pub fn lsb_chunk_mask(chunk_width: u32, bits_per_chunk: u32) -> u64 {
    make_chunk_mask(chunk_width, bits_per_chunk, 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bit(x: u64, row: u32, col: u32) -> bool {
        (x >> (row * 8 + col)) & 1 != 0
    }

    #[test]
    fn transpose_is_involution() {
        // Seed-audit: the canonical seeded_rng stream, not an ad-hoc LCG.
        let mut r = crate::util::rng::seeded_rng(0xB175);
        for _ in 0..100 {
            let s = r.next_u64();
            assert_eq!(transpose8x8(transpose8x8(s)), s);
        }
    }

    #[test]
    fn transpose_moves_bits() {
        let mut s = 1u64;
        for row in 0..8 {
            for col in 0..8 {
                let x = 1u64 << (row * 8 + col);
                let t = transpose8x8(x);
                assert!(bit(t, col, row), "bit ({row},{col})");
                assert_eq!(t.count_ones(), 1);
                s = s.wrapping_add(x);
            }
        }
    }

    #[test]
    fn falling_edges_counts() {
        // 1,0,1,0,... starting from prev=1: falls at beats 1,3,5,7 plus
        // prev(1)->beat0(1)? no. seq bit0=1.
        let (n, last) = falling_edges(0b0101_0101, true);
        assert_eq!(n, 4);
        assert!(!last);
        // all-ones from 0: no falls, ends high.
        let (n, last) = falling_edges(0xFF, false);
        assert_eq!(n, 0);
        assert!(last);
        // single pulse at beat 0 from prev=0: one fall (beat0 -> beat1).
        let (n, last) = falling_edges(0b0000_0001, false);
        assert_eq!(n, 1);
        assert!(!last);
        // prev=1, all-zero seq: one fall at entry.
        let (n, _) = falling_edges(0, true);
        assert_eq!(n, 1);
    }

    #[test]
    fn chunk_masks() {
        assert_eq!(msb_chunk_mask(8, 2), 0xC0C0_C0C0_C0C0_C0C0);
        assert_eq!(msb_chunk_mask(16, 4), 0xF000_F000_F000_F000);
        assert_eq!(lsb_chunk_mask(8, 4), 0x0F0F_0F0F_0F0F_0F0F);
        assert_eq!(lsb_chunk_mask(16, 2), 0x0003_0003_0003_0003);
        assert_eq!(msb_chunk_mask(64, 16), 0xFFFF_0000_0000_0000);
        assert_eq!(lsb_chunk_mask(32, 0), 0);
    }

    #[test]
    fn tolerance_truncation_disjoint_when_sane() {
        let tol = msb_chunk_mask(8, 2);
        let trunc = lsb_chunk_mask(8, 2);
        assert_eq!(tol & trunc, 0);
    }
}
