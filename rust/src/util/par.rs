//! Scoped-thread fan-out helpers (offline stand-in for `rayon`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::thread::JoinHandle;

/// Join every handle, collecting results in order; if any worker
/// panicked, every other worker is still joined (drained) first, then
/// the first panic payload is re-raised. Callers — the streaming
/// `Pipeline` and the `system::ChannelArray` — thus neither leak
/// sibling threads nor mask the root cause behind a generic join error.
pub fn join_all_reraise<T>(workers: Vec<JoinHandle<T>>) -> Vec<T> {
    let mut results = Vec::with_capacity(workers.len());
    let mut panicked = None;
    for w in workers {
        match w.join() {
            Ok(r) => results.push(r),
            Err(p) => panicked = panicked.or(Some(p)),
        }
    }
    if let Some(p) = panicked {
        std::panic::resume_unwind(p);
    }
    results
}

/// Map `f` over `items` on up to `threads` OS threads, preserving order.
///
/// Work distribution is a shared atomic cursor over the item list:
/// every worker claims the next unclaimed index with one `fetch_add`
/// and runs that single item — work-stealing at item granularity. The
/// previous fixed pre-chunking parceled ~4 ranges per thread up front,
/// so one expensive item (a 4-channel ECC cell under MRAM faults next
/// to a 1-channel OHE cell) stranded its whole chunk behind it while
/// sibling workers idled; with the cursor, a worker that finishes a
/// cheap item immediately steals the next pending one. The per-slot
/// mutexes are uncontended by construction (an index is claimed
/// exactly once) — they exist only to share the in/out slots across
/// the scope without `unsafe`, which this repo confines to
/// `encoding/simd.rs`.
///
/// If `f` panics on any item, the siblings drain the remaining work,
/// and the *original* panic payload is re-raised at the call site —
/// the same contract as [`join_all_reraise`] — never a generic
/// "scoped thread panicked" or an `unwrap` on the missing output slot
/// that would mask the root cause.
pub fn par_map<T, U, F>(items: Vec<T>, threads: usize, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let inputs: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let outputs: Vec<Mutex<Option<U>>> =
        std::iter::repeat_with(|| Mutex::new(None)).take(n).collect();
    let next = AtomicUsize::new(0);
    // First worker panic payload, captured (not propagated through the
    // scope, which would replace it with a generic message).
    let panicked: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = inputs[i].lock().unwrap().take().expect("index claimed once");
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(item))) {
                    Ok(v) => *outputs[i].lock().unwrap() = Some(v),
                    Err(p) => {
                        let mut first = panicked.lock().unwrap();
                        if first.is_none() {
                            *first = Some(p);
                        }
                        // This worker stops; siblings drain the rest.
                        return;
                    }
                }
            });
        }
    });
    if let Some(p) = panicked.into_inner().unwrap() {
        std::panic::resume_unwind(p);
    }
    outputs
        .into_iter()
        .map(|o| o.into_inner().unwrap().unwrap())
        .collect()
}

/// Reasonable worker count for this host.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map_or(4, |n| n.get())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = par_map((0..100).collect::<Vec<_>>(), 8, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let out = par_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = par_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn chunking_covers_every_item_for_awkward_sizes() {
        // Sizes around the chunking boundaries: n ≤ threads, n = prime,
        // n just above threads*4.
        for n in [2usize, 3, 7, 8, 9, 31, 33, 97] {
            let out = par_map((0..n as i32).collect::<Vec<_>>(), 8, |x| x + 1);
            assert_eq!(out, (1..=n as i32).collect::<Vec<_>>(), "n={n}");
        }
    }

    #[test]
    fn join_all_reraise_drains_siblings_then_reraises_original_payload() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        // Happy path: results in handle order.
        let hs = vec![std::thread::spawn(|| 1), std::thread::spawn(|| 2)];
        assert_eq!(join_all_reraise(hs), vec![1, 2]);
        // Panic path: the sibling still runs to completion (drained) and
        // the original payload — not a generic join error — is re-raised.
        let sibling_ran = Arc::new(AtomicBool::new(false));
        let flag = sibling_ran.clone();
        let dying = std::thread::spawn(|| -> i32 { panic!("boom") });
        let healthy = std::thread::spawn(move || {
            flag.store(true, Ordering::SeqCst);
            2
        });
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            join_all_reraise(vec![dying, healthy])
        }));
        let payload = caught.unwrap_err();
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"boom"));
        assert!(sibling_ran.load(Ordering::SeqCst));
    }

    #[test]
    fn par_map_reraises_original_worker_panic_payload() {
        // Regression: a worker panic used to surface as a generic
        // scope/unwrap panic, discarding the payload. The original
        // message must survive to the call site.
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            par_map((0..64).collect::<Vec<_>>(), 4, |x| {
                if x == 33 {
                    panic!("item 33 exploded");
                }
                x * 2
            })
        }));
        let payload = caught.unwrap_err();
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"item 33 exploded"));
    }

    #[test]
    fn uneven_item_costs_complete_in_order() {
        // One pathological item (index 0) costs ~50x its neighbours.
        // Under the old fixed pre-chunking its whole chunk queued
        // behind it; the atomic cursor hands every other item to the
        // free workers. Correctness pin: all items complete, in order.
        let out = par_map((0..32).collect::<Vec<_>>(), 4, |x| {
            let ms = if x == 0 { 50 } else { 1 };
            std::thread::sleep(std::time::Duration::from_millis(ms));
            x * 3
        });
        assert_eq!(out, (0..32).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn actually_uses_threads() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let ids = Mutex::new(HashSet::new());
        par_map((0..64).collect::<Vec<_>>(), 4, |x| {
            ids.lock().unwrap().insert(std::thread::current().id());
            std::thread::sleep(std::time::Duration::from_millis(1));
            x
        });
        assert!(ids.lock().unwrap().len() > 1);
    }
}
