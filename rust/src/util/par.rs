//! Scoped-thread fan-out helpers (offline stand-in for `rayon`).

/// Map `f` over `items` on up to `threads` OS threads, preserving order.
pub fn par_map<T, U, F>(items: Vec<T>, threads: usize, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let inputs: Vec<std::sync::Mutex<Option<T>>> =
        items.into_iter().map(|x| std::sync::Mutex::new(Some(x))).collect();
    let outputs: Vec<std::sync::Mutex<Option<U>>> =
        (0..n).map(|_| std::sync::Mutex::new(None)).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = inputs[i].lock().unwrap().take().unwrap();
                *outputs[i].lock().unwrap() = Some(f(item));
            });
        }
    });
    outputs
        .into_iter()
        .map(|m| m.into_inner().unwrap().unwrap())
        .collect()
}

/// Reasonable worker count for this host.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map_or(4, |n| n.get())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = par_map((0..100).collect::<Vec<_>>(), 8, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let out = par_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = par_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn actually_uses_threads() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let ids = Mutex::new(HashSet::new());
        par_map((0..64).collect::<Vec<_>>(), 4, |x| {
            ids.lock().unwrap().insert(std::thread::current().id());
            std::thread::sleep(std::time::Duration::from_millis(1));
            x
        });
        assert!(ids.lock().unwrap().len() > 1);
    }
}
