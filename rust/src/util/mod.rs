//! Self-contained utility substrate.
//!
//! The sandbox vendors only the `xla` + `anyhow` dependency chains, so the
//! usual ecosystem crates are re-implemented here in minimal, fully-tested
//! form (see DESIGN.md for the substitution table):
//!
//! * [`rng`] — deterministic xoshiro256** RNG + distributions (for `rand`)
//! * [`bits`] — bit-matrix transpose and word/lane helpers
//! * [`cli`] — declarative argument parser (for `clap`)
//! * [`json_lite`] — JSON parser/serializer (for `serde_json`)
//! * [`toml_lite`] — TOML-subset parser (for `toml`)
//! * [`bench`] — mini-criterion measurement harness (for `criterion`)
//! * [`prop`] — property-testing mini-framework (for `proptest`)
//! * [`par`] — scoped-thread parallel map (for `rayon`)
//! * [`table`] — aligned text tables for the figure harness

pub mod bench;
pub mod bits;
pub mod cli;
pub mod json_lite;
pub mod par;
pub mod prop;
pub mod rng;
pub mod table;
pub mod toml_lite;

pub use rng::seeded_rng;
