//! Deterministic xoshiro256** PRNG + the distributions the simulator needs.
//!
//! Every experiment in this repo is seeded, so runs are bit-reproducible;
//! the generator is Blackman/Vigna xoshiro256** (not cryptographic — this
//! is simulation, not security).

/// The canonical deterministic RNG constructor for tests, benches and
/// experiment harnesses: every seeded stream in the repo goes through
/// this one helper (audited — no test rolls its own ad-hoc LCG), so
/// "what generator produced this data?" always has the same answer and
/// a seed printed in a failure reproduces the stream anywhere.
pub fn seeded_rng(seed: u64) -> Rng {
    Rng::new(seed)
}

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so that small consecutive seeds give
    /// well-decorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (Lemire's method, unbiased enough for simulation).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-12 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (std::f64::consts::TAU * v).cos();
            }
        }
    }

    /// Normal with mean/std as f32.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k << n assumed).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for n in [1u64, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
