//! Figure/table harness: one generator per figure and table of the
//! paper's evaluation (§VIII), printing the same rows/series the paper
//! reports. See DESIGN.md §6 for the full experiment index.
//!
//! Energy-only figures (10, 14, 22, ...) need no trained models and run
//! in seconds; quality figures lazily build the trained workload
//! [`Suite`] once and share it.

mod ablations;
mod energy;
mod misc;
mod quality_figs;
mod training;

use std::sync::OnceLock;

use anyhow::Result;

use crate::datasets;
use crate::encoding::CodecSpec;
use crate::runtime::Runtime;
use crate::session::{RunReport, Session, Trace, TrafficClass};
use crate::workloads::{Kind, Suite, SuiteBudget};

/// Drive a byte trace through a single-channel approximate-traffic
/// [`Session`] — the one simulate call every figure generator shares.
pub(crate) fn simulate(spec: &CodecSpec, bytes: &[u8]) -> Result<RunReport> {
    Session::builder()
        .codec(spec.clone())
        .traffic(TrafficClass::Approximate)
        .build()?
        .run(&Trace::from_bytes(bytes.to_vec()))
}

/// Same for f32 weight traffic: the spec's tolerance-mask override is
/// projected per chip by the session's weights codec path.
pub(crate) fn simulate_weights(spec: &CodecSpec, xs: &[f32]) -> Result<RunReport> {
    Session::builder()
        .codec_weights(spec.clone())
        .traffic(TrafficClass::Approximate)
        .build()?
        .run(&Trace::from_f32s(xs))
}

pub use ablations::ablations;
pub use energy::{fig10, fig14, fig2, fig22, table1};
pub use misc::{fig1, fig19, sec6};
pub use quality_figs::{fig11, fig12, fig13, fig15, fig16, fig17};
pub use training::{fig18, fig20, fig21};

/// All figure ids, in paper order.
pub const ALL: &[&str] = &[
    "fig1", "fig2", "fig10", "fig11", "fig12", "fig13", "fig14", "fig15", "fig16", "fig17",
    "fig18", "fig19", "fig20", "fig21", "fig22", "table1", "sec6", "ablations",
];

/// Shared context: seed, budget, and a lazily-built workload suite.
pub struct FigureCtx {
    pub seed: u64,
    pub budget: SuiteBudget,
    suite: OnceLock<Suite>,
}

impl FigureCtx {
    pub fn new(seed: u64, budget: SuiteBudget) -> Self {
        FigureCtx {
            seed,
            budget,
            suite: OnceLock::new(),
        }
    }

    /// The trained suite (built on first use).
    pub fn suite(&self) -> Result<&Suite> {
        if self.suite.get().is_none() {
            let rt = Runtime::load(Runtime::default_dir())?;
            let s = Suite::build(rt, self.seed, self.budget)?;
            let _ = self.suite.set(s);
        }
        Ok(self.suite.get().expect("just set"))
    }

    /// The byte trace each workload's evaluation input produces
    /// (energy-only figures; no trained models required).
    pub fn workload_trace(&self, kind: Kind) -> Vec<u8> {
        let seed = self.seed;
        let images = match kind {
            Kind::ImageNet | Kind::ResNet => {
                datasets::synth_images(self.budget.eval_images, seed ^ 0x7e57)
            }
            Kind::Quant => datasets::kodak_like(self.budget.kodak_images, 64, 64, seed ^ 0x0d),
            Kind::Eigen => datasets::faces_split(16, 8, 8, seed ^ 0xFA).1,
            Kind::Svm => datasets::fmnist_like(self.budget.svm_test, seed ^ 0x5e),
        };
        let mut bytes = Vec::new();
        for img in &images {
            bytes.extend_from_slice(&img.data);
        }
        bytes
    }
}

/// Render a figure by id.
pub fn render(ctx: &FigureCtx, id: &str) -> Result<String> {
    match id {
        "fig1" => fig1(ctx),
        "fig2" => fig2(),
        "fig10" => fig10(ctx),
        "fig11" => fig11(ctx),
        "fig12" => fig12(ctx),
        "fig13" => fig13(ctx),
        "fig14" => fig14(ctx),
        "fig15" => fig15(ctx),
        "fig16" => fig16(ctx),
        "fig17" => fig17(ctx),
        "fig18" => fig18(ctx),
        "fig19" => fig19(ctx),
        "fig20" => fig20(ctx),
        "fig21" => fig21(ctx),
        "fig22" => fig22(ctx),
        "table1" => table1(),
        "sec6" => sec6(ctx),
        "ablations" => ablations(ctx),
        other => anyhow::bail!("unknown figure {other:?}; known: {}", ALL.join(", ")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> FigureCtx {
        FigureCtx::new(42, SuiteBudget::quick())
    }

    #[test]
    fn energy_only_figures_render() {
        let c = ctx();
        for id in ["fig1", "fig2", "fig10", "fig14", "fig19", "fig22", "table1", "sec6"] {
            let out = render(&c, id).unwrap_or_else(|e| panic!("{id}: {e}"));
            assert!(out.len() > 50, "{id} output too short:\n{out}");
        }
    }

    #[test]
    fn unknown_figure_is_an_error() {
        assert!(render(&ctx(), "fig99").is_err());
    }
}
