//! Training/weights figures: 18 (train-on-reconstructed), 20
//! (weight-approximation sweep), 21 (weights + images + training).

use anyhow::Result;

use super::{simulate, simulate_weights, FigureCtx};
use crate::encoding::CodecSpec;
use crate::util::table::{f, pct, TextTable};
use crate::workloads::Kind;

/// Fig. 18: ResNet trained on original vs reconstructed images, both
/// evaluated on reconstructed test images, across configs.
pub fn fig18(ctx: &FigureCtx) -> Result<String> {
    let suite = ctx.suite()?;
    let mut t = TextTable::new(&[
        "config",
        "trained-on-original q",
        "trained-on-reconstructed q",
        "improvement",
    ]);
    // The last row is the paper's "aggressive" regime where the
    // trained-on-original model collapses and ZAC-aware training shows
    // its largest recovery (paper: up to 9x).
    for (l, tr) in [(80u32, 0u32), (75, 0), (70, 0), (70, 2), (70, 4)] {
        let spec = CodecSpec::zac_full(l, tr, 0);
        let base = suite.eval(&spec, Kind::ResNet)?;
        let retrained = suite.resnet_trained_on_recon(&spec)?;
        let imp = if base.quality > 0.0 {
            retrained.quality / base.quality
        } else if retrained.quality > 0.0 {
            f64::INFINITY
        } else {
            1.0
        };
        t.row(vec![
            format!("L{l} T{}", tr * 8),
            f(base.quality, 3),
            f(retrained.quality, 3),
            format!("{imp:.2}x"),
        ]);
    }
    Ok(format!(
        "Fig. 18 — ResNet trained on original vs ZAC-DEST-reconstructed\n\
         images (paper: training on reconstructed data recovers quality,\n\
         up to 9x at aggressive configs)\n\n{}",
        t.render()
    ))
}

/// Fig. 20: InceptionNet-analogue — approximating the *weights* with
/// weight similarity limits 70/65/60/50 (images at a fixed L90),
/// reporting weight-trace termination savings vs BDE and quality.
pub fn fig20(ctx: &FigureCtx) -> Result<String> {
    let suite = ctx.suite()?;
    let img_spec = CodecSpec::zac(90);
    let flat = suite.resnet.flatten();
    let weight_bytes = crate::trace::f32s_to_bytes(&flat);
    let bde = simulate(&CodecSpec::named("BDE"), &weight_bytes)?;
    let mut t = TextTable::new(&[
        "weight limit",
        "term savings vs BDE (weights)",
        "quality (img L90)",
    ]);
    for l in [70u32, 65, 60, 50] {
        let wspec = CodecSpec::zac_weights(l);
        let r = suite.resnet_with_approx_weights(&wspec, Some(&img_spec))?;
        t.row(vec![
            format!("L{l}"),
            pct(r.run.counts.termination_savings_vs(&bde.counts)),
            f(r.quality, 3),
        ]);
    }
    Ok(format!(
        "Fig. 20 — Weight + image approximation (paper: weight limits\n\
         70/65/60/50 give 10/40/59/60% termination savings vs BDE on the\n\
         weight traffic, quality falling 0.92→0.57 at image L90)\n\n{}",
        t.render()
    ))
}

/// Fig. 21: weights *and* images approximated during both training and
/// testing — train-on-reconstructed vs train-on-original, with
/// approximate weights at inference.
pub fn fig21(ctx: &FigureCtx) -> Result<String> {
    let suite = ctx.suite()?;
    let mut t = TextTable::new(&[
        "weight limit",
        "img limit",
        "orig-trained q",
        "recon-trained q",
    ]);
    for (wl, il) in [(70u32, 90u32), (60, 80), (50, 75)] {
        let wspec = CodecSpec::zac_weights(wl);
        let ispec = CodecSpec::zac(il);
        // Original-trained model, approx weights + images.
        let base = suite.resnet_with_approx_weights(&wspec, Some(&ispec))?;
        // Re-trained on reconstructed images, then the same weight
        // approximation applied at inference.
        let retrained = suite.resnet_trained_on_recon(&ispec)?;
        // Apply weight approximation to the retrained parameters.
        let (recon_train, _) = suite.reconstruct_images(&ispec, &suite.train_images)?;
        let (p, _) = crate::workloads::cnn::train(
            &suite.rt,
            &recon_train,
            suite.budget.train_steps * 3 / 2,
            suite.budget.lr,
            suite.seed ^ 0x18,
        )?;
        let wf = simulate_weights(&wspec, &p.flatten())?.to_f32s();
        let p2 = p.unflatten(&wf);
        let (recon_test, _) = suite.reconstruct_images(&ispec, &suite.test_images)?;
        let acc = crate::workloads::cnn::accuracy(&suite.rt, &p2, &recon_test)?;
        let retrained_q =
            crate::quality::quality_ratio(acc, suite.resnet_clean_acc);
        let _ = retrained; // quality already folded into retrained_q path
        t.row(vec![
            format!("L{wl}"),
            format!("L{il}"),
            f(base.quality, 3),
            f(retrained_q, 3),
        ]);
    }
    Ok(format!(
        "Fig. 21 — ResNet with both weights and images approximated,\n\
         training with vs without ZAC-DEST (paper: ZAC-aware training\n\
         improves output quality)\n\n{}",
        t.render()
    ))
}
