//! Energy-only figures: Fig. 2, 10, 14, 22 and Table I. These need only
//! the workload *traces*, not trained models.

use anyhow::Result;

use super::{simulate, simulate_weights, FigureCtx};
use crate::channel::energy::Ddr4Breakdown;
use crate::encoding::{CodecSpec, Outcome, Scheme};
use crate::util::table::{pct, TextTable};
use crate::workloads::Kind;

/// Fig. 2: DDR4 energy breakdown (constants from [14]).
pub fn fig2() -> Result<String> {
    let b = Ddr4Breakdown::paper();
    let mut t = TextTable::new(&["component", "% of DRAM energy"]);
    t.row(vec!["I/O termination".into(), pct(b.io_termination_pct)]);
    t.row(vec!["I/O switching".into(), pct(b.io_switching_pct)]);
    t.row(vec!["core (activate/rd/wr)".into(), pct(b.core_pct)]);
    t.row(vec!["background/refresh".into(), pct(b.background_pct)]);
    Ok(format!(
        "Fig. 2 — DDR4 DRAM sub-system energy breakdown [14]\n\
         (I/O total = {:.1}%, termination = 67% of I/O)\n\n{}",
        b.io_total_pct(),
        t.render()
    ))
}

/// Table I: encoding schemes under evaluation.
pub fn table1() -> Result<String> {
    let mut t = TextTable::new(&["label", "scheme"]);
    for s in Scheme::all() {
        t.row(vec![s.label().into(), s.description().into()]);
    }
    Ok(format!("Table I — Encoding schemes under evaluation\n\n{}", t.render()))
}

/// Fig. 10: termination/switching savings of the exact schemes
/// (DBI, BDE_ORG, BDE) vs unencoded ORG, per workload.
pub fn fig10(ctx: &FigureCtx) -> Result<String> {
    let schemes = [Scheme::Dbi, Scheme::BdeOrg, Scheme::Bde];
    let mut t = TextTable::new(&[
        "workload",
        "DBI term",
        "BDE_ORG term",
        "BDE term",
        "DBI sw",
        "BDE_ORG sw",
        "BDE sw",
    ]);
    let mut mean = [[0.0f64; 2]; 3];
    for kind in Kind::all() {
        let bytes = ctx.workload_trace(kind);
        let base = simulate(&CodecSpec::named("ORG"), &bytes)?;
        let mut row = vec![kind.label().to_string()];
        let mut sw_cells = Vec::new();
        for (i, s) in schemes.iter().enumerate() {
            let out = simulate(&CodecSpec::named(s.label()), &bytes)?;
            let ts = out.counts.termination_savings_vs(&base.counts);
            let ss = out.counts.switching_savings_vs(&base.counts);
            mean[i][0] += ts / 5.0;
            mean[i][1] += ss / 5.0;
            row.push(pct(ts));
            sw_cells.push(pct(ss));
        }
        row.extend(sw_cells);
        t.row(row);
    }
    t.row(vec![
        "MEAN".into(),
        pct(mean[0][0]),
        pct(mean[1][0]),
        pct(mean[2][0]),
        pct(mean[0][1]),
        pct(mean[1][1]),
        pct(mean[2][1]),
    ]);
    Ok(format!(
        "Fig. 10 — Savings of exact models vs unencoded (ORG) baseline\n\
         (paper: DBI ≈ 28%, BDE_ORG ≈ 20% — *worse* than DBI — and\n\
          modified BDE ≈ 41% termination reduction on average)\n\n{}",
        t.render()
    ))
}

/// Fig. 14: ZAC-DEST termination/switching savings vs BDE for the four
/// similarity limits, per workload.
pub fn fig14(ctx: &FigureCtx) -> Result<String> {
    let limits = [90u32, 80, 75, 70];
    let mut t = TextTable::new(&[
        "workload", "L90 term", "L80 term", "L75 term", "L70 term", "L90 sw", "L80 sw",
        "L75 sw", "L70 sw",
    ]);
    let mut mean = [[0.0f64; 2]; 4];
    for kind in Kind::all() {
        let bytes = ctx.workload_trace(kind);
        let base = simulate(&CodecSpec::named("BDE"), &bytes)?;
        let mut row = vec![kind.label().to_string()];
        let mut sw = Vec::new();
        for (i, l) in limits.iter().enumerate() {
            let out = simulate(&CodecSpec::zac(*l), &bytes)?;
            let ts = out.counts.termination_savings_vs(&base.counts);
            let ss = out.counts.switching_savings_vs(&base.counts);
            mean[i][0] += ts / 5.0;
            mean[i][1] += ss / 5.0;
            row.push(pct(ts));
            sw.push(pct(ss));
        }
        row.extend(sw);
        t.row(row);
    }
    let mut mrow = vec!["MEAN".to_string()];
    for i in 0..4 {
        mrow.push(pct(mean[i][0]));
    }
    for i in 0..4 {
        mrow.push(pct(mean[i][1]));
    }
    t.row(mrow);
    Ok(format!(
        "Fig. 14 — ZAC-DEST energy savings vs BDE while varying the\n\
         similarity limit (paper means: 8/20/32/60% termination for\n\
         limits 90/80/75/70)\n\n{}",
        t.render()
    ))
}

/// Fig. 22: frequency of each encoding outcome for BDE and ZAC-DEST,
/// image and weight traffic, across similarity limits.
pub fn fig22(ctx: &FigureCtx) -> Result<String> {
    let mut t = TextTable::new(&[
        "traffic", "scheme", "zero", "ohe-skip", "bde", "unencoded",
    ]);
    // Image traffic: the ImageNet trace. Weight traffic: a trained-CNN
    // weight stream if the suite is built; otherwise a synthetic
    // normal-weight stream (identical layout).
    let img_bytes = ctx.workload_trace(Kind::ImageNet);
    let weight_bytes = {
        let mut r = crate::util::rng::Rng::new(ctx.seed ^ 0x3e);
        let xs: Vec<f32> = (0..65536).map(|_| r.normal_f32(0.0, 0.05)).collect();
        crate::trace::f32s_to_bytes(&xs)
    };
    for (traffic, bytes) in [("images", &img_bytes), ("weights", &weight_bytes)] {
        let bde = simulate(&CodecSpec::named("BDE"), bytes)?;
        t.row(vec![
            traffic.into(),
            "BDE".into(),
            pct(100.0 * bde.stats.fraction(Outcome::ZeroSkip)),
            "-".into(),
            pct(100.0 * bde.stats.fraction(Outcome::Bde)),
            pct(100.0 * bde.stats.fraction(Outcome::Raw)),
        ]);
        for limit in [90u32, 80, 75, 70] {
            let out = if traffic == "weights" {
                let xs = crate::trace::bytes_to_f32s(bytes);
                simulate_weights(&CodecSpec::zac_weights(limit), &xs)?
            } else {
                simulate(&CodecSpec::zac(limit), bytes)?
            };
            t.row(vec![
                traffic.into(),
                format!("ZAC L{limit}"),
                pct(100.0 * out.stats.fraction(Outcome::ZeroSkip)),
                pct(100.0 * out.stats.fraction(Outcome::OheSkip)),
                pct(100.0 * out.stats.fraction(Outcome::Bde)),
                pct(100.0 * out.stats.fraction(Outcome::Raw)),
            ]);
        }
    }
    Ok(format!(
        "Fig. 22 — Frequency of encoding outcomes during (a) weight and\n\
         (b) image transfers (paper: ~6.5% of accesses unencoded under\n\
         ZAC-DEST, ~6.6% under BDE)\n\n{}",
        t.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::SuiteBudget;

    #[test]
    fn fig10_bde_beats_bde_org() {
        // The paper's headline ordering: modified BDE > DBI > BDE_ORG on
        // average termination savings.
        let ctx = FigureCtx::new(42, SuiteBudget::quick());
        let mut means = [0.0f64; 3];
        for kind in Kind::all() {
            let bytes = ctx.workload_trace(kind);
            let base = simulate(&CodecSpec::named("ORG"), &bytes).unwrap();
            for (i, s) in [Scheme::Dbi, Scheme::BdeOrg, Scheme::Bde].iter().enumerate() {
                let out = simulate(&CodecSpec::named(s.label()), &bytes).unwrap();
                means[i] += out.counts.termination_savings_vs(&base.counts) / 5.0;
            }
        }
        let (dbi, bde_org, bde) = (means[0], means[1], means[2]);
        assert!(bde > dbi, "BDE {bde:.1}% should beat DBI {dbi:.1}%");
        assert!(bde > bde_org, "BDE {bde:.1}% should beat BDE_ORG {bde_org:.1}%");
        assert!(dbi > 0.0 && bde_org > 0.0);
    }

    #[test]
    fn fig14_savings_increase_as_limit_drops() {
        let ctx = FigureCtx::new(42, SuiteBudget::quick());
        let bytes = ctx.workload_trace(Kind::ImageNet);
        let base = simulate(&CodecSpec::named("BDE"), &bytes).unwrap();
        let mut prev = -1.0;
        for l in [90u32, 80, 75, 70] {
            let out = simulate(&CodecSpec::zac(l), &bytes).unwrap();
            let s = out.counts.termination_savings_vs(&base.counts);
            assert!(s >= prev, "L{l}: savings {s} < previous {prev}");
            prev = s;
        }
        assert!(prev > 0.0);
    }

    #[test]
    fn fig22_most_accesses_encoded() {
        let ctx = FigureCtx::new(42, SuiteBudget::quick());
        let bytes = ctx.workload_trace(Kind::ImageNet);
        let out = simulate(&CodecSpec::zac(80), &bytes).unwrap();
        // Paper: only ~6.5% of accesses stay unencoded.
        assert!(
            out.stats.unencoded_fraction() < 0.5,
            "unencoded fraction {}",
            out.stats.unencoded_fraction()
        );
    }
}
