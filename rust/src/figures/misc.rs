//! Misc figures: Fig. 1 (error-resilience motivation), Fig. 19 (IEEE-754
//! layout + exponent sensitivity), §VI (circuit overheads).

use anyhow::Result;

use super::FigureCtx;
use crate::circuits;
use crate::quality::psnr_u8;
use crate::trace::{flip_lsb_ones, float_layout};
use crate::util::table::{f, pct, TextTable};

/// Fig. 1: PSNR after flipping a fraction of the 1s in pixel LSBs
/// (paper: 20% flipped → PSNR 36, 40% → 32, both acceptable >30).
pub fn fig1(ctx: &FigureCtx) -> Result<String> {
    let img = &crate::datasets::kodak_like(1, 64, 64, ctx.seed ^ 0x0d)[0];
    let mut t = TextTable::new(&["% of 1s flipped in 4 LSBs", "PSNR (dB)"]);
    t.row(vec!["0 (original)".into(), "inf".into()]);
    for frac in [0.2f64, 0.4, 0.8] {
        let approx = flip_lsb_ones(&img.data, 4, frac);
        t.row(vec![
            format!("{:.0}%", frac * 100.0),
            f(psnr_u8(&img.data, &approx), 1),
        ]);
    }
    Ok(format!(
        "Fig. 1 — Error resilience of images to LSB one-flips\n\
         (paper: 20% → PSNR 36, 40% → PSNR 32; PSNR > 30 is visually\n\
         indistinguishable)\n\n{}",
        t.render()
    ))
}

/// Fig. 19: IEEE-754 f32 layout and why Tolerance must pin the
/// exponent: one low-exponent-bit flip vs 12-bit mantissa truncation.
pub fn fig19(ctx: &FigureCtx) -> Result<String> {
    let mut r = crate::util::rng::Rng::new(ctx.seed ^ 0x19);
    let weights: Vec<f32> = (0..8192).map(|_| r.normal_f32(0.0, 0.05)).collect();
    let (exp_err, man_err) = float_layout::exponent_flip_damage(&weights, 12);
    let mask = float_layout::weight_tolerance_mask();
    let mut t = TextTable::new(&["perturbation", "mean relative error"]);
    t.row(vec!["flip lowest exponent bit".into(), pct(exp_err * 100.0)]);
    t.row(vec!["truncate 12 mantissa LSBs".into(), pct(man_err * 100.0)]);
    Ok(format!(
        "Fig. 19 — IEEE-754 f32: [sign 1][exponent 8][mantissa 23]\n\
         Weights-mode tolerance mask (per packed 64-bit word): {mask:#018x}\n\
         (paper §VIII-G: approximating even the last exponent bit costs\n\
         ~60% output quality; mantissa LSBs are nearly free)\n\n{}",
        t.render()
    ))
}

/// §VI: circuit implementation overheads from the gate-level model
/// (10 000-vector switching activity, calibrated to BD-Coder's 7 pJ /
/// 2.4 ns).
pub fn sec6(ctx: &FigureCtx) -> Result<String> {
    let (bd, zd) = circuits::evaluate(circuits::paper::ACTIVITY_VECTORS, ctx.seed);
    let mut t = TextTable::new(&[
        "design", "transistors", "energy/access (pJ)", "latency (ns)",
    ]);
    for r in [&bd, &zd] {
        t.row(vec![
            r.name.into(),
            format!("{}", r.transistors),
            f(r.energy_pj, 2),
            f(r.latency_ns, 2),
        ]);
    }
    Ok(format!(
        "§VI — Circuit overheads (UMC 65 nm model; paper: 7 → 7.66 pJ,\n\
         2.4 → 3.4 ns, +15% area, +9% sub-module energy)\n\n{}\n\
         area overhead: {}   energy overhead: {}\n",
        t.render(),
        pct(zd.area_overhead_pct(&bd)),
        pct(zd.energy_overhead_pct(&bd)),
    ))
}
