//! Ablation study of the paper's §IV/§V design choices, beyond the
//! figures the paper prints (DESIGN.md calls these out):
//!
//! 1. **One-hot vs binary skip index** (§IV-B "Using the Unused")
//! 2. **Zero bypass** on sparse traffic (§V-A)
//! 3. **Dedup/exact-only table update vs update-always** (§IV-A)
//! 4. **Table size** (16/32/64, the [14] design sweep)

use anyhow::Result;

use super::{simulate, FigureCtx};
use crate::encoding::{config::Ablation, CodecSpec};
use crate::util::table::{pct, TextTable};
use crate::workloads::Kind;

fn with_ablation(limit: u32, ab: Ablation) -> CodecSpec {
    let mut spec = CodecSpec::zac(limit);
    spec.zac_knobs_mut().expect("zac spec").ablation = ab;
    spec
}

/// Render the full ablation table.
pub fn ablations(ctx: &FigureCtx) -> Result<String> {
    let mut t = TextTable::new(&["ablation", "trace", "term 1s", "delta vs paper-default"]);
    let image = ctx.workload_trace(Kind::ImageNet);
    let sparse = ctx.workload_trace(Kind::Svm);

    // Baselines.
    let base_img = simulate(&CodecSpec::zac(70), &image)?;
    let base_sparse = simulate(&CodecSpec::zac(70), &sparse)?;

    let row = |t: &mut TextTable, name: &str, trace: &str, ones: u64, base: u64| {
        let delta = 100.0 * (ones as f64 / base as f64 - 1.0);
        t.row(vec![
            name.into(),
            trace.into(),
            format!("{ones}"),
            format!("{delta:+.1}%"),
        ]);
    };

    row(
        &mut t,
        "paper default (L70)",
        "images",
        base_img.counts.termination_ones,
        base_img.counts.termination_ones,
    );

    // 1. Binary index instead of one-hot for skips.
    let ab = Ablation {
        ohe_index: false,
        ..Ablation::default()
    };
    let out = simulate(&with_ablation(70, ab), &image)?;
    row(
        &mut t,
        "binary skip index (no OHE)",
        "images",
        out.counts.termination_ones,
        base_img.counts.termination_ones,
    );

    // 2. Zero bypass off, on the sparse (SVM) trace.
    row(
        &mut t,
        "paper default (L70)",
        "sparse",
        base_sparse.counts.termination_ones,
        base_sparse.counts.termination_ones,
    );
    let ab = Ablation {
        zero_skip: false,
        ..Ablation::default()
    };
    let out = simulate(&with_ablation(70, ab), &sparse)?;
    row(
        &mut t,
        "no zero bypass",
        "sparse",
        out.counts.termination_ones,
        base_sparse.counts.termination_ones,
    );

    // 3. Update-always (BD-Coder policy) instead of dedup.
    let ab = Ablation {
        dedup_update: false,
        ..Ablation::default()
    };
    let out = simulate(&with_ablation(70, ab), &image)?;
    row(
        &mut t,
        "update-always table (no dedup)",
        "images",
        out.counts.termination_ones,
        base_img.counts.termination_ones,
    );

    // 4. Table size sweep.
    for size in [16usize, 32, 64] {
        let mut spec = CodecSpec::zac(70);
        spec.zac_knobs_mut().expect("zac spec").table_size = size;
        let out = simulate(&spec, &image)?;
        row(
            &mut t,
            &format!("table size {size}"),
            "images",
            out.counts.termination_ones,
            base_img.counts.termination_ones,
        );
    }

    // Context: BDE baseline for scale.
    let bde = simulate(&CodecSpec::named("BDE"), &image)?;
    Ok(format!(
        "Ablations — each §IV/§V design choice isolated (L70, vs the\n\
         paper-default configuration; BDE on the same image trace: {} 1s,\n\
         i.e. ZAC default saves {})\n\n{}",
        bde.counts.termination_ones,
        pct(base_img.counts.termination_savings_vs(&bde.counts)),
        t.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workloads::SuiteBudget;

    fn image_like(n: usize, seed: u64) -> Vec<u8> {
        let mut r = Rng::new(seed);
        let mut v = 128i32;
        (0..n)
            .map(|_| {
                v = (v + (r.below(9) as i32 - 4)).clamp(0, 255);
                v as u8
            })
            .collect()
    }

    #[test]
    fn ohe_index_saves_ones_vs_binary() {
        let bytes = image_like(65536, 1);
        let default = simulate(&CodecSpec::zac(70), &bytes).unwrap();
        let binary = simulate(
            &with_ablation(
                70,
                Ablation {
                    ohe_index: false,
                    ..Ablation::default()
                },
            ),
            &bytes,
        )
        .unwrap();
        // Reconstructions identical (index encoding is energy-only)...
        assert_eq!(default.bytes, binary.bytes);
        // ...but the one-hot index costs fewer 1s (§IV-B: ≤6 → exactly 1).
        assert!(
            default.counts.termination_ones < binary.counts.termination_ones,
            "OHE {} !< binary {}",
            default.counts.termination_ones,
            binary.counts.termination_ones
        );
    }

    #[test]
    fn zero_bypass_pays_on_sparse_traffic() {
        let mut bytes = vec![0u8; 65536];
        let mut r = Rng::new(2);
        for _ in 0..300 {
            let p = r.range(0, bytes.len());
            bytes[p] = r.next_u32() as u8;
        }
        let on = simulate(&CodecSpec::zac(70), &bytes).unwrap();
        let off = simulate(
            &with_ablation(
                70,
                Ablation {
                    zero_skip: false,
                    ..Ablation::default()
                },
            ),
            &bytes,
        )
        .unwrap();
        assert!(
            on.counts.termination_ones <= off.counts.termination_ones,
            "zero bypass must not cost energy on sparse traffic"
        );
    }

    #[test]
    fn all_ablation_combos_stay_mirror_consistent() {
        // Correctness must hold under every ablation combination: exact
        // traffic round-trips, approx stays within the envelope.
        let bytes = image_like(16384, 3);
        for ohe in [true, false] {
            for zero in [true, false] {
                for dedup in [true, false] {
                    let spec = with_ablation(
                        75,
                        Ablation {
                            ohe_index: ohe,
                            zero_skip: zero,
                            dedup_update: dedup,
                        },
                    );
                    // Exact traffic is always exact (Critical session).
                    let exact = crate::session::Session::builder()
                        .codec(spec.clone())
                        .build()
                        .unwrap()
                        .run(&crate::session::Trace::from_bytes(bytes.clone()))
                        .unwrap();
                    assert_eq!(exact.bytes, bytes, "ohe={ohe} zero={zero} dedup={dedup}");
                    // Approx stays within the envelope.
                    let out = simulate(&spec, &bytes).unwrap();
                    let thr = spec.zac_knobs().unwrap().dissimilar_threshold();
                    let a = crate::trace::bytes_to_chip_words(&bytes);
                    let b = crate::trace::bytes_to_chip_words(&out.bytes);
                    for (wa, wb) in a.iter().zip(&b) {
                        for j in 0..8 {
                            assert!(
                                (wa[j] ^ wb[j]).count_ones() < thr,
                                "ohe={ohe} zero={zero} dedup={dedup}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn larger_tables_never_hurt_much() {
        let bytes = image_like(65536, 4);
        let mut prev = u64::MAX;
        for size in [16usize, 32, 64] {
            let mut spec = CodecSpec::zac(70);
            spec.zac_knobs_mut().unwrap().table_size = size;
            let out = simulate(&spec, &bytes).unwrap();
            // Bigger CAM → more skip opportunities → allow small jitter.
            assert!(
                out.counts.termination_ones <= prev + prev / 10,
                "table {size}"
            );
            prev = out.counts.termination_ones;
        }
    }

    #[test]
    fn ablation_figure_renders() {
        let ctx = FigureCtx::new(5, SuiteBudget::quick());
        let out = ablations(&ctx).unwrap();
        assert!(out.contains("binary skip index"));
        assert!(out.contains("table size 64"));
    }
}
