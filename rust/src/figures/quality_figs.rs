//! Quality figures (11, 12, 13, 15, 16, 17): reconstruct the workload
//! inputs through the channel and re-run the trained models.

use anyhow::Result;

use super::{simulate, FigureCtx};
use crate::encoding::CodecSpec;
use crate::quality::psnr_u8;
use crate::util::table::{f, pct, TextTable};
use crate::workloads::{cnn, Kind};

const LIMITS: [u32; 4] = [90, 80, 75, 70];

/// Fig. 11: top-1 precision of every CNN in the zoo vs similarity limit
/// (the red line = original accuracy).
pub fn fig11(ctx: &FigureCtx) -> Result<String> {
    let suite = ctx.suite()?;
    let mut t = TextTable::new(&["model", "original", "L90", "L80", "L75", "L70"]);
    let mut recon_sets = Vec::new();
    for l in LIMITS {
        recon_sets.push(suite.reconstruct_images(&CodecSpec::zac(l), &suite.test_images)?.0);
    }
    for (m, (params, &clean)) in suite.zoo.iter().zip(&suite.zoo_clean_acc).enumerate() {
        let mut row = vec![format!("cnn-{m}"), f(clean, 3)];
        for recon in &recon_sets {
            row.push(f(cnn::accuracy(&suite.rt, params, recon)?, 3));
        }
        t.row(row);
    }
    Ok(format!(
        "Fig. 11 — Effect of Similarity Limit on top-1 precision for the\n\
         CNN zoo (original accuracy = the paper's red line)\n\n{}",
        t.render()
    ))
}

/// Fig. 12: PSNR of reconstructed images per similarity limit (the
/// paper shows the images; we report PSNR and dump PPMs next to the
/// binary when ZAC_DUMP_IMAGES is set).
pub fn fig12(ctx: &FigureCtx) -> Result<String> {
    let imgs = crate::datasets::kodak_like(1, 64, 64, ctx.seed ^ 0x0d);
    let img = &imgs[0];
    let mut t = TextTable::new(&["similarity limit", "PSNR (dB)"]);
    t.row(vec!["original".into(), "inf".into()]);
    for l in LIMITS {
        let out = simulate(&CodecSpec::zac(l), &img.data)?;
        let rec = img.with_data(out.bytes.clone());
        let p = psnr_u8(&img.data, &rec.data);
        if std::env::var("ZAC_DUMP_IMAGES").is_ok() {
            std::fs::write(format!("fig12_L{l}.ppm"), rec.to_pnm())?;
        }
        t.row(vec![format!("L{l}"), if p.is_finite() { f(p, 1) } else { "inf".into() }]);
    }
    Ok(format!(
        "Fig. 12 — Reconstructed-image fidelity per Similarity Limit\n\
         (PSNR decreases as the limit drops; paper shows the images)\n\n{}",
        t.render()
    ))
}

/// Fig. 13: output quality vs similarity limit for all five workloads.
pub fn fig13(ctx: &FigureCtx) -> Result<String> {
    let suite = ctx.suite()?;
    let mut t = TextTable::new(&["workload", "L90", "L80", "L75", "L70"]);
    for kind in Kind::all() {
        let mut row = vec![kind.label().to_string()];
        for l in LIMITS {
            let r = suite.eval(&CodecSpec::zac(l), kind)?;
            row.push(f(r.quality, 3));
        }
        t.row(row);
    }
    Ok(format!(
        "Fig. 13 — Effect of Similarity Limit on output quality\n\
         (paper: qualities ≈ 1 at L90, declining as the limit drops;\n\
          ImageNet/Quant fall faster than ResNet/SVM/Eigen)\n\n{}",
        t.render()
    ))
}

/// Fig. 15: Truncation × Similarity-Limit grid — termination savings vs
/// BDE and mean output quality per cell.
pub fn fig15(ctx: &FigureCtx) -> Result<String> {
    let suite = ctx.suite()?;
    let truncs = [0u32, 1, 2]; // bits/byte-chunk = 0 / 8 / 16 total
    let mut t = TextTable::new(&[
        "config", "term savings vs BDE", "switch savings", "mean quality",
    ]);
    for l in LIMITS {
        for tr in truncs {
            let spec = CodecSpec::zac_full(l, tr, 0);
            let mut term = 0.0;
            let mut sw = 0.0;
            let mut q = 0.0;
            for kind in Kind::all() {
                let bytes = ctx.workload_trace(kind);
                let base = simulate(&CodecSpec::named("BDE"), &bytes)?;
                let out = simulate(&spec, &bytes)?;
                term += out.counts.termination_savings_vs(&base.counts) / 5.0;
                sw += out.counts.switching_savings_vs(&base.counts) / 5.0;
                q += suite.eval(&spec, kind)?.quality / 5.0;
            }
            t.row(vec![
                format!("L{l} T{}", tr * 8),
                pct(term),
                pct(sw),
                f(q, 3),
            ]);
        }
    }
    Ok(format!(
        "Fig. 15 — Effect of Truncation and Similarity Limit on energy\n\
         and quality (paper: at L80, T0→T16 lifts savings 20%→68% while\n\
         quality drops 0.96→0.77; truncation bites harder at low limits)\n\n{}",
        t.render()
    ))
}

/// Fig. 16: the design-space scatter — every (limit, truncation,
/// tolerance) point with its energy savings and mean quality (CSV-ish
/// rows; plot externally).
pub fn fig16(ctx: &FigureCtx) -> Result<String> {
    let suite = ctx.suite()?;
    let mut t = TextTable::new(&[
        "limit", "trunc bits", "tol bits", "term savings vs BDE", "mean quality",
    ]);
    for l in LIMITS {
        for tr in [0u32, 1, 2] {
            for tol in [0u32, 1, 2] {
                let spec = CodecSpec::zac_full(l, tr, tol);
                let mut term = 0.0;
                let mut q = 0.0;
                for kind in Kind::all() {
                    let bytes = ctx.workload_trace(kind);
                    let base = simulate(&CodecSpec::named("BDE"), &bytes)?;
                    let out = simulate(&spec, &bytes)?;
                    term += out.counts.termination_savings_vs(&base.counts) / 5.0;
                    q += suite.eval(&spec, kind)?.quality / 5.0;
                }
                t.row(vec![
                    format!("{l}"),
                    format!("{}", tr * 8),
                    format!("{}", tol * 8),
                    pct(term),
                    f(q, 3),
                ]);
            }
        }
    }
    Ok(format!(
        "Fig. 16 — Quality/energy design space over all knob settings\n\
         (paper: lower limits & more truncation → bottom-left; tolerance\n\
          pushes points back toward top-right)\n\n{}",
        t.render()
    ))
}

/// Fig. 17: ImageNet vs ResNet quality stability across configurations.
pub fn fig17(ctx: &FigureCtx) -> Result<String> {
    let suite = ctx.suite()?;
    let mut t = TextTable::new(&["config", "ImageNet quality", "ResNet quality"]);
    for l in LIMITS {
        for tr in [0u32, 2] {
            let spec = CodecSpec::zac_full(l, tr, 0);
            let a = suite.eval(&spec, Kind::ImageNet)?;
            let b = suite.eval(&spec, Kind::ResNet)?;
            t.row(vec![
                format!("L{l} T{}", tr * 8),
                f(a.quality, 3),
                f(b.quality, 3),
            ]);
        }
    }
    Ok(format!(
        "Fig. 17 — ImageNet dips sharply at aggressive configs while\n\
         ResNet remains comparatively stable (paper §VIII-F)\n\n{}",
        t.render()
    ))
}
