//! The metrics registry: per-shard live metric sets shared with the
//! data-plane workers, and the immutable [`TelemetrySnapshot`] taken
//! after a run — which is what serializes into the `"telemetry"`
//! section of reports and `BENCH_*.json` artifacts.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Arc;
use std::time::Instant;

use crate::util::json_lite::{num, obj, Json};
use crate::util::table::TextTable;

use super::hist::Histogram;
use super::metrics::{Counter, Gauge, Stage, StageSet};

/// Live metrics for one shard (or the single lane set of a
/// batch/pipelined run). Shared via `Arc` between the producer
/// (mailbox sender) and the shard worker.
#[derive(Debug)]
pub struct ShardMetrics {
    enabled: bool,
    /// Per-stage drive-loop nanoseconds, shared by the shard's lanes.
    pub stages: Arc<StageSet>,
    /// Mailbox depth sampled at each send (value + high-water mark).
    pub depth: Gauge,
    sent: AtomicU64,
    received: AtomicU64,
    /// Cumulative time the producer spent blocked on a full mailbox —
    /// the backpressure signal.
    pub send_block_ns: Counter,
    /// Number of sends that found the mailbox at capacity.
    pub blocked_sends: Counter,
    /// Per-chunk service latency in the worker loop.
    pub service: Histogram,
}

impl ShardMetrics {
    fn new(enabled: bool) -> ShardMetrics {
        ShardMetrics {
            enabled,
            stages: Arc::new(StageSet::default()),
            depth: Gauge::default(),
            sent: AtomicU64::new(0),
            received: AtomicU64::new(0),
            send_block_ns: Counter::default(),
            blocked_sends: Counter::default(),
            service: Histogram::new(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Producer side: a chunk was handed to the mailbox.
    pub fn chunk_sent(&self) {
        self.sent.fetch_add(1, Relaxed);
    }

    /// Worker side: a chunk was pulled out of the mailbox.
    pub fn chunk_received(&self) {
        self.received.fetch_add(1, Relaxed);
    }

    /// Chunks currently in the mailbox (sent but not yet received).
    pub fn in_flight(&self) -> u64 {
        self.sent
            .load(Relaxed)
            .saturating_sub(self.received.load(Relaxed))
    }
}

/// Owns the per-shard metric sets for one run and stamps the wall
/// clock. Cheap to construct disabled — every consumer checks
/// [`MetricsRegistry::enabled`] (or the per-shard copy) before
/// touching a clock.
#[derive(Debug)]
pub struct MetricsRegistry {
    enabled: bool,
    start: Instant,
    shards: Vec<Arc<ShardMetrics>>,
}

impl MetricsRegistry {
    pub fn new(enabled: bool, nshards: usize) -> MetricsRegistry {
        MetricsRegistry {
            enabled,
            start: Instant::now(),
            shards: (0..nshards)
                .map(|_| Arc::new(ShardMetrics::new(enabled)))
                .collect(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn shard(&self, s: usize) -> &Arc<ShardMetrics> {
        &self.shards[s]
    }

    pub fn shards(&self) -> &[Arc<ShardMetrics>] {
        &self.shards
    }

    /// Freeze the registry into an immutable snapshot. Take it after
    /// the workers have joined so histograms and stage sets are
    /// complete.
    pub fn snapshot(&self, lines: u64) -> TelemetrySnapshot {
        TelemetrySnapshot {
            wall_ns: self.start.elapsed().as_nanos() as u64,
            lines,
            shards: self
                .shards
                .iter()
                .map(|m| ShardSnapshot {
                    stage_ns: Stage::ALL.map(|st| m.stages.ns(st)),
                    batches: m.stages.batches(),
                    mailbox_depth: m.depth.get(),
                    mailbox_max_depth: m.depth.max(),
                    send_block_ns: m.send_block_ns.get(),
                    blocked_sends: m.blocked_sends.get(),
                    service_count: m.service.count(),
                    service_p50_ns: m.service.percentile(50.0),
                    service_p95_ns: m.service.percentile(95.0),
                    service_p99_ns: m.service.percentile(99.0),
                    service_max_ns: m.service.max(),
                })
                .collect(),
        }
    }
}

/// One shard's frozen metrics; `stage_ns` follows [`Stage::ALL`]
/// order.
#[derive(Clone, Debug)]
pub struct ShardSnapshot {
    pub stage_ns: [u64; 5],
    pub batches: u64,
    pub mailbox_depth: u64,
    pub mailbox_max_depth: u64,
    pub send_block_ns: u64,
    pub blocked_sends: u64,
    pub service_count: u64,
    pub service_p50_ns: u64,
    pub service_p95_ns: u64,
    pub service_p99_ns: u64,
    pub service_max_ns: u64,
}

/// Frozen telemetry for one run: wall clock, line throughput, and the
/// per-shard stage/backpressure/latency metrics.
#[derive(Clone, Debug)]
pub struct TelemetrySnapshot {
    pub wall_ns: u64,
    pub lines: u64,
    pub shards: Vec<ShardSnapshot>,
}

impl TelemetrySnapshot {
    pub fn lines_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            0.0
        } else {
            self.lines as f64 / (self.wall_ns as f64 * 1e-9)
        }
    }

    pub fn to_json(&self) -> Json {
        let shards = self
            .shards
            .iter()
            .enumerate()
            .map(|(i, sh)| {
                let stages = Stage::ALL
                    .iter()
                    .map(|&st| (st.label(), num(sh.stage_ns[st as usize] as f64)))
                    .collect();
                let stage_ns = obj(stages);
                obj(vec![
                    ("shard", num(i as f64)),
                    ("stage_ns", stage_ns),
                    ("batches", num(sh.batches as f64)),
                    ("mailbox_depth", num(sh.mailbox_depth as f64)),
                    ("mailbox_max_depth", num(sh.mailbox_max_depth as f64)),
                    ("send_block_ns", num(sh.send_block_ns as f64)),
                    ("blocked_sends", num(sh.blocked_sends as f64)),
                    ("service_count", num(sh.service_count as f64)),
                    ("service_p50_ns", num(sh.service_p50_ns as f64)),
                    ("service_p95_ns", num(sh.service_p95_ns as f64)),
                    ("service_p99_ns", num(sh.service_p99_ns as f64)),
                    ("service_max_ns", num(sh.service_max_ns as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("wall_ns", num(self.wall_ns as f64)),
            ("lines", num(self.lines as f64)),
            ("lines_per_sec", num(self.lines_per_sec())),
            ("shards", Json::Arr(shards)),
        ])
    }

    /// Parse a snapshot back out of its JSON form — the read half of
    /// [`Self::to_json`], used when `sweep --resume` carries a prior
    /// run's rows (telemetry included) into the merged report. The
    /// derived `lines_per_sec` key is recomputed, not stored.
    pub fn from_json(j: &Json) -> anyhow::Result<TelemetrySnapshot> {
        let shards = j
            .get("shards")?
            .as_arr()?
            .iter()
            .map(|sh| {
                let stages = sh.get("stage_ns")?;
                let mut stage_ns = [0u64; 5];
                for &st in Stage::ALL.iter() {
                    stage_ns[st as usize] = stages.get(st.label())?.as_usize()? as u64;
                }
                Ok(ShardSnapshot {
                    stage_ns,
                    batches: sh.get("batches")?.as_usize()? as u64,
                    mailbox_depth: sh.get("mailbox_depth")?.as_usize()? as u64,
                    mailbox_max_depth: sh.get("mailbox_max_depth")?.as_usize()? as u64,
                    send_block_ns: sh.get("send_block_ns")?.as_usize()? as u64,
                    blocked_sends: sh.get("blocked_sends")?.as_usize()? as u64,
                    service_count: sh.get("service_count")?.as_usize()? as u64,
                    service_p50_ns: sh.get("service_p50_ns")?.as_usize()? as u64,
                    service_p95_ns: sh.get("service_p95_ns")?.as_usize()? as u64,
                    service_p99_ns: sh.get("service_p99_ns")?.as_usize()? as u64,
                    service_max_ns: sh.get("service_max_ns")?.as_usize()? as u64,
                })
            })
            .collect::<anyhow::Result<_>>()?;
        Ok(TelemetrySnapshot {
            wall_ns: j.get("wall_ns")?.as_usize()? as u64,
            lines: j.get("lines")?.as_usize()? as u64,
            shards,
        })
    }

    /// Human-readable telemetry section for the rendered reports.
    pub fn render_table(&self) -> String {
        let mut t = TextTable::new(&[
            "shard", "gather", "encode", "transmit", "inject", "decode", "batches", "mbox max",
            "blocked", "svc p50", "svc p95", "svc p99",
        ]);
        for (i, sh) in self.shards.iter().enumerate() {
            t.row(vec![
                i.to_string(),
                fmt_ns(sh.stage_ns[Stage::Gather as usize]),
                fmt_ns(sh.stage_ns[Stage::Encode as usize]),
                fmt_ns(sh.stage_ns[Stage::Transmit as usize]),
                fmt_ns(sh.stage_ns[Stage::Inject as usize]),
                fmt_ns(sh.stage_ns[Stage::Decode as usize]),
                sh.batches.to_string(),
                sh.mailbox_max_depth.to_string(),
                format!("{} ({})", fmt_ns(sh.send_block_ns), sh.blocked_sends),
                fmt_ns(sh.service_p50_ns),
                fmt_ns(sh.service_p95_ns),
                fmt_ns(sh.service_p99_ns),
            ]);
        }
        format!(
            "telemetry: wall {}  lines {}  ({:.0} lines/s)\n{}",
            fmt_ns(self.wall_ns),
            self.lines,
            self.lines_per_sec(),
            t.render()
        )
    }
}

/// Humanize a nanosecond quantity for tables (JSON keeps raw ns).
fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.2}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.1}us", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_recorded_metrics() {
        let reg = MetricsRegistry::new(true, 2);
        assert!(reg.enabled());
        let m0 = reg.shard(0);
        m0.stages.add(Stage::Encode, 1_000);
        m0.stages.add_batch();
        m0.chunk_sent();
        m0.depth.set(3);
        m0.depth.set(1);
        m0.send_block_ns.add(42);
        m0.blocked_sends.add(1);
        m0.service.record(500);
        m0.service.record(1_500);
        m0.chunk_received();

        let snap = reg.snapshot(512);
        assert_eq!(snap.lines, 512);
        assert_eq!(snap.shards.len(), 2);
        let sh = &snap.shards[0];
        assert_eq!(sh.stage_ns[Stage::Encode as usize], 1_000);
        assert_eq!(sh.batches, 1);
        assert_eq!(sh.mailbox_depth, 1);
        assert_eq!(sh.mailbox_max_depth, 3);
        assert_eq!(sh.send_block_ns, 42);
        assert_eq!(sh.blocked_sends, 1);
        assert_eq!(sh.service_count, 2);
        assert!(sh.service_p50_ns >= 500);
        assert!(sh.service_p99_ns >= 1_500);
        // Idle shard stays all-zero.
        let idle = &snap.shards[1];
        assert_eq!(idle.send_block_ns, 0);
        assert_eq!(idle.mailbox_max_depth, 0);
        assert_eq!(idle.service_count, 0);
    }

    #[test]
    fn in_flight_tracks_sent_minus_received() {
        let m = ShardMetrics::new(true);
        assert_eq!(m.in_flight(), 0);
        m.chunk_sent();
        m.chunk_sent();
        assert_eq!(m.in_flight(), 2);
        m.chunk_received();
        assert_eq!(m.in_flight(), 1);
    }

    #[test]
    fn json_carries_the_grep_keys() {
        let reg = MetricsRegistry::new(true, 1);
        reg.shard(0).service.record(10);
        let json = reg.snapshot(1).to_json().to_pretty();
        for key in [
            "\"stage_ns\"",
            "\"mailbox_max_depth\"",
            "\"service_p99_ns\"",
            "\"send_block_ns\"",
            "\"lines_per_sec\"",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
    }

    #[test]
    fn snapshot_json_round_trips_bit_identical() {
        // The resume contract: parse(serialize(snap)) re-serializes
        // byte-for-byte, so a resumed row's telemetry section is
        // indistinguishable from the original run's.
        let reg = MetricsRegistry::new(true, 2);
        let m0 = reg.shard(0);
        m0.stages.add(Stage::Encode, 1_000);
        m0.stages.add_batch();
        m0.depth.set(3);
        m0.send_block_ns.add(42);
        m0.blocked_sends.add(1);
        m0.service.record(500);
        m0.service.record(1_500);
        let snap = reg.snapshot(512);
        let text = snap.to_json().to_string();
        let back = TelemetrySnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), text);
        assert_eq!(back.shards[0].stage_ns, snap.shards[0].stage_ns);
        assert_eq!(back.shards[0].service_p99_ns, snap.shards[0].service_p99_ns);
        // Malformed input is an error, not a default.
        assert!(TelemetrySnapshot::from_json(&Json::Null).is_err());
    }

    #[test]
    fn render_table_lists_every_shard() {
        let reg = MetricsRegistry::new(true, 3);
        let table = reg.snapshot(0).render_table();
        assert!(table.contains("telemetry:"));
        assert!(table.contains("svc p99"));
        assert!(table.lines().count() >= 5, "{table}");
    }

    #[test]
    fn fmt_ns_humanizes_each_decade() {
        assert_eq!(fmt_ns(17), "17ns");
        assert_eq!(fmt_ns(1_700), "1.7us");
        assert_eq!(fmt_ns(1_700_000), "1.70ms");
        assert_eq!(fmt_ns(1_700_000_000), "1.70s");
    }
}
