//! Log-bucketed latency histogram with bounded-error percentile
//! extraction.
//!
//! Values (nanoseconds, but any `u64` works) land in one of 1920
//! buckets: exact buckets for `0..32`, then 32 sub-buckets per
//! power-of-two decade above. Reported percentiles are each bucket's
//! *inclusive upper bound*, so the estimate never under-reports and
//! overshoots by at most `floor(exact / 32)` — a ≤ 3.125% relative
//! error, pinned against a sorted-`Vec` oracle by the property test
//! below. Recording is a single relaxed `fetch_add`, safe to share
//! across shard workers via `Arc`.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Sub-bucket resolution: 2^5 slices per power-of-two decade.
const SUB_BITS: u32 = 5;
const SUB: u64 = 1 << SUB_BITS;
/// 32 exact low buckets + 32 slices for each exponent 5..=63.
const BUCKETS: usize = SUB as usize + (64 - SUB_BITS as usize) * SUB as usize;

/// Concurrent log-bucketed histogram (relaxed atomics throughout).
#[derive(Debug)]
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        let buckets = (0..BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            buckets,
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Bucket index of `v`; monotone non-decreasing in `v`.
    fn index(v: u64) -> usize {
        if v < SUB {
            v as usize
        } else {
            // Highest set bit h is in 5..=63; keep the top SUB_BITS+1
            // bits, the SUB_BITS below the leader pick the sub-bucket.
            let h = 63 - v.leading_zeros();
            let sub = (v >> (h - SUB_BITS)) & (SUB - 1);
            SUB as usize + (h - SUB_BITS) as usize * SUB as usize + sub as usize
        }
    }

    /// Inclusive upper bound of bucket `i` — the value percentiles
    /// report. For `i < 32` this is exact.
    fn upper(i: usize) -> u64 {
        if i < SUB as usize {
            i as u64
        } else {
            let b = (i - SUB as usize) as u64;
            let e = b / SUB; // exponent offset: width of the bucket is 2^e
            let sub = b % SUB;
            let lo = (1u64 << (e + SUB_BITS as u64)) + (sub << e);
            lo + ((1u64 << e) - 1)
        }
    }

    /// Record one observation.
    pub fn record(&self, v: u64) {
        self.buckets[Self::index(v)].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum.fetch_add(v, Relaxed);
        self.max.fetch_max(v, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Relaxed)
    }

    pub fn max(&self) -> u64 {
        self.max.load(Relaxed)
    }

    /// The `p`-th percentile (`0 < p <= 100`), as the upper bound of
    /// the bucket holding the rank-`ceil(p/100 · n)` observation.
    /// Returns 0 on an empty histogram.
    pub fn percentile(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((p / 100.0 * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Relaxed);
            if cum >= rank {
                return Self::upper(i);
            }
        }
        self.max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    /// Exact quantile oracle: same rank convention as `percentile`.
    fn oracle(sorted: &[u64], p: f64) -> u64 {
        let n = sorted.len() as u64;
        let rank = ((p / 100.0 * n as f64).ceil() as u64).clamp(1, n);
        sorted[(rank - 1) as usize]
    }

    fn pin(values: &[u64]) -> Result<(), String> {
        if values.is_empty() {
            // Shrinkers may propose the empty vector; covered by
            // `empty_histogram_reports_zero`.
            return Ok(());
        }
        let h = Histogram::new();
        for &v in values {
            h.record(v);
        }
        let mut sorted = values.to_vec();
        sorted.sort_unstable();
        for p in [50.0, 95.0, 99.0] {
            let exact = oracle(&sorted, p);
            let approx = h.percentile(p);
            prop_assert!(
                approx >= exact && approx <= exact + exact / SUB,
                "p{p}: approx {approx} vs exact {exact} (n={})",
                values.len()
            );
        }
        prop_assert!(h.max() == *sorted.last().unwrap(), "max mismatch");
        prop_assert!(h.count() == values.len() as u64, "count mismatch");
        Ok(())
    }

    #[test]
    fn percentiles_track_sorted_oracle_uniform() {
        check(
            "hist p50/p95/p99 vs oracle (uniform)",
            11,
            |r| {
                let n = 1 + r.below(400) as usize;
                let span = 1u64 << (1 + r.below(40));
                (0..n).map(|_| r.next_u64() % span).collect::<Vec<u64>>()
            },
            |v| pin(v),
        );
    }

    #[test]
    fn percentiles_track_sorted_oracle_bimodal() {
        check(
            "hist p50/p95/p99 vs oracle (bimodal)",
            12,
            |r| {
                let n = 1 + r.below(300) as usize;
                (0..n)
                    .map(|_| {
                        if r.below(2) == 0 {
                            r.next_u64() % 100 // fast mode
                        } else {
                            1_000_000 + r.next_u64() % 50_000 // slow mode
                        }
                    })
                    .collect::<Vec<u64>>()
            },
            |v| pin(v),
        );
    }

    #[test]
    fn single_sample_is_reported_within_bound() {
        check(
            "hist single sample",
            13,
            |r| vec![r.next_u64() >> (r.below(64) as u32)],
            |v| pin(v),
        );
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(50.0), 0);
        assert_eq!(h.percentile(99.0), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn low_buckets_are_exact() {
        let h = Histogram::new();
        for v in 0..32u64 {
            h.record(v);
        }
        // p50 of 0..=31 at rank 16 is value 15 — exact, no bucket slop.
        assert_eq!(h.percentile(50.0), 15);
        assert_eq!(h.percentile(100.0), 31);
    }

    #[test]
    fn index_is_monotone_and_upper_bounds_hold() {
        let mut r = Rng::new(7);
        let mut probes: Vec<u64> = (0..31).map(|_| r.next_u64()).collect();
        probes.extend([0, 1, 31, 32, 33, 63, 64, u64::MAX]);
        for &v in &probes {
            let i = Histogram::index(v);
            assert!(i < BUCKETS, "index {i} out of range for {v}");
            assert!(Histogram::upper(i) >= v, "upper({i}) < {v}");
            if v > 0 {
                assert!(Histogram::index(v - 1) <= i, "index not monotone at {v}");
            }
        }
        assert_eq!(Histogram::index(u64::MAX), BUCKETS - 1);
        assert_eq!(Histogram::upper(BUCKETS - 1), u64::MAX);
    }
}
