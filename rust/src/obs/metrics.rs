//! Core metric primitives: relaxed-atomic counters and gauges, the
//! pipeline stage taxonomy, and the two timing helpers — a scoped
//! [`StageTimer`] guard and the lap-style [`StageClock`] used by the
//! shared drive loop (one `Instant::now` per stage *boundary*, and
//! none at all when telemetry is off).

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Instant;

/// Monotone counter (relaxed atomic `u64`).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Last-value gauge that also tracks its high-water mark.
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicU64,
    max: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: u64) {
        self.value.store(v, Relaxed);
        self.max.fetch_max(v, Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Relaxed)
    }

    pub fn max(&self) -> u64 {
        self.max.load(Relaxed)
    }
}

/// The five stages of the shared drive loop in `encoding/lane.rs`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// De-interleave one chip's words out of the line chunk.
    Gather,
    /// `encode_batch` through the codec.
    Encode,
    /// Channel transfer + energy/outcome accounting.
    Transmit,
    /// Fault injection (~0 when no fault model is active).
    Inject,
    /// `decode_batch` + error/correction accounting.
    Decode,
}

impl Stage {
    pub const ALL: [Stage; 5] = [
        Stage::Gather,
        Stage::Encode,
        Stage::Transmit,
        Stage::Inject,
        Stage::Decode,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Stage::Gather => "gather",
            Stage::Encode => "encode",
            Stage::Transmit => "transmit",
            Stage::Inject => "inject",
            Stage::Decode => "decode",
        }
    }

    fn index(self) -> usize {
        self as usize
    }
}

/// Cumulative nanoseconds per stage plus a batch counter; one per
/// shard, shared across that shard's eight chip lanes.
#[derive(Debug, Default)]
pub struct StageSet {
    ns: [Counter; 5],
    batches: Counter,
}

impl StageSet {
    pub fn add(&self, stage: Stage, ns: u64) {
        self.ns[stage.index()].add(ns);
    }

    pub fn ns(&self, stage: Stage) -> u64 {
        self.ns[stage.index()].get()
    }

    pub fn total_ns(&self) -> u64 {
        Stage::ALL.iter().map(|&s| self.ns(s)).sum()
    }

    pub fn add_batch(&self) {
        self.batches.add(1);
    }

    pub fn batches(&self) -> u64 {
        self.batches.get()
    }

    /// Scoped timer: charges the elapsed time to `stage` on drop.
    pub fn timer(&self, stage: Stage) -> StageTimer<'_> {
        StageTimer {
            set: self,
            stage,
            start: Instant::now(),
        }
    }
}

/// RAII guard from [`StageSet::timer`]; adds the elapsed nanoseconds
/// to its stage when dropped.
#[derive(Debug)]
pub struct StageTimer<'a> {
    set: &'a StageSet,
    stage: Stage,
    start: Instant,
}

impl Drop for StageTimer<'_> {
    fn drop(&mut self) {
        self.set.add(self.stage, self.start.elapsed().as_nanos() as u64);
    }
}

/// Lap clock for straight-line stage sequences: `lap(stage)` charges
/// the time since the previous lap (or `start`) to `stage` with a
/// single `Instant::now` per boundary. Constructed from an
/// `Option<&StageSet>` — when `None`, every call is a no-op and no
/// clock is ever read, which is the telemetry-off overhead contract.
#[derive(Debug)]
pub struct StageClock<'a> {
    at: Option<(Instant, &'a StageSet)>,
}

impl<'a> StageClock<'a> {
    pub fn start(set: Option<&'a StageSet>) -> StageClock<'a> {
        StageClock {
            at: set.map(|s| (Instant::now(), s)),
        }
    }

    pub fn lap(&mut self, stage: Stage) {
        if let Some((at, set)) = &mut self.at {
            let now = Instant::now();
            set.add(stage, now.duration_since(*at).as_nanos() as u64);
            *at = now;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::default();
        c.add(3);
        c.add(4);
        assert_eq!(c.get(), 7);

        let g = Gauge::default();
        g.set(5);
        g.set(2);
        assert_eq!(g.get(), 2);
        assert_eq!(g.max(), 5);
    }

    /// Busy-wait until the monotonic clock has visibly advanced, so
    /// timing assertions hold even under coarse clock resolution.
    fn tick() {
        let mark = Instant::now();
        while mark.elapsed().as_nanos() == 0 {
            std::hint::black_box(0u64);
        }
    }

    #[test]
    fn stage_timer_charges_its_stage() {
        let set = StageSet::default();
        {
            let _t = set.timer(Stage::Encode);
            tick();
        }
        assert!(set.ns(Stage::Encode) > 0);
        assert_eq!(set.ns(Stage::Decode), 0);
        assert_eq!(set.total_ns(), set.ns(Stage::Encode));
    }

    #[test]
    fn stage_clock_laps_accumulate_and_none_is_inert() {
        let set = StageSet::default();
        let mut clock = StageClock::start(Some(&set));
        tick();
        clock.lap(Stage::Gather);
        tick();
        clock.lap(Stage::Decode);
        assert!(set.ns(Stage::Gather) > 0);
        assert!(set.ns(Stage::Decode) > 0);

        let mut off = StageClock::start(None);
        off.lap(Stage::Encode); // must not panic, must not record
        assert_eq!(set.ns(Stage::Encode), 0);
    }

    #[test]
    fn stage_labels_are_stable_json_keys() {
        let labels: Vec<&str> = Stage::ALL.iter().map(|s| s.label()).collect();
        let want = ["gather", "encode", "transmit", "inject", "decode"];
        assert_eq!(labels, want);
    }
}
