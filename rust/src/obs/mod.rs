//! Observability: zero-dependency runtime telemetry for the data
//! plane.
//!
//! Three pieces:
//!
//! * [`metrics`] — relaxed-atomic [`Counter`]/[`Gauge`] primitives,
//!   the drive-loop [`Stage`] taxonomy, and the [`StageTimer`] /
//!   [`StageClock`] timing helpers.
//! * [`hist`] — the log-bucketed [`Histogram`] behind the service
//!   latency p50/p95/p99 (≤ 3.125% overshoot, never under-reports).
//! * [`registry`] — [`MetricsRegistry`] owning per-shard
//!   [`ShardMetrics`], frozen into a [`TelemetrySnapshot`] that
//!   renders and serializes as the `"telemetry"` report section.
//!
//! Overhead contract: instrumentation is compiled in but every clock
//! read is gated on an enable flag carried by the registry (or the
//! `Option`-ness of a `StageSet` reference), so a telemetry-off run
//! does no `Instant::now` calls in the hot loop and the energy /
//! bit-identity accounting is untouched either way.

pub mod hist;
pub mod metrics;
pub mod registry;

pub use hist::Histogram;
pub use metrics::{Counter, Gauge, Stage, StageClock, StageSet, StageTimer};
pub use registry::{MetricsRegistry, ShardMetrics, ShardSnapshot, TelemetrySnapshot};

/// Read the `ZAC_METRICS` toggle: `"1"` enables telemetry, unset or
/// `"0"` disables it; anything else is an error (fail loud, like the
/// other `ZAC_*` overrides).
pub fn metrics_from_env() -> anyhow::Result<bool> {
    match std::env::var("ZAC_METRICS") {
        Err(_) => Ok(false),
        Ok(v) if v == "1" => Ok(true),
        Ok(v) if v == "0" => Ok(false),
        Ok(v) => anyhow::bail!("ZAC_METRICS: expected \"0\" or \"1\", got {v:?}"),
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn metrics_env_parses_strictly() {
        // Can't mutate the real env safely under the parallel test
        // runner; pin the parse rules through a local copy of the
        // match arms instead.
        let parse = |v: Option<&str>| -> anyhow::Result<bool> {
            match v {
                None => Ok(false),
                Some("1") => Ok(true),
                Some("0") => Ok(false),
                Some(v) => anyhow::bail!("ZAC_METRICS: expected \"0\" or \"1\", got {v:?}"),
            }
        };
        assert!(!parse(None).unwrap());
        assert!(parse(Some("1")).unwrap());
        assert!(!parse(Some("0")).unwrap());
        assert!(parse(Some("yes")).is_err());
    }
}
