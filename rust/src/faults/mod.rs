//! Approximate-DRAM fault-injection layer (EDEN / SparkXD-style error
//! models).
//!
//! The repo's channel was perfect until this module: nothing ever
//! flipped a bit, so the paper's quality-loss axis on *error resilient*
//! applications was unreproducible. EDEN (arXiv:1910.05340) models
//! voltage/latency-scaled DRAM as a bit-error-rate that rises roughly
//! one decade per ~50 mV below nominal, weighted toward 1→0 flips
//! (charge loss in true cells); SparkXD (arXiv:2103.00421) splits
//! traffic by criticality so only error-resilient accesses ride the
//! scaled (faulty) path.
//!
//! Both ideas land here:
//!
//! * [`FaultModel`] — the deterministic, seed-driven corruption hook
//!   the one shared drive loop ([`crate::encoding::lane::drive_batches`])
//!   applies to the wire **between** `transmit_batch` and
//!   `decode_batch`. Energy accounting is untouched by construction
//!   (the transfer already happened); only what the receiver *senses*
//!   changes.
//! * [`FaultSpec`] — the serializable knob bag every ingestion boundary
//!   (CLI `--faults`, run/sweep TOML, `Session::builder().faults(..)`)
//!   parses and validates, mirroring the `CodecSpec` contract: a bad
//!   spec is an error at the boundary, never a silent fallback.
//! * Criticality split: the drive loop only corrupts words whose
//!   per-access flag marks them error-resilient —
//!   [`TrafficClass::Critical`](crate::session::TrafficClass) streams
//!   bypass injection entirely, SparkXD-style. (The guarantee is
//!   per-access *injection*; in a mixed per-word stream, corruption of
//!   an approximate transfer can propagate through a table-based
//!   codec's shared mirror state into later words — see
//!   `encoding::lane` for the exact scope.)
//!
//! Determinism contract: a model's flip sequence is a pure function of
//! `(spec seed, shard, chip, words seen so far)`. There is no wall-clock
//! or OS entropy anywhere, so a fixed-seed run is byte-for-byte
//! reproducible at any channel count, and `FaultSpec::perfect()` is
//! pinned bit-identical to the historical no-fault path by property
//! tests (`rust/tests/faults.rs`).

pub mod model;
pub mod profile;

pub use model::{FaultModel, PerLaneBer, PerfectChannel, UniformBer};
pub use profile::FaultProfile;

/// Per-stream fault-injection statistics, merged across chips and
/// shards exactly like [`EncodeStats`](crate::encoding::EncodeStats).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Wire data bits flipped by the model.
    pub injected_bits: u64,
    /// Transfers with at least one injected flip.
    pub injected_words: u64,
    /// End-to-end error bits: Σ hamming(original word, decoded word).
    /// Includes codec approximation *and* fault propagation, so with a
    /// perfect channel this is the pure approximation error.
    pub observed_error_bits: u64,
    /// Words driven (denominator for the rates below).
    pub words: u64,
}

impl FaultStats {
    /// Merge another stream's stats (per-chip / per-shard aggregation).
    pub fn merge(&mut self, o: &FaultStats) {
        self.injected_bits += o.injected_bits;
        self.injected_words += o.injected_words;
        self.observed_error_bits += o.observed_error_bits;
        self.words += o.words;
    }

    /// Injected flips per transferred data bit (the measured BER).
    pub fn injected_ber(&self) -> f64 {
        if self.words == 0 {
            0.0
        } else {
            self.injected_bits as f64 / (self.words as f64 * 64.0)
        }
    }

    /// End-to-end error bits per data bit (the quality-delta rate).
    pub fn observed_error_rate(&self) -> f64 {
        if self.words == 0 {
            0.0
        } else {
            self.observed_error_bits as f64 / (self.words as f64 * 64.0)
        }
    }
}

/// Which error model a [`FaultSpec`] builds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// No corruption — the historical behaviour, and the default.
    Perfect,
    /// Uniform BER across all lanes with 1→0/0→1 asymmetry.
    Uniform {
        /// Overall bit-error rate in [0, 1].
        ber: f64,
        /// Fraction of flips that are 1→0 on balanced data, in [0, 1]
        /// (charge-loss asymmetry; EDEN's default here is 0.75).
        one_to_zero_fraction: f64,
    },
    /// EDEN-style voltage-binned profile: the supply-voltage knob maps
    /// to a per-lane BER through [`FaultProfile`].
    Voltage {
        /// DRAM supply voltage in millivolts
        /// ([`FaultProfile::MIN_MV`]..=[`FaultProfile::NOMINAL_MV`]).
        millivolts: u32,
    },
}

/// A validated, serializable fault-model description: the fault-layer
/// analogue of [`CodecSpec`](crate::encoding::CodecSpec).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    pub kind: FaultKind,
    /// Base seed; each (shard, chip) lane derives a decorrelated
    /// sub-stream from it.
    pub seed: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec::perfect()
    }
}

impl FaultSpec {
    /// Default injection seed (any fixed value works; this one is just
    /// recognizable in reports).
    pub const DEFAULT_SEED: u64 = 0x5EED_FA17;

    /// The charge-loss asymmetry used when a spec doesn't pick its own:
    /// three of four flips discharge a stored 1.
    pub const DEFAULT_ONE_TO_ZERO_FRACTION: f64 = 0.75;

    /// No corruption (the historical behaviour).
    pub fn perfect() -> FaultSpec {
        FaultSpec {
            kind: FaultKind::Perfect,
            seed: Self::DEFAULT_SEED,
        }
    }

    /// Uniform BER with the default 1→0 bias.
    pub fn uniform(ber: f64) -> FaultSpec {
        FaultSpec {
            kind: FaultKind::Uniform {
                ber,
                one_to_zero_fraction: Self::DEFAULT_ONE_TO_ZERO_FRACTION,
            },
            seed: Self::DEFAULT_SEED,
        }
    }

    /// EDEN-style voltage-scaled profile at `millivolts`.
    pub fn voltage(millivolts: u32) -> FaultSpec {
        FaultSpec {
            kind: FaultKind::Voltage { millivolts },
            seed: Self::DEFAULT_SEED,
        }
    }

    /// Same spec with an explicit base seed.
    pub fn with_seed(mut self, seed: u64) -> FaultSpec {
        self.seed = seed;
        self
    }

    /// Whether this spec can never flip a bit (lets every layer keep
    /// the historical fast path).
    pub fn is_perfect(&self) -> bool {
        match self.kind {
            FaultKind::Perfect => true,
            FaultKind::Uniform { ber, .. } => ber <= 0.0,
            FaultKind::Voltage { millivolts } => {
                FaultProfile::ber_at(millivolts) <= 0.0
            }
        }
    }

    /// Validate the spec. Every ingestion boundary calls this before a
    /// model is built — mirrors `CodecSpec::validate`.
    pub fn validate(&self) -> anyhow::Result<()> {
        match self.kind {
            FaultKind::Perfect => Ok(()),
            FaultKind::Uniform {
                ber,
                one_to_zero_fraction,
            } => {
                anyhow::ensure!(
                    (0.0..=1.0).contains(&ber) && ber.is_finite(),
                    "fault BER {ber} out of range [0, 1]"
                );
                anyhow::ensure!(
                    (0.0..=1.0).contains(&one_to_zero_fraction),
                    "1->0 fraction {one_to_zero_fraction} out of range [0, 1]"
                );
                Ok(())
            }
            FaultKind::Voltage { millivolts } => {
                anyhow::ensure!(
                    (FaultProfile::MIN_MV..=FaultProfile::NOMINAL_MV)
                        .contains(&millivolts),
                    "supply voltage {millivolts} mV outside the modelled \
                     scaling range [{}, {}] mV",
                    FaultProfile::MIN_MV,
                    FaultProfile::NOMINAL_MV
                );
                Ok(())
            }
        }
    }

    /// Short label for scenario rows / figure legends, e.g. `perfect`,
    /// `ber1e-4`, `vdd1050mV`. Faithful and collision-free: the exact
    /// BER is printed (no rounding), a non-default 1→0 fraction is
    /// appended as `:f<frac>` and a non-default seed as `@<seed>`, so
    /// distinct sweep cells never collapse to one label.
    pub fn label(&self) -> String {
        let mut label = match self.kind {
            FaultKind::Perfect => "perfect".to_string(),
            FaultKind::Uniform {
                ber,
                one_to_zero_fraction,
            } => {
                let mut l = format!("ber{ber:e}");
                if one_to_zero_fraction != Self::DEFAULT_ONE_TO_ZERO_FRACTION {
                    l.push_str(&format!(":f{one_to_zero_fraction}"));
                }
                l
            }
            FaultKind::Voltage { millivolts } => format!("vdd{millivolts}mV"),
        };
        if self.seed != Self::DEFAULT_SEED && !self.is_perfect() {
            label.push_str(&format!("@{}", self.seed));
        }
        label
    }

    /// Parse the uniform textual form shared by CLI flags and TOML:
    ///
    /// * `perfect`
    /// * `uniform:<ber>` or `uniform:<ber>:<one_to_zero_fraction>`
    /// * `voltage:<millivolts>`
    ///
    /// any of which may carry an `@<seed>` suffix (`voltage:1050@7`).
    /// Unknown model names and malformed numbers are rejected — same
    /// "no silent knob absorption" contract as `CodecSpec::set_knob`.
    pub fn parse(text: &str) -> anyhow::Result<FaultSpec> {
        let text = text.trim();
        let (body, seed) = match text.split_once('@') {
            Some((body, s)) => {
                let seed: u64 = s
                    .trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("fault seed {s:?}: {e}"))?;
                (body.trim(), seed)
            }
            None => (text, Self::DEFAULT_SEED),
        };
        let mut parts = body.split(':');
        let name = parts.next().unwrap_or("").trim().to_ascii_lowercase();
        let args: Vec<&str> = parts.map(|p| p.trim()).collect();
        let num = |what: &str, s: &str| -> anyhow::Result<f64> {
            s.parse::<f64>()
                .map_err(|e| anyhow::anyhow!("fault {what} {s:?}: {e}"))
        };
        let spec = match name.as_str() {
            "perfect" | "none" => {
                anyhow::ensure!(args.is_empty(), "perfect takes no arguments");
                FaultSpec::perfect()
            }
            "uniform" | "ber" => {
                anyhow::ensure!(
                    (1..=2).contains(&args.len()),
                    "uniform needs uniform:<ber>[:<one_to_zero_fraction>]"
                );
                let ber = num("BER", args[0])?;
                let frac = match args.get(1) {
                    Some(s) => num("1->0 fraction", s)?,
                    None => Self::DEFAULT_ONE_TO_ZERO_FRACTION,
                };
                FaultSpec {
                    kind: FaultKind::Uniform {
                        ber,
                        one_to_zero_fraction: frac,
                    },
                    seed: Self::DEFAULT_SEED,
                }
            }
            "voltage" | "vdd" => {
                anyhow::ensure!(
                    args.len() == 1,
                    "voltage needs voltage:<millivolts>"
                );
                let mv = num("voltage", args[0])?;
                anyhow::ensure!(
                    mv >= 0.0 && mv.fract() == 0.0,
                    "voltage must be a whole number of millivolts, got {mv}"
                );
                FaultSpec::voltage(mv as u32)
            }
            other => anyhow::bail!(
                "unknown fault model {other:?}; known: perfect, \
                 uniform:<ber>[:<frac>], voltage:<mV> (each optionally @<seed>)"
            ),
        };
        let spec = spec.with_seed(seed);
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a comma-separated fault axis, e.g.
    /// `perfect,voltage:1050,uniform:1e-4`.
    pub fn parse_list(text: &str) -> anyhow::Result<Vec<FaultSpec>> {
        let list: Vec<FaultSpec> = text
            .split(',')
            .map(FaultSpec::parse)
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(!list.is_empty(), "empty fault list");
        Ok(list)
    }

    /// Build the model instance for one lane. Each `(shard, chip)` pair
    /// gets a decorrelated sub-seed, so lanes inject independent
    /// streams while the whole run stays a pure function of the base
    /// seed.
    pub fn build(&self, shard: usize, chip: usize) -> Box<dyn FaultModel> {
        let seed = lane_seed(self.seed, shard, chip);
        match self.kind {
            FaultKind::Perfect => Box::new(PerfectChannel),
            FaultKind::Uniform {
                ber,
                one_to_zero_fraction,
            } => Box::new(UniformBer::new(seed, ber, one_to_zero_fraction)),
            FaultKind::Voltage { millivolts } => {
                Box::new(FaultProfile::eden(millivolts).model(seed))
            }
        }
    }
}

/// Decorrelate one lane's injection stream from its siblings: mix the
/// (shard, chip) coordinates in with a golden-ratio stride before the
/// RNG's own splitmix seeding. Adjacent base seeds and adjacent lanes
/// both land far apart.
fn lane_seed(seed: u64, shard: usize, chip: usize) -> u64 {
    let lane = ((shard as u64) << 8) | (chip as u64 + 1);
    seed ^ lane.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::WireWord;

    #[test]
    fn parse_accepts_the_documented_forms() {
        assert_eq!(FaultSpec::parse("perfect").unwrap(), FaultSpec::perfect());
        let u = FaultSpec::parse("uniform:1e-3").unwrap();
        assert_eq!(
            u.kind,
            FaultKind::Uniform {
                ber: 1e-3,
                one_to_zero_fraction: FaultSpec::DEFAULT_ONE_TO_ZERO_FRACTION
            }
        );
        let u = FaultSpec::parse("uniform:0.01:0.9@77").unwrap();
        assert_eq!(u.seed, 77);
        assert_eq!(
            u.kind,
            FaultKind::Uniform {
                ber: 0.01,
                one_to_zero_fraction: 0.9
            }
        );
        let v = FaultSpec::parse(" voltage:1050 ").unwrap();
        assert_eq!(v.kind, FaultKind::Voltage { millivolts: 1050 });
        assert!(!v.is_perfect());
        assert!(FaultSpec::parse("vdd:1250@3").unwrap().is_perfect());
        assert_eq!(
            FaultSpec::parse_list("perfect,voltage:1050").unwrap().len(),
            2
        );
    }

    #[test]
    fn parse_rejects_unknown_models_and_bad_numbers() {
        for bad in [
            "wat",
            "uniform",
            "uniform:lots",
            "uniform:2.0", // BER out of range
            "uniform:1e-3:1.5",
            "voltage",
            "voltage:12.5",
            "voltage:400", // below modelled range
            "voltage:1050@zzz",
            "perfect:1",
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "{bad:?} accepted");
        }
        assert!(FaultSpec::parse_list("").is_err());
    }

    #[test]
    fn labels_are_stable_faithful_and_collision_free() {
        assert_eq!(FaultSpec::perfect().label(), "perfect");
        assert_eq!(FaultSpec::uniform(1e-4).label(), "ber1e-4");
        assert_eq!(FaultSpec::voltage(1050).label(), "vdd1050mV");
        // The exact BER is printed, never rounded to one digit.
        assert_eq!(FaultSpec::uniform(1.5e-4).label(), "ber1.5e-4");
        // Distinct fractions / seeds get distinct labels.
        let a = FaultSpec::parse("uniform:1e-3:0.5").unwrap().label();
        let b = FaultSpec::parse("uniform:1e-3:0.9").unwrap().label();
        assert_ne!(a, b);
        assert_eq!(a, "ber1e-3:f0.5");
        let c = FaultSpec::parse("uniform:1e-3@1").unwrap().label();
        let d = FaultSpec::parse("uniform:1e-3@2").unwrap().label();
        assert_ne!(c, d);
        assert_eq!(d, "ber1e-3@2");
        assert_eq!(FaultSpec::voltage(1000).with_seed(9).label(), "vdd1000mV@9");
        // A non-default seed on a perfect spec changes nothing, so the
        // label stays clean.
        assert_eq!(FaultSpec::perfect().with_seed(9).label(), "perfect");
    }

    #[test]
    fn lane_seeds_decorrelate() {
        let mut seen = std::collections::BTreeSet::new();
        for shard in 0..4 {
            for chip in 0..8 {
                assert!(seen.insert(lane_seed(42, shard, chip)));
            }
        }
        assert_ne!(lane_seed(1, 0, 0), lane_seed(2, 0, 0));
    }

    #[test]
    fn stats_merge_adds() {
        let mut a = FaultStats {
            injected_bits: 3,
            injected_words: 2,
            observed_error_bits: 5,
            words: 10,
        };
        let b = FaultStats {
            injected_bits: 1,
            injected_words: 1,
            observed_error_bits: 2,
            words: 6,
        };
        a.merge(&b);
        assert_eq!(a.injected_bits, 4);
        assert_eq!(a.injected_words, 3);
        assert_eq!(a.observed_error_bits, 7);
        assert_eq!(a.words, 16);
        assert!((a.injected_ber() - 4.0 / (16.0 * 64.0)).abs() < 1e-15);
        assert!(FaultStats::default().injected_ber() == 0.0);
    }

    #[test]
    fn built_models_are_deterministic_per_lane() {
        let spec = FaultSpec::uniform(0.05).with_seed(9);
        let mut a = spec.build(1, 3);
        let mut b = spec.build(1, 3);
        let mut c = spec.build(1, 4);
        let mut same = true;
        let mut diff = false;
        for i in 0..256u64 {
            let word = i.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut wa = WireWord::raw(word);
            let mut wb = WireWord::raw(word);
            let mut wc = WireWord::raw(word);
            a.corrupt(&mut wa);
            b.corrupt(&mut wb);
            c.corrupt(&mut wc);
            same &= wa == wb;
            diff |= wa != wc;
        }
        assert!(same, "same lane + seed must corrupt identically");
        assert!(diff, "sibling lanes must inject independent streams");
    }
}
